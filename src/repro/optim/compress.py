"""Int8 gradient compression codec with error feedback.

The distributed-optimization trick for cross-pod gradient reduction: before
the (slow, DCN-bound) pod-axis all-reduce, gradients are quantized to int8
with a per-tensor scale; the quantization residual is carried to the next
step (error feedback), which keeps SGD/Adam convergence intact in practice.

Under GSPMD the data/model-axis reductions are emitted by XLA, so this
codec is applied at the optimizer boundary (quantize -> dequantize + error
state).  The bandwidth saving applies when the launcher routes the pod-axis
reduction through :func:`compressed_psum` inside a shard_map block; on this
CPU container we validate the numerics (round-trip error, error-feedback
accumulation) and count the 4x byte reduction in the roofline's collective
term when the flag is on.
"""

from __future__ import annotations

from typing import Any, Optional, Tuple

import jax
import jax.numpy as jnp


def quantize_int8(x: jax.Array, key: Optional[jax.Array] = None
                  ) -> Tuple[jax.Array, jax.Array]:
    """Per-tensor symmetric int8 quantization; stochastic rounding if a
    PRNG key is given."""
    xf = x.astype(jnp.float32)
    scale = jnp.maximum(jnp.max(jnp.abs(xf)), 1e-12) / 127.0
    y = xf / scale
    if key is not None:
        y = jnp.floor(y + jax.random.uniform(key, y.shape))
    else:
        y = jnp.round(y)
    return jnp.clip(y, -127, 127).astype(jnp.int8), scale


def dequantize_int8(q: jax.Array, scale: jax.Array) -> jax.Array:
    return q.astype(jnp.float32) * scale


def int8_codec_roundtrip(x: jax.Array, err: Optional[jax.Array] = None,
                         key: Optional[jax.Array] = None
                         ) -> Tuple[jax.Array, jax.Array]:
    """Quantize->dequantize with error feedback: returns (x_hat, new_err)
    where x_hat + new_err == x + err (up to fp32)."""
    target = x.astype(jnp.float32) + (0.0 if err is None else err)
    q, s = quantize_int8(target, key)
    xhat = dequantize_int8(q, s)
    return xhat, target - xhat


def compress_grads(grads: Any, err_state: Optional[Any] = None) -> Tuple[Any, Any]:
    """Apply the codec leaf-wise across a gradient pytree."""
    if err_state is None:
        err_state = jax.tree.map(lambda g: jnp.zeros(g.shape, jnp.float32), grads)
    out = jax.tree.map(lambda g, e: int8_codec_roundtrip(g, e), grads, err_state)
    xhat = jax.tree.map(lambda o: o[0], out, is_leaf=lambda x: isinstance(x, tuple))
    err = jax.tree.map(lambda o: o[1], out, is_leaf=lambda x: isinstance(x, tuple))
    return xhat, err
