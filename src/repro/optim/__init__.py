"""Optimizers for the training plane."""

from .adamw import AdamWConfig, adamw_init, adamw_update, cosine_schedule
from .compress import int8_codec_roundtrip, quantize_int8, dequantize_int8

__all__ = [
    "AdamWConfig", "adamw_init", "adamw_update", "cosine_schedule",
    "int8_codec_roundtrip", "quantize_int8", "dequantize_int8",
]
