"""AdamW with mixed-precision master weights and global-norm clipping.

State layout (all sharded like the params by the launcher):
  master: fp32 copy of the params (optional — ``keep_master=False`` runs
          the memory-lean variant that updates the bf16 params directly)
  m, v:   fp32 moments
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp


@dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    keep_master: bool = True
    warmup_steps: int = 100
    total_steps: int = 10000
    min_lr_ratio: float = 0.1


def cosine_schedule(cfg: AdamWConfig, step: jax.Array) -> jax.Array:
    step = step.astype(jnp.float32)
    warm = jnp.minimum(step / jnp.maximum(cfg.warmup_steps, 1), 1.0)
    t = jnp.clip((step - cfg.warmup_steps)
                 / jnp.maximum(cfg.total_steps - cfg.warmup_steps, 1), 0.0, 1.0)
    cos = 0.5 * (1 + jnp.cos(jnp.pi * t))
    return cfg.lr * warm * (cfg.min_lr_ratio + (1 - cfg.min_lr_ratio) * cos)


def adamw_init(cfg: AdamWConfig, params: Any) -> Dict[str, Any]:
    zeros = lambda p: jnp.zeros(p.shape, jnp.float32)
    st = {
        "m": jax.tree.map(zeros, params),
        "v": jax.tree.map(zeros, params),
        "step": jnp.zeros((), jnp.int32),
    }
    if cfg.keep_master:
        # copy=True: fp32 params would otherwise alias the master buffer,
        # which breaks donation (same buffer donated twice).
        st["master"] = jax.tree.map(
            lambda p: jnp.array(p, jnp.float32, copy=True), params)
    return st


def global_norm(tree: Any) -> jax.Array:
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
                        for x in jax.tree.leaves(tree)))


def adamw_update(cfg: AdamWConfig, params: Any, grads: Any,
                 state: Dict[str, Any]) -> Tuple[Any, Dict[str, Any], Dict[str, jax.Array]]:
    """Returns (new_params [param dtype], new_state, metrics)."""
    step = state["step"] + 1
    gnorm = global_norm(grads)
    clip = jnp.minimum(1.0, cfg.grad_clip / jnp.maximum(gnorm, 1e-9))
    lr = cosine_schedule(cfg, step)
    b1c = 1 - cfg.b1 ** step.astype(jnp.float32)
    b2c = 1 - cfg.b2 ** step.astype(jnp.float32)

    src = state.get("master", params)

    def upd(p, g, m, v):
        g = g.astype(jnp.float32) * clip
        m = cfg.b1 * m + (1 - cfg.b1) * g
        v = cfg.b2 * v + (1 - cfg.b2) * g * g
        mhat = m / b1c
        vhat = v / b2c
        pf = p.astype(jnp.float32)
        pf = pf - lr * (mhat / (jnp.sqrt(vhat) + cfg.eps) + cfg.weight_decay * pf)
        return pf, m, v

    flat_p, treedef = jax.tree.flatten(src)
    flat_g = jax.tree.leaves(grads)
    flat_m = jax.tree.leaves(state["m"])
    flat_v = jax.tree.leaves(state["v"])
    out = [upd(p, g, m, v) for p, g, m, v in zip(flat_p, flat_g, flat_m, flat_v)]
    new_master = jax.tree.unflatten(treedef, [o[0] for o in out])
    new_m = jax.tree.unflatten(treedef, [o[1] for o in out])
    new_v = jax.tree.unflatten(treedef, [o[2] for o in out])

    param_dtypes = jax.tree.map(lambda p: p.dtype, params)
    new_params = jax.tree.map(lambda f, dt: f.astype(dt), new_master, param_dtypes)
    new_state = {"m": new_m, "v": new_v, "step": step}
    if cfg.keep_master:
        new_state["master"] = new_master
    metrics = {"grad_norm": gnorm, "lr": lr}
    return new_params, new_state, metrics
