"""Train / prefill / decode step builders (pjit-able pure functions)."""

from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models.api import Model
from repro.optim.adamw import AdamWConfig, adamw_init, adamw_update


def make_train_state(model: Model, opt_cfg: AdamWConfig, rng) -> Dict[str, Any]:
    params = model.init(rng)
    return {"params": params, "opt": adamw_init(opt_cfg, params)}


def train_state_shape(model: Model, opt_cfg: AdamWConfig) -> Dict[str, Any]:
    """ShapeDtypeStruct tree of the train state — no allocation."""
    return jax.eval_shape(
        lambda r: make_train_state(model, opt_cfg, r), jax.random.PRNGKey(0))


def make_train_step(model: Model, opt_cfg: AdamWConfig):
    def train_step(state: Dict[str, Any], batch: Dict[str, jax.Array]):
        loss, grads = jax.value_and_grad(model.loss)(state["params"], batch)
        new_params, new_opt, metrics = adamw_update(
            opt_cfg, state["params"], grads, state["opt"])
        metrics = dict(metrics, loss=loss)
        return {"params": new_params, "opt": new_opt}, metrics

    return train_step


def make_prefill_step(model: Model, max_len: int):
    def prefill_step(params, batch):
        return model.prefill(params, batch, max_len)

    return prefill_step


def make_decode_step(model: Model):
    def decode_step(params, cache, token, pos):
        return model.decode_step(params, cache, token, pos)

    return decode_step


def make_generate_loop(model: Model, steps: int):
    """Greedy generation: prefill + `steps` decode steps under one jit."""

    def generate(params, batch, max_len):
        logits, cache = model.prefill(params, batch, max_len)
        B, S = batch["tokens"].shape
        tok = jnp.argmax(logits[:, : model.cfg.vocab_size], -1)

        def body(carry, t):
            tok, cache = carry
            pos = jnp.full((B,), S + t, jnp.int32)
            logits, cache = model.decode_step(params, cache, tok, pos)
            tok = jnp.argmax(logits[:, : model.cfg.vocab_size], -1)
            return (tok, cache), tok

        (_, _), toks = jax.lax.scan(body, (tok, cache), jnp.arange(steps))
        return jnp.moveaxis(toks, 0, 1)  # (B, steps)

    return generate
