"""Distributed launch plane: production mesh, sharding rules, train/serve
steps, input specs and the multi-pod dry-run driver."""
