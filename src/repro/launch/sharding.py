"""Sharding rules: PartitionSpec trees for params, optimizer state,
batches and caches.

Strategy (DP + FSDP + TP + EP, adaptively per tensor):

* batch dims shard over the data axes — ("pod", "data") on the multi-pod
  mesh — i.e. plain DP with the pod axis as an outer data axis;
* every parameter is FSDP-sharded over "data" on its d_model-like dim and
  TP-sharded over "model" on its heads/ffn/vocab/expert dim *when
  divisible* — a preference list per tensor name, applied greedily with
  axis-uniqueness and divisibility checks, so e.g. MQA (kv=1) or 28-head
  attention simply skips the model axis instead of failing;
* MoE experts shard over "model" (EP) when num_experts divides it, else
  the per-expert FFN dim takes the model axis (TP-within-expert;
  granite's 40 experts on a 16-wide axis);
* KV caches shard batch over data and kv-heads (or head_dim, for MQA)
  over model; SSM/RWKV states shard batch + heads.

Preferences use *negative* dim indices so the same rule covers a plain
tensor and its layer-stacked twin (scan-over-layers adds a leading axis).
"""

from __future__ import annotations

import re
from typing import Any, Dict, List, Optional, Sequence, Tuple

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

DATA = "data"
MODEL = "model"

def _key(name: str) -> str:
    """Anchor a leaf name as the final tree_util keystr component."""
    return rf"\['{name}'\]$"


# (path regex, [(negative_dim, role), ...]) — first match wins.
# roles: "dp" (all data axes), "data" (FSDP axis), "model" (TP axis)
PARAM_RULES: List[Tuple[str, List[Tuple[int, str]]]] = [
    # vocab on "model" ONLY: sharding the d_model dim of the embedding over
    # "data" makes XLA psum (B,C,V)-sized logits partial-products in the
    # chunked loss — 100+ GiB of all-reduce per step (measured in the
    # dry-run, see EXPERIMENTS.md §Perf iteration 1).
    (r"embed.*tok", [(-2, "model")]),
    (r"lm_head", [(-1, "model")]),
    (r"pos_dec", [(-2, "data")]),
    # attention (plain + cross)
    (r"attn'\].*" + _key("w[qkv]"), [(-3, "data"), (-2, "model")]),
    (r"attn'\].*" + _key("wo"), [(-3, "model"), (-1, "data")]),
    (r"attn'\].*" + _key("b[qkv]"), [(-2, "model")]),
    # MLA
    (r"q_down", [(-2, "data"), (-1, "model")]),
    (r"q_up", [(-3, "data"), (-2, "model")]),
    (r"kv_down", [(-2, "data")]),
    (r"[kv]_up", [(-3, "data"), (-2, "model")]),
    # MoE (before generic ffn rules; shared experts first)
    (r"router", [(-2, "data")]),
    (r"shared'\].*" + _key("w[ig]"), [(-2, "data"), (-1, "model")]),
    (r"shared'\].*" + _key("wo"), [(-2, "model"), (-1, "data")]),
    # ffn covers MoE 3-D (E,D,F) and dense 2-D (D,F): prefs skip missing
    # dims, and the greedy axis-unique pass resolves the rest.
    (r"ffn'\].*" + _key("w[ig]"), [(-3, "model"), (-2, "data"), (-1, "model")]),
    (r"ffn'\].*" + _key("wo"), [(-3, "model"), (-2, "model"), (-1, "data")]),
    (r"mlp'\].*" + _key("w[ig]"), [(-2, "data"), (-1, "model")]),
    (r"mlp'\].*" + _key("wo"), [(-2, "model"), (-1, "data")]),
    # Mamba2
    (r"mixer'\].*" + _key("in_proj"), [(-2, "data")]),
    (r"mixer'\].*" + _key("out_proj"), [(-2, "model"), (-1, "data")]),
    # RWKV6
    (r"tm'\].*" + _key("w[rkvg]"), [(-2, "data"), (-1, "model")]),
    (r"tm'\].*" + _key("wo"), [(-2, "model"), (-1, "data")]),
    (r"tm'\].*" + _key("cm_k"), [(-2, "data"), (-1, "model")]),
    (r"tm'\].*" + _key("cm_v"), [(-2, "model"), (-1, "data")]),
    (r"tm'\].*" + _key("cm_r"), [(-2, "data"), (-1, "model")]),
    (r"tm'\].*" + _key("mix_w1"), [(-2, "data")]),
    (r"tm'\].*" + _key("mix_w2"), [(-1, "data")]),
    (r"tm'\].*" + _key("w1"), [(-2, "data")]),
    (r"tm'\].*" + _key("w2"), [(-1, "data")]),
    (r"tm'\].*" + _key("u"), [(-2, "model")]),
]

#: decode caches: batch over the data axes; kv-heads over "model" when
#: divisible, else the *sequence* dim (distributed cache for MQA /
#: batch=1 long-context cells).
CACHE_RULES: List[Tuple[str, List[Tuple[int, str]]]] = [
    (r"mem_[kv]", [(-4, "dp"), (-3, "model")]),
    (r"\bk\b|\bv\b|'k'|'v'", [(-4, "dp"), (-2, "model"), (-3, "model")]),
    (r"ckv", [(-3, "dp"), (-1, "model"), (-2, "model")]),
    (r"kpe", [(-3, "dp"), (-2, "model")]),
    (r"ssm", [(-4, "dp"), (-3, "model")]),
    (r"conv", [(-3, "dp"), (-1, "model")]),
    (r"wkv", [(-4, "dp"), (-3, "model")]),
    (r"tm_x|cm_x", [(-2, "dp")]),
]


def data_axes(mesh: Mesh, profile: str = "tp") -> Tuple[str, ...]:
    names = ["pod", "data"] + (["model"] if profile == "fsdp" else [])
    return tuple(a for a in names if a in mesh.axis_names)


def _axis_for(role: str, mesh: Mesh, profile: str = "tp"):
    if role == "dp":
        ax = data_axes(mesh, profile)
        return ax if len(ax) > 1 else (ax[0] if ax else None)
    return role if role in mesh.axis_names else None


def _axis_size(mesh: Mesh, axis) -> int:
    if axis is None:
        return 1
    if isinstance(axis, tuple):
        n = 1
        for a in axis:
            n *= mesh.shape[a]
        return n
    return mesh.shape[axis]


def spec_from_prefs(shape: Sequence[int], prefs: List[Tuple[int, str]],
                    mesh: Mesh, profile: str = "tp") -> P:
    """Greedy, divisibility-checked, axis-unique assignment.  "dp" roles
    degrade through a fallback chain (all data axes -> fewer)."""
    nd = len(shape)
    assign: Dict[int, Any] = {}
    used = set()
    for negdim, role in prefs:
        dim = nd + negdim
        if dim < 0 or dim in assign:
            continue
        if role == "dp":
            ax = data_axes(mesh, profile)
            candidates = [ax[:k] for k in range(len(ax), 0, -1)]
        else:
            axis = _axis_for(role, mesh, profile)
            if axis is None:
                continue
            candidates = [axis if isinstance(axis, tuple) else (axis,)]
        for names in candidates:
            if not names or any(n in used for n in names):
                continue
            size = 1
            for a in names:
                size *= mesh.shape[a]
            if size > 1 and shape[dim] % size == 0:
                assign[dim] = names if len(names) > 1 else names[0]
                used.update(names)
                break
    return P(*[assign.get(d) for d in range(nd)])


def _tree_specs(tree: Any, rules, mesh: Mesh, profile: str = "tp") -> Any:
    def leaf_spec(path, leaf):
        name = jax.tree_util.keystr(path)
        shape = getattr(leaf, "shape", ())
        for rx, prefs in rules:
            if re.search(rx, name):
                return spec_from_prefs(shape, prefs, mesh, profile)
        return P()

    return jax.tree_util.tree_map_with_path(leaf_spec, tree)


def param_specs(params: Any, mesh: Mesh, profile: str = "tp") -> Any:
    # param sharding is profile-independent: FSDP over "data" + the
    # heads/ffn/expert dims over "model" serve both profiles (under fsdp
    # the model-dim shard is just more parameter sharding).
    return _tree_specs(params, PARAM_RULES, mesh)


def opt_state_specs(opt_state: Any, params_spec: Any, mesh: Mesh) -> Any:
    """m/v/master mirror the param specs; step is replicated."""
    out = {}
    for k, v in opt_state.items():
        if k == "step":
            out[k] = P()
        else:
            out[k] = params_spec
    return out


def cache_specs(cache: Any, mesh: Mesh, profile: str = "tp") -> Any:
    return _tree_specs(cache, CACHE_RULES, mesh, profile)


def batch_specs(batch: Any, mesh: Mesh, profile: str = "tp") -> Any:
    def leaf_spec(path, leaf):
        name = jax.tree_util.keystr(path)
        shape = getattr(leaf, "shape", ())
        if not shape:
            return P()
        if "positions" in name and len(shape) == 3:  # (3, B, S) M-RoPE
            s = spec_from_prefs(shape[1:], [(-2, "dp")], mesh, profile)
            return P(None, *s)
        s = spec_from_prefs(shape, [(-len(shape), "dp")], mesh, profile)
        return s

    return jax.tree_util.tree_map_with_path(leaf_spec, batch)


def named(tree_specs: Any, mesh: Mesh) -> Any:
    return jax.tree.map(lambda s: NamedSharding(mesh, s), tree_specs,
                        is_leaf=lambda x: isinstance(x, P))
