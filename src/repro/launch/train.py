"""End-to-end training driver.

    PYTHONPATH=src python -m repro.launch.train --arch tinyllama-1.1b \
        --smoke --steps 100 --batch 8 --seq 128 --data /tmp/repro_data \
        --ckpt /tmp/repro_ckpt

Wires every subsystem together: synthetic shard generation (once),
foreactor-speculated batch loading, jitted train step on the host mesh,
async foreactor-backed checkpointing with restore-on-start, straggler
accounting.  ``--kill-at N`` aborts at step N to exercise the
crash/restore path (rerun the same command to resume).
"""

from __future__ import annotations

import argparse
from dataclasses import replace

from repro.checkpoint import CheckpointManager, CheckpointPolicy
from repro.configs import get_config
from repro.core import Foreactor, OSDevice
from repro.data import (DataConfig, ShardedTokenDataset, TokenBatchLoader,
                        write_synthetic_dataset)
from repro.launch.mesh import make_host_mesh
from repro.models import build_model
from repro.optim.adamw import AdamWConfig
from repro.runtime import Trainer, TrainerConfig


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="tinyllama-1.1b")
    ap.add_argument("--smoke", action="store_true",
                    help="use the reduced same-family config (CPU-friendly)")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--data", default="/tmp/repro_data")
    ap.add_argument("--ckpt", default="/tmp/repro_ckpt")
    ap.add_argument("--ckpt-every", type=int, default=25)
    ap.add_argument("--shards", type=int, default=4)
    ap.add_argument("--records-per-shard", type=int, default=256)
    ap.add_argument("--no-restore", action="store_true")
    ap.add_argument("--serial-ckpt", action="store_true",
                    help="disable write-behind checkpointing (save blocks "
                         "the training thread; the bench_write baseline)")
    ap.add_argument("--kill-at", type=int, default=0,
                    help="simulate a node failure at this step")
    ap.add_argument("--keep-last", type=int, default=3,
                    help="retention: newest N step-checkpoints to keep")
    ap.add_argument("--keep-spaced", type=int, default=0,
                    help="retention: newest M time-spaced anchor checkpoints")
    ap.add_argument("--spacing-s", type=float, default=3600.0,
                    help="retention: minimum seconds between anchors")
    ap.add_argument("--delta-every", type=int, default=0,
                    help="write K delta checkpoints between full saves "
                         "(0 = every save full)")
    args = ap.parse_args()

    cfg = get_config(args.arch, smoke=args.smoke)
    if cfg.enc_dec is not None or cfg.visual_stub:
        raise SystemExit("train driver covers LM archs; see examples/ for "
                         "multimodal smoke steps")
    model = build_model(cfg)
    device = OSDevice()
    fa = Foreactor(device=device, backend="io_uring", depth=32)

    dcfg = DataConfig(seq_len=args.seq, batch_size=args.batch, seed=0)
    shard0 = f"{args.data}/shard_00000.rio"
    try:
        device.fstatat(shard0)
    except FileNotFoundError:
        print(f"[train] generating synthetic dataset under {args.data}")
        write_synthetic_dataset(device, args.data, dcfg, args.shards,
                                args.records_per_shard, cfg.vocab_size)
    ds = ShardedTokenDataset(
        device, [f"{args.data}/shard_{i:05d}.rio" for i in range(args.shards)])
    loader = TokenBatchLoader(ds, dcfg, fa=fa)

    ckpt = CheckpointManager(device, args.ckpt, fa=fa, num_shards=4)
    opt = AdamWConfig(lr=args.lr, warmup_steps=max(2, args.steps // 20),
                      total_steps=args.steps)
    tcfg = TrainerConfig(steps=args.steps, ckpt_every=args.ckpt_every,
                         log_every=10, restore=not args.no_restore,
                         write_behind=not args.serial_ckpt,
                         retention=CheckpointPolicy(
                             keep_last=args.keep_last,
                             keep_spaced=args.keep_spaced,
                             spacing_s=args.spacing_s),
                         delta_every=args.delta_every)
    trainer = Trainer(model, opt, loader, ckpt, make_host_mesh(), tcfg)

    if args.kill_at:
        orig = loader.load

        def killing_load(e, s):
            if e * loader.steps_per_epoch + s >= args.kill_at:
                raise RuntimeError(f"simulated node failure at step {args.kill_at}")
            return orig(e, s)

        loader.load = killing_load

    out = trainer.fit()
    mode = "serial" if args.serial_ckpt else "write-behind"
    print(f"[train] done: step {out['final_step']}  "
          f"final loss {out['losses'][-1]:.4f}  "
          f"mean step {1e3 * (out['mean_step_s'] or 0):.0f}ms  "
          f"stragglers {out['stragglers']}  "
          f"ckpt[{mode}] {out['ckpt_saves']} saves, "
          f"{out['ckpt_wait_s']:.2f}s stalled")
    loader.close()
    fa.shutdown()


if __name__ == "__main__":
    main()
