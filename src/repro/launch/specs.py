"""ShapeDtypeStruct stand-ins for every model input — the dry-run's fuel.

``input_specs(cfg, shape)`` returns the batch pytree for a train/prefill
lowering; ``decode_specs`` the (token, pos) pair; cache/state shapes come
from ``jax.eval_shape`` over the model's own constructors, so specs can
never drift from the real functions.
"""

from __future__ import annotations

from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

from repro.configs import ShapeSpec
from repro.models.config import ModelConfig

#: number of stubbed visual patches for the VLM backbone
N_VISUAL = 256


def _sds(shape, dtype):
    return jax.ShapeDtypeStruct(shape, dtype)


def input_specs(cfg: ModelConfig, shape: ShapeSpec) -> Dict[str, Any]:
    """Batch spec for train (tokens+labels) or prefill (tokens)."""
    B, S = shape.global_batch, shape.seq_len
    batch: Dict[str, Any] = {"tokens": _sds((B, S), jnp.int32)}
    if shape.kind == "train":
        batch["labels"] = _sds((B, S), jnp.int32)
    if cfg.visual_stub:
        batch["visual_embeds"] = _sds((B, N_VISUAL, cfg.d_model), jnp.bfloat16)
        batch["positions"] = _sds((3, B, S), jnp.int32)
    if cfg.enc_dec is not None:
        batch["frames"] = _sds((B, cfg.enc_dec.n_audio_ctx, cfg.d_model),
                               jnp.bfloat16)
    return batch


def decode_specs(cfg: ModelConfig, shape: ShapeSpec) -> Tuple[Any, Any]:
    B = shape.global_batch
    return _sds((B,), jnp.int32), _sds((B,), jnp.int32)


def concrete_batch(cfg: ModelConfig, shape: ShapeSpec, rng=None) -> Dict[str, Any]:
    """A real (host numpy) batch matching input_specs — smoke/examples."""
    import numpy as np

    rng = np.random.default_rng(0) if rng is None else rng
    spec = input_specs(cfg, shape)

    def mk(s):
        if s.dtype == jnp.int32:
            if s.shape and len(s.shape) == 3:  # positions
                return np.zeros(s.shape, np.int32)
            return rng.integers(0, cfg.vocab_size, s.shape).astype(np.int32)
        return rng.normal(size=s.shape).astype(np.float32)

    return jax.tree.map(mk, spec)
