"""Multi-tenant I/O request server: closed-loop clients and an open-loop
session stream (the shared-backend serving workloads).

Many concurrent clients — each a *tenant* with a priority class and weight —
hammer one storage substrate through two request types:

* ``get``      — LSM point lookup (paper Fig. 4c: the candidate pread chain
  with early exit), speculated through ``plugins.build_lsm_get_graph``;
* ``restore``  — checkpoint-restore scan (open_list + pread_extents over
  every chunk of a step), the framework-plane bulk-read path.

Three serving modes compare arbitration strategies on identical hardware:

* ``sync``     — no speculation (baseline);
* ``isolated`` — the paper's setup: every client thread owns a private
  queue pair and speculates independently (no arbitration);
* ``shared``   — ONE queue pair for everyone; a
  :class:`repro.core.backends.SlotScheduler` leases submission slots
  weighted-fairly across tenants, with priority classes and
  pressure-triggered cancellation of speculative-only requests.

Two load-generation disciplines share that substrate:

* **closed loop** (:func:`run_serving`) — each client issues its next
  request only after the previous one completed.  Simple, but it
  structurally hides queueing collapse: an overloaded server slows its own
  clients down, so offered load self-throttles to capacity.
* **open loop** (:func:`run_openloop`) — requests arrive on a fixed,
  precomputed schedule (:func:`arrival_schedule`) regardless of how the
  server is doing, each arrival a *fresh tenant session*; latency is
  measured from the *scheduled arrival time* (wrk2-style, so coordinated
  omission cannot flatter the tail) and the in-flight session count is
  recovered post hoc from the (arrival, completion) event log.  Pushing the
  arrival rate past capacity exposes the saturation knee the paper's
  serving claim lives on.

    PYTHONPATH=src python -m repro.launch.ioserver --mode shared --clients 8
    PYTHONPATH=src python -m repro.launch.ioserver --mode all --clients 8
    PYTHONPATH=src python -m repro.launch.ioserver --openloop --mode shared \\
        --sessions 1024 --rate 0.35 --duration 2.0
"""

from __future__ import annotations

import argparse
import threading
import time
from dataclasses import dataclass, field
from typing import Dict, List, Optional

import numpy as np

from repro.core import (DeviceProfile, Foreactor, MemDevice, SimulatedDevice,
                        io)
from repro.core.patterns import build_pread_extents_graph
from repro.store import plugins
from repro.store.lsm import LSMTree

#: serving-tier device: ms-scale per-op latency, far above both CI sleep
#: granularity and the engine's per-intercept CPU cost (the benchmark runs
#: on 2-vCPU containers — a faster synthetic device would measure GIL
#: contention, not I/O arbitration), with enough internal parallelism that
#: the scheduler, not the device, decides who waits.
SERVE_PROFILE = DeviceProfile(channels=32, base_latency=5.0e-3,
                              metadata_latency=4.0e-3, per_byte=2.0e-10,
                              crossing_cost=4e-6)

#: speculation depth for the speculating modes.  The LSM get chain exits
#: early (~half its ~8 candidates): depth 16 would waste ~2x the device's
#: work at scale, the adaptive controller whipsaws when 8 concurrent
#: sessions with different exit points feed one per-graph controller — a
#: fixed moderate pipeline width is the serving sweet spot (docs/TUNING.md,
#: "Priority mixes on a shared backend").
SERVE_DEPTH = 4
#: shared-pool sizing: workers stay below the device's channel count so
#: demand I/O always finds free channels even when every worker is busy
#: running speculation, while the scheduler's slot window is a bit wider —
#: slots above the worker count queue as PREPARED entries, which is exactly
#: the state pressure eviction can cancel.
SHARED_WORKERS = 24
SHARED_SLOTS = 32
#: isolated mode gives every client thread a private queue pair; the
#: worker-thread budget is fixed for the whole experiment and divided
#: across clients by :func:`isolated_workers`.  (The original code
#: hard-coded 8 workers per client — an "8 clients × 8 = 64 threads"
#: assumption that oversubscribed badly once ``--clients`` grew: 64 clients
#: would have spawned 512 worker threads.)
ISOLATED_THREAD_BUDGET = 64


def isolated_workers(clients: int) -> int:
    """Per-client queue-pair size in isolated mode: the fixed
    :data:`ISOLATED_THREAD_BUDGET` split across clients, clamped to [2, 8]
    (8 matches the historical 8-client benchmark shape; below 2 a queue
    pair cannot overlap anything)."""
    return max(2, min(8, ISOLATED_THREAD_BUDGET // max(1, clients)))


@dataclass
class ClientSpec:
    """One closed-loop client: its tenant identity and request mix."""

    name: str
    workload: str = "get"  # "get" | "multiget" | "restore"
    priority: str = "normal"  # "high" | "normal" | "low"
    weight: float = 1.0
    ops: int = 60
    warmup: int = 3  # leading ops excluded from latency stats
    batch: int = 8  # keys per op for the "multiget" workload


@dataclass
class ClientResult:
    spec: ClientSpec
    latencies_s: List[float] = field(default_factory=list)
    errors: int = 0


def percentile(xs: List[float], q: float) -> float:
    if not xs:
        return float("nan")
    return float(np.percentile(np.asarray(xs), q))


# -- workload construction ----------------------------------------------------

def build_store(n_keys: int = 2000, record: int = 128, l0_tables: int = 8,
                ckpt_chunks: int = 16, seed: int = 0):
    """Build the LSM database and a small checkpoint on a raw MemDevice
    (no latency during setup); returns (inner, reference dict)."""
    rng = np.random.default_rng(seed)
    inner = MemDevice()
    per_table = max(1, n_keys // l0_tables)
    limit = per_table * (record + 12)
    lsm = LSMTree(inner, "/db", memtable_limit_bytes=limit, l0_limit=10 ** 6,
                  fsync_writes=False)
    ref: Dict[int, bytes] = {}
    payload = rng.bytes(record)
    for k in rng.permutation(n_keys):
        v = int(k).to_bytes(8, "little") + payload[:-8]
        lsm.put(int(k), v)
        ref[int(k)] = v
    lsm.flush()
    lsm.close()
    # checkpoint chunks for the restore path: one file of ckpt_chunks extents
    fd = inner.open("/ck/blob.bin", "w")
    inner.pwrite(fd, rng.bytes(ckpt_chunks * 16384), 0)
    inner.close(fd)
    return inner, ref


def restore_extents(dev, n_chunks: int = 16, chunk: int = 16384):
    fd = dev.open("/ck/blob.bin", "r")
    return [(fd, chunk, i * chunk) for i in range(n_chunks)]


def make_foreactor(mode: str, dev, depth=SERVE_DEPTH,
                   clients: int = 8, remine: bool = False) -> Foreactor:
    if mode == "sync":
        fa = Foreactor(device=dev, backend="sync", depth=0)
    elif mode == "isolated":
        fa = Foreactor(device=dev, backend="io_uring", depth=depth,
                       workers=isolated_workers(clients))
    elif mode == "shared":
        fa = Foreactor(device=dev, backend="io_uring", depth=depth,
                       workers=SHARED_WORKERS, shared=True,
                       shared_slots=SHARED_SLOTS)
    else:
        raise ValueError(f"unknown mode {mode!r}")
    # warm the plan cache before the first client request: first-request
    # latency should pay a dict probe, not a graph build + lowering
    plugins.register_all(fa, precompile=True)
    fa.register("restore_scan",
                lambda: build_pread_extents_graph("restore_scan"))
    fa.plan("restore_scan")
    if remine and mode != "sync":
        # online re-mining on the hot endpoint: sampled activations record
        # traces, validated candidates hot-swap, regressions roll back —
        # swaps/rollbacks surface in the serving report's plan lines
        from repro.analysis.remine import ReMiner
        ReMiner(fa, watch=["lsm_get"])
    return fa


# -- the serving loop ---------------------------------------------------------

def _client_loop(fa: Foreactor, dev, lsm: LSMTree, ref: Dict[int, bytes],
                 spec: ClientSpec, result: ClientResult,
                 start_gate: threading.Event, seed: int) -> None:
    """Closed loop: the next request starts only after the previous one's
    session is fully torn down (cancel + drain — that cost lands in
    throughput), but *latency* is recorded at response time, when the
    result is in hand: a server answers the client before it cleans up its
    speculation leftovers."""
    rng = np.random.default_rng(seed)
    extents = restore_extents(dev)
    keys = rng.integers(0, len(ref), size=spec.ops + spec.warmup)
    # drawn after `keys` so get/restore clients' random streams are
    # unchanged by the multiget op class existing
    mkeys = rng.integers(0, len(ref),
                         size=(spec.ops + spec.warmup) * spec.batch) \
        if spec.workload == "multiget" else None
    with fa.tenant(spec.name, weight=spec.weight, priority=spec.priority):
        start_gate.wait()
        for i in range(spec.ops + spec.warmup):
            t0 = time.perf_counter()
            dt = None
            try:
                if spec.workload == "get":
                    key = int(keys[i])
                    sess = fa.activate("lsm_get",
                                       plugins.capture_lsm_get(lsm, key))
                    try:
                        v = lsm.get(key)
                        dt = time.perf_counter() - t0  # response latency
                    finally:
                        fa.deactivate(sess)
                    if v != ref[key]:
                        result.errors += 1
                elif spec.workload == "multiget":
                    # scatter-gather op class: one N-key batch per request,
                    # one generated plan per batch (the futures fan-out)
                    batch = [int(k) for k in
                             mkeys[i * spec.batch:(i + 1) * spec.batch]]
                    sess = fa.activate(
                        "lsm_multiget",
                        plugins.capture_lsm_multiget(lsm, batch))
                    try:
                        vs = lsm.multi_get(batch)
                        dt = time.perf_counter() - t0
                    finally:
                        fa.deactivate(sess)
                    if vs != [ref.get(k) for k in batch]:
                        result.errors += 1
                else:
                    sess = fa.activate("restore_scan", {"extents": extents})
                    try:
                        for fd, n, off in extents:
                            io.pread(dev, fd, n, off)
                        dt = time.perf_counter() - t0
                    finally:
                        fa.deactivate(sess)
            except Exception:
                result.errors += 1
                dt = time.perf_counter() - t0
            if i >= spec.warmup:
                result.latencies_s.append(dt)


def run_serving(mode: str, clients: List[ClientSpec],
                profile: DeviceProfile = SERVE_PROFILE,
                seed: int = 0, store=None, remine: bool = False) -> dict:
    """Run one closed-loop serving experiment; returns the report dict."""
    inner, ref = store if store is not None else build_store(seed=seed)
    dev = SimulatedDevice(inner, profile)
    fa = make_foreactor(mode, dev, clients=len(clients), remine=remine)
    lsm = LSMTree.open_existing(dev, "/db", fsync_writes=False)
    results = [ClientResult(spec=c) for c in clients]
    start_gate = threading.Event()
    threads = [
        threading.Thread(target=_client_loop, name=c.name,
                         args=(fa, dev, lsm, ref, c, r, start_gate, seed + i))
        for i, (c, r) in enumerate(zip(clients, results))
    ]
    for t in threads:
        t.start()
    t0 = time.perf_counter()
    start_gate.set()
    for t in threads:
        t.join()
    wall = time.perf_counter() - t0
    lsm.close()
    fa.shutdown()

    per_client = {}
    by_class: Dict[str, List[float]] = {}
    total_ops = 0
    total_errors = 0
    for r in results:
        lat = r.latencies_s
        total_ops += len(lat)
        total_errors += r.errors
        per_client[r.spec.name] = {
            "workload": r.spec.workload,
            "priority": r.spec.priority,
            "ops": len(lat),
            "errors": r.errors,
            "p50_ms": percentile(lat, 50) * 1e3,
            "p99_ms": percentile(lat, 99) * 1e3,
        }
        by_class.setdefault(r.spec.priority, []).extend(lat)
    report = {
        "mode": mode,
        "clients": len(clients),
        "wall_s": wall,
        "throughput_ops": total_ops / wall if wall > 0 else 0.0,
        "errors": total_errors,
        "per_client": per_client,
        "classes": {
            prio: {"ops": len(lat),
                   "p50_ms": percentile(lat, 50) * 1e3,
                   "p99_ms": percentile(lat, 99) * 1e3}
            for prio, lat in by_class.items()
        },
        "scheduler": fa.scheduler.snapshot() if fa.scheduler else None,
        # plan-cache + mined-graph-version observability (per endpoint):
        # thrash shows as compiles tracking probes instead of hits
        "plans": fa.plan_cache_stats(),
        # online re-mining activity: sampling/attempt/swap/rollback counters
        # and the deterministic decision log (None when --remine is off)
        "remine": fa.reminer.snapshot() if fa.reminer else None,
    }
    return report


def get_clients(n: int, priority: str = "normal", ops: int = 60,
                prefix: str = "get") -> List[ClientSpec]:
    return [ClientSpec(name=f"{prefix}-{i}", workload="get",
                       priority=priority, ops=ops) for i in range(n)]


def multiget_clients(n: int, priority: str = "normal", ops: int = 20,
                     batch: int = 8,
                     prefix: str = "multiget") -> List[ClientSpec]:
    """Scatter-gather clients: each op is one ``batch``-key multiget served
    by a single generated ``lsm_multiget`` plan."""
    return [ClientSpec(name=f"{prefix}-{i}", workload="multiget",
                       priority=priority, ops=ops, batch=batch, warmup=1)
            for i in range(n)]


def restore_clients(n: int, priority: str = "low", ops: int = 12,
                    prefix: str = "restore") -> List[ClientSpec]:
    # background bulk work: low priority class AND low weight — its fair
    # share stays small enough that the workers it occupies never crowd out
    # the hot tenants' speculation (docs/TUNING.md "Priority mixes")
    return [ClientSpec(name=f"{prefix}-{i}", workload="restore",
                       priority=priority, weight=0.25, ops=ops, warmup=1)
            for i in range(n)]


# -- open-loop load generation ------------------------------------------------

#: server-side worker threads draining the open-loop arrival queue.  This is
#: the service capacity knob, NOT the concurrency cap on sessions: arrivals
#: past it queue (that queueing *is* the measurement), and in-flight
#: sessions — arrived, not yet completed — run into the thousands once the
#: arrival rate passes the knee.
OPENLOOP_SERVER_THREADS = 32


class FakeClock:
    """Deterministic clock for the seeded scheduler harness
    (tests/test_openloop.py): time advances only when the test says so, so
    a 1k-session trace replays identically on every run with zero
    wall-clock sleeps."""

    def __init__(self, t0: float = 0.0):
        self.t = float(t0)

    def now(self) -> float:
        return self.t

    def advance_to(self, t: float) -> None:
        """Jump forward to ``t`` (never backwards — arrivals are sorted)."""
        if t > self.t:
            self.t = float(t)


def arrival_schedule(sessions: int, rate_per_session: float,
                     duration_s: float, seed: int = 0) -> List[tuple]:
    """Seeded open-loop arrival trace.

    ``sessions`` independent tenants each issue requests as a Poisson
    process of ``rate_per_session`` per second; the superposition is one
    Poisson stream at the aggregate rate, which is how we draw it.  Every
    arrival is a *fresh* session (activate -> serve -> deactivate), so the
    trace exercises the scheduler's whole tenant lifecycle, not just its
    steady state.  Returns a time-sorted list of ``(arrival_s,
    session_idx)`` covering ``[0, duration_s)``; the same seed always
    yields the identical trace (the deterministic test harness and the
    benchmark share this generator).
    """
    rng = np.random.default_rng(seed)
    rate = float(sessions) * float(rate_per_session)
    if rate <= 0:
        return []
    out: List[tuple] = []
    t = 0.0
    idx = 0
    while True:
        t += float(rng.exponential(1.0 / rate))
        if t >= duration_s:
            return out
        out.append((t, idx))
        idx += 1


def max_inflight(events: List[tuple]) -> int:
    """Peak concurrent sessions from (arrival_s, completion_s) pairs: the
    classic +1/-1 sweep (completions sort before arrivals at a tie — a
    session that ends the instant another starts does not overlap it)."""
    marks = [(t, 1) for t, _ in events] + [(d, -1) for _, d in events]
    marks.sort(key=lambda m: (m[0], m[1]))
    cur = peak = 0
    for _t, delta in marks:
        cur += delta
        peak = max(peak, cur)
    return peak


def run_openloop(mode: str, sessions: int, rate_per_session: float,
                 duration_s: float, profile: DeviceProfile = SERVE_PROFILE,
                 seed: int = 0, store=None,
                 server_threads: int = OPENLOOP_SERVER_THREADS) -> dict:
    """One open-loop cell: replay a fixed arrival schedule against one
    serving mode and report achieved throughput, virtual-time latency
    percentiles, and the peak in-flight session count.

    Latency is virtual-time (wrk2's correction for coordinated omission):
    measured from each request's *scheduled* arrival, not from when a
    server thread finally picked it up — so when the server falls behind,
    the queueing delay lands in the tail instead of silently stretching
    the load generator.
    """
    inner, ref = store if store is not None else build_store(seed=seed)
    dev = SimulatedDevice(inner, profile)
    fa = make_foreactor(mode, dev, clients=server_threads)
    lsm = LSMTree.open_existing(dev, "/db", fsync_writes=False)
    schedule = arrival_schedule(sessions, rate_per_session, duration_s, seed)
    rng = np.random.default_rng(seed + 1)
    keys = rng.integers(0, len(ref), size=max(1, len(schedule)))
    n = len(schedule)
    events: List[Optional[tuple]] = [None] * n
    latencies: List[Optional[float]] = [None] * n
    cursor = [0]
    errors = [0]
    lock = threading.Lock()

    # warm the serving path before the clock starts (plan cache is already
    # precompiled; this pulls the LSM filters/index blocks and the first
    # worker wakeups out of the measured window — without it the first few
    # arrivals eat cold-start cost and pollute the low-rate cells' p99)
    for key in map(int, keys[: min(4, len(keys))]):
        with fa.tenant("warmup", priority="normal"):
            sess = fa.activate("lsm_get", plugins.capture_lsm_get(lsm, key))
            try:
                lsm.get(key)
            finally:
                fa.deactivate(sess)

    t0 = time.monotonic() + 0.02  # small lead so arrival 0 is in the future

    def server() -> None:
        while True:
            with lock:
                i = cursor[0]
                if i >= n:
                    return
                cursor[0] = i + 1
            t_arr, idx = schedule[i]
            delay = (t0 + t_arr) - time.monotonic()
            if delay > 0:  # ahead of schedule: hold the arrival back
                time.sleep(delay)
            key = int(keys[i])
            t_resp = None
            try:
                with fa.tenant(f"s{idx}", priority="normal"):
                    sess = fa.activate("lsm_get",
                                       plugins.capture_lsm_get(lsm, key))
                    try:
                        v = lsm.get(key)
                        t_resp = time.monotonic()
                        if v != ref[key]:
                            with lock:
                                errors[0] += 1
                    finally:
                        fa.deactivate(sess)
            except Exception:
                with lock:
                    errors[0] += 1
            t_done = time.monotonic()
            if t_resp is None:
                t_resp = t_done
            events[i] = (t_arr, t_done - t0)
            latencies[i] = (t_resp - t0) - t_arr

    threads = [threading.Thread(target=server, name=f"openloop-{i}")
               for i in range(server_threads)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    lsm.close()
    sched_snap = fa.scheduler.snapshot() if fa.scheduler else None
    plans_snap = fa.plan_cache_stats()
    fa.shutdown()

    lat = [x for x in latencies if x is not None]
    evs = [e for e in events if e is not None]
    last_done = max((d for _, d in evs), default=duration_s)
    offered = n / duration_s if duration_s > 0 else 0.0
    achieved = len(evs) / last_done if last_done > 0 else 0.0
    return {
        "mode": mode,
        "sessions": sessions,
        "rate_per_session": rate_per_session,
        "duration_s": duration_s,
        "arrivals": n,
        "offered_rate": offered,
        "achieved_rate": achieved,
        "completed": len(evs),
        "errors": errors[0],
        "p50_ms": percentile(lat, 50) * 1e3,
        "p99_ms": percentile(lat, 99) * 1e3,
        "max_inflight_sessions": max_inflight(evs),
        "server_threads": server_threads,
        "scheduler": sched_snap,
        "plans": plans_snap,
    }


def _print_report(rep: dict) -> None:
    print(f"[ioserver] mode={rep['mode']} clients={rep['clients']} "
          f"wall={rep['wall_s']:.2f}s tput={rep['throughput_ops']:.0f} op/s "
          f"errors={rep['errors']}")
    for prio, c in sorted(rep["classes"].items()):
        print(f"  class {prio:7s} ops={c['ops']:4d} "
              f"p50={c['p50_ms']:.2f}ms p99={c['p99_ms']:.2f}ms")
    if rep["scheduler"]:
        print(f"  scheduler: {rep['scheduler']}")
    plans = rep.get("plans") or {}
    for name, p in sorted(plans.get("per_graph", {}).items()):
        print(f"  plan {name:14s} probes={p['probes']:3d} "
              f"hits={p['hits']:3d} compiles={p['compiles']} "
              f"graph_v{p['graph_version']} swaps={p.get('swaps', 0)} "
              f"rollbacks={p.get('rollbacks', 0)}")
    rm = rep.get("remine")
    if rm:
        for name, ep in sorted(rm["endpoints"].items()):
            print(f"  remine {name:12s} samples={ep['samples']:3d} "
                  f"attempts={ep['attempts']} swaps={ep['swaps']} "
                  f"rollbacks={ep['rollbacks']} "
                  f"refusals={sum(ep['refusals'].values())}")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--mode", default="shared",
                    choices=["sync", "isolated", "shared", "all"])
    ap.add_argument("--clients", type=int, default=8)
    ap.add_argument("--ops", type=int, default=60)
    ap.add_argument("--low-pri-restores", type=int, default=0,
                    help="add N low-priority restore clients")
    ap.add_argument("--multigets", type=int, default=0,
                    help="add N scatter-gather multiget clients "
                         "(8-key batches)")
    ap.add_argument("--remine", action="store_true",
                    help="attach the online re-miner to the lsm_get "
                         "endpoint (closed-loop modes)")
    ap.add_argument("--openloop", action="store_true",
                    help="open-loop session stream instead of closed-loop "
                         "clients")
    ap.add_argument("--sessions", type=int, default=256,
                    help="(openloop) tenant sessions driving the arrivals")
    ap.add_argument("--rate", type=float, default=0.35,
                    help="(openloop) per-session arrival rate, 1/s")
    ap.add_argument("--duration", type=float, default=2.0,
                    help="(openloop) arrival window, seconds")
    args = ap.parse_args()

    store = build_store()
    modes = ["sync", "isolated", "shared"] if args.mode == "all" \
        else [args.mode]
    if args.openloop:
        for mode in modes:
            rep = run_openloop(mode, args.sessions, args.rate,
                               args.duration, store=store)
            print(f"[openloop] mode={rep['mode']} sessions={rep['sessions']} "
                  f"offered={rep['offered_rate']:.0f}/s "
                  f"achieved={rep['achieved_rate']:.0f}/s "
                  f"p50={rep['p50_ms']:.1f}ms p99={rep['p99_ms']:.1f}ms "
                  f"max_inflight={rep['max_inflight_sessions']} "
                  f"errors={rep['errors']}")
        return
    specs = get_clients(args.clients, priority="high", ops=args.ops)
    specs += restore_clients(args.low_pri_restores)
    specs += multiget_clients(args.multigets)
    for mode in modes:
        _print_report(run_serving(mode, specs, store=store,
                                  remine=args.remine))


if __name__ == "__main__":
    main()
