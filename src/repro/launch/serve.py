"""Serving driver: prefill a batch of prompts, then greedy-decode.

    PYTHONPATH=src python -m repro.launch.serve --arch tinyllama-1.1b \
        --smoke --batch 4 --prompt-len 32 --gen 16
"""

from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.launch.mesh import make_host_mesh, mesh_context
from repro.launch.steps import make_generate_loop
from repro.models import build_model


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="tinyllama-1.1b")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=16)
    args = ap.parse_args()

    cfg = get_config(args.arch, smoke=args.smoke)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    key = jax.random.PRNGKey(1)
    batch = {"tokens": jax.random.randint(
        key, (args.batch, args.prompt_len), 0, cfg.vocab_size)}
    if cfg.visual_stub:
        batch["visual_embeds"] = jax.random.normal(
            key, (args.batch, 8, cfg.d_model), jnp.float32)
    if cfg.enc_dec is not None:
        batch["frames"] = jax.random.normal(
            key, (args.batch, cfg.enc_dec.n_audio_ctx, cfg.d_model), jnp.float32)

    gen = make_generate_loop(model, args.gen)
    max_len = args.prompt_len + args.gen + 1
    with mesh_context(make_host_mesh()):
        jitted = jax.jit(gen, static_argnums=(2,))
        t0 = time.perf_counter()
        toks = jitted(params, batch, max_len)
        toks.block_until_ready()
        t_first = time.perf_counter() - t0
        t0 = time.perf_counter()
        toks = jitted(params, batch, max_len)
        toks.block_until_ready()
        t_warm = time.perf_counter() - t0
    tput = args.batch * args.gen / t_warm
    print(f"[serve] generated {toks.shape} tokens; "
          f"first(incl compile)={t_first:.2f}s warm={t_warm*1e3:.0f}ms "
          f"({tput:.0f} tok/s)")
    print("[serve] sample:", toks[0, :12].tolist())


if __name__ == "__main__":
    main()
