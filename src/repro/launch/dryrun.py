import os

os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count=512 "
    + os.environ.get("XLA_FLAGS", "")
)

"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

For each cell this driver jits the real step function (train_step for
train shapes, prefill/serve steps for inference shapes) against
ShapeDtypeStruct inputs with full production shardings, compiles it, and
records:

* ``memory_analysis()``  — proves the cell fits per-device HBM;
* ``cost_analysis()``    — HLO FLOPs / bytes for the roofline;
* per-device collective bytes, parsed from the compiled HLO
  (all-gather / all-reduce / reduce-scatter / all-to-all /
  collective-permute operand sizes) — cost_analysis does not report them.

Reports land in ``reports/dryrun/<arch>__<shape>__<mesh>.json``.

Usage::

    python -m repro.launch.dryrun --arch tinyllama-1.1b --shape train_4k
    python -m repro.launch.dryrun --all                 # every runnable cell
    python -m repro.launch.dryrun --all --multi-pod     # 2x16x16 pass
"""

import argparse
import json
import re
import sys
import time
import traceback
from typing import Any, Dict

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.analysis.hlo import analyze_hlo
from repro.analysis.roofline import model_flops
from repro.configs import ARCH_IDS, SHAPES, SKIP_CELLS, get_config, resolve
from repro.launch import sharding as shd
from repro.launch.mesh import make_production_mesh, mesh_context
from repro.launch.specs import decode_specs, input_specs
from repro.launch.steps import (make_decode_step, make_prefill_step,
                                make_train_step, train_state_shape)
from repro.models.api import build_model
from repro.models.common import set_sharding_profile
from repro.optim.adamw import AdamWConfig

_last_profile = [None]  # set by lower_cell; read by run_cell for the report

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")


def _resident_bytes_per_device(sds_trees, spec_trees, mesh) -> int:
    """Exact per-device bytes of sharded residents (state/params/cache):
    sum over leaves of nbytes / (product of mesh-axis sizes in its spec)."""
    from jax.sharding import PartitionSpec

    total = 0
    for sds_tree, spec_tree in zip(sds_trees, spec_trees):
        leaves = jax.tree.leaves(sds_tree)
        specs = jax.tree.leaves(spec_tree,
                                is_leaf=lambda x: isinstance(x, PartitionSpec))
        for leaf, spec in zip(leaves, specs):
            frac = 1
            for axis in tuple(spec):
                if axis is None:
                    continue
                for a in (axis if isinstance(axis, tuple) else (axis,)):
                    frac *= mesh.shape[a]
            total += leaf.size * leaf.dtype.itemsize // frac
    return total


def _shape_bytes(sig: str) -> int:
    """Sum byte sizes of every array shape in an HLO result signature."""
    total = 0
    for dt, dims in _SHAPE_RE.findall(sig):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def collective_bytes(hlo_text: str) -> Dict[str, int]:
    """Per-device bytes moved by each collective kind (result sizes of the
    per-partition SPMD module)."""
    out: Dict[str, int] = {k: 0 for k in _COLLECTIVES}
    out["count"] = 0
    for line in hlo_text.splitlines():
        s = line.strip()
        m = re.match(r"(?:ROOT\s+)?%?[\w.\-]+\s*=\s*(\([^)]*\)|\S+)\s+(\w[\w-]*)\(", s)
        if not m:
            continue
        op = m.group(2)
        # fusion(...) etc. won't match a collective name; *-start ops count,
        # their corresponding *-done ops don't (avoid double counting).
        base = op.replace("-start", "")
        if base in _COLLECTIVES and not op.endswith("-done"):
            out[base] += _shape_bytes(m.group(1))
            out["count"] += 1
    return out


def lower_cell(arch: str, shape_name: str, multi_pod: bool,
               opt_overrides: Dict[str, Any] = None,
               profile: str = None):
    cfg = get_config(arch)
    model = build_model(cfg)
    shape = SHAPES[shape_name]
    mesh = make_production_mesh(multi_pod=multi_pod)
    # serve cells engage the model axis via activation sharding ("tp");
    # train cells use the arch default (fsdp except DeepSeek's EP).
    if profile is None:
        profile = cfg.sharding_profile if shape.kind == "train" else "tp"
    set_sharding_profile(profile)
    _last_profile[0] = profile

    with mesh_context(mesh):
        if shape.kind == "train":
            opt_cfg = AdamWConfig(**(opt_overrides or {}))
            state_sds = train_state_shape(model, opt_cfg)
            batch_sds = input_specs(cfg, shape)
            pspecs = shd.param_specs(state_sds["params"], mesh)
            state_specs = {"params": pspecs,
                           "opt": shd.opt_state_specs(state_sds["opt"], pspecs, mesh)}
            bspecs = shd.batch_specs(batch_sds, mesh, profile)
            step = make_train_step(model, opt_cfg)
            metrics_specs = {"loss": P(), "grad_norm": P(), "lr": P()}
            jitted = jax.jit(step,
                             in_shardings=(shd.named(state_specs, mesh),
                                           shd.named(bspecs, mesh)),
                             out_shardings=(shd.named(state_specs, mesh),
                                            shd.named(metrics_specs, mesh)),
                             donate_argnums=(0,))
            lowered = jitted.lower(state_sds, batch_sds)
            resident = _resident_bytes_per_device(
                [state_sds, batch_sds], [state_specs, bspecs], mesh)
        elif shape.kind == "prefill":
            params_sds = jax.eval_shape(model.init, jax.random.PRNGKey(0))
            batch_sds = input_specs(cfg, shape)
            pspecs = shd.param_specs(params_sds, mesh)
            bspecs = shd.batch_specs(batch_sds, mesh, profile)
            step = make_prefill_step(model, shape.seq_len)
            _, cache_sds = jax.eval_shape(step, params_sds, batch_sds)
            cspecs = shd.cache_specs(cache_sds, mesh, profile)
            logits_spec = shd.spec_from_prefs(
                (shape.global_batch, cfg.padded_vocab),
                [(-2, "dp"), (-1, "model")], mesh, profile)
            jitted = jax.jit(step,
                             in_shardings=(shd.named(pspecs, mesh),
                                           shd.named(bspecs, mesh)),
                             out_shardings=(shd.named(logits_spec, mesh),
                                            shd.named(cspecs, mesh)))
            lowered = jitted.lower(params_sds, batch_sds)
            resident = _resident_bytes_per_device(
                [params_sds, batch_sds], [pspecs, bspecs], mesh)
        else:  # decode
            params_sds = jax.eval_shape(model.init, jax.random.PRNGKey(0))
            pspecs = shd.param_specs(params_sds, mesh)
            # the serve-time cache: same structure prefill would produce
            if model.is_enc_dec:
                pre_batch = input_specs(cfg, SHAPES["train_4k"])
                pre_batch["tokens"] = jax.ShapeDtypeStruct(
                    (shape.global_batch, 8), jnp.int32)
                pre_batch["frames"] = jax.ShapeDtypeStruct(
                    (shape.global_batch, cfg.enc_dec.n_audio_ctx, cfg.d_model),
                    jnp.bfloat16)
                pre_batch.pop("labels", None)
                _, cache_sds = jax.eval_shape(
                    lambda p, b: model.prefill(p, b, shape.seq_len),
                    params_sds, pre_batch)
            else:
                from repro.models import lm

                cache_sds = jax.eval_shape(
                    lambda: lm.init_cache(cfg, shape.global_batch, shape.seq_len))
            cspecs = shd.cache_specs(cache_sds, mesh, profile)
            tok_sds, pos_sds = decode_specs(cfg, shape)
            bspec = shd.spec_from_prefs((shape.global_batch,),
                                        [(-1, "dp")], mesh, profile)
            logits_spec = shd.spec_from_prefs(
                (shape.global_batch, cfg.padded_vocab),
                [(-2, "dp"), (-1, "model")], mesh, profile)
            step = make_decode_step(model)
            jitted = jax.jit(step,
                             in_shardings=(shd.named(pspecs, mesh),
                                           shd.named(cspecs, mesh),
                                           shd.named(bspec, mesh),
                                           shd.named(bspec, mesh)),
                             out_shardings=(shd.named(logits_spec, mesh),
                                            shd.named(cspecs, mesh)),
                             donate_argnums=(1,))
            lowered = jitted.lower(params_sds, cache_sds, tok_sds, pos_sds)
            resident = _resident_bytes_per_device(
                [params_sds, cache_sds], [pspecs, cspecs], mesh)
    return lowered, mesh, resident


def run_cell(arch: str, shape_name: str, multi_pod: bool, out_dir: str,
             opt_overrides=None, verbose: bool = True,
             profile: str = None, tag: str = "") -> Dict[str, Any]:
    mesh_name = "2x16x16" if multi_pod else "16x16"
    t0 = time.time()
    lowered, mesh, resident = lower_cell(arch, shape_name, multi_pod,
                                         opt_overrides, profile=profile)
    t_lower = time.time() - t0
    t0 = time.time()
    compiled = lowered.compile()
    t_compile = time.time() - t0
    mem = compiled.memory_analysis()
    cost = compiled.cost_analysis()
    hlo = analyze_hlo(compiled.as_text())
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    mflops = model_flops(cfg, shape, shape.kind) / mesh.size
    report = {
        "arch": arch,
        "shape": shape_name,
        "mesh": mesh_name,
        "profile": _last_profile[0],
        "devices": int(mesh.size),
        "lower_s": round(t_lower, 2),
        "compile_s": round(t_compile, 2),
        "memory": {
            # NB: the forced-host-platform memory_analysis aggregates across
            # partitions and is unreliable for argument sizes; resident_bytes
            # is computed exactly from the sharded input trees.
            "argument_bytes": getattr(mem, "argument_size_in_bytes", None),
            "output_bytes": getattr(mem, "output_size_in_bytes", None),
            "temp_bytes": getattr(mem, "temp_size_in_bytes", None),
            "peak_bytes": getattr(mem, "peak_memory_in_bytes", None),
            "resident_bytes_per_device": resident,
            "temp_bytes_per_device": (getattr(mem, "temp_size_in_bytes", 0) or 0)
            // mesh.size,
        },
        "cost": {
            "flops": cost.get("flops"),
            "bytes_accessed": cost.get("bytes accessed"),
            "transcendentals": cost.get("transcendentals"),
        },
        "hlo": hlo.to_dict(),
        "model_flops_per_dev": mflops,
    }
    os.makedirs(out_dir, exist_ok=True)
    path = f"{out_dir}/{resolve(arch)}__{shape_name}__{mesh_name}{tag}.json"
    with open(path, "w") as f:
        json.dump(report, f, indent=2)
    if verbose:
        hbm = report["memory"]["resident_bytes_per_device"] + \
            report["memory"]["temp_bytes_per_device"]
        print(f"[dryrun] {arch:22s} {shape_name:12s} {mesh_name:8s} "
              f"OK  lower={t_lower:6.1f}s compile={t_compile:6.1f}s "
              f"hbm/dev={_gb(hbm)}  dotflops/dev={hlo.dot_flops:.3e} "
              f"(model {mflops:.3e})  coll/dev={_gb(hlo.collective_bytes)}",
              flush=True)
    return report


def _gb(n) -> str:
    if n is None:
        return "?"
    return f"{n / (1 << 30):.2f}GiB"


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--out", default="reports/dryrun")
    ap.add_argument("--no-master", action="store_true",
                    help="memory-lean optimizer (no fp32 master copy)")
    ap.add_argument("--profile", default=None, choices=["tp", "fsdp"],
                    help="override the arch's sharding profile")
    ap.add_argument("--tag", default="", help="report filename suffix")
    args = ap.parse_args()

    opt_overrides = {"keep_master": False} if args.no_master else None
    meshes = [False, True] if args.both_meshes else [args.multi_pod]
    cells = []
    if args.all:
        for a in ARCH_IDS:
            for s in SHAPES:
                if (a, s) in SKIP_CELLS:
                    print(f"[dryrun] SKIP {a} {s}: {SKIP_CELLS[(a, s)]}")
                    continue
                cells.append((a, s))
    else:
        if not args.arch or not args.shape:
            ap.error("--arch and --shape required unless --all")
        cells.append((resolve(args.arch), args.shape))

    failures = []
    for mp in meshes:
        for a, s in cells:
            try:
                run_cell(a, s, mp, args.out, opt_overrides,
                         profile=args.profile, tag=args.tag)
            except Exception as e:
                failures.append((a, s, mp, repr(e)))
                print(f"[dryrun] FAIL {a} {s} multi_pod={mp}: {e}",
                      flush=True)
                traceback.print_exc()
    if failures:
        print(f"\n{len(failures)} FAILURES:")
        for f in failures:
            print("  ", f)
        sys.exit(1)
    print("\nall dry-run cells passed")


if __name__ == "__main__":
    main()
