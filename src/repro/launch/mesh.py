"""Production mesh construction.

Single pod: 16x16 = 256 chips over ("data", "model").
Multi-pod:  2x16x16 = 512 chips over ("pod", "data", "model") — the pod
axis is an outer data axis (per-pod FSDP, cross-pod gradient all-reduce
over DCN), which is why batch specs shard over ("pod", "data") jointly.

Defined as functions so importing this module never touches jax device
state (device count is locked at first backend init).
"""

from __future__ import annotations

from typing import Tuple

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(
        shape, axes,
        axis_types=(jax.sharding.AxisType.Auto,) * len(axes))


def make_host_mesh():
    """Whatever this host actually has — smoke tests and examples."""
    n = len(jax.devices())
    return jax.make_mesh((n, 1), ("data", "model"),
                         axis_types=(jax.sharding.AxisType.Auto,) * 2)


def batch_axes(mesh) -> Tuple[str, ...]:
    """The axes a global batch is sharded over."""
    return tuple(a for a in mesh.axis_names if a in ("pod", "data"))


def axis_size(mesh, name: str) -> int:
    return mesh.shape[name] if name in mesh.axis_names else 1
