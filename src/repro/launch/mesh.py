"""Production mesh construction.

Single pod: 16x16 = 256 chips over ("data", "model").
Multi-pod:  2x16x16 = 512 chips over ("pod", "data", "model") — the pod
axis is an outer data axis (per-pod FSDP, cross-pod gradient all-reduce
over DCN), which is why batch specs shard over ("pod", "data") jointly.

Defined as functions so importing this module never touches jax device
state (device count is locked at first backend init).
"""

from __future__ import annotations

from typing import Tuple

import jax


def _axis_type_kwargs(n: int) -> dict:
    """axis_types=(Auto,)*n on jax >= 0.5; older jax has neither the enum
    nor the kwarg, and Auto is its only behaviour anyway."""
    axis_type = getattr(jax.sharding, "AxisType", None)
    if axis_type is None:
        return {}
    return {"axis_types": (axis_type.Auto,) * n}


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes, **_axis_type_kwargs(len(axes)))


def make_host_mesh():
    """Whatever this host actually has — smoke tests and examples."""
    n = len(jax.devices())
    return jax.make_mesh((n, 1), ("data", "model"), **_axis_type_kwargs(2))


def mesh_context(mesh):
    """``jax.set_mesh(mesh)`` where available; on older jax the Mesh object
    is itself the context manager (equivalent for Auto-typed axes)."""
    set_mesh = getattr(jax, "set_mesh", None)
    if set_mesh is not None:
        return set_mesh(mesh)
    return mesh


def batch_axes(mesh) -> Tuple[str, ...]:
    """The axes a global batch is sharded over."""
    return tuple(a for a in mesh.axis_names if a in ("pod", "data"))


def axis_size(mesh, name: str) -> int:
    return mesh.shape[name] if name in mesh.axis_names else 1
