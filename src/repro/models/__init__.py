"""Model plane: composable JAX definitions for all assigned architectures.

Everything is pure functions over nested-dict params (no framework deps):
``init(cfg, rng)``, ``loss(cfg, params, batch)``, ``prefill`` / ``decode_step``
with explicit KV/SSM caches.  Sharding lives in
:mod:`repro.launch.sharding`, which mirrors the param tree with
PartitionSpecs; kernels are behind :mod:`repro.kernels.ops` impl flags.
"""

from .config import MLAConfig, MambaConfig, ModelConfig, MoEConfig, RWKVConfig
from .api import build_model

__all__ = [
    "ModelConfig", "MoEConfig", "MLAConfig", "MambaConfig", "RWKVConfig",
    "build_model",
]
