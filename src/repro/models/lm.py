"""Decoder-only LM assembled from a per-layer block pattern.

Covers 9 of the 10 assigned architectures (whisper is enc-dec, see
:mod:`repro.models.whisper`): dense GQA/MQA transformers, MoE, MLA,
Mamba2 hybrids with a shared attention block (Zamba2), and RWKV6.

Layers are grouped into runs of identical (block kind, ffn kind) and each
run's parameters are *stacked* with a leading layer axis; the forward pass
``lax.scan``s over the stack (MaxText-style).  This keeps the HLO size —
and therefore SPMD-partitioning time at 512 devices — independent of
depth, and gives remat a natural per-layer boundary.

API (all pure):
  init(cfg, rng) -> params
  loss(cfg, params, batch) -> scalar           (train)
  prefill(cfg, params, batch, max_len) -> (last_logits, cache)
  decode_step(cfg, params, cache, token, pos) -> (logits, cache)
"""

from __future__ import annotations

import functools
from dataclasses import dataclass
from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp

from . import attention as attn
from . import mlp as mlpm
from . import ssm
from .common import (apply_norm, chunked_softmax_xent, constrain_batch,
                     dense_init, embed_tokens, embedding_init,
                     lm_head_logits, merge_visual, norm_init, positions_for)
from .config import ModelConfig


# ---------------------------------------------------------------------------
# layer grouping
# ---------------------------------------------------------------------------
@dataclass(frozen=True)
class LayerGroup:
    kind: str      # attn | mla | mamba2 | rwkv6 | shared_attn
    ffn: str       # moe | mlp | dense | none
    start: int     # absolute index of first layer in the group
    count: int


def _ffn_kind(cfg: ModelConfig, layer_idx: int) -> str:
    kind = cfg.blocks[layer_idx]
    if kind in ("mamba2", "rwkv6"):
        return "none"
    m = cfg.moe
    if m is None:
        return "mlp"
    if layer_idx >= m.first_dense_layers:
        return "moe"
    return "dense"


def layer_groups(cfg: ModelConfig) -> List[LayerGroup]:
    groups: List[LayerGroup] = []
    for i, kind in enumerate(cfg.blocks):
        sig = (kind, _ffn_kind(cfg, i))
        if groups and kind != "shared_attn" \
                and (groups[-1].kind, groups[-1].ffn) == sig:
            g = groups[-1]
            groups[-1] = LayerGroup(g.kind, g.ffn, g.start, g.count + 1)
        else:
            groups.append(LayerGroup(kind, sig[1], i, 1))
    return groups


# ---------------------------------------------------------------------------
# per-layer init
# ---------------------------------------------------------------------------
def _ffn_init(cfg: ModelConfig, key, ffn: str) -> Dict:
    if ffn == "moe":
        return mlpm.moe_init(cfg, key)
    if ffn == "dense":
        return mlpm.mlp_init(cfg, key, d_ff=cfg.moe.dense_d_ff)
    return mlpm.mlp_init(cfg, key)


def _block_init(cfg: ModelConfig, g: LayerGroup, key) -> Dict:
    ks = jax.random.split(key, 4)
    if g.kind in ("attn", "mla"):
        p = {
            "ln1": norm_init(cfg),
            "attn": attn.attn_init(cfg, ks[0]) if g.kind == "attn"
            else attn.mla_init(cfg, ks[0]),
            "ffn": _ffn_init(cfg, ks[1], g.ffn),
        }
        if not cfg.parallel_block:
            p["ln2"] = norm_init(cfg)
        return p
    if g.kind == "mamba2":
        return {"ln1": norm_init(cfg), "mixer": ssm.mamba2_init(cfg, ks[0])}
    if g.kind == "rwkv6":
        return {"ln1": norm_init(cfg), "tm": ssm.rwkv6_init(cfg, ks[0]),
                "ln2": norm_init(cfg)}
    raise ValueError(g.kind)


def _stack(trees: List[Any]) -> Any:
    return jax.tree.map(lambda *xs: jnp.stack(xs), *trees)


def init(cfg: ModelConfig, rng) -> Dict:
    groups = layer_groups(cfg)
    keys = jax.random.split(rng, cfg.n_layers + 4)
    layers = []
    for g in groups:
        if g.kind == "shared_attn":
            layers.append({})
            continue
        per = [_block_init(cfg, g, keys[g.start + i]) for i in range(g.count)]
        layers.append(_stack(per))
    params: Dict[str, Any] = {
        "embed": embedding_init(cfg, keys[cfg.n_layers]),
        "final_norm": norm_init(cfg),
        "layers": layers,
    }
    if any(k == "shared_attn" for k in cfg.blocks):
        params["shared_block"] = _block_init(
            cfg, LayerGroup("attn", "mlp", 0, 1), keys[cfg.n_layers + 1])
    if not cfg.tie_embeddings:
        params["lm_head"] = dense_init(keys[cfg.n_layers + 2], cfg.d_model,
                                       (cfg.padded_vocab,), cfg.param_jdtype()).T
    if cfg.rwkv is not None:
        params["ln0"] = norm_init(cfg)
    return params


# ---------------------------------------------------------------------------
# forward (training)
# ---------------------------------------------------------------------------
def _apply_ffn(cfg: ModelConfig, ffn_kind: str, fp: Dict, h, serve=False):
    if ffn_kind == "moe":
        return mlpm.moe_apply(cfg, fp, h, serve=serve)
    return mlpm.mlp_apply(cfg, fp, h), jnp.zeros((), jnp.float32)


def _apply_attn_layer(cfg: ModelConfig, kind: str, ffn_kind: str, lp: Dict,
                      x, positions, serve=False):
    attn_fn = attn.attn_apply if kind in ("attn", "shared_attn") else attn.mla_apply
    h = apply_norm(cfg, lp["ln1"], x)
    a = attn_fn(cfg, lp["attn"], h, positions)
    if cfg.parallel_block:
        f, aux = _apply_ffn(cfg, ffn_kind, lp["ffn"], h, serve)
        return x + a + f, aux
    # pin the residual to batch-only sharding at the psum point: without
    # this GSPMD keeps x d_model-sharded and re-gathers it (in f32) for
    # every consumer — ~3 redundant (B,S,D) all-gathers per layer on the
    # tp profile (EXPERIMENTS §Perf it. 12).
    x = constrain_batch(x + a)
    h = apply_norm(cfg, lp["ln2"], x)
    f, aux = _apply_ffn(cfg, ffn_kind, lp["ffn"], h, serve)
    return x + f, aux


def _apply_layer(cfg: ModelConfig, g: LayerGroup, lp: Dict, x, positions,
                 shared: Optional[Dict] = None):
    if g.kind in ("attn", "mla"):
        return _apply_attn_layer(cfg, g.kind, g.ffn, lp, x, positions)
    if g.kind == "shared_attn":
        return _apply_attn_layer(cfg, "attn", "mlp", shared, x, positions)
    if g.kind == "mamba2":
        h = apply_norm(cfg, lp["ln1"], x)
        return x + ssm.mamba2_apply(cfg, lp["mixer"], h), jnp.zeros((), jnp.float32)
    if g.kind == "rwkv6":
        h = apply_norm(cfg, lp["ln1"], x)
        tm, _ = ssm.rwkv6_time_mix(cfg, lp["tm"], h)
        x = x + tm
        h = apply_norm(cfg, lp["ln2"], x)
        cm, _ = ssm.rwkv6_channel_mix(cfg, lp["tm"], h)
        return x + cm, jnp.zeros((), jnp.float32)
    raise ValueError(g.kind)


def backbone(cfg: ModelConfig, params: Dict, batch: Dict) -> Tuple[jax.Array, jax.Array]:
    """tokens -> final hidden states (B,S,D) + total aux loss."""
    x = embed_tokens(cfg, params["embed"], batch["tokens"])
    x = merge_visual(cfg, x, batch)
    x = constrain_batch(x)
    if cfg.rwkv is not None:
        x = apply_norm(cfg, params["ln0"], x)
    positions = positions_for(cfg, batch)
    aux_total = jnp.zeros((), jnp.float32)

    for gi, g in enumerate(layer_groups(cfg)):
        gp = params["layers"][gi]
        if g.kind == "shared_attn":
            def shared_body(x):
                return _apply_layer(cfg, g, {}, x, positions,
                                    shared=params["shared_block"])
            for _ in range(g.count):
                y, aux = (jax.checkpoint(shared_body)(x) if cfg.remat
                          else shared_body(x))
                x = y
                aux_total = aux_total + aux
            continue

        def body(x, lp):
            y, aux = _apply_layer(cfg, g, lp, x, positions)
            return constrain_batch(y), aux

        if cfg.remat:
            pol = (jax.checkpoint_policies.dots_with_no_batch_dims_saveable
                   if cfg.remat_policy == "dots"
                   else jax.checkpoint_policies.nothing_saveable)
            body = jax.checkpoint(body, policy=pol)
        x, auxs = jax.lax.scan(body, x, gp)
        aux_total = aux_total + auxs.sum()
    x = apply_norm(cfg, params["final_norm"], x)
    return x, aux_total


def loss(cfg: ModelConfig, params: Dict, batch: Dict) -> jax.Array:
    h, aux = backbone(cfg, params, batch)
    xent = chunked_softmax_xent(cfg, params["embed"], params.get("lm_head"),
                                h, batch["labels"], batch.get("loss_mask"))
    return xent + aux


def logits_fn(cfg: ModelConfig, params: Dict, batch: Dict) -> jax.Array:
    """Full logits — tiny shapes/tests only."""
    h, _ = backbone(cfg, params, batch)
    return lm_head_logits(cfg, params["embed"], params.get("lm_head"), h)


# ---------------------------------------------------------------------------
# serving: cache init / prefill / decode
# ---------------------------------------------------------------------------
def _cache_one(cfg: ModelConfig, kind: str, batch: int, max_len: int, dt) -> Dict:
    if kind in ("attn", "shared_attn"):
        return attn.attn_init_cache(cfg, batch, max_len, dt)
    if kind == "mla":
        return attn.mla_init_cache(cfg, batch, max_len, dt)
    if kind == "mamba2":
        return ssm.mamba2_init_state(cfg, batch, dt)
    if kind == "rwkv6":
        return ssm.rwkv6_init_state(cfg, batch, dt)
    raise ValueError(kind)


def init_cache(cfg: ModelConfig, batch: int, max_len: int) -> List[Any]:
    """One stacked cache tree per layer group."""
    dt = cfg.compute_jdtype()
    out = []
    for g in layer_groups(cfg):
        one = _cache_one(cfg, g.kind, batch, max_len, dt)
        out.append(jax.tree.map(
            lambda x: jnp.broadcast_to(x[None], (g.count,) + x.shape), one))
    return out


def _prefill_layer(cfg: ModelConfig, g: LayerGroup, lp: Dict, x, positions,
                   cache: Dict, shared: Optional[Dict] = None):
    kind = g.kind
    bp = shared if kind == "shared_attn" else lp
    if kind in ("attn", "shared_attn", "mla"):
        h = apply_norm(cfg, bp["ln1"], x)
        pf = attn.mla_prefill if kind == "mla" else attn.attn_prefill
        a, c = pf(cfg, bp["attn"], h, positions, cache)
        if cfg.parallel_block:
            f, _ = _apply_ffn(cfg, "mlp" if kind == "shared_attn" else g.ffn,
                              bp["ffn"], h, serve=True)
            return x + a + f, c
        x = x + a
        h = apply_norm(cfg, bp["ln2"], x)
        f, _ = _apply_ffn(cfg, "mlp" if kind == "shared_attn" else g.ffn,
                          bp["ffn"], h, serve=True)
        return x + f, c
    if kind == "mamba2":
        h = apply_norm(cfg, lp["ln1"], x)
        out = ssm.mamba2_apply(cfg, lp["mixer"], h)
        c = ssm.mamba2_prefill_state(cfg, lp["mixer"], h, cache)
        return x + out, c
    if kind == "rwkv6":
        h = apply_norm(cfg, lp["ln1"], x)
        tm, (last_x, s) = ssm.rwkv6_time_mix(cfg, lp["tm"], h)
        x = x + tm
        h2 = apply_norm(cfg, lp["ln2"], x)
        cm, cm_last = ssm.rwkv6_channel_mix(cfg, lp["tm"], h2)
        return x + cm, {"tm_x": last_x, "wkv": s, "cm_x": cm_last}
    raise ValueError(kind)


def prefill(cfg: ModelConfig, params: Dict, batch: Dict,
            max_len: int) -> Tuple[jax.Array, List[Any]]:
    """Process a prompt of S tokens; return last-position logits and the
    primed cache (max_len slots)."""
    tokens = batch["tokens"]
    B, S = tokens.shape
    x = embed_tokens(cfg, params["embed"], tokens)
    x = merge_visual(cfg, x, batch)
    if cfg.rwkv is not None:
        x = apply_norm(cfg, params["ln0"], x)
    positions = positions_for(cfg, batch)
    cache0 = init_cache(cfg, B, max_len)
    new_cache: List[Any] = []
    for gi, g in enumerate(layer_groups(cfg)):
        gp = params["layers"][gi]
        cg = cache0[gi]
        if g.kind == "shared_attn":
            cs = []
            for j in range(g.count):
                cj = jax.tree.map(lambda t: t[j], cg)
                x, c = _prefill_layer(cfg, g, {}, x, positions, cj,
                                      shared=params["shared_block"])
                cs.append(c)
            new_cache.append(_stack(cs))
            continue

        def body(x, inp):
            lp, c = inp
            y, c2 = _prefill_layer(cfg, g, lp, x, positions, c)
            return y, c2

        x, cg2 = jax.lax.scan(body, x, (gp, cg))
        new_cache.append(cg2)
    x = apply_norm(cfg, params["final_norm"], x)
    logits = lm_head_logits(cfg, params["embed"], params.get("lm_head"), x[:, -1])
    return logits, new_cache


def _decode_layer(cfg: ModelConfig, g: LayerGroup, lp: Dict, x, pos,
                  cache: Dict, shared: Optional[Dict] = None):
    kind = g.kind
    bp = shared if kind == "shared_attn" else lp
    if kind in ("attn", "shared_attn", "mla"):
        h = apply_norm(cfg, bp["ln1"], x)
        dec = attn.mla_decode if kind == "mla" else attn.attn_decode
        a, c = dec(cfg, bp["attn"], h, pos, cache)
        if cfg.parallel_block:
            f, _ = _apply_ffn(cfg, "mlp" if kind == "shared_attn" else g.ffn,
                              bp["ffn"], h, serve=True)
            return x + a + f, c
        x = x + a
        h = apply_norm(cfg, bp["ln2"], x)
        f, _ = _apply_ffn(cfg, "mlp" if kind == "shared_attn" else g.ffn,
                          bp["ffn"], h, serve=True)
        return x + f, c
    if kind == "mamba2":
        h = apply_norm(cfg, lp["ln1"], x)
        a, c = ssm.mamba2_decode(cfg, lp["mixer"], h, cache)
        return x + a, c
    if kind == "rwkv6":
        h = apply_norm(cfg, lp["ln1"], x)
        tm, c = ssm.rwkv6_decode(cfg, lp["tm"], h, cache)
        x = x + tm
        h2 = apply_norm(cfg, lp["ln2"], x)
        cm, c = ssm.rwkv6_channel_decode(cfg, lp["tm"], h2, c)
        return x + cm, c
    raise ValueError(kind)


def decode_step(cfg: ModelConfig, params: Dict, cache: List[Any],
                token: jax.Array, pos: jax.Array
                ) -> Tuple[jax.Array, List[Any]]:
    """One decode step.  token: (B,), pos: (B,) -> logits (B, V)."""
    x = embed_tokens(cfg, params["embed"], token[:, None])
    if cfg.rwkv is not None:
        x = apply_norm(cfg, params["ln0"], x)
    new_cache: List[Any] = []
    for gi, g in enumerate(layer_groups(cfg)):
        gp = params["layers"][gi]
        cg = cache[gi]
        if g.kind == "shared_attn":
            cs = []
            for j in range(g.count):
                cj = jax.tree.map(lambda t: t[j], cg)
                x, c = _decode_layer(cfg, g, {}, x, pos, cj,
                                     shared=params["shared_block"])
                cs.append(c)
            new_cache.append(_stack(cs))
            continue

        def body(x, inp):
            lp, c = inp
            y, c2 = _decode_layer(cfg, g, lp, x, pos, c)
            return y, c2

        x, cg2 = jax.lax.scan(body, x, (gp, cg))
        new_cache.append(cg2)
    x = apply_norm(cfg, params["final_norm"], x)
    logits = lm_head_logits(cfg, params["embed"], params.get("lm_head"), x[:, 0])
    return logits, new_cache
