"""Architecture configuration dataclasses."""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import Optional, Tuple


@dataclass(frozen=True)
class MoEConfig:
    num_experts: int
    top_k: int
    d_expert: int                 # per-expert FFN hidden size
    num_shared: int = 0           # always-active shared experts
    first_dense_layers: int = 0   # leading layers use a dense FFN
    dense_d_ff: int = 0           # hidden size of those dense layers
    capacity_factor: float = 1.25
    serve_capacity_factor: float = 3.0  # decode/prefill headroom (no-drop margin)
    aux_loss_weight: float = 1e-3
    # capacity dispatch cost scales as group_tokens^2 * K * cf * D — small
    # groups keep the one-hot dispatch einsums a fraction of expert FLOPs
    # (dispatch/expert ~ group_tokens * cf / (3 * d_expert)).
    group_tokens: int = 1024
    map_chunk_groups: int = 4096  # lax.map chunking escape hatch: only
                                  # engages for >4096 groups (dispatch temps
                                  # are mesh-sharded, so vmap is the default;
                                  # each map step re-gathers expert weights)
    dropless: bool = False        # True: sort + ragged_dot (exact; used by
                                  # smoke/tests — the XLA fallback lowers to
                                  # dense per-expert dots, so big shapes use
                                  # the capacity path)


@dataclass(frozen=True)
class MLAConfig:
    q_lora: int = 1536
    kv_lora: int = 512
    qk_nope: int = 128
    qk_rope: int = 64
    v_head: int = 128


@dataclass(frozen=True)
class MambaConfig:
    d_state: int = 64
    d_conv: int = 4
    expand: int = 2
    headdim: int = 64
    ngroups: int = 1

    def d_inner(self, d_model: int) -> int:
        return self.expand * d_model

    def n_heads(self, d_model: int) -> int:
        return self.d_inner(d_model) // self.headdim


@dataclass(frozen=True)
class RWKVConfig:
    head_dim: int = 64
    decay_lora: int = 64
    mix_lora: int = 32


@dataclass(frozen=True)
class EncDecConfig:
    n_enc_layers: int = 4
    n_audio_ctx: int = 1500  # encoder positions (conv frontend stubbed)


@dataclass(frozen=True)
class ModelConfig:
    name: str
    vocab_size: int
    d_model: int
    n_layers: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    head_dim: int = 0  # 0 => d_model // n_heads
    # block pattern: per-layer type; default all "attn".
    #   "attn"        standard (GQA/MQA) attention block
    #   "mla"         multi-head latent attention block (DeepSeek-V2)
    #   "mamba2"      Mamba2 SSD block
    #   "rwkv6"       RWKV6 block (token mix + channel mix)
    #   "shared_attn" shared-parameter attention block (Zamba2)
    block_pattern: Tuple[str, ...] = ()
    mlp_act: str = "silu"           # silu => SwiGLU, gelu => GeGLU, gelu_mlp => plain
    qkv_bias: bool = False
    parallel_block: bool = False     # attn + mlp in parallel (Command-R)
    tie_embeddings: bool = False
    scale_embed: bool = False        # multiply embeddings by sqrt(d) (Gemma)
    norm: str = "rmsnorm"            # or "layernorm"
    norm_eps: float = 1e-6
    norm_unit_offset: bool = False   # RMSNorm computes (1 + w) * x_hat (Gemma)
    rope_theta: float = 10000.0
    rope_type: str = "standard"      # "standard" | "mrope" | "none"
    mrope_sections: Tuple[int, int, int] = (16, 24, 24)
    logit_softcap: float = 0.0
    moe: Optional[MoEConfig] = None
    mla: Optional[MLAConfig] = None
    mamba: Optional[MambaConfig] = None
    rwkv: Optional[RWKVConfig] = None
    enc_dec: Optional[EncDecConfig] = None
    visual_stub: bool = False        # qwen2-vl patch-embedding merge stub
    param_dtype: str = "bfloat16"
    compute_dtype: str = "bfloat16"
    vocab_round: int = 256           # pad vocab for shardability
    loss_chunk: int = 1024           # sequence-chunked softmax-xent
    remat: bool = True
    # "nothing": full recompute (min memory); "dots": keep matmul
    # outputs (no dot recompute in bwd — higher useful-FLOP ratio
    # when HBM allows, see §Perf)
    remat_policy: str = "nothing"
    attn_impl: str = "ref"           # kernels/ops impl selector
    scan_impl: str = "ref"
    # "fsdp": model axis = extra data/param shards (best for small-to-mid
    # models at large batch); "tp": Megatron activation sharding on the
    # model axis (needed when per-layer weights dwarf activations, e.g.
    # DeepSeek-V2's 160-expert layers where EP is mandatory).
    sharding_profile: str = "fsdp"

    @property
    def hd(self) -> int:
        return self.head_dim if self.head_dim else self.d_model // self.n_heads

    @property
    def padded_vocab(self) -> int:
        r = self.vocab_round
        return (self.vocab_size + r - 1) // r * r

    @property
    def blocks(self) -> Tuple[str, ...]:
        if self.block_pattern:
            if len(self.block_pattern) != self.n_layers:
                raise ValueError("block_pattern length must equal n_layers")
            return self.block_pattern
        return ("attn",) * self.n_layers

    def param_jdtype(self):
        import jax.numpy as jnp

        return {"bfloat16": jnp.bfloat16, "float32": jnp.float32}[self.param_dtype]

    def compute_jdtype(self):
        import jax.numpy as jnp

        return {"bfloat16": jnp.bfloat16, "float32": jnp.float32}[self.compute_dtype]
