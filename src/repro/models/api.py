"""Unified model front-door: ``build_model(cfg)`` returns a Model facade
with init / loss / prefill / decode_step bound to the right family."""

from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Callable, Dict, Optional

from . import lm, whisper
from .config import ModelConfig


@dataclass(frozen=True)
class Model:
    cfg: ModelConfig
    init: Callable
    loss: Callable
    prefill: Callable
    decode_step: Callable
    logits: Optional[Callable] = None

    @property
    def is_enc_dec(self) -> bool:
        return self.cfg.enc_dec is not None


def build_model(cfg: ModelConfig) -> Model:
    if cfg.enc_dec is not None:
        return Model(
            cfg=cfg,
            init=lambda rng: whisper.init(cfg, rng),
            loss=lambda params, batch: whisper.loss(cfg, params, batch),
            prefill=lambda params, batch, max_len: whisper.prefill(cfg, params, batch, max_len),
            decode_step=lambda params, cache, token, pos: whisper.decode_step(cfg, params, cache, token, pos),
        )
    return Model(
        cfg=cfg,
        init=lambda rng: lm.init(cfg, rng),
        loss=lambda params, batch: lm.loss(cfg, params, batch),
        prefill=lambda params, batch, max_len: lm.prefill(cfg, params, batch, max_len),
        decode_step=lambda params, cache, token, pos: lm.decode_step(cfg, params, cache, token, pos),
        logits=lambda params, batch: lm.logits_fn(cfg, params, batch),
    )
