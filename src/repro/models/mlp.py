"""Feed-forward blocks: gated MLPs (SwiGLU / GeGLU) and mixture-of-experts.

The MoE layer uses the capacity-dispatch formulation (Switch/t5x style):
tokens pick top-k experts, positions inside an expert's buffer come from a
cumulative sum (no sort), and dispatch/combine are einsums against a
(tokens, experts, capacity) one-hot — the formulation GSPMD partitions
well with experts on the "model" axis (EP) and tokens on "data".
``group_chunk`` processes groups of sequences through a lax.map to bound
the transient dispatch tensors for very large shapes (the hillclimb knob).
"""

from __future__ import annotations

from typing import Dict, Optional, Tuple

import math

import jax
import jax.numpy as jnp

from .common import act_fn, constrain_dims, constrain_hidden, dense_init
from .config import ModelConfig, MoEConfig


# ---------------------------------------------------------------------------
# dense gated MLP
# ---------------------------------------------------------------------------
def mlp_init(cfg: ModelConfig, key, d_ff: Optional[int] = None) -> Dict:
    d_ff = d_ff or cfg.d_ff
    dt = cfg.param_jdtype()
    ks = jax.random.split(key, 3)
    return {
        "wi": dense_init(ks[0], cfg.d_model, (d_ff,), dt),    # gate proj
        "wg": dense_init(ks[1], cfg.d_model, (d_ff,), dt),    # up proj
        "wo": dense_init(ks[2], d_ff, (cfg.d_model,), dt),
    }


def mlp_apply(cfg: ModelConfig, p: Dict, x: jax.Array) -> jax.Array:
    act = act_fn(cfg.mlp_act)
    gate = act(jnp.einsum("bsd,df->bsf", x, p["wi"].astype(x.dtype)))
    if cfg.mlp_act == "gelu_mlp":  # plain 2-layer MLP (whisper)
        h = gate
    else:
        h = gate * jnp.einsum("bsd,df->bsf", x, p["wg"].astype(x.dtype))
    h = constrain_hidden(h)  # ffn dim on "model": Megatron column-parallel
    return jnp.einsum("bsf,fd->bsd", h, p["wo"].astype(x.dtype))


# ---------------------------------------------------------------------------
# mixture of experts
# ---------------------------------------------------------------------------
def moe_init(cfg: ModelConfig, key) -> Dict:
    m = cfg.moe
    dt = cfg.param_jdtype()
    ks = jax.random.split(key, 5)
    D, E, F = cfg.d_model, m.num_experts, m.d_expert
    p = {
        "router": dense_init(ks[0], D, (E,), jnp.float32),
        "wi": dense_init(ks[1], D, (E, F), dt).transpose(1, 0, 2),  # (E,D,F)
        "wg": dense_init(ks[2], D, (E, F), dt).transpose(1, 0, 2),
        "wo": dense_init(ks[3], F, (E, D), dt).transpose(1, 0, 2),  # (E,F,D)
    }
    if m.num_shared:
        sk = jax.random.split(ks[4], 3)
        Fs = m.d_expert * m.num_shared
        p["shared"] = {
            "wi": dense_init(sk[0], D, (Fs,), dt),
            "wg": dense_init(sk[1], D, (Fs,), dt),
            "wo": dense_init(sk[2], Fs, (D,), dt),
        }
    return p


def _moe_group(cfg: ModelConfig, p: Dict, x: jax.Array,
               cf: Optional[float] = None) -> Tuple[jax.Array, jax.Array]:
    """MoE over one token group.  x: (T, D) -> (y (T, D), aux scalar)."""
    m = cfg.moe
    T, D = x.shape
    E, K, F = m.num_experts, m.top_k, m.d_expert
    cf = m.capacity_factor if cf is None else cf
    C = max(1, int(T * K * cf / E))
    act = act_fn(cfg.mlp_act)

    logits = jnp.einsum("td,de->te", x, p["router"].astype(x.dtype),
                        preferred_element_type=jnp.float32)
    probs = jax.nn.softmax(logits, axis=-1)
    gate_w, gate_i = jax.lax.top_k(probs, K)                  # (T,K)
    gate_w = gate_w / jnp.maximum(gate_w.sum(-1, keepdims=True), 1e-9)

    # load-balancing auxiliary loss (Switch): E * sum_e f_e * P_e
    me = probs.mean(0)                                        # (E,)
    onehot_k = jax.nn.one_hot(gate_i, E, dtype=jnp.float32)   # (T,K,E)
    ce = onehot_k.sum(1).mean(0)                              # fraction per expert
    aux = (me * ce).sum() * E * m.aux_loss_weight

    # position of each (t, k) assignment inside its expert buffer
    flat = onehot_k.reshape(T * K, E)                         # row-major: t-major, k-minor
    pos = (jnp.cumsum(flat, axis=0) - flat)                   # (T*K, E) exclusive
    pos = (pos * flat).sum(-1).reshape(T, K)                  # (T,K)
    keep = pos < C
    gate_w = gate_w * keep

    # dispatch one-hot: (T, K, E, C) -> einsum'd, never stored past fusion
    pos_oh = jax.nn.one_hot(jnp.where(keep, pos, C), C, dtype=x.dtype)  # (T,K,C)
    disp = jnp.einsum("tke,tkc->tec", onehot_k.astype(x.dtype), pos_oh)
    expert_in = jnp.einsum("tec,td->ecd", disp, x)            # (E,C,D)
    expert_in = constrain_dims(expert_in, {0: "model"})       # EP over "model"

    h = act(jnp.einsum("ecd,edf->ecf", expert_in, p["wi"].astype(x.dtype)))
    h = h * jnp.einsum("ecd,edf->ecf", expert_in, p["wg"].astype(x.dtype))
    h = constrain_dims(h, {0: "model", 2: "model"})           # EP, else TP-in-expert
    expert_out = jnp.einsum("ecf,efd->ecd", h, p["wo"].astype(x.dtype))  # (E,C,D)
    expert_out = constrain_dims(expert_out, {0: "model"})

    comb = jnp.einsum("tke,tkc,tk->tec", onehot_k.astype(x.dtype), pos_oh,
                      gate_w.astype(x.dtype))
    y = jnp.einsum("tec,ecd->td", comb, expert_out)
    return y, aux


def _moe_group_dropless(cfg: ModelConfig, p: Dict, x: jax.Array) -> Tuple[jax.Array, jax.Array]:
    """Dropless megablocks-style dispatch: sort (token, k) assignments by
    expert and run grouped matmuls with ``jax.lax.ragged_dot``.  Exact —
    no capacity, no drops — hence also the serving path."""
    m = cfg.moe
    T, D = x.shape
    E, K = m.num_experts, m.top_k
    act = act_fn(cfg.mlp_act)

    logits = jnp.einsum("td,de->te", x, p["router"].astype(x.dtype),
                        preferred_element_type=jnp.float32)
    probs = jax.nn.softmax(logits, axis=-1)
    gate_w, gate_i = jax.lax.top_k(probs, K)
    gate_w = gate_w / jnp.maximum(gate_w.sum(-1, keepdims=True), 1e-9)

    me = probs.mean(0)
    onehot_k = jax.nn.one_hot(gate_i, E, dtype=jnp.float32)
    ce = onehot_k.sum(1).mean(0)
    aux = (me * ce).sum() * E * m.aux_loss_weight

    flat_e = gate_i.reshape(-1)                    # (T*K,)
    order = jnp.argsort(flat_e)                    # stable sort by expert
    tok = order // K
    xs = x[tok]                                    # (T*K, D)
    group_sizes = jnp.bincount(flat_e, length=E).astype(jnp.int32)
    h = act(jax.lax.ragged_dot(xs, p["wi"].astype(x.dtype), group_sizes))
    h = h * jax.lax.ragged_dot(xs, p["wg"].astype(x.dtype), group_sizes)
    h = constrain_dims(h, {1: "model"})
    out = jax.lax.ragged_dot(h, p["wo"].astype(x.dtype), group_sizes)  # (T*K, D)
    w_sorted = gate_w.reshape(-1)[order].astype(x.dtype)
    y = jnp.zeros_like(x).at[tok].add(out * w_sorted[:, None])
    return y, aux


def moe_apply(cfg: ModelConfig, p: Dict, x: jax.Array,
              serve: bool = False) -> Tuple[jax.Array, jax.Array]:
    """x: (B,S,D) -> (y, aux_loss).

    Capacity dispatch over fixed-size token groups (``group_tokens``),
    vmapped per group and lax.map'd over chunks of groups so the one-hot
    dispatch temporaries stay bounded.  ``serve=True`` uses the larger
    no-drop capacity margin; configs with ``dropless=True`` (smoke/tests)
    take the exact sort+ragged_dot path instead.
    """
    m = cfg.moe
    B, S, D = x.shape
    cf = m.serve_capacity_factor if serve else m.capacity_factor

    if m.dropless:
        y, aux = _moe_group_dropless(cfg, p, x.reshape(B * S, D))
        y = y.reshape(B, S, D)
    else:
        T = B * S
        gt = min(m.group_tokens, T)
        if T % gt:
            gt = math.gcd(T, gt)
        groups = T // gt
        xg = x.reshape(groups, gt, D)

        def do_group(g):
            return _moe_group(cfg, p, g, cf)

        mc = m.map_chunk_groups
        if groups > mc and groups % mc == 0:
            ys, auxs = jax.lax.map(lambda ch: jax.vmap(do_group)(ch),
                                   xg.reshape(groups // mc, mc, gt, D))
            y = ys.reshape(B, S, D)
            aux = auxs.mean()
        else:
            ys, auxs = jax.vmap(do_group)(xg)
            y = ys.reshape(B, S, D)
            aux = auxs.mean()

    if m.num_shared:
        sp = p["shared"]
        act = act_fn(cfg.mlp_act)
        g = act(jnp.einsum("bsd,df->bsf", x, sp["wi"].astype(x.dtype)))
        h = g * jnp.einsum("bsd,df->bsf", x, sp["wg"].astype(x.dtype))
        h = constrain_hidden(h)
        y = y + jnp.einsum("bsf,fd->bsd", h, sp["wo"].astype(x.dtype))
    return y, aux


def moe_apply_dense_oracle(cfg: ModelConfig, p: Dict, x: jax.Array) -> jax.Array:
    """All-experts dense evaluation with top-k gating — the correctness
    oracle for tests (O(E) flops; tiny shapes only).  No capacity drops."""
    m = cfg.moe
    B, S, D = x.shape
    act = act_fn(cfg.mlp_act)
    xf = x.reshape(B * S, D)
    logits = jnp.einsum("td,de->te", xf.astype(jnp.float32), p["router"])
    probs = jax.nn.softmax(logits, axis=-1)
    gate_w, gate_i = jax.lax.top_k(probs, m.top_k)
    gate_w = gate_w / jnp.maximum(gate_w.sum(-1, keepdims=True), 1e-9)
    w_full = jnp.zeros_like(probs)
    w_full = jax.vmap(lambda w, gw, gi: w.at[gi].set(gw))(w_full, gate_w, gate_i)
    h = act(jnp.einsum("td,edf->etf", xf, p["wi"].astype(x.dtype)))
    h = h * jnp.einsum("td,edf->etf", xf, p["wg"].astype(x.dtype))
    out = jnp.einsum("etf,efd->etd", h, p["wo"].astype(x.dtype))
    y = jnp.einsum("te,etd->td", w_full.astype(x.dtype), out).reshape(B, S, D)
    if m.num_shared:
        sp = p["shared"]
        g = act(jnp.einsum("bsd,df->bsf", x, sp["wi"].astype(x.dtype)))
        hh = g * jnp.einsum("bsd,df->bsf", x, sp["wg"].astype(x.dtype))
        y = y + jnp.einsum("bsf,fd->bsd", hh, sp["wo"].astype(x.dtype))
    return y
