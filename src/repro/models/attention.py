"""Attention blocks: GQA/MQA (with RoPE / M-RoPE) and DeepSeek-V2 MLA.

Each block provides ``init``, ``apply`` (full-sequence, causal) and
``decode`` (one token against a mutable KV cache).  Caches are plain dicts
of arrays; sharding is attached externally.
"""

from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.kernels import ops
from .common import (apply_mrope, apply_norm, apply_rope, constrain_dims,
                     dense_init, norm_init)
from .config import ModelConfig


# ---------------------------------------------------------------------------
# standard GQA attention
# ---------------------------------------------------------------------------
def attn_init(cfg: ModelConfig, key) -> Dict:
    D, H, KV, hd = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.hd
    ks = jax.random.split(key, 4)
    dt = cfg.param_jdtype()
    p = {
        "wq": dense_init(ks[0], D, (H, hd), dt),
        "wk": dense_init(ks[1], D, (KV, hd), dt),
        "wv": dense_init(ks[2], D, (KV, hd), dt),
        "wo": dense_init(ks[3], H * hd, (D,), dt).reshape(H, hd, D),
    }
    if cfg.qkv_bias:
        p["bq"] = jnp.zeros((H, hd), dt)
        p["bk"] = jnp.zeros((KV, hd), dt)
        p["bv"] = jnp.zeros((KV, hd), dt)
    return p


def _qkv(cfg: ModelConfig, p: Dict, x: jax.Array, positions) -> Tuple:
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"].astype(x.dtype))
    k = jnp.einsum("bsd,dhk->bshk", x, p["wk"].astype(x.dtype))
    v = jnp.einsum("bsd,dhk->bshk", x, p["wv"].astype(x.dtype))
    if cfg.qkv_bias:
        q = q + p["bq"].astype(x.dtype)
        k = k + p["bk"].astype(x.dtype)
        v = v + p["bv"].astype(x.dtype)
    if cfg.rope_type == "mrope":
        q = apply_mrope(q, positions, cfg.rope_theta, cfg.mrope_sections)
        k = apply_mrope(k, positions, cfg.rope_theta, cfg.mrope_sections)
    elif cfg.rope_type == "standard":
        q = apply_rope(q, positions, cfg.rope_theta)
        k = apply_rope(k, positions, cfg.rope_theta)
    # heads on "model"; if the head count does not divide (28-head qwen,
    # MQA), q falls back to SEQUENCE sharding (context parallelism) and
    # k/v stay replicated over model.  Never shard head_dim: it is the
    # attention contraction dim, and sharding it makes GSPMD psum
    # (B,H,S,block) logits per kv block — measured at ~6 TiB/device for
    # qwen prefill_32k (EXPERIMENTS §Perf it. 8).
    q = constrain_dims(q, {0: "dp", 2: "model", 1: "model"})
    k = constrain_dims(k, {0: "dp", 2: "model"})
    v = constrain_dims(v, {0: "dp", 2: "model"})
    return q, k, v


def attn_apply(cfg: ModelConfig, p: Dict, x: jax.Array, positions,
               causal: bool = True) -> jax.Array:
    """x: (B,S,D) -> (B,S,D), full-sequence causal attention."""
    q, k, v = _qkv(cfg, p, x, positions)
    o = ops.attention(q.transpose(0, 2, 1, 3), k.transpose(0, 2, 1, 3),
                      v.transpose(0, 2, 1, 3), causal=causal,
                      impl=cfg.attn_impl)
    o = o.transpose(0, 2, 1, 3)  # (B,S,H,hd)
    o = constrain_dims(o, {0: "dp", 2: "model", 1: "model"})
    return jnp.einsum("bshk,hkd->bsd", o, p["wo"].astype(x.dtype))


def attn_init_cache(cfg: ModelConfig, batch: int, max_len: int,
                    dtype) -> Dict:
    KV, hd = cfg.n_kv_heads, cfg.hd
    return {
        "k": jnp.zeros((batch, max_len, KV, hd), dtype),
        "v": jnp.zeros((batch, max_len, KV, hd), dtype),
    }


def attn_prefill(cfg: ModelConfig, p: Dict, x: jax.Array, positions,
                 cache: Dict) -> Tuple[jax.Array, Dict]:
    q, k, v = _qkv(cfg, p, x, positions)
    S = x.shape[1]
    cache = {
        "k": jax.lax.dynamic_update_slice_in_dim(cache["k"], k.astype(cache["k"].dtype), 0, 1),
        "v": jax.lax.dynamic_update_slice_in_dim(cache["v"], v.astype(cache["v"].dtype), 0, 1),
    }
    o = ops.attention(q.transpose(0, 2, 1, 3), k.transpose(0, 2, 1, 3),
                      v.transpose(0, 2, 1, 3), causal=True, impl=cfg.attn_impl)
    o = o.transpose(0, 2, 1, 3)
    return jnp.einsum("bshk,hkd->bsd", o, p["wo"].astype(x.dtype)), cache


def attn_decode(cfg: ModelConfig, p: Dict, x: jax.Array, pos: jax.Array,
                cache: Dict) -> Tuple[jax.Array, Dict]:
    """x: (B,1,D); pos: (B,) current position; in-cache attention."""
    B = x.shape[0]
    if cfg.rope_type == "mrope":
        positions = jnp.broadcast_to(pos[None, :, None], (3, B, 1))
    else:
        positions = pos[:, None]
    q, k, v = _qkv(cfg, p, x, positions)
    ck = jax.vmap(lambda c, upd, i: jax.lax.dynamic_update_slice_in_dim(c, upd, i, 0))(
        cache["k"], k.astype(cache["k"].dtype), pos)
    cv = jax.vmap(lambda c, upd, i: jax.lax.dynamic_update_slice_in_dim(c, upd, i, 0))(
        cache["v"], v.astype(cache["v"].dtype), pos)
    o = ops.decode_attention(q[:, 0], ck.transpose(0, 2, 1, 3),
                             cv.transpose(0, 2, 1, 3), pos + 1,
                             impl=cfg.attn_impl if cfg.attn_impl != "ref" else "ref")
    o = o[:, None]  # (B,1,H,hd)
    return (jnp.einsum("bshk,hkd->bsd", o, p["wo"].astype(x.dtype)),
            {"k": ck, "v": cv})


# ---------------------------------------------------------------------------
# DeepSeek-V2 Multi-head Latent Attention
# ---------------------------------------------------------------------------
def mla_init(cfg: ModelConfig, key) -> Dict:
    m = cfg.mla
    D, H = cfg.d_model, cfg.n_heads
    dt = cfg.param_jdtype()
    ks = jax.random.split(key, 8)
    qk_head = m.qk_nope + m.qk_rope
    return {
        "q_down": dense_init(ks[0], D, (m.q_lora,), dt),
        "q_norm": norm_init(cfg, m.q_lora),
        "q_up": dense_init(ks[1], m.q_lora, (H, qk_head), dt),
        "kv_down": dense_init(ks[2], D, (m.kv_lora + m.qk_rope,), dt),
        "kv_norm": norm_init(cfg, m.kv_lora),
        "k_up": dense_init(ks[3], m.kv_lora, (H, m.qk_nope), dt),
        "v_up": dense_init(ks[4], m.kv_lora, (H, m.v_head), dt),
        "wo": dense_init(ks[5], H * m.v_head, (D,), dt).reshape(H, m.v_head, D),
    }


def _mla_qkv(cfg: ModelConfig, p: Dict, x: jax.Array, positions):
    m = cfg.mla
    cq = jnp.einsum("bsd,dl->bsl", x, p["q_down"].astype(x.dtype))
    cq = apply_norm(cfg, p["q_norm"], cq)
    q = jnp.einsum("bsl,lhk->bshk", cq, p["q_up"].astype(x.dtype))
    q_nope, q_pe = q[..., : m.qk_nope], q[..., m.qk_nope:]
    ckv_full = jnp.einsum("bsd,dl->bsl", x, p["kv_down"].astype(x.dtype))
    ckv, k_pe = ckv_full[..., : m.kv_lora], ckv_full[..., m.kv_lora:]
    ckv = apply_norm(cfg, p["kv_norm"], ckv)
    q_pe = apply_rope(q_pe, positions, cfg.rope_theta)
    k_pe = apply_rope(k_pe[:, :, None, :], positions, cfg.rope_theta)[:, :, 0]
    q_nope = constrain_dims(q_nope, {0: "dp", 2: "model"})
    q_pe = constrain_dims(q_pe, {0: "dp", 2: "model"})
    return q_nope, q_pe, ckv, k_pe


def mla_apply(cfg: ModelConfig, p: Dict, x: jax.Array, positions,
              causal: bool = True) -> jax.Array:
    m = cfg.mla
    q_nope, q_pe, ckv, k_pe = _mla_qkv(cfg, p, x, positions)
    k_nope = jnp.einsum("bsl,lhk->bshk", ckv, p["k_up"].astype(x.dtype))
    v = jnp.einsum("bsl,lhk->bshk", ckv, p["v_up"].astype(x.dtype))
    k_nope = constrain_dims(k_nope, {0: "dp", 2: "model"})
    v = constrain_dims(v, {0: "dp", 2: "model"})
    q = jnp.concatenate([q_nope, q_pe], -1)
    k = jnp.concatenate([k_nope,
                         jnp.broadcast_to(k_pe[:, :, None, :],
                                          k_nope.shape[:3] + (m.qk_rope,))], -1)
    scale = (m.qk_nope + m.qk_rope) ** -0.5
    # pad v to qk head dim for the shared kernel, then slice back
    o = ops.attention(q.transpose(0, 2, 1, 3), k.transpose(0, 2, 1, 3),
                      jnp.pad(v, ((0, 0), (0, 0), (0, 0),
                                  (0, q.shape[-1] - m.v_head))).transpose(0, 2, 1, 3),
                      causal=causal, scale=scale, impl=cfg.attn_impl)
    o = o.transpose(0, 2, 1, 3)[..., : m.v_head]
    o = constrain_dims(o, {0: "dp", 2: "model"})
    return jnp.einsum("bshk,hkd->bsd", o, p["wo"].astype(x.dtype))


def mla_init_cache(cfg: ModelConfig, batch: int, max_len: int, dtype) -> Dict:
    m = cfg.mla
    # the MLA trick: cache ONLY the compressed latent + shared rope key —
    # (kv_lora + qk_rope) per token instead of 2*H*hd.
    return {"ckv": jnp.zeros((batch, max_len, m.kv_lora), dtype),
            "kpe": jnp.zeros((batch, max_len, m.qk_rope), dtype)}


def mla_prefill(cfg: ModelConfig, p: Dict, x: jax.Array, positions,
                cache: Dict) -> Tuple[jax.Array, Dict]:
    q_nope, q_pe, ckv, k_pe = _mla_qkv(cfg, p, x, positions)
    cache = {
        "ckv": jax.lax.dynamic_update_slice_in_dim(
            cache["ckv"], ckv.astype(cache["ckv"].dtype), 0, 1),
        "kpe": jax.lax.dynamic_update_slice_in_dim(
            cache["kpe"], k_pe.astype(cache["kpe"].dtype), 0, 1),
    }
    out = mla_apply(cfg, p, x, positions)  # recompute path for prefill
    return out, cache


def mla_decode(cfg: ModelConfig, p: Dict, x: jax.Array, pos: jax.Array,
               cache: Dict) -> Tuple[jax.Array, Dict]:
    """Latent-space decode: queries are projected INTO the compressed space
    (absorbed k_up) so attention runs against the (kv_lora+rope) cache
    directly — the MLA serving trick."""
    m = cfg.mla
    B = x.shape[0]
    positions = pos[:, None]
    q_nope, q_pe, ckv_new, kpe_new = _mla_qkv(cfg, p, x, positions)
    ckv_c = jax.vmap(lambda c, u, i: jax.lax.dynamic_update_slice_in_dim(c, u, i, 0))(
        cache["ckv"], ckv_new.astype(cache["ckv"].dtype), pos)
    kpe_c = jax.vmap(lambda c, u, i: jax.lax.dynamic_update_slice_in_dim(c, u, i, 0))(
        cache["kpe"], kpe_new.astype(cache["kpe"].dtype), pos)
    # absorb k_up into q:   q_lat = q_nope @ k_up^T  -> (B,1,H,kv_lora)
    q_lat = jnp.einsum("bshk,lhk->bshl", q_nope, p["k_up"].astype(x.dtype))
    scale = (m.qk_nope + m.qk_rope) ** -0.5
    T = ckv_c.shape[1]
    logits = (jnp.einsum("bhl,btl->bht", q_lat[:, 0], ckv_c)
              + jnp.einsum("bhk,btk->bht", q_pe[:, 0], kpe_c)) * scale
    mask = jnp.arange(T)[None, None, :] <= pos[:, None, None]
    logits = jnp.where(mask, logits.astype(jnp.float32), -1e30)
    w = jax.nn.softmax(logits, axis=-1).astype(x.dtype)
    ctx = jnp.einsum("bht,btl->bhl", w, ckv_c)          # (B,H,kv_lora)
    o = jnp.einsum("bhl,lhk->bhk", ctx, p["v_up"].astype(x.dtype))
    out = jnp.einsum("bhk,hkd->bd", o, p["wo"].astype(x.dtype))[:, None]
    return out, {"ckv": ckv_c, "kpe": kpe_c}
