"""Whisper-style encoder-decoder (audio backbone; conv frontend stubbed).

``input_specs`` for this arch provides precomputed frame embeddings
(B, n_audio_ctx, d_model) — the mel-spectrogram conv stem is the modality
frontend and out of scope per the assignment.  Everything downstream is
real: sinusoidal encoder positions, bidirectional encoder self-attention,
causal decoder self-attention + cross-attention, tied LM head.
"""

from __future__ import annotations

import math
from typing import Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.kernels import ops
from . import attention as attn
from . import mlp as mlpm
from .common import (apply_norm, chunked_softmax_xent, dense_init,
                     embed_tokens, embedding_init, lm_head_logits, norm_init)
from .config import ModelConfig


def sinusoids(length: int, channels: int) -> jax.Array:
    log_timescale = math.log(10000.0) / (channels // 2 - 1)
    inv = jnp.exp(-log_timescale * jnp.arange(channels // 2))
    ang = jnp.arange(length)[:, None] * inv[None, :]
    return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], axis=1)


def _xattn_init(cfg: ModelConfig, key) -> Dict:
    return attn.attn_init(cfg, key)


def init(cfg: ModelConfig, rng) -> Dict:
    ed = cfg.enc_dec
    keys = jax.random.split(rng, 2 * cfg.n_layers + ed.n_enc_layers * 2 + 8)
    ki = iter(range(len(keys)))
    p: Dict = {
        "embed": embedding_init(cfg, keys[next(ki)]),
        # learned decoder positions, sized for the largest assigned decoder
        # context (prefill_32k); whisper-tiny's published 448 is a subset.
        "pos_dec": (jax.random.normal(keys[next(ki)], (32768 + 8, cfg.d_model))
                    * 0.01).astype(cfg.param_jdtype()),
        "enc_layers": [], "dec_layers": [],
        "enc_norm": norm_init(cfg), "dec_norm": norm_init(cfg),
    }
    for _ in range(ed.n_enc_layers):
        p["enc_layers"].append({
            "ln1": norm_init(cfg),
            "attn": attn.attn_init(cfg, keys[next(ki)]),
            "ln2": norm_init(cfg),
            "mlp": mlpm.mlp_init(cfg, keys[next(ki)]),
        })
    for _ in range(cfg.n_layers):
        p["dec_layers"].append({
            "ln1": norm_init(cfg),
            "attn": attn.attn_init(cfg, keys[next(ki)]),
            "lnx": norm_init(cfg),
            "xattn": _xattn_init(cfg, keys[next(ki)]),
            "ln2": norm_init(cfg),
            "mlp": mlpm.mlp_init(cfg, keys[next(ki)]),
        })
    return p


def _self_attn(cfg, bp, x, positions, causal):
    h = apply_norm(cfg, bp["ln1"], x)
    return x + attn.attn_apply(cfg, bp["attn"], h, positions, causal=causal)


def _cross_attn(cfg, bp, x, mem_k, mem_v):
    """Pre-projected encoder memory keys/values."""
    h = apply_norm(cfg, bp["lnx"], x)
    q = jnp.einsum("bsd,dhk->bshk", h, bp["xattn"]["wq"].astype(h.dtype))
    if cfg.qkv_bias:
        q = q + bp["xattn"]["bq"].astype(h.dtype)
    o = ops.attention(q.transpose(0, 2, 1, 3), mem_k, mem_v, causal=False,
                      impl=cfg.attn_impl)
    o = o.transpose(0, 2, 1, 3)
    return x + jnp.einsum("bshk,hkd->bsd", o, bp["xattn"]["wo"].astype(h.dtype))


def _mlp(cfg, bp, x):
    h = apply_norm(cfg, bp["ln2"], x)
    return x + mlpm.mlp_apply(cfg, bp["mlp"], h)


def _mem_kv(cfg, bp, mem):
    k = jnp.einsum("btd,dhk->bthk", mem, bp["xattn"]["wk"].astype(mem.dtype))
    v = jnp.einsum("btd,dhk->bthk", mem, bp["xattn"]["wv"].astype(mem.dtype))
    if cfg.qkv_bias:
        k = k + bp["xattn"]["bk"].astype(mem.dtype)
        v = v + bp["xattn"]["bv"].astype(mem.dtype)
    return k.transpose(0, 2, 1, 3), v.transpose(0, 2, 1, 3)


def encode(cfg: ModelConfig, params: Dict, frames: jax.Array) -> jax.Array:
    """frames: (B, T_audio, D) precomputed embeddings (conv stub)."""
    B, T, D = frames.shape
    x = frames.astype(cfg.compute_jdtype()) + sinusoids(T, D).astype(cfg.compute_jdtype())[None]
    positions = jnp.broadcast_to(jnp.arange(T)[None], (B, T))
    for bp in params["enc_layers"]:
        x = _self_attn(cfg, bp, x, positions, causal=False)
        x = _mlp(cfg, bp, x)
    return apply_norm(cfg, params["enc_norm"], x)


def _decoder_embed(cfg, params, tokens, pos0: int = 0):
    B, S = tokens.shape
    x = embed_tokens(cfg, params["embed"], tokens)
    x = x + params["pos_dec"].astype(x.dtype)[pos0 : pos0 + S][None]
    return x


def loss(cfg: ModelConfig, params: Dict, batch: Dict) -> jax.Array:
    """batch: frames (B,T,D), tokens (B,S), labels (B,S)."""
    mem = encode(cfg, params, batch["frames"])
    tokens = batch["tokens"]
    B, S = tokens.shape
    x = _decoder_embed(cfg, params, tokens)
    positions = jnp.broadcast_to(jnp.arange(S)[None], (B, S))
    for bp in params["dec_layers"]:
        def run(x):
            mk, mv = _mem_kv(cfg, bp, mem)
            h = _self_attn(cfg, bp, x, positions, causal=True)
            h = _cross_attn(cfg, bp, h, mk, mv)
            return _mlp(cfg, bp, h)
        x = jax.checkpoint(run)(x) if cfg.remat else run(x)
    x = apply_norm(cfg, params["dec_norm"], x)
    return chunked_softmax_xent(cfg, params["embed"], None, x, batch["labels"],
                                batch.get("loss_mask"))


# -- serving ------------------------------------------------------------------
def init_cache(cfg: ModelConfig, params: Dict, mem: jax.Array,
               max_len: int) -> Dict:
    """Self-attn caches + precomputed cross K/V per decoder layer."""
    B = mem.shape[0]
    dt = cfg.compute_jdtype()
    layers = []
    for bp in params["dec_layers"]:
        mk, mv = _mem_kv(cfg, bp, mem)
        layers.append({
            "self": attn.attn_init_cache(cfg, B, max_len, dt),
            "mem_k": mk, "mem_v": mv,
        })
    return {"layers": layers}


def prefill(cfg: ModelConfig, params: Dict, batch: Dict,
            max_len: int) -> Tuple[jax.Array, Dict]:
    mem = encode(cfg, params, batch["frames"])
    cache = init_cache(cfg, params, mem, max_len)
    tokens = batch["tokens"]
    B, S = tokens.shape
    x = _decoder_embed(cfg, params, tokens)
    positions = jnp.broadcast_to(jnp.arange(S)[None], (B, S))
    for bp, lc in zip(params["dec_layers"], cache["layers"]):
        h = apply_norm(cfg, bp["ln1"], x)
        a, lc["self"] = attn.attn_prefill(cfg, bp["attn"], h, positions, lc["self"])
        x = x + a
        x = _cross_attn(cfg, bp, x, lc["mem_k"], lc["mem_v"])
        x = _mlp(cfg, bp, x)
    x = apply_norm(cfg, params["dec_norm"], x)
    logits = lm_head_logits(cfg, params["embed"], None, x[:, -1])
    return logits, cache


def decode_step(cfg: ModelConfig, params: Dict, cache: Dict, token: jax.Array,
                pos: jax.Array) -> Tuple[jax.Array, Dict]:
    B = token.shape[0]
    x = embed_tokens(cfg, params["embed"], token[:, None])
    x = x + jnp.take(params["pos_dec"].astype(x.dtype), pos, axis=0)[:, None]
    new_layers = []
    for bp, lc in zip(params["dec_layers"], cache["layers"]):
        h = apply_norm(cfg, bp["ln1"], x)
        a, self_c = attn.attn_decode(cfg, bp["attn"], h, pos, lc["self"])
        x = x + a
        h = apply_norm(cfg, bp["lnx"], x)
        q = jnp.einsum("bsd,dhk->bshk", h, bp["xattn"]["wq"].astype(h.dtype))
        if cfg.qkv_bias:
            q = q + bp["xattn"]["bq"].astype(h.dtype)
        T = lc["mem_k"].shape[2]
        o = ops.decode_attention(q[:, 0], lc["mem_k"], lc["mem_v"],
                                 jnp.full((B,), T, jnp.int32), impl="ref")
        x = x + jnp.einsum("bhk,hkd->bd", o, bp["xattn"]["wo"].astype(h.dtype))[:, None]
        x = _mlp(cfg, bp, x)
        new_layers.append({"self": self_c, "mem_k": lc["mem_k"], "mem_v": lc["mem_v"]})
    x = apply_norm(cfg, params["dec_norm"], x)
    logits = lm_head_logits(cfg, params["embed"], None, x[:, 0])
    return logits, {"layers": new_layers}
