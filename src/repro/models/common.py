"""Shared layers: norms, RoPE / M-RoPE, embeddings, chunked LM loss."""

from __future__ import annotations

import math
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from .config import ModelConfig


# ---------------------------------------------------------------------------
# init helpers
# ---------------------------------------------------------------------------
def dense_init(key, in_dim: int, out_shape, dtype) -> jax.Array:
    """Truncated-normal fan-in init for a (in_dim, *out) weight."""
    scale = 1.0 / math.sqrt(in_dim)
    return (jax.random.truncated_normal(key, -2.0, 2.0, (in_dim, *out_shape))
            * scale).astype(dtype)


def embed_init(key, vocab: int, d: int, dtype) -> jax.Array:
    return (jax.random.normal(key, (vocab, d)) * 0.02).astype(dtype)


# ---------------------------------------------------------------------------
# norms
# ---------------------------------------------------------------------------
def norm_init(cfg: ModelConfig, d: Optional[int] = None) -> Dict:
    d = d if d is not None else cfg.d_model
    p = {"scale": jnp.zeros(d, cfg.param_jdtype()) if cfg.norm_unit_offset
         else jnp.ones(d, cfg.param_jdtype())}
    if cfg.norm == "layernorm":
        p["bias"] = jnp.zeros(d, cfg.param_jdtype())
    return p


def apply_norm(cfg: ModelConfig, p: Dict, x: jax.Array) -> jax.Array:
    xf = x.astype(jnp.float32)
    if cfg.norm == "layernorm":
        mu = xf.mean(-1, keepdims=True)
        var = ((xf - mu) ** 2).mean(-1, keepdims=True)
        y = (xf - mu) * jax.lax.rsqrt(var + cfg.norm_eps)
        y = y * p["scale"].astype(jnp.float32) + p["bias"].astype(jnp.float32)
    else:
        y = xf * jax.lax.rsqrt((xf ** 2).mean(-1, keepdims=True) + cfg.norm_eps)
        scale = p["scale"].astype(jnp.float32)
        if cfg.norm_unit_offset:
            scale = scale + 1.0
        y = y * scale
    return y.astype(x.dtype)


# ---------------------------------------------------------------------------
# RoPE (standard + multimodal M-RoPE)
# ---------------------------------------------------------------------------
def rope_freqs(dim: int, theta: float) -> jax.Array:
    return 1.0 / (theta ** (jnp.arange(0, dim, 2, dtype=jnp.float32) / dim))


def apply_rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """x: (B, S, H, D) with D even; positions: (B, S) int."""
    D = x.shape[-1]
    freqs = rope_freqs(D, theta)  # (D/2,)
    ang = positions[..., None].astype(jnp.float32) * freqs  # (B,S,D/2)
    cos = jnp.cos(ang)[:, :, None, :]
    sin = jnp.sin(ang)[:, :, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


def apply_mrope(x: jax.Array, positions: jax.Array, theta: float,
                sections: Tuple[int, int, int]) -> jax.Array:
    """Qwen2-VL multimodal RoPE.

    ``positions``: (3, B, S) — temporal / height / width position ids (all
    equal for text tokens).  The rotary dim is split into three sections
    (in half-dim units), each rotated by its own position stream.
    """
    D = x.shape[-1]
    half = D // 2
    assert sum(sections) == half, "mrope sections must sum to head_dim/2"
    freqs = rope_freqs(D, theta)  # (half,)
    # pick the position stream per frequency-section
    sec_id = jnp.repeat(jnp.arange(3), jnp.asarray(sections), total_repeat_length=half)
    pos3 = positions.astype(jnp.float32)  # (3,B,S)
    # gather: for each frequency index f, use positions[sec_id[f]]
    ang = pos3[sec_id.astype(jnp.int32), :, :]  # (half, B, S) -- advanced index on axis 0
    ang = jnp.moveaxis(ang, 0, -1) * freqs  # (B,S,half)
    cos = jnp.cos(ang)[:, :, None, :]
    sin = jnp.sin(ang)[:, :, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


def positions_for(cfg: ModelConfig, batch: Dict) -> jax.Array:
    """Standard (B,S) or M-RoPE (3,B,S) position ids from the batch."""
    tokens = batch["tokens"]
    B, S = tokens.shape
    if cfg.rope_type == "mrope":
        if "positions" in batch:
            return batch["positions"]
        p = jnp.broadcast_to(jnp.arange(S)[None], (B, S))
        return jnp.broadcast_to(p[None], (3, B, S))
    if "positions" in batch:
        return batch["positions"]
    return jnp.broadcast_to(jnp.arange(S)[None], (B, S))


# ---------------------------------------------------------------------------
# embeddings + chunked LM loss
# ---------------------------------------------------------------------------
def embedding_init(cfg: ModelConfig, key) -> Dict:
    p = {"tok": embed_init(key, cfg.padded_vocab, cfg.d_model, cfg.param_jdtype())}
    return p


def embed_tokens(cfg: ModelConfig, emb: Dict, tokens: jax.Array) -> jax.Array:
    x = emb["tok"].astype(cfg.compute_jdtype())[tokens]
    if cfg.scale_embed:
        x = x * jnp.asarray(math.sqrt(cfg.d_model), x.dtype)
    return x


def merge_visual(cfg: ModelConfig, x: jax.Array, batch: Dict) -> jax.Array:
    """Qwen2-VL stub: splice precomputed patch embeddings over the first
    ``n_img`` token slots (the modality frontend is out of scope)."""
    if not cfg.visual_stub or "visual_embeds" not in batch:
        return x
    ve = batch["visual_embeds"].astype(x.dtype)  # (B, n_img, D)
    n = ve.shape[1]
    return jnp.concatenate([ve, x[:, n:]], axis=1)


def lm_head_logits(cfg: ModelConfig, emb: Dict, out_w: Optional[jax.Array],
                   h: jax.Array) -> jax.Array:
    w = emb["tok"] if cfg.tie_embeddings or out_w is None else out_w
    logits = jnp.einsum("...d,vd->...v", h.astype(jnp.float32),
                        w.astype(jnp.float32))
    if cfg.logit_softcap > 0:
        c = cfg.logit_softcap
        logits = jnp.tanh(logits / c) * c
    # mask padded vocab rows
    if cfg.padded_vocab != cfg.vocab_size:
        neg = jnp.full((cfg.padded_vocab - cfg.vocab_size,), -1e30, jnp.float32)
        logits = logits.at[..., cfg.vocab_size:].set(neg)
    return logits


def chunked_softmax_xent(cfg: ModelConfig, emb: Dict, out_w: Optional[jax.Array],
                         h: jax.Array, labels: jax.Array,
                         mask: Optional[jax.Array] = None) -> jax.Array:
    """Mean next-token loss without materializing (B,S,V) logits.

    Scans over sequence chunks; each chunk computes (B,C,V) logits, its
    log-sum-exp and the label logit, then discards them.  With V up to
    256 k this is the difference between fitting and not fitting.
    """
    B, S, D = h.shape
    C = min(cfg.loss_chunk, S)
    if S % C:
        raise ValueError("seq len must divide loss_chunk")
    nchunks = S // C
    w = emb["tok"] if cfg.tie_embeddings or out_w is None else out_w
    wf = w.astype(jnp.float32)
    if mask is None:
        mask = jnp.ones((B, S), jnp.float32)
    hc = h.reshape(B, nchunks, C, D)
    lc = labels.reshape(B, nchunks, C)
    mc = mask.reshape(B, nchunks, C)

    def chunk_loss(carry, i):
        hh = hc[:, i].astype(jnp.float32)           # (B,C,D)
        logits = jnp.einsum("bcd,vd->bcv", hh, wf)  # (B,C,V)
        logits = constrain_dims(logits, {0: "dp", 2: "model"})
        if cfg.logit_softcap > 0:
            logits = jnp.tanh(logits / cfg.logit_softcap) * cfg.logit_softcap
        if cfg.padded_vocab != cfg.vocab_size:
            pad_mask = jnp.arange(cfg.padded_vocab) < cfg.vocab_size
            logits = jnp.where(pad_mask[None, None, :], logits, -1e30)
        lse = jax.nn.logsumexp(logits, axis=-1)     # (B,C)
        lab = jnp.take_along_axis(logits, lc[:, i][..., None], axis=-1)[..., 0]
        nll = (lse - lab) * mc[:, i]
        return carry + nll.sum(), None

    total, _ = jax.lax.scan(chunk_loss, jnp.zeros((), jnp.float32),
                            jnp.arange(nchunks))
    denom = jnp.maximum(mask.sum(), 1.0)
    return total / denom


_SHARDING_PROFILE = "tp"  # "tp" | "fsdp" — set by the launcher


def set_sharding_profile(profile: str) -> None:
    """"tp": model axis shards hidden activation dims (Megatron-style).
    "fsdp": model axis is an extra data/param-shard axis; activation
    constraints on "model" become no-ops and batch dims may shard over it.
    Chosen per (arch x shape); see EXPERIMENTS.md §Perf."""
    global _SHARDING_PROFILE
    assert profile in ("tp", "fsdp")
    _SHARDING_PROFILE = profile


def get_sharding_profile() -> str:
    return _SHARDING_PROFILE


def _dp_axes(mesh) -> tuple:
    names = ["pod", "data"]
    if _SHARDING_PROFILE == "fsdp":
        names.append("model")
    return tuple(a for a in names if a in mesh.axis_names)


def constrain_dims(x: jax.Array, assignments: Dict[int, str]) -> jax.Array:
    """Pin activation dims to mesh axes (no-op outside a mesh context).

    ``assignments`` maps dim -> role, role in {"dp", "model"}.  "dp" is all
    data axes (("pod","data") on the multi-pod mesh).  A dim whose size
    does not divide the axis is silently skipped, so the same model code
    works for MQA (kv=1), 28-head attention, 40-expert MoE, etc.

    Without these anchors GSPMD tends to resolve ambiguous einsum
    shardings by replicating the tensor-parallel dim — measured as a 16x
    per-device FLOP inflation in the dry-run (EXPERIMENTS.md §Perf it. 2).
    """
    get_mesh = getattr(jax.sharding, "get_abstract_mesh", None)
    if get_mesh is None:  # jax < 0.5: no abstract-mesh context, nothing to pin
        return x
    mesh = get_mesh()
    if mesh is None or getattr(mesh, "empty", True):
        return x
    from jax.sharding import PartitionSpec

    spec = [None] * x.ndim
    used = set()
    for dim, role in assignments.items():
        d = dim % x.ndim
        if role == "dp":
            ax = _dp_axes(mesh)
            # fallback chain: all data axes, then progressively fewer
            candidates = [ax[:k] for k in range(len(ax), 0, -1)]
        else:
            if _SHARDING_PROFILE == "fsdp":
                continue  # model axis belongs to the data pool under fsdp
            if role not in mesh.axis_names:
                continue
            candidates = [(role,)]
        for names in candidates:
            if not names or any(a in used for a in names):
                continue
            size = 1
            for a in names:
                size *= mesh.shape[a]
            if size > 1 and x.shape[d] % size == 0:
                spec[d] = names if len(names) > 1 else names[0]
                used.update(names)
                break
    if all(s is None for s in spec):
        return x
    return jax.lax.with_sharding_constraint(x, PartitionSpec(*spec))


def constrain_batch(x: jax.Array) -> jax.Array:
    """Pin the leading batch dim to the data axes (block-boundary anchor)."""
    return constrain_dims(x, {0: "dp"})


def constrain_hidden(x: jax.Array, model_dim: int = -1) -> jax.Array:
    """Batch on data axes + a hidden (ffn/heads/vocab) dim on "model"."""
    return constrain_dims(x, {0: "dp", model_dim: "model"})


def act_fn(name: str):
    if name in ("silu", "swiglu"):
        return jax.nn.silu
    if name in ("gelu", "geglu", "gelu_mlp"):
        return lambda x: jax.nn.gelu(x, approximate=True)
    raise ValueError(name)
