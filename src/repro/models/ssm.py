"""State-space blocks: Mamba2 (Zamba2's mixer) and RWKV6 (Finch).

Both blocks expose ``apply`` (full sequence, chunked scan through
:mod:`repro.kernels.ops`) and ``decode`` (O(1)-state single-token step),
plus ``init_state`` for serving.
"""

from __future__ import annotations

import math
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.kernels import ops
from .common import constrain_dims, dense_init
from .config import ModelConfig


# ---------------------------------------------------------------------------
# Mamba2
# ---------------------------------------------------------------------------
def mamba2_init(cfg: ModelConfig, key) -> Dict:
    mc = cfg.mamba
    D = cfg.d_model
    Din = mc.d_inner(D)
    H = mc.n_heads(D)
    G, N = mc.ngroups, mc.d_state
    dt_dim = H
    # in_proj -> [z (Din), x (Din), B (G*N), C (G*N), dt (H)]
    proj_out = 2 * Din + 2 * G * N + dt_dim
    ks = jax.random.split(key, 4)
    dt = cfg.param_jdtype()
    # S4D-real A init: -(1..H)
    A = -jnp.exp(jax.random.uniform(ks[2], (H,), jnp.float32,
                                    math.log(1.0), math.log(16.0)))
    return {
        "in_proj": dense_init(ks[0], D, (proj_out,), dt),
        "conv_w": (jax.random.normal(ks[3], (mc.d_conv, Din + 2 * G * N))
                   * 0.1).astype(dt),
        "A_log": jnp.log(-A),  # store log(-A) like the reference impls
        "D_skip": jnp.ones((H,), jnp.float32),
        "dt_bias": jnp.zeros((H,), jnp.float32),
        "norm_w": jnp.ones((Din,), dt),  # gated RMSNorm before out_proj
        "out_proj": dense_init(ks[1], Din, (D,), dt),
    }


def _mamba2_split(cfg: ModelConfig, proj: jax.Array):
    mc = cfg.mamba
    Din = mc.d_inner(cfg.d_model)
    H = mc.n_heads(cfg.d_model)
    G, N = mc.ngroups, mc.d_state
    z, xbc_dt = jnp.split(proj, [Din], axis=-1)
    xbc, dt_raw = jnp.split(xbc_dt, [Din + 2 * G * N], axis=-1)
    return z, xbc, dt_raw, (Din, H, G, N)


def _gated_rmsnorm(y: jax.Array, z: jax.Array, w: jax.Array, eps: float) -> jax.Array:
    y = y * jax.nn.silu(z.astype(jnp.float32)).astype(y.dtype)
    yf = y.astype(jnp.float32)
    y = (yf * jax.lax.rsqrt((yf ** 2).mean(-1, keepdims=True) + eps))
    return (y * w.astype(jnp.float32)).astype(z.dtype)


def mamba2_apply(cfg: ModelConfig, p: Dict, x: jax.Array) -> jax.Array:
    """x: (B,S,D) -> (B,S,D); full-sequence chunked SSD scan."""
    mc = cfg.mamba
    B, S, D = x.shape
    proj = jnp.einsum("bsd,do->bso", x, p["in_proj"].astype(x.dtype))
    z, xbc, dt_raw, (Din, H, G, N) = _mamba2_split(cfg, proj)
    # causal depthwise conv over (x, B, C)
    w = p["conv_w"].astype(x.dtype)  # (d_conv, Din+2GN)
    pad = jnp.pad(xbc, ((0, 0), (mc.d_conv - 1, 0), (0, 0)))
    conv = sum(w[i][None, None, :] * pad[:, i : i + S] for i in range(mc.d_conv))
    conv = jax.nn.silu(conv.astype(jnp.float32)).astype(x.dtype)
    xs, Bm, Cm = jnp.split(conv, [Din, Din + G * N], axis=-1)
    xs = xs.reshape(B, S, H, mc.headdim)
    xs = constrain_dims(xs, {0: "dp", 2: "model"})
    Bm = Bm.reshape(B, S, G, N)
    Cm = Cm.reshape(B, S, G, N)
    dtv = jax.nn.softplus(dt_raw.astype(jnp.float32) + p["dt_bias"][None, None, :])
    A = -jnp.exp(p["A_log"])
    y, _ = ops.mamba2(xs, dtv, A, Bm, Cm, impl=cfg.scan_impl)
    y = y + xs * p["D_skip"][None, None, :, None].astype(x.dtype)
    y = y.reshape(B, S, Din)
    y = _gated_rmsnorm(y, z, p["norm_w"], cfg.norm_eps)
    return jnp.einsum("bsf,fd->bsd", y, p["out_proj"].astype(x.dtype))


def mamba2_prefill_state(cfg: ModelConfig, p: Dict, h: jax.Array,
                         state: Dict) -> Dict:
    """Final SSM + conv state after a full-sequence pass (for decode)."""
    mc = cfg.mamba
    B, S, D = h.shape
    proj = jnp.einsum("bsd,do->bso", h, p["in_proj"].astype(h.dtype))
    z, xbc, dt_raw, (Din, H, G, N) = _mamba2_split(cfg, proj)
    w = p["conv_w"].astype(h.dtype)
    pad = jnp.pad(xbc, ((0, 0), (mc.d_conv - 1, 0), (0, 0)))
    conv = sum(w[i][None, None, :] * pad[:, i : i + S] for i in range(mc.d_conv))
    conv = jax.nn.silu(conv.astype(jnp.float32)).astype(h.dtype)
    xs, Bm, Cm = jnp.split(conv, [Din, Din + G * N], axis=-1)
    xs = xs.reshape(B, S, H, mc.headdim)
    Bm = Bm.reshape(B, S, G, N)
    Cm = Cm.reshape(B, S, G, N)
    dtv = jax.nn.softplus(dt_raw.astype(jnp.float32) + p["dt_bias"][None, None, :])
    A = -jnp.exp(p["A_log"])
    _, hfin = ops.mamba2(xs, dtv, A, Bm, Cm, impl=cfg.scan_impl)
    return {"ssm": hfin, "conv": xbc[:, S - (mc.d_conv - 1):]}


def mamba2_init_state(cfg: ModelConfig, batch: int, dtype) -> Dict:
    mc = cfg.mamba
    D = cfg.d_model
    Din = mc.d_inner(D)
    H, G, N = mc.n_heads(D), mc.ngroups, mc.d_state
    return {
        "ssm": jnp.zeros((batch, H, mc.headdim, N), jnp.float32),
        "conv": jnp.zeros((batch, mc.d_conv - 1, Din + 2 * G * N), dtype),
    }


def mamba2_decode(cfg: ModelConfig, p: Dict, x: jax.Array,
                  state: Dict) -> Tuple[jax.Array, Dict]:
    """x: (B,1,D) single token."""
    mc = cfg.mamba
    B = x.shape[0]
    proj = jnp.einsum("bsd,do->bso", x, p["in_proj"].astype(x.dtype))
    z, xbc, dt_raw, (Din, H, G, N) = _mamba2_split(cfg, proj)
    hist = jnp.concatenate([state["conv"], xbc], axis=1)  # (B, d_conv, C)
    w = p["conv_w"].astype(x.dtype)
    conv = jnp.einsum("btc,tc->bc", hist, w)[:, None]
    conv = jax.nn.silu(conv.astype(jnp.float32)).astype(x.dtype)
    xs, Bm, Cm = jnp.split(conv, [Din, Din + G * N], axis=-1)
    xs = xs.reshape(B, H, mc.headdim)
    Bm = Bm.reshape(B, G, N)
    Cm = Cm.reshape(B, G, N)
    dtv = jax.nn.softplus(dt_raw[:, 0].astype(jnp.float32) + p["dt_bias"][None, :])
    A = -jnp.exp(p["A_log"])
    y, ssm = ops.mamba2_decode(xs, dtv, A, Bm, Cm, state["ssm"])
    y = y + xs * p["D_skip"][None, :, None].astype(x.dtype)
    y = y.reshape(B, 1, Din)
    y = _gated_rmsnorm(y, z, p["norm_w"], cfg.norm_eps)
    out = jnp.einsum("bsf,fd->bsd", y, p["out_proj"].astype(x.dtype))
    return out, {"ssm": ssm, "conv": hist[:, 1:]}


# ---------------------------------------------------------------------------
# RWKV6
# ---------------------------------------------------------------------------
def rwkv6_init(cfg: ModelConfig, key) -> Dict:
    rc = cfg.rwkv
    D = cfg.d_model
    H = D // rc.head_dim
    dt = cfg.param_jdtype()
    ks = jax.random.split(key, 12)
    return {
        # token mix
        "mix_x": (jnp.ones((5, D)) * 0.5).astype(jnp.float32),
        "mix_w1": dense_init(ks[0], D, (5 * rc.mix_lora,), dt),
        "mix_w2": (jax.random.normal(ks[1], (5, rc.mix_lora, D)) * 0.02).astype(dt),
        "w0": jnp.full((D,), -3.0, jnp.float32),   # decay bias
        "w1": dense_init(ks[2], D, (rc.decay_lora,), dt),
        "w2": (jax.random.normal(ks[3], (rc.decay_lora, D)) * 0.02).astype(dt),
        "wr": dense_init(ks[4], D, (D,), dt),
        "wk": dense_init(ks[5], D, (D,), dt),
        "wv": dense_init(ks[6], D, (D,), dt),
        "wg": dense_init(ks[7], D, (D,), dt),
        "u": (jax.random.normal(ks[8], (H, rc.head_dim)) * 0.1).astype(jnp.float32),
        "ln_w": jnp.ones((D,), dt),  # per-head group norm
        "wo": dense_init(ks[9], D, (D,), dt),
        # channel mix
        "cm_mix": (jnp.ones((2, D)) * 0.5).astype(jnp.float32),
        "cm_k": dense_init(ks[10], D, (cfg.d_ff,), dt),
        "cm_v": dense_init(ks[11], cfg.d_ff, (D,), dt),
        "cm_r": dense_init(jax.random.fold_in(key, 99), D, (D,), dt),
    }


def _rwkv6_mix(p: Dict, x: jax.Array, x_prev: jax.Array):
    """Data-dependent token-shift mixing -> (xr, xk, xv, xw, xg)."""
    sx = x_prev - x
    base = x + sx * p["mix_x"][0][None, None, :].astype(x.dtype)
    lora = jnp.einsum("bsd,dk->bsk", base, p["mix_w1"].astype(x.dtype))
    lora = jnp.tanh(lora.astype(jnp.float32)).astype(x.dtype)
    lora = lora.reshape(*lora.shape[:-1], 5, -1)
    adj = jnp.einsum("bsnk,nkd->bsnd", lora, p["mix_w2"].astype(x.dtype))
    mixed = []
    for i in range(5):
        mi = p["mix_x"][i][None, None, :].astype(x.dtype) + adj[:, :, i]
        mixed.append(x + sx * mi)
    return mixed  # order: r, k, v, w, g


def _rwkv6_rkvwg(cfg: ModelConfig, p: Dict, x: jax.Array, x_prev: jax.Array):
    rc = cfg.rwkv
    D = cfg.d_model
    H = D // rc.head_dim
    xr, xk, xv, xw, xg = _rwkv6_mix(p, x, x_prev)
    r = constrain_dims(jnp.einsum("bsd,dk->bsk", xr, p["wr"].astype(x.dtype)), {0: "dp", 2: "model"})
    k = constrain_dims(jnp.einsum("bsd,dk->bsk", xk, p["wk"].astype(x.dtype)), {0: "dp", 2: "model"})
    v = constrain_dims(jnp.einsum("bsd,dk->bsk", xv, p["wv"].astype(x.dtype)), {0: "dp", 2: "model"})
    g = jnp.einsum("bsd,dk->bsk", xg, p["wg"].astype(x.dtype))
    dw = jnp.einsum("bsd,dk->bsk", xw, p["w1"].astype(x.dtype))
    dw = jnp.einsum("bsk,kd->bsd", jnp.tanh(dw.astype(jnp.float32)).astype(x.dtype),
                    p["w2"].astype(x.dtype))
    # per-channel log decay, always negative: w = -exp(w0 + dw)
    w = -jnp.exp(p["w0"][None, None, :] + dw.astype(jnp.float32))
    shp = x.shape[:2] + (H, rc.head_dim)
    return (r.reshape(shp), k.reshape(shp), v.reshape(shp),
            w.reshape(shp), g)


def _rwkv6_out(cfg: ModelConfig, p: Dict, y: jax.Array, g: jax.Array,
               dtype) -> jax.Array:
    B = y.shape[0]
    S = y.shape[1]
    D = cfg.d_model
    # per-head group norm
    yf = y.astype(jnp.float32)
    mu = yf.mean(-1, keepdims=True)
    var = ((yf - mu) ** 2).mean(-1, keepdims=True)
    yf = (yf - mu) * jax.lax.rsqrt(var + 64e-5)
    yn = yf.reshape(B, S, D) * p["ln_w"].astype(jnp.float32)[None, None, :]
    yn = yn.astype(dtype) * jax.nn.silu(g.astype(jnp.float32)).astype(dtype)
    return jnp.einsum("bsd,dk->bsk", yn, p["wo"].astype(dtype))


def rwkv6_time_mix(cfg: ModelConfig, p: Dict, x: jax.Array,
                   x_prev_last: Optional[jax.Array] = None,
                   s0: Optional[jax.Array] = None):
    """Full-sequence token mix.  Returns (out, (last_x, s_final))."""
    shift = jnp.pad(x, ((0, 0), (1, 0), (0, 0)))[:, :-1]
    if x_prev_last is not None:
        shift = shift.at[:, 0].set(x_prev_last)
    r, k, v, w, g = _rwkv6_rkvwg(cfg, p, x, shift)
    y, sfin = ops.rwkv6(r, k, v, w, p["u"], s0=s0, impl=cfg.scan_impl)
    out = _rwkv6_out(cfg, p, y, g, x.dtype)
    return out, (x[:, -1], sfin)


def rwkv6_channel_mix(cfg: ModelConfig, p: Dict, x: jax.Array,
                      x_prev_last: Optional[jax.Array] = None):
    shift = jnp.pad(x, ((0, 0), (1, 0), (0, 0)))[:, :-1]
    if x_prev_last is not None:
        shift = shift.at[:, 0].set(x_prev_last)
    sx = shift - x
    xk = x + sx * p["cm_mix"][0][None, None, :].astype(x.dtype)
    xr = x + sx * p["cm_mix"][1][None, None, :].astype(x.dtype)
    kk = jnp.einsum("bsd,df->bsf", xk, p["cm_k"].astype(x.dtype))
    kk = constrain_dims(kk, {0: "dp", 2: "model"})
    kk = jnp.square(jax.nn.relu(kk.astype(jnp.float32))).astype(x.dtype)
    vv = jnp.einsum("bsf,fd->bsd", kk, p["cm_v"].astype(x.dtype))
    rr = jax.nn.sigmoid(jnp.einsum("bsd,dk->bsk", xr,
                                   p["cm_r"].astype(x.dtype)).astype(jnp.float32))
    return rr.astype(x.dtype) * vv, x[:, -1]


def rwkv6_init_state(cfg: ModelConfig, batch: int, dtype) -> Dict:
    rc = cfg.rwkv
    D = cfg.d_model
    H = D // rc.head_dim
    return {
        "tm_x": jnp.zeros((batch, D), dtype),
        "wkv": jnp.zeros((batch, H, rc.head_dim, rc.head_dim), jnp.float32),
        "cm_x": jnp.zeros((batch, D), dtype),
    }


def rwkv6_decode(cfg: ModelConfig, p: Dict, x: jax.Array,
                 state: Dict) -> Tuple[jax.Array, Dict]:
    """One-token step for both mixes.  x: (B,1,D)."""
    prev = state["tm_x"][:, None]
    r, k, v, w, g = _rwkv6_rkvwg(cfg, p, x, prev)
    y, s = ops.rwkv6_decode(r[:, 0], k[:, 0], v[:, 0], w[:, 0], p["u"],
                            state["wkv"])
    out = _rwkv6_out(cfg, p, y[:, None], g, x.dtype)
    return out, {**state, "tm_x": x[:, 0], "wkv": s}


def rwkv6_channel_decode(cfg: ModelConfig, p: Dict, x: jax.Array,
                         state: Dict) -> Tuple[jax.Array, Dict]:
    out, last = rwkv6_channel_mix(cfg, p, x, state["cm_x"])
    return out, {**state, "cm_x": last}
