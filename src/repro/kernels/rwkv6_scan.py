"""Chunked RWKV6 (Finch) WKV kernel for TPU.

Per head, the recurrence over a (K x V) state S with per-channel
data-dependent log-decay w_t (<= 0) and a current-token bonus u:

    y_t = r_t^T (S_{t-1} + diag(u) k_t v_t^T)
    S_t = diag(exp(w_t)) S_{t-1} + k_t v_t^T

Chunked form, grid ``(B, H, num_chunks)`` (chunks innermost/sequential,
state carried in VMEM scratch):

* state-in term:  (r_t * exp(cw_excl_t)) @ S            — (L,K)x(K,V) MXU
* intra-chunk:    pair blocks (Ls x Ls): the decay between positions t>s is
  exp(cw_excl_t - cw_s), a *negative* exponent (difference of inclusive
  cumsums inside the chunk), computed directly per (t, s, k) sub-block —
  numerically safe for any w, unlike the r*exp(cw), k*exp(-cw)
  factorization which overflows for strongly-decaying channels.
* diagonal bonus: sum_k r*u*k per token.
* state-out:      S' = diag(exp(cw_L)) S + (k * exp(cw_L - cw))^T @ v — MXU.
"""

from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _rwkv6_kernel(r_ref, k_ref, v_ref, w_ref, u_ref, s0_ref,
                  y_ref, sfin_ref, state_scr, *,
                  chunk: int, sub: int, num_chunks: int):
    ci = pl.program_id(2)

    @pl.when(ci == 0)
    def _init():
        state_scr[...] = s0_ref[0, 0].astype(jnp.float32)

    r = r_ref[0, :, 0].astype(jnp.float32)  # (L, K)
    k = k_ref[0, :, 0].astype(jnp.float32)  # (L, K)
    v = v_ref[0, :, 0].astype(jnp.float32)  # (L, V)
    w = w_ref[0, :, 0].astype(jnp.float32)  # (L, K) log-decay <= 0
    u = u_ref[0].astype(jnp.float32)        # (K,)
    L = r.shape[0]

    cw = jnp.cumsum(w, axis=0)   # inclusive
    cwx = cw - w                 # exclusive
    total = cw[-1]               # (K,)
    s = state_scr[...]           # (K, V)

    # carried-in state contribution
    y = jax.lax.dot_general(r * jnp.exp(cwx), s, (((1,), (0,)), ((), ())),
                            preferred_element_type=jnp.float32)  # (L, V)

    # intra-chunk pairs, sub-block by sub-block (t > s strictly)
    nsub = L // sub
    for ti in range(nsub):
        rt = jax.lax.dynamic_slice_in_dim(r, ti * sub, sub, 0)
        ct = jax.lax.dynamic_slice_in_dim(cwx, ti * sub, sub, 0)
        acc = jnp.zeros((sub, v.shape[1]), jnp.float32)
        for si in range(ti + 1):
            ks = jax.lax.dynamic_slice_in_dim(k, si * sub, sub, 0)
            vs = jax.lax.dynamic_slice_in_dim(v, si * sub, sub, 0)
            cs = jax.lax.dynamic_slice_in_dim(cw, si * sub, sub, 0)
            # D[t,s,k] = exp(cwx_t - cw_s) (<= 0 exponent for t > s)
            D = jnp.exp(ct[:, None, :] - cs[None, :, :])  # (sub, sub, K)
            qk = jnp.sum(rt[:, None, :] * D * ks[None, :, :], axis=-1)  # (sub, sub)
            if si == ti:
                tril = (jax.lax.broadcasted_iota(jnp.int32, (sub, sub), 0)
                        > jax.lax.broadcasted_iota(jnp.int32, (sub, sub), 1))
                qk = jnp.where(tril, qk, 0.0)
            acc += jax.lax.dot_general(qk, vs, (((1,), (0,)), ((), ())),
                                       preferred_element_type=jnp.float32)
        y = jax.lax.dynamic_update_slice_in_dim(
            y, jax.lax.dynamic_slice_in_dim(y, ti * sub, sub, 0) + acc, ti * sub, 0)

    # current-token bonus
    diag = jnp.sum(r * u[None, :] * k, axis=-1)  # (L,)
    y = y + diag[:, None] * v
    y_ref[0, :, 0] = y.astype(y_ref.dtype)

    # state update (safe exponents: total - cw <= 0)
    kd = k * jnp.exp(total[None, :] - cw)
    state_scr[...] = s * jnp.exp(total)[:, None] + jax.lax.dot_general(
        kd, v, (((0,), (0,)), ((), ())), preferred_element_type=jnp.float32)

    @pl.when(ci == num_chunks - 1)
    def _finalize():
        sfin_ref[0, 0] = state_scr[...]


def rwkv6_scan(
    r: jax.Array,  # (B, S, H, K)
    k: jax.Array,  # (B, S, H, K)
    v: jax.Array,  # (B, S, H, V)
    w: jax.Array,  # (B, S, H, K) log-decay (<= 0)
    u: jax.Array,  # (H, K)
    s0: Optional[jax.Array] = None,  # (B, H, K, V)
    chunk: int = 64,
    sub: int = 32,
    interpret: bool = False,
) -> Tuple[jax.Array, jax.Array]:
    B, S, H, K = r.shape
    V = v.shape[-1]
    chunk = min(chunk, S)
    if S % chunk:
        raise ValueError("S must divide chunk")
    sub = min(sub, chunk)
    if chunk % sub:
        raise ValueError("chunk must divide sub")
    nc = S // chunk
    if s0 is None:
        s0 = jnp.zeros((B, H, K, V), jnp.float32)

    kernel = functools.partial(_rwkv6_kernel, chunk=chunk, sub=sub, num_chunks=nc)
    y, sfin = pl.pallas_call(
        kernel,
        grid=(B, H, nc),
        in_specs=[
            pl.BlockSpec((1, chunk, 1, K), lambda b, h, ci: (b, ci, h, 0)),
            pl.BlockSpec((1, chunk, 1, K), lambda b, h, ci: (b, ci, h, 0)),
            pl.BlockSpec((1, chunk, 1, V), lambda b, h, ci: (b, ci, h, 0)),
            pl.BlockSpec((1, chunk, 1, K), lambda b, h, ci: (b, ci, h, 0)),
            pl.BlockSpec((1, K), lambda b, h, ci: (h, 0)),
            pl.BlockSpec((1, 1, K, V), lambda b, h, ci: (b, h, 0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, chunk, 1, V), lambda b, h, ci: (b, ci, h, 0)),
            pl.BlockSpec((1, 1, K, V), lambda b, h, ci: (b, h, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B, S, H, V), v.dtype),
            jax.ShapeDtypeStruct((B, H, K, V), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((K, V), jnp.float32)],
        interpret=interpret,
    )(r, k, v, w, u, s0)
    return y, sfin
