"""Flash-decode kernel: one query token against a long KV cache.

serve_step's hot spot at decode_32k / long_500k shapes.  Grid
``(B, H, num_kv_blocks)``: KV blocks stream through VMEM innermost with a
running (m, l, acc) in scratch; invalid cache positions (>= length[b]) are
masked with an iota comparison against a scalar-prefetched length.

The query head -> KV head mapping is again done in the index maps
(GQA/MQA without materialized repeats).  For a 1-token query the matmul is
a (1, D) x (D, block_k) contraction — small for the MXU, which is exactly
why decode is memory-bound: the kernel's job is to stream K/V through VMEM
at full HBM bandwidth, not to saturate the MXU.
"""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _decode_kernel(len_ref, q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr,
                   *, scale: float, block_k: int, num_k_blocks: int):
    b = pl.program_id(0)
    ki = pl.program_id(2)

    @pl.when(ki == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    length = len_ref[b]

    @pl.when(ki * block_k < length)
    def _compute():
        q = q_ref[0, 0].astype(jnp.float32)      # (1, D)
        k = k_ref[0, 0].astype(jnp.float32)      # (bk, D)
        v = v_ref[0, 0]                          # (bk, D)
        s = jax.lax.dot_general(
            q * scale, k, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32)  # (1, bk)
        kpos = jax.lax.broadcasted_iota(jnp.int32, (1, block_k), 1) + ki * block_k
        s = jnp.where(kpos < length, s, NEG_INF)
        m_prev = m_scr[...]
        m_new = jnp.maximum(m_prev, s.max(axis=-1))
        p = jnp.exp(s - m_new[:, None])
        corr = jnp.exp(m_prev - m_new)
        l_scr[...] = l_scr[...] * corr + p.sum(axis=-1)
        m_scr[...] = m_new
        acc_scr[...] = acc_scr[...] * corr[:, None] + jax.lax.dot_general(
            p.astype(v.dtype), v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)

    @pl.when(ki == num_k_blocks - 1)
    def _finalize():
        l = jnp.maximum(l_scr[...], 1e-30)
        o_ref[0, 0] = (acc_scr[...] / l[:, None]).astype(o_ref.dtype)


def flash_decode(
    q: jax.Array,       # (B, H, D)
    k: jax.Array,       # (B, KV, T, D)
    v: jax.Array,       # (B, KV, T, D)
    length: jax.Array,  # (B,) int32 valid lengths
    scale: Optional[float] = None,
    block_k: int = 512,
    interpret: bool = False,
) -> jax.Array:
    B, H, D = q.shape
    KV, T = k.shape[1], k.shape[2]
    if H % KV:
        raise ValueError("query heads must be a multiple of kv heads")
    group = H // KV
    scale_ = D ** -0.5 if scale is None else scale
    block_k = min(block_k, T)
    if T % block_k:
        raise ValueError("cache length must divide block_k")
    nk = T // block_k

    kernel = functools.partial(_decode_kernel, scale=scale_, block_k=block_k,
                               num_k_blocks=nk)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,  # length lands in SMEM before the grid runs
        grid=(B, H, nk),
        in_specs=[
            pl.BlockSpec((1, 1, 1, D), lambda b, h, ki, lens: (b, h, 0, 0)),
            pl.BlockSpec((1, 1, block_k, D), lambda b, h, ki, lens: (b, h // group, ki, 0)),
            pl.BlockSpec((1, 1, block_k, D), lambda b, h, ki, lens: (b, h // group, ki, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, 1, D), lambda b, h, ki, lens: (b, h, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((1,), jnp.float32),
            pltpu.VMEM((1,), jnp.float32),
            pltpu.VMEM((1, D), jnp.float32),
        ],
    )
    out = pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((B, H, 1, D), q.dtype),
        interpret=interpret,
    )(length.astype(jnp.int32), q[:, :, None, :], k, v)
    return out[:, :, 0, :]
