"""Pallas TPU kernels for the compute hot spots of the model plane.

Foreactor itself is a host-I/O technique (no device-kernel contribution in
the paper); these kernels exist because the *framework* needs perf-critical
device compute:

* :mod:`repro.kernels.flash_attention` — blockwise online-softmax attention
  (GQA/MQA-aware, causal block skipping), targets the MXU with 128-aligned
  q/k blocks held in VMEM.
* :mod:`repro.kernels.decode_attention` — flash-decode for single-token
  queries against long KV caches (streamed KV blocks, running max/sum).
* :mod:`repro.kernels.mamba2_scan` — chunked SSD scan: dense intra-chunk
  matmuls on the MXU + carried inter-chunk state.
* :mod:`repro.kernels.rwkv6_scan` — RWKV6 (Finch) data-dependent-decay
  recurrence, chunked the same way.

Each kernel ships ``<name>.py`` (pl.pallas_call + BlockSpec), a jitted
wrapper in :mod:`repro.kernels.ops`, and a pure-jnp oracle in
:mod:`repro.kernels.ref`.  On this CPU container kernels are validated with
``interpret=True``; model code defaults to the memory-efficient jnp
reference implementations (which is also what the dry-run lowers, keeping
cost/memory analysis faithful on the CPU backend).
"""
