"""Jitted front-door for every kernel, with implementation selection.

``impl``:
* ``"naive"``      — simplest oracle (tests, tiny shapes)
* ``"ref"``        — memory-efficient pure-XLA twin (blockwise / chunked);
                     differentiable; the default on CPU and in the dry-run
* ``"pallas"``     — the TPU kernel (compiled via Mosaic)
* ``"interpret"``  — the TPU kernel executed in interpret mode (CPU CI)
* ``"auto"``       — pallas on TPU, ref elsewhere

Pallas forwards are wrapped in ``jax.custom_vjp`` with the reference
implementation's VJP as the backward (recompute-style), so training code
can use kernels without a hand-written backward kernel.
"""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from . import ref
from .decode_attention import flash_decode
from .flash_attention import flash_attention_fwd
from .mamba2_scan import mamba2_scan
from .rwkv6_scan import rwkv6_scan


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def _resolve(impl: str) -> str:
    if impl == "auto":
        return "pallas" if _on_tpu() else "ref"
    return impl


# ---------------------------------------------------------------------------
# attention
# ---------------------------------------------------------------------------
@functools.partial(jax.custom_vjp, nondiff_argnums=(3, 4, 5))
def _attention_pallas(q, k, v, causal, scale, interpret):
    return flash_attention_fwd(q, k, v, causal=causal, scale=scale,
                               interpret=interpret)


def _attention_pallas_fwd(q, k, v, causal, scale, interpret):
    return _attention_pallas(q, k, v, causal, scale, interpret), (q, k, v)


def _attention_pallas_bwd(causal, scale, interpret, res, g):
    q, k, v = res
    _, vjp = jax.vjp(lambda q, k, v: ref.attention_blockwise(q, k, v, causal, scale),
                     q, k, v)
    return vjp(g)


_attention_pallas.defvjp(_attention_pallas_fwd, _attention_pallas_bwd)


def attention(q: jax.Array, k: jax.Array, v: jax.Array, causal: bool = True,
              scale: Optional[float] = None, impl: str = "auto",
              block_q: int = 512, block_k: int = 1024) -> jax.Array:
    """(B,H,S,D) x (B,KV,T,D)^2 -> (B,H,S,D); GQA via head groups."""
    impl = _resolve(impl)
    if impl == "naive":
        return ref.attention_naive(q, k, v, causal, scale)
    if impl == "ref":
        bq = min(block_q, q.shape[2])
        bk = min(block_k, k.shape[2])
        return ref.attention_blockwise(q, k, v, causal, scale,
                                       block_q=bq, block_k=bk)
    if impl == "pallas":
        return _attention_pallas(q, k, v, causal, scale, False)
    if impl == "interpret":
        return _attention_pallas(q, k, v, causal, scale, True)
    raise ValueError(f"unknown impl {impl!r}")


def decode_attention(q: jax.Array, k: jax.Array, v: jax.Array,
                     length: jax.Array, scale: Optional[float] = None,
                     impl: str = "auto", block_k: int = 512) -> jax.Array:
    """(B,H,D) query vs (B,KV,T,D) cache with per-batch valid lengths."""
    impl = _resolve(impl)
    if impl in ("naive", "ref"):
        return ref.decode_attention_naive(q, k, v, length, scale)
    if impl == "pallas":
        return flash_decode(q, k, v, length, scale, block_k=block_k)
    if impl == "interpret":
        return flash_decode(q, k, v, length, scale, block_k=block_k,
                            interpret=True)
    raise ValueError(f"unknown impl {impl!r}")


# ---------------------------------------------------------------------------
# Mamba2 / SSD
# ---------------------------------------------------------------------------
@functools.partial(jax.custom_vjp, nondiff_argnums=(6, 7))
def _mamba2_pallas(x, dt, A, B, C, h0, chunk, interpret):
    return mamba2_scan(x, dt, A, B, C, h0, chunk=chunk, interpret=interpret)


def _mamba2_pallas_fwd(x, dt, A, B, C, h0, chunk, interpret):
    return _mamba2_pallas(x, dt, A, B, C, h0, chunk, interpret), (x, dt, A, B, C, h0)


def _mamba2_pallas_bwd(chunk, interpret, res, g):
    x, dt, A, B, C, h0 = res
    _, vjp = jax.vjp(
        lambda x, dt, A, B, C, h0: ref.mamba2_scan_chunked(x, dt, A, B, C, h0, chunk=chunk),
        x, dt, A, B, C, h0)
    return vjp(g)


_mamba2_pallas.defvjp(_mamba2_pallas_fwd, _mamba2_pallas_bwd)


def mamba2(x, dt, A, B, C, h0=None, impl: str = "auto", chunk: int = 128):
    """Chunked SSD scan -> (y, h_final)."""
    impl = _resolve(impl)
    if impl == "naive":
        return ref.mamba2_scan_naive(x, dt, A, B, C, h0)
    if impl == "ref":
        return ref.mamba2_scan_chunked(x, dt, A, B, C, h0, chunk=min(chunk, x.shape[1]))
    if h0 is None:
        Bsz, _, H, P = x.shape
        N = B.shape[-1]
        h0 = jnp.zeros((Bsz, H, P, N), jnp.float32)
    if impl == "pallas":
        return _mamba2_pallas(x, dt, A, B, C, h0, min(chunk, x.shape[1]), False)
    if impl == "interpret":
        return _mamba2_pallas(x, dt, A, B, C, h0, min(chunk, x.shape[1]), True)
    raise ValueError(f"unknown impl {impl!r}")


def mamba2_decode(x, dt, A, B, C, h):
    """Single-token SSD step (serving)."""
    return ref.mamba2_decode_step(x, dt, A, B, C, h)


# ---------------------------------------------------------------------------
# RWKV6
# ---------------------------------------------------------------------------
@functools.partial(jax.custom_vjp, nondiff_argnums=(6, 7))
def _rwkv6_pallas(r, k, v, w, u, s0, chunk, interpret):
    return rwkv6_scan(r, k, v, w, u, s0, chunk=chunk, interpret=interpret)


def _rwkv6_pallas_fwd(r, k, v, w, u, s0, chunk, interpret):
    return _rwkv6_pallas(r, k, v, w, u, s0, chunk, interpret), (r, k, v, w, u, s0)


def _rwkv6_pallas_bwd(chunk, interpret, res, g):
    r, k, v, w, u, s0 = res
    _, vjp = jax.vjp(
        lambda r, k, v, w, u, s0: ref.rwkv6_scan_chunked(r, k, v, w, u, s0, chunk=chunk),
        r, k, v, w, u, s0)
    return vjp(g)


_rwkv6_pallas.defvjp(_rwkv6_pallas_fwd, _rwkv6_pallas_bwd)


def rwkv6(r, k, v, w, u, s0=None, impl: str = "auto", chunk: int = 64):
    """Chunked WKV6 scan -> (y, s_final)."""
    impl = _resolve(impl)
    if impl == "naive":
        return ref.rwkv6_scan_naive(r, k, v, w, u, s0)
    if impl == "ref":
        return ref.rwkv6_scan_chunked(r, k, v, w, u, s0, chunk=min(chunk, r.shape[1]))
    if s0 is None:
        B, _, H, K = r.shape
        V = v.shape[-1]
        s0 = jnp.zeros((B, H, K, V), jnp.float32)
    if impl == "pallas":
        return _rwkv6_pallas(r, k, v, w, u, s0, min(chunk, r.shape[1]), False)
    if impl == "interpret":
        return _rwkv6_pallas(r, k, v, w, u, s0, min(chunk, r.shape[1]), True)
    raise ValueError(f"unknown impl {impl!r}")


def rwkv6_decode(r, k, v, w, u, s):
    """Single-token WKV6 step (serving)."""
    return ref.rwkv6_decode_step(r, k, v, w, u, s)
