"""Pure-jnp oracles for every Pallas kernel.

Two flavors where it matters:

* ``*_naive`` — the simplest possible semantics (materializes S x S scores,
  steps the recurrence token by token).  These define correctness.
* ``attention_blockwise`` / chunked scans — memory-efficient pure-XLA
  implementations used by the model plane on CPU and in the dry-run
  (numerically equal to the naive versions up to float assoc.).
"""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

NEG_INF = -1e30


# ---------------------------------------------------------------------------
# attention
# ---------------------------------------------------------------------------
def attention_naive(
    q: jax.Array,  # (B, H, S, D)
    k: jax.Array,  # (B, KV, T, D)
    v: jax.Array,  # (B, KV, T, D)
    causal: bool = True,
    scale: Optional[float] = None,
) -> jax.Array:
    """Full softmax attention with GQA head-group broadcast."""
    B, H, S, D = q.shape
    KV, T = k.shape[1], k.shape[2]
    assert H % KV == 0
    scale = D ** -0.5 if scale is None else scale
    kr = jnp.repeat(k, H // KV, axis=1)
    vr = jnp.repeat(v, H // KV, axis=1)
    logits = jnp.einsum("bhsd,bhtd->bhst", q * scale, kr).astype(jnp.float32)
    if causal:
        # allow query i (at absolute position offset + i) to see keys <= it;
        # when S != T the queries are the *last* S positions of T.
        offs = T - S
        qpos = jnp.arange(S)[:, None] + offs
        kpos = jnp.arange(T)[None, :]
        logits = jnp.where(kpos <= qpos, logits, NEG_INF)
    p = jax.nn.softmax(logits, axis=-1).astype(v.dtype)
    return jnp.einsum("bhst,bhtd->bhsd", p, vr)


def attention_blockwise(
    q: jax.Array,  # (B, H, S, D)
    k: jax.Array,  # (B, KV, T, D)
    v: jax.Array,  # (B, KV, T, D)
    causal: bool = True,
    scale: Optional[float] = None,
    block_q: int = 512,
    block_k: int = 1024,
) -> jax.Array:
    """Online-softmax attention in pure jnp (never materializes S x T).

    This is the 'flash-in-XLA' path the model plane uses for long
    sequences on the CPU backend and in the dry-run; the Pallas kernel in
    :mod:`repro.kernels.flash_attention` is the TPU fast path with the
    same math.
    """
    B, H, S, D = q.shape
    KV, T = k.shape[1], k.shape[2]
    G = H // KV
    scale_ = D ** -0.5 if scale is None else scale

    def _pick(n, target):  # largest divisor of n that is <= target
        d = min(target, n)
        while n % d:
            d -= 1
        return d

    block_q = _pick(S, block_q)
    block_k = _pick(T, block_k)
    nq = S // block_q
    nk = T // block_k
    offs = T - S

    # (B, KV, nk, bk, D) views
    kb = k.reshape(B, KV, nk, block_k, D)
    vb = v.reshape(B, KV, nk, block_k, D)

    def q_block(qi, qchunk):  # qchunk: (B, H, bq, D)
        def kv_step(carry, ki):
            acc, m, l = carry
            kk = jax.lax.dynamic_index_in_dim(kb, ki, axis=2, keepdims=False)
            vv = jax.lax.dynamic_index_in_dim(vb, ki, axis=2, keepdims=False)
            kk = jnp.repeat(kk, G, axis=1)  # (B, H, bk, D)
            vv = jnp.repeat(vv, G, axis=1)
            s = jnp.einsum("bhqd,bhkd->bhqk", qchunk * scale_, kk).astype(jnp.float32)
            if causal:
                qpos = qi * block_q + jnp.arange(block_q)[:, None] + offs
                kpos = ki * block_k + jnp.arange(block_k)[None, :]
                s = jnp.where(kpos <= qpos, s, NEG_INF)
            m_new = jnp.maximum(m, s.max(-1))
            p = jnp.exp(s - m_new[..., None])
            corr = jnp.exp(m - m_new)
            l_new = l * corr + p.sum(-1)
            acc = acc * corr[..., None] + jnp.einsum(
                "bhqk,bhkd->bhqd", p.astype(vv.dtype), vv
            ).astype(jnp.float32)
            return (acc, m_new, l_new), None

        acc0 = jnp.zeros((B, H, block_q, D), jnp.float32)
        m0 = jnp.full((B, H, block_q), NEG_INF, jnp.float32)
        l0 = jnp.zeros((B, H, block_q), jnp.float32)
        if causal:
            # skip fully-masked kv blocks for this q block
            hi = ((qi + 1) * block_q + offs + block_k - 1) // block_k
            hi = jnp.minimum(hi, nk)
        else:
            hi = nk
        (acc, m, l), _ = jax.lax.scan(
            lambda c, ki: jax.lax.cond(ki < hi, lambda: kv_step(c, ki),
                                       lambda: (c, None)),
            (acc0, m0, l0), jnp.arange(nk))
        return (acc / jnp.maximum(l, 1e-30)[..., None]).astype(q.dtype)

    qb = q.reshape(B, H, nq, block_q, D)
    outs = [q_block(qi, qb[:, :, qi]) for qi in range(nq)]
    return jnp.concatenate(outs, axis=2) if len(outs) > 1 else outs[0]


def decode_attention_naive(
    q: jax.Array,  # (B, H, D) single-token query
    k: jax.Array,  # (B, KV, T, D) cache
    v: jax.Array,  # (B, KV, T, D)
    length: jax.Array,  # (B,) valid cache lengths
    scale: Optional[float] = None,
) -> jax.Array:
    B, H, D = q.shape
    KV, T = k.shape[1], k.shape[2]
    scale_ = D ** -0.5 if scale is None else scale
    kr = jnp.repeat(k, H // KV, axis=1)
    vr = jnp.repeat(v, H // KV, axis=1)
    logits = jnp.einsum("bhd,bhtd->bht", q * scale_, kr).astype(jnp.float32)
    mask = jnp.arange(T)[None, None, :] < length[:, None, None]
    logits = jnp.where(mask, logits, NEG_INF)
    p = jax.nn.softmax(logits, axis=-1).astype(v.dtype)
    return jnp.einsum("bht,bhtd->bhd", p, vr)


# ---------------------------------------------------------------------------
# Mamba2 (SSD) scan
# ---------------------------------------------------------------------------
def mamba2_scan_naive(
    x: jax.Array,   # (B, S, H, P)  inputs per head
    dt: jax.Array,  # (B, S, H)     softplus'd step sizes (>0)
    A: jax.Array,   # (H,)          negative decay rates (A < 0)
    Bm: jax.Array,  # (B, S, G, N)  input projections (G groups)
    Cm: jax.Array,  # (B, S, G, N)  output projections
    h0: Optional[jax.Array] = None,  # (B, H, P, N) initial state
):
    """Token-by-token SSD recurrence:
        h_t = exp(dt_t A) h_{t-1} + dt_t * x_t B_t^T ;  y_t = h_t C_t
    Returns (y (B,S,H,P), h_final (B,H,P,N))."""
    B, S, H, P = x.shape
    G, N = Bm.shape[2], Bm.shape[3]
    assert H % G == 0
    rep = H // G
    Bh = jnp.repeat(Bm, rep, axis=2)  # (B,S,H,N)
    Ch = jnp.repeat(Cm, rep, axis=2)
    h = jnp.zeros((B, H, P, N), jnp.float32) if h0 is None else h0.astype(jnp.float32)

    def step(h, t):
        decay = jnp.exp(dt[:, t] * A[None, :])  # (B,H)
        upd = (dt[:, t] * 1.0)[..., None, None] * (
            x[:, t][..., :, None] * Bh[:, t][..., None, :]
        )  # (B,H,P,N)
        h = h * decay[..., None, None] + upd.astype(jnp.float32)
        y = jnp.einsum("bhpn,bhn->bhp", h, Ch[:, t].astype(jnp.float32))
        return h, y

    h, ys = jax.lax.scan(step, h, jnp.arange(S))
    y = jnp.moveaxis(ys, 0, 1).astype(x.dtype)  # (B,S,H,P)
    return y, h


def mamba2_scan_chunked(
    x: jax.Array, dt: jax.Array, A: jax.Array, Bm: jax.Array, Cm: jax.Array,
    h0: Optional[jax.Array] = None, chunk: int = 128,
):
    """Chunked SSD: dense intra-chunk matmuls + inter-chunk state carry.
    Mathematically identical to the naive recurrence (fp32 accumulation).
    This is the pure-XLA twin of the Pallas kernel."""
    B, S, H, P = x.shape
    G, N = Bm.shape[2], Bm.shape[3]
    rep = H // G
    if S % chunk:
        raise ValueError("S must divide chunk")
    nc = S // chunk
    Bh = jnp.repeat(Bm, rep, axis=2).reshape(B, nc, chunk, H, N)
    Ch = jnp.repeat(Cm, rep, axis=2).reshape(B, nc, chunk, H, N)
    xc = x.reshape(B, nc, chunk, H, P)
    dtc = dt.reshape(B, nc, chunk, H)

    # per-chunk cumulative log-decay: a_t = dt_t * A  (<= 0)
    ac = dtc * A[None, None, None, :]  # (B,nc,L,H)
    cum = jnp.cumsum(ac, axis=2)  # inclusive cumsum over L

    def chunk_step(h, i):
        a = ac[:, i]          # (B,L,H)
        cs = cum[:, i]        # (B,L,H) inclusive
        xb = xc[:, i]         # (B,L,H,P)
        bb = Bh[:, i]         # (B,L,H,N)
        cb = Ch[:, i]         # (B,L,H,N)
        dtb = dtc[:, i]       # (B,L,H)
        total = cs[:, -1]     # (B,H) full-chunk log decay
        # intra-chunk: y_intra[t] = sum_{s<=t} exp(cs_t - cs_s) dt_s (C_t.B_s) x_s
        # NB: mask the exponent (not the exp) so gradients of masked entries
        # are exactly zero instead of inf * 0 = NaN.
        lmask = jnp.tril(jnp.ones((chunk, chunk), bool))[None, :, :, None]  # (1,t,s,1)
        expo = jnp.where(lmask, cs[:, :, None, :] - cs[:, None, :, :], -1e30)
        L = jnp.exp(expo)
        cb_dot_bb = jnp.einsum("blhn,bmhn->blmh", cb, bb)  # (B,t,s,H)
        w = L * cb_dot_bb * dtb[:, None, :, :]  # (B,t,s,H)
        y_intra = jnp.einsum("blmh,bmhp->blhp", w, xb)
        # contribution of carried-in state: y_state[t] = C_t . (exp(cs_t) h)
        decay_t = jnp.exp(cs)  # (B,L,H)
        y_state = jnp.einsum("blhn,bhpn->blhp", cb, h) * decay_t[..., None]
        # new state: h' = exp(total) h + sum_s exp(total - cs_s) dt_s B_s x_s^T
        wst = jnp.exp(total[:, None, :] - cs) * dtb  # (B,L,H)
        h_new = h * jnp.exp(total)[..., None, None] + jnp.einsum(
            "blh,blhp,blhn->bhpn", wst, xb.astype(jnp.float32), bb.astype(jnp.float32))
        return h_new, (y_intra + y_state).astype(x.dtype)

    h = jnp.zeros((B, H, P, N), jnp.float32) if h0 is None else h0.astype(jnp.float32)
    h, ys = jax.lax.scan(chunk_step, h, jnp.arange(nc))
    y = jnp.moveaxis(ys, 0, 1).reshape(B, S, H, P)
    return y, h


# ---------------------------------------------------------------------------
# RWKV6 (Finch) scan
# ---------------------------------------------------------------------------
def rwkv6_scan_naive(
    r: jax.Array,  # (B, S, H, K)
    k: jax.Array,  # (B, S, H, K)
    v: jax.Array,  # (B, S, H, V)
    w: jax.Array,  # (B, S, H, K)  per-step log-decay (<0): state *= exp(w)
    u: jax.Array,  # (H, K)        bonus for the current token
    s0: Optional[jax.Array] = None,  # (B, H, K, V)
):
    """Token-by-token WKV6:
        y_t = r_t^T (S_{t-1} + diag(u) k_t v_t^T)
        S_t = diag(exp(w_t)) S_{t-1} + k_t v_t^T
    Returns (y (B,S,H,V), S_final)."""
    B, S, H, K = r.shape
    V = v.shape[-1]
    s = jnp.zeros((B, H, K, V), jnp.float32) if s0 is None else s0.astype(jnp.float32)

    def step(s, t):
        kv = k[:, t][..., :, None] * v[:, t][..., None, :]  # (B,H,K,V)
        y = jnp.einsum("bhk,bhkv->bhv",
                       r[:, t].astype(jnp.float32),
                       s + u[None, :, :, None] * kv.astype(jnp.float32))
        s = jnp.exp(w[:, t].astype(jnp.float32))[..., None] * s + kv.astype(jnp.float32)
        return s, y

    s, ys = jax.lax.scan(step, s, jnp.arange(S))
    return jnp.moveaxis(ys, 0, 1).astype(v.dtype), s


def rwkv6_scan_chunked(
    r: jax.Array, k: jax.Array, v: jax.Array, w: jax.Array, u: jax.Array,
    s0: Optional[jax.Array] = None, chunk: int = 64,
):
    """Chunked WKV6 with per-channel data-dependent decay.

    Within a chunk, define inclusive log-decay prefix W_t = sum_{s<=t} w_s.
    y_t = r_t [ exp(W_{t-1} ... ) ... ]  — implemented with dense (t,s)
    matrices per chunk; inter-chunk state carried exactly.
    """
    B, S, H, K = r.shape
    V = v.shape[-1]
    if S % chunk:
        raise ValueError("S must divide chunk")
    nc = S // chunk
    rc = r.reshape(B, nc, chunk, H, K)
    kc = k.reshape(B, nc, chunk, H, K)
    vc = v.reshape(B, nc, chunk, H, V)
    wc = w.reshape(B, nc, chunk, H, K).astype(jnp.float32)

    def chunk_step(s, i):
        rb, kb, vb, wb = rc[:, i], kc[:, i], vc[:, i], wc[:, i]
        cw = jnp.cumsum(wb, axis=1)  # inclusive (B,L,H,K)
        # state contribution: y_state[t] = (r_t * exp(cw_{t-1})) @ S
        # exclusive prefix: cw_excl[t] = cw[t] - w[t]
        cw_excl = cw - wb
        rs = rb.astype(jnp.float32) * jnp.exp(cw_excl)
        y_state = jnp.einsum("blhk,bhkv->blhv", rs, s)
        # intra-chunk: pairs s < t contribute exp(cw_excl_t - cw_s) r_t.k_s
        # diag (s == t) contributes via bonus u instead of decay.
        # Mask the exponent (not the product) so masked entries carry zero
        # gradient instead of inf * 0 = NaN.
        mask = jnp.tril(jnp.ones((chunk, chunk), bool), k=-1)[None, :, :, None, None]
        expo = jnp.where(mask, cw_excl[:, :, None] - cw[:, None, :], -1e30)
        qk = jnp.einsum("blhk,bmhk,blmhk->blmh",
                        rb.astype(jnp.float32),
                        kb.astype(jnp.float32),
                        jnp.exp(expo))
        y_intra = jnp.einsum("blmh,bmhv->blhv", qk, vb.astype(jnp.float32))
        diag = jnp.einsum("blhk,hk,blhk->blh", rb.astype(jnp.float32),
                          u, kb.astype(jnp.float32))
        y_diag = diag[..., None] * vb.astype(jnp.float32)
        # new state: S' = diag(exp(cw_L)) S + sum_s exp(cw_L - cw_s) k_s v_s^T
        total = cw[:, -1]  # (B,H,K)
        dec = jnp.exp(total[:, None] - cw)  # (B,L,H,K)
        s_new = jnp.exp(total)[..., None] * s + jnp.einsum(
            "blhk,blhv->bhkv", kb.astype(jnp.float32) * dec, vb.astype(jnp.float32))
        return s_new, (y_state + y_intra + y_diag).astype(v.dtype)

    s = jnp.zeros((B, H, K, V), jnp.float32) if s0 is None else s0.astype(jnp.float32)
    s, ys = jax.lax.scan(chunk_step, s, jnp.arange(nc))
    return jnp.moveaxis(ys, 0, 1).reshape(B, S, H, V), s


def rwkv6_decode_step(r, k, v, w, u, s):
    """Single-token WKV6 update for serving: shapes (B,H,K) / (B,H,V)."""
    kv = k[..., :, None] * v[..., None, :]
    y = jnp.einsum("bhk,bhkv->bhv", r.astype(jnp.float32),
                   s + u[None, :, :, None] * kv.astype(jnp.float32))
    s = jnp.exp(w.astype(jnp.float32))[..., None] * s + kv.astype(jnp.float32)
    return y.astype(v.dtype), s


def mamba2_decode_step(x, dt, A, Bm, Cm, h):
    """Single-token SSD update: x (B,H,P), dt (B,H), Bm/Cm (B,G,N)."""
    H = x.shape[1]
    G = Bm.shape[1]
    rep = H // G
    Bh = jnp.repeat(Bm, rep, axis=1)
    Ch = jnp.repeat(Cm, rep, axis=1)
    decay = jnp.exp(dt * A[None, :])
    upd = dt[..., None, None] * (x[..., :, None] * Bh[..., None, :])
    h = h * decay[..., None, None] + upd.astype(jnp.float32)
    y = jnp.einsum("bhpn,bhn->bhp", h, Ch.astype(jnp.float32))
    return y.astype(x.dtype), h
