"""Blockwise (flash) attention forward kernel for TPU.

Grid ``(B, H, num_q_blocks, num_kv_blocks)`` with the KV dimension
innermost — TPU grids iterate sequentially over the last axis, so the
online-softmax running state (m, l, acc) lives in VMEM scratch that
persists across KV steps and the output block is written once on the last
step.  GQA/MQA is handled in the BlockSpec index maps: the KV block for
query head ``h`` is head ``h // (H // KV)`` — no materialized repeat.

Causal masking skips fully-masked KV blocks via ``pl.when`` (no MXU work
issued for them) and applies an iota mask on the diagonal blocks.

Block shapes are (128, head_dim)-aligned by default, matching the MXU's
128-lane systolic tiles; head_dim 64/128/256 are all lane-aligned.
"""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _fwd_kernel(q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr, *,
                scale: float, causal: bool, offs: int, block_q: int,
                block_k: int, num_k_blocks: int):
    qi = pl.program_id(2)
    ki = pl.program_id(3)

    @pl.when(ki == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    def _compute():
        q = q_ref[0, 0].astype(jnp.float32)  # (bq, D)
        k = k_ref[0, 0].astype(jnp.float32)  # (bk, D)
        v = v_ref[0, 0]                      # (bk, D)
        s = jax.lax.dot_general(
            q * scale, k, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32)  # (bq, bk)
        if causal:
            qpos = jax.lax.broadcasted_iota(jnp.int32, (block_q, block_k), 0) \
                + qi * block_q + offs
            kpos = jax.lax.broadcasted_iota(jnp.int32, (block_q, block_k), 1) \
                + ki * block_k
            s = jnp.where(kpos <= qpos, s, NEG_INF)
        m_prev = m_scr[...]
        m_new = jnp.maximum(m_prev, s.max(axis=-1))
        p = jnp.exp(s - m_new[:, None])
        corr = jnp.exp(m_prev - m_new)
        l_scr[...] = l_scr[...] * corr + p.sum(axis=-1)
        m_scr[...] = m_new
        acc_scr[...] = acc_scr[...] * corr[:, None] + jax.lax.dot_general(
            p.astype(v.dtype), v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)

    if causal:
        # skip KV blocks strictly above the diagonal for this q block:
        # the last query position of the block sees keys <= qpos_max.
        qpos_max = (qi + 1) * block_q - 1 + offs
        pl.when(ki * block_k <= qpos_max)(_compute)
    else:
        _compute()

    @pl.when(ki == num_k_blocks - 1)
    def _finalize():
        l = jnp.maximum(l_scr[...], 1e-30)
        o_ref[0, 0] = (acc_scr[...] / l[:, None]).astype(o_ref.dtype)


def flash_attention_fwd(
    q: jax.Array,  # (B, H, S, D)
    k: jax.Array,  # (B, KV, T, D)
    v: jax.Array,  # (B, KV, T, D)
    causal: bool = True,
    scale: Optional[float] = None,
    block_q: int = 128,
    block_k: int = 128,
    interpret: bool = False,
) -> jax.Array:
    B, H, S, D = q.shape
    KV, T = k.shape[1], k.shape[2]
    if H % KV:
        raise ValueError("query heads must be a multiple of kv heads")
    group = H // KV
    scale_ = D ** -0.5 if scale is None else scale
    block_q = min(block_q, S)
    block_k = min(block_k, T)
    if S % block_q or T % block_k:
        raise ValueError("sequence lengths must divide block sizes")
    nq, nk = S // block_q, T // block_k
    offs = T - S

    kernel = functools.partial(
        _fwd_kernel, scale=scale_, causal=causal, offs=offs,
        block_q=block_q, block_k=block_k, num_k_blocks=nk)

    # causal block skipping happens inside the kernel via pl.when; here we
    # still express it through the (python-bool) short circuit above.
    return pl.pallas_call(
        kernel,
        grid=(B, H, nq, nk),
        in_specs=[
            pl.BlockSpec((1, 1, block_q, D), lambda b, h, qi, ki: (b, h, qi, 0)),
            pl.BlockSpec((1, 1, block_k, D), lambda b, h, qi, ki: (b, h // group, ki, 0)),
            pl.BlockSpec((1, 1, block_k, D), lambda b, h, qi, ki: (b, h // group, ki, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, block_q, D), lambda b, h, qi, ki: (b, h, qi, 0)),
        out_shape=jax.ShapeDtypeStruct((B, H, S, D), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q,), jnp.float32),
            pltpu.VMEM((block_q,), jnp.float32),
            pltpu.VMEM((block_q, D), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v)
