"""Chunked Mamba2 (SSD) scan kernel for TPU.

The SSD recurrence  h_t = exp(dt_t A) h_{t-1} + dt_t x_t B_t^T,
y_t = h_t C_t  is sequential per token — useless for the MXU.  The chunked
decomposition turns it into dense per-chunk matmuls plus a tiny sequential
state carry:

  grid (B, head_blocks, num_chunks), chunks innermost (TPU grids iterate
  the last axis sequentially), state (hb, P, N) carried in VMEM scratch:

  * intra-chunk:  y[t] += sum_{s<=t} exp(cs_t - cs_s) dt_s (C_t . B_s) x_s
    — an (L x L) per-head-weighted matmul against x (MXU work; the decay
    exponents are differences of an inclusive cumsum, always <= 0, so the
    exponentials are numerically safe);
  * state in:     y[t] += exp(cs_t) C_t . h
  * state out:    h' = exp(cs_L) h + sum_s exp(cs_L - cs_s) dt_s B_s x_s^T

B/C are shared across the ``rep = H // G`` heads of a group; the BlockSpec
index map points every head block at its group's B/C block (no repeat in
HBM).  Requires ``head_block`` to divide ``rep`` when G < H.
"""

from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _mamba2_kernel(x_ref, dt_ref, a_ref, b_ref, c_ref, h0_ref,
                   y_ref, hfin_ref, state_scr, *,
                   chunk: int, num_chunks: int, gb: int):
    ci = pl.program_id(2)

    @pl.when(ci == 0)
    def _init():
        state_scr[...] = h0_ref[0].astype(jnp.float32)

    x = x_ref[0].astype(jnp.float32)     # (L, hb, P)
    dt = dt_ref[0].astype(jnp.float32)   # (L, hb)
    A = a_ref[...].astype(jnp.float32)   # (hb,)
    bb = b_ref[0].astype(jnp.float32)    # (L, gb, N)
    cb = c_ref[0].astype(jnp.float32)    # (L, gb, N)
    L, hb, P = x.shape

    a = dt * A[None, :]                  # (L, hb) log-decays, <= 0
    cs = jnp.cumsum(a, axis=0)           # inclusive
    total = cs[-1]                       # (hb,)

    # (L, L, gb) group-shared C.B inner products — MXU matmuls per group
    CB = jax.lax.dot_general(
        cb.transpose(1, 0, 2), bb.transpose(1, 0, 2),
        (((2,), (2,)), ((0,), (0,))),
        preferred_element_type=jnp.float32)          # (gb, Lt, Ls)
    CB = CB.transpose(1, 2, 0)                        # (Lt, Ls, gb)
    if gb == 1:
        CB = jnp.broadcast_to(CB, (L, L, hb))

    tril = (jax.lax.broadcasted_iota(jnp.int32, (L, L), 0)
            >= jax.lax.broadcasted_iota(jnp.int32, (L, L), 1))
    decay = jnp.where(tril[:, :, None],
                      jnp.exp(cs[:, None, :] - cs[None, :, :]), 0.0)  # (Lt,Ls,hb)
    w = decay * CB * dt[None, :, :]                   # (Lt, Ls, hb)
    # y_intra[t,h,p] = sum_s w[t,s,h] x[s,h,p]
    y = jax.lax.dot_general(
        w.transpose(2, 0, 1), x.transpose(1, 0, 2),
        (((2,), (1,)), ((0,), (0,))),
        preferred_element_type=jnp.float32)           # (hb, Lt, P)
    y = y.transpose(1, 0, 2)                          # (L, hb, P)

    # carried-in state: y[t,h,p] += exp(cs[t,h]) sum_n C[t,g,n] h[h,p,n]
    h = state_scr[...]                                # (hb, P, N)
    cb_h = cb if gb > 1 else jnp.broadcast_to(cb, (L, hb, cb.shape[-1]))
    y_state = jax.lax.dot_general(
        cb_h.transpose(1, 0, 2), h,
        (((2,), (2,)), ((0,), (0,))),
        preferred_element_type=jnp.float32)           # (hb, L, P)
    y += y_state.transpose(1, 0, 2) * jnp.exp(cs)[:, :, None]
    y_ref[0] = y.astype(y_ref.dtype)

    # state update
    wst = jnp.exp(total[None, :] - cs) * dt           # (L, hb)
    bb_h = bb if gb > 1 else jnp.broadcast_to(bb, (L, hb, bb.shape[-1]))
    xw = x * wst[:, :, None]                          # (L, hb, P)
    upd = jax.lax.dot_general(
        xw.transpose(1, 2, 0), bb_h.transpose(1, 0, 2),
        (((2,), (1,)), ((0,), (0,))),
        preferred_element_type=jnp.float32)           # (hb, P, N)
    state_scr[...] = h * jnp.exp(total)[:, None, None] + upd

    @pl.when(ci == num_chunks - 1)
    def _finalize():
        hfin_ref[0] = state_scr[...]


def mamba2_scan(
    x: jax.Array,   # (B, S, H, P)
    dt: jax.Array,  # (B, S, H)
    A: jax.Array,   # (H,)
    Bm: jax.Array,  # (B, S, G, N)
    Cm: jax.Array,  # (B, S, G, N)
    h0: Optional[jax.Array] = None,  # (B, H, P, N)
    chunk: int = 128,
    head_block: int = 8,
    interpret: bool = False,
) -> Tuple[jax.Array, jax.Array]:
    B, S, H, P = x.shape
    G, N = Bm.shape[2], Bm.shape[3]
    if H % G:
        raise ValueError("H must be a multiple of G")
    rep = H // G
    chunk = min(chunk, S)
    if S % chunk:
        raise ValueError("S must divide chunk")
    nc = S // chunk
    hb = min(head_block, H)
    if H % hb:
        raise ValueError("H must divide head_block")
    if rep > 1 and rep % hb:
        raise ValueError("head_block must divide H//G")
    gb = hb if rep == 1 else 1
    if h0 is None:
        h0 = jnp.zeros((B, H, P, N), jnp.float32)

    def g_index(b, hi, ci):
        return (b, ci, (hi * hb) // rep if rep > 1 else hi, 0)

    kernel = functools.partial(_mamba2_kernel, chunk=chunk, num_chunks=nc, gb=gb)
    y, hfin = pl.pallas_call(
        kernel,
        grid=(B, H // hb, nc),
        in_specs=[
            pl.BlockSpec((1, chunk, hb, P), lambda b, hi, ci: (b, ci, hi, 0)),
            pl.BlockSpec((1, chunk, hb), lambda b, hi, ci: (b, ci, hi)),
            pl.BlockSpec((hb,), lambda b, hi, ci: (hi,)),
            pl.BlockSpec((1, chunk, gb, N), g_index),
            pl.BlockSpec((1, chunk, gb, N), g_index),
            pl.BlockSpec((1, hb, P, N), lambda b, hi, ci: (b, hi, 0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, chunk, hb, P), lambda b, hi, ci: (b, ci, hi, 0)),
            pl.BlockSpec((1, hb, P, N), lambda b, hi, ci: (b, hi, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B, S, H, P), x.dtype),
            jax.ShapeDtypeStruct((B, H, P, N), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((hb, P, N), jnp.float32)],
        interpret=interpret,
    )(x, dt, A, Bm, Cm, h0)
    return y, hfin
