"""Training runtime: fault-tolerant loop, straggler watch, elastic resume."""

from .trainer import Trainer, TrainerConfig

__all__ = ["Trainer", "TrainerConfig"]
