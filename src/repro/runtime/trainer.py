"""Fault-tolerant training loop.

* deterministic resumability: the data order is a pure function of
  (seed, epoch, step), so restoring {params, opt, epoch, step} from the
  newest committed checkpoint reproduces the exact remaining schedule;
* write-behind checkpointing through the foreactor-backed
  CheckpointManager: the save is one speculated write graph (staged
  creates, pre-issued extent writes, commit marker published last) running
  on a background thread, so checkpoint I/O overlaps step compute and the
  trainer only blocks when a save is still in flight at the next
  checkpoint boundary (``ckpt_wait_s`` in the fit() summary measures
  exactly that residual stall — ``write_behind=False`` degrades to
  synchronous saves for comparison);
* straggler watch: a per-step wall-time EMA; steps slower than
  ``straggler_factor x`` EMA are recorded (and, on a real cluster, would
  feed the coordinator's slow-host eviction);
* crash safety: any exception triggers a synchronous emergency save of
  the last good state before re-raising;
* elastic resume: ``Trainer.fit`` can be re-entered with a different mesh
  (fewer/more hosts) — checkpoints are mesh-agnostic (full arrays +
  named leaves), so the step function is simply re-lowered.
"""

from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, List, Optional

import jax
import numpy as np

from repro.checkpoint import CheckpointManager, CheckpointPolicy
from repro.data.pipeline import TokenBatchLoader
from repro.launch import sharding as shd
from repro.launch.steps import make_train_step, make_train_state
from repro.launch.mesh import mesh_context
from repro.models.api import Model
from repro.optim.adamw import AdamWConfig


@dataclass
class TrainerConfig:
    steps: int = 100
    ckpt_every: int = 50
    log_every: int = 10
    seed: int = 0
    straggler_factor: float = 3.0
    restore: bool = True
    #: overlap checkpoint saves with step compute (save_async); False runs
    #: every save synchronously on the training thread (the serial baseline
    #: benchmarks/bench_write.py measures against)
    write_behind: bool = True
    #: retention policy installed on the CheckpointManager at fit() time
    #: (None keeps whatever the manager was built with); every save is
    #: followed by a GC pass collecting steps outside the policy's keep-set
    retention: Optional[CheckpointPolicy] = None
    #: delta cadence: number of delta (incremental) saves between full
    #: saves.  0 = every save full; k writes k deltas then one full, so a
    #: restore chains at most k+1 checkpoints.
    delta_every: int = 0


@dataclass
class StepEvent:
    step: int
    seconds: float
    loss: float
    straggler: bool


class Trainer:
    def __init__(self, model: Model, opt_cfg: AdamWConfig,
                 loader: TokenBatchLoader, ckpt: Optional[CheckpointManager],
                 mesh, tcfg: TrainerConfig = TrainerConfig(),
                 batch_extras: Optional[Callable[[Dict], Dict]] = None):
        self.model = model
        self.opt_cfg = opt_cfg
        self.loader = loader
        self.ckpt = ckpt
        self.mesh = mesh
        self.tcfg = tcfg
        self.batch_extras = batch_extras
        self.events: List[StepEvent] = []
        self.stragglers: List[int] = []
        self.ckpt_wait_s = 0.0  # training-thread time lost to checkpoint I/O
        self.ckpt_saves = 0
        # delta cadence state: primed so the very first save is a full one
        self._saves_since_full = tcfg.delta_every

    def _next_delta(self) -> bool:
        """True iff the next periodic save should be incremental: the
        cadence writes ``delta_every`` deltas between full saves (the
        emergency save is always full — the crash path should not depend
        on chain state)."""
        if self.tcfg.delta_every <= 0:
            return False
        if self._saves_since_full >= self.tcfg.delta_every:
            self._saves_since_full = 0
            return False
        self._saves_since_full += 1
        return True

    # -- step construction -------------------------------------------------
    def _jit_step(self):
        step = make_train_step(self.model, self.opt_cfg)
        return jax.jit(step, donate_argnums=(0,))

    def _init_or_restore(self):
        state = None
        start_epoch, start_step = 0, 0
        if self.ckpt is not None and self.tcfg.restore:
            like = jax.eval_shape(
                lambda r: make_train_state(self.model, self.opt_cfg, r),
                jax.random.PRNGKey(self.tcfg.seed))
            out = self.ckpt.restore_latest(like=like)
            if out is not None:
                ckpt_step, tree, extra = out
                state = jax.tree.map(jax.numpy.asarray, tree)
                start_epoch = int(extra.get("epoch", 0))
                start_step = int(extra.get("step", ckpt_step))
                print(f"[trainer] restored step {ckpt_step} "
                      f"-> resuming at (epoch {start_epoch}, step {start_step})")
        if state is None:
            state = make_train_state(self.model, self.opt_cfg,
                                     jax.random.PRNGKey(self.tcfg.seed))
        return state, start_epoch, start_step

    # -- the loop ------------------------------------------------------------
    def fit(self) -> Dict[str, Any]:
        if self.ckpt is not None and self.tcfg.retention is not None:
            self.ckpt.policy = self.tcfg.retention
        with mesh_context(self.mesh):
            step_fn = self._jit_step()
            state, epoch, step0 = self._init_or_restore()
            spe = self.loader.steps_per_epoch
            ema = None
            losses = []
            global_step = step0
            try:
                while global_step < self.tcfg.steps:
                    e, s = divmod(global_step, spe)
                    batch = self.loader.load(e, s)
                    if self.batch_extras is not None:
                        batch = self.batch_extras(batch)
                    t0 = time.perf_counter()
                    state, metrics = step_fn(state, batch)
                    loss = float(metrics["loss"])
                    dt = time.perf_counter() - t0
                    straggler = ema is not None and dt > self.tcfg.straggler_factor * ema
                    ema = dt if ema is None else 0.9 * ema + 0.1 * dt
                    self.events.append(StepEvent(global_step, dt, loss, straggler))
                    if straggler:
                        self.stragglers.append(global_step)
                        print(f"[trainer] STRAGGLER step {global_step}: "
                              f"{dt:.3f}s vs ema {ema:.3f}s")
                    losses.append(loss)
                    if self.tcfg.log_every and global_step % self.tcfg.log_every == 0:
                        print(f"[trainer] step {global_step:5d} loss {loss:.4f} "
                              f"gnorm {float(metrics['grad_norm']):.3f} {dt*1e3:.0f}ms")
                    global_step += 1
                    if self.ckpt is not None and self.tcfg.ckpt_every \
                            and global_step % self.tcfg.ckpt_every == 0:
                        e2, s2 = divmod(global_step, spe)
                        extra = {"epoch": e2, "step": global_step}
                        t0 = time.perf_counter()
                        delta = self._next_delta()
                        if self.tcfg.write_behind:
                            # blocks only while a previous save is still in
                            # flight; the write graph runs behind compute
                            self.ckpt.save_async(global_step, state,
                                                 extra=extra, delta=delta)
                        else:
                            self.ckpt.save(global_step, state, extra=extra,
                                           delta=delta)
                        self.ckpt_wait_s += time.perf_counter() - t0
                        self.ckpt_saves += 1
            except BaseException:
                if self.ckpt is not None:
                    try:  # emergency checkpoint of the last good state
                        self.ckpt.wait_pending()
                        self.ckpt.save(global_step, state,
                                       extra={"epoch": epoch, "step": global_step,
                                              "emergency": True})
                        print(f"[trainer] emergency checkpoint at step {global_step}")
                    except BaseException as e2:
                        print(f"[trainer] emergency save failed: {e2!r}")
                raise
            if self.ckpt is not None:
                t0 = time.perf_counter()
                self.ckpt.wait_pending()
                self.ckpt.save(global_step, state,
                               extra={"epoch": epoch, "step": global_step},
                               delta=self._next_delta())
                self.ckpt_wait_s += time.perf_counter() - t0
                self.ckpt_saves += 1
            return {
                "state": state,
                "losses": losses,
                "final_step": global_step,
                "stragglers": self.stragglers,
                "ckpt_wait_s": self.ckpt_wait_s,
                "ckpt_saves": self.ckpt_saves,
                "mean_step_s": float(np.mean([ev.seconds for ev in self.events[1:]]))
                if len(self.events) > 1 else None,
            }
