"""Sharded, fault-tolerant checkpoint manager (see package docstring).

Shard *files* were always round-robin striped over leaf chunks; on a
:class:`repro.core.device.ShardedDevice` each shard file is additionally
placed on a distinct sub-device (``Device.place``), so a restore's pre-issued
pread batch fans out across queue pairs and aggregate bandwidth scales with
device count (docs/ARCHITECTURE.md, "Sharded multi-device substrate").
Manifest and commit marker stay in the bare namespace: the sharded device
hash-routes them and merges ``getdents`` across sub-devices, so discovery
(:meth:`CheckpointManager.committed_steps`) is topology-blind.

The save path is one foreaction *write graph* (docs/ARCHITECTURE.md,
"Undoable write speculation"): shard creates are staged (undoable), every
extent pwrite pre-issues with its data thunk serializing leaf *k+1* while
the writes for leaf *k* are in flight, per-shard fsync/close ride behind as
harvest barriers, and the manifest + commit marker chains are gated so the
marker still publishes strictly last.  An aborted save rolls its staged
files back — no partial step ever enters the committed namespace.
"""

from __future__ import annotations

import json
import threading
import zlib
from dataclasses import dataclass
from typing import Any, Callable, Dict, List, Optional, Sequence, Tuple

import numpy as np

import jax

from repro.core.api import Foreactor, io
from repro.core.device import Device
from repro.core.graph import ForeactionGraph, FromNode, GraphBuilder
from repro.core.patterns import register_patterns
from repro.core.syscalls import Sys

COMMIT_MARKER = "COMMIT"
MANIFEST = "manifest.json"


class CheckpointError(RuntimeError):
    pass


def _leaf_name(path) -> str:
    return jax.tree_util.keystr(path)


@dataclass
class _Extent:
    leaf: int  # leaf index
    leaf_off: int  # offset within the leaf byte stream
    shard: int  # shard file id
    shard_off: int  # offset within the shard file
    length: int


def _plan_extents(nbytes_per_leaf: Sequence[int], num_shards: int,
                  chunk_bytes: int) -> Tuple[List[_Extent], List[int]]:
    """Round-robin chunks of all leaves across shard files."""
    extents: List[_Extent] = []
    shard_sizes = [0] * num_shards
    nxt = 0
    for li, n in enumerate(nbytes_per_leaf):
        off = 0
        while off < n:
            ln = min(chunk_bytes, n - off)
            s = nxt % num_shards
            extents.append(_Extent(li, off, s, shard_sizes[s], ln))
            shard_sizes[s] += ln
            off += ln
            nxt += 1
    return extents, shard_sizes


class _LazyBlobs:
    """Per-leaf serialization on first touch, cached.

    The extent plan needs only ``nbytes`` (known without serializing), so
    ``tobytes()`` runs when a write's data thunk fires at pre-issue time —
    the engine serializes leaf *k+1* on the application thread while the
    workers are still writing leaf *k*'s extents.
    """

    def __init__(self, arrays: Sequence[np.ndarray]):
        self.arrays = arrays
        self._blobs: Dict[int, bytes] = {}

    def __getitem__(self, i: int) -> bytes:
        b = self._blobs.get(i)
        if b is None:
            b = self._blobs[i] = self.arrays[i].tobytes()
        return b

    def __len__(self) -> int:
        return len(self.arrays)


def build_save_graph(num_shards: int, num_extents: int,
                     name: str) -> ForeactionGraph:
    """The full checkpoint-save chain as one foreaction graph.

    Shape depends only on (num_shards, num_extents); which shard each
    extent targets, the data thunks, and the paths come from ctx::

        ctx = {"paths": [shard paths], "writes": [(shard, thunk, off)],
               "per_shard": [extent count per shard],
               "manifest_path": str, "manifest_bytes": ()->bytes,
               "marker_path": str}

    Node order mirrors the serial save exactly: S creating opens, E extent
    pwrites, S fsyncs, S closes, then the manifest chain, then the commit
    marker chain.  All edges are strong (a started save is guaranteed), so
    opens and data writes pre-issue in one sweep — the writes take their fd
    as ``FromNode`` of their shard's open, which is what lets them enter
    the queue before any open completes.  The fsync of shard *s* is
    harvest-gated on shard *s*'s writes and each close on its fsync; the
    marker chain is gated on every shard close plus the manifest close, so
    the commit marker is published strictly last even though everything
    before it overlapped.
    """
    b = GraphBuilder(name)

    def _fd_of(ctx, s: int):
        """This shard's fd: the harvested value once the frontier served the
        open, else a FromNode deferred to the pre-issued open request.  The
        fallback matters at the chain head — the very first open is served
        at the frontier (never pre-issued), so nodes depending on it can
        only bind through ctx."""
        fds = ctx.get("fds", ())
        return fds[s] if s in fds else FromNode(f"open{s}")

    def _open(s: int):
        def args(ctx, ep):
            return ((ctx["paths"][s], "w"), False)

        def save(ctx, ep, rc):
            ctx.setdefault("fds", {})[s] = rc

        return args, save

    def _write(j: int):
        def args(ctx, ep):
            s, thunk, off = ctx["writes"][j]
            return ((_fd_of(ctx, s), thunk(), off), False)

        def save(ctx, ep, rc):
            s, _thunk, _off = ctx["writes"][j]
            done = ctx.setdefault("_w_done", [0] * len(ctx["paths"]))
            done[s] += 1
            ctx["_w_total"] = ctx.get("_w_total", 0) + 1

        return args, save

    def _fsync(s: int):
        def args(ctx, ep):
            done = ctx.get("_w_done", [0] * len(ctx["paths"]))
            if done[s] < ctx["per_shard"][s]:
                return None  # harvest barrier: this shard's writes first
            return ((_fd_of(ctx, s),), False)

        def save(ctx, ep, rc):
            ctx.setdefault("_synced", set()).add(s)

        return args, save

    def _close(s: int):
        def args(ctx, ep):
            if s not in ctx.get("_synced", ()):
                return None
            return ((_fd_of(ctx, s),), False)

        def save(ctx, ep, rc):
            ctx["_closed"] = ctx.get("_closed", 0) + 1

        return args, save

    num = [0]

    def chain(nm, sc, args, save=None):
        b.AddSyscallNode(nm, sc, args, save)
        if num[0]:
            b.SyscallSetNext(prev[0], nm)
        prev[0] = nm
        num[0] += 1

    prev = [None]
    for s in range(num_shards):
        a, sv = _open(s)
        chain(f"open{s}", Sys.OPEN, a, sv)
    for j in range(num_extents):
        a, sv = _write(j)
        chain(f"w{j}", Sys.PWRITE, a, sv)
    for s in range(num_shards):
        a, sv = _fsync(s)
        chain(f"sync{s}", Sys.FSYNC, a, sv)
    for s in range(num_shards):
        a, sv = _close(s)
        chain(f"close{s}", Sys.CLOSE, a, sv)

    # manifest chain: content is ready once every extent write is harvested
    def m_open_args(ctx, ep):
        return ((ctx["manifest_path"], "w"), False)

    def _mfd(ctx):
        return ctx["mfd"] if "mfd" in ctx else FromNode("open_m")

    def _cfd(ctx):
        return ctx["cfd"] if "cfd" in ctx else FromNode("open_c")

    def m_write_args(ctx, ep):
        if ctx.get("_w_total", 0) < len(ctx["writes"]):
            return None
        return ((_mfd(ctx), ctx["manifest_bytes"](), 0), False)

    def m_write_save(ctx, ep, rc):
        ctx["_m_written"] = True

    def m_sync_args(ctx, ep):
        if not ctx.get("_m_written"):
            return None
        return ((_mfd(ctx),), False)

    def m_sync_save(ctx, ep, rc):
        ctx["_m_synced"] = True

    def m_close_args(ctx, ep):
        if not ctx.get("_m_synced"):
            return None
        return ((_mfd(ctx),), False)

    def m_close_save(ctx, ep, rc):
        ctx["_m_closed"] = True

    chain("open_m", Sys.OPEN, m_open_args,
          lambda ctx, ep, rc: ctx.__setitem__("mfd", rc))
    chain("w_m", Sys.PWRITE, m_write_args, m_write_save)
    chain("sync_m", Sys.FSYNC, m_sync_args, m_sync_save)
    chain("close_m", Sys.CLOSE, m_close_args, m_close_save)

    # commit-marker chain: gated on every shard close + the manifest close,
    # so the marker publishes strictly last (the atomic-commit invariant)
    def c_open_args(ctx, ep):
        if ctx.get("_closed", 0) < len(ctx["paths"]) or not ctx.get("_m_closed"):
            return None
        return ((ctx["marker_path"], "w"), False)

    def c_write_args(ctx, ep):
        return ((_cfd(ctx), b"ok", 0), False)

    def c_write_save(ctx, ep, rc):
        ctx["_c_written"] = True

    def c_sync_args(ctx, ep):
        if not ctx.get("_c_written"):
            return None
        return ((_cfd(ctx),), False)

    def c_sync_save(ctx, ep, rc):
        ctx["_c_synced"] = True

    def c_close_args(ctx, ep):
        if not ctx.get("_c_synced"):
            return None
        return ((_cfd(ctx),), False)

    chain("open_c", Sys.OPEN, c_open_args,
          lambda ctx, ep, rc: ctx.__setitem__("cfd", rc))
    chain("w_c", Sys.PWRITE, c_write_args, c_write_save)
    chain("sync_c", Sys.FSYNC, c_sync_args, c_sync_save)
    chain("close_c", Sys.CLOSE, c_close_args)
    b.SyscallSetNext("close_c", None)
    return b.Build()


class CheckpointManager:
    """Save/restore pytrees of arrays under ``root`` on a Device.

    Directory layout::

        root/step_{N:010d}/shard_{i:04d}.bin
        root/step_{N:010d}/manifest.json
        root/step_{N:010d}/COMMIT          (written last: atomic commit)
    """

    def __init__(
        self,
        device: Device,
        root: str,
        fa: Optional[Foreactor] = None,
        num_shards: int = 16,
        chunk_bytes: int = 4 << 20,
        keep: int = 3,
    ):
        self.device = device
        self.root = root.rstrip("/")
        self.num_shards = num_shards
        self.chunk_bytes = chunk_bytes
        self.keep = keep
        self.fa = fa if fa is not None else Foreactor(device=device, depth=32)
        register_patterns(self.fa)
        self._async_thread: Optional[threading.Thread] = None
        self._async_error: Optional[BaseException] = None
        # serializes save_async/wait_pending: starting a second background
        # save MUST join-or-raise the first (losing its error or orphaning
        # its thread would silently drop a checkpoint)
        self._async_lock = threading.Lock()

    # -- paths ----------------------------------------------------------------
    def step_dir(self, step: int) -> str:
        return f"{self.root}/step_{step:010d}"

    def _shard_path(self, step: int, i: int) -> str:
        # place() pins shard file i to sub-device i % N on a ShardedDevice
        # (identity on flat devices), spreading restore/save I/O across
        # every available queue pair.
        return self.device.place(f"{self.step_dir(step)}/shard_{i:04d}.bin", hint=i)

    # -- save -------------------------------------------------------------------
    def save(self, step: int, tree: Any, extra: Optional[Dict[str, Any]] = None) -> None:
        """Write one committed checkpoint step as a single foreaction write
        graph (:func:`build_save_graph`): staged shard creates, pipelined
        leaf serialization, pre-issued extent writes, fsync/close harvest
        barriers, commit marker published strictly last.  Aborting mid-save
        rolls the staged files back — no trace in the committed namespace.
        """
        leaves_kp, treedef = jax.tree_util.tree_flatten_with_path(tree)
        names = [_leaf_name(kp) for kp, _ in leaves_kp]
        arrays = [np.asarray(v) for _, v in leaves_kp]
        blobs = _LazyBlobs(arrays)
        extents, shard_sizes = _plan_extents([a.nbytes for a in arrays],
                                             self.num_shards, self.chunk_bytes)
        d = self.step_dir(step)
        paths = [self._shard_path(step, i) for i in range(self.num_shards)]
        per_shard = [0] * self.num_shards
        for e in extents:
            per_shard[e.shard] += 1
        writes: List[Tuple[int, Callable[[], bytes], int]] = [
            (e.shard,
             (lambda e=e: blobs[e.leaf][e.leaf_off : e.leaf_off + e.length]),
             e.shard_off)
            for e in extents
        ]
        manifest_cache: Dict[str, bytes] = {}

        def manifest_bytes() -> bytes:
            data = manifest_cache.get("data")
            if data is None:
                manifest = {
                    "step": step,
                    "num_shards": self.num_shards,
                    "shard_sizes": shard_sizes,
                    "leaves": [
                        {
                            "name": names[i],
                            "dtype": str(arrays[i].dtype),
                            "shape": list(arrays[i].shape),
                            "nbytes": arrays[i].nbytes,
                            "crc32": zlib.crc32(blobs[i]),
                        }
                        for i in range(len(arrays))
                    ],
                    "extents": [
                        [e.leaf, e.leaf_off, e.shard, e.shard_off, e.length]
                        for e in extents
                    ],
                    "extra": extra or {},
                }
                data = manifest_cache["data"] = json.dumps(manifest).encode()
            return data

        # register is an idempotent builder assignment; the built graph and
        # its compiled plan are cached by name/(graph, depth-mode), so every
        # save after the first of a given shape costs two dict probes
        graph_name = f"ckpt_save_s{self.num_shards}_e{len(extents)}"
        self.fa.register(
            graph_name,
            lambda S=self.num_shards, E=len(extents), n=graph_name:
                build_save_graph(S, E, n))
        self.fa.plan(graph_name)

        def capture():
            return {
                "paths": paths,
                "writes": writes,
                "per_shard": per_shard,
                "manifest_path": f"{d}/{MANIFEST}",
                "manifest_bytes": manifest_bytes,
                "marker_path": f"{d}/{COMMIT_MARKER}",
            }

        @self.fa.wrap(graph_name, capture)
        def _save_all():
            fds = [io.open(self.device, p, "w") for p in paths]
            for s, thunk, off in writes:
                io.pwrite(self.device, fds[s], thunk(), off)
            for fd in fds:
                io.fsync(self.device, fd)
            for fd in fds:
                io.close(self.device, fd)
            mf = io.open(self.device, f"{d}/{MANIFEST}", "w")
            io.pwrite(self.device, mf, manifest_bytes(), 0)
            io.fsync(self.device, mf)
            io.close(self.device, mf)
            # atomic commit: the marker is written (and published) last
            cf = io.open(self.device, f"{d}/{COMMIT_MARKER}", "w")
            io.pwrite(self.device, cf, b"ok", 0)
            io.fsync(self.device, cf)
            io.close(self.device, cf)

        _save_all()
        self._gc()

    def save_async(self, step: int, tree: Any, extra: Optional[Dict[str, Any]] = None) -> None:
        """Write-behind checkpointing: snapshot to host memory now, run the
        (speculated) write graph on a background thread, overlap with step
        compute.  Join-or-raise semantics: if a previous background save is
        still running it is joined first, and if it failed its error is
        raised *here* — a second call can never silently orphan an
        in-flight save or swallow its failure."""
        with self._async_lock:
            self._join_pending_locked()
            # snapshot to host memory synchronously; write in the background
            tree = jax.tree_util.tree_map(np.asarray, tree)

            def run():
                try:
                    self.save(step, tree, extra)
                except BaseException as e:  # surfaced on next wait_pending()
                    self._async_error = e

            self._async_thread = threading.Thread(
                target=run, name=f"ckpt-save-{step}", daemon=True)
            self._async_thread.start()

    def wait_pending(self) -> None:
        with self._async_lock:
            self._join_pending_locked()

    def _join_pending_locked(self) -> None:
        if self._async_thread is not None:
            self._async_thread.join()
            self._async_thread = None
        if self._async_error is not None:
            e, self._async_error = self._async_error, None
            raise CheckpointError(f"async checkpoint save failed: {e!r}") from e

    # -- discovery / validation ---------------------------------------------------
    def committed_steps(self) -> List[int]:
        try:
            entries = io.getdents(self.device, self.root)
        except FileNotFoundError:
            return []
        steps = []
        for e in entries:
            if e.startswith("step_"):
                marker = f"{self.root}/{e}/{COMMIT_MARKER}"
                try:
                    fd = io.open(self.device, marker, "r")
                    ok = io.pread(self.device, fd, 2, 0) == b"ok"
                    io.close(self.device, fd)
                except FileNotFoundError:
                    continue
                if ok:  # gc tombstones overwrite the marker with b"gc"
                    steps.append(int(e[len("step_"):]))
        return sorted(steps)

    def latest_step(self) -> Optional[int]:
        s = self.committed_steps()
        return s[-1] if s else None

    def read_manifest(self, step: int) -> Dict[str, Any]:
        p = f"{self.step_dir(step)}/{MANIFEST}"
        st = io.fstatat(self.device, p)
        fd = io.open(self.device, p, "r")
        data = io.pread(self.device, fd, st.st_size, 0)
        io.close(self.device, fd)
        return json.loads(data)

    def validate(self, step: int) -> bool:
        """du-shaped parallel fstat over every shard file; size check."""
        m = self.read_manifest(step)
        paths = [self._shard_path(step, i) for i in range(m["num_shards"])]

        @self.fa.wrap("stat_list", lambda paths: {"paths": paths})
        def _stat_all(paths):
            return [io.fstatat(self.device, p) for p in paths]

        try:
            stats = _stat_all(paths)
        except FileNotFoundError:
            return False
        return all(st.st_size == sz for st, sz in zip(stats, m["shard_sizes"]))

    # -- restore ---------------------------------------------------------------------
    def restore(self, step: int, check_crc: bool = True) -> Tuple[Any, Dict[str, Any]]:
        """Parallel chunked restore -> (flat {name: np.ndarray}, extra)."""
        m = self.read_manifest(step)
        paths = [self._shard_path(step, i) for i in range(m["num_shards"])]

        # read-only opens are pure -> pre-issued as one batch; on a sharded
        # device they fan out to their owning sub-devices in parallel
        @self.fa.wrap("open_list", lambda paths: {"paths": paths})
        def _open_all(paths):
            return [io.open(self.device, p, "r") for p in paths]

        fds = _open_all(paths)
        extents = [_Extent(*e) for e in m["extents"]]
        ext_args = [(fds[e.shard], e.length, e.shard_off) for e in extents]

        @self.fa.wrap("pread_extents", lambda extents: {"extents": extents})
        def _read_all(extents):
            return [io.pread(self.device, fd, n, off) for fd, n, off in extents]

        chunks = _read_all(ext_args)
        for fd in fds:
            io.close(self.device, fd)
        bufs = [bytearray(leaf["nbytes"]) for leaf in m["leaves"]]
        for e, c in zip(extents, chunks):
            if len(c) != e.length:
                raise CheckpointError(
                    f"short read: shard {e.shard} off {e.shard_off}: "
                    f"{len(c)} != {e.length}")
            bufs[e.leaf][e.leaf_off : e.leaf_off + e.length] = c
        out: Dict[str, np.ndarray] = {}
        for leaf, buf in zip(m["leaves"], bufs):
            if check_crc and zlib.crc32(bytes(buf)) != leaf["crc32"]:
                raise CheckpointError(f"crc mismatch for leaf {leaf['name']}")
            out[leaf["name"]] = np.frombuffer(bytes(buf), dtype=leaf["dtype"]).reshape(leaf["shape"])
        return out, m["extra"]

    def restore_tree(self, step: int, like: Any, check_crc: bool = True) -> Tuple[Any, Dict[str, Any]]:
        """Restore into the structure of ``like`` (names must match)."""
        flat, extra = self.restore(step, check_crc=check_crc)
        leaves_kp, treedef = jax.tree_util.tree_flatten_with_path(like)
        leaves = []
        for kp, proto in leaves_kp:
            name = _leaf_name(kp)
            if name not in flat:
                raise CheckpointError(f"checkpoint missing leaf {name}")
            arr = flat[name]
            proto_shape = tuple(getattr(proto, "shape", ()) or ())
            if proto_shape and tuple(arr.shape) != proto_shape:
                raise CheckpointError(
                    f"shape mismatch for {name}: ckpt {arr.shape} vs model {proto_shape}")
            leaves.append(arr)
        return jax.tree_util.tree_unflatten(treedef, leaves), extra

    def restore_latest(self, like: Any = None) -> Optional[Tuple[int, Any, Dict[str, Any]]]:
        """Newest committed checkpoint that validates; falls back past
        corrupt ones (node-failure recovery path)."""
        for step in reversed(self.committed_steps()):
            try:
                if not self.validate(step):
                    continue
                if like is None:
                    tree, extra = self.restore(step)
                else:
                    tree, extra = self.restore_tree(step, like)
                return step, tree, extra
            except (CheckpointError, FileNotFoundError):
                continue
        return None

    # -- replication ---------------------------------------------------------------
    def replicate(self, step: int, dst: "CheckpointManager") -> None:
        """Copy a committed checkpoint to another tier via Link'ed
        pread->pwrite chains (the cp graph at framework scale)."""
        m = self.read_manifest(step)
        pairs = []
        closers = []
        for i in range(m["num_shards"]):
            sfd = io.open(self.device, self._shard_path(step, i), "r")
            dfd = io.open(dst.device, dst._shard_path(step, i), "w")
            closers.append((sfd, dfd))
            size = m["shard_sizes"][i]
            off = 0
            while off < size or (size == 0 and off == 0):
                n = min(self.chunk_bytes, size - off)
                if n > 0:
                    pairs.append((sfd, dfd, n, off))
                off += max(n, 1)
                if size == 0:
                    break

        # NOTE: source and destination may be different Devices; the copy
        # graph runs on the source's engine, writes go to dst.device through
        # a device-dispatching session only when devices match.  For
        # cross-device replication we fall back to chunked read->write.
        if dst.device is self.device:
            @self.fa.wrap("copy_extents", lambda pairs: {"pairs": pairs})
            def _copy_all(pairs):
                for sfd, dfd, n, off in pairs:
                    data = io.pread(self.device, sfd, n, off)
                    io.pwrite(self.device, dfd, data, off)
            _copy_all(pairs)
        else:
            for sfd, dfd, n, off in pairs:
                data = io.pread(self.device, sfd, n, off)
                io.pwrite(dst.device, dfd, data, off)
        for sfd, dfd in closers:
            io.close(self.device, sfd)
            io.fsync(dst.device, dfd)
            io.close(dst.device, dfd)
        # manifest + commit marker on the destination
        mf = io.open(dst.device, f"{dst.step_dir(step)}/{MANIFEST}", "w")
        io.pwrite(dst.device, mf, json.dumps(m).encode(), 0)
        io.close(dst.device, mf)
        cf = io.open(dst.device, f"{dst.step_dir(step)}/{COMMIT_MARKER}", "w")
        io.pwrite(dst.device, cf, b"ok", 0)
        io.close(dst.device, cf)

    # -- gc ---------------------------------------------------------------------------
    def _gc(self) -> None:
        steps = self.committed_steps()
        # best effort: we cannot unlink through the Device API; tombstone the
        # commit marker instead so stale steps stop being restore candidates.
        for s in steps[: max(0, len(steps) - self.keep)]:
            try:
                cf = io.open(self.device, f"{self.step_dir(s)}/{COMMIT_MARKER}", "w")
                io.pwrite(self.device, cf, b"gc", 0)
                io.close(self.device, cf)
            except FileNotFoundError:
                pass
