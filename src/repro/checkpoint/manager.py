"""Sharded, fault-tolerant checkpoint manager (see package docstring).

Shard *files* were always round-robin striped over leaf chunks; on a
:class:`repro.core.device.ShardedDevice` each shard file is additionally
placed on a distinct sub-device (``Device.place``), so a restore's pre-issued
pread batch fans out across queue pairs and aggregate bandwidth scales with
device count (docs/ARCHITECTURE.md, "Sharded multi-device substrate").
Manifest and commit marker stay in the bare namespace: the sharded device
hash-routes them and merges ``getdents`` across sub-devices, so discovery
(:meth:`CheckpointManager.committed_steps`) is topology-blind.

The save path is one foreaction *write graph* (docs/ARCHITECTURE.md,
"Undoable write speculation"): shard creates are staged (undoable), every
extent pwrite pre-issues with its data thunk serializing leaf *k+1* while
the writes for leaf *k* are in flight, per-shard fsync/close ride behind as
harvest barriers, and the manifest + commit marker chains are gated so the
marker still publishes strictly last.  An aborted save rolls its staged
files back — no partial step ever enters the committed namespace.
"""

from __future__ import annotations

import json
import threading
import time
import zlib
from dataclasses import dataclass
from typing import Any, Callable, Dict, List, Optional, Sequence, Tuple

import numpy as np

import jax

from repro.core.api import Foreactor, current_session, io
from repro.core.buffers import BufferPool
from repro.core.coalesce import _pool_alignment
from repro.core.device import Device, ShardedDevice
from repro.core.graph import ForeactionGraph, FromNode, GraphBuilder
from repro.core.patterns import register_patterns
from repro.core.syscalls import Sys
from repro.store.staging import STAGE_TAG

from .policy import CheckpointPolicy, SaveInfo, chain_of

COMMIT_MARKER = "COMMIT"
MANIFEST = "manifest.json"
#: suffix of a de-committed (mid-GC) commit marker; its presence without an
#: ``ok`` marker flags the directory as collection-in-progress for the sweep
GC_TAG = ".__gc"


class CheckpointError(RuntimeError):
    pass


def _leaf_name(path) -> str:
    return jax.tree_util.keystr(path)


@dataclass
class _Extent:
    leaf: int  # leaf index
    leaf_off: int  # offset within the leaf byte stream
    shard: int  # shard file id
    shard_off: int  # offset within the shard file
    length: int


def _plan_extents(nbytes_per_leaf: Sequence[int], num_shards: int,
                  chunk_bytes: int) -> Tuple[List[_Extent], List[int]]:
    """Round-robin chunks of all leaves across shard files."""
    extents: List[_Extent] = []
    shard_sizes = [0] * num_shards
    nxt = 0
    for li, n in enumerate(nbytes_per_leaf):
        off = 0
        while off < n:
            ln = min(chunk_bytes, n - off)
            s = nxt % num_shards
            extents.append(_Extent(li, off, s, shard_sizes[s], ln))
            shard_sizes[s] += ln
            off += ln
            nxt += 1
    return extents, shard_sizes


class _LazyBlobs:
    """Per-leaf serialization on first touch, cached.

    The extent plan needs only ``nbytes`` (known without serializing), so
    serialization runs when a write's data thunk fires at pre-issue time —
    the engine serializes leaf *k+1* on the application thread while the
    workers are still writing leaf *k*'s extents.

    With a ``pool``, serialization lands in *leased aligned buffers* (the
    WRITE_FIXED analogue): each leaf is copied once into a registered slab
    and every extent thunk hands out a zero-copy ``memoryview`` slice of
    it, so the save graph's pwrites write straight out of registered —
    and, on a direct-mode device, O_DIRECT-valid — memory instead of a
    fresh ``tobytes`` allocation per leaf.  The caller releases the slabs
    via :meth:`release` once the save graph has drained; leaves the pool
    declines (over-class or at capacity) fall back to ``tobytes``.
    """

    def __init__(self, arrays: Sequence[np.ndarray],
                 pool: Optional[BufferPool] = None, alignment: int = 0):
        self.arrays = arrays
        self.pool = pool
        self.alignment = alignment
        self._blobs: Dict[int, Any] = {}
        self._leases: List[Any] = []

    def __getitem__(self, i: int):
        b = self._blobs.get(i)
        if b is None:
            a = self.arrays[i]
            lease = (self.pool.lease(a.nbytes, alignment=self.alignment)
                     if self.pool is not None else None)
            if lease is not None:
                mv = lease.mv[: a.nbytes]
                try:
                    mv[:] = memoryview(np.ascontiguousarray(a)).cast("B")
                except (TypeError, ValueError):
                    mv[:] = a.tobytes()
                lease.filled(a.nbytes)
                self._leases.append(lease)
                b = self._blobs[i] = mv
            else:
                b = self._blobs[i] = a.tobytes()
        return b

    def release(self) -> None:
        """Return the leased slabs to the pool.  Must run only after every
        consumer is done with the views (the save session has drained) —
        the slabs recycle immediately."""
        leases, self._leases = self._leases, []
        self._blobs.clear()
        for lease in leases:
            lease.release()

    def __len__(self) -> int:
        return len(self.arrays)


def build_save_graph(num_shards: int, num_extents: int,
                     name: str) -> ForeactionGraph:
    """The full checkpoint-save chain as one foreaction graph.

    Shape depends only on (num_shards, num_extents); which shard each
    extent targets, the data thunks, and the paths come from ctx::

        ctx = {"paths": [shard paths], "writes": [(shard, thunk, off)],
               "per_shard": [extent count per shard],
               "manifest_path": str, "manifest_bytes": ()->bytes,
               "marker_path": str}

    Node order mirrors the serial save exactly: S creating opens, E extent
    pwrites, S fsyncs, S closes, then the manifest chain, then the commit
    marker chain.  All edges are strong (a started save is guaranteed), so
    opens and data writes pre-issue in one sweep — the writes take their fd
    as ``FromNode`` of their shard's open, which is what lets them enter
    the queue before any open completes.  The fsync of shard *s* is
    harvest-gated on shard *s*'s writes and each close on its fsync; the
    marker chain is gated on every shard close plus the manifest close, so
    the commit marker is published strictly last even though everything
    before it overlapped.
    """
    b = GraphBuilder(name)

    def _fd_of(ctx, s: int):
        """This shard's fd: the harvested value once the frontier served the
        open, else a FromNode deferred to the pre-issued open request.  The
        fallback matters at the chain head — the very first open is served
        at the frontier (never pre-issued), so nodes depending on it can
        only bind through ctx."""
        fds = ctx.get("fds", ())
        return fds[s] if s in fds else FromNode(f"open{s}")

    def _open(s: int):
        def args(ctx, ep):
            return ((ctx["paths"][s], "w"), False)

        def save(ctx, ep, rc):
            ctx.setdefault("fds", {})[s] = rc

        return args, save

    def _write(j: int):
        def args(ctx, ep):
            s, thunk, off = ctx["writes"][j]
            return ((_fd_of(ctx, s), thunk(), off), False)

        def save(ctx, ep, rc):
            s, _thunk, _off = ctx["writes"][j]
            done = ctx.setdefault("_w_done", [0] * len(ctx["paths"]))
            done[s] += 1
            ctx["_w_total"] = ctx.get("_w_total", 0) + 1

        return args, save

    def _fsync(s: int):
        def args(ctx, ep):
            done = ctx.get("_w_done", [0] * len(ctx["paths"]))
            if done[s] < ctx["per_shard"][s]:
                return None  # harvest barrier: this shard's writes first
            return ((_fd_of(ctx, s),), False)

        def save(ctx, ep, rc):
            ctx.setdefault("_synced", set()).add(s)

        return args, save

    def _close(s: int):
        def args(ctx, ep):
            if s not in ctx.get("_synced", ()):
                return None
            return ((_fd_of(ctx, s),), False)

        def save(ctx, ep, rc):
            ctx["_closed"] = ctx.get("_closed", 0) + 1

        return args, save

    num = [0]

    def chain(nm, sc, args, save=None):
        b.AddSyscallNode(nm, sc, args, save)
        if num[0]:
            b.SyscallSetNext(prev[0], nm)
        prev[0] = nm
        num[0] += 1

    prev = [None]
    for s in range(num_shards):
        a, sv = _open(s)
        chain(f"open{s}", Sys.OPEN, a, sv)
    for j in range(num_extents):
        a, sv = _write(j)
        chain(f"w{j}", Sys.PWRITE, a, sv)
    for s in range(num_shards):
        a, sv = _fsync(s)
        chain(f"sync{s}", Sys.FSYNC, a, sv)
    for s in range(num_shards):
        a, sv = _close(s)
        chain(f"close{s}", Sys.CLOSE, a, sv)

    # manifest chain: content is ready once every extent write is harvested
    def m_open_args(ctx, ep):
        return ((ctx["manifest_path"], "w"), False)

    def _mfd(ctx):
        return ctx["mfd"] if "mfd" in ctx else FromNode("open_m")

    def _cfd(ctx):
        return ctx["cfd"] if "cfd" in ctx else FromNode("open_c")

    def m_write_args(ctx, ep):
        if ctx.get("_w_total", 0) < len(ctx["writes"]):
            return None
        return ((_mfd(ctx), ctx["manifest_bytes"](), 0), False)

    def m_write_save(ctx, ep, rc):
        ctx["_m_written"] = True

    def m_sync_args(ctx, ep):
        if not ctx.get("_m_written"):
            return None
        return ((_mfd(ctx),), False)

    def m_sync_save(ctx, ep, rc):
        ctx["_m_synced"] = True

    def m_close_args(ctx, ep):
        if not ctx.get("_m_synced"):
            return None
        return ((_mfd(ctx),), False)

    def m_close_save(ctx, ep, rc):
        ctx["_m_closed"] = True

    chain("open_m", Sys.OPEN, m_open_args,
          lambda ctx, ep, rc: ctx.__setitem__("mfd", rc))
    chain("w_m", Sys.PWRITE, m_write_args, m_write_save)
    chain("sync_m", Sys.FSYNC, m_sync_args, m_sync_save)
    chain("close_m", Sys.CLOSE, m_close_args, m_close_save)

    # commit-marker chain: gated on every shard close + the manifest close,
    # so the marker publishes strictly last (the atomic-commit invariant)
    def c_open_args(ctx, ep):
        if ctx.get("_closed", 0) < len(ctx["paths"]) or not ctx.get("_m_closed"):
            return None
        return ((ctx["marker_path"], "w"), False)

    def c_write_args(ctx, ep):
        return ((_cfd(ctx), b"ok", 0), False)

    def c_write_save(ctx, ep, rc):
        ctx["_c_written"] = True

    def c_sync_args(ctx, ep):
        if not ctx.get("_c_written"):
            return None
        return ((_cfd(ctx),), False)

    def c_sync_save(ctx, ep, rc):
        ctx["_c_synced"] = True

    def c_close_args(ctx, ep):
        if not ctx.get("_c_synced"):
            return None
        return ((_cfd(ctx),), False)

    chain("open_c", Sys.OPEN, c_open_args,
          lambda ctx, ep, rc: ctx.__setitem__("cfd", rc))
    chain("w_c", Sys.PWRITE, c_write_args, c_write_save)
    chain("sync_c", Sys.FSYNC, c_sync_args, c_sync_save)
    chain("close_c", Sys.CLOSE, c_close_args)
    b.SyscallSetNext("close_c", None)
    return b.Build()


def build_gc_graph(name: str = "ckpt_gc") -> ForeactionGraph:
    """Collect one superseded checkpoint directory, crash-safely.

    ctx: ``{"marker": str, "tomb": str, "victims": [str]}``.

    Protocol (forward-only; every intermediate state is safe):

    1. ``rename(marker -> tomb)`` — the *tombstone rename*.  Moving the
       commit marker aside atomically de-commits the directory: discovery
       (:meth:`CheckpointManager.committed_steps`) requires the marker at
       its canonical name, so ``restore_latest`` can never pick a directory
       whose files are about to disappear.  The rename is *undoable*
       (:meth:`repro.store.staging.StagingTxn.stage_rename`): an abort
       before the commit point below renames it back and the checkpoint
       stays fully live.
    2. The wrapped function then calls
       :meth:`repro.store.staging.StagingTxn.publish_demanded` — the point
       of no return.  From here the tombstone survives any abort.
    3. Unlink every file, the tombstone last.  Unlinks are barriers and
       gated on the tombstone rename being harvested (``_tomb_done``), so
       speculation can never delete a byte of a still-committed checkpoint;
       past the gate the whole victim list pre-issues as one batch and fans
       out across sub-devices.

    A crash anywhere mid-protocol leaves either a fully live checkpoint
    (before the commit point) or a tombstoned, partially emptied directory
    that discovery skips and the next GC pass sweeps to completion
    (:meth:`CheckpointManager.gc`).
    """
    b = GraphBuilder(name)

    def r_args(ctx, ep):
        return ((ctx["marker"], ctx["tomb"]), False)

    def r_save(ctx, ep, rc):
        ctx["_tomb_done"] = True

    def u_args(ctx, ep):
        if not ctx.get("_tomb_done"):
            return None  # harvest barrier: de-commit before any deletion
        vs = ctx["victims"]
        return ((vs[ep[0]],), False) if ep[0] < len(vs) else None

    def head(ctx, ep):
        return 0 if len(ctx["victims"]) > 0 else 1

    def more(ctx, ep):
        return 0 if ep[0] + 1 < len(ctx["victims"]) else 1

    b.AddSyscallNode("tomb", Sys.RENAME, r_args, r_save)
    b.AddBranchingNode("any", head)
    b.AddSyscallNode("unlink", Sys.UNLINK, u_args)
    b.AddBranchingNode("more", more)
    b.SetStart("tomb")
    b.SyscallSetNext("tomb", "any")
    b.BranchAppendChild("any", "unlink")
    b.BranchAppendChild("any", None)
    b.SyscallSetNext("unlink", "more")
    b.BranchAppendChild("more", "unlink", loopback=True)
    b.BranchAppendChild("more", None)
    return b.Build()


class CheckpointManager:
    """Save/restore pytrees of arrays under ``root`` on a Device.

    Directory layout::

        root/step_{N:010d}/shard_{i:04d}.bin
        root/step_{N:010d}/manifest.json
        root/step_{N:010d}/COMMIT          (written last: atomic commit)
    """

    def __init__(
        self,
        device: Device,
        root: str,
        fa: Optional[Foreactor] = None,
        num_shards: int = 16,
        chunk_bytes: int = 4 << 20,
        keep: int = 3,
        policy: Optional[CheckpointPolicy] = None,
        max_delta_chain: int = 8,
    ):
        self.device = device
        self.root = root.rstrip("/")
        self.num_shards = num_shards
        self.chunk_bytes = chunk_bytes
        #: retention: ``policy`` wins; the legacy ``keep`` int is sugar for
        #: CheckpointPolicy(keep_last=keep)
        self.policy = policy if policy is not None \
            else CheckpointPolicy(keep_last=keep)
        self.keep = self.policy.keep_last
        #: a delta save whose base chain is already this deep falls back to
        #: a full save (restore cost and failure blast radius stay bounded)
        self.max_delta_chain = max_delta_chain
        self.fa = fa if fa is not None else Foreactor(device=device, depth=32)
        #: registered slabs for leaf serialization (the WRITE_FIXED
        #: analogue): save graphs write out of leased aligned buffers
        #: instead of a fresh tobytes() per leaf; alignment follows the
        #: device's direct-I/O block size (0 on buffered devices)
        self.save_pool = BufferPool()
        register_patterns(self.fa)
        self.fa.register("ckpt_gc", build_gc_graph)
        self._async_thread: Optional[threading.Thread] = None
        self._async_error: Optional[BaseException] = None
        # serializes save_async/wait_pending: starting a second background
        # save MUST join-or-raise the first (losing its error or orphaning
        # its thread would silently drop a checkpoint)
        self._async_lock = threading.Lock()
        # highest wall_time ever committed (lazily recovered from on-disk
        # manifests); stored wall times are clamped to >= this floor so a
        # backwards system-clock step between saves cannot produce a
        # non-monotone committed history
        self._wall_floor: Optional[float] = None

    # -- paths ----------------------------------------------------------------
    def step_dir(self, step: int) -> str:
        return f"{self.root}/step_{step:010d}"

    def _shard_path(self, step: int, i: int) -> str:
        # place() pins shard file i to sub-device i % N on a ShardedDevice
        # (identity on flat devices), spreading restore/save I/O across
        # every available queue pair.
        return self.device.place(f"{self.step_dir(step)}/shard_{i:04d}.bin", hint=i)

    def _tombstone_path(self, step: int) -> str:
        """The mid-GC name of a step's commit marker.  On a sharded device
        it is pinned to the marker's own sub-device (like staged names are)
        so the tombstone rename stays a single atomic same-shard rename."""
        marker = f"{self.step_dir(step)}/{COMMIT_MARKER}"
        if isinstance(self.device, ShardedDevice):
            shard, sub = self.device.resolve(marker)
            return f"shard{shard}:{sub}{GC_TAG}"
        return marker + GC_TAG

    # -- save -------------------------------------------------------------------
    def save(self, step: int, tree: Any, extra: Optional[Dict[str, Any]] = None,
             delta: bool = False) -> None:
        """Write one committed checkpoint step as a single foreaction write
        graph (:func:`build_save_graph`): staged shard creates, pipelined
        leaf serialization, pre-issued extent writes, fsync/close harvest
        barriers, commit marker published strictly last.  Aborting mid-save
        rolls the staged files back — no trace in the committed namespace.

        ``delta=True`` writes an *incremental* checkpoint: every extent of
        the (identically chunked) tree is hashed against the effective
        per-extent CRCs of the newest committed chain, and only changed
        extents are written, packed densely into this step's shard files;
        the manifest records ``base`` so restore can chain.  Falls back to
        a full save when there is no usable base (nothing committed, leaf
        spec changed, chain too deep, or the base predates per-extent
        CRCs).  Each save is followed by a policy-driven GC pass
        (:meth:`gc`).
        """
        leaves_kp, treedef = jax.tree_util.tree_flatten_with_path(tree)
        names = [_leaf_name(kp) for kp, _ in leaves_kp]
        arrays = [np.asarray(v) for _, v in leaves_kp]
        blobs = _LazyBlobs(arrays, pool=self.save_pool,
                           alignment=_pool_alignment(self.device))
        if step in self.committed_steps():
            # re-saving a committed step (e.g. an emergency save landing on
            # the step a periodic save already wrote) must not overwrite it
            # in place: publish renames land file-by-file, so a crash
            # mid-resave would leave a directory whose stale ``ok`` marker
            # vouches for mixed old/new bytes.  De-commit and collect the
            # old directory first — a crash now leaves an uncommitted
            # partial that discovery skips, and restore falls back to the
            # previous committed step.
            self._collect(step)
        extents, shard_sizes = _plan_extents([a.nbytes for a in arrays],
                                             self.num_shards, self.chunk_bytes)
        base_step: Optional[int] = None
        if delta:
            base_map = self._delta_base(names, arrays)
            if base_map is not None:
                base_step, chain_crcs = base_map
                dsizes = [0] * self.num_shards
                changed: List[Tuple[_Extent, int]] = []
                for e in extents:
                    crc = zlib.crc32(
                        blobs[e.leaf][e.leaf_off : e.leaf_off + e.length])
                    if chain_crcs.get((names[e.leaf], e.leaf_off, e.length)) == crc:
                        continue
                    ne = _Extent(e.leaf, e.leaf_off, e.shard,
                                 dsizes[e.shard], e.length)
                    dsizes[e.shard] += e.length
                    changed.append((ne, crc))
                extents = [e for e, _ in changed]
                shard_sizes = dsizes
                ext_crcs: Optional[List[int]] = [c for _, c in changed]
            else:
                delta = False
        if not delta:
            ext_crcs = None  # full save: extent CRCs computed lazily below
        d = self.step_dir(step)
        paths = [self._shard_path(step, i) for i in range(self.num_shards)]
        per_shard = [0] * self.num_shards
        for e in extents:
            per_shard[e.shard] += 1
        writes: List[Tuple[int, Callable[[], bytes], int]] = [
            (e.shard,
             (lambda e=e: blobs[e.leaf][e.leaf_off : e.leaf_off + e.length]),
             e.shard_off)
            for e in extents
        ]
        manifest_cache: Dict[str, bytes] = {}
        # stamp the wall time eagerly (not inside the lazily-evaluated
        # manifest closure) and clamp it against the committed floor:
        # retention anchoring orders history by wall_time, so a clock that
        # steps backwards must not make a later step look older
        wall_time = max(time.time(), self._wall_time_floor())

        def manifest_bytes() -> bytes:
            data = manifest_cache.get("data")
            if data is None:
                crcs = ext_crcs if ext_crcs is not None else [
                    zlib.crc32(blobs[e.leaf][e.leaf_off : e.leaf_off + e.length])
                    for e in extents
                ]
                manifest = {
                    "step": step,
                    "num_shards": self.num_shards,
                    "shard_sizes": shard_sizes,
                    "wall_time": wall_time,
                    "kind": "delta" if base_step is not None else "full",
                    "base": base_step,
                    "leaves": [
                        {
                            "name": names[i],
                            "dtype": str(arrays[i].dtype),
                            "shape": list(arrays[i].shape),
                            "nbytes": arrays[i].nbytes,
                            "crc32": zlib.crc32(blobs[i]),
                        }
                        for i in range(len(arrays))
                    ],
                    "extents": [
                        [e.leaf, e.leaf_off, e.shard, e.shard_off, e.length, c]
                        for e, c in zip(extents, crcs)
                    ],
                    "extra": extra or {},
                }
                data = manifest_cache["data"] = json.dumps(manifest).encode()
            return data

        # register is an idempotent builder assignment; the built graph and
        # its compiled plan are cached by name/(graph, depth-mode), so every
        # save after the first of a given shape costs two dict probes
        graph_name = f"ckpt_save_s{self.num_shards}_e{len(extents)}"
        self.fa.register(
            graph_name,
            lambda S=self.num_shards, E=len(extents), n=graph_name:
                build_save_graph(S, E, n))
        self.fa.plan(graph_name)

        def capture():
            return {
                "paths": paths,
                "writes": writes,
                "per_shard": per_shard,
                "manifest_path": f"{d}/{MANIFEST}",
                "manifest_bytes": manifest_bytes,
                "marker_path": f"{d}/{COMMIT_MARKER}",
            }

        @self.fa.wrap(graph_name, capture)
        def _save_all():
            fds = [io.open(self.device, p, "w") for p in paths]
            for s, thunk, off in writes:
                io.pwrite(self.device, fds[s], thunk(), off)
            for fd in fds:
                io.fsync(self.device, fd)
            for fd in fds:
                io.close(self.device, fd)
            mf = io.open(self.device, f"{d}/{MANIFEST}", "w")
            io.pwrite(self.device, mf, manifest_bytes(), 0)
            io.fsync(self.device, mf)
            io.close(self.device, mf)
            # atomic commit: the marker is written (and published) last
            cf = io.open(self.device, f"{d}/{COMMIT_MARKER}", "w")
            io.pwrite(self.device, cf, b"ok", 0)
            io.fsync(self.device, cf)
            io.close(self.device, cf)

        try:
            _save_all()
        finally:
            # the wrapped session has drained (or rolled back): no worker
            # still reads the leased slabs, so they recycle now
            blobs.release()
        self._wall_floor = wall_time
        self.gc()

    def save_async(self, step: int, tree: Any, extra: Optional[Dict[str, Any]] = None,
                   delta: bool = False) -> None:
        """Write-behind checkpointing: snapshot to host memory now, run the
        (speculated) write graph on a background thread, overlap with step
        compute.  Join-or-raise semantics: if a previous background save is
        still running it is joined first, and if it failed its error is
        raised *here* — a second call can never silently orphan an
        in-flight save or swallow its failure."""
        with self._async_lock:
            self._join_pending_locked()
            # snapshot to host memory synchronously; write in the background
            tree = jax.tree_util.tree_map(np.asarray, tree)

            def run():
                try:
                    self.save(step, tree, extra, delta=delta)
                except BaseException as e:  # surfaced on next wait_pending()
                    self._async_error = e

            self._async_thread = threading.Thread(
                target=run, name=f"ckpt-save-{step}", daemon=True)
            self._async_thread.start()

    def wait_pending(self) -> None:
        with self._async_lock:
            self._join_pending_locked()

    def _join_pending_locked(self) -> None:
        if self._async_thread is not None:
            self._async_thread.join()
            self._async_thread = None
        if self._async_error is not None:
            e, self._async_error = self._async_error, None
            raise CheckpointError(f"async checkpoint save failed: {e!r}") from e

    # -- discovery / validation ---------------------------------------------------
    def committed_steps(self) -> List[int]:
        """Steps with a readable ``ok`` commit marker, sorted ascending.

        Everything else is skipped, never raised on: directories without a
        marker (a killed save's partial output, or a mid-GC directory whose
        marker was renamed to its tombstone), markers with other content
        (legacy ``gc`` tombstones), entries that do not parse as a step
        number (staged debris), and per-entry I/O errors.  This is the
        load-bearing half of the atomic-commit invariant — a partial
        directory must never shadow the real latest checkpoint."""
        try:
            entries = io.getdents(self.device, self.root)
        except FileNotFoundError:
            return []
        steps = []
        for e in entries:
            if not e.startswith("step_"):
                continue
            try:
                step = int(e[len("step_"):])
            except ValueError:
                continue
            marker = f"{self.root}/{e}/{COMMIT_MARKER}"
            fd = None
            try:
                fd = io.open(self.device, marker, "r")
                ok = io.pread(self.device, fd, 2, 0) == b"ok"
            except (FileNotFoundError, OSError):
                ok = False
            finally:
                if fd is not None:
                    try:
                        io.close(self.device, fd)
                    except OSError:
                        pass
            if ok:
                steps.append(step)
        return sorted(steps)

    def latest_step(self) -> Optional[int]:
        s = self.committed_steps()
        return s[-1] if s else None

    def read_manifest(self, step: int) -> Dict[str, Any]:
        p = f"{self.step_dir(step)}/{MANIFEST}"
        st = io.fstatat(self.device, p)
        fd = io.open(self.device, p, "r")
        data = io.pread(self.device, fd, st.st_size, 0)
        io.close(self.device, fd)
        return json.loads(data)

    def _manifest_chain(self, step: int) -> List[Dict[str, Any]]:
        """Manifests of ``step``'s delta chain, base-first (a full save is a
        chain of one).  Raises :class:`CheckpointError` on a cycle or an
        over-deep chain; a missing base manifest surfaces as the underlying
        FileNotFoundError (both make ``restore_latest`` fall back)."""
        ms = [self.read_manifest(step)]
        seen = {step}
        while ms[0].get("base") is not None:
            b = ms[0]["base"]
            if b in seen or len(ms) > 64:
                raise CheckpointError(
                    f"delta chain at step {step} is cyclic or too deep")
            seen.add(b)
            ms.insert(0, self.read_manifest(b))
        return ms

    def history(self) -> List[SaveInfo]:
        """The committed save history, rebuilt from manifests — the pure
        input :meth:`repro.checkpoint.policy.CheckpointPolicy.keep_steps`
        consumes.  No in-memory retention state exists to lose in a crash."""
        out: List[SaveInfo] = []
        for step in self.committed_steps():
            try:
                m = self.read_manifest(step)
            except (FileNotFoundError, OSError, ValueError):
                continue
            out.append(SaveInfo(step=step,
                                wall_time=float(m.get("wall_time", step)),
                                kind=m.get("kind", "full"),
                                base=m.get("base")))
        return out

    def _wall_time_floor(self) -> float:
        """Highest ``wall_time`` across committed manifests (0.0 when none),
        cached after the first scan and advanced on every successful commit.
        :meth:`save` clamps the stamped wall time to this floor, so the
        history handed to the retention policy is non-decreasing in step
        order even across process restarts and backwards clock steps."""
        if self._wall_floor is None:
            self._wall_floor = max(
                (info.wall_time for info in self.history()), default=0.0)
        return self._wall_floor

    def _delta_base(self, names: List[str], arrays: List[np.ndarray],
                    ) -> Optional[Tuple[int, Dict[Tuple[str, int, int], int]]]:
        """(base step, effective per-extent CRC map) for a delta save, or
        None when no committed chain can serve as base: nothing committed,
        the leaf spec changed, the chain is at ``max_delta_chain``, or the
        base predates per-extent CRCs."""
        base_step = self.latest_step()
        if base_step is None:
            return None
        try:
            ms = self._manifest_chain(base_step)
        except (CheckpointError, FileNotFoundError, OSError, ValueError):
            return None
        if len(ms) >= self.max_delta_chain:
            return None
        top = ms[-1]
        spec = [(lf["name"], lf["dtype"], tuple(lf["shape"]))
                for lf in top["leaves"]]
        ours = [(names[i], str(arrays[i].dtype), tuple(arrays[i].shape))
                for i in range(len(names))]
        if spec != ours:
            return None
        crcs: Dict[Tuple[str, int, int], int] = {}
        for m in ms:  # base-first: newer chain members overlay older CRCs
            lnames = [lf["name"] for lf in m["leaves"]]
            for e in m["extents"]:
                if len(e) < 6:
                    return None  # pre-delta manifest: no per-extent CRCs
                li, loff, _s, _soff, ln, crc = e[:6]
                crcs[(lnames[li], loff, ln)] = crc
        return base_step, crcs

    def validate(self, step: int) -> bool:
        """du-shaped parallel fstat over every shard file of every chain
        member; size check.  A delta checkpoint is only as valid as its
        whole chain — a collected or torn base invalidates the delta."""
        try:
            ms = self._manifest_chain(step)
        except (CheckpointError, FileNotFoundError, OSError, ValueError):
            return False

        @self.fa.wrap("stat_list", lambda paths: {"paths": paths})
        def _stat_all(paths):
            return [io.fstatat(self.device, p) for p in paths]

        for m in ms:
            paths = [self._shard_path(m["step"], i)
                     for i in range(m["num_shards"])]
            try:
                stats = _stat_all(paths)
            except FileNotFoundError:
                return False
            if not all(st.st_size == sz
                       for st, sz in zip(stats, m["shard_sizes"])):
                return False
        return True

    # -- restore ---------------------------------------------------------------------
    def _read_step_into(self, m: Dict[str, Any],
                        bufs: Dict[str, bytearray]) -> None:
        """Overlay one chain member's extents into the per-leaf buffers
        (parallel open + chunked pread graphs, as before)."""
        step = m["step"]
        paths = [self._shard_path(step, i) for i in range(m["num_shards"])]

        # read-only opens are pure -> pre-issued as one batch; on a sharded
        # device they fan out to their owning sub-devices in parallel
        @self.fa.wrap("open_list", lambda paths: {"paths": paths})
        def _open_all(paths):
            return [io.open(self.device, p, "r") for p in paths]

        fds = _open_all(paths)
        extents = [_Extent(*e[:5]) for e in m["extents"]]
        # group by owning shard: the round-robin extent plan interleaves
        # shards in manifest order, but within one shard file the extents
        # are densely packed at ascending shard_off.  Sorting by
        # (shard, shard_off) exposes exactly the statically-adjacent
        # same-fd runs the I/O plane's extent coalescer fuses into
        # super-reads, and keeps whole runs on one lane of a multi-queue
        # backend; the overlay below follows the same order, so restored
        # bytes are identical either way.
        extents.sort(key=lambda e: (e.shard, e.shard_off))
        ext_args = [(fds[e.shard], e.length, e.shard_off) for e in extents]

        @self.fa.wrap("pread_extents", lambda extents: {"extents": extents})
        def _read_all(extents):
            return [io.pread(self.device, fd, n, off) for fd, n, off in extents]

        chunks = _read_all(ext_args)
        for fd in fds:
            io.close(self.device, fd)
        lnames = [lf["name"] for lf in m["leaves"]]
        for e, c in zip(extents, chunks):
            if len(c) != e.length:
                raise CheckpointError(
                    f"short read: shard {e.shard} off {e.shard_off}: "
                    f"{len(c)} != {e.length}")
            buf = bufs.get(lnames[e.leaf])
            if buf is None:
                raise CheckpointError(
                    f"chain member {step} has unknown leaf {lnames[e.leaf]}")
            buf[e.leaf_off : e.leaf_off + e.length] = c

    def restore(self, step: int, check_crc: bool = True) -> Tuple[Any, Dict[str, Any]]:
        """Parallel chunked restore -> (flat {name: np.ndarray}, extra).

        A delta checkpoint restores by chaining: the rooting full save is
        read first, then each delta overlays its changed extents base-first.
        The final per-leaf CRC check comes from the *top* manifest, so a
        chained restore is verified byte-identical to what the delta save
        hashed — corruption anywhere in the chain fails the restore (and
        ``restore_latest`` falls back to an older step)."""
        ms = self._manifest_chain(step)
        top = ms[-1]
        bufs: Dict[str, bytearray] = {
            leaf["name"]: bytearray(leaf["nbytes"]) for leaf in top["leaves"]}
        for m in ms:
            self._read_step_into(m, bufs)
        out: Dict[str, np.ndarray] = {}
        for leaf in top["leaves"]:
            buf = bufs[leaf["name"]]
            if check_crc and zlib.crc32(bytes(buf)) != leaf["crc32"]:
                raise CheckpointError(f"crc mismatch for leaf {leaf['name']}")
            out[leaf["name"]] = np.frombuffer(
                bytes(buf), dtype=leaf["dtype"]).reshape(leaf["shape"])
        return out, top["extra"]

    def restore_tree(self, step: int, like: Any, check_crc: bool = True) -> Tuple[Any, Dict[str, Any]]:
        """Restore into the structure of ``like`` (names must match)."""
        flat, extra = self.restore(step, check_crc=check_crc)
        leaves_kp, treedef = jax.tree_util.tree_flatten_with_path(like)
        leaves = []
        for kp, proto in leaves_kp:
            name = _leaf_name(kp)
            if name not in flat:
                raise CheckpointError(f"checkpoint missing leaf {name}")
            arr = flat[name]
            proto_shape = tuple(getattr(proto, "shape", ()) or ())
            if proto_shape and tuple(arr.shape) != proto_shape:
                raise CheckpointError(
                    f"shape mismatch for {name}: ckpt {arr.shape} vs model {proto_shape}")
            leaves.append(arr)
        return jax.tree_util.tree_unflatten(treedef, leaves), extra

    def restore_latest(self, like: Any = None) -> Optional[Tuple[int, Any, Dict[str, Any]]]:
        """Newest committed checkpoint that validates; falls back past
        corrupt ones (node-failure recovery path)."""
        for step in reversed(self.committed_steps()):
            try:
                if not self.validate(step):
                    continue
                if like is None:
                    tree, extra = self.restore(step)
                else:
                    tree, extra = self.restore_tree(step, like)
                return step, tree, extra
            except (CheckpointError, FileNotFoundError):
                continue
        return None

    # -- replication ---------------------------------------------------------------
    def replicate(self, step: int, dst: "CheckpointManager") -> None:
        """Copy a committed checkpoint to another tier via Link'ed
        pread->pwrite chains (the cp graph at framework scale).  A delta
        checkpoint replicates its whole chain — a delta without its base is
        unrestorable, so the chain is the unit of replication just as it is
        the unit of retention."""
        for m in self._manifest_chain(step):
            self._replicate_one(m, dst)

    def _replicate_one(self, m: Dict[str, Any], dst: "CheckpointManager") -> None:
        step = m["step"]
        pairs = []
        closers = []
        for i in range(m["num_shards"]):
            sfd = io.open(self.device, self._shard_path(step, i), "r")
            dfd = io.open(dst.device, dst._shard_path(step, i), "w")
            closers.append((sfd, dfd))
            size = m["shard_sizes"][i]
            off = 0
            while off < size or (size == 0 and off == 0):
                n = min(self.chunk_bytes, size - off)
                if n > 0:
                    pairs.append((sfd, dfd, n, off))
                off += max(n, 1)
                if size == 0:
                    break

        # NOTE: source and destination may be different Devices; the copy
        # graph runs on the source's engine, writes go to dst.device through
        # a device-dispatching session only when devices match.  For
        # cross-device replication we fall back to chunked read->write.
        if dst.device is self.device:
            @self.fa.wrap("copy_extents", lambda pairs: {"pairs": pairs})
            def _copy_all(pairs):
                for sfd, dfd, n, off in pairs:
                    data = io.pread(self.device, sfd, n, off)
                    io.pwrite(self.device, dfd, data, off)
            _copy_all(pairs)
        else:
            for sfd, dfd, n, off in pairs:
                data = io.pread(self.device, sfd, n, off)
                io.pwrite(dst.device, dfd, data, off)
        for sfd, dfd in closers:
            io.close(self.device, sfd)
            io.fsync(dst.device, dfd)
            io.close(dst.device, dfd)
        # manifest + commit marker on the destination
        mf = io.open(dst.device, f"{dst.step_dir(step)}/{MANIFEST}", "w")
        io.pwrite(dst.device, mf, json.dumps(m).encode(), 0)
        io.close(dst.device, mf)
        cf = io.open(dst.device, f"{dst.step_dir(step)}/{COMMIT_MARKER}", "w")
        io.pwrite(dst.device, cf, b"ok", 0)
        io.close(dst.device, cf)

    # -- gc ---------------------------------------------------------------------------
    def gc(self) -> None:
        """Policy-driven garbage collection, run after every save.

        The keep-set is :meth:`CheckpointPolicy.keep_steps` over the
        manifest-derived history, always including the newest committed
        step (and, via chain closure, everything it transitively bases on):
        a store that collects the checkpoint it just wrote is useless.
        Victims are collected newest-first so a delta is always gone before
        its base starts being collected — a crash between the two leaves a
        base that is merely unreferenced, never a committed delta with a
        hole under it.  A final sweep finishes any collection a previous
        crash left mid-protocol (tombstone present, marker absent) and
        legacy ``gc``-marker tombstones."""
        committed = self.committed_steps()
        if committed:
            history = self.history()
            by_step = {s.step: s for s in history}
            keep = set(self.policy.keep_steps(history))
            keep.add(committed[-1])
            keep.update(chain_of(committed[-1], by_step))
            for s in sorted((s for s in committed if s not in keep),
                            reverse=True):
                self._collect(s)
        self._sweep()

    def _collect(self, step: int) -> None:
        """Collect one committed step via the GC foreaction graph
        (:func:`build_gc_graph`): tombstone rename, hard commit point,
        then batched unlinks with the tombstone last."""
        d = self.step_dir(step)
        marker = f"{d}/{COMMIT_MARKER}"
        tomb = self._tombstone_path(step)
        try:
            nshards = self.read_manifest(step)["num_shards"]
        except (FileNotFoundError, OSError, ValueError):
            nshards = self.num_shards
        victims = [self._shard_path(step, i) for i in range(nshards)]
        victims.append(f"{d}/{MANIFEST}")
        victims.append(tomb)  # last: its absence means the GC completed

        @self.fa.wrap("ckpt_gc", lambda: {"marker": marker, "tomb": tomb,
                                          "victims": victims})
        def _gc_one():
            io.rename(self.device, marker, tomb)
            sess = current_session()
            if sess is not None and getattr(sess, "staging", None) is not None:
                # point of no return: the tombstone rename survives any
                # abort from here on (see build_gc_graph's protocol notes)
                sess.staging.publish_demanded()
            for p in victims:
                io.unlink(self.device, p)

        _gc_one()
        self._rmdir(d)

    def _sweep(self) -> None:
        """Finish crashed collections.  A step directory is GC-pending iff
        it is *not* committed (no readable ``ok`` marker — an ``ok`` marker
        always wins, covering a crashed non-atomic tombstone copy) but
        still carries a marker tombstone or a legacy ``gc`` marker.
        In killed-save debris (no marker at all) only stale *staging
        extents* are reclaimed: a crashed process cannot roll its staged
        files back, and nothing else ever would.  Deleting a staging extent
        out from under a racing save is safe — its publish rename fails and
        the save aborts cleanly, committing nothing (one save per root at a
        time is the supported regime anyway; the manager serializes its
        own)."""
        try:
            entries = io.getdents(self.device, self.root)
        except FileNotFoundError:
            return
        committed = set(self.committed_steps())
        for e in sorted(entries):
            if not e.startswith("step_"):
                continue
            try:
                step = int(e[len("step_"):])
            except ValueError:
                continue
            if step in committed:
                continue
            d = f"{self.root}/{e}"
            try:
                names = io.getdents(self.device, d)
            except FileNotFoundError:
                names = []
            if (COMMIT_MARKER + GC_TAG) not in names \
                    and COMMIT_MARKER not in names:
                staged = [n for n in names if STAGE_TAG in n]
                for n in sorted(staged):
                    try:
                        self.device.unlink(f"{d}/{n}")
                    except (FileNotFoundError, OSError):
                        pass
                if staged and len(staged) == len(names):
                    self._rmdir(d)  # the crash left nothing but residue
                continue
            victims = [f"{d}/{n}" for n in sorted(names)]

            @self.fa.wrap("unlink_list", lambda: {"victims": victims})
            def _sweep_one():
                for p in victims:
                    io.unlink(self.device, p)

            try:
                _sweep_one()
            except (FileNotFoundError, OSError):
                continue  # racing save/GC elsewhere; retried next pass
            self._rmdir(d)

    def _rmdir(self, d: str) -> None:
        # the emptied step directory itself: a real directory on OSDevice
        # (removed through the unlink verb's rmdir path), implicit on
        # mem-backed devices (gone with its last file)
        try:
            self.device.unlink(d)
        except (FileNotFoundError, OSError):
            pass
