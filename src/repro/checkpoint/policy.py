"""Checkpoint retention policy: which saved steps survive garbage collection.

``CheckpointPolicy.keep_steps`` is a *pure* function of the save history —
no filesystem access, no clock reads — so retention decisions are
reproducible from manifests alone and property-testable in isolation
(``tests/test_ckpt_lifecycle.py``).  The manager rebuilds the history from
the committed manifests on every GC pass, which makes the policy crash-safe
by construction: there is no in-memory retention state to lose.

Two retention axes, union'd:

* **keep_last** — the newest N steps by step number (the "resume from the
  latest few" window every trainer needs).
* **keep_spaced** — the newest M *time anchors*.  Anchors are chosen
  greedily oldest-first: the first save is an anchor, and each later save
  is an anchor iff its wall time is at least ``spacing_s`` past the
  previous anchor's.  Prefix-stable by construction (appending a save
  never changes which earlier saves are anchors), which is what makes the
  keep-set monotone: ``keep_steps(h + [x]) ⊆ keep_steps(h) ∪ {x.step}``.

The union is then closed under delta-chain base references: a kept delta
checkpoint keeps its base (transitively, down to the full save that roots
the chain).  A chain is one *retention unit* — GC may drop its newest
members, but never a base a surviving delta still needs.
"""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, FrozenSet, List, Optional, Sequence

__all__ = ["SaveInfo", "CheckpointPolicy", "chain_of"]


@dataclass(frozen=True)
class SaveInfo:
    """One committed save, as recorded in its manifest."""

    step: int
    wall_time: float
    kind: str = "full"  # "full" | "delta"
    base: Optional[int] = None  # delta: the step this delta patches


def chain_of(step: int, by_step: Dict[int, SaveInfo]) -> List[int]:
    """The delta chain rooted under ``step``: ``step`` itself plus every
    transitive base, newest first.  Stops (returning the partial chain) if a
    base is missing from the history — validation, not retention, is the
    layer that rejects broken chains."""
    out: List[int] = []
    cur: Optional[int] = step
    while cur is not None and cur in by_step and cur not in out:
        out.append(cur)
        cur = by_step[cur].base
    return out


@dataclass(frozen=True)
class CheckpointPolicy:
    """keep the last ``keep_last`` steps + the newest ``keep_spaced``
    time-anchors spaced ``spacing_s`` seconds apart, closed under
    delta-chain bases."""

    keep_last: int = 3
    keep_spaced: int = 0
    spacing_s: float = 3600.0

    def __post_init__(self):
        if self.keep_last < 0 or self.keep_spaced < 0:
            raise ValueError("keep_last/keep_spaced must be >= 0")
        if self.spacing_s <= 0:
            raise ValueError("spacing_s must be > 0")

    def anchors(self, history: Sequence[SaveInfo]) -> List[int]:
        """Greedy oldest-first time anchors (all of them, not yet capped at
        keep_spaced).  Prefix-stable: anchors(h) is a prefix-closed function
        of h sorted by step."""
        out: List[int] = []
        last_t: Optional[float] = None
        for s in sorted(history, key=lambda s: s.step):
            if last_t is None or s.wall_time - last_t >= self.spacing_s:
                out.append(s.step)
                last_t = s.wall_time
        return out

    def keep_steps(self, history: Sequence[SaveInfo]) -> FrozenSet[int]:
        """The retained step set for ``history`` (order-insensitive; entries
        are keyed by step and deduplicated, newest entry winning)."""
        by_step: Dict[int, SaveInfo] = {s.step: s for s in history}
        if not by_step:
            return frozenset()
        ordered = sorted(by_step)
        keep = set(ordered[-self.keep_last:] if self.keep_last else [])
        if self.keep_spaced:
            anchors = self.anchors(list(by_step.values()))
            keep.update(anchors[-self.keep_spaced:])
        # close under delta-base references: a kept delta pins its chain
        for step in list(keep):
            keep.update(chain_of(step, by_step))
        return frozenset(keep)
