"""Fault-tolerant sharded checkpointing with foreactor-parallel I/O.

* Save: leaves are packed into N shard files (one per I/O writer — the
  per-host analogue), written through a guaranteed-pwrite foreaction graph,
  then committed atomically (manifest + COMMIT marker last).
* Restore: manifest -> parallel fstat validation (du-shaped graph) ->
  parallel chunked preads (Fig. 4a-shaped graph) -> pytree reassembly.
* Replicate: checkpoint copy between storage tiers via Link'ed pread->pwrite
  chains (cp-shaped graph, Fig. 4b).
* Fault tolerance: corrupt/missing shards are detected by size+crc checks
  and restore falls back to the newest older committed step.
"""

from .manager import CheckpointManager, CheckpointError

__all__ = ["CheckpointManager", "CheckpointError"]
