"""Fault-tolerant sharded checkpointing with foreactor-parallel I/O.

* Save: leaves are packed into N shard files (one per I/O writer — the
  per-host analogue), written through a guaranteed-pwrite foreaction graph,
  then committed atomically (manifest + COMMIT marker last).
* Restore: manifest -> parallel fstat validation (du-shaped graph) ->
  parallel chunked preads (Fig. 4a-shaped graph) -> pytree reassembly.
* Replicate: checkpoint copy between storage tiers via Link'ed pread->pwrite
  chains (cp-shaped graph, Fig. 4b).
* Fault tolerance: corrupt/missing shards are detected by size+crc checks
  and restore falls back to the newest older committed step.
* Lifecycle: every save is followed by a policy-driven GC pass
  (:class:`repro.checkpoint.policy.CheckpointPolicy`) that collects
  superseded steps through a crash-safe tombstone-rename + unlink
  foreaction graph; ``save(..., delta=True)`` writes only the extents
  whose CRCs changed since the newest committed chain, and restore chains
  base + deltas back to a byte-identical tree.
"""

from .manager import CheckpointManager, CheckpointError, build_gc_graph
from .policy import CheckpointPolicy, SaveInfo, chain_of

__all__ = ["CheckpointManager", "CheckpointError", "CheckpointPolicy",
           "SaveInfo", "build_gc_graph", "chain_of"]
