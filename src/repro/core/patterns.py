"""Reusable foreaction-graph patterns.

The paper's case-study graphs (Fig. 4) reduce to a handful of loop shapes.
The framework's own substrates (data pipeline, checkpointing) instantiate
these generic builders instead of hand-drawing a graph per call site:

* ``build_stat_list_graph``     — fstatat over a path list (du shape, Fig. 4a)
* ``build_open_list_graph``     — read-only open over a path list (pure, so
  pre-issuable even across weak edges; fans shard-file opens across devices)
* ``build_pread_extents_graph`` — pread over (fd, size, offset) extents
* ``build_pwrite_extents_graph``— pwrite over (fd, data|thunk, offset) extents
  (guaranteed writes: strong edges throughout)
* ``build_copy_extents_graph``  — Link'ed pread->pwrite pairs (cp shape, Fig. 4b)
* ``build_write_file_graph``    — create + pwrite loop + fsync + close: the
  whole-file write chain, speculable end to end because the create is
  *undoable* (it lands in a staging extent and publishes at the close
  barrier — repro.store.staging); fsync/close are harvest-gated so the
  barrier never runs ahead of the writes it orders
* ``build_unlink_list_graph`` — unlink over a path list (all-strong, so the
  barrier unlinks batch once entered; the checkpoint GC sweep's shape)

ctx conventions are documented per builder.  Results are harvested into
ctx lists so wrapped functions can also consume them if desired.

Cross-references: docs/ARCHITECTURE.md ("Reusable graph patterns"); the loop
shapes here are the ones the sharded substrate's consumers (checkpoint
manager, data pipeline) fan out across devices.
"""

from __future__ import annotations

from typing import Any, Callable, Dict, List, Optional, Sequence, Tuple

from .graph import ForeactionGraph, FromNode, GraphBuilder
from .syscalls import Sys


def build_stat_list_graph(name: str = "stat_list") -> ForeactionGraph:
    """ctx: {"paths": [str]}; harvests into ctx["stats"] (dict e -> stat)."""
    b = GraphBuilder(name)

    def args(ctx, ep):
        paths = ctx["paths"]
        return ((paths[ep[0]],), False) if ep[0] < len(paths) else None

    def save(ctx, ep, rc):
        ctx.setdefault("stats", {})[ep[0]] = rc

    def head(ctx, ep):
        return 0 if len(ctx["paths"]) > 0 else 1

    def more(ctx, ep):
        return 0 if ep[0] + 1 < len(ctx["paths"]) else 1

    b.AddBranchingNode("any", head)
    b.AddSyscallNode("fstat", Sys.FSTATAT, args, save)
    b.AddBranchingNode("more", more)
    b.SetStart("any")
    b.BranchAppendChild("any", "fstat")
    b.BranchAppendChild("any", None)
    b.SyscallSetNext("fstat", "more")
    b.BranchAppendChild("more", "fstat", loopback=True)
    b.BranchAppendChild("more", None)
    return b.Build()


def build_open_list_graph(name: str = "open_list") -> ForeactionGraph:
    """ctx: {"paths": [str]}; read-only opens, harvested into ctx["fds"]
    (dict epoch -> fd).  open(path, "r") is pure (cancellable via close), so
    the whole list pre-issues in one batch — on a sharded device the opens
    land on their owning sub-devices concurrently."""
    b = GraphBuilder(name)

    def args(ctx, ep):
        paths = ctx["paths"]
        return ((paths[ep[0]], "r"), False) if ep[0] < len(paths) else None

    def save(ctx, ep, rc):
        ctx.setdefault("fds", {})[ep[0]] = rc

    def head(ctx, ep):
        return 0 if len(ctx["paths"]) > 0 else 1

    def more(ctx, ep):
        return 0 if ep[0] + 1 < len(ctx["paths"]) else 1

    b.AddBranchingNode("any", head)
    b.AddSyscallNode("open", Sys.OPEN, args, save)
    b.AddBranchingNode("more", more)
    b.SetStart("any")
    b.BranchAppendChild("any", "open")
    b.BranchAppendChild("any", None)
    b.SyscallSetNext("open", "more")
    b.BranchAppendChild("more", "open", loopback=True)
    b.BranchAppendChild("more", None)
    return b.Build()


def build_pread_extents_graph(name: str = "pread_extents",
                              weak: bool = False) -> ForeactionGraph:
    """ctx: {"extents": [(fd, size, offset)]}; pure read loop.

    ``weak=True`` marks the loop's closing edge weak — the LSM-get shape
    where the caller may return early after any read (still safe to
    pre-issue: reads are pure)."""
    b = GraphBuilder(name)

    def args(ctx, ep):
        ext = ctx["extents"]
        return ((tuple(ext[ep[0]])), False) if ep[0] < len(ext) else None

    def head(ctx, ep):
        return 0 if len(ctx["extents"]) > 0 else 1

    def more(ctx, ep):
        return 0 if ep[0] + 1 < len(ctx["extents"]) else 1

    b.AddBranchingNode("any", head)
    b.AddSyscallNode("pread", Sys.PREAD, args)
    b.AddBranchingNode("more", more)
    b.SetStart("any")
    b.BranchAppendChild("any", "pread")
    b.BranchAppendChild("any", None)
    b.SyscallSetNext("pread", "more", weak=weak)
    b.BranchAppendChild("more", "pread", loopback=True)
    b.BranchAppendChild("more", None)
    return b.Build()


def build_pwrite_extents_graph(name: str = "pwrite_extents") -> ForeactionGraph:
    """ctx: {"writes": [(fd, data|()->data, offset)]}; guaranteed writes.

    ``data`` may be a zero-arg thunk — the Compute annotation materializes
    the bytes at pre-issue time (computation pulled ahead of the frontier,
    §3.2 'any necessary computation required to produce argument values')."""
    b = GraphBuilder(name)

    def args(ctx, ep):
        ws = ctx["writes"]
        if ep[0] >= len(ws):
            return None
        fd, data, off = ws[ep[0]]
        if callable(data):
            data = data()
        return ((fd, data, off), False)

    def head(ctx, ep):
        return 0 if len(ctx["writes"]) > 0 else 1

    def more(ctx, ep):
        return 0 if ep[0] + 1 < len(ctx["writes"]) else 1

    b.AddBranchingNode("any", head)
    b.AddSyscallNode("pwrite", Sys.PWRITE, args)
    b.AddBranchingNode("more", more)
    b.SetStart("any")
    b.BranchAppendChild("any", "pwrite")
    b.BranchAppendChild("any", None)
    b.SyscallSetNext("pwrite", "more")
    b.BranchAppendChild("more", "pwrite", loopback=True)
    b.BranchAppendChild("more", None)
    return b.Build()


def build_write_file_graph(name: str = "write_file") -> ForeactionGraph:
    """ctx: {"path": str, "writes": [(data|()->data, offset)]}.

    One whole-file write chain: ``open(path, "w")`` -> pwrite loop ->
    fsync -> close.  The open's fd is harvested into ``ctx["fd"]``; each
    write may carry a zero-arg thunk so serialization is pulled ahead of
    the frontier.  fsync is not ready until every write is harvested and
    close not until the fsync is — the mined-graph *harvest barrier* idiom
    — so the teardown pair can never overtake the data it orders.  With a
    staging transaction active the create is undoable: the file appears in
    the committed namespace only at the close (publish) barrier.
    """
    b = GraphBuilder(name)

    def open_args(ctx, ep):
        return ((ctx["path"], "w"), False)

    def open_save(ctx, ep, rc):
        ctx["fd"] = rc

    def wargs(ctx, ep):
        ws = ctx["writes"]
        if "fd" not in ctx or ep[0] >= len(ws):
            return None
        data, off = ws[ep[0]]
        if callable(data):
            data = data()
        return ((ctx["fd"], data, off), False)

    def wsave(ctx, ep, rc):
        ctx["_wf_done"] = ctx.get("_wf_done", 0) + 1

    def sync_args(ctx, ep):
        if ctx.get("_wf_done", 0) < len(ctx["writes"]) or "fd" not in ctx:
            return None  # harvest barrier: all writes first
        return ((ctx["fd"],), False)

    def sync_save(ctx, ep, rc):
        ctx["_wf_synced"] = True

    def close_args(ctx, ep):
        if not ctx.get("_wf_synced"):
            return None
        return ((ctx["fd"],), False)

    def head(ctx, ep):
        return 0 if len(ctx["writes"]) > 0 else 1

    def more(ctx, ep):
        return 0 if ep[0] + 1 < len(ctx["writes"]) else 1

    b.AddSyscallNode("open", Sys.OPEN, open_args, open_save)
    b.AddBranchingNode("any", head)
    b.AddSyscallNode("pwrite", Sys.PWRITE, wargs, wsave)
    b.AddBranchingNode("more", more)
    b.AddSyscallNode("fsync", Sys.FSYNC, sync_args, sync_save)
    b.AddSyscallNode("close", Sys.CLOSE, close_args)
    b.SetStart("open")
    b.SyscallSetNext("open", "any")
    b.BranchAppendChild("any", "pwrite")
    b.BranchAppendChild("any", "fsync")
    b.SyscallSetNext("pwrite", "more")
    b.BranchAppendChild("more", "pwrite", loopback=True)
    b.BranchAppendChild("more", "fsync")
    b.SyscallSetNext("fsync", "close")
    b.SyscallSetNext("close", None)
    return b.Build()


def build_copy_extents_graph(name: str = "copy_extents") -> ForeactionGraph:
    """ctx: {"pairs": [(src_fd, dst_fd, size, offset)]}; each iteration is a
    Link'ed pread->pwrite — the write consumes the read's internal buffer
    with no intermediate copy (Fig. 4b)."""
    b = GraphBuilder(name)

    def rargs(ctx, ep):
        ps = ctx["pairs"]
        if ep[0] >= len(ps):
            return None
        sfd, _dfd, size, off = ps[ep[0]]
        return ((sfd, size, off), True)  # Link with the following pwrite

    def wargs(ctx, ep):
        ps = ctx["pairs"]
        if ep[0] >= len(ps):
            return None
        _sfd, dfd, _size, off = ps[ep[0]]
        return ((dfd, FromNode("pread"), off), False)

    def head(ctx, ep):
        return 0 if len(ctx["pairs"]) > 0 else 1

    def more(ctx, ep):
        return 0 if ep[0] + 1 < len(ctx["pairs"]) else 1

    b.AddBranchingNode("any", head)
    b.AddSyscallNode("pread", Sys.PREAD, rargs)
    b.AddSyscallNode("pwrite", Sys.PWRITE, wargs)
    b.AddBranchingNode("more", more)
    b.SetStart("any")
    b.BranchAppendChild("any", "pread")
    b.BranchAppendChild("any", None)
    b.SyscallSetNext("pread", "pwrite")
    b.SyscallSetNext("pwrite", "more")
    b.BranchAppendChild("more", "pread", loopback=True)
    b.BranchAppendChild("more", None)
    return b.Build()


def build_unlink_list_graph(name: str = "unlink_list") -> ForeactionGraph:
    """ctx: {"victims": [str]}; unlink loop over a path list.

    Unlinks are barriers (the removed bytes are unrecoverable), but all
    edges here are strong, so once the loop starts the whole remainder is
    guaranteed and pre-issues as one batch — on a sharded device the
    unlinks fan out to their owning sub-devices.  Callers must order any
    de-commit step (tombstone rename) *before* activating this graph; the
    checkpoint manager's GC sweep is the canonical user."""
    b = GraphBuilder(name)

    def args(ctx, ep):
        vs = ctx["victims"]
        return ((vs[ep[0]],), False) if ep[0] < len(vs) else None

    def head(ctx, ep):
        return 0 if len(ctx["victims"]) > 0 else 1

    def more(ctx, ep):
        return 0 if ep[0] + 1 < len(ctx["victims"]) else 1

    b.AddBranchingNode("any", head)
    b.AddSyscallNode("unlink", Sys.UNLINK, args)
    b.AddBranchingNode("more", more)
    b.SetStart("any")
    b.BranchAppendChild("any", "unlink")
    b.BranchAppendChild("any", None)
    b.SyscallSetNext("unlink", "more")
    b.BranchAppendChild("more", "unlink", loopback=True)
    b.BranchAppendChild("more", None)
    return b.Build()


PATTERNS: Dict[str, Callable[[], ForeactionGraph]] = {
    "stat_list": build_stat_list_graph,
    "open_list": build_open_list_graph,
    "pread_extents": build_pread_extents_graph,
    "pwrite_extents": build_pwrite_extents_graph,
    "write_file": build_write_file_graph,
    "copy_extents": build_copy_extents_graph,
    "unlink_list": build_unlink_list_graph,
}


def register_patterns(fa, precompile: bool = False) -> None:
    """Register the reusable patterns on a Foreactor.

    ``precompile=True`` additionally builds each graph and compiles its
    :class:`repro.core.plan.GraphPlan` now, so the first wrapped call pays
    a cache probe instead of build+lower — consumers with latency-sensitive
    first calls (the serving loop, the data pipeline's first batch) opt in;
    everyone else keeps the paper's lazy build-on-first-activation."""
    for name, builder in PATTERNS.items():
        fa.register(name, builder)
    if precompile:
        for name in PATTERNS:
            fa.plan(name)
