"""Foreactor core: explicit speculation over foreaction graphs.

The paper's contribution (Hu et al., "Foreactor: Exploiting Storage I/O
Parallelism with Explicit Speculation") as a reusable library:

* :mod:`repro.core.graph` — the foreaction graph abstraction (§3.2)
* :mod:`repro.core.engine` — the pre-issuing algorithm (§5.2, Alg. 1)
* :mod:`repro.core.backends` — io_uring-style queue pair & user thread pool (§5.4)
* :mod:`repro.core.device` — real / simulated storage devices (§2.1, Fig. 1)
* :mod:`repro.core.api` — plugin registration + interception surface (§5.1)

The sharded multi-device substrate (``ShardedDevice`` + ``MultiQueueBackend``)
extends the paper's single queue pair to one queue pair per device; see
docs/ARCHITECTURE.md for the full paper-to-module map.
"""

from .api import Foreactor, current_session, io, make_foreactor
from .backends import (
    BACKENDS, MultiQueueBackend, QueuePairBackend, SharedBackend,
    SlotScheduler, SyncBackend, ThreadPoolBackend, make_backend,
)
from .device import (
    Device, DeviceProfile, MemDevice, NVME_PROFILE, OSDevice, REMOTE_PROFILE,
    ShardedDevice, SimulatedDevice,
)
from .engine import DepthController, GraphMismatch, SessionStats, SpecSession
from .graph import BranchNode, ForeactionGraph, GraphBuilder, SyscallNode
from .syscalls import Effect, Sys, effect_of, is_pure
from .trace import Trace, TraceEvent, TraceRecorder

__all__ = [
    "Foreactor", "current_session", "io", "make_foreactor",
    "BACKENDS", "MultiQueueBackend", "QueuePairBackend", "SharedBackend",
    "SlotScheduler", "SyncBackend", "ThreadPoolBackend", "make_backend",
    "Device", "DeviceProfile", "MemDevice", "NVME_PROFILE", "OSDevice",
    "REMOTE_PROFILE", "ShardedDevice", "SimulatedDevice",
    "DepthController", "GraphMismatch", "SessionStats", "SpecSession",
    "BranchNode", "ForeactionGraph", "GraphBuilder", "SyscallNode",
    "Effect", "Sys", "effect_of", "is_pure",
    "Trace", "TraceEvent", "TraceRecorder",
]
