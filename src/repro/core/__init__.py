"""Foreactor core: explicit speculation over foreaction graphs.

The paper's contribution (Hu et al., "Foreactor: Exploiting Storage I/O
Parallelism with Explicit Speculation") as a reusable library:

* :mod:`repro.core.graph` — the foreaction graph abstraction (§3.2)
* :mod:`repro.core.plan` — compiled graph plans: the authoring graph
  lowered once to flat node records the engine interprets (§5.2 fast path)
* :mod:`repro.core.engine` — the pre-issuing algorithm (§5.2, Alg. 1)
* :mod:`repro.core.backends` — the unified I/O plane: one reactor with
  pluggable submission lanes (io_uring queue pair, user thread pool,
  per-device lanes, multi-tenant slot scheduling) (§5.4)
* :mod:`repro.core.buffers` — registered buffer pool leased to PREAD
  requests (io_uring READ_FIXED analogue; Fig. 10 "result copy")
* :mod:`repro.core.device` — real / simulated storage devices (§2.1, Fig. 1)
* :mod:`repro.core.api` — plugin registration + interception surface (§5.1)

The sharded multi-device substrate (``ShardedDevice`` + ``MultiQueueBackend``)
extends the paper's single queue pair to one lane per device; see
docs/ARCHITECTURE.md for the full paper-to-module map.
"""

from .api import Foreactor, current_session, io, make_foreactor
from .backends import (
    BACKENDS, IOPlane, MultiQueueBackend, QueuePairBackend, SharedBackend,
    SlotScheduler, SyncBackend, ThreadPoolBackend, make_backend,
)
from .lanes import SubmissionLane
from .buffers import BufferLease, BufferPool
from .completion import CompletionPool, completion_pool
from .device import (
    Device, DeviceProfile, MemDevice, NVME_PROFILE, OSDevice, REMOTE_PROFILE,
    ShardedDevice, SimulatedDevice,
)
from .engine import (DepthController, FuturePoisoned, GraphMismatch,
                     SessionStats, SpecSession)
from .graph import BranchNode, ForeactionGraph, GraphBuilder, SyscallNode
from .plan import GraphPlan, compile_plan
from .syscalls import (Effect, FromRequest, FutureCancelled, IOFuture, Sys,
                       effect_of, is_pure)
from .trace import (RecordingSession, Trace, TraceEvent,
                    TraceRecorder, TraceRing)

__all__ = [
    "Foreactor", "current_session", "io", "make_foreactor",
    "BACKENDS", "IOPlane", "MultiQueueBackend", "QueuePairBackend",
    "SharedBackend", "SlotScheduler", "SubmissionLane", "SyncBackend",
    "ThreadPoolBackend", "make_backend",
    "BufferLease", "BufferPool",
    "CompletionPool", "completion_pool",
    "Device", "DeviceProfile", "MemDevice", "NVME_PROFILE", "OSDevice",
    "REMOTE_PROFILE", "ShardedDevice", "SimulatedDevice",
    "DepthController", "FuturePoisoned", "GraphMismatch", "SessionStats",
    "SpecSession",
    "BranchNode", "ForeactionGraph", "GraphBuilder", "SyscallNode",
    "GraphPlan", "compile_plan",
    "Effect", "FromRequest", "FutureCancelled", "IOFuture", "Sys",
    "effect_of", "is_pure",
    "Trace", "TraceEvent", "TraceRecorder", "TraceRing",
    "RecordingSession",
]
