"""Compiled graph plans: lowering a ``ForeactionGraph`` to flat arrays.

The authoring layer (:mod:`repro.core.graph`) optimizes for expressiveness:
nodes are dataclass objects, edges are ``Edge`` records, branch children are
lists, and a peek step chases ``node.out.dst`` attribute chains and hashes
``(name, epochs)`` string-keyed tuples.  That representation is walked on
*every intercepted syscall* (paper §5.2, Algorithm 1), so its interpretation
cost lands directly on the Fig. 10 "pre-issuing algorithm" overhead line —
and it scales with graph-authoring style (a mined 40-node chain pays 40
attribute chases per window walk; a 3-node loop pays 3).

``GraphPlan`` is the same graph lowered once into immutable, topologically
ordered node records held in parallel flat arrays indexed by a dense integer
node id:

* ``kind[i]``          — syscall or branch record
* ``sc[i]``            — syscall id (``Sys``), ``None`` for branch records
* ``effect[i]``        — statically known effect class (paper §3.3), or
                         ``None`` when it depends on runtime args (OPEN's
                         mode flag) — the interpreter then falls back to
                         :func:`repro.core.syscalls.effect_of`
* ``compute[i]`` / ``save[i]`` / ``choose[i]`` — the plugin stub slots
  (argument thunks are *called* at peek time exactly as before; compilation
  never evaluates them)
* ``out_dst[i]`` / ``out_weak[i]`` / ``out_loop[i]`` — a syscall node's one
  outgoing edge (``dst = -1`` encodes End, ``loop = -1`` no epoch bump)
* ``child_off[i]`` + ``edge_dst``/``edge_weak``/``edge_loop`` — a branch
  node's edge table, flattened: child ``k`` of node ``i`` lives at flat
  index ``child_off[i] + k`` (the Choice stub's return value indexes it
  directly, no per-edge object hop)

The interpreter (:meth:`repro.core.engine.SpecSession._peek_and_preissue`)
walks these arrays with integer cursors and a tuple epoch vector; node state
is keyed by ``(node_id, epochs)`` — two machine-word hashes instead of a
string hash per step.

Compilation is cached per ``(graph, depth_mode)``: ``compile_plan`` returns
the *same* ``GraphPlan`` object for repeated calls on one graph (the cache
the ``Foreactor`` relies on so per-activation cost is a dict hit), entries
are evicted when the source graph is garbage collected, and an id-reuse
collision can never alias two distinct graphs (the cache validates through a
weak reference to the source).

Cross-references: docs/ARCHITECTURE.md ("Plan compilation & the unified I/O
plane"); *graph plan* is defined in docs/GLOSSARY.md.
"""

from __future__ import annotations

import threading
import weakref
from typing import Any, Callable, Dict, List, Optional, Tuple

from .graph import BranchNode, ForeactionGraph, FromNode, SyscallNode
from .syscalls import PURE, Effect, Sys

KIND_SYSCALL = 0
KIND_BRANCH = 1

#: End-of-graph sentinel in dst arrays (no node id is negative)
END = -1


def _static_effect(sc: Sys) -> Optional[Effect]:
    """Effect class when it does not depend on runtime arguments.

    OPEN is the one dynamic case: its mode flag ('r'/'w'/'rw'/'a') decides
    pure vs undoable vs barrier (see ``syscalls.effect_of``)."""
    if sc in PURE:
        return Effect.PURE
    if sc is Sys.OPEN:
        return None
    if sc is Sys.PWRITE:
        return Effect.UNDOABLE
    if sc is Sys.RENAME:
        return Effect.UNDOABLE
    return Effect.BARRIER  # close, fsync, unlink


class GraphPlan:
    """Immutable lowered form of one ``ForeactionGraph``.

    Instances are created by :func:`compile_plan` only; all fields are
    written once during lowering and never mutated afterwards (sessions on
    many threads interpret one plan concurrently)."""

    __slots__ = (
        "name", "num_loops", "num_nodes", "depth_mode",
        "kind", "names", "sc", "effect", "compute", "save", "choose",
        "out_dst", "out_weak", "out_loop",
        "child_off", "edge_dst", "edge_weak", "edge_loop",
        "start_dst", "start_weak", "id_of", "source_ref",
    )

    def __init__(self) -> None:
        self.kind: List[int] = []
        self.names: List[str] = []
        self.sc: List[Optional[Sys]] = []
        self.effect: List[Optional[Effect]] = []
        self.compute: List[Optional[Callable]] = []
        self.save: List[Optional[Callable]] = []
        self.choose: List[Optional[Callable]] = []
        self.out_dst: List[int] = []
        self.out_weak: List[bool] = []
        self.out_loop: List[int] = []
        self.child_off: List[int] = []
        self.edge_dst: List[int] = []
        self.edge_weak: List[bool] = []
        self.edge_loop: List[int] = []
        self.id_of: Dict[str, int] = {}

    # -- derived views -----------------------------------------------------
    @property
    def source(self) -> Optional[ForeactionGraph]:
        """The graph this plan was lowered from (None once collected)."""
        return self.source_ref()

    def initial_epochs(self) -> Tuple[int, ...]:
        return (0,) * self.num_loops

    def structure(self) -> Tuple:
        """Hashable structural fingerprint: everything except the stub
        callables' identities.  Two independent builds of the same authoring
        code lower to equal structures (the plan-equality property test)."""
        return (
            self.name, self.num_loops, tuple(self.kind), tuple(self.names),
            tuple(s.value if s else None for s in self.sc),
            tuple(e.value if e else None for e in self.effect),
            tuple(self.out_dst), tuple(self.out_weak), tuple(self.out_loop),
            tuple(self.child_off), tuple(self.edge_dst),
            tuple(self.edge_weak), tuple(self.edge_loop),
            self.start_dst, self.start_weak,
        )

    # -- symbolic walking (validation replay, tests) -----------------------
    def resolve_branches(self, nid: int, epochs: Tuple[int, ...],
                         ctx: Dict[str, Any],
                         weak: bool) -> Optional[Tuple[int, Tuple[int, ...], bool]]:
        """Follow branch records until a syscall record or End; ``None`` when
        a Choice stub is not ready.  Mirrors the interpreter's inline loop —
        exposed for the validation replay and the lowering-equivalence
        tests."""
        while nid != END and self.kind[nid] == KIND_BRANCH:
            idx = self.choose[nid](ctx, epochs)
            if idx is None:
                return None
            e = self.child_off[nid] + idx
            lid = self.edge_loop[e]
            if lid >= 0:
                epochs = epochs[:lid] + (epochs[lid] + 1,) + epochs[lid + 1:]
            weak = weak or self.edge_weak[e]
            nid = self.edge_dst[e]
        return nid, epochs, weak

    def follow_out(self, nid: int,
                   epochs: Tuple[int, ...]) -> Tuple[int, Tuple[int, ...], bool]:
        """(next id, epochs, edge weak) across a syscall record's out edge."""
        lid = self.out_loop[nid]
        if lid >= 0:
            epochs = epochs[:lid] + (epochs[lid] + 1,) + epochs[lid + 1:]
        return self.out_dst[nid], epochs, self.out_weak[nid]


#: sentinel for "producer result unavailable" during the symbolic walk
_MISSING = object()


def predicted_preissue(plan: GraphPlan, ctx: Dict[str, Any],
                       events) -> int:
    """Predicted pre-issue schedule coverage: how many of ``events`` this
    plan would pre-issue with exactly the application's arguments.

    ``events`` is a sequence of recorded syscalls (``.sc``, ``.args``,
    ``.result`` — :class:`repro.core.trace.TraceEvent` or anything
    shaped like it).  The walk mirrors the validator's serial replay
    (:func:`repro.analysis.mine.replay_trace`) but *scores* instead of
    judging: it stops at the first divergence — wrong syscall kind,
    mismatched arguments, undecidable branch, graph exhausted — and
    returns the count of matched events.  Against a live trace this is the
    number of intercepts the engine's harvest-time argument guard would
    accept from this plan, i.e. the speculation the graph can still buy.

    The online re-miner's improvement check compares this score between
    the incumbent graph and a mined candidate over held-out sampled
    traces: a hot-swap is only allowed when the candidate's predicted
    schedule covers strictly more of the live pattern than the incumbent's
    (a drifted incumbent scores the pre-drift prefix at best)."""
    ctx = dict(ctx)
    ctx.pop("__mined__", None)
    ctx.pop("__mined_n__", None)
    epochs = plan.initial_epochs()
    nid = plan.start_dst
    results: Dict[Tuple[str, Tuple[int, ...]], Any] = {}
    matched = 0
    for ev in events:
        res = plan.resolve_branches(nid, epochs, ctx, False)
        if res is None:
            break
        nid, epochs, _weak = res
        if nid == END or plan.sc[nid] is not ev.sc:
            break
        out = plan.compute[nid](ctx, epochs)
        if out is None:
            break
        args, _link = out
        if len(args) != len(ev.args):
            break
        ok = True
        for a, b in zip(args, ev.args):
            if isinstance(a, FromNode):
                a = results.get((a.name, epochs), _MISSING)
            if a is _MISSING or a != b:
                ok = False
                break
        if not ok:
            break
        results[(plan.names[nid], epochs)] = ev.result
        if plan.save[nid] is not None:
            plan.save[nid](ctx, epochs, ev.result)
        matched += 1
        nid, epochs, _weak = plan.follow_out(nid, epochs)
    return matched


def _topo_order(graph: ForeactionGraph) -> List[str]:
    """Deterministic traversal order from start.  Loop-back edges are
    visited too — usually they only revisit seen nodes, but the validator
    accepts graphs where a node is reachable *only* through one (a
    do-while body), and every reachable node needs an id."""
    order: List[str] = []
    seen = set()
    stack = [graph.start.dst]
    while stack:
        node = stack.pop()
        if node is None or node.name in seen:
            continue
        seen.add(node.name)
        order.append(node.name)
        if isinstance(node, SyscallNode):
            if node.out is not None:
                stack.append(node.out.dst)
        else:
            # reversed: child 0 is visited first, keeping ids aligned with
            # the likely execution order
            for e in reversed(node.children):
                stack.append(e.dst)
    return order


def _lower(graph: ForeactionGraph, depth_mode: str) -> GraphPlan:
    plan = GraphPlan()
    plan.name = graph.name
    plan.num_loops = graph.num_loops
    plan.depth_mode = depth_mode
    order = _topo_order(graph)
    plan.id_of = {name: i for i, name in enumerate(order)}
    plan.num_nodes = len(order)

    def nid(node) -> int:
        return END if node is None else plan.id_of[node.name]

    for name in order:
        node = graph.syscall_nodes.get(name)
        if node is not None:
            plan.kind.append(KIND_SYSCALL)
            plan.names.append(name)
            plan.sc.append(node.sc)
            plan.effect.append(_static_effect(node.sc))
            plan.compute.append(node.compute_args)
            plan.save.append(node.save_result)
            plan.choose.append(None)
            out = node.out
            plan.out_dst.append(nid(out.dst))
            plan.out_weak.append(out.weak)
            plan.out_loop.append(-1 if out.loop_id is None else out.loop_id)
            plan.child_off.append(-1)
        else:
            br: BranchNode = graph.branch_nodes[name]
            plan.kind.append(KIND_BRANCH)
            plan.names.append(name)
            plan.sc.append(None)
            plan.effect.append(None)
            plan.compute.append(None)
            plan.save.append(None)
            plan.choose.append(br.choose)
            plan.out_dst.append(END)
            plan.out_weak.append(False)
            plan.out_loop.append(-1)
            plan.child_off.append(len(plan.edge_dst))
            for e in br.children:
                plan.edge_dst.append(nid(e.dst))
                plan.edge_weak.append(e.weak)
                plan.edge_loop.append(-1 if e.loop_id is None else e.loop_id)
    plan.start_dst = nid(graph.start.dst)
    plan.start_weak = graph.start.weak
    plan.source_ref = weakref.ref(graph)
    return plan


# ---------------------------------------------------------------------------
# The compilation cache: one plan per (graph, depth-mode), for the process
# ---------------------------------------------------------------------------
_cache: Dict[Tuple[int, str], GraphPlan] = {}
_cache_lock = threading.Lock()
#: cache-effectiveness counters (tests + bench_overhead assert on these)
stats = {"compiles": 0, "hits": 0}


def _evict(key: Tuple[int, str]) -> None:
    with _cache_lock:
        _cache.pop(key, None)


def compile_plan(graph: ForeactionGraph,
                 depth_mode: str = "fixed") -> GraphPlan:
    """Lower ``graph`` (or return its cached lowering).

    Repeated calls with the same graph object and depth mode return the
    *identical* ``GraphPlan`` instance — per-activation cost is one dict
    probe.  The entry lives exactly as long as the graph does."""
    key = (id(graph), depth_mode)
    with _cache_lock:
        plan = _cache.get(key)
        if plan is not None and plan.source is graph:
            stats["hits"] += 1
            return plan
    new = _lower(graph, depth_mode)
    with _cache_lock:
        # lost race: someone else compiled while we lowered — keep theirs
        plan = _cache.get(key)
        if plan is not None and plan.source is graph:
            stats["hits"] += 1
            return plan
        stats["compiles"] += 1
        _cache[key] = new
    weakref.finalize(graph, _evict, key)
    return new
