"""Registered buffer pool for the I/O plane (paper Fig. 10 "result copy").

io_uring lets an application *register* a fixed set of buffers with the
kernel once (``io_uring_register(IORING_REGISTER_BUFFERS)``) and then issue
``IORING_OP_READ_FIXED`` against them, so the hot path never allocates a
per-request buffer.  This module is that idea for the
:class:`repro.core.backends.IOPlane`: a size-classed pool of pre-allocated
``bytearray`` buffers that are *leased* to ``IORequest``s at submission time,
filled by the worker via :meth:`repro.core.device.Device.pread_into` (no
per-request allocation on the device side), and returned to the pool when the
session finishes.

Why it pays off in this runtime: the unpooled read path allocates twice per
pread (the device slices its backing store into a fresh ``bytearray``, then
wraps it in ``bytes``), and speculative reads that the function never demands
(cancelled / wasted completions — the paper's early-exit overhead) pay that
allocation for nothing.  A leased read does one copy into a recycled buffer;
a *wasted* leased read allocates nothing at all, and a harvested one pays
exactly one materialize copy (``IORequest.take_result``) — the paper's
result-copy, now bounded and measured.

Lease lifecycle (enforced by the plane + engine, not by this module):

1. ``pool.lease(size)`` at submission — ``None`` when the pool is at
   capacity, in which case the request simply runs unleased (the classic
   allocate-per-request path; registered buffers are a fixed budget, exactly
   like io_uring's).
2. The worker fills ``lease.mv`` and records the byte count via
   ``lease.filled(n)``.
3. Consumers (the frontier harvest, ``FromRequest.resolve``) call
   ``IORequest.take_result`` which materializes ``bytes`` at most once.
4. ``lease.release()`` at session teardown, strictly after the backend
   drain — no worker can still be writing into the buffer, and every
   consumer holds materialized ``bytes``, never the buffer itself.

Cross-references: docs/ARCHITECTURE.md ("Plan compilation & the unified I/O
plane"); *registered buffer* and *buffer lease* are defined in
docs/GLOSSARY.md.
"""

from __future__ import annotations

import mmap
import threading
from typing import Dict, List, Optional, Tuple

#: size classes: powers of two from 512 B to 4 MiB.  Requests above the top
#: class run unleased (huge reads are rare and amortize their allocation).
_MIN_CLASS = 9  # 2**9 = 512
_MAX_CLASS = 22  # 2**22 = 4 MiB

#: alignment classes accepted by :meth:`BufferPool.lease`.  0 means "any
#: address" (plain ``bytearray`` slab); 512 and 4096 are the two logical
#: block sizes O_DIRECT cares about.  Aligned slabs are anonymous ``mmap``
#: regions, which the kernel hands back page-aligned — one slab kind
#: satisfies both nonzero classes.
ALIGNMENT_CLASSES = (0, 512, 4096)


def size_class(size: int) -> Optional[int]:
    """The smallest power-of-two class holding ``size`` bytes, or None if
    the size is out of the registered range."""
    if size <= 0 or size > (1 << _MAX_CLASS):
        return None
    c = _MIN_CLASS
    while (1 << c) < size:
        c += 1
    return c


class BufferLease:
    """One registered buffer, on loan from the pool to one ``IORequest``.

    Leases are refcounted: the dispatching request holds the initial ref,
    and additional consumers that must read ``mv`` later (a ``FromRequest``
    stub, an unresolved future) take one via :meth:`addref`.  The buffer
    goes back to the pool when the *last* holder releases — which, since
    ``IORequest.take_result`` materializes bytes and releases at first
    demand, happens mid-session rather than at teardown."""

    __slots__ = ("pool", "cls", "buf", "mv", "nbytes", "tenant", "aligned",
                 "_refs")

    def __init__(self, pool: "BufferPool", cls: int, buf,
                 tenant: Optional[str] = None, aligned: bool = False):
        self.pool = pool
        self.cls = cls
        self.buf = buf
        self.mv = memoryview(buf)
        self.nbytes = 0
        self.tenant = tenant
        #: True when ``buf`` is a page-aligned mmap slab (valid O_DIRECT
        #: target); recycles into the aligned free list
        self.aligned = aligned
        self._refs = 1

    def filled(self, n: int) -> None:
        """Record how many bytes the device wrote (short reads included)."""
        self.nbytes = n

    def to_bytes(self) -> bytes:
        """Materialize the filled prefix — the result copy of paper Fig. 10,
        exactly one bounded memcpy out of the registered buffer."""
        return bytes(self.mv[: self.nbytes])

    def addref(self) -> "BufferLease":
        """Register one more holder; pairs with one :meth:`release`."""
        with self.pool._lock:
            if self._refs <= 0:
                raise RuntimeError("addref on a released buffer lease")
            self._refs += 1
        return self

    def release(self) -> None:
        """Drop one holder's ref; the last drop returns the buffer to the
        pool.  Extra releases are ignored (teardown paths and first-demand
        materialization may both try).  Callers must ensure no consumer
        still reads ``mv`` past their release."""
        with self.pool._lock:
            if self._refs <= 0:
                return
            self._refs -= 1
            if self._refs > 0:
                return
            self.pool._give_back_locked(self)

    def view(self, start: int, nbytes: int) -> "LeaseView":
        """A zero-copy window into this buffer — the *scatter view* a fused
        super-read hands each covered extent.  Takes one ref on this lease;
        the slab recycles only after every view (and the carrier) releases."""
        if start < 0 or start + nbytes > len(self.mv):
            raise ValueError(f"view [{start}, {start + nbytes}) outside "
                             f"lease of {len(self.mv)} bytes")
        self.addref()
        return LeaseView(self, start, nbytes)

    def __len__(self) -> int:
        return self.nbytes


class LeaseView:
    """Zero-copy sub-range of a :class:`BufferLease` (one scattered extent
    of a fused super-read).  Quacks like a lease for the consumer paths that
    matter — ``to_bytes`` / ``release`` / ``mv`` — so ``IORequest.take_result``
    and session teardown need no special casing.  Releasing a view drops the
    ref it holds on the parent lease; the parent's slab goes back to the
    pool when the last view/carrier releases."""

    __slots__ = ("parent", "start", "nbytes", "_refs")

    def __init__(self, parent: BufferLease, start: int, nbytes: int):
        self.parent = parent
        self.start = start
        self.nbytes = nbytes
        self._refs = 1

    @property
    def mv(self) -> memoryview:
        return self.parent.mv[self.start: self.start + self.nbytes]

    def filled(self, n: int) -> None:
        self.nbytes = n

    def to_bytes(self) -> bytes:
        return bytes(self.parent.mv[self.start: self.start + self.nbytes])

    def addref(self) -> "LeaseView":
        with self.parent.pool._lock:
            if self._refs <= 0:
                raise RuntimeError("addref on a released lease view")
            self._refs += 1
        self.parent.addref()
        return self

    def release(self) -> None:
        # extra releases are ignored, like BufferLease.release: teardown
        # and first-demand materialization may both try
        with self.parent.pool._lock:
            if self._refs <= 0:
                return
            self._refs -= 1
        self.parent.release()

    def __len__(self) -> int:
        return self.nbytes


class BufferPool:
    """Size-classed pool of pre-allocated, recycled I/O buffers.

    ``capacity_bytes`` bounds the total registered memory (leased + idle),
    like io_uring's fixed registration: when the budget is exhausted,
    :meth:`lease` returns ``None`` and the request falls back to the
    allocate-per-request path instead of blocking.  Thread-safe; stats are
    exposed to benchmarks (``bench_overhead``) and tests.

    **Per-tenant budgets** (multi-tenant serving): when a lease names a
    tenant, the class size is charged against that tenant's
    ``tenant_budget_bytes`` slice of the registered memory and refunded at
    release.  A tenant at its budget is declined — it falls back to the
    allocate-per-request path for *its own* reads — without touching the
    free lists, so one huge-read tenant can never drain the recycled
    buffers every other tenant's leases ride on.  Untenanted leases
    (private, single-session backends) are uncharged, as before.
    """

    def __init__(self, capacity_bytes: int = 64 << 20,
                 tenant_budget_bytes: Optional[int] = None):
        self.capacity_bytes = capacity_bytes
        #: per-tenant slice of the registered memory; the default (1/8 of
        #: capacity) lets a handful of hot tenants saturate the pool while
        #: no single one can claim more than its slice
        self.tenant_budget_bytes = (capacity_bytes // 8
                                    if tenant_budget_bytes is None
                                    else tenant_budget_bytes)
        #: free lists keyed (size class, aligned?) — aligned slabs are mmap
        #: regions and must never satisfy (or be polluted by) plain leases
        self._free: Dict[Tuple[int, bool], List] = {}
        self._lock = threading.Lock()
        #: total bytes currently registered (idle + leased)
        self.registered_bytes = 0
        #: bytes currently charged to each tenant (leased, not yet refunded)
        self._charged: Dict[str, int] = {}
        # observability
        self.leases = 0
        self.recycle_hits = 0
        self.grows = 0
        self.declined = 0
        self.budget_declines = 0
        self.released = 0
        #: leases handed out from page-aligned mmap slabs (O_DIRECT-ready)
        self.aligned_leases = 0
        #: occupancy gauges — the mid-session recycling regression surface:
        #: a session of R harvested reads must peak at O(depth), not O(R)
        self.leased_now = 0
        self.peak_leased = 0

    def lease(self, size: int, tenant: Optional[str] = None,
              alignment: int = 0) -> Optional[BufferLease]:
        """Lease a registered buffer of at least ``size`` bytes.

        ``alignment`` (0, 512 or 4096) asks for a buffer whose base address
        is a valid O_DIRECT target; aligned slabs come from anonymous
        ``mmap`` (page-aligned, so one slab kind serves both classes) and
        the tenant budget charges the same ``1 << cls`` as a plain lease.
        """
        if alignment not in ALIGNMENT_CLASSES:
            raise ValueError(f"alignment must be one of {ALIGNMENT_CLASSES},"
                             f" got {alignment}")
        aligned = alignment > 0
        cls = size_class(size)
        if cls is None:
            with self._lock:
                self.declined += 1
            return None
        nbytes = 1 << cls
        with self._lock:
            if tenant is not None:
                charged = self._charged.get(tenant, 0)
                if charged + nbytes > self.tenant_budget_bytes:
                    # over budget: this tenant allocates classically; the
                    # free lists stay untouched for everyone else
                    self.declined += 1
                    self.budget_declines += 1
                    return None
            free = self._free.get((cls, aligned))
            if free:
                buf = free.pop()
                self.recycle_hits += 1
            else:
                if self.registered_bytes + nbytes > self.capacity_bytes:
                    self.declined += 1
                    return None
                buf = mmap.mmap(-1, nbytes) if aligned else bytearray(nbytes)
                self.registered_bytes += nbytes
                self.grows += 1
            if tenant is not None:
                self._charged[tenant] = self._charged.get(tenant, 0) + nbytes
            self.leases += 1
            if aligned:
                self.aligned_leases += 1
            self.leased_now += 1
            if self.leased_now > self.peak_leased:
                self.peak_leased = self.leased_now
        return BufferLease(self, cls, buf, tenant, aligned=aligned)

    def _give_back_locked(self, lease: BufferLease) -> None:
        """Recycle a fully-released lease; caller holds ``self._lock``."""
        self.released += 1
        self.leased_now -= 1
        if lease.tenant is not None:
            left = self._charged.get(lease.tenant, 0) - (1 << lease.cls)
            if left > 0:
                self._charged[lease.tenant] = left
            else:  # fully refunded: drop the entry (bounded tenant map)
                self._charged.pop(lease.tenant, None)
        self._free.setdefault((lease.cls, lease.aligned), []).append(lease.buf)

    def charged_bytes(self, tenant: str) -> int:
        """Bytes currently charged to ``tenant`` (0 once fully refunded)."""
        with self._lock:
            return self._charged.get(tenant, 0)

    @property
    def hit_rate(self) -> float:
        return self.recycle_hits / self.leases if self.leases else 0.0

    def snapshot(self) -> Dict[str, float]:
        with self._lock:
            return {
                "registered_bytes": self.registered_bytes,
                "leases": self.leases,
                "recycle_hits": self.recycle_hits,
                "hit_rate": self.recycle_hits / self.leases if self.leases
                else 0.0,
                "grows": self.grows,
                "declined": self.declined,
                "budget_declines": self.budget_declines,
                "released": self.released,
                "aligned_leases": self.aligned_leases,
                "leased_now": self.leased_now,
                "peak_leased": self.peak_leased,
                "tenants_charged": len(self._charged),
            }
