"""The unified I/O plane (paper §2.3, §5.4): one reactor, pluggable lanes.

Every backend in this module is one class — :class:`IOPlane` — configured
with *submission lanes*.  A lane is a queue pair: an ``io_workqueue`` worker
pool plus the crossing policy that models how entries reach it (one
``io_uring_enter`` per submitted batch, or one ordinary syscall per request
for the user-level thread pool).  The plane owns the submission queue, the
submitted-request ledger (one lock, acquired once per ``submit``), the
chain partitioner, and a :class:`repro.core.buffers.BufferPool` of
registered buffers leased to PREAD requests at dispatch.

The historical five backends are lane configurations of that one reactor:

* ``SyncBackend`` — zero lanes: nothing runs early, demand executes inline
  at ``wait`` (the no-speculation baseline; also the conformance oracle, so
  it takes no buffer pool and keeps the classic allocate-per-request path).
* ``QueuePairBackend`` — one batched lane (io_uring analogue: one boundary
  crossing per submitted batch, harvest costs none).
* ``ThreadPoolBackend`` — one per-request lane (each entry pays its own
  crossing: an ordinary blocking syscall on some thread).
* ``MultiQueueBackend`` — one batched lane per sub-device of a
  :class:`repro.core.device.ShardedDevice`; chains route whole by their
  head's target shard and each touched lane pays one crossing.
* ``SharedBackend`` + ``SlotScheduler`` — the multi-tenant layer, riding on
  top of any async plane unchanged in semantics: many concurrent sessions
  lease submission slots from one plane, with weighted-fair shares,
  priority classes, and pressure eviction of speculative-only requests.

Cross-references: docs/ARCHITECTURE.md ("Plan compilation & the unified I/O
plane", "Sharded multi-device substrate", "Shared-backend scheduling") maps
this module to paper §2.3/§5.4; see docs/GLOSSARY.md for *submission lane*,
*queue-pair crossing*, *registered buffer*, *tenant*, and *slot lease*.
"""

from __future__ import annotations

import threading
from typing import Callable, Dict, List, Optional, Sequence, Tuple

from .buffers import BufferPool
from .coalesce import ExtentCoalescer
from .completion import completion_pool
from .device import Device, ShardedDevice
from .lanes import SubmissionLane
from .syscalls import IORequest, ReqState, Sys, perform


class Backend:
    """Engine-facing interface — identical across backends (paper Table 1)."""

    name = "abstract"
    #: requests this backend can usefully run at once (worker count, summed
    #: across lanes); 0 = no async execution.  The adaptive depth
    #: controller stops growing once occupancy reaches this.
    capacity = 0

    def __init__(self, device: Device):
        self.device = device

    def inflight(self) -> int:
        """Submitted-but-incomplete request count (queue occupancy)."""
        return 0

    def prepare(self, req: IORequest) -> None:
        raise NotImplementedError

    def submit_all(self) -> int:
        """Make prepared requests eligible to run; returns #submitted."""
        raise NotImplementedError

    def submit(self, batch: List[IORequest]) -> int:
        """Submit a pre-formed batch in one call — the plan interpreter's
        fast path: the engine accumulates its peeked requests locally and
        hands them over with a single lock acquisition instead of one
        ``prepare`` crossing per request."""
        for req in batch:
            self.prepare(req)
        return self.submit_all()

    def wait(self, req: IORequest):
        raise NotImplementedError

    def cancel_remaining(self) -> int:
        """Cancel every request not yet executing (early exit, paper §6.4)."""
        raise NotImplementedError

    def drain(self) -> None:
        """Block until nothing is in flight (session teardown)."""
        raise NotImplementedError

    def spec_budget(self) -> Optional[int]:
        """Speculation-budget lease: how many requests this backend will let
        its session keep speculative at once, or None for unlimited (private
        backends).  The engine caps its peek depth here; on a
        :class:`SharedBackend` this is the tenant's weighted-fair share."""
        return None

    def note_demand(self) -> None:
        """Hint: the session is about to serve a request synchronously (a
        *demand* operation).  Shared backends use it to shed speculative
        queue pressure; private backends ignore it."""

    def shutdown(self) -> None:
        pass


def _chains(batch: List[IORequest]) -> List[List[IORequest]]:
    """Group a submitted batch into link chains (io_uring IOSQE_IO_LINK): a
    req with link=True executes before its successor, on the same worker."""
    chains: List[List[IORequest]] = []
    chain: List[IORequest] = []
    for req in batch:
        chain.append(req)
        if not req.link:
            chains.append(chain)
            chain = []
    if chain:  # trailing link=True at batch end — still a chain
        chains.append(chain)
    return chains


class IOPlane(Backend):
    """The unified reactor behind every backend name.

    One submission queue + submitted-request ledger behind a single lock
    (``submit`` acquires it once per batch — the paper's "one
    io_uring_enter per batch" submission-cost model, now also true of the
    Python-side locking), N :class:`SubmissionLane`\\ s, a chain router, and
    a registered :class:`BufferPool` leased to PREAD entries at dispatch.

    With zero lanes the plane degenerates to the synchronous baseline:
    nothing runs early, ``wait`` executes the request inline at demand time
    (and the ledger still lets ``cancel_remaining`` account every prepared
    entry, keeping the SessionStats invariant
    ``pre_issued == served_async + cancelled + wasted_completions``).
    """

    name = "io_plane"

    def __init__(self, device: Device, lanes: Sequence[SubmissionLane] = (),
                 router: Optional[Callable[[IORequest], int]] = None,
                 pool: Optional[BufferPool] = None,
                 coalesce: bool = False):
        super().__init__(device)
        self.lanes: List[SubmissionLane] = list(lanes)
        if len(self.lanes) > 1 and router is None:
            raise ValueError(
                "a multi-lane IOPlane needs a router (chains would all land "
                "on lane 0 while capacity reports every lane's workers)")
        self._router = router
        self.pool = pool
        #: extent coalescing (repro.core.coalesce): fuse adjacent same-fd
        #: PREAD runs into super-reads at dispatch.  Off on the zero-lane
        #: (sync) plane regardless — the conformance oracle executes every
        #: request exactly as written.
        self.coalescer: Optional[ExtentCoalescer] = (
            ExtentCoalescer(pool) if coalesce and self.lanes else None)
        self.capacity = sum(lane.workers for lane in self.lanes)
        self._sq: List[IORequest] = []
        self._submitted: List[IORequest] = []
        # guards both queues: inflight()/drain() rebuild the _submitted ledger
        # and submit() swaps _sq — unguarded, concurrent sessions sharing
        # this plane lose ledger entries (requests that then never get
        # cancelled or drained).
        self._lock = threading.Lock()

    # -- engine surface ----------------------------------------------------
    def inflight(self) -> int:
        # prune completed entries while counting, keeping the ledger short
        with self._lock:
            self._submitted = [r for r in self._submitted if not r.is_done()]
            return len(self._submitted)

    def prepare(self, req: IORequest) -> None:
        with self._lock:
            self._sq.append(req)

    def submit_all(self) -> int:
        with self._lock:
            if not self._sq:
                return 0
            batch, self._sq = self._sq, []
        return self.submit(batch)

    #: amortized ledger-compaction threshold: above this many entries,
    #: submit() drops completed ones in place.  Without it a long-lived
    #: plane under open-loop load (sessions come and go, nobody calls
    #: ``inflight``) grows the ledger without bound.
    _LEDGER_COMPACT = 2048

    def submit(self, batch: List[IORequest]) -> int:
        if not batch:
            return 0
        if not self.lanes:
            # synchronous plane: entries only reach the ledger (they run at
            # wait); returns 0 — nothing was made eligible to run early
            with self._lock:
                self._submitted.extend(batch)
                if len(self._submitted) > self._LEDGER_COMPACT:
                    self._submitted = [r for r in self._submitted
                                       if not r.is_done()]
            return 0
        self._dispatch(batch)
        with self._lock:
            self._submitted.extend(batch)
            if len(self._submitted) > self._LEDGER_COMPACT:
                self._submitted = [r for r in self._submitted
                                   if not r.is_done()]
        return len(batch)

    # SharedBackend views stage their entries privately and submit through
    # here, so concurrent tenants can never interleave entries into each
    # other's link chains.
    submit_batch = submit

    def wait(self, req: IORequest):
        if not self.lanes:
            # the no-speculation baseline: demand I/O runs inline, paying
            # its own boundary crossing
            self.device.charge_crossing()
            req.finish(perform(self.device, req))
        if req.fused is not None:
            # a demanded member of a fused super-read whose carrier was
            # cancelled before executing is decomposed back to its own
            # per-extent read (repro.core.coalesce) instead of blocking
            req.fused.on_demand(self.device, req)
        return req.wait_result()

    def cancel_remaining(self) -> int:
        with self._lock:
            pending, self._sq = self._sq, []
            submitted = list(self._submitted)
        n = 0
        for req in pending:
            if req.cancel():
                n += 1
        for req in submitted:
            if req.cancel():
                n += 1
        return n

    def drain(self) -> None:
        for lane in self.lanes:
            lane.drain()
        with self._lock:
            self._submitted = [r for r in self._submitted if not r.is_done()]

    def shutdown(self) -> None:
        for lane in self.lanes:
            lane.shutdown()

    # -- dispatch ----------------------------------------------------------
    def _lease_buffers(self, batch: List[IORequest]) -> None:
        """Attach registered-buffer leases to PREAD entries (READ_FIXED):
        the worker will fill recycled memory instead of allocating a result
        per request.  Pool exhaustion or odd shapes (staged runners,
        deferred size arguments) silently fall back to the classic path.

        On a coalescing plane, PWRITE entries with static payloads get the
        WRITE_FIXED analogue: the payload is copied into an aligned lease at
        dispatch (the registration copy) and the worker writes straight out
        of registered memory — on a direct-mode device the buffer is a
        valid O_DIRECT source."""
        pool = self.pool
        if pool is None:
            return
        align = 0
        if self.coalescer is not None:
            from .coalesce import _pool_alignment

            align = _pool_alignment(self.device)
        for req in batch:
            if req.sc is Sys.PREAD and req.runner is None \
                    and req.lease is None and isinstance(req.args[1], int):
                req.lease = pool.lease(req.args[1], tenant=req.tenant,
                                       alignment=align)
            elif self.coalescer is not None and req.sc is Sys.PWRITE \
                    and req.runner is None and req.lease is None \
                    and isinstance(req.args[1], (bytes, bytearray, memoryview)):
                data = req.args[1]
                lease = pool.lease(len(data), tenant=req.tenant,
                                   alignment=align)
                if lease is None:
                    continue
                n = len(data)
                lease.mv[:n] = data
                lease.filled(n)
                req.lease = lease
                fd, _, off = req.args
                req.runner = (lambda device, fd=fd, off=off, lease=lease,
                              n=n: device.pwrite(fd, lease.mv[:n], off))

    def _dispatch(self, batch: List[IORequest]) -> None:
        if self.coalescer is not None:
            chains = self.coalescer.fuse(_chains(batch))
            # satellites left the dispatch set; lease/charge only what runs
            batch = [r for chain in chains for r in chain]
            self._lease_buffers(batch)
        else:
            self._lease_buffers(batch)
            chains = _chains(batch)
        if len(self.lanes) == 1 or self._router is None:
            lane = self.lanes[0]
            lane.charge(len(batch))
            lane.push_batch(chains)
            return
        # multi-lane: whole chains route by their head (io_uring link
        # ordering survives), each touched lane pays one crossing and
        # receives its share of the batch in one ring fill
        routed: Dict[int, List[List[IORequest]]] = {}
        for chain in chains:
            routed.setdefault(self._router(chain[0]), []).append(chain)
        for li in sorted(routed):
            lane_chains = routed[li]
            self.lanes[li].charge(sum(len(c) for c in lane_chains))
            self.lanes[li].push_batch(lane_chains)


# ---------------------------------------------------------------------------
# Lane configurations: the historical backend names, one reactor underneath
# ---------------------------------------------------------------------------
class SyncBackend(IOPlane):
    """No speculation: zero lanes, requests execute at wait().

    Deliberately takes no buffer pool: this is the conformance oracle and
    keeps the classic allocate-per-request result path.
    """

    name = "sync"

    def __init__(self, device: Device):
        super().__init__(device, lanes=())


class QueuePairBackend(IOPlane):
    """io_uring analogue: one batched lane (SQ/CQ pair + io_workqueue).

    prepare()/submit() fill SQ entries with no crossings; dispatch costs
    exactly one boundary crossing for the whole batch; completions are
    harvested by waiting on the request's event (CQ poll — no crossing).
    """

    name = "io_uring"

    def __init__(self, device: Device, workers: int = 16,
                 coalesce: bool = False):
        super().__init__(device, lanes=(SubmissionLane(device, workers),),
                         pool=BufferPool(), coalesce=coalesce)


class ThreadPoolBackend(IOPlane):
    """User-level thread pool: same semantics, one crossing per request."""

    name = "user_threads"

    def __init__(self, device: Device, workers: int = 16,
                 coalesce: bool = False):
        super().__init__(
            device,
            lanes=(SubmissionLane(device, workers, per_request=True),),
            pool=BufferPool(),
            coalesce=coalesce,
        )


class MultiQueueBackend(IOPlane):
    """Per-device lanes over a :class:`ShardedDevice`.

    The engine sees the usual single prepare/submit/wait surface; internally
    each sub-device owns a lane sized ``workers`` (total concurrency =
    ``num_devices * workers``).  Chains route by the target shard of their
    head — never splitting across lanes — and every touched lane charges one
    crossing on its sub-device (one ``io_uring_enter`` per touched queue
    pair) plus the aggregate view.
    """

    name = "multi_queue"

    def __init__(self, device: Device, workers: int = 16,
                 coalesce: bool = False):
        if not isinstance(device, ShardedDevice):
            raise TypeError(
                "MultiQueueBackend requires a ShardedDevice "
                f"(got {type(device).__name__}); use 'io_uring' for flat devices"
            )
        # workers execute against the sharded device (vfd/namespace routing
        # happens there); the router decides *which* lane runs a chain and
        # which sub-device pays the crossing.
        super().__init__(
            device,
            lanes=[
                SubmissionLane(device, workers, crossing_device=sub,
                               aggregate=device.stats)
                for sub in device.devices
            ],
            router=self._route_head,
            pool=BufferPool(),
            coalesce=coalesce,
        )

    def _route_head(self, head: IORequest) -> int:
        dev: ShardedDevice = self.device  # type: ignore[assignment]
        try:
            return dev.route(head.sc, head.args)
        except OSError:
            return 0  # unknown fd (e.g. closed early): any lane can fail it


# ---------------------------------------------------------------------------
# Multi-tenant shared-backend scheduling
# ---------------------------------------------------------------------------
#: priority classes, ordered: higher value preempts lower-value speculation
PRIORITIES = {"low": 0, "normal": 1, "high": 2}


def resolve_priority(priority) -> int:
    if isinstance(priority, str):
        return PRIORITIES[priority]
    return int(priority)


class _TenantState:
    """Scheduler-side view of one tenant: its weight/priority, the live
    speculative-slot count, and the eviction-candidate ledger.

    ``spec_count`` is the authoritative occupancy (incremented at admission,
    decremented exactly once per request by the completion callback or by
    demand conversion); ``spec`` is only the *candidate list* for pressure
    eviction — it may lag behind (holding already-terminal or demanded
    entries, which eviction skips by flag) and is compacted amortized, so no
    path ever scans every tenant's whole ledger."""

    __slots__ = ("name", "weight", "priority", "views", "spec", "spec_count")

    def __init__(self, name: str, weight: float, priority: int):
        self.name = name
        self.weight = weight
        self.priority = priority
        self.views: set = set()
        # (request, owning view) — admitted speculation still worth evicting;
        # stale entries are skipped via req._spec_counted, not by scanning
        self.spec: List[Tuple[IORequest, "SharedBackend"]] = []
        self.spec_count = 0

    def compact(self) -> None:
        """Drop stale candidates once the list is far longer than the live
        count (amortized O(1) per admission)."""
        if len(self.spec) > 4 * self.spec_count + 16:
            self.spec = [(r, v) for (r, v) in self.spec
                         if getattr(r, "_spec_counted", False)]


class SlotScheduler:
    """Weighted-fair arbitration of one backend's submission slots.

    A *slot* is one speculative request in flight on the shared backend.
    Each tenant's share is ``capacity * weight / sum(active weights)``
    (at least 1); admission is per link chain (chains never split) and is
    denied once the tenant is at its share or the backend at capacity —
    denied chains stay staged in the tenant's view until capacity frees or
    the frontier *demands* them, at which point they bypass the budget
    entirely.  Under pressure, :meth:`make_room` cancels speculative-only
    requests that have not started executing, lowest priority class first,
    most-over-share tenant first, newest request first (LIFO wastes the
    least already-paid queue time).  Total speculative occupancy therefore
    never exceeds ``capacity``: a demand request can never wait behind more
    than ``capacity`` speculative ones.

    **Admission is O(chain), independent of tenant count.**  The original
    implementation pruned every tenant's request ledger and re-summed every
    tenant's occupancy on each ``admit``/``make_room`` — O(tenants ×
    requests) per admission, which at open-loop scale (thousands of
    sessions) turns the scheduler itself into the bottleneck.  Occupancy is
    now pure counter maintenance: admission increments ``spec_count`` /
    ``_spec_total``, and every admitted request carries a completion
    callback (:mod:`repro.core.completion` fires it exactly once, on finish
    *or* cancel) that decrements them.  The active-weight sum behind the
    fair share is maintained incrementally at attach/detach.  Two locks,
    strictly ordered: ``_lock`` (outer — tenant table, candidate ledgers,
    active weight) and ``_count_lock`` (inner — the occupancy counters the
    completion callback touches; the callback takes only this one, so a
    worker finishing a request never contends with a long admission and
    never deadlocks against ``make_room`` cancelling under ``_lock``).
    """

    def __init__(self, capacity: int):
        self.capacity = max(1, int(capacity))
        self._lock = threading.Lock()
        self._count_lock = threading.Lock()
        self._tenants: Dict[str, _TenantState] = {}
        #: sum of weights of tenants with >= 1 attached view (under _lock)
        self._active_weight = 0.0
        #: authoritative speculative occupancy (under _count_lock)
        self._spec_total = 0
        #: tenants with spec_count > 0 — the only ones make_room must look
        #: at; bounded by capacity, not by tenant count (under _count_lock)
        self._spec_tenants: set = set()
        #: tenant names whose last slot freed after their last view detached
        #: (the callback cannot take _lock, so it queues the reap and the
        #: next attach/detach/admit sweeps it — the tenant table stays
        #: bounded by *live* tenants even at 10k sessions)
        self._reap: List[str] = []
        # observability (tests + bench report)
        self.max_spec_inflight = 0
        self.admitted = 0
        self.deferred = 0
        self.evictions = 0
        self.demand_promotions = 0

    # -- tenant lifecycle ---------------------------------------------------
    def _reap_idle(self) -> None:
        """Drop tenants whose last slot freed after detach (under _lock)."""
        with self._count_lock:
            names, self._reap = self._reap, []
            for name in names:
                t = self._tenants.get(name)
                if t is not None and not t.views and t.spec_count == 0:
                    self._spec_tenants.discard(t)
                    del self._tenants[name]

    def attach(self, view: "SharedBackend") -> None:
        with self._lock:
            self._reap_idle()
            t = self._tenants.get(view.tenant)
            if t is None:
                t = _TenantState(view.tenant, view.weight, view.priority)
                self._tenants[view.tenant] = t
                self._active_weight += t.weight
            else:
                # latest activation's weight/priority wins for the tenant
                if t.views:
                    self._active_weight += view.weight - t.weight
                else:
                    self._active_weight += view.weight
                t.weight = view.weight
                t.priority = view.priority
            t.views.add(view)

    def detach(self, view: "SharedBackend") -> None:
        with self._lock:
            t = self._tenants.get(view.tenant)
            if t is None:
                return
            had = view in t.views
            t.views.discard(view)
            if had and not t.views:
                self._active_weight -= t.weight
            with self._count_lock:
                if not t.views and t.spec_count == 0:
                    self._spec_tenants.discard(t)
                    del self._tenants[view.tenant]
            self._reap_idle()

    # -- shares -------------------------------------------------------------
    def _share_of(self, t: _TenantState) -> int:
        """Fair share from the incrementally maintained active-weight sum —
        O(1), requires _lock."""
        active_w = max(self._active_weight, t.weight, 1e-9)
        return max(1, int(self.capacity * t.weight / active_w))

    def fair_share(self, tenant: str) -> int:
        with self._lock:
            t = self._tenants.get(tenant)
            if t is None:
                return self.capacity
            return self._share_of(t)

    # -- completion accounting ---------------------------------------------
    def _spec_done(self, req: IORequest) -> None:
        """Completion callback: release the slot this request held.  Fired
        exactly once per admitted request (finish or cancel, whichever comes
        first — the completion pool guarantees the swap); requests already
        converted to demand carry a cleared flag and fall through."""
        with self._count_lock:
            if not getattr(req, "_spec_counted", False):
                return
            req._spec_counted = False
            ten: _TenantState = req._spec_tenant
            ten.spec_count -= 1
            self._spec_total -= 1
            if ten.spec_count == 0:
                self._spec_tenants.discard(ten)
                if not ten.views:
                    self._reap.append(ten.name)

    # -- admission ----------------------------------------------------------
    def admit(self, view: "SharedBackend",
              chains: List[List[IORequest]]) -> Tuple[List[List[IORequest]],
                                                      List[List[IORequest]]]:
        """Partition ``chains`` into (admitted, deferred).  Whole chains
        only; an over-length chain is still admitted when the tenant holds
        no slots at all (a tenant is never locked out of speculation
        entirely by a share smaller than its shortest chain)."""
        with self._lock:
            ten = self._tenants.get(view.tenant)
            if ten is None:  # detached view: nothing speculates anymore
                return [], chains
            share = self._share_of(ten)
            admitted: List[List[IORequest]] = []
            deferred: List[List[IORequest]] = []
            with self._count_lock:
                total = self._spec_total
                cnt = ten.spec_count
                for chain in chains:
                    n = len(chain)
                    fits_share = cnt + n <= share or cnt == 0
                    if fits_share and total + n <= self.capacity:
                        cnt += n
                        total += n
                        admitted.append(chain)
                        self.admitted += n
                    else:
                        deferred.append(chain)
                        # count each chain's first denial only: deferred
                        # chains are re-offered on every wait/flush, and
                        # counting the retries would inflate the metric by
                        # orders of magnitude
                        if not getattr(chain[0], "_defer_counted", False):
                            chain[0]._defer_counted = True
                            self.deferred += n
                if admitted:
                    ten.spec_count = cnt
                    self._spec_total = total
                    self._spec_tenants.add(ten)
                if total > self.max_spec_inflight:
                    self.max_spec_inflight = total
            # hook the slot release before the caller dispatches.  No
            # worker can touch these yet (the candidate append below is
            # what exposes them to eviction, and we still hold _lock), but
            # an IOFuture holds a direct reference to its request and may
            # cancel() it at any time — e.g. multi_get abandoning a tail
            # read whose chain was still deferred.  The stripe lock
            # serializes the hook against that terminal transition: a
            # request observed done here never takes a slot (its callback
            # already fired as a no-op and will never fire again).
            dead = 0
            for chain in admitted:
                for r in chain:
                    s = completion_pool().stripe(r)
                    with s.lock:
                        if r._done:
                            dead += 1
                            continue
                        r._spec_tenant = ten
                        r._spec_counted = True
                        r.completion_cb = self._spec_done
                    ten.spec.append((r, view))
            if dead:
                with self._count_lock:
                    ten.spec_count -= dead
                    self._spec_total -= dead
                    if ten.spec_count == 0:
                        self._spec_tenants.discard(ten)
            ten.compact()
            return admitted, deferred

    # -- demand -------------------------------------------------------------
    def note_demanded(self, view: "SharedBackend", req: IORequest) -> None:
        """A speculative request just became demanded (the frontier reached
        it): it no longer counts against anyone's budget and must never be
        evicted.  Clearing the flag both releases the slot now and turns the
        still-attached completion callback into a no-op (exactly-once)."""
        with self._count_lock:
            if not getattr(req, "_spec_counted", False):
                return
            req._spec_counted = False
            ten: _TenantState = req._spec_tenant
            ten.spec_count -= 1
            self._spec_total -= 1
            if ten.spec_count == 0:
                self._spec_tenants.discard(ten)

    def make_room(self, need: int = 1) -> int:
        """Pressure-triggered cancellation: free ``need`` slots for demand
        I/O by cancelling speculative requests that have not started
        executing.  Victim order: priority class ascending, occupancy/share
        ratio descending, newest request first.  Returns #evicted.

        The no-pressure fast path is one counter read; under pressure only
        tenants actually holding slots (``_spec_tenants``, bounded by
        capacity) are examined.  ``req.cancel()`` is issued outside
        ``_count_lock`` because it fires the slot-release callback, which
        takes ``_count_lock`` itself."""
        with self._count_lock:
            if self._spec_total + need <= self.capacity:
                return 0
        evicted = 0
        with self._lock:
            while True:
                with self._count_lock:
                    if self._spec_total + need <= self.capacity:
                        break
                    victims = sorted(
                        self._spec_tenants,
                        key=lambda t: (t.priority,
                                       -t.spec_count / self._share_of(t),
                                       t.name))
                progressed = False
                for t in victims:
                    for i in range(len(t.spec) - 1, -1, -1):
                        req, _view = t.spec[i]
                        if not getattr(req, "_spec_counted", False):
                            continue  # demanded or already terminal: immune
                        if req.state is not ReqState.PREPARED:
                            continue  # a worker is already running it
                        if req.cancel():  # atomic; fires the slot release
                            t.spec.pop(i)
                            self.evictions += 1
                            evicted += 1
                            progressed = True
                            break
                    if progressed:
                        break
                if not progressed:  # racing workers picked everything up
                    break
        return evicted

    def note_promotion(self) -> None:
        with self._lock:
            self.demand_promotions += 1

    def snapshot(self) -> Dict[str, int]:
        with self._lock, self._count_lock:
            return {
                "capacity": self.capacity,
                "tenants": len(self._tenants),
                "spec_inflight": self._spec_total,
                "max_spec_inflight": self.max_spec_inflight,
                "admitted": self.admitted,
                "deferred": self.deferred,
                "evictions": self.evictions,
                "demand_promotions": self.demand_promotions,
            }


class SharedBackend(Backend):
    """One session's lease on a shared async backend.

    Implements the engine-facing ``Backend`` surface, but every submission
    passes through the :class:`SlotScheduler`: prepared entries stage in a
    per-view queue, ``submit_all`` asks for slots chain-by-chain, and chains
    the scheduler defers stay staged until capacity frees or the frontier
    demands one of their requests — ``wait`` then *promotes* the chain past
    the budget (demand beats speculation, always).  ``cancel_remaining`` and
    ``drain`` are view-scoped: they touch only this session's requests, so
    one tenant tearing down never cancels or blocks on another tenant's
    work.
    """

    name = "shared"
    is_view = True

    def __init__(self, inner: IOPlane, scheduler: SlotScheduler,
                 tenant: str, weight: float = 1.0, priority=1):
        super().__init__(inner.device)
        self.inner = inner
        self.scheduler = scheduler
        self.tenant = tenant
        self.weight = float(weight)
        self.priority = resolve_priority(priority)
        self._lock = threading.Lock()
        self._sq: List[IORequest] = []  # prepared, not yet offered
        self._deferred: List[List[IORequest]] = []  # offered, denied slots
        self._submitted: List[IORequest] = []  # admitted or promoted
        self._closed = False
        scheduler.attach(self)

    # the adaptive depth controller gates growth on capacity/inflight; for a
    # view both are tenant-scoped: the fair share and this session's own
    # speculative occupancy.
    @property
    def capacity(self) -> int:  # type: ignore[override]
        return self.scheduler.fair_share(self.tenant)

    def spec_budget(self) -> Optional[int]:
        return self.scheduler.fair_share(self.tenant)

    def inflight(self) -> int:
        with self._lock:
            self._submitted = [r for r in self._submitted if not r.is_done()]
            return len(self._submitted) + sum(len(c) for c in self._deferred)

    #: priority stamp for demand-promoted chains: above every priority
    #: class, so promoted demand never queues behind anyone's speculation
    DEMAND_BOOST = 1 << 20

    def prepare(self, req: IORequest) -> None:
        req.priority = self.priority  # tenant class orders the worker queue
        req.tenant = self.tenant  # buffer leases charge this tenant's budget
        with self._lock:
            self._sq.append(req)

    def submit_all(self) -> int:
        with self._lock:
            batch, self._sq = self._sq, []
            if batch:
                self._deferred.extend(_chains(batch))
        return self._flush_deferred()

    def submit(self, batch: List[IORequest]) -> int:
        """The engine's single-call batch path: stamp the tenant's priority
        class, stage the chains, offer them to the scheduler — one lock
        acquisition, same admission semantics as prepare()+submit_all()."""
        if not batch:
            return 0
        for req in batch:
            req.priority = self.priority
            req.tenant = self.tenant
        with self._lock:
            self._deferred.extend(_chains(batch))
        return self._flush_deferred()

    def _flush_deferred(self) -> int:
        """Offer every staged chain to the scheduler and dispatch whatever
        it admits; chains denied slots go back to the staging queue.  The
        admitted set is dispatched as one flat batch — chain boundaries
        survive concatenation (a chain's last request has link=False), so
        this costs one crossing, like a private backend's submit_all."""
        with self._lock:
            chains, self._deferred = self._deferred, []
        if not chains:
            return 0
        # drop requests that went terminal while staged (a cancelled
        # IOFuture terminates its request in place) — re-offering them
        # would burn slots on work nobody will ever execute
        chains = [c for c in ([r for r in chain if not r.is_done()]
                              for chain in chains) if c]
        if not chains:
            return 0
        admitted, deferred = self.scheduler.admit(self, chains)
        with self._lock:
            self._deferred.extend(deferred)
        if not admitted:
            return 0
        batch = [r for chain in admitted for r in chain]
        n = self.inner.submit_batch(batch)
        with self._lock:
            self._submitted.extend(batch)
        return n

    def note_demand(self) -> None:
        """The session is about to run a demand op synchronously: shed
        speculative queue pressure so it is not stuck behind cold tenants'
        speculation, then give own deferred chains a chance."""
        self.scheduler.make_room(1)
        self._flush_deferred()

    def wait(self, req: IORequest):
        promoted: Optional[List[IORequest]] = None
        with self._lock:
            for i, chain in enumerate(self._deferred):
                if req in chain:
                    promoted = self._deferred.pop(i)
                    break
        if promoted is None:
            # completion frees slots: give deferred chains a chance before
            # blocking, so the pipeline refills without waiting for the
            # next prepare (otherwise a saturated tenant degenerates to
            # demand-at-a-time serial execution)
            self._flush_deferred()
        if promoted is not None:
            # demand promotion: bypass the speculation budget entirely —
            # evict other tenants' queued speculation if the queue is full,
            # and outrank every queued chain in the worker pool
            for r in promoted:
                r.priority = self.DEMAND_BOOST + self.priority
            self.scheduler.make_room(len(promoted))
            self.scheduler.note_promotion()
            self.inner.submit_batch(promoted)
            with self._lock:
                self._submitted.extend(promoted)
        else:
            self.scheduler.note_demanded(self, req)
        if req.fused is not None:
            # fused-satellite demand: if the carrier was evicted/cancelled
            # before scattering, serve this member's own extent inline
            req.fused.on_demand(self.device, req)
        try:
            return req.wait_result()
        except RuntimeError:
            if req.state is ReqState.CANCELLED and req.error is None:
                # evicted between the engine's state check and this wait:
                # serve it as demand inline.  Safe: eviction only cancels
                # PREPARED requests and workers skip anything not PREPARED,
                # so nobody else will ever execute it.
                self.device.charge_crossing()
                result = perform(self.device, req)
                req.finish(result)
                return result
            raise

    def cancel_remaining(self) -> int:
        with self._lock:
            pending, self._sq = self._sq, []
            for chain in self._deferred:
                pending.extend(chain)
            self._deferred = []
            submitted = list(self._submitted)
        n = 0
        for req in pending:
            if req.cancel():
                n += 1
        for req in submitted:
            if req.cancel():
                n += 1
        return n

    def drain(self) -> None:
        # view-scoped: wait for this session's submitted requests only —
        # never for other tenants' work on the shared pool.
        with self._lock:
            submitted = list(self._submitted)
        for req in submitted:
            req.wait_done()
        with self._lock:
            self._submitted = [r for r in self._submitted if not r.is_done()]

    def shutdown(self) -> None:
        """Release the lease (the inner backend is owned by the Foreactor
        and outlives every view)."""
        if self._closed:
            return
        self._closed = True
        self.scheduler.detach(self)


BACKENDS = {
    "sync": SyncBackend,
    "io_uring": QueuePairBackend,
    "user_threads": ThreadPoolBackend,
    "multi_queue": MultiQueueBackend,
}


def make_backend(name: str, device: Device, workers: int = 16,
                 coalesce: bool = False) -> Backend:
    """Instantiate a backend by name.

    ``name="auto"`` picks the best match for the device topology: per-device
    queue pairs for a :class:`ShardedDevice`, a single io_uring-style queue
    pair otherwise.  ``coalesce=True`` enables the plane's extent coalescer
    (ignored by the sync backend — the oracle never rewrites requests).
    """
    if name == "auto":
        name = "multi_queue" if isinstance(device, ShardedDevice) else "io_uring"
    cls = BACKENDS[name]
    if cls is SyncBackend:
        return cls(device)
    return cls(device, workers=workers, coalesce=coalesce)
