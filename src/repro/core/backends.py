"""Asynchronous syscall backends (paper §2.3, §5.4).

``QueuePairBackend`` reproduces io_uring's semantics: a submission queue
filled without kernel involvement, a single boundary crossing per submitted
batch (``io_uring_enter``), an in-process ``io_workqueue`` worker pool that
may execute entries in parallel, request *linking* to force ordered
execution of chains, and completion harvesting that costs no crossing.

``ThreadPoolBackend`` is the paper's user-level thread-pool alternative:
identical engine-facing semantics, but each request costs its own boundary
crossing (it is an ordinary blocking syscall on some thread).

``SyncBackend`` degenerates to synchronous in-place execution and is the
no-speculation baseline.

``MultiQueueBackend`` is the sharded extension: one queue pair + worker pool
per sub-device of a :class:`repro.core.device.ShardedDevice`.  ``prepare``
stays a single engine-facing submission queue, but ``submit_all`` partitions
the batch by target device (link chains stay whole, routed by their head) and
pays one boundary crossing *per sub-device touched* — N parallel
``io_uring_enter`` calls instead of one global queue, so independent requests
ride independent execution resources and aggregate bandwidth scales with
device count.

Cross-references: docs/ARCHITECTURE.md ("Backends", "Sharded multi-device
substrate") maps this module to paper §2.3/§5.4; see docs/GLOSSARY.md for
*queue-pair crossing* and *link flag*.
"""

from __future__ import annotations

import queue
import threading
from typing import List, Optional

from .device import Device, ShardedDevice
from .syscalls import IORequest, ReqState, Sys, execute


class Backend:
    """Engine-facing interface — identical across backends (paper Table 1)."""

    name = "abstract"
    #: requests this backend can usefully run at once (worker count, summed
    #: across queue pairs); 0 = no async execution.  The adaptive depth
    #: controller stops growing once occupancy reaches this.
    capacity = 0

    def __init__(self, device: Device):
        self.device = device

    def inflight(self) -> int:
        """Submitted-but-incomplete request count (queue occupancy)."""
        return 0

    def prepare(self, req: IORequest) -> None:
        raise NotImplementedError

    def submit_all(self) -> int:
        """Make prepared requests eligible to run; returns #submitted."""
        raise NotImplementedError

    def wait(self, req: IORequest):
        raise NotImplementedError

    def cancel_remaining(self) -> int:
        """Cancel every request not yet executing (early exit, paper §6.4)."""
        raise NotImplementedError

    def drain(self) -> None:
        """Block until nothing is in flight (session teardown)."""
        raise NotImplementedError

    def shutdown(self) -> None:
        pass


class SyncBackend(Backend):
    """No speculation: requests execute at wait()."""

    name = "sync"

    def __init__(self, device: Device):
        super().__init__(device)
        self._prepared: List[IORequest] = []

    def prepare(self, req: IORequest) -> None:
        self._prepared.append(req)

    def submit_all(self) -> int:
        n = len(self._prepared)
        self._prepared.clear()  # sync backend never runs anything early
        return 0 if n else 0

    def wait(self, req: IORequest):
        self.device.charge_crossing()
        req.finish(execute(self.device, req.sc, req.args))
        return req.wait_result()

    def cancel_remaining(self) -> int:
        n = len(self._prepared)
        self._prepared.clear()
        return n

    def drain(self) -> None:
        pass


class _WorkerPool:
    """Shared worker-pool machinery (the 'io_workqueue')."""

    def __init__(self, device: Device, workers: int):
        self.device = device
        self._q: "queue.Queue[Optional[List[IORequest]]]" = queue.Queue()
        self._inflight = 0
        self._lock = threading.Lock()
        self._idle = threading.Condition(self._lock)
        self._threads = [
            threading.Thread(target=self._run, name=f"io_workqueue-{i}", daemon=True)
            for i in range(workers)
        ]
        for t in self._threads:
            t.start()
        self._shutdown = False

    def push_chain(self, chain: List[IORequest]) -> None:
        with self._lock:
            self._inflight += 1
        self._q.put(chain)

    def _run(self) -> None:
        while True:
            chain = self._q.get()
            if chain is None:
                return
            try:
                for req in chain:
                    if req.state is ReqState.CANCELLED:
                        continue
                    req.state = ReqState.SUBMITTED
                    try:
                        req.finish(execute(self.device, req.sc, req.args))
                    except BaseException as e:  # propagate to the waiter
                        req.finish(error=e)
                        # a failed link head breaks the chain (io_uring semantics)
                        for rest in chain[chain.index(req) + 1 :]:
                            rest.cancel()
                        break
            finally:
                with self._lock:
                    self._inflight -= 1
                    if self._inflight == 0:
                        self._idle.notify_all()

    def drain(self) -> None:
        with self._lock:
            while self._inflight > 0:
                self._idle.wait()

    def shutdown(self) -> None:
        if self._shutdown:
            return
        self._shutdown = True
        for _ in self._threads:
            self._q.put(None)
        for t in self._threads:
            t.join(timeout=5)


def _chains(batch: List[IORequest]) -> List[List[IORequest]]:
    """Group a submitted batch into link chains (io_uring IOSQE_IO_LINK): a
    req with link=True executes before its successor, on the same worker."""
    chains: List[List[IORequest]] = []
    chain: List[IORequest] = []
    for req in batch:
        chain.append(req)
        if not req.link:
            chains.append(chain)
            chain = []
    if chain:  # trailing link=True at batch end — still a chain
        chains.append(chain)
    return chains


class _AsyncBackend(Backend):
    """Shared SQ/CQ machinery of the async backends: a submission queue, the
    submitted-request ledger, and event-based completion harvesting.
    Subclasses define ``_dispatch`` (crossing accounting + routing chains to
    their worker pools) and own their pool lifecycle."""

    def __init__(self, device: Device):
        super().__init__(device)
        self._sq: List[IORequest] = []
        self._submitted: List[IORequest] = []

    def inflight(self) -> int:
        # prune completed entries while counting, keeping the ledger short
        self._submitted = [r for r in self._submitted if not r.done.is_set()]
        return len(self._submitted)

    def prepare(self, req: IORequest) -> None:
        self._sq.append(req)

    def _dispatch(self, batch: List[IORequest]) -> None:
        raise NotImplementedError

    def _pools(self) -> List[_WorkerPool]:
        raise NotImplementedError

    def submit_all(self) -> int:
        if not self._sq:
            return 0
        batch, self._sq = self._sq, []
        self._dispatch(batch)
        self._submitted.extend(batch)
        return len(batch)

    def wait(self, req: IORequest):
        return req.wait_result()

    def cancel_remaining(self) -> int:
        n = 0
        for req in self._sq:
            if req.cancel():
                n += 1
        self._sq.clear()
        for req in self._submitted:
            if req.cancel():
                n += 1
        return n

    def drain(self) -> None:
        for pool in self._pools():
            pool.drain()
        self._submitted = [r for r in self._submitted if not r.done.is_set()]

    def shutdown(self) -> None:
        for pool in self._pools():
            pool.shutdown()


class QueuePairBackend(_AsyncBackend):
    """io_uring analogue: SQ/CQ queue pair + in-process io_workqueue.

    prepare() fills SQ entries with no crossings; submit_all() costs exactly
    one boundary crossing for the whole batch; completions are harvested by
    waiting on the request's event (CQ poll — no crossing).
    """

    name = "io_uring"

    def __init__(self, device: Device, workers: int = 16):
        super().__init__(device)
        self.capacity = workers
        self._pool = _WorkerPool(device, workers)

    def _pools(self) -> List[_WorkerPool]:
        return [self._pool]

    def _dispatch(self, batch: List[IORequest]) -> None:
        self.device.charge_crossing()  # the single io_uring_enter()
        for chain in _chains(batch):
            self._pool.push_chain(chain)


class ThreadPoolBackend(_AsyncBackend):
    """User-level thread pool: same semantics, one crossing per request."""

    name = "user_threads"

    def __init__(self, device: Device, workers: int = 16):
        super().__init__(device)
        self.capacity = workers
        self._pool = _WorkerPool(device, workers)

    def _pools(self) -> List[_WorkerPool]:
        return [self._pool]

    def _dispatch(self, batch: List[IORequest]) -> None:
        for req in batch:
            self.device.charge_crossing()  # every request is its own syscall
        for chain in _chains(batch):
            self._pool.push_chain(chain)


class MultiQueueBackend(_AsyncBackend):
    """Per-device queue pairs over a :class:`ShardedDevice`.

    The engine sees the usual single prepare/submit/wait surface; internally
    each sub-device owns a queue pair and an io_workqueue sized ``workers``
    (total concurrency = ``num_devices * workers``).  ``submit_all``
    partitions the batch by the target shard of each link chain's head —
    chains never split across queues, preserving io_uring link ordering —
    and charges one crossing on every sub-device that received entries
    (one ``io_uring_enter`` per touched queue pair).
    """

    name = "multi_queue"

    def __init__(self, device: Device, workers: int = 16):
        if not isinstance(device, ShardedDevice):
            raise TypeError(
                "MultiQueueBackend requires a ShardedDevice "
                f"(got {type(device).__name__}); use 'io_uring' for flat devices"
            )
        super().__init__(device)
        # workers execute against the sharded device (vfd/namespace routing
        # happens there); the partition decides *which* pool runs a chain and
        # which sub-device pays the crossing.
        self.capacity = workers * len(device.devices)
        self._queue_pools = [_WorkerPool(device, workers) for _ in device.devices]

    def _pools(self) -> List[_WorkerPool]:
        return self._queue_pools

    def _dispatch(self, batch: List[IORequest]) -> None:
        dev: ShardedDevice = self.device  # type: ignore[assignment]
        routed: List[tuple] = []
        touched: set = set()
        for chain in _chains(batch):
            head = chain[0]
            try:
                qi = dev.route(head.sc, head.args)
            except OSError:
                qi = 0  # unknown fd (e.g. closed early): any queue can fail it
            routed.append((qi, chain))
            touched.add(qi)
        for qi in sorted(touched):
            dev.devices[qi].charge_crossing()  # one enter() per queue pair
            dev.stats.crossing()  # keep the aggregate view consistent
        for qi, chain in routed:
            self._queue_pools[qi].push_chain(chain)


BACKENDS = {
    "sync": SyncBackend,
    "io_uring": QueuePairBackend,
    "user_threads": ThreadPoolBackend,
    "multi_queue": MultiQueueBackend,
}


def make_backend(name: str, device: Device, workers: int = 16) -> Backend:
    """Instantiate a backend by name.

    ``name="auto"`` picks the best match for the device topology: per-device
    queue pairs for a :class:`ShardedDevice`, a single io_uring-style queue
    pair otherwise.
    """
    if name == "auto":
        name = "multi_queue" if isinstance(device, ShardedDevice) else "io_uring"
    cls = BACKENDS[name]
    if cls is SyncBackend:
        return cls(device)
    return cls(device, workers=workers)
