"""Asynchronous syscall backends (paper §2.3, §5.4).

``QueuePairBackend`` reproduces io_uring's semantics: a submission queue
filled without kernel involvement, a single boundary crossing per submitted
batch (``io_uring_enter``), an in-process ``io_workqueue`` worker pool that
may execute entries in parallel, request *linking* to force ordered
execution of chains, and completion harvesting that costs no crossing.

``ThreadPoolBackend`` is the paper's user-level thread-pool alternative:
identical engine-facing semantics, but each request costs its own boundary
crossing (it is an ordinary blocking syscall on some thread).

``SyncBackend`` degenerates to synchronous in-place execution and is the
no-speculation baseline.
"""

from __future__ import annotations

import queue
import threading
from typing import List, Optional

from .device import Device
from .syscalls import IORequest, ReqState, Sys, execute


class Backend:
    """Engine-facing interface — identical across backends (paper Table 1)."""

    name = "abstract"

    def __init__(self, device: Device):
        self.device = device

    def prepare(self, req: IORequest) -> None:
        raise NotImplementedError

    def submit_all(self) -> int:
        """Make prepared requests eligible to run; returns #submitted."""
        raise NotImplementedError

    def wait(self, req: IORequest):
        raise NotImplementedError

    def cancel_remaining(self) -> int:
        """Cancel every request not yet executing (early exit, paper §6.4)."""
        raise NotImplementedError

    def drain(self) -> None:
        """Block until nothing is in flight (session teardown)."""
        raise NotImplementedError

    def shutdown(self) -> None:
        pass


class SyncBackend(Backend):
    """No speculation: requests execute at wait()."""

    name = "sync"

    def __init__(self, device: Device):
        super().__init__(device)
        self._prepared: List[IORequest] = []

    def prepare(self, req: IORequest) -> None:
        self._prepared.append(req)

    def submit_all(self) -> int:
        n = len(self._prepared)
        self._prepared.clear()  # sync backend never runs anything early
        return 0 if n else 0

    def wait(self, req: IORequest):
        self.device.charge_crossing()
        req.finish(execute(self.device, req.sc, req.args))
        return req.wait_result()

    def cancel_remaining(self) -> int:
        n = len(self._prepared)
        self._prepared.clear()
        return n

    def drain(self) -> None:
        pass


class _WorkerPool:
    """Shared worker-pool machinery (the 'io_workqueue')."""

    def __init__(self, device: Device, workers: int):
        self.device = device
        self._q: "queue.Queue[Optional[List[IORequest]]]" = queue.Queue()
        self._inflight = 0
        self._lock = threading.Lock()
        self._idle = threading.Condition(self._lock)
        self._threads = [
            threading.Thread(target=self._run, name=f"io_workqueue-{i}", daemon=True)
            for i in range(workers)
        ]
        for t in self._threads:
            t.start()
        self._shutdown = False

    def push_chain(self, chain: List[IORequest]) -> None:
        with self._lock:
            self._inflight += 1
        self._q.put(chain)

    def _run(self) -> None:
        while True:
            chain = self._q.get()
            if chain is None:
                return
            try:
                for req in chain:
                    if req.state is ReqState.CANCELLED:
                        continue
                    req.state = ReqState.SUBMITTED
                    try:
                        req.finish(execute(self.device, req.sc, req.args))
                    except BaseException as e:  # propagate to the waiter
                        req.finish(error=e)
                        # a failed link head breaks the chain (io_uring semantics)
                        for rest in chain[chain.index(req) + 1 :]:
                            rest.cancel()
                        break
            finally:
                with self._lock:
                    self._inflight -= 1
                    if self._inflight == 0:
                        self._idle.notify_all()

    def drain(self) -> None:
        with self._lock:
            while self._inflight > 0:
                self._idle.wait()

    def shutdown(self) -> None:
        if self._shutdown:
            return
        self._shutdown = True
        for _ in self._threads:
            self._q.put(None)
        for t in self._threads:
            t.join(timeout=5)


class QueuePairBackend(Backend):
    """io_uring analogue: SQ/CQ queue pair + in-process io_workqueue.

    prepare() fills SQ entries with no crossings; submit_all() costs exactly
    one boundary crossing for the whole batch; completions are harvested by
    waiting on the request's event (CQ poll — no crossing).
    """

    name = "io_uring"

    def __init__(self, device: Device, workers: int = 16):
        super().__init__(device)
        self._sq: List[IORequest] = []
        self._pool = _WorkerPool(device, workers)
        self._submitted: List[IORequest] = []

    def prepare(self, req: IORequest) -> None:
        self._sq.append(req)

    def submit_all(self) -> int:
        if not self._sq:
            return 0
        self.device.charge_crossing()  # the single io_uring_enter()
        batch, self._sq = self._sq, []
        # group linked runs: a req with link=True executes before its successor
        chain: List[IORequest] = []
        for req in batch:
            chain.append(req)
            if not req.link:
                self._pool.push_chain(chain)
                chain = []
        if chain:  # trailing link=True at batch end — still a chain
            self._pool.push_chain(chain)
        self._submitted.extend(batch)
        return len(batch)

    def wait(self, req: IORequest):
        return req.wait_result()

    def cancel_remaining(self) -> int:
        n = 0
        for req in self._sq:
            if req.cancel():
                n += 1
        self._sq.clear()
        for req in self._submitted:
            if req.cancel():
                n += 1
        return n

    def drain(self) -> None:
        self._pool.drain()
        self._submitted = [r for r in self._submitted if not r.done.is_set()]

    def shutdown(self) -> None:
        self._pool.shutdown()


class ThreadPoolBackend(Backend):
    """User-level thread pool: same semantics, one crossing per request."""

    name = "user_threads"

    def __init__(self, device: Device, workers: int = 16):
        super().__init__(device)
        self._sq: List[IORequest] = []
        self._pool = _WorkerPool(device, workers)
        self._submitted: List[IORequest] = []

    def prepare(self, req: IORequest) -> None:
        self._sq.append(req)

    def submit_all(self) -> int:
        if not self._sq:
            return 0
        batch, self._sq = self._sq, []
        chain: List[IORequest] = []
        for req in batch:
            self.device.charge_crossing()  # every request is its own syscall
            chain.append(req)
            if not req.link:
                self._pool.push_chain(chain)
                chain = []
        if chain:
            self._pool.push_chain(chain)
        self._submitted.extend(batch)
        return len(batch)

    def wait(self, req: IORequest):
        return req.wait_result()

    def cancel_remaining(self) -> int:
        n = 0
        for req in self._sq:
            if req.cancel():
                n += 1
        self._sq.clear()
        for req in self._submitted:
            if req.cancel():
                n += 1
        return n

    def drain(self) -> None:
        self._pool.drain()
        self._submitted = [r for r in self._submitted if not r.done.is_set()]

    def shutdown(self) -> None:
        self._pool.shutdown()


BACKENDS = {
    "sync": SyncBackend,
    "io_uring": QueuePairBackend,
    "user_threads": ThreadPoolBackend,
}


def make_backend(name: str, device: Device, workers: int = 16) -> Backend:
    cls = BACKENDS[name]
    if cls is SyncBackend:
        return cls(device)
    return cls(device, workers=workers)
