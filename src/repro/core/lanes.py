"""Submission lanes: the execution machinery under the I/O plane.

A :class:`SubmissionLane` is one queue pair — an in-process ``io_workqueue``
worker pool (:class:`_WorkerPool`) plus the crossing policy for how entries
reach it.  :class:`repro.core.backends.IOPlane` owns the submission queue
and ledger and routes whole link chains here; this module owns execution:
priority-ordered dispatch, chain link semantics (a failed head cancels its
dependents), claim/cancel atomicity against early exits and scheduler
eviction, and batched ring fills (one lock acquisition per submitted
batch).

Cross-references: docs/ARCHITECTURE.md ("Plan compilation & the unified I/O
plane"); *submission lane* and *queue-pair crossing* are defined in
docs/GLOSSARY.md.
"""

from __future__ import annotations

import heapq
import threading
from typing import List, Optional, Sequence, Tuple

from .completion import CompletionPool, completion_pool  # noqa: F401 — the
# lane-level completion machinery (CQ analogue) lives in repro.core.completion;
# re-exported here because lanes are where completions are produced
from .device import Device, DeviceStats
from .syscalls import IORequest, perform


class _WorkerPool:
    """Shared worker-pool machinery (the 'io_workqueue').

    The queue is priority-ordered (FIFO within a priority level via the
    sequence counter): a multi-tenant backend stamps requests with their
    tenant's priority class, so a hot tenant's chains never wait behind a
    cold tenant's queued speculation.  Single-tenant backends leave every
    request at priority 0 — plain FIFO, as before.

    Submission is *batched*: :meth:`push_chains` enqueues a whole
    submission's chains under one lock acquisition and wakes at most one
    waiter per queued chain — the in-process analogue of filling the SQ
    ring and crossing once, and the difference between O(chains) and O(1)
    lock traffic on the engine's measured peek path (a per-chain
    ``PriorityQueue.put`` costs a mutex round-trip + condition signal per
    chain, which under 16 running workers dominates the submission cost the
    paper's Fig. 10 attributes to the pre-issuing algorithm).
    """

    _SHUTDOWN_PRIORITY = -(1 << 30)  # drains after all real work

    def __init__(self, device: Device, workers: int):
        self.device = device
        self._heap: List[Tuple[int, int, Optional[List[IORequest]]]] = []
        self._seq = 0
        self._inflight = 0
        self._lock = threading.Lock()
        self._ready = threading.Condition(self._lock)
        self._idle = threading.Condition(self._lock)
        self._threads = [
            threading.Thread(target=self._run, name=f"io_workqueue-{i}", daemon=True)
            for i in range(workers)
        ]
        for t in self._threads:
            t.start()
        self._shutdown = False

    def push_chains(self, chains: Sequence[List[IORequest]]) -> None:
        """Enqueue every chain of one submitted batch: one lock acquisition
        for the whole batch (the SQ-ring fill), then wake workers."""
        if not chains:
            return
        with self._lock:
            self._inflight += len(chains)
            seq = self._seq
            for chain in chains:
                heapq.heappush(self._heap, (-chain[0].priority, seq, chain))
                seq += 1
            self._seq = seq
            if len(chains) == 1:
                self._ready.notify()
            else:
                self._ready.notify_all()

    def push_chain(self, chain: List[IORequest]) -> None:
        self.push_chains((chain,))

    def _run(self) -> None:
        while True:
            with self._lock:
                while not self._heap:
                    self._ready.wait()
                _prio, _seq, chain = heapq.heappop(self._heap)
            if chain is None:
                return
            try:
                for req in chain:
                    # atomically claim the request; a failed claim means it
                    # was cancelled (early exit / scheduler eviction) or
                    # served inline by a demand promotion — executing it here
                    # would double a side effect.
                    if not req.claim():
                        continue
                    try:
                        req.finish(perform(self.device, req))
                    except BaseException as e:  # propagate to the waiter
                        req.finish(error=e)
                        # a failed link head breaks the chain (io_uring semantics)
                        for rest in chain[chain.index(req) + 1 :]:
                            rest.cancel()
                        break
            finally:
                with self._lock:
                    self._inflight -= 1
                    if self._inflight == 0:
                        self._idle.notify_all()

    def drain(self) -> None:
        with self._lock:
            while self._inflight > 0:
                self._idle.wait()

    def shutdown(self) -> None:
        if self._shutdown:
            return
        self._shutdown = True
        with self._lock:
            for _ in self._threads:
                seq = self._seq
                self._seq += 1
                heapq.heappush(self._heap,
                               (-self._SHUTDOWN_PRIORITY, seq, None))
            self._ready.notify_all()
        for t in self._threads:
            t.join(timeout=5)


class SubmissionLane:
    """One queue pair of the I/O plane: an io_workqueue plus the crossing
    policy that models how entries enter it.

    ``exec_device`` is what the workers execute requests against (for a
    sharded plane this is the *sharded* device — vfd/namespace routing
    happens inside it); ``crossing_device`` is who pays the boundary
    crossing (the owning sub-device on a sharded plane).  ``per_request``
    selects the thread-pool cost model (one crossing per entry) over the
    io_uring one (one crossing per submitted batch); ``aggregate`` is an
    optional extra stats sink so a sharded device's aggregate crossing
    count stays consistent with its sub-devices'.
    """

    __slots__ = ("workers", "per_request", "crossing_device", "aggregate",
                 "_pool")

    def __init__(self, exec_device: Device, workers: int,
                 per_request: bool = False,
                 crossing_device: Optional[Device] = None,
                 aggregate: Optional[DeviceStats] = None):
        self.workers = workers
        self.per_request = per_request
        self.crossing_device = crossing_device if crossing_device is not None \
            else exec_device
        self.aggregate = aggregate
        self._pool = _WorkerPool(exec_device, workers)

    def charge(self, n_requests: int) -> None:
        """Pay this submission's boundary crossings (one ``io_uring_enter``
        for the whole batch, or one syscall per request)."""
        if self.per_request:
            for _ in range(n_requests):
                self.crossing_device.charge_crossing()
        else:
            self.crossing_device.charge_crossing()
        if self.aggregate is not None:
            self.aggregate.crossing()

    def push(self, chain: List[IORequest]) -> None:
        self._pool.push_chain(chain)

    def push_batch(self, chains: Sequence[List[IORequest]]) -> None:
        """All of one submission's chains in one workqueue lock acquisition."""
        self._pool.push_chains(chains)

    def drain(self) -> None:
        self._pool.drain()

    def shutdown(self) -> None:
        self._pool.shutdown()
