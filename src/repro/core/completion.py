"""Pooled completion: shared wait machinery for ``IORequest`` (CQ analogue).

The original runtime gave every :class:`repro.core.syscalls.IORequest` its
own ``threading.Event`` *and* its own claim lock — two lock allocations per
request, paid even by speculative requests nobody ever waits on.  At serving
scale that is the dominant per-record constant (``results/overhead.json``):
an open-loop run at thousands of in-flight sessions allocates tens of
thousands of kernel-backed locks per second just to mostly never touch them.

io_uring does not do this: completions post to one shared completion queue
and waiters park on *it*, not on per-SQE state.  This module is that idea at
the Python level — a fixed array of *stripes* (lock + condition + waiter
count) shared by all requests.  A request is mapped to its stripe by
identity hash; its completion flag is a plain attribute (safe to read
lock-free under the GIL), and only the slow paths — an actual blocking wait,
or the PREPARED -> {SUBMITTED, CANCELLED} claim race — touch the stripe.

Properties the tests (``tests/test_completion.py``) pin down:

* **no lost wakeups** — a waiter that registered on the stripe before the
  completer set the flag is always notified (flag write + notify happen
  under the stripe lock; waiters re-check the flag under the same lock);
* **no double delivery** — the completion callback attached to a request
  (the slot scheduler's accounting hook) fires exactly once across any
  interleaving of ``finish`` and ``cancel``, including the shared backend's
  evict-then-serve-inline re-finish;
* **claim/cancel exclusivity** — at most one of ``claim()`` / ``cancel()``
  wins, exactly as the old per-request lock guaranteed.

False sharing (two hot requests on one stripe) costs a spurious wakeup plus
a predicate re-check, never correctness; with the default 64 stripes and
completions typically consumed promptly, collisions are rare and cheap.

Cross-references: docs/ARCHITECTURE.md ("Open-loop serving & pooled
completion"); *completion pool* is defined in docs/GLOSSARY.md.
"""

from __future__ import annotations

import threading
import time
from typing import Dict, List


class _Stripe:
    """One slot of the completion pool: a lock/condition pair plus the
    count of threads currently blocked on it (so completers can skip the
    notify entirely when nobody is waiting — the common speculative case)."""

    __slots__ = ("lock", "cond", "waiters")

    def __init__(self) -> None:
        self.lock = threading.Lock()
        self.cond = threading.Condition(self.lock)
        self.waiters = 0


class CompletionPool:
    """A fixed set of stripes shared by every ``IORequest``.

    ``n_stripes`` must be a power of two; requests map to stripes by
    ``(id(req) >> 6) & (n - 1)`` (the shift discards allocator-alignment
    zeros so consecutive allocations spread across stripes).
    """

    __slots__ = ("_stripes", "_mask")

    def __init__(self, n_stripes: int = 64) -> None:
        if n_stripes & (n_stripes - 1):
            raise ValueError("n_stripes must be a power of two")
        self._stripes: List[_Stripe] = [_Stripe() for _ in range(n_stripes)]
        self._mask = n_stripes - 1

    def stripe(self, obj: object) -> _Stripe:
        return self._stripes[(id(obj) >> 6) & self._mask]

    # -- waiting ------------------------------------------------------------
    def wait(self, req, timeout=None) -> bool:
        """Block until ``req._done`` is true; returns False on timeout.

        The fast path never touches the stripe: a completed request costs
        one attribute read.  The slow path registers as a waiter under the
        stripe lock and re-checks the flag before every sleep, so a
        completion that lands between the lock-free check and the lock
        acquisition is never missed.
        """
        if req._done:
            return True
        s = self.stripe(req)
        with s.lock:
            if req._done:
                return True
            s.waiters += 1
            try:
                if timeout is None:
                    while not req._done:
                        s.cond.wait()
                    return True
                deadline = time.monotonic() + timeout
                while not req._done:
                    remaining = deadline - time.monotonic()
                    if remaining <= 0:
                        return False
                    s.cond.wait(remaining)
                return True
            finally:
                s.waiters -= 1

    def snapshot(self) -> Dict[str, int]:
        return {
            "stripes": len(self._stripes),
            "waiters": sum(s.waiters for s in self._stripes),
        }


#: the process-wide pool every IORequest parks on (io_uring has one CQ per
#: ring; we go further — one waiter table per process — because a stripe
#: collision costs a re-check, not a correctness hazard)
_POOL = CompletionPool()


def completion_pool() -> CompletionPool:
    """The process-wide completion pool (exposed for tests/observability)."""
    return _POOL
