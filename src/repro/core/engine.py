"""The pre-issuing engine (paper §5.2, Algorithm 1) and per-invocation
speculation sessions.

A ``SpecSession`` is the per-thread, per-invocation instance of a foreaction
graph.  Every intercepted I/O call:

1. peeks up to ``depth`` successor nodes in execution order, computing
   argument values explicitly and *preparing* every node that is safe —
   pure nodes always, non-pure nodes only when no weak edge lies on the
   path from the frontier (paper §3.3: no unrecoverable side effects);
2. submits all prepared entries as one batch to the backend;
3. serves the frontier itself — harvesting the async completion if it was
   pre-issued, else invoking it synchronously — and runs its SaveResult
   stub exactly once;
4. advances the frontier.

On function exit, remaining speculative requests are cancelled and the
backend drained (the cancellation overhead of paper Fig. 10).

The engine is backend-agnostic: a batch submitted through
:class:`repro.core.backends.MultiQueueBackend` fans out across the queue
pairs of a sharded device with no change here — routing is a backend/device
concern, Algorithm 1 only ever sees prepare/submit/wait.

Cross-references: docs/ARCHITECTURE.md ("Pre-issuing engine") maps this
module to paper §5.2; *frontier*, *epoch vector*, *pre-issue* and friends are
defined in docs/GLOSSARY.md.
"""

from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Any, Dict, Optional, Tuple

from .backends import Backend
from .device import Device
from .graph import BranchNode, Edge, ForeactionGraph, FromNode, SyscallNode
from .syscalls import FromRequest, IORequest, ReqState, Sys, execute, is_pure


@dataclass
class Cursor:
    """A dynamic position in the graph: node (or None == End) + epoch vector."""

    node: Optional[object]  # SyscallNode | BranchNode | None
    epochs: Tuple[int, ...]
    weak_crossed: bool = False  # a weak edge was crossed getting here (peek only)


@dataclass
class NodeState:
    issued: bool = False
    req: Optional[IORequest] = None
    harvested: bool = False


@dataclass
class SessionStats:
    intercepted: int = 0
    untracked: int = 0
    pre_issued: int = 0
    submits: int = 0  # non-empty submit_all() batches (queue-pair crossings)
    served_async: int = 0
    served_sync: int = 0
    cancelled: int = 0
    wasted_completions: int = 0
    peek_seconds: float = 0.0
    wait_seconds: float = 0.0
    sync_seconds: float = 0.0
    harvest_seconds: float = 0.0

    def merge(self, other: "SessionStats") -> None:
        for f in (
            "intercepted", "untracked", "pre_issued", "submits", "served_async",
            "served_sync", "cancelled", "wasted_completions",
        ):
            setattr(self, f, getattr(self, f) + getattr(other, f))
        for f in ("peek_seconds", "wait_seconds", "sync_seconds", "harvest_seconds"):
            setattr(self, f, getattr(self, f) + getattr(other, f))


class GraphMismatch(RuntimeError):
    """The intercepted syscall does not match the foreaction graph."""


class SpecSession:
    """One activation of a registered function on one thread."""

    def __init__(
        self,
        graph: ForeactionGraph,
        ctx: Dict[str, Any],
        backend: Backend,
        device: Device,
        depth: int = 8,
        strict: bool = True,
    ):
        self.graph = graph
        self.ctx = ctx
        self.backend = backend
        self.device = device
        self.depth = depth
        self.strict = strict
        self.stats = SessionStats()
        self._state: Dict[Tuple[str, Tuple[int, ...]], NodeState] = {}
        self._cursor = Cursor(node=graph.start.dst, epochs=graph.initial_epochs(),
                              weak_crossed=graph.start.weak)
        # sliding peek window: resume point past the contiguous issued prefix,
        # and its distance (in syscall nodes) from the current frontier
        self._peek: Optional[Cursor] = None
        self._peek_dist = 0
        self._finished = False

    # -- cursor movement ---------------------------------------------------
    @staticmethod
    def _follow(edge: Edge, epochs: Tuple[int, ...], weak: bool) -> Cursor:
        if edge.loop_id is not None:
            lst = list(epochs)
            lst[edge.loop_id] += 1
            epochs = tuple(lst)
        return Cursor(node=edge.dst, epochs=epochs, weak_crossed=weak or edge.weak)

    def _resolve_branches(self, cur: Cursor) -> Optional[Cursor]:
        """Follow branch nodes whose Choice is ready; None if a choice is
        not ready (peek must stop there)."""
        while isinstance(cur.node, BranchNode):
            idx = cur.node.choose(self.ctx, cur.epochs)
            if idx is None:
                return None
            edge = cur.node.children[idx]
            cur = self._follow(edge, cur.epochs, cur.weak_crossed)
        return cur

    def _node_state(self, node: SyscallNode, epochs: Tuple[int, ...]) -> NodeState:
        key = (node.name, epochs)
        st = self._state.get(key)
        if st is None:
            st = NodeState()
            self._state[key] = st
        return st

    # -- Algorithm 1 --------------------------------------------------------
    def _peek_and_preissue(self) -> None:
        """Peek up to ``depth`` nodes beyond the frontier; prepare the safe
        ones; submit the batch (one crossing on the queue-pair backend).

        The peek window *slides*: once every node between the frontier and
        the resume cursor is issued, the next peek continues from the cursor
        instead of re-walking the whole window — amortized O(1) per
        intercept on strong-edge loops (long extent lists would otherwise
        pay an O(depth) walk per call).  A node that was not ready keeps the
        resume cursor behind it so it is retried; a weak-crossed cursor is
        discarded because the frontier passing the weak edge can unblock
        non-pure nodes behind it (recompute from the frontier, the paper's
        original walk)."""
        t0 = time.perf_counter()
        frontier = self._cursor
        assert isinstance(frontier.node, SyscallNode)
        if self._peek is not None and not self._peek.weak_crossed:
            cur, dist = self._peek, self._peek_dist
        else:
            # n = frontier.next (weak flag of the frontier's own out edge counts)
            cur, dist = self._follow(frontier.node.out, frontier.epochs, False), 0
        prefix = True  # still walking the contiguous issued prefix
        prepared_any = False
        while dist < self.depth and cur.node is not None:
            cur2 = self._resolve_branches(cur)
            if cur2 is None:  # branch decision not ready: stop peeking
                break
            cur = cur2
            if cur.node is None:  # reached End
                break
            node: SyscallNode = cur.node
            st = self._node_state(node, cur.epochs)
            if node is frontier.node and cur.epochs == frontier.epochs:
                # the resume cursor caught up with the frontier: intercept()
                # is serving this node right now — pre-issuing it here would
                # buy no overlap and cost an extra crossing + worker handoff
                pass
            elif not st.issued:
                out = node.compute_args(self.ctx, cur.epochs)
                if out is not None:
                    args, link = out
                    args = self._bind_deferred(args, cur.epochs)
                    if args is not None:
                        pure = is_pure(node.sc, args)
                        if pure or not cur.weak_crossed:
                            req = IORequest(sc=node.sc, args=args, link=link,
                                            tag=(node.name, cur.epochs))
                            self.backend.prepare(req)
                            st.issued = True
                            st.req = req
                            self.stats.pre_issued += 1
                            prepared_any = True
                if not st.issued:
                    prefix = False  # retry this node on the next peek
            cur = self._follow(node.out, cur.epochs, cur.weak_crossed)
            dist += 1
            if prefix:
                self._peek, self._peek_dist = cur, dist
        if prepared_any:
            if self.backend.submit_all():
                self.stats.submits += 1
        self.stats.peek_seconds += time.perf_counter() - t0

    def _bind_deferred(self, args, epochs):
        """Rewrite FromNode placeholders to the producer's request at the
        same epoch; None if a producer has not been pre-issued (not ready)."""
        if not any(isinstance(a, FromNode) for a in args):
            return args
        bound = []
        for a in args:
            if isinstance(a, FromNode):
                st = self._state.get((a.name, epochs))
                if st is None or st.req is None:
                    return None
                bound.append(FromRequest(st.req))
            else:
                bound.append(a)
        return tuple(bound)

    def intercept(self, sc: Sys, args: Tuple[Any, ...]) -> Any:
        """Entry point for every I/O call made while this session is active."""
        if self._finished:
            return self._exec_untracked(sc, args)
        self.stats.intercepted += 1
        # resolve the frontier: real execution has passed any branch points,
        # so their Choice stubs must now be decidable.
        cur = self._resolve_branches(self._cursor)
        if cur is None or cur.node is None or not isinstance(cur.node, SyscallNode) \
                or cur.node.sc is not sc:
            # Syscall not described by the graph (e.g. the omitted rare
            # `open` branch in the paper's LSM graph): pass through.
            if self.strict and cur is not None and cur.node is not None \
                    and isinstance(cur.node, SyscallNode) and cur.node.sc is not sc:
                raise GraphMismatch(
                    f"graph {self.graph.name!r}: expected {cur.node.sc} at node "
                    f"{cur.node.name!r}, application issued {sc}"
                )
            return self._exec_untracked(sc, args)
        self._cursor = Cursor(node=cur.node, epochs=cur.epochs, weak_crossed=False)
        frontier: SyscallNode = cur.node

        # 1-2. peek + batch submit (overlaps with serving the frontier below)
        self._peek_and_preissue()

        # 3. serve the frontier
        st = self._node_state(frontier, cur.epochs)
        if st.issued and st.req is not None and st.req.state is not ReqState.CANCELLED:
            t0 = time.perf_counter()
            result = self.backend.wait(st.req)
            self.stats.wait_seconds += time.perf_counter() - t0
            self.stats.served_async += 1
            # copy the internal buffer back to the caller (paper Fig. 10
            # 'result copy' overhead) — bytes results are memcpy'd.
            t0 = time.perf_counter()
            if isinstance(result, bytes):
                result = bytes(result)
            self.stats.harvest_seconds += time.perf_counter() - t0
        else:
            t0 = time.perf_counter()
            self.device.charge_crossing()
            result = execute(self.device, sc, args)
            self.stats.sync_seconds += time.perf_counter() - t0
            self.stats.served_sync += 1
            st.issued = True
        if frontier.save_result is not None and not st.harvested:
            frontier.save_result(self.ctx, cur.epochs, result)
        st.harvested = True

        # 4. advance the frontier (the peek window's origin moves with it)
        self._cursor = self._follow(frontier.out, cur.epochs, False)
        if self._peek_dist > 0:
            self._peek_dist -= 1
        return result

    def _exec_untracked(self, sc: Sys, args: Tuple[Any, ...]) -> Any:
        self.stats.untracked += 1
        self.device.charge_crossing()
        return execute(self.device, sc, args)

    # -- teardown ------------------------------------------------------------
    def finish(self) -> SessionStats:
        """Cancel in-flight speculation and account for wasted work."""
        if self._finished:
            return self.stats
        self._finished = True
        self.stats.cancelled += self.backend.cancel_remaining()
        self.backend.drain()
        for st in self._state.values():
            if st.issued and not st.harvested and st.req is not None \
                    and st.req.state is ReqState.COMPLETED:
                self.stats.wasted_completions += 1
        return self.stats
