"""The pre-issuing engine (paper §5.2, Algorithm 1) and per-invocation
speculation sessions.

A ``SpecSession`` is the per-thread, per-invocation instance of a foreaction
graph.  Every intercepted I/O call:

1. peeks up to ``depth`` successor nodes in execution order, computing
   argument values explicitly and *preparing* every node that is safe —
   pure nodes always, non-pure nodes only when no weak edge lies on the
   path from the frontier (paper §3.3: no unrecoverable side effects);
2. submits all prepared entries as one batch to the backend;
3. serves the frontier itself — harvesting the async completion if it was
   pre-issued, else invoking it synchronously — and runs its SaveResult
   stub exactly once;
4. advances the frontier.

On function exit, remaining speculative requests are cancelled and the
backend drained (the cancellation overhead of paper Fig. 10).

The engine is backend-agnostic: a batch submitted through
:class:`repro.core.backends.MultiQueueBackend` fans out across the queue
pairs of a sharded device with no change here — routing is a backend/device
concern, Algorithm 1 only ever sees prepare/submit/wait.

Cross-references: docs/ARCHITECTURE.md ("Pre-issuing engine") maps this
module to paper §5.2; *frontier*, *epoch vector*, *pre-issue* and friends are
defined in docs/GLOSSARY.md.
"""

from __future__ import annotations

import threading
import time
from dataclasses import dataclass, field
from typing import Any, Dict, Optional, Tuple

from .backends import Backend
from .device import Device
from .graph import BranchNode, Edge, ForeactionGraph, FromNode, SyscallNode
from .syscalls import (Effect, FromRequest, IORequest, ReqState, Sys,
                       effect_of, execute)


class DepthController:
    """Online speculation-depth tuning (replaces a hand-picked fixed depth).

    The paper fixes ``depth`` per workload; Fig. 10 shows why no single
    value wins — too shallow leaves the device idle (the frontier blocks on
    requests issued moments earlier), too deep pays cancellation/wasted-
    completion overhead on early exits and drain time at teardown.  The
    controller learns the workload's shape from two cheap signals:

    * **wait fraction** — time the frontier spends blocked in ``wait()``
      relative to wall time.  High wait with no waste means requests were
      issued too late: grow (multiplicative).
    * **wasted work** — cancelled + wasted completions at session teardown.
      Waste above ``waste_tolerance`` × harvested means speculation ran past
      the function's real exit: shrink the depth to just past the observed
      consumption (``served_async + 1``).

    Growth is additionally gated on *backend queue occupancy*: when the
    backend already has ``capacity`` requests in flight, more depth only
    queues entries behind busy workers, so the controller stops growing
    there (paper Fig. 10's submission-cost plateau).

    One controller is shared by every session of a graph (per
    ``Foreactor``), so short repeated invocations converge across calls
    while a single long loop converges within one session via the
    window-based wait signal.  Thread-safe; decisions are coarse on purpose
    — the cost of being one step off is tiny next to device latency.
    """

    def __init__(
        self,
        initial: int = 2,
        min_depth: int = 1,
        max_depth: int = 64,
        window: int = 8,
        waste_tolerance: float = 0.25,
        wait_threshold: float = 0.05,
    ):
        self.min_depth = max(1, min_depth)
        self.max_depth = max(self.min_depth, max_depth)
        self._depth = min(self.max_depth, max(self.min_depth, initial))
        self.window = max(2, window)
        self.waste_tolerance = waste_tolerance
        self.wait_threshold = wait_threshold
        self._lock = threading.Lock()
        # intra-session window accumulators
        self._win_serves = 0
        self._win_wait = 0.0
        self._win_t0: Optional[float] = None
        # last finished session's waste verdict (gates growth)
        self._last_wasteful = False
        self.grows = 0
        self.shrinks = 0

    @property
    def depth(self) -> int:
        return self._depth

    def _grow(self, backend: Optional[Backend]) -> None:
        if self._last_wasteful:
            return  # the workload exits early; deeper only wastes more
        if backend is not None:
            cap = backend.capacity
            if cap and backend.inflight() >= cap and self._depth >= cap:
                return  # queue already saturated: depth buys nothing
        new = min(self.max_depth, self._depth * 2)
        if new != self._depth:
            self._depth = new
            self.grows += 1

    def on_serve(self, wait_seconds: float, async_hit: bool,
                 backend: Optional[Backend] = None) -> None:
        """Per-intercept signal: how long the frontier blocked."""
        now = time.perf_counter()
        with self._lock:
            if self._win_t0 is None:
                self._win_t0 = now
                return  # the first serve of a window only starts the clock
            self._win_serves += 1
            self._win_wait += wait_seconds
            if self._win_serves >= self.window:
                elapsed = max(now - self._win_t0, 1e-9)
                if self._win_wait > self.wait_threshold * elapsed:
                    self._grow(backend)
                self._win_serves = 0
                self._win_wait = 0.0
                self._win_t0 = now

    def on_finish(self, stats: "SessionStats", wall_seconds: float,
                  backend: Optional[Backend] = None) -> None:
        """Session-teardown signal: wasted vs harvested speculation."""
        with self._lock:
            self._win_serves = 0
            self._win_wait = 0.0
            self._win_t0 = None
            waste = stats.cancelled + stats.wasted_completions
            useful = stats.served_async
            if stats.pre_issued > 0 and waste > self.waste_tolerance * max(1, useful):
                target = max(self.min_depth, useful + 1)
                if target < self._depth:
                    self._depth = target
                    self.shrinks += 1
                self._last_wasteful = True
                return
            # hysteresis: one clean session must pass after a wasteful one
            # before growth resumes (prevents grow/shrink oscillation on
            # early-exit workloads)
            prev_wasteful = self._last_wasteful
            self._last_wasteful = False
            wall = max(wall_seconds, 1e-9)
            if not prev_wasteful and stats.intercepted >= 2 and \
                    stats.wait_seconds + stats.sync_seconds > self.wait_threshold * wall:
                self._grow(backend)

    def snapshot(self) -> Dict[str, int]:
        with self._lock:
            return {"depth": self._depth, "grows": self.grows,
                    "shrinks": self.shrinks}


@dataclass
class Cursor:
    """A dynamic position in the graph: node (or None == End) + epoch vector."""

    node: Optional[object]  # SyscallNode | BranchNode | None
    epochs: Tuple[int, ...]
    weak_crossed: bool = False  # a weak edge was crossed getting here (peek only)


@dataclass
class NodeState:
    issued: bool = False
    req: Optional[IORequest] = None
    harvested: bool = False


@dataclass
class SessionStats:
    intercepted: int = 0
    untracked: int = 0
    pre_issued: int = 0
    submits: int = 0  # non-empty submit_all() batches (queue-pair crossings)
    served_async: int = 0
    served_sync: int = 0
    cancelled: int = 0
    wasted_completions: int = 0
    peek_seconds: float = 0.0
    wait_seconds: float = 0.0
    sync_seconds: float = 0.0
    harvest_seconds: float = 0.0

    def merge(self, other: "SessionStats") -> None:
        for f in (
            "intercepted", "untracked", "pre_issued", "submits", "served_async",
            "served_sync", "cancelled", "wasted_completions",
        ):
            setattr(self, f, getattr(self, f) + getattr(other, f))
        for f in ("peek_seconds", "wait_seconds", "sync_seconds", "harvest_seconds"):
            setattr(self, f, getattr(self, f) + getattr(other, f))


class GraphMismatch(RuntimeError):
    """The intercepted syscall does not match the foreaction graph."""


class SpecSession:
    """One activation of a registered function on one thread."""

    def __init__(
        self,
        graph: ForeactionGraph,
        ctx: Dict[str, Any],
        backend: Backend,
        device: Device,
        depth: int = 8,
        strict: bool = True,
        controller: Optional[DepthController] = None,
        tenant: Optional[str] = None,
        staging: bool = False,
    ):
        self.graph = graph
        self.ctx = ctx
        self.backend = backend
        self.device = device
        # tenant identity: who this activation speculates on behalf of (the
        # shared-backend scheduler arbitrates slots between tenants); private
        # backends leave it None.
        self.tenant = tenant if tenant is not None \
            else getattr(backend, "tenant", None)
        self._fixed_depth = depth
        self.controller = controller
        self.strict = strict
        self.stats = SessionStats()
        self._t0 = time.perf_counter()
        self._state: Dict[Tuple[str, Tuple[int, ...]], NodeState] = {}
        self._cursor = Cursor(node=graph.start.dst, epochs=graph.initial_epochs(),
                              weak_crossed=graph.start.weak)
        # sliding peek window: resume point past the contiguous issued prefix,
        # and its distance (in syscall nodes) from the current frontier
        self._peek: Optional[Cursor] = None
        self._peek_dist = 0
        self._finished = False
        # undoable write speculation: when enabled, every tracked UNDOABLE
        # syscall — pre-issued or frontier-served — runs inside one staging
        # transaction (repro.store.staging), committed on clean exit and
        # rolled back on failure.  The txn is created lazily on first use.
        self._staging_enabled = staging
        self.staging = None  # Optional[StagingTxn]
        self._failed = False

    def mark_failed(self) -> None:
        """The wrapped function raised: the session's staging transaction
        must roll back instead of committing (called by ``Foreactor.wrap``
        before ``deactivate`` runs in its ``finally``)."""
        self._failed = True

    def _txn(self):
        if self.staging is None and self._staging_enabled:
            from repro.store.staging import StagingTxn  # lazy: no cycle
            self.staging = StagingTxn(self.device)
        return self.staging

    @property
    def depth(self) -> int:
        """Current speculation depth — fixed, or the adaptive controller's
        live value (re-read at every peek, so depth changes mid-session) —
        capped by the backend's speculation-budget lease: on a shared
        backend a session never peeks past its tenant's fair share of the
        queue, so depth tuning and slot arbitration cannot fight."""
        d = self.controller.depth if self.controller is not None \
            else self._fixed_depth
        lease = self.backend.spec_budget()
        return d if lease is None else min(d, lease)

    # -- cursor movement ---------------------------------------------------
    @staticmethod
    def _follow(edge: Edge, epochs: Tuple[int, ...], weak: bool) -> Cursor:
        if edge.loop_id is not None:
            lst = list(epochs)
            lst[edge.loop_id] += 1
            epochs = tuple(lst)
        return Cursor(node=edge.dst, epochs=epochs, weak_crossed=weak or edge.weak)

    def _resolve_branches(self, cur: Cursor) -> Optional[Cursor]:
        """Follow branch nodes whose Choice is ready; None if a choice is
        not ready (peek must stop there)."""
        while isinstance(cur.node, BranchNode):
            idx = cur.node.choose(self.ctx, cur.epochs)
            if idx is None:
                return None
            edge = cur.node.children[idx]
            cur = self._follow(edge, cur.epochs, cur.weak_crossed)
        return cur

    def _node_state(self, node: SyscallNode, epochs: Tuple[int, ...]) -> NodeState:
        key = (node.name, epochs)
        st = self._state.get(key)
        if st is None:
            st = NodeState()
            self._state[key] = st
        return st

    # -- Algorithm 1 --------------------------------------------------------
    def _peek_and_preissue(self) -> None:
        """Peek up to ``depth`` nodes beyond the frontier; prepare the safe
        ones; submit the batch (one crossing on the queue-pair backend).

        The peek window *slides*: once every node between the frontier and
        the resume cursor is issued, the next peek continues from the cursor
        instead of re-walking the whole window — amortized O(1) per
        intercept on strong-edge loops (long extent lists would otherwise
        pay an O(depth) walk per call).  A node that was not ready keeps the
        resume cursor behind it so it is retried; a weak-crossed cursor is
        discarded because the frontier passing the weak edge can unblock
        non-pure nodes behind it (recompute from the frontier, the paper's
        original walk)."""
        t0 = time.perf_counter()
        frontier = self._cursor
        assert isinstance(frontier.node, SyscallNode)
        if self._peek is not None and not self._peek.weak_crossed:
            cur, dist = self._peek, self._peek_dist
        else:
            # n = frontier.next (weak flag of the frontier's own out edge counts)
            cur, dist = self._follow(frontier.node.out, frontier.epochs, False), 0
        prefix = True  # still walking the contiguous issued prefix
        prepared_any = False
        # snapshot once per peek: on a shared backend the depth property
        # consults the scheduler (a global lock) for the tenant's lease —
        # per-node re-evaluation would serialize every peeking thread on it
        depth = self.depth
        try:
            while dist < depth and cur.node is not None:
                cur2 = self._resolve_branches(cur)
                if cur2 is None:  # branch decision not ready: stop peeking
                    break
                cur = cur2
                if cur.node is None:  # reached End
                    break
                node: SyscallNode = cur.node
                st = self._node_state(node, cur.epochs)
                if node is frontier.node and cur.epochs == frontier.epochs:
                    # the resume cursor caught up with the frontier: intercept()
                    # is serving this node right now — pre-issuing it here would
                    # buy no overlap and cost an extra crossing + worker handoff
                    pass
                elif not st.issued:
                    out = node.compute_args(self.ctx, cur.epochs)
                    if out is not None:
                        args, link = out
                        args = self._bind_deferred(args, cur.epochs)
                        if args is not None:
                            req = self._make_request(node, args, link,
                                                     cur.epochs,
                                                     cur.weak_crossed)
                            if req is not None:
                                self.backend.prepare(req)
                                st.issued = True
                                st.req = req
                                self.stats.pre_issued += 1
                                prepared_any = True
                    if not st.issued:
                        prefix = False  # retry this node on the next peek
                cur = self._follow(node.out, cur.epochs, cur.weak_crossed)
                dist += 1
                if prefix:
                    self._peek, self._peek_dist = cur, dist
            # only a completed walk submits: if a stub raised mid-batch the
            # prepared entries stay in the submission queue, where finish()
            # cancels them before they ever execute — a non-pure request is
            # only "guaranteed to happen" while the function keeps running.
            if prepared_any:
                if self.backend.submit_all():
                    self.stats.submits += 1
        finally:
            self.stats.peek_seconds += time.perf_counter() - t0

    def _make_request(self, node: SyscallNode, args, link: bool,
                      epochs: Tuple[int, ...],
                      weak_crossed: bool) -> Optional[IORequest]:
        """Build the IORequest for a peeked node, or None if the node's
        effect class forbids pre-issuing here (paper §3.3, extended):

        * PURE — always pre-issuable, unchanged.
        * UNDOABLE — with staging on, always pre-issuable: creates are
          redirected to a staging extent, overwrites capture undo bytes
          (writes to files this txn created need neither).  Without
          staging, only when guaranteed (no weak edge crossed) — the
          paper's original rule.
        * BARRIER — only when guaranteed; a barrier can never run ahead of
          an exit that might abandon it.
        """
        tag = (node.name, epochs)
        eff = effect_of(node.sc, args)
        if eff is Effect.PURE:
            return IORequest(sc=node.sc, args=args, link=link, tag=tag)
        if eff is Effect.UNDOABLE and self._staging_enabled:
            txn = self._txn()
            if node.sc is Sys.OPEN:
                runner, rec = txn.stage_create(
                    args[0], args[1] if len(args) > 1 else "w")
                return IORequest(sc=node.sc, args=args, link=link, tag=tag,
                                 runner=runner, stage=rec)
            # PWRITE into a file this transaction created: on a guaranteed
            # path it needs no undo record (rollback unlinks the file).
            # Behind a weak edge it must NOT pre-issue at all — if the
            # create publishes (its open was demanded) the file's bytes
            # commit wholesale, and a byte-range undo of the un-demanded
            # writes is unsound under concurrency (interleaved extends make
            # old-bytes + truncate replay order-dependent).  The create
            # itself still speculates; its writes wait for the frontier.
            if self._fd_is_staged(txn, args[0]):
                if weak_crossed:
                    return None
                return IORequest(sc=node.sc, args=args, link=link, tag=tag)
            runner, rec = txn.stage_overwrite(args)
            return IORequest(sc=node.sc, args=args, link=link, tag=tag,
                             runner=runner, stage=rec)
        if not weak_crossed:  # guaranteed: UNDOABLE-unstaged and BARRIER
            req = IORequest(sc=node.sc, args=args, link=link, tag=tag)
            if node.sc is Sys.CLOSE:
                # bind the publish barrier to its record NOW, while the fd
                # is still open; the worker may execute this close (and the
                # OS recycle the fd number) long before the frontier serves
                # it, making fd-keyed lookup at harvest time unsound
                req.barrier_for = self._close_barrier_rec(args[0])
            return req
        return None

    def _close_barrier_rec(self, fd_arg):
        """The staged-create record a CLOSE's fd refers to, or None."""
        if self.staging is None:
            return None
        if isinstance(fd_arg, FromRequest):
            rec = fd_arg.req.stage
            return rec if rec is not None and rec.kind == "create" else None
        if isinstance(fd_arg, int):
            return self.staging.record_for_fd(fd_arg)
        return None

    @staticmethod
    def _fd_is_staged(txn, fd_arg) -> bool:
        if isinstance(fd_arg, FromRequest):
            rec = fd_arg.req.stage
            return rec is not None and rec.kind == "create"
        return isinstance(fd_arg, int) and txn.is_staged_fd(fd_arg)

    def _bind_deferred(self, args, epochs):
        """Rewrite FromNode placeholders to the producer's request at the
        same epoch; None if a producer has not been pre-issued (not ready)."""
        if not any(isinstance(a, FromNode) for a in args):
            return args
        bound = []
        for a in args:
            if isinstance(a, FromNode):
                st = self._state.get((a.name, epochs))
                if st is None or st.req is None:
                    return None
                bound.append(FromRequest(st.req))
            else:
                bound.append(a)
        return tuple(bound)

    def intercept(self, sc: Sys, args: Tuple[Any, ...]) -> Any:
        """Entry point for every I/O call made while this session is active."""
        if self._finished:
            return self._exec_untracked(sc, args)
        self.stats.intercepted += 1
        # resolve the frontier: real execution has passed any branch points,
        # so their Choice stubs must now be decidable.
        cur = self._resolve_branches(self._cursor)
        if cur is None or cur.node is None or not isinstance(cur.node, SyscallNode) \
                or cur.node.sc is not sc:
            # Syscall not described by the graph (e.g. the omitted rare
            # `open` branch in the paper's LSM graph): pass through.
            if self.strict and cur is not None and cur.node is not None \
                    and isinstance(cur.node, SyscallNode) and cur.node.sc is not sc:
                raise GraphMismatch(
                    f"graph {self.graph.name!r}: expected {cur.node.sc} at node "
                    f"{cur.node.name!r}, application issued {sc}"
                )
            return self._exec_untracked(sc, args)
        self._cursor = Cursor(node=cur.node, epochs=cur.epochs, weak_crossed=False)
        frontier: SyscallNode = cur.node

        # 1-2. peek + batch submit (overlaps with serving the frontier below)
        self._peek_and_preissue()

        # 3. serve the frontier
        st = self._node_state(frontier, cur.epochs)
        # resolve a close's publish-barrier record BEFORE serving: for a
        # pre-issued close it was bound at pre-issue; for a sync serve the
        # fd is still open right now.  After the close executes, the OS may
        # recycle the fd number onto a newer staged create.
        close_rec = None
        if sc is Sys.CLOSE and self.staging is not None:
            if st.issued and st.req is not None \
                    and st.req.state is not ReqState.CANCELLED:
                close_rec = st.req.barrier_for
            else:
                close_rec = self.staging.record_for_fd(args[0])
        if st.issued and st.req is not None and st.req.state is not ReqState.CANCELLED:
            t0 = time.perf_counter()
            result = self.backend.wait(st.req)
            blocked = time.perf_counter() - t0
            self.stats.wait_seconds += blocked
            self.stats.served_async += 1
            served_async = True
            if st.req.stage is not None:
                # the frontier reached a staged side effect: real execution
                # now depends on it — eligible for publish at its barrier
                self.staging.on_demand(st.req.stage)
            # copy the internal buffer back to the caller (paper Fig. 10
            # 'result copy' overhead) — bytes results are memcpy'd.
            t0 = time.perf_counter()
            if isinstance(result, bytes):
                result = bytes(result)
            self.stats.harvest_seconds += time.perf_counter() - t0
        else:
            t0 = time.perf_counter()
            # demand I/O about to run synchronously: let a shared backend
            # shed speculative queue pressure first (no-op on private ones)
            self.backend.note_demand()
            self.device.charge_crossing()
            result = self._serve_sync(sc, args)
            blocked = time.perf_counter() - t0
            self.stats.sync_seconds += blocked
            self.stats.served_sync += 1
            served_async = False
            st.issued = True
        if close_rec is not None:
            # publish barrier: closing a staged file commits it (rename)
            self.staging.publish_close(close_rec)
        if self.controller is not None:
            self.controller.on_serve(blocked, served_async, self.backend)
        if frontier.save_result is not None and not st.harvested:
            frontier.save_result(self.ctx, cur.epochs, result)
        st.harvested = True

        # 4. advance the frontier (the peek window's origin moves with it)
        self._cursor = self._follow(frontier.out, cur.epochs, False)
        if self._peek_dist > 0:
            self._peek_dist -= 1
        return result

    def _serve_sync(self, sc: Sys, args: Tuple[Any, ...]) -> Any:
        """Serve the frontier synchronously.  With staging on, undoable
        syscalls stay inside the transaction even here: a session is a
        write transaction whether or not speculation got ahead, so the
        abort path can roll back demand writes too."""
        if self._staging_enabled and effect_of(sc, args) is Effect.UNDOABLE:
            txn = self._txn()
            if sc is Sys.OPEN:
                runner, rec = txn.stage_create(
                    args[0], args[1] if len(args) > 1 else "w")
            elif not self._fd_is_staged(txn, args[0]):
                runner, rec = txn.stage_overwrite(args)
            else:  # write into a staged file: nothing extra to log
                return execute(self.device, sc, args)
            rec.demanded = True
            return runner(self.device)
        return execute(self.device, sc, args)

    def _exec_untracked(self, sc: Sys, args: Tuple[Any, ...]) -> Any:
        self.stats.untracked += 1
        # untracked closes are still publish barriers (plenty of wrapped
        # functions open through the graph but tear down outside it);
        # resolve the record before the close frees the fd number
        close_rec = None
        if sc is Sys.CLOSE and self.staging is not None:
            close_rec = self.staging.record_for_fd(args[0])
        self.device.charge_crossing()
        result = execute(self.device, sc, args)
        if close_rec is not None:
            self.staging.publish_close(close_rec)
        return result

    # -- teardown ------------------------------------------------------------
    def finish(self) -> SessionStats:
        """Cancel in-flight speculation and account for wasted work.

        Exception-safe and idempotent: even when ``intercept`` raised
        mid-batch (a stub error between ``prepare`` and ``submit_all``, a
        strict-mode :class:`GraphMismatch`, a failed request surfacing at
        ``wait``), every pre-issued-but-unharvested request is cancelled or
        drained exactly once — nothing may keep running into the next
        activation that reuses this backend, and nothing may be counted
        twice.  If cancellation itself raises, the drain and the wasted-work
        accounting still run before the error propagates.
        """
        if self._finished:
            return self.stats
        self._finished = True
        try:
            self.backend.cancel_remaining()
        finally:
            try:
                self.backend.drain()
            finally:
                # Account every pre-issued request from this session's own
                # node-state ledger, not from the backend's return value: on
                # a shared backend the scheduler may have evicted requests
                # mid-session, and a failed link head cancels its chain's
                # dependents on the worker — both must land in ``cancelled``
                # exactly once for the invariant
                #   pre_issued == served_async + cancelled + wasted_completions
                # to hold (tests/test_conformance.py checks it everywhere).
                for st in self._state.values():
                    if not st.issued or st.req is None:
                        continue
                    if st.req.state is ReqState.CANCELLED:
                        self.stats.cancelled += 1
                    elif st.req.state is ReqState.COMPLETED and not st.harvested:
                        self.stats.wasted_completions += 1
                try:
                    # settle the write transaction strictly after the drain:
                    # no staged runner can still be executing.  Success
                    # publishes what the frontier demanded and rolls back
                    # overshoot; failure rolls back everything unpublished.
                    if self.staging is not None:
                        self.staging.finalize(ok=not self._failed)
                finally:
                    if self.controller is not None:
                        self.controller.on_finish(
                            self.stats, time.perf_counter() - self._t0,
                            self.backend
                        )
        return self.stats
