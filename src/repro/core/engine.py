"""The pre-issuing engine (paper §5.2, Algorithm 1) and per-invocation
speculation sessions.

A ``SpecSession`` is the per-thread, per-invocation instance of a foreaction
graph.  Every intercepted I/O call:

1. peeks up to ``depth`` successor nodes in execution order, computing
   argument values explicitly and *preparing* every node that is safe —
   pure nodes always, non-pure nodes only when no weak edge lies on the
   path from the frontier (paper §3.3: no unrecoverable side effects);
2. submits all prepared entries as one batch to the backend;
3. serves the frontier itself — harvesting the async completion if it was
   pre-issued, else invoking it synchronously — and runs its SaveResult
   stub exactly once;
4. advances the frontier.

On function exit, remaining speculative requests are cancelled and the
backend drained (the cancellation overhead of paper Fig. 10).

The session never walks the authoring-layer object graph: it interprets the
graph's *compiled plan* (:mod:`repro.core.plan`) — flat node records indexed
by integer id — with integer cursors, and it accumulates the peeked batch
locally, handing it to the backend in one ``submit`` call.  Two consequences
the old object walker could not offer:

* peek cost no longer scales with graph-authoring style — the sliding peek
  window survives weak edges as long as the window's prefix stays fully
  issued (an all-pure mined chain re-walks nothing), falling back to the
  paper's exact from-the-frontier walk only when a non-pure node was
  actually deferred by a conservatively stale weak flag, which keeps the
  pre-issue schedule identical to the original algorithm;
* the submission path costs one lock acquisition per batch instead of one
  per request (the Python mirror of "one io_uring_enter per batch").

The engine is backend-agnostic: a batch submitted through
:class:`repro.core.backends.MultiQueueBackend` fans out across the lanes of
a sharded device with no change here — routing is an I/O-plane/device
concern, Algorithm 1 only ever sees submit/wait.

Cross-references: docs/ARCHITECTURE.md ("Pre-issuing engine", "Plan
compilation & the unified I/O plane") maps this module to paper §5.2;
*frontier*, *epoch vector*, *pre-issue*, *graph plan* and friends are
defined in docs/GLOSSARY.md.
"""

from __future__ import annotations

import threading
import time
from dataclasses import dataclass, field
from typing import Any, Dict, List, Optional, Tuple

from .backends import Backend
from .device import Device
from .graph import ForeactionGraph, FromNode
from .plan import END, KIND_BRANCH, KIND_SYSCALL, GraphPlan, compile_plan
from .syscalls import (Effect, FromRequest, IOFuture, IORequest, ReqState,
                       Sys, effect_of, execute)


class FuturePoisoned(RuntimeError):
    """``IOFuture.result()`` on a future whose session failed
    (:meth:`SpecSession.mark_failed`) before the future resolved — the
    speculated bytes must never be trusted."""


class DepthController:
    """Online speculation-depth tuning (replaces a hand-picked fixed depth).

    The paper fixes ``depth`` per workload; Fig. 10 shows why no single
    value wins — too shallow leaves the device idle (the frontier blocks on
    requests issued moments earlier), too deep pays cancellation/wasted-
    completion overhead on early exits and drain time at teardown.  The
    controller learns the workload's shape from two cheap signals:

    * **wait fraction** — time the frontier spends blocked in ``wait()``
      relative to wall time.  High wait with no waste means requests were
      issued too late: grow (multiplicative).
    * **wasted work** — cancelled + wasted completions at session teardown.
      Waste above ``waste_tolerance`` × harvested means speculation ran past
      the function's real exit: shrink the depth to just past the observed
      consumption (``served_async + 1``).

    Growth is additionally gated on *backend queue occupancy*: when the
    backend already has ``capacity`` requests in flight, more depth only
    queues entries behind busy workers, so the controller stops growing
    there (paper Fig. 10's submission-cost plateau).

    One controller is shared by every session of a graph (per
    ``Foreactor``), so short repeated invocations converge across calls
    while a single long loop converges within one session via the
    window-based wait signal.  Thread-safe; decisions are coarse on purpose
    — the cost of being one step off is tiny next to device latency.
    """

    def __init__(
        self,
        initial: int = 2,
        min_depth: int = 1,
        max_depth: int = 64,
        window: int = 8,
        waste_tolerance: float = 0.25,
        wait_threshold: float = 0.05,
    ):
        self.min_depth = max(1, min_depth)
        self.max_depth = max(self.min_depth, max_depth)
        self._depth = min(self.max_depth, max(self.min_depth, initial))
        self.window = max(2, window)
        self.waste_tolerance = waste_tolerance
        self.wait_threshold = wait_threshold
        self._lock = threading.Lock()
        # intra-session window accumulators
        self._win_serves = 0
        self._win_wait = 0.0
        self._win_t0: Optional[float] = None
        # last finished session's waste verdict (gates growth)
        self._last_wasteful = False
        self.grows = 0
        self.shrinks = 0

    @property
    def depth(self) -> int:
        return self._depth

    def _grow(self, backend: Optional[Backend]) -> None:
        if self._last_wasteful:
            return  # the workload exits early; deeper only wastes more
        if backend is not None:
            cap = backend.capacity
            if cap and backend.inflight() >= cap and self._depth >= cap:
                return  # queue already saturated: depth buys nothing
        new = min(self.max_depth, self._depth * 2)
        if new != self._depth:
            self._depth = new
            self.grows += 1

    def on_serve(self, wait_seconds: float, async_hit: bool,
                 backend: Optional[Backend] = None) -> None:
        """Per-intercept signal: how long the frontier blocked."""
        now = time.perf_counter()
        with self._lock:
            if self._win_t0 is None:
                self._win_t0 = now
                return  # the first serve of a window only starts the clock
            self._win_serves += 1
            self._win_wait += wait_seconds
            if self._win_serves >= self.window:
                elapsed = max(now - self._win_t0, 1e-9)
                if self._win_wait > self.wait_threshold * elapsed:
                    self._grow(backend)
                self._win_serves = 0
                self._win_wait = 0.0
                self._win_t0 = now

    def on_finish(self, stats: "SessionStats", wall_seconds: float,
                  backend: Optional[Backend] = None) -> None:
        """Session-teardown signal: wasted vs harvested speculation."""
        with self._lock:
            self._win_serves = 0
            self._win_wait = 0.0
            self._win_t0 = None
            waste = stats.cancelled + stats.wasted_completions
            useful = stats.served_async
            if stats.pre_issued > 0 and waste > self.waste_tolerance * max(1, useful):
                target = max(self.min_depth, useful + 1)
                if target < self._depth:
                    self._depth = target
                    self.shrinks += 1
                self._last_wasteful = True
                return
            # hysteresis: one clean session must pass after a wasteful one
            # before growth resumes (prevents grow/shrink oscillation on
            # early-exit workloads)
            prev_wasteful = self._last_wasteful
            self._last_wasteful = False
            wall = max(wall_seconds, 1e-9)
            if not prev_wasteful and stats.intercepted >= 2 and \
                    stats.wait_seconds + stats.sync_seconds > self.wait_threshold * wall:
                self._grow(backend)

    def snapshot(self) -> Dict[str, int]:
        with self._lock:
            return {"depth": self._depth, "grows": self.grows,
                    "shrinks": self.shrinks}


@dataclass
class NodeState:
    issued: bool = False
    req: Optional[IORequest] = None
    harvested: bool = False
    #: the pre-issued request's arguments did not match what the
    #: application actually asked for (the live pattern drifted away from
    #: the graph) — its completion must be accounted as wasted, never
    #: harvested
    stale: bool = False


def _spec_args_match(spec_args: Tuple[Any, ...],
                     args: Tuple[Any, ...]) -> bool:
    """Harvest-time argument guard: may a pre-issued request's result be
    served for this intercepted call?

    The frontier is resolved by syscall *kind*; under pattern drift (a
    stale mined graph after an LSM compaction changed the level geometry)
    the kinds can still line up while the graph-computed arguments — fd,
    offset, size, path — point at yesterday's layout.  Harvesting such a
    request would silently return the wrong bytes, so the engine compares
    arguments before trusting a speculated completion and falls back to
    synchronous service on mismatch (the stale request is accounted as
    wasted/cancelled at finish, keeping the ledger invariant).

    Positions holding :class:`FromRequest` link placeholders are skipped —
    the producer's buffer *is* the argument, there is no application value
    to compare — as are raw write payloads (``bytes``/``bytearray``/
    ``memoryview``), where an O(n) memcmp per intercept would tax every
    staged write to defend against a drift mode the fd/offset check
    already catches."""
    if len(spec_args) != len(args):
        return False
    for a, b in zip(spec_args, args):
        if isinstance(a, (FromRequest, bytes, bytearray, memoryview)) \
                or isinstance(b, (bytes, bytearray, memoryview)):
            continue
        if a != b:
            return False
    return True


@dataclass
class SessionStats:
    intercepted: int = 0
    untracked: int = 0
    pre_issued: int = 0
    submits: int = 0  # non-empty submitted batches (queue-pair crossings)
    served_async: int = 0
    served_sync: int = 0
    cancelled: int = 0
    wasted_completions: int = 0
    #: pre-issued requests rejected by the harvest-time argument guard —
    #: the graph computed different arguments than the application issued
    #: (stale mined graph under pattern drift); served synchronously instead
    stale_harvests: int = 0
    #: async intercepts that handed back an unresolved IOFuture (the
    #: late-demand entries of the ledger)
    futures_issued: int = 0
    #: futures still unresolved when finish() ran — drained-then-materialized
    #: (clean exit) or poisoned (failed session)
    futures_drained: int = 0
    peek_seconds: float = 0.0
    wait_seconds: float = 0.0
    sync_seconds: float = 0.0
    harvest_seconds: float = 0.0

    def merge(self, other: "SessionStats") -> None:
        for f in (
            "intercepted", "untracked", "pre_issued", "submits", "served_async",
            "served_sync", "cancelled", "wasted_completions", "stale_harvests",
            "futures_issued", "futures_drained",
        ):
            setattr(self, f, getattr(self, f) + getattr(other, f))
        for f in ("peek_seconds", "wait_seconds", "sync_seconds", "harvest_seconds"):
            setattr(self, f, getattr(self, f) + getattr(other, f))


class GraphMismatch(RuntimeError):
    """The intercepted syscall does not match the foreaction graph."""


class SpecSession:
    """One activation of a registered function on one thread.

    The session interprets the graph's compiled :class:`GraphPlan`: cursors
    are ``(node id, epoch vector)`` integer/tuple pairs, per-node dynamic
    state is keyed by them, and Algorithm 1's peek walks the plan's flat
    arrays.  The authoring-layer graph object is kept only for
    introspection (``sess.graph``).
    """

    def __init__(
        self,
        graph: ForeactionGraph,
        ctx: Dict[str, Any],
        backend: Backend,
        device: Device,
        depth: int = 8,
        strict: bool = True,
        controller: Optional[DepthController] = None,
        tenant: Optional[str] = None,
        staging: bool = False,
        plan: Optional[GraphPlan] = None,
        graph_name: Optional[str] = None,
        graph_version: int = 0,
    ):
        self.graph = graph
        #: registry identity, stamped by Foreactor.activate: which endpoint
        #: this session serves and which build of its graph it started on.
        #: A hot-swap mid-session never retargets a live session — it keeps
        #: speculating on the plan it activated with, and the version lets
        #: the re-miner's rollback guard attribute its waste ledger to the
        #: right graph build.
        self.graph_name = graph_name if graph_name is not None else graph.name
        self.graph_version = graph_version
        self.plan = plan if plan is not None else compile_plan(
            graph, "adaptive" if controller is not None else "fixed")
        self.ctx = ctx
        self.backend = backend
        self.device = device
        # tenant identity: who this activation speculates on behalf of (the
        # shared-backend scheduler arbitrates slots between tenants); private
        # backends leave it None.
        self.tenant = tenant if tenant is not None \
            else getattr(backend, "tenant", None)
        self._fixed_depth = depth
        self.controller = controller
        self.strict = strict
        self.stats = SessionStats()
        self._t0 = time.perf_counter()
        #: dynamic node state, keyed by (node id, epoch vector)
        self._state: Dict[Tuple[int, Tuple[int, ...]], NodeState] = {}
        #: the frontier cursor, possibly resting on a branch record
        self._cur: Tuple[int, Tuple[int, ...]] = (
            self.plan.start_dst, self.plan.initial_epochs())
        #: the branch-resolved syscall record intercept() is serving now
        #: (peek skips it: pre-issuing it would buy no overlap)
        self._frontier: Tuple[int, Tuple[int, ...]] = (END, ())
        # sliding peek window: resume point past the contiguous issued
        # prefix as (node id, epochs, conservative weak flag), and its
        # distance (in syscall records) from the current frontier
        self._peek: Optional[Tuple[int, Tuple[int, ...], bool]] = None
        self._peek_dist = 0
        #: peeked requests a mid-walk stub error kept from being submitted;
        #: finish() cancels them so the ledger invariant still holds
        self._orphans: List[IORequest] = []
        #: unresolved IOFutures handed out by intercept_async; finish()
        #: drains-then-materializes them (or poisons them on failure)
        self._futures: List[IOFuture] = []
        self._finished = False
        # undoable write speculation: when enabled, every tracked UNDOABLE
        # syscall — pre-issued or frontier-served — runs inside one staging
        # transaction (repro.store.staging), committed on clean exit and
        # rolled back on failure.  The txn is created lazily on first use.
        self._staging_enabled = staging
        self.staging = None  # Optional[StagingTxn]
        self._failed = False

    def mark_failed(self) -> None:
        """The wrapped function raised: the session's staging transaction
        must roll back instead of committing (called by ``Foreactor.wrap``
        before ``deactivate`` runs in its ``finally``)."""
        self._failed = True

    def _txn(self):
        if self.staging is None and self._staging_enabled:
            from repro.store.staging import StagingTxn  # lazy: no cycle
            self.staging = StagingTxn(self.device)
        return self.staging

    @property
    def depth(self) -> int:
        """Current speculation depth — fixed, or the adaptive controller's
        live value (re-read at every peek, so depth changes mid-session) —
        capped by the backend's speculation-budget lease: on a shared
        backend a session never peeks past its tenant's fair share of the
        queue, so depth tuning and slot arbitration cannot fight."""
        d = self.controller.depth if self.controller is not None \
            else self._fixed_depth
        lease = self.backend.spec_budget()
        return d if lease is None else min(d, lease)

    # -- Algorithm 1, over the compiled plan ---------------------------------
    def _peek_and_preissue(self) -> None:
        """Peek up to ``depth`` records beyond the frontier; prepare the
        safe ones; hand the batch to the backend in one ``submit`` call
        (one lock acquisition, one crossing on the queue-pair lane).

        The peek window *slides*: once every record between the frontier
        and the resume cursor is issued, the next peek continues from the
        cursor instead of re-walking the whole window — amortized O(1) per
        intercept regardless of authoring style, weak edges included (the
        cursor's stored weak flag is conservative relative to the advanced
        frontier, which can never unsoundly issue a non-pure record).  Only
        when that conservatism actually deferred one — ``_walk_window``
        reports it — does the peek fall back to the paper's exact walk from
        the frontier, so the pre-issue schedule is identical to the
        original object walker's."""
        t0 = time.perf_counter()
        batch: List[IORequest] = []
        ok = False
        try:
            # re-offer entries a mid-walk stub error stranded earlier: the
            # function kept running (it issued this very intercept), so they
            # are "guaranteed to happen" again — the object walker left them
            # in the backend SQ for the next flush for the same reason, and
            # without this a frontier demanding one would wait forever on a
            # request no worker ever received.
            if self._orphans:
                batch.extend(self._orphans)
                self._orphans.clear()
            depth = self.depth
            resume = self._peek
            if resume is not None:
                if self._walk_window(resume[0], resume[1], resume[2],
                                     self._peek_dist, depth, batch):
                    # stale-weak fallback: exact re-walk from the frontier
                    self._peek = None
                    fnid, fep = self._frontier
                    nid, ep, weak = self.plan.follow_out(fnid, fep)
                    self._walk_window(nid, ep, weak, 0, depth, batch)
            else:
                fnid, fep = self._frontier
                nid, ep, weak = self.plan.follow_out(fnid, fep)
                self._walk_window(nid, ep, weak, 0, depth, batch)
            ok = True
        finally:
            if batch:
                if ok:
                    # only a completed walk submits: if a stub raised
                    # mid-batch the accumulated entries are quarantined and
                    # finish() cancels them before they ever execute — a
                    # non-pure request is only "guaranteed to happen" while
                    # the function keeps running.
                    if self.backend.submit(batch):
                        self.stats.submits += 1
                else:
                    self._orphans.extend(batch)
            self.stats.peek_seconds += time.perf_counter() - t0

    def _walk_window(self, nid: int, ep: Tuple[int, ...], weak: bool,
                     dist: int, depth: int,
                     batch: List[IORequest]) -> bool:
        """One pass of the peek window over the plan arrays, appending every
        safely issuable record to ``batch``.  Returns True iff a non-pure
        record was deferred *because of* the walk's weak flag — the caller's
        cue that a conservatively stale resume cursor may have deferred
        something the exact walk would issue."""
        p = self.plan
        kind = p.kind
        choose = p.choose
        child_off = p.child_off
        edge_dst = p.edge_dst
        edge_weak = p.edge_weak
        edge_loop = p.edge_loop
        out_dst = p.out_dst
        out_weak = p.out_weak
        out_loop = p.out_loop
        compute = p.compute
        state = self._state
        ctx = self.ctx
        fnid, fep = self._frontier
        prefix = True  # still walking the contiguous issued prefix
        weak_deferral = False
        while dist < depth and nid != END:
            # resolve branch records until a syscall record (or End)
            while nid != END and kind[nid] == KIND_BRANCH:
                idx = choose[nid](ctx, ep)
                if idx is None:  # branch decision not ready: stop peeking
                    return weak_deferral
                e = child_off[nid] + idx
                lid = edge_loop[e]
                if lid >= 0:
                    ep = ep[:lid] + (ep[lid] + 1,) + ep[lid + 1:]
                if edge_weak[e]:
                    weak = True
                nid = edge_dst[e]
            if nid == END:
                return weak_deferral
            key = (nid, ep)
            st = state.get(key)
            if st is None:
                st = NodeState()
                state[key] = st
            if nid == fnid and ep == fep:
                # the resume cursor caught up with the frontier: intercept()
                # is serving this record right now — pre-issuing it here
                # would buy no overlap and cost an extra crossing + worker
                # handoff
                pass
            elif not st.issued:
                out = compute[nid](ctx, ep)
                if out is not None:
                    args, link = out
                    args = self._bind_deferred(args, ep)
                    if args is not None:
                        req = self._make_request(nid, args, link, ep, weak)
                        if req is not None:
                            st.issued = True
                            st.req = req
                            self.stats.pre_issued += 1
                            batch.append(req)
                        elif weak:
                            # the effect gate said no and the weak flag was
                            # the reason — possibly conservatively
                            weak_deferral = True
                if not st.issued:
                    prefix = False  # retry this record on the next peek
            # advance across the syscall record's out edge
            lid = out_loop[nid]
            if lid >= 0:
                ep = ep[:lid] + (ep[lid] + 1,) + ep[lid + 1:]
            if out_weak[nid]:
                weak = True
            nid = out_dst[nid]
            dist += 1
            if prefix:
                self._peek = (nid, ep, weak)
                self._peek_dist = dist
        return weak_deferral

    def _make_request(self, nid: int, args, link: bool,
                      epochs: Tuple[int, ...],
                      weak_crossed: bool) -> Optional[IORequest]:
        """Build the IORequest for a peeked record, or None if its effect
        class forbids pre-issuing here (paper §3.3, extended):

        * PURE — always pre-issuable, unchanged.
        * UNDOABLE — with staging on, always pre-issuable: creates are
          redirected to a staging extent, overwrites capture undo bytes
          (writes to files this txn created need neither).  Without
          staging, only when guaranteed (no weak edge crossed) — the
          paper's original rule.
        * BARRIER — only when guaranteed; a barrier can never run ahead of
          an exit that might abandon it.

        The effect class is read from the plan when statically known
        (everything but OPEN) — no per-peek classification call.
        """
        p = self.plan
        sc = p.sc[nid]
        tag = (nid, epochs)
        eff = p.effect[nid]
        if eff is None:
            eff = effect_of(sc, args)
        if eff is Effect.PURE:
            return IORequest(sc=sc, args=args, link=link, tag=tag)
        if eff is Effect.UNDOABLE and self._staging_enabled:
            txn = self._txn()
            if sc is Sys.OPEN:
                runner, rec = txn.stage_create(
                    args[0], args[1] if len(args) > 1 else "w")
                return IORequest(sc=sc, args=args, link=link, tag=tag,
                                 runner=runner, stage=rec)
            if sc is Sys.RENAME:
                runner, rec = txn.stage_rename(args)
                return IORequest(sc=sc, args=args, link=link, tag=tag,
                                 runner=runner, stage=rec)
            # PWRITE into a file this transaction created: on a guaranteed
            # path it needs no undo record (rollback unlinks the file).
            # Behind a weak edge it must NOT pre-issue at all — if the
            # create publishes (its open was demanded) the file's bytes
            # commit wholesale, and a byte-range undo of the un-demanded
            # writes is unsound under concurrency (interleaved extends make
            # old-bytes + truncate replay order-dependent).  The create
            # itself still speculates; its writes wait for the frontier.
            if self._fd_is_staged(txn, args[0]):
                if weak_crossed:
                    return None
                return IORequest(sc=sc, args=args, link=link, tag=tag)
            runner, rec = txn.stage_overwrite(args)
            return IORequest(sc=sc, args=args, link=link, tag=tag,
                             runner=runner, stage=rec)
        if not weak_crossed:  # guaranteed: UNDOABLE-unstaged and BARRIER
            req = IORequest(sc=sc, args=args, link=link, tag=tag)
            if sc is Sys.CLOSE:
                # bind the publish barrier to its record NOW, while the fd
                # is still open; the worker may execute this close (and the
                # OS recycle the fd number) long before the frontier serves
                # it, making fd-keyed lookup at harvest time unsound
                req.barrier_for = self._close_barrier_rec(args[0])
            return req
        return None

    def _close_barrier_rec(self, fd_arg):
        """The staged-create record a CLOSE's fd refers to, or None."""
        if self.staging is None:
            return None
        if isinstance(fd_arg, FromRequest):
            rec = fd_arg.req.stage
            return rec if rec is not None and rec.kind == "create" else None
        if isinstance(fd_arg, int):
            return self.staging.record_for_fd(fd_arg)
        return None

    @staticmethod
    def _fd_is_staged(txn, fd_arg) -> bool:
        if isinstance(fd_arg, FromRequest):
            rec = fd_arg.req.stage
            return rec is not None and rec.kind == "create"
        return isinstance(fd_arg, int) and txn.is_staged_fd(fd_arg)

    def _bind_deferred(self, args, epochs):
        """Rewrite FromNode placeholders to the producer's request at the
        same epoch; None if a producer has not been pre-issued (not ready)."""
        if not any(isinstance(a, FromNode) for a in args):
            return args
        id_of = self.plan.id_of
        bound = []
        for a in args:
            if isinstance(a, FromNode):
                pid = id_of.get(a.name)
                st = self._state.get((pid, epochs)) if pid is not None else None
                if st is None or st.req is None:
                    return None
                bound.append(FromRequest(st.req))
            else:
                bound.append(a)
        return tuple(bound)

    def intercept(self, sc: Sys, args: Tuple[Any, ...]) -> Any:
        """Entry point for every I/O call made while this session is active."""
        if self._finished:
            return self._exec_untracked(sc, args)
        self.stats.intercepted += 1
        # resolve the frontier: real execution has passed any branch points,
        # so their Choice stubs must now be decidable.
        p = self.plan
        nid, ep = self._cur
        res = p.resolve_branches(nid, ep, self.ctx, False)
        if res is None or res[0] == END or p.sc[res[0]] is not sc:
            # Syscall not described by the graph (e.g. the omitted rare
            # `open` branch in the paper's LSM graph): pass through.
            if self.strict and res is not None and res[0] != END \
                    and p.sc[res[0]] is not sc:
                raise GraphMismatch(
                    f"graph {self.plan.name!r}: expected {p.sc[res[0]]} at "
                    f"node {p.names[res[0]]!r}, application issued {sc}"
                )
            return self._exec_untracked(sc, args)
        fnid, fep = res[0], res[1]
        self._cur = (fnid, fep)
        self._frontier = (fnid, fep)

        # 1-2. peek + batch submit (overlaps with serving the frontier below)
        self._peek_and_preissue()

        # 3. serve the frontier
        key = (fnid, fep)
        st = self._state.get(key)
        if st is None:
            st = NodeState()
            self._state[key] = st
        # harvestable: a live pre-issued request exists AND the harvest-time
        # argument guard agrees it answers the call the application actually
        # made — under pattern drift (stale mined graph) the kinds match but
        # the graph-computed fd/offset/size point at yesterday's layout, and
        # harvesting would silently serve the wrong bytes
        harvestable = st.issued and st.req is not None \
            and st.req.state is not ReqState.CANCELLED
        if harvestable and not st.harvested \
                and not _spec_args_match(st.req.args, args):
            harvestable = False
            st.stale = True
            self.stats.stale_harvests += 1
        # resolve a close's publish-barrier record BEFORE serving: for a
        # pre-issued close it was bound at pre-issue; for a sync serve the
        # fd is still open right now.  After the close executes, the OS may
        # recycle the fd number onto a newer staged create.
        close_rec = None
        if sc is Sys.CLOSE and self.staging is not None:
            if harvestable:
                close_rec = st.req.barrier_for
            else:
                close_rec = self.staging.record_for_fd(args[0])
        if harvestable:
            t0 = time.perf_counter()
            self.backend.wait(st.req)
            blocked = time.perf_counter() - t0
            self.stats.wait_seconds += blocked
            self.stats.served_async += 1
            served_async = True
            if st.req.stage is not None:
                # the frontier reached a staged side effect: real execution
                # now depends on it — eligible for publish at its barrier
                self.staging.on_demand(st.req.stage)
            # materialize the result out of the internal buffer (paper
            # Fig. 10 'result copy') — for a leased read this is the one
            # bounded memcpy out of the registered buffer.
            t0 = time.perf_counter()
            result = st.req.take_result()
            self.stats.harvest_seconds += time.perf_counter() - t0
        else:
            t0 = time.perf_counter()
            # demand I/O about to run synchronously: let a shared backend
            # shed speculative queue pressure first (no-op on private ones)
            self.backend.note_demand()
            self.device.charge_crossing()
            result = self._serve_sync(sc, args)
            blocked = time.perf_counter() - t0
            self.stats.sync_seconds += blocked
            self.stats.served_sync += 1
            served_async = False
            st.issued = True
        if close_rec is not None:
            # publish barrier: closing a staged file commits it (rename)
            self.staging.publish_close(close_rec)
        if self.controller is not None:
            self.controller.on_serve(blocked, served_async, self.backend)
        save = p.save[fnid]
        if save is not None and not st.harvested:
            save(self.ctx, fep, result)
        st.harvested = True

        # 4. advance the frontier (the peek window's origin moves with it)
        lid = p.out_loop[fnid]
        if lid >= 0:
            fep = fep[:lid] + (fep[lid] + 1,) + fep[lid + 1:]
        self._cur = (p.out_dst[fnid], fep)
        if self._peek_dist > 0:
            self._peek_dist -= 1
        return result

    def intercept_async(self, sc: Sys, args: Tuple[Any, ...]) -> IOFuture:
        """Futures-style entry point: like :meth:`intercept`, but instead of
        blocking at the frontier it hands back an :class:`IOFuture` whose
        ``result()`` is the *late demand point*.

        The future is a harvestable ledger entry: its request may already be
        in flight via speculation (pre-issued by an earlier peek), or is
        demand-issued here — either way it rides the same node-state ledger
        and the same ``pre_issued == served_async + cancelled +
        wasted_completions`` accounting as a blocking intercept.  Compute
        between issue and ``result()`` overlaps with the I/O, with zero new
        threads.

        PURE calls always defer.  A PWRITE defers too when the session runs
        a staging transaction — the write is undoable there (staged extent
        or undo bytes), so its completion can be demanded late exactly like
        a read's; ``result()`` returns the byte count.  Everything else
        (close, fsync, unstaged writes) is an ordering point the frontier
        must serve in place, so it takes the blocking path and returns an
        already-resolved future.
        """
        if self._finished:
            return IOFuture.resolved(self._exec_untracked(sc, args))
        eff = effect_of(sc, args)
        if eff is not Effect.PURE and not (
                eff is Effect.UNDOABLE and sc is Sys.PWRITE
                and self._staging_enabled):
            return IOFuture.resolved(self.intercept(sc, args))
        self.stats.intercepted += 1
        p = self.plan
        nid, ep = self._cur
        res = p.resolve_branches(nid, ep, self.ctx, False)
        if res is None or res[0] == END or p.sc[res[0]] is not sc:
            if self.strict and res is not None and res[0] != END \
                    and p.sc[res[0]] is not sc:
                raise GraphMismatch(
                    f"graph {self.plan.name!r}: expected {p.sc[res[0]]} at "
                    f"node {p.names[res[0]]!r}, application issued {sc}"
                )
            return IOFuture.resolved(self._exec_untracked(sc, args))
        fnid, fep = res[0], res[1]
        self._cur = (fnid, fep)
        self._frontier = (fnid, fep)

        # peek + batch submit, exactly as the blocking path would
        self._peek_and_preissue()

        key = (fnid, fep)
        st = self._state.get(key)
        if st is None:
            st = NodeState()
            self._state[key] = st
        if st.issued and st.req is not None \
                and st.req.state is not ReqState.CANCELLED \
                and not st.harvested \
                and not _spec_args_match(st.req.args, args):
            # harvest-time argument guard, async flavour: never hand out a
            # future backed by a request whose arguments drifted away from
            # the application's — resolve it synchronously instead and let
            # finish() account the stale completion as waste
            st.stale = True
            self.stats.stale_harvests += 1
        if st.issued and (st.req is None
                         or st.req.state is ReqState.CANCELLED
                         or st.stale):
            # evicted under pressure (shared backend) or stale under drift:
            # same demand fallback as a blocking intercept — serve
            # synchronously; the dead request stays in the ledger and is
            # counted at finish
            t0 = time.perf_counter()
            self.backend.note_demand()
            self.device.charge_crossing()
            result = self._serve_sync(sc, args)
            self.stats.sync_seconds += time.perf_counter() - t0
            self.stats.served_sync += 1
            save = p.save[fnid]
            if save is not None and not st.harvested:
                save(self.ctx, fep, result)
            st.harvested = True
            fut = IOFuture.resolved(result)
        else:
            if not st.issued:
                # beyond the peek window (depth exhausted or stub not ready
                # at peek time): demand-issue now — the request still rides
                # the async ledger, and the future defers the wait.  Built
                # via _make_request so an undoable write picks up its staged
                # runner (a bare IORequest would land the bytes in the
                # committed namespace before the txn publishes).
                req = self._make_request(fnid, args, False, fep, False)
                if req is None:  # effect gate refused: serve in place
                    return IOFuture.resolved(self.intercept(sc, args))
                st.issued = True
                st.req = req
                self.stats.pre_issued += 1
                if self.backend.submit([req]):
                    self.stats.submits += 1
            fut = IOFuture(
                st.req,
                resolver=lambda st=st, fnid=fnid, fep=fep:
                    self._harvest_late(st, fnid, fep))
            self.stats.futures_issued += 1
            self._futures.append(fut)

        # advance the frontier without serving it: resolution happens at
        # the future's demand point (or at finish's drain)
        lid = p.out_loop[fnid]
        if lid >= 0:
            fep = fep[:lid] + (fep[lid] + 1,) + fep[lid + 1:]
        self._cur = (p.out_dst[fnid], fep)
        if self._peek_dist > 0:
            self._peek_dist -= 1
        return fut

    def _harvest_late(self, st: NodeState, fnid: int,
                      fep: Tuple[int, ...]) -> Any:
        """Resolve one future: harvest its request exactly as a blocking
        intercept would.  ``backend.wait`` is the demand signal — on a
        shared backend it promotes a deferred chain with demand priority
        (``note_demanded``), so a future demand is indistinguishable from a
        blocking one to the slot scheduler."""
        req = st.req
        t0 = time.perf_counter()
        self.backend.wait(req)
        blocked = time.perf_counter() - t0
        self.stats.wait_seconds += blocked
        self.stats.served_async += 1
        if req.stage is not None and self.staging is not None:
            self.staging.on_demand(req.stage)
        t0 = time.perf_counter()
        result = req.take_result()
        self.stats.harvest_seconds += time.perf_counter() - t0
        save = self.plan.save[fnid]
        if save is not None and not st.harvested:
            save(self.ctx, fep, result)
        st.harvested = True
        if self.controller is not None and not self._finished:
            self.controller.on_serve(blocked, True, self.backend)
        return result

    def _serve_sync(self, sc: Sys, args: Tuple[Any, ...]) -> Any:
        """Serve the frontier synchronously.  With staging on, undoable
        syscalls stay inside the transaction even here: a session is a
        write transaction whether or not speculation got ahead, so the
        abort path can roll back demand writes too."""
        if self._staging_enabled and effect_of(sc, args) is Effect.UNDOABLE:
            txn = self._txn()
            if sc is Sys.OPEN:
                runner, rec = txn.stage_create(
                    args[0], args[1] if len(args) > 1 else "w")
            elif sc is Sys.RENAME:
                runner, rec = txn.stage_rename(args)
            elif not self._fd_is_staged(txn, args[0]):
                runner, rec = txn.stage_overwrite(args)
            else:  # write into a staged file: nothing extra to log
                return execute(self.device, sc, args)
            rec.demanded = True
            return runner(self.device)
        return execute(self.device, sc, args)

    def _exec_untracked(self, sc: Sys, args: Tuple[Any, ...]) -> Any:
        self.stats.untracked += 1
        # untracked closes are still publish barriers (plenty of wrapped
        # functions open through the graph but tear down outside it);
        # resolve the record before the close frees the fd number
        close_rec = None
        if sc is Sys.CLOSE and self.staging is not None:
            close_rec = self.staging.record_for_fd(args[0])
        self.device.charge_crossing()
        result = execute(self.device, sc, args)
        if close_rec is not None:
            self.staging.publish_close(close_rec)
        return result

    # -- teardown ------------------------------------------------------------
    def finish(self) -> SessionStats:
        """Cancel in-flight speculation and account for wasted work.

        Exception-safe and idempotent: even when ``intercept`` raised
        mid-batch (a stub error between the walk and ``submit``, a
        strict-mode :class:`GraphMismatch`, a failed request surfacing at
        ``wait``), every pre-issued-but-unharvested request is cancelled or
        drained exactly once — nothing may keep running into the next
        activation that reuses this backend, and nothing may be counted
        twice.  If cancellation itself raises, the drain and the wasted-work
        accounting still run before the error propagates.  Harvested reads
        released their registered-buffer leases at materialization
        (``take_result``); the leases still attached here — wasted
        completions and cancellations — are recycled strictly after the
        drain, when no worker can still be filling them.
        """
        if self._finished:
            return self.stats
        self._finished = True
        # Late futures settle FIRST, while the backend still runs.  A clean
        # exit drains-then-materializes them: result() after finish returns
        # bytes immediately instead of waiting on a torn-down backend (and
        # on the sync backend, whose ledgered requests only execute at
        # wait(), resolution *is* the execution — cancelling first would
        # lose their results).  A failed session poisons them instead:
        # speculated bytes from a function that raised must never be
        # trusted, and the cancellation sweep below then accounts their
        # requests as cancelled or wasted.
        if self._futures:
            futures, self._futures = self._futures, []
            for fut in futures:
                if fut.settled:
                    continue
                self.stats.futures_drained += 1
                if self._failed:
                    fut.poison(FuturePoisoned(
                        "session failed before this I/O future resolved"))
                else:
                    try:
                        fut.result()
                    except BaseException:
                        pass  # cached in the future; re-raised at result()
        try:
            # quarantined batch from a mid-walk stub error: these never
            # reached the backend, so cancel them here (they are in the
            # node-state ledger and must be accounted exactly once)
            for req in self._orphans:
                req.cancel()
            self.backend.cancel_remaining()
        finally:
            try:
                self.backend.drain()
            finally:
                # Account every pre-issued request from this session's own
                # node-state ledger, not from the backend's return value: on
                # a shared backend the scheduler may have evicted requests
                # mid-session, and a failed link head cancels its chain's
                # dependents on the worker — both must land in ``cancelled``
                # exactly once for the invariant
                #   pre_issued == served_async + cancelled + wasted_completions
                # to hold (tests/test_conformance.py checks it everywhere).
                for st in self._state.values():
                    if not st.issued or st.req is None:
                        continue
                    if st.req.state is ReqState.CANCELLED:
                        self.stats.cancelled += 1
                    elif st.req.state is ReqState.COMPLETED \
                            and (not st.harvested or st.stale):
                        # stale nodes were *served* (synchronously, after
                        # the argument guard rejected the speculation) but
                        # their pre-issued completion is pure waste
                        self.stats.wasted_completions += 1
                    if st.req.lease is not None:
                        # post-drain: no worker is filling it; harvested
                        # results already released at materialization
                        # (take_result), so this only recycles the leases
                        # of wasted completions and cancellations
                        st.req.drop_lease()
                try:
                    # settle the write transaction strictly after the drain:
                    # no staged runner can still be executing.  Success
                    # publishes what the frontier demanded and rolls back
                    # overshoot; failure rolls back everything unpublished.
                    if self.staging is not None:
                        self.staging.finalize(ok=not self._failed)
                finally:
                    if self.controller is not None:
                        self.controller.on_finish(
                            self.stats, time.perf_counter() - self._t0,
                            self.backend
                        )
        return self.stats
