"""Typed syscall descriptors and I/O request records (paper §3.2).

A syscall node is *pure* if it is read-only — its only side effect is
possibly bringing data into the OS page cache (pread, fstat, getdents,
read-only open).  Non-pure syscalls (pwrite, creating opens, close, fsync)
leave permanent side effects and may only be pre-issued when guaranteed to
happen (no weak edge on the path from the frontier — paper §3.3).

Cross-references: docs/ARCHITECTURE.md ("Syscall layer"); *pure syscall* and
*pre-issue* are defined in docs/GLOSSARY.md.
"""

from __future__ import annotations

import threading
from dataclasses import dataclass, field
from enum import Enum
from typing import Any, Optional, Tuple


class Sys(Enum):
    OPEN = "open"
    CLOSE = "close"
    PREAD = "pread"
    PWRITE = "pwrite"
    FSTATAT = "fstatat"
    GETDENTS = "getdents"
    FSYNC = "fsync"


#: read-only syscalls with no externally visible side effect
PURE: frozenset = frozenset({Sys.PREAD, Sys.FSTATAT, Sys.GETDENTS})


def is_pure(sc: Sys, args: Tuple[Any, ...]) -> bool:
    """open(path, 'r') allocates an fd but leaves no persistent state and is
    cancellable via close; creating/truncating opens are non-pure."""
    if sc in PURE:
        return True
    if sc is Sys.OPEN:
        return len(args) < 2 or args[1] == "r"
    return False


class FromRequest:
    """Deferred argument: the result of another (linked) request.

    Used by Link'ed read->write pairs (paper §4.1, Fig. 4b): the pwrite's
    data argument *is* the internal buffer the linked pread populates, with
    no intermediate copy.  Linked chains run in order on one worker, so the
    producer has completed by the time the consumer executes.
    """

    def __init__(self, req: "IORequest"):
        self.req = req

    def resolve(self):
        # The producer may have been submitted in an earlier batch and still
        # be in flight; block until it completes.  (Inside a Link chain the
        # producer has necessarily finished already.)
        self.req.done.wait()
        if self.req.error is not None:
            raise self.req.error
        if self.req.result is None and self.req.state.name == "CANCELLED":
            raise RuntimeError("deferred argument's producer was cancelled")
        return self.req.result


def resolve_args(args: Tuple[Any, ...]) -> Tuple[Any, ...]:
    return tuple(a.resolve() if isinstance(a, FromRequest) else a for a in args)


def execute(device, sc: Sys, args: Tuple[Any, ...]):
    """Dispatch a syscall descriptor against a Device."""
    args = resolve_args(args)
    if sc is Sys.OPEN:
        return device.open(*args)
    if sc is Sys.CLOSE:
        return device.close(*args)
    if sc is Sys.PREAD:
        return device.pread(*args)
    if sc is Sys.PWRITE:
        return device.pwrite(*args)
    if sc is Sys.FSTATAT:
        return device.fstatat(*args)
    if sc is Sys.GETDENTS:
        return device.getdents(*args)
    if sc is Sys.FSYNC:
        return device.fsync(*args)
    raise ValueError(f"unknown syscall {sc}")


class ReqState(Enum):
    PREPARED = 0  # in the submission queue, not yet visible to the 'kernel'
    SUBMITTED = 1  # picked up by the io_workqueue
    COMPLETED = 2  # result in the completion queue
    CANCELLED = 3  # cancelled before execution (early function exit)


@dataclass
class IORequest:
    """One entry in the submission queue.

    ``link`` forces this request to be executed before the next one in the
    same submitted batch on the same worker (io_uring IOSQE_IO_LINK).
    """

    sc: Sys
    args: Tuple[Any, ...]
    link: bool = False
    tag: Any = None  # (node id, epoch) — used by the engine to find it again
    #: dispatch priority (io_uring's IOSQE ioprio analogue): worker pools
    #: run higher values first; shared-backend views stamp their tenant's
    #: priority class here, demand promotions outrank all speculation
    priority: int = 0
    state: ReqState = ReqState.PREPARED
    result: Any = None
    error: Optional[BaseException] = None
    done: threading.Event = field(default_factory=threading.Event, repr=False)
    # serializes the PREPARED -> {SUBMITTED, CANCELLED} transition: a worker
    # claiming the request and a canceller (early exit, scheduler eviction)
    # race on the same check-then-act, and whoever loses must see the other's
    # state — otherwise a cancelled request could still execute (or execute
    # twice via the demand-promotion fallback).
    _claim_lock: threading.Lock = field(default_factory=threading.Lock,
                                        repr=False)

    def finish(self, result: Any = None, error: Optional[BaseException] = None) -> None:
        self.result = result
        self.error = error
        self.state = ReqState.COMPLETED
        self.done.set()

    def claim(self) -> bool:
        """Atomically take PREPARED -> SUBMITTED (a worker about to execute
        it); False means it was already claimed, cancelled, or completed."""
        with self._claim_lock:
            if self.state is ReqState.PREPARED:
                self.state = ReqState.SUBMITTED
                return True
            return False

    def cancel(self) -> bool:
        with self._claim_lock:
            if self.state is ReqState.PREPARED:
                self.state = ReqState.CANCELLED
                self.done.set()
                return True
            return False

    def wait_result(self):
        self.done.wait()
        if self.error is not None:
            raise self.error
        if self.state is ReqState.CANCELLED:
            raise RuntimeError("waited on a cancelled I/O request")
        return self.result
