"""Typed syscall descriptors and I/O request records (paper §3.2).

Every syscall node falls into one of three *effect classes* (the paper's
§3.3 pure/non-pure split, refined so that write chains become speculable):

* **pure** — read-only; the only side effect is possibly bringing data into
  the OS page cache (pread, fstat, getdents, read-only open).  Always safe
  to pre-issue, even across weak edges.
* **undoable** — leaves persistent state that a staging layer can revert:
  pwrite (old bytes can be logged and replayed), truncating-create opens
  (the file can land in a staged name and be renamed into place later),
  and rename to a fresh destination (renaming back restores the namespace).
  Pre-issuable across weak edges *when the session runs a staging
  transaction* (:mod:`repro.store.staging`); otherwise only when guaranteed.
* **barrier** — unrecoverable or ordering-bearing side effects: fsync,
  close, unlink (the removed bytes are gone), and opens of pre-existing
  files in write modes ("rw"/"a", whose
  prior contents a file-granularity stage cannot preserve).  Never
  pre-issued across a weak edge; serving one at the frontier is the
  *publish barrier* that commits the staged files behind it.

Cross-references: docs/ARCHITECTURE.md ("Syscall layer", "Undoable write
speculation"); *pure syscall*, *undoable syscall* and *publish barrier* are
defined in docs/GLOSSARY.md.
"""

from __future__ import annotations

import threading
from dataclasses import dataclass, field
from enum import Enum
from typing import Any, Callable, Optional, Tuple

from .completion import completion_pool


class Sys(Enum):
    OPEN = "open"
    CLOSE = "close"
    PREAD = "pread"
    PWRITE = "pwrite"
    FSTATAT = "fstatat"
    GETDENTS = "getdents"
    FSYNC = "fsync"
    RENAME = "rename"
    UNLINK = "unlink"


#: read-only syscalls with no externally visible side effect
PURE: frozenset = frozenset({Sys.PREAD, Sys.FSTATAT, Sys.GETDENTS})


class Effect(Enum):
    """Three-way side-effect classification of a (syscall, args) pair."""

    PURE = "pure"
    UNDOABLE = "undoable"
    BARRIER = "barrier"


def effect_of(sc: Sys, args: Tuple[Any, ...]) -> Effect:
    """Classify a concrete call.

    open(path, 'r') allocates an fd but leaves no persistent state and is
    cancellable via close — pure.  open(path, 'w') truncating-creates: the
    file can be staged under a temporary name and renamed into place at
    publish — undoable.  open with 'rw'/'a' mutates a file that may already
    exist, which file-granularity staging cannot revert — barrier.
    """
    if sc in PURE:
        return Effect.PURE
    if sc is Sys.OPEN:
        if len(args) < 2 or args[1] == "r":
            return Effect.PURE
        if args[1] == "w":
            return Effect.UNDOABLE
        return Effect.BARRIER
    if sc is Sys.PWRITE:
        return Effect.UNDOABLE
    if sc is Sys.RENAME:
        # renaming back restores the old namespace (staged renames assume a
        # fresh destination; see repro.store.staging.StagingTxn.stage_rename)
        return Effect.UNDOABLE
    return Effect.BARRIER  # close, fsync, unlink


def is_pure(sc: Sys, args: Tuple[Any, ...]) -> bool:
    return effect_of(sc, args) is Effect.PURE


class FutureCancelled(RuntimeError):
    """Raised by :meth:`IOFuture.result` when the future was explicitly
    cancelled (:meth:`IOFuture.cancel`) before it resolved."""


class IOFuture:
    """First-class deferred I/O result (the futures-style session API).

    An unresolved future is a *harvestable ledger entry*: its
    :class:`IORequest` may already be in flight via speculation, and
    :meth:`result` is a *late demand point* — the engine harvests (or
    demand-promotes, on a shared backend) the request only when the caller
    finally needs the bytes, so compute between issue and ``result()``
    overlaps with I/O with zero new threads.

    Resolution runs at most once, under an internal lock; the value or
    error is cached, so repeated ``result()`` calls are cheap and a failed
    session's *poisoned* futures keep raising the same error.  A future is
    also a valid graph-stub input: :class:`FromRequest` accepts one, so a
    consumer node's argument can be "whatever this future resolves to".
    """

    __slots__ = ("req", "_resolver", "_lock", "_done_flag", "_value", "_error")

    def __init__(self, req: Optional["IORequest"] = None,
                 resolver: Optional[Callable[[], Any]] = None):
        self.req = req
        self._resolver = resolver
        self._lock = threading.Lock()
        self._done_flag = False
        self._value: Any = None
        self._error: Optional[BaseException] = None

    @classmethod
    def resolved(cls, value: Any) -> "IOFuture":
        """An already-materialized future (the no-session / recorder path)."""
        f = cls()
        f._done_flag = True
        f._value = value
        return f

    def done(self) -> bool:
        """True once ``result()`` is guaranteed not to block: the future is
        resolved, poisoned, or its request has reached a terminal state."""
        if self._done_flag:
            return True
        return self.req is not None and self.req.is_done()

    @property
    def settled(self) -> bool:
        """True once the future's value or error is pinned (resolved,
        poisoned, or cancelled).  Unlike :meth:`done`, completion of the
        underlying request alone does not settle a future — the session's
        finish() drain materializes completed-but-unresolved ones."""
        return self._done_flag

    def result(self) -> Any:
        """Resolve (demand) the future; returns the same bytes the blocking
        ``io.*`` call would have, or raises the same error it would have."""
        with self._lock:
            if not self._done_flag:
                try:
                    if self._resolver is not None:
                        self._value = self._resolver()
                    elif self.req is not None:
                        self._value = self.req.wait_result()
                except BaseException as e:
                    self._error = e
                self._done_flag = True
                self._resolver = None
            if self._error is not None:
                raise self._error
            return self._value

    def poison(self, error: BaseException) -> bool:
        """Mark an unresolved future as failed — ``result()`` will raise
        ``error`` forever after.  No-op (False) if already resolved."""
        with self._lock:
            if self._done_flag:
                return False
            self._done_flag = True
            self._error = error
            self._resolver = None
            return True

    def cancel(self) -> bool:
        """Abandon an unresolved future: its request is cancelled if still
        queued (counted *cancelled* in the session ledger; a completed one
        becomes a *wasted completion* at finish), and ``result()`` raises
        :class:`FutureCancelled` from now on.  False if already resolved."""
        with self._lock:
            if self._done_flag:
                return False
            self._done_flag = True
            self._error = FutureCancelled("I/O future was cancelled")
            self._resolver = None
        if self.req is not None:
            self.req.cancel()
        return True


class FromRequest:
    """Deferred argument: the result of another (linked) request.

    Used by Link'ed read->write pairs (paper §4.1, Fig. 4b): the pwrite's
    data argument *is* the internal buffer the linked pread populates, with
    no intermediate copy.  Linked chains run in order on one worker, so the
    producer has completed by the time the consumer executes.

    Also accepts an :class:`IOFuture`: a consumer node's argument can be a
    future another part of the program holds — resolution then routes
    through the future (so the session's late-demand accounting and the
    future's cached value/error stay authoritative).
    """

    def __init__(self, req):
        if isinstance(req, IOFuture):
            self._future: Optional[IOFuture] = req
            self.req = req.req
        else:
            self._future = None
            self.req = req

    def resolve(self):
        if self._future is not None:
            return self._future.result()
        # The producer may have been submitted in an earlier batch and still
        # be in flight; block until it completes.  (Inside a Link chain the
        # producer has necessarily finished already.)
        self.req.wait_done()
        if self.req.error is not None:
            raise self.req.error
        if self.req.result is None and self.req.state.name == "CANCELLED":
            raise RuntimeError("deferred argument's producer was cancelled")
        # materialize (never hand out the registered buffer itself: the
        # lease is recycled at session teardown and a raw view would dangle)
        return self.req.take_result()


def resolve_args(args: Tuple[Any, ...]) -> Tuple[Any, ...]:
    return tuple(a.resolve() if isinstance(a, FromRequest)
                 else a.result() if isinstance(a, IOFuture)
                 else a for a in args)


def execute(device, sc: Sys, args: Tuple[Any, ...]):
    """Dispatch a syscall descriptor against a Device."""
    args = resolve_args(args)
    if sc is Sys.OPEN:
        return device.open(*args)
    if sc is Sys.CLOSE:
        return device.close(*args)
    if sc is Sys.PREAD:
        return device.pread(*args)
    if sc is Sys.PWRITE:
        return device.pwrite(*args)
    if sc is Sys.FSTATAT:
        return device.fstatat(*args)
    if sc is Sys.GETDENTS:
        return device.getdents(*args)
    if sc is Sys.FSYNC:
        return device.fsync(*args)
    if sc is Sys.RENAME:
        return device.rename(*args)
    if sc is Sys.UNLINK:
        return device.unlink(*args)
    raise ValueError(f"unknown syscall {sc}")


def perform(device, req: "IORequest"):
    """Execute one request against a device, honouring its staged runner
    and its registered-buffer lease.

    Every execution site (worker pools, the sync backend's deferred
    execution, the shared backend's inline demand fallback) must go through
    here — calling ``execute`` directly would bypass staging and land a
    speculative write in the committed namespace.

    A leased PREAD reads *into* its registered buffer
    (:meth:`repro.core.device.Device.pread_into`): no per-request result
    allocation on the device side, and a speculated read the function never
    demands costs zero allocations.  The request's result is the lease;
    consumers materialize bytes through :meth:`IORequest.take_result`.
    """
    if req.runner is not None:
        return req.runner(device)
    lease = req.lease
    if lease is not None and req.sc is Sys.PREAD:
        fd, size, offset = resolve_args(req.args)
        n = device.pread_into(fd, lease.mv[:size], offset)
        lease.filled(n)
        return lease
    return execute(device, req.sc, req.args)


class ReqState(Enum):
    PREPARED = 0  # in the submission queue, not yet visible to the 'kernel'
    SUBMITTED = 1  # picked up by the io_workqueue
    COMPLETED = 2  # result in the completion queue
    CANCELLED = 3  # cancelled before execution (early function exit)


@dataclass
class IORequest:
    """One entry in the submission queue.

    ``link`` forces this request to be executed before the next one in the
    same submitted batch on the same worker (io_uring IOSQE_IO_LINK).
    """

    sc: Sys
    args: Tuple[Any, ...]
    link: bool = False
    tag: Any = None  # (node id, epoch) — used by the engine to find it again
    #: staged execution override: when set, workers call ``runner(device)``
    #: instead of ``execute(device, sc, args)`` — the staging layer uses it
    #: to redirect a speculative create to its staged name or to capture an
    #: overwrite's undo bytes before the write lands
    runner: Optional[Callable[[Any], Any]] = None
    #: the StageRecord this request belongs to, if its side effect is staged
    #: (undo/publish bookkeeping lives in repro.store.staging)
    stage: Any = None
    #: for CLOSE requests: the staged-create record this close is the
    #: publish barrier of.  Resolved at pre-issue time, while the fd is
    #: provably still open — resolving at harvest would race with OS
    #: fd-number reuse once the worker-executed close freed the number.
    barrier_for: Any = None
    #: dispatch priority (io_uring's IOSQE ioprio analogue): worker pools
    #: run higher values first; shared-backend views stamp their tenant's
    #: priority class here, demand promotions outrank all speculation
    priority: int = 0
    #: registered-buffer lease (repro.core.buffers), attached by the I/O
    #: plane at dispatch time for PREAD requests; the worker fills it, and
    #: the engine releases it back to the pool at session teardown
    lease: Any = None
    #: owning tenant name on a shared backend (stamped by the view alongside
    #: the priority class); the buffer pool charges leases against it
    tenant: Optional[str] = None
    #: the FusedRead this request belongs to, when the plane's extent
    #: coalescer fused it into a super-read (repro.core.coalesce).  Backends
    #: consult it on demand-wait: a satellite whose carrier was cancelled or
    #: failed is decomposed back to its own per-extent read.
    fused: Any = field(default=None, repr=False)
    #: completion hook — fired exactly once, on whichever of finish/cancel
    #: terminates the request first (the slot scheduler hangs its O(1) slot
    #: accounting here).  Fired outside the stripe lock; must not block.
    completion_cb: Optional[Callable[["IORequest"], None]] = \
        field(default=None, repr=False)
    state: ReqState = ReqState.PREPARED
    result: Any = None
    error: Optional[BaseException] = None
    #: terminal flag, readable lock-free under the GIL (result/error/state
    #: are written strictly before it); blocking waits ride the process-wide
    #: completion pool (repro.core.completion) instead of a per-request
    #: Event — zero lock allocations on the per-request hot path.
    _done: bool = field(default=False, repr=False)

    def is_done(self) -> bool:
        """True once the request reached COMPLETED or CANCELLED."""
        return self._done

    def wait_done(self, timeout: Optional[float] = None) -> bool:
        """Block until the request is terminal; False on timeout."""
        return completion_pool().wait(self, timeout)

    def finish(self, result: Any = None, error: Optional[BaseException] = None) -> None:
        self.result = result
        self.error = error
        self.state = ReqState.COMPLETED
        s = completion_pool().stripe(self)
        with s.lock:
            cb, self.completion_cb = self.completion_cb, None
            self._done = True
            if s.waiters:
                s.cond.notify_all()
        if cb is not None:
            cb(self)

    def claim(self) -> bool:
        """Atomically take PREPARED -> SUBMITTED (a worker about to execute
        it); False means it was already claimed, cancelled, or completed.
        The stripe lock serializes this against cancel(): whoever loses must
        see the other's state — otherwise a cancelled request could still
        execute (or execute twice via the demand-promotion fallback)."""
        s = completion_pool().stripe(self)
        with s.lock:
            if self.state is ReqState.PREPARED:
                self.state = ReqState.SUBMITTED
                return True
            return False

    def cancel(self) -> bool:
        s = completion_pool().stripe(self)
        with s.lock:
            if self.state is not ReqState.PREPARED:
                return False
            self.state = ReqState.CANCELLED
            cb, self.completion_cb = self.completion_cb, None
            self._done = True
            if s.waiters:
                s.cond.notify_all()
        if cb is not None:
            cb(self)
        return True

    def take_result(self):
        """The request's result with any registered-buffer lease
        materialized to ``bytes`` (paper Fig. 10's result copy — exactly one
        bounded memcpy, cached so repeated consumers share the object).

        Materialization releases the lease: once the bytes are copied out,
        nothing reads the registered buffer again, so it goes back to the
        pool *mid-session* instead of at teardown — a long session's pool
        occupancy stays O(depth), not O(reads).  The stripe lock serializes
        concurrent consumers (two futures, a future plus a ``FromRequest``
        stub): exactly one copies and releases; the rest see bytes."""
        lease = None
        s = completion_pool().stripe(self)
        with s.lock:
            r = self.result
            if self.lease is not None and r is self.lease:
                lease, self.lease = self.lease, None
                r = lease.to_bytes()
                self.result = r
        if lease is not None:
            lease.release()
        return r

    def drop_lease(self) -> None:
        """Return an unconsumed lease to the pool (wasted completions and
        cancellations at session teardown); idempotent with take_result."""
        s = completion_pool().stripe(self)
        with s.lock:
            lease, self.lease = self.lease, None
            if self.result is lease:
                self.result = None
        if lease is not None:
            lease.release()

    def wait_result(self):
        self.wait_done()
        if self.error is not None:
            raise self.error
        if self.state is ReqState.CANCELLED:
            raise RuntimeError("waited on a cancelled I/O request")
        return self.result
