"""Public Foreactor API (paper §5.1): graph registration, function wrapping,
and the POSIX-call interception layer.

Python offers no linker ``--wrap``/LD_PRELOAD, so we use the paper's stated
alternative ("developers could directly inject wrapper code in-place around
candidate functions", §5.4): application code performs I/O through the
``repro.core.api.io`` module-level functions, and a registered function is
activated with ``Foreactor.wrap``.  While an activation is live on a thread,
every ``io.*`` call on that thread is intercepted by its ``SpecSession``;
otherwise calls go straight to the device.  Graph instances are per-thread
(paper: "every foreaction graph instance is per-thread local").

Backend selection is topology-aware: the default ``backend="auto"`` resolves
to per-device queue pairs (:class:`repro.core.backends.MultiQueueBackend`)
when the device is a :class:`repro.core.device.ShardedDevice`, and to the
single io_uring-style queue pair otherwise — existing call sites gain
multi-device fan-out transparently.

Concurrency model is opt-in per Foreactor: the default keeps one private
live queue pair per application thread (the paper's setup); ``shared=True``
instead multiplexes every concurrent session onto ONE backend through a
:class:`repro.core.backends.SlotScheduler` — sessions carry a *tenant*
identity (``activate(tenant=...)``, the ``fa.tenant(...)`` thread context,
or the thread name) and lease submission slots weighted-fairly, so a
serving process with hundreds of clients does not need hundreds of worker
pools and no tenant's demand I/O waits behind another's speculation.

Cross-references: docs/ARCHITECTURE.md ("Public API") maps this module to
paper §5.1; docs/GLOSSARY.md defines the terms used here.
"""

from __future__ import annotations

import contextlib
import functools
import threading
from typing import Any, Callable, Dict, List, Optional, Tuple, Union

from .backends import (Backend, SharedBackend, SlotScheduler, SyncBackend,
                       make_backend, resolve_priority)
from .device import Device, OSDevice
from .engine import DepthController, SessionStats, SpecSession
from .graph import ForeactionGraph
from .plan import GraphPlan, compile_plan
from .plan import stats as plan_stats
from .syscalls import IOFuture, Sys
from .trace import RecordingSession, Trace, TraceRecorder, TraceRing

_tls = threading.local()


def _session_stack() -> List[SpecSession]:
    st = getattr(_tls, "stack", None)
    if st is None:
        st = []
        _tls.stack = st
    return st


def current_session() -> Optional[SpecSession]:
    st = _session_stack()
    return st[-1] if st else None


class Foreactor:
    """The libforeactor singleton-ish object: device + backend + registry."""

    def __init__(
        self,
        device: Optional[Device] = None,
        backend: str = "auto",
        depth: Union[int, str] = 8,
        workers: int = 16,
        strict: bool = False,
        depth_range: Tuple[int, int] = (1, 64),
        shared: bool = False,
        shared_slots: Optional[int] = None,
        staging: bool = True,
        trace_capacity: int = 64,
        coalesce: bool = False,
    ):
        if not (isinstance(depth, int) or depth == "adaptive"):
            raise ValueError(f"depth must be an int or 'adaptive', got {depth!r}")
        self.device = device if device is not None else OSDevice()
        self.backend_name = backend
        self.depth = depth
        self.depth_range = depth_range
        self.workers = workers
        self.strict = strict
        #: shared=True replaces the per-thread private queue pairs with ONE
        #: backend whose submission slots are leased to concurrent sessions
        #: through a SlotScheduler (multi-tenant serving mode).  shared_slots
        #: sets the scheduler's slot window independently of the worker
        #: count (slots above it queue as cancellable, evictable entries);
        #: default: one slot per worker.
        self.shared = shared
        self.shared_slots = shared_slots
        #: extent coalescing (repro.core.coalesce): async backends fuse
        #: adjacent same-fd PREAD runs into MB-scale super-reads at
        #: dispatch.  Off by default — it changes the device-op profile
        #: (fewer, larger reads), which bandwidth-oriented workloads want
        #: and op-count-sensitive tests do not.
        self.coalesce = coalesce
        #: undoable write speculation (repro.store.staging): sessions run
        #: tracked writes inside a staging transaction — speculative pwrites
        #: land in staging extents / carry undo bytes, creating opens get
        #: anonymous staged names, publish happens at close barriers or
        #: session commit, rollback on abort.  Requires device support
        #: (rename/unlink/truncate); silently off where unsupported.
        self.staging = staging and getattr(
            self.device, "supports_staging", lambda: False)()
        self._graphs: Dict[str, ForeactionGraph] = {}
        self._graph_builders: Dict[str, Callable[[], ForeactionGraph]] = {}
        #: plan-cache observability, per graph name: how many times plan()
        #: was probed, how many probes produced a new plan object (compile
        #: or first sight), and how many times the graph was (re)built — the
        #: version bumps when mine() replaces a registered graph, so serving
        #: stats can tell plan-cache thrash from healthy reuse
        self._plan_probes: Dict[str, int] = {}
        self._plan_builds: Dict[str, int] = {}
        # the last plan OBJECT seen per (name, mode) — identity, not id():
        # a recompiled plan can land at a freed predecessor's address
        self._plan_seen: Dict[Tuple[str, str], GraphPlan] = {}
        self._graph_versions: Dict[str, int] = {}
        #: hot-swap observability, per graph name: how many times a new
        #: builder replaced the registered one mid-flight (swap_graph) and
        #: how many of those were the rollback guard restoring the previous
        #: graph after a regression
        self._graph_swaps: Dict[str, int] = {}
        self._graph_rollbacks: Dict[str, int] = {}
        self._controllers: Dict[str, DepthController] = {}
        #: recorded traces, one bounded ring per endpoint — sampling must
        #: never grow memory without bound (every trace pins its raw I/O
        #: buffers); overflow evicts the oldest pair and is counted in
        #: trace_stats()
        self.trace_capacity = trace_capacity
        self._traces: Dict[str, TraceRing] = {}
        #: attached online re-miner (repro.analysis.remine.ReMiner), or None
        self._reminer = None
        self.total_stats = SessionStats()
        self._backends: List[Backend] = []
        self._backend_pool = threading.local()  # one live queue pair per thread
        self._tenant_tls = threading.local()  # fa.tenant(...) context state
        self.scheduler: Optional[SlotScheduler] = None
        self._shared_inner: Optional[Backend] = None
        self._lock = threading.Lock()

    # -- registry ----------------------------------------------------------
    def register(self, name: str, builder: Callable[[], ForeactionGraph]) -> None:
        """Register a graph builder; built lazily on first activation
        (paper: 'invoked only once upon the first invocation of f')."""
        self._graph_builders[name] = builder

    def graph(self, name: str) -> ForeactionGraph:
        with self._lock:
            if name not in self._graphs:
                self._graphs[name] = self._graph_builders[name]()
                self._graph_versions[name] = \
                    self._graph_versions.get(name, 0) + 1
            return self._graphs[name]

    def invalidate_graph(self, name: str) -> None:
        """Drop the cached built graph so the next activation rebuilds it
        from the (possibly re-registered) builder — bumping the graph
        version ``plan_cache_stats`` reports.  ``mine()`` uses this when a
        mined graph replaces a registered one."""
        with self._lock:
            self._graphs.pop(name, None)

    def graph_version(self, name: str) -> int:
        """Times the graph under ``name`` has been built (bumps on the
        first build after registration, mine() re-registration, or a
        swap_graph hot-swap).  0 until the first activation builds it."""
        with self._lock:
            return self._graph_versions.get(name, 0)

    def swap_graph(self, name: str,
                   builder: Callable[[], ForeactionGraph],
                   rollback: bool = False) -> Optional[Callable[[], ForeactionGraph]]:
        """Atomically hot-swap the registered graph: replace the builder and
        drop the cached built graph in one critical section, so the next
        activation builds (and compiles) the new graph at version N+1 while
        every in-flight session keeps speculating on the plan object it
        activated with — plans are immutable and cached per graph *object*,
        so a swap can never mutate a live session's schedule.

        Returns the previous builder (the re-miner stashes it so its
        regression guard can roll back a swap whose waste ledger regresses;
        ``rollback=True`` marks this swap as such a restoration).  Counted
        per graph in :meth:`plan_cache_stats` (``swaps``/``rollbacks``)."""
        with self._lock:
            prev = self._graph_builders.get(name)
            self._graph_builders[name] = builder
            self._graphs.pop(name, None)  # next activation builds version N+1
            self._graph_swaps[name] = self._graph_swaps.get(name, 0) + 1
            if rollback:
                self._graph_rollbacks[name] = \
                    self._graph_rollbacks.get(name, 0) + 1
        return prev

    @property
    def reminer(self):
        """The attached online re-miner, or None."""
        return self._reminer

    def attach_reminer(self, reminer) -> None:
        """Attach an online re-miner (:class:`repro.analysis.remine.ReMiner`
        does this in its constructor).  From then on ``activate`` asks it to
        elect sampled activations (which record a trace serially instead of
        speculating) and ``deactivate`` feeds it every finished session's
        stats for the per-version waste ledger its rollback guard watches."""
        self._reminer = reminer

    def _depth_mode(self, depth) -> str:
        return "adaptive" if depth == "adaptive" else "fixed"

    def plan(self, name: str, depth: Optional[Union[int, str]] = None) -> GraphPlan:
        """The compiled :class:`GraphPlan` for a registered graph — built
        (and the graph itself, if still lazy) on first use, then cached per
        ``(graph, depth-mode)`` so every activation pays one dict probe.
        Consumers with latency-critical first calls (checkpoint saves,
        serving warm-up) call this eagerly to move compilation off the
        measured path."""
        depth = self.depth if depth is None else depth
        mode = self._depth_mode(depth)
        p = compile_plan(self.graph(name), mode)
        with self._lock:
            self._plan_probes[name] = self._plan_probes.get(name, 0) + 1
            if self._plan_seen.get((name, mode)) is not p:
                self._plan_seen[(name, mode)] = p
                self._plan_builds[name] = self._plan_builds.get(name, 0) + 1
        return p

    def plan_cache_stats(self) -> Dict[str, Any]:
        """Plan-cache and graph-version observability, surfaced in serving
        summaries (``repro.launch.ioserver``): per graph name, ``probes``
        (plan() calls), ``compiles`` (probes that produced a new plan
        object), ``hits`` (probes served by the cache), ``graph_version``
        (times the graph was built — bumps when a mined graph replaces a
        registered one), and ``swaps``/``rollbacks`` (hot-swaps applied by
        the online re-miner, and how many of those its regression guard
        reverted).  ``global`` mirrors the process-wide
        :data:`repro.core.plan.stats` counters."""
        with self._lock:
            per = {}
            for name in set(self._plan_probes) | set(self._graph_swaps):
                probes = self._plan_probes.get(name, 0)
                builds = self._plan_builds.get(name, 0)
                per[name] = {
                    "probes": probes,
                    "compiles": builds,
                    "hits": probes - builds,
                    "graph_version": self._graph_versions.get(name, 0),
                    "swaps": self._graph_swaps.get(name, 0),
                    "rollbacks": self._graph_rollbacks.get(name, 0),
                }
            return {"per_graph": per, "global": dict(plan_stats)}

    def _make_backend(self) -> Backend:
        """Per-thread backend reuse: like the paper, each application thread
        keeps its own live io_uring queue pair across activations instead of
        paying setup cost per wrapped call."""
        b = getattr(self._backend_pool, "backend", None)
        if b is None:
            b = make_backend(self.backend_name, self.device,
                             workers=self.workers, coalesce=self.coalesce)
            self._backend_pool.backend = b
            with self._lock:
                self._backends.append(b)
        return b

    def shared_backend(self) -> Backend:
        """The one shared async backend (created lazily; ``shared=True``)."""
        with self._lock:
            if self._shared_inner is None:
                inner = make_backend(self.backend_name, self.device,
                                     workers=self.workers,
                                     coalesce=self.coalesce)
                if isinstance(inner, SyncBackend):
                    raise ValueError(
                        "shared=True needs an async backend (got 'sync')")
                self._shared_inner = inner
                self.scheduler = SlotScheduler(self.shared_slots
                                               or inner.capacity)
                self._backends.append(inner)
            return self._shared_inner

    @contextlib.contextmanager
    def tenant(self, name: str, weight: float = 1.0, priority="normal"):
        """Default tenant identity for activations made on this thread —
        how a serving client thread (or anything activating indirectly,
        e.g. through the checkpoint manager) states who it is and what its
        weight/priority class are, without threading kwargs through every
        call site."""
        prev = getattr(self._tenant_tls, "ident", None)
        self._tenant_tls.ident = (name, float(weight), priority)
        try:
            yield self
        finally:
            self._tenant_tls.ident = prev

    def _shared_view(self, tenant: Optional[str], weight: Optional[float],
                     priority) -> SharedBackend:
        inner = self.shared_backend()
        tls = getattr(self._tenant_tls, "ident", None)
        if tenant is None:
            # the TLS context's weight/priority belong to the TLS tenant —
            # they must never leak onto an explicitly named tenant
            tenant = tls[0] if tls else threading.current_thread().name
            if weight is None:
                weight = tls[1] if tls else None
            if priority is None:
                priority = tls[2] if tls else None
        return SharedBackend(inner, self.scheduler, tenant=tenant,
                             weight=1.0 if weight is None else weight,
                             priority=resolve_priority(
                                 "normal" if priority is None else priority))

    def controller(self, graph_name: str) -> DepthController:
        """The shared per-graph adaptive depth controller (created lazily);
        sessions of the same graph learn one depth together."""
        with self._lock:
            c = self._controllers.get(graph_name)
            if c is None:
                lo, hi = self.depth_range
                c = DepthController(min_depth=lo, max_depth=hi)
                self._controllers[graph_name] = c
            return c

    # -- activation ----------------------------------------------------------
    def activate(self, graph_name: str, ctx: Dict[str, Any],
                 depth: Optional[Union[int, str]] = None,
                 tenant: Optional[str] = None,
                 weight: Optional[float] = None,
                 priority=None) -> SpecSession:
        # trace sampling: an attached re-miner elects 1-in-N activations per
        # watched endpoint; those run serially under a RecordingSession (no
        # speculation — observation must not perturb the pattern) and
        # deliver their trace to the endpoint's bounded ring on clean finish
        rm = self._reminer
        if rm is not None and rm.sample(graph_name):
            rec = RecordingSession(self.device, graph_name, ctx,
                                   sink=self._deliver_trace)
            rec.graph_version = self.graph_version(graph_name)
            _session_stack().append(rec)
            return rec  # duck-types the SpecSession surface wrap/io touch
        depth = self.depth if depth is None else depth
        controller = None
        if depth == "adaptive":
            controller = self.controller(graph_name)
            depth = 0  # ignored: SpecSession.depth tracks the controller live
        if self.shared:
            backend: Backend = self._shared_view(tenant, weight, priority)
        else:
            backend = self._make_backend()
        graph = self.graph(graph_name)
        sess = SpecSession(
            graph=graph,
            ctx=ctx,
            backend=backend,
            device=self.device,
            depth=depth,
            strict=self.strict,
            controller=controller,
            tenant=tenant,
            staging=self.staging,
            plan=self.plan(graph_name,
                           "adaptive" if controller is not None else depth),
            graph_name=graph_name,
            graph_version=self.graph_version(graph_name),
        )
        _session_stack().append(sess)
        return sess

    def deactivate(self, sess: SpecSession) -> SessionStats:
        st = _session_stack()
        assert st and st[-1] is sess, "unbalanced session stack"
        st.pop()
        stats = sess.finish()  # cancels leftovers + drains; backend is reused
        if getattr(sess.backend, "is_view", False):
            sess.backend.shutdown()  # release the slot lease, keep the inner
        with self._lock:
            self.total_stats.merge(stats)
        rm = self._reminer
        if rm is not None and not getattr(sess, "is_recording", False):
            # per-version waste ledger for the rollback guard: attribute
            # this session's counters to the graph build it activated on
            rm.on_session_finish(getattr(sess, "graph_name", None),
                                 getattr(sess, "graph_version", 0), stats)
        return stats

    def wrap(self, graph_name: str,
             capture: Callable[..., Dict[str, Any]],
             auto_graph: bool = False,
             observe_calls: int = 2,
             tenant: Optional[Union[str, Callable[..., str]]] = None,
             weight: Optional[float] = None,
             priority=None) -> Callable:
        """Decorator: shadow function ``f`` with a wrapper that captures the
        Input annotation variables and runs ``f`` under a SpecSession.

        ``tenant``/``weight``/``priority`` set the activation's identity for
        the shared-backend scheduler (``shared=True``); ``tenant`` may be a
        callable over the wrapped function's arguments for per-call tenancy.
        Unset, they fall back to the thread's ``fa.tenant(...)`` context and
        then to the thread name.

        With ``auto_graph=True`` no registered graph is needed: the first
        ``observe_calls`` invocations run serially under a
        :class:`TraceRecorder`, then the traces are mined into a graph
        (:func:`repro.analysis.mine.mine_and_validate`) and — if the mined
        graph replays every recorded trace exactly — registered and used for
        speculation from then on.  A function the miner cannot prove sound
        stays permanently serial (``wrapper.__foreactor_auto__['state']``
        reports ``'disabled'`` with the reason) rather than speculating on a
        wrong graph.
        """

        def _tenant_of(args, kwargs) -> Optional[str]:
            return tenant(*args, **kwargs) if callable(tenant) else tenant

        def deco(fn: Callable) -> Callable:
            if not auto_graph:
                @functools.wraps(fn)
                def wrapper(*args, **kwargs):
                    ctx = capture(*args, **kwargs)
                    sess = self.activate(graph_name, ctx,
                                         tenant=_tenant_of(args, kwargs),
                                         weight=weight, priority=priority)
                    try:
                        return fn(*args, **kwargs)
                    except BaseException:
                        # the staging transaction must roll back, not commit
                        sess.mark_failed()
                        raise
                    finally:
                        self.deactivate(sess)

                wrapper.__foreactor_graph__ = graph_name  # type: ignore[attr-defined]
                return wrapper

            state = {"state": "observing", "reason": None}
            state_lock = threading.Lock()

            @functools.wraps(fn)
            def auto_wrapper(*args, **kwargs):
                with state_lock:
                    mode = state["state"]
                if mode == "speculating":
                    ctx = capture(*args, **kwargs)
                    sess = self.activate(graph_name, ctx,
                                         tenant=_tenant_of(args, kwargs),
                                         weight=weight, priority=priority)
                    try:
                        return fn(*args, **kwargs)
                    except BaseException:
                        sess.mark_failed()
                        raise
                    finally:
                        self.deactivate(sess)
                if mode == "disabled":
                    return fn(*args, **kwargs)
                # observing: record one more trace, then try to mine
                ctx = capture(*args, **kwargs)
                out = self.record(graph_name, ctx, fn, *args, **kwargs)
                with state_lock:
                    if state["state"] == "observing" \
                            and len(self.traces(graph_name)) >= observe_calls:
                        try:
                            self.mine(graph_name)
                            state["state"] = "speculating"
                        except Exception as e:  # Unminable / Unsound
                            state["state"] = "disabled"
                            state["reason"] = str(e)
                return out

            auto_wrapper.__foreactor_graph__ = graph_name  # type: ignore[attr-defined]
            auto_wrapper.__foreactor_auto__ = state  # type: ignore[attr-defined]
            return auto_wrapper

        return deco

    # -- observe-then-speculate ----------------------------------------------
    def record(self, name: str, ctx: Dict[str, Any],
               fn: Callable, *args, **kwargs) -> Any:
        """Run ``fn`` once under a :class:`TraceRecorder` (serial, direct
        execution) and store the (ctx, trace) pair under ``name``."""
        rec = TraceRecorder(self.device, name=name)
        _session_stack().append(rec)
        try:
            out = fn(*args, **kwargs)
        finally:
            st = _session_stack()
            assert st and st[-1] is rec, "unbalanced recorder stack"
            st.pop()
        trace = rec.finish()
        self._deliver_trace(name, ctx, trace)
        return out

    def _deliver_trace(self, name: str, ctx: Dict[str, Any],
                       trace: Trace) -> None:
        """Store one recorded (ctx, trace) pair in the endpoint's bounded
        ring and tell the attached re-miner (if any) new evidence exists —
        its cadence counter decides whether a re-mine attempt runs now."""
        with self._lock:
            ring = self._traces.get(name)
            if ring is None:
                ring = self._traces[name] = TraceRing(self.trace_capacity)
            ring.append(dict(ctx), trace)
        rm = self._reminer
        if rm is not None:
            rm.on_trace(name)

    def observe(self, name: str,
                capture: Callable[..., Dict[str, Any]]) -> Callable:
        """Decorator: every invocation of the wrapped function is recorded
        as a trace under ``name`` (serial execution; see ``wrap(...,
        auto_graph=True)`` for the record→mine→speculate pipeline)."""

        def deco(fn: Callable) -> Callable:
            @functools.wraps(fn)
            def wrapper(*args, **kwargs):
                ctx = capture(*args, **kwargs)
                return self.record(name, ctx, fn, *args, **kwargs)

            wrapper.__foreactor_observed__ = name  # type: ignore[attr-defined]
            return wrapper

        return deco

    def traces(self, name: str) -> List[Tuple[Dict[str, Any], Trace]]:
        with self._lock:
            ring = self._traces.get(name)
            return ring.snapshot() if ring is not None else []

    def trace_stats(self) -> Dict[str, Dict[str, int]]:
        """Per-endpoint trace-ring occupancy and drop counts
        (:meth:`repro.core.trace.TraceRing.stats`): sustained sampling is
        memory-bounded by design, and nonzero ``dropped`` means traces are
        arriving faster than the re-mine cadence consumes them."""
        with self._lock:
            return {name: ring.stats() for name, ring in self._traces.items()}

    def drop_traces(self, name: str) -> None:
        """Release every recorded trace under ``name`` (the re-miner calls
        this after a hot-swap or rollback: evidence of the old pattern must
        not contaminate the next mining attempt)."""
        with self._lock:
            self._traces.pop(name, None)

    def mine(self, name: str, register: bool = True, holdout: bool = True):
        """Mine the traces recorded under ``name`` into a validated
        ``ForeactionGraph`` and (by default) register it under the same
        name.  Raises ``UnminableTrace``/``UnsoundGraph`` on refusal.

        On successful registration the recorded traces are released — the
        raw I/O buffers they hold (every pread result) must not stay
        resident for the Foreactor's lifetime once the graph exists.
        """
        from repro.analysis.mine import mine_and_validate  # lazy: no cycle

        pairs = self.traces(name)
        if not pairs:
            raise ValueError(f"no traces recorded under {name!r}")
        ctxs = [c for (c, _t) in pairs]
        trs = [t for (_c, t) in pairs]
        mined = mine_and_validate(trs, ctxs, name=name, holdout=holdout)
        if register:
            with self._lock:
                self._graph_builders[name] = mined.builder()
                self._graphs.pop(name, None)  # rebuild on next activation
                self._traces.pop(name, None)
        return mined

    def shutdown(self) -> None:
        with self._lock:
            backends, self._backends = self._backends, []
        for b in backends:
            b.shutdown()


class _PassthroughForeactor(Foreactor):
    """A disabled Foreactor: wrap() runs the function unmodified (baseline)."""

    def activate(self, graph_name, ctx, depth=None, **kw):  # type: ignore[override]
        sess = SpecSession(self.graph(graph_name), ctx, SyncBackend(self.device),
                           self.device, depth=0, strict=False)
        # depth=0 sync-backend session == original serial execution
        _session_stack().append(sess)
        return sess


def make_foreactor(enabled: bool = True, **kw) -> Foreactor:
    return Foreactor(**kw) if enabled else _PassthroughForeactor(**kw)


# ---------------------------------------------------------------------------
# The interception layer: application code calls these.  With an active
# session whose device matches, calls are routed through the pre-issuing
# engine; otherwise they hit the device directly.
# ---------------------------------------------------------------------------
class io:
    @staticmethod
    def _route(device: Device, sc: Sys, args: tuple) -> Any:
        sess = current_session()
        if sess is not None and sess.device is device:
            return sess.intercept(sc, args)
        return _direct(device, sc, args)

    @staticmethod
    def _route_async(device: Device, sc: Sys, args: tuple) -> IOFuture:
        """Futures-style routing: with an active matching session the call
        becomes a harvestable ledger entry whose ``result()`` is a late
        demand point; otherwise (no session, or a TraceRecorder that must
        observe serial order) it executes now and the future is returned
        already resolved — so code written against the async API behaves
        identically with speculation off."""
        sess = current_session()
        if sess is not None and sess.device is device:
            ia = getattr(sess, "intercept_async", None)
            if ia is not None:
                return ia(sc, args)
            return IOFuture.resolved(sess.intercept(sc, args))
        return IOFuture.resolved(_direct(device, sc, args))

    @staticmethod
    def open(device: Device, path: str, flags: str = "r") -> int:
        return io._route(device, Sys.OPEN, (path, flags))

    @staticmethod
    def close(device: Device, fd: int) -> None:
        return io._route(device, Sys.CLOSE, (fd,))

    @staticmethod
    def pread(device: Device, fd: int, size: int, offset: int) -> bytes:
        return io._route(device, Sys.PREAD, (fd, size, offset))

    @staticmethod
    def pwrite(device: Device, fd: int, data: bytes, offset: int) -> int:
        return io._route(device, Sys.PWRITE, (fd, data, offset))

    @staticmethod
    def fstatat(device: Device, path: str):
        return io._route(device, Sys.FSTATAT, (path,))

    @staticmethod
    def getdents(device: Device, path: str) -> list:
        return io._route(device, Sys.GETDENTS, (path,))

    @staticmethod
    def fsync(device: Device, fd: int) -> None:
        return io._route(device, Sys.FSYNC, (fd,))

    # -- futures-style variants (late demand; see engine.intercept_async) --
    @staticmethod
    def pread_async(device: Device, fd: int, size: int,
                    offset: int) -> IOFuture:
        return io._route_async(device, Sys.PREAD, (fd, size, offset))

    @staticmethod
    def pwrite_async(device: Device, fd: int, data: bytes,
                     offset: int) -> IOFuture:
        """Futures-style write: inside a session running a staging
        transaction the pwrite becomes a harvestable (speculable, undoable)
        ledger entry and ``result()`` is the late demand point returning the
        byte count; without staging — or with no session — it degrades to
        the blocking write, already resolved."""
        return io._route_async(device, Sys.PWRITE, (fd, data, offset))

    @staticmethod
    def open_async(device: Device, path: str, flags: str = "r") -> IOFuture:
        return io._route_async(device, Sys.OPEN, (path, flags))

    @staticmethod
    def fstatat_async(device: Device, path: str) -> IOFuture:
        return io._route_async(device, Sys.FSTATAT, (path,))

    @staticmethod
    def rename(device: Device, src: str, dst: str) -> None:
        return io._route(device, Sys.RENAME, (src, dst))

    @staticmethod
    def unlink(device: Device, path: str) -> None:
        return io._route(device, Sys.UNLINK, (path,))


def _direct(device: Device, sc: Sys, args: tuple) -> Any:
    from .syscalls import execute

    device.charge_crossing()
    return execute(device, sc, args)
