"""The foreaction graph abstraction (paper §3.2).

A foreaction graph is a directed graph describing the exact order of I/O
system calls an application function could issue, plus the computation
needed to produce their argument values:

* **Syscall nodes** — typed (pread/pwrite/...), *pure* iff read-only.
  Annotations map to two plugin stubs (paper §5.1):
  ``ComputeArgs(ctx, epochs) -> None | (args, link)`` (None = not ready) and
  ``SaveResult(ctx, epochs, rc)`` (evaluated exactly once per node x epoch).
* **Branching nodes** — ``Choice(ctx, epochs) -> None | child-index``.
* **Start/End** — implicit: the builder's start edge, and ``Edge(dst=None)``.
* **Edges** — may be *weak* (function may exit early across them) and, for a
  branching node's child, *looping-back* (carries an epoch counter).

Epochs: one counter per looping-back edge; the tuple of all counters
identifies a dynamic node instance, and is passed to every stub so that
array-like variables can be indexed per-iteration.

Cross-references: docs/ARCHITECTURE.md ("Foreaction graphs") maps this module
to paper §3.2; *weak edge*, *epoch vector* and *link flag* are defined in
docs/GLOSSARY.md.
"""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import Any, Callable, Dict, List, Optional, Tuple

from .syscalls import Effect, Sys, effect_of, is_pure

# Stub signatures (paper §5.1):
#   ComputeArgsFn(ctx, epochs) -> None (not ready) | (args_tuple, link_flag)
#   SaveResultFn(ctx, epochs, rc) -> None
#   ChoiceFn(ctx, epochs) -> None (not ready) | int (child index)
ComputeArgsFn = Callable[[Dict[str, Any], Tuple[int, ...]], Optional[Tuple[Tuple[Any, ...], bool]]]
SaveResultFn = Callable[[Dict[str, Any], Tuple[int, ...], Any], None]
ChoiceFn = Callable[[Dict[str, Any], Tuple[int, ...]], Optional[int]]


class FromNode:
    """Plugin-side deferred argument: 'the result of syscall node ``name``
    at the same epoch'.  The engine rewrites it to a concrete
    :class:`repro.core.syscalls.FromRequest` when pre-issuing; a node whose
    args reference a not-yet-issued producer is simply not ready yet."""

    __slots__ = ("name",)

    def __init__(self, name: str):
        self.name = name

    def __repr__(self) -> str:
        return f"FromNode({self.name!r})"


@dataclass
class Edge:
    dst: Optional["Node"]  # None == the End node
    weak: bool = False
    loop_id: Optional[int] = None  # set iff this is a looping-back edge


class Node:
    name: str


@dataclass
class SyscallNode(Node):
    name: str
    sc: Sys
    compute_args: ComputeArgsFn
    save_result: Optional[SaveResultFn] = None
    out: Optional[Edge] = None

    def pure_with(self, args: Tuple[Any, ...]) -> bool:
        return is_pure(self.sc, args)

    def effect_with(self, args: Tuple[Any, ...]) -> Effect:
        """Three-way effect class of this node with concrete arguments:
        pure / undoable / barrier (the §3.3 pre-issue gate, extended —
        see ``repro.core.syscalls.effect_of``)."""
        return effect_of(self.sc, args)


@dataclass
class BranchNode(Node):
    name: str
    choose: ChoiceFn
    children: List[Edge] = field(default_factory=list)


@dataclass
class ForeactionGraph:
    name: str
    start: Edge
    syscall_nodes: Dict[str, SyscallNode]
    branch_nodes: Dict[str, BranchNode]
    num_loops: int

    def validate(self) -> None:
        """Structural invariants from paper §3.2."""
        for n in self.syscall_nodes.values():
            if n.out is None:
                raise ValueError(f"syscall node {n.name!r} has no outgoing edge")
            if n.out.loop_id is not None:
                raise ValueError(
                    f"loop-back edges may only leave branching nodes, not {n.name!r}"
                )
        for b in self.branch_nodes.values():
            if not b.children:
                raise ValueError(f"branching node {b.name!r} has no outgoing edge")
        seen_loops = set()
        for b in self.branch_nodes.values():
            for e in b.children:
                if e.loop_id is not None:
                    if e.loop_id in seen_loops:
                        raise ValueError("duplicate loop id")
                    seen_loops.add(e.loop_id)
        if len(seen_loops) != self.num_loops:
            raise ValueError("loop count mismatch")
        # reachability: every node reachable from start (ignoring loop edges)
        reach = set()
        stack = [self.start.dst]
        while stack:
            n = stack.pop()
            if n is None or n.name in reach:
                continue
            reach.add(n.name)
            if isinstance(n, SyscallNode):
                stack.append(n.out.dst if n.out else None)
            else:
                stack.extend(e.dst for e in n.children)
        all_names = set(self.syscall_nodes) | set(self.branch_nodes)
        unreachable = all_names - reach
        if unreachable:
            raise ValueError(f"unreachable nodes: {sorted(unreachable)}")

    def initial_epochs(self) -> Tuple[int, ...]:
        return (0,) * self.num_loops

    def to_dot(self) -> str:
        """GraphViz rendering (docs/debugging)."""
        lines = [f'digraph "{self.name}" {{', "  rankdir=LR;", "  S [shape=circle];", "  E [shape=doublecircle];"]

        def ename(e: Edge) -> str:
            return "E" if e.dst is None else f'"{e.dst.name}"'

        def attrs(e: Edge) -> str:
            a = []
            if e.weak:
                a.append("style=dashed")
            if e.loop_id is not None:
                a.append(f'label="loop {e.loop_id}"')
            return f" [{', '.join(a)}]" if a else ""

        lines.append(f"  S -> {ename(self.start)}{attrs(self.start)};")
        for n in self.syscall_nodes.values():
            lines.append(f'  "{n.name}" [shape=box, label="{n.name}\\n{n.sc.value}"];')
            if n.out:
                lines.append(f'  "{n.name}" -> {ename(n.out)}{attrs(n.out)};')
        for b in self.branch_nodes.values():
            lines.append(f'  "{b.name}" [shape=diamond];')
            for e in b.children:
                lines.append(f'  "{b.name}" -> {ename(e)}{attrs(e)};')
        lines.append("}")
        return "\n".join(lines)


class GraphBuilder:
    """Programmatic graph composition — the plugin-code API (paper §5.1).

    Method names mirror libforeactor: ``AddSyscallNode``,
    ``AddBranchingNode``, ``SyscallSetNext``, ``BranchAppendChild``.
    """

    END = None  # sentinel for the End node in SetNext/AppendChild

    def __init__(self, name: str):
        self.name = name
        self._sys: Dict[str, SyscallNode] = {}
        self._br: Dict[str, BranchNode] = {}
        self._start: Optional[str] = None
        self._start_weak = False
        self._loops = 0
        # wiring is recorded by name and resolved at Build() so plugins can
        # forward-reference nodes (loops make that unavoidable).
        self._next: Dict[str, Tuple[Optional[str], bool]] = {}
        self._children: Dict[str, List[Tuple[Optional[str], bool, Optional[int]]]] = {}

    # -- node creation ----------------------------------------------------
    def AddSyscallNode(
        self,
        name: str,
        sc: Sys,
        compute_args: ComputeArgsFn,
        save_result: Optional[SaveResultFn] = None,
    ) -> str:
        if name in self._sys or name in self._br:
            raise ValueError(f"duplicate node name {name!r}")
        self._sys[name] = SyscallNode(name=name, sc=sc, compute_args=compute_args, save_result=save_result)
        if self._start is None:
            self._start = name
        return name

    def AddBranchingNode(self, name: str, choose: ChoiceFn) -> str:
        if name in self._sys or name in self._br:
            raise ValueError(f"duplicate node name {name!r}")
        self._br[name] = BranchNode(name=name, choose=choose)
        if self._start is None:
            self._start = name
        return name

    # -- wiring -----------------------------------------------------------
    def _resolve(self, name: Optional[str]) -> Optional[Node]:
        if name is None:
            return None
        if name in self._sys:
            return self._sys[name]
        if name in self._br:
            return self._br[name]
        raise KeyError(name)

    def SetStart(self, name: str, weak: bool = False) -> None:
        self._start = name
        self._start_weak = weak

    def SyscallSetNext(self, src: str, dst: Optional[str], weak: bool = False) -> None:
        if src not in self._sys:
            raise KeyError(src)
        self._next[src] = (dst, weak)

    def BranchAppendChild(self, src: str, dst: Optional[str], weak: bool = False, loopback: bool = False) -> int:
        if src not in self._br:
            raise KeyError(src)
        loop_id = None
        if loopback:
            loop_id = self._loops
            self._loops += 1
        self._children.setdefault(src, []).append((dst, weak, loop_id))
        return len(self._children[src]) - 1

    def Build(self) -> ForeactionGraph:
        if self._start is None:
            raise ValueError("empty graph")
        for src, (dst, weak) in self._next.items():
            self._sys[src].out = Edge(dst=self._resolve(dst), weak=weak)
        for src, kids in self._children.items():
            self._br[src].children = [
                Edge(dst=self._resolve(dst), weak=weak, loop_id=loop_id)
                for (dst, weak, loop_id) in kids
            ]
        g = ForeactionGraph(
            name=self.name,
            start=Edge(dst=self._resolve(self._start), weak=self._start_weak),
            syscall_nodes=dict(self._sys),
            branch_nodes=dict(self._br),
            num_loops=self._loops,
        )
        g.validate()
        return g
