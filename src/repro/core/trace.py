"""Syscall trace recording — the *observe* half of observe-then-speculate.

The paper's adoption cost is hand-writing foreaction graphs.  This module
removes it for a large class of functions: run the function once (or a few
times) under a :class:`TraceRecorder`, and the recorded syscall trace —
ordered events with full argument and result values — becomes the input to
the graph miner (:mod:`repro.analysis.mine`), which folds traces into a
directly-follows graph and emits a ready-to-register ``ForeactionGraph``.

A ``TraceRecorder`` rides the same per-thread activation stack that
``SpecSession`` uses: while it is on top, every ``io.*`` call on that thread
executes *directly* against the device (no speculation, no extra crossings
beyond the serial baseline) and is appended to the trace.  Recording cost is
one tuple append per call — near-zero next to any real device latency.

Cross-references: docs/AUTHORING.md ("Mining a graph from traces") is the
end-to-end guide; *trace* and *directly-follows graph* are defined in
docs/GLOSSARY.md.
"""

from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Any, Dict, Iterator, List, Optional, Tuple

from .device import Device
from .syscalls import Sys, execute


@dataclass
class TraceEvent:
    """One recorded syscall: position, descriptor, arguments, and outcome.

    ``result`` holds the live return value (bytes for pread, fd int for
    open, stat object, entry list) — the miner needs the real values for
    argument-provenance detection, so no summarization happens here.
    """

    seq: int
    sc: Sys
    args: Tuple[Any, ...]
    result: Any = None
    error: Optional[BaseException] = None
    t_seconds: float = 0.0  # service time of this call (serial, by design)

    def kind(self) -> Sys:
        return self.sc


class Trace:
    """An ordered sequence of :class:`TraceEvent` from one invocation."""

    def __init__(self, name: str = "trace"):
        self.name = name
        self.events: List[TraceEvent] = []
        self.wall_seconds: float = 0.0

    def append(self, ev: TraceEvent) -> None:
        self.events.append(ev)

    def __len__(self) -> int:
        return len(self.events)

    def __iter__(self) -> Iterator[TraceEvent]:
        return iter(self.events)

    def __getitem__(self, i: int) -> TraceEvent:
        return self.events[i]

    def kinds(self) -> List[Sys]:
        """The syscall-kind string of the trace — the miner's alphabet."""
        return [ev.sc for ev in self.events]

    def to_jsonable(self, max_bytes: int = 32) -> List[Dict[str, Any]]:
        """A JSON-friendly rendering for docs/debugging (large byte values
        are abbreviated; objects fall back to repr)."""

        def _render(v: Any) -> Any:
            if isinstance(v, bytes):
                if len(v) > max_bytes:
                    return f"<{len(v)} bytes>"
                return v.hex()
            if isinstance(v, (int, float, str, bool)) or v is None:
                return v
            if isinstance(v, (list, tuple)):
                return [_render(x) for x in v]
            return repr(v)

        return [
            {
                "seq": ev.seq,
                "sc": ev.sc.value,
                "args": _render(ev.args),
                "result": _render(ev.result),
                "error": repr(ev.error) if ev.error is not None else None,
            }
            for ev in self.events
        ]


class TraceRecorder:
    """Records every intercepted I/O call while active on a thread.

    Duck-types the slice of the ``SpecSession`` surface the interception
    layer (:class:`repro.core.api.io`) touches: ``.device`` for routing and
    ``.intercept(sc, args)`` for the call itself.  Execution is strictly
    serial and direct — observation must not perturb the behaviour being
    recorded (the mined graph describes the *serial* order, exactly what the
    pre-issuing engine needs).
    """

    def __init__(self, device: Device, name: str = "trace"):
        self.device = device
        self.trace = Trace(name)
        self._t0 = time.perf_counter()

    def intercept(self, sc: Sys, args: Tuple[Any, ...]) -> Any:
        t0 = time.perf_counter()
        ev = TraceEvent(seq=len(self.trace.events), sc=sc, args=args)
        self.trace.append(ev)
        try:
            self.device.charge_crossing()
            result = execute(self.device, sc, args)
        except BaseException as e:
            ev.error = e
            ev.t_seconds = time.perf_counter() - t0
            raise
        ev.result = result
        ev.t_seconds = time.perf_counter() - t0
        return result

    def finish(self) -> Trace:
        self.trace.wall_seconds = time.perf_counter() - self._t0
        return self.trace
