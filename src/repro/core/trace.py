"""Syscall trace recording — the *observe* half of observe-then-speculate.

The paper's adoption cost is hand-writing foreaction graphs.  This module
removes it for a large class of functions: run the function once (or a few
times) under a :class:`TraceRecorder`, and the recorded syscall trace —
ordered events with full argument and result values — becomes the input to
the graph miner (:mod:`repro.analysis.mine`), which folds traces into a
directly-follows graph and emits a ready-to-register ``ForeactionGraph``.

A ``TraceRecorder`` rides the same per-thread activation stack that
``SpecSession`` uses: while it is on top, every ``io.*`` call on that thread
executes *directly* against the device (no speculation, no extra crossings
beyond the serial baseline) and is appended to the trace.  Recording cost is
one tuple append per call — near-zero next to any real device latency.

Cross-references: docs/AUTHORING.md ("Mining a graph from traces") is the
end-to-end guide; *trace* and *directly-follows graph* are defined in
docs/GLOSSARY.md.
"""

from __future__ import annotations

import time
from collections import deque
from dataclasses import dataclass, field
from typing import Any, Callable, Deque, Dict, Iterator, List, Optional, Tuple

from .device import Device
from .syscalls import Sys, execute


@dataclass
class TraceEvent:
    """One recorded syscall: position, descriptor, arguments, and outcome.

    ``result`` holds the live return value (bytes for pread, fd int for
    open, stat object, entry list) — the miner needs the real values for
    argument-provenance detection, so no summarization happens here.
    """

    seq: int
    sc: Sys
    args: Tuple[Any, ...]
    result: Any = None
    error: Optional[BaseException] = None
    t_seconds: float = 0.0  # service time of this call (serial, by design)

    def kind(self) -> Sys:
        return self.sc


class Trace:
    """An ordered sequence of :class:`TraceEvent` from one invocation."""

    def __init__(self, name: str = "trace"):
        self.name = name
        self.events: List[TraceEvent] = []
        self.wall_seconds: float = 0.0

    def append(self, ev: TraceEvent) -> None:
        self.events.append(ev)

    def __len__(self) -> int:
        return len(self.events)

    def __iter__(self) -> Iterator[TraceEvent]:
        return iter(self.events)

    def __getitem__(self, i: int) -> TraceEvent:
        return self.events[i]

    def kinds(self) -> List[Sys]:
        """The syscall-kind string of the trace — the miner's alphabet."""
        return [ev.sc for ev in self.events]

    def to_jsonable(self, max_bytes: int = 32) -> List[Dict[str, Any]]:
        """A JSON-friendly rendering for docs/debugging (large byte values
        are abbreviated; objects fall back to repr)."""

        def _render(v: Any) -> Any:
            if isinstance(v, bytes):
                if len(v) > max_bytes:
                    return f"<{len(v)} bytes>"
                return v.hex()
            if isinstance(v, (int, float, str, bool)) or v is None:
                return v
            if isinstance(v, (list, tuple)):
                return [_render(x) for x in v]
            return repr(v)

        return [
            {
                "seq": ev.seq,
                "sc": ev.sc.value,
                "args": _render(ev.args),
                "result": _render(ev.result),
                "error": repr(ev.error) if ev.error is not None else None,
            }
            for ev in self.events
        ]


class TraceRing:
    """Bounded per-endpoint store of sampled ``(ctx, trace)`` pairs.

    Every trace pins the raw result of each recorded I/O (the miner needs
    the live values for provenance detection), so an unbounded trace list
    under sustained sampling grows by one buffer set per sampled request —
    the original ``Foreactor._traces`` list did exactly that when
    ``observe`` ran long.  The ring keeps the *newest* ``capacity`` pairs
    (the ones that describe the current live pattern, which is what online
    re-mining wants) and counts what it evicted, so ``trace_stats`` can
    report drop pressure instead of hiding it.
    """

    def __init__(self, capacity: int = 64):
        if capacity < 1:
            raise ValueError(f"trace ring capacity must be >= 1, got {capacity}")
        self.capacity = capacity
        self._items: Deque[Tuple[Dict[str, Any], "Trace"]] = deque(
            maxlen=capacity)
        #: total pairs ever appended (survivors + dropped)
        self.recorded = 0
        #: pairs evicted to make room — nonzero means sampling outpaces
        #: re-mining cadence (docs/TUNING.md, "Sample rate vs re-mine
        #: cadence")
        self.dropped = 0

    def append(self, ctx: Dict[str, Any], trace: "Trace") -> None:
        if len(self._items) == self.capacity:
            self.dropped += 1
        self._items.append((ctx, trace))
        self.recorded += 1

    def snapshot(self) -> List[Tuple[Dict[str, Any], "Trace"]]:
        return list(self._items)

    def clear(self) -> None:
        self._items.clear()

    def __len__(self) -> int:
        return len(self._items)

    def stats(self) -> Dict[str, int]:
        return {
            "capacity": self.capacity,
            "resident": len(self._items),
            "recorded": self.recorded,
            "dropped": self.dropped,
        }


class TraceRecorder:
    """Records every intercepted I/O call while active on a thread.

    Duck-types the slice of the ``SpecSession`` surface the interception
    layer (:class:`repro.core.api.io`) touches: ``.device`` for routing and
    ``.intercept(sc, args)`` for the call itself.  Execution is strictly
    serial and direct — observation must not perturb the behaviour being
    recorded (the mined graph describes the *serial* order, exactly what the
    pre-issuing engine needs).
    """

    def __init__(self, device: Device, name: str = "trace"):
        self.device = device
        self.trace = Trace(name)
        self._t0 = time.perf_counter()

    def intercept(self, sc: Sys, args: Tuple[Any, ...]) -> Any:
        t0 = time.perf_counter()
        ev = TraceEvent(seq=len(self.trace.events), sc=sc, args=args)
        self.trace.append(ev)
        try:
            self.device.charge_crossing()
            result = execute(self.device, sc, args)
        except BaseException as e:
            ev.error = e
            ev.t_seconds = time.perf_counter() - t0
            raise
        ev.result = result
        ev.t_seconds = time.perf_counter() - t0
        return result

    def finish(self) -> Trace:
        self.trace.wall_seconds = time.perf_counter() - self._t0
        return self.trace


class RecordingSession:
    """A *sampled* activation: records the live syscall pattern instead of
    speculating on it — the trace sampler half of online re-mining.

    ``Foreactor.activate`` returns one of these for the 1-in-N activations
    an attached :class:`repro.analysis.remine.ReMiner` elects to sample.
    It duck-types the slice of the ``SpecSession`` surface that
    ``Foreactor.deactivate``, ``Foreactor.wrap`` and the interception layer
    touch (``device``, ``intercept``, ``mark_failed``, ``finish`` returning
    a ``SessionStats``), executes strictly serially like a
    :class:`TraceRecorder` (observation must not perturb the pattern being
    observed), and on clean finish delivers its ``(ctx, trace)`` pair to
    the per-endpoint :class:`TraceRing` via the ``sink`` callback.  A
    failed activation delivers nothing — the miner only learns from clean
    runs.  Unsampled activations never touch this class, so the steady-
    state cost of having a re-miner attached is one counter increment per
    activation.
    """

    #: lets Foreactor.deactivate tell a sampling activation from a real one
    is_recording = True

    def __init__(self, device: Device, name: str, ctx: Dict[str, Any],
                 sink: Optional[Callable[[str, Dict[str, Any], Trace],
                                         None]] = None):
        from .engine import SessionStats  # engine does not import trace

        self.device = device
        self.graph_name = name
        self.graph_version = 0
        self.ctx = dict(ctx)
        self.backend = None  # no speculation: nothing to lease or shut down
        self.stats = SessionStats()
        self._recorder = TraceRecorder(device, name=name)
        self._sink = sink
        self._failed = False
        self._finished = False

    def intercept(self, sc: Sys, args: Tuple[Any, ...]) -> Any:
        self.stats.intercepted += 1
        self.stats.served_sync += 1
        return self._recorder.intercept(sc, args)

    def mark_failed(self) -> None:
        self._failed = True

    def finish(self):
        if not self._finished:
            self._finished = True
            trace = self._recorder.finish()
            if not self._failed and self._sink is not None:
                self._sink(self.graph_name, self.ctx, trace)
        return self.stats
