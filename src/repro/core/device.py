"""Storage device layer.

Foreactor's syscall nodes ultimately hit a storage device. On the paper's
testbed that is a Toshiba NVMe SSD behind ext4; in this framework the same
role is played by a ``Device`` object so that

* ``OSDevice`` issues the real host syscalls (os.pread/os.pwrite/...), and
* ``SimulatedDevice`` wraps any device with the paper's Fig.-1 cost model:
  every operation occupies one of ``channels`` internal units for
  ``base_latency + bytes * per_byte`` seconds.  This makes the storage-I/O-
  parallelism effect (throughput scaling with queue depth until channels
  saturate) deterministic and measurable inside a CI container whose page
  cache would otherwise hide it.

A *boundary crossing* models the user/kernel transition cost: io_uring-style
backends pay one crossing per submitted batch, thread-pool/sync backends pay
one per request (paper §2.3, Table 1).

``ShardedDevice`` composes N independent sub-devices under one namespace
(``shard3:/path`` addresses sub-device 3) so that pre-issued batches can fan
out across devices and aggregate bandwidth approaches ``sum(BW_i)``; it pairs
with :class:`repro.core.backends.MultiQueueBackend`, which keeps one queue
pair per sub-device.

Cross-references: docs/ARCHITECTURE.md ("Device layer", "Sharded multi-device
substrate") maps this module to paper §2.1/Fig. 1; terms like *queue-pair
crossing* are defined in docs/GLOSSARY.md.
"""

from __future__ import annotations

import os
import re
import threading
import time
import zlib
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence, Tuple


@dataclass
class DeviceStats:
    """Operation counters, used by benchmarks and tests."""

    ops: int = 0
    read_bytes: int = 0
    write_bytes: int = 0
    crossings: int = 0
    inflight: int = 0
    max_inflight: int = 0
    _lock: threading.Lock = field(default_factory=threading.Lock, repr=False)

    def op_begin(self) -> None:
        with self._lock:
            self.ops += 1
            self.inflight += 1
            self.max_inflight = max(self.max_inflight, self.inflight)

    def op_end(self, read_bytes: int = 0, write_bytes: int = 0) -> None:
        with self._lock:
            self.inflight -= 1
            self.read_bytes += read_bytes
            self.write_bytes += write_bytes

    def crossing(self) -> None:
        with self._lock:
            self.crossings += 1

    def snapshot(self) -> dict:
        with self._lock:
            return {
                "ops": self.ops,
                "read_bytes": self.read_bytes,
                "write_bytes": self.write_bytes,
                "crossings": self.crossings,
                "max_inflight": self.max_inflight,
            }


class Device:
    """Abstract storage device: the sink for all syscall nodes."""

    stats: DeviceStats

    #: logical-block alignment (bytes) that direct-I/O transfers on this
    #: device must honor — offset, length and buffer address all multiples
    #: of it.  0 means the device takes any shape (buffered path); devices
    #: opened in direct mode report 512 or 4096.  The buffer pool
    #: (:meth:`repro.core.buffers.BufferPool.lease`) and the extent
    #: coalescer key their aligned leases off this value.
    alignment: int = 0

    def open(self, path: str, flags: str = "r") -> int:
        raise NotImplementedError

    def close(self, fd: int) -> None:
        raise NotImplementedError

    def pread(self, fd: int, size: int, offset: int) -> bytes:
        raise NotImplementedError

    def pwrite(self, fd: int, data: bytes, offset: int) -> int:
        raise NotImplementedError

    def pread_into(self, fd: int, buf, offset: int) -> int:
        """Read up to ``len(buf)`` bytes at ``offset`` *into* a
        caller-provided writable buffer (a registered-buffer lease from
        :class:`repro.core.buffers.BufferPool`); returns the byte count.

        The io_uring READ_FIXED analogue: the device fills registered
        memory instead of allocating a fresh result object per request.
        The default implementation falls back to :meth:`pread` + copy so
        every device works; devices with a reachable backing store
        override it to skip the intermediate allocation."""
        data = self.pread(fd, len(buf), offset)
        n = len(data)
        buf[:n] = data
        return n

    def fstatat(self, path: str) -> os.stat_result:
        raise NotImplementedError

    def getdents(self, path: str) -> List[str]:
        raise NotImplementedError

    def fsync(self, fd: int) -> None:
        raise NotImplementedError

    # -- staging support (repro.store.staging) ----------------------------
    # rename/unlink/truncate are not syscall nodes (graphs never speculate
    # them); they are the namespace operations the staging layer needs to
    # publish (rename staged -> final), undo (unlink a staged file), and
    # roll back an extending overwrite (truncate to the old end).
    def rename(self, src: str, dst: str) -> None:
        raise NotImplementedError

    def unlink(self, path: str) -> None:
        raise NotImplementedError

    def truncate(self, fd: int, size: int) -> None:
        raise NotImplementedError

    def supports_staging(self) -> bool:
        """True iff rename/unlink/truncate are implemented — the gate for
        undoable write speculation on this device."""
        return False

    # cost hook for the user/kernel boundary; real devices pay it implicitly.
    def charge_crossing(self) -> None:
        self.stats.crossing()

    def place(self, path: str, hint: int = 0) -> str:
        """Return the path at which a file striped with index ``hint`` should
        live.  Flat devices ignore the hint; :class:`ShardedDevice` maps it to
        a ``shard{k}:`` namespace so callers (checkpoint manager, data
        pipeline) spread their shard files across sub-devices without knowing
        the device topology."""
        return path


_FLAGS = {
    "r": os.O_RDONLY,
    "w": os.O_WRONLY | os.O_CREAT | os.O_TRUNC,
    "rw": os.O_RDWR | os.O_CREAT,
    "a": os.O_WRONLY | os.O_CREAT | os.O_APPEND,
}


class OSDevice(Device):
    """Direct host filesystem device (real syscalls).

    ``direct=True`` opens read-only data files with ``O_DIRECT`` — the
    *direct lane*: transfers DMA straight between the device and aligned
    user memory, skipping the page cache.  Support is probed per open (a
    filesystem that refuses — tmpfs, some overlayfs — raises ``EINVAL`` at
    open time), and refusal falls back to buffered I/O per fd, counted in
    ``direct_fallbacks``; nothing in CI hard-requires O_DIRECT to work.
    While direct mode is active, :attr:`alignment` reports the logical
    block size direct transfers must honor; unaligned reads on a direct fd
    transparently bounce through a page-aligned mmap buffer covering the
    aligned superset of the requested range."""

    def __init__(self, direct: bool = False) -> None:
        self.stats = DeviceStats()
        self.direct = direct
        self.alignment = 4096 if direct else 0
        self._direct_fds: set = set()
        self._fd_lock = threading.Lock()
        #: probe counters: opens that got O_DIRECT vs. refused-and-buffered
        self.direct_opens = 0
        self.direct_fallbacks = 0

    def open(self, path: str, flags: str = "r") -> int:
        self.stats.op_begin()
        try:
            if flags != "r":
                parent = os.path.dirname(path)
                if parent and not os.path.isdir(parent):
                    os.makedirs(parent, exist_ok=True)
            if self.direct and flags == "r" and hasattr(os, "O_DIRECT"):
                try:
                    fd = os.open(path, _FLAGS[flags] | os.O_DIRECT, 0o644)
                except OSError:
                    # this mount refuses O_DIRECT: buffered fallback, per fd
                    with self._fd_lock:
                        self.direct_fallbacks += 1
                else:
                    with self._fd_lock:
                        self._direct_fds.add(fd)
                        self.direct_opens += 1
                    return fd
            return os.open(path, _FLAGS[flags], 0o644)
        finally:
            self.stats.op_end()

    def close(self, fd: int) -> None:
        self.stats.op_begin()
        try:
            os.close(fd)
            with self._fd_lock:
                self._direct_fds.discard(fd)
        finally:
            self.stats.op_end()

    def _is_direct(self, fd: int) -> bool:
        with self._fd_lock:
            return fd in self._direct_fds

    def _direct_pread_raw(self, fd: int, size: int, offset: int) -> bytes:
        """Bounce read on an O_DIRECT fd: read the aligned superset
        [floor(offset), ceil(offset+size)) into a page-aligned mmap buffer,
        then slice the requested window (short reads at EOF included)."""
        import mmap

        a = self.alignment or 4096
        lo = (offset // a) * a
        hi = ((offset + size + a - 1) // a) * a
        bounce = mmap.mmap(-1, hi - lo)
        try:
            n = os.preadv(fd, [bounce], lo)
            start = offset - lo
            end = min(n, start + size)
            return bytes(bounce[start:end]) if end > start else b""
        finally:
            bounce.close()

    def pread(self, fd: int, size: int, offset: int) -> bytes:
        self.stats.op_begin()
        try:
            if self._is_direct(fd):
                return self._direct_pread_raw(fd, size, offset)
            data = os.pread(fd, size, offset)
            return data
        finally:
            self.stats.op_end(read_bytes=size)

    def pwrite(self, fd: int, data: bytes, offset: int) -> int:
        self.stats.op_begin()
        try:
            return os.pwrite(fd, data, offset)
        finally:
            self.stats.op_end(write_bytes=len(data))

    def pread_into(self, fd: int, buf, offset: int) -> int:
        self.stats.op_begin()
        try:
            if self._is_direct(fd):
                a = self.alignment or 4096
                if offset % a == 0 and len(buf) % a == 0:
                    try:
                        # aligned lease + aligned shape: true direct DMA
                        # into registered memory (READ_FIXED on the direct
                        # lane); EINVAL means the buffer address itself is
                        # unaligned — bounce below
                        return os.preadv(fd, [buf], offset)
                    except OSError:
                        pass
                data = self._direct_pread_raw(fd, len(buf), offset)
                n = len(data)
                buf[:n] = data
                return n
            # scatter-read straight into the registered buffer: the kernel
            # fills caller memory, no intermediate bytes object
            return os.preadv(fd, [buf], offset)
        finally:
            self.stats.op_end(read_bytes=len(buf))

    def fstatat(self, path: str) -> os.stat_result:
        self.stats.op_begin()
        try:
            return os.stat(path)
        finally:
            self.stats.op_end()

    def getdents(self, path: str) -> List[str]:
        self.stats.op_begin()
        try:
            return sorted(os.listdir(path))
        finally:
            self.stats.op_end()

    def fsync(self, fd: int) -> None:
        self.stats.op_begin()
        try:
            os.fsync(fd)
        finally:
            self.stats.op_end()

    def rename(self, src: str, dst: str) -> None:
        self.stats.op_begin()
        try:
            parent = os.path.dirname(dst)
            if parent and not os.path.isdir(parent):
                os.makedirs(parent, exist_ok=True)
            os.replace(src, dst)
        finally:
            self.stats.op_end()

    def unlink(self, path: str) -> None:
        self.stats.op_begin()
        try:
            try:
                os.unlink(path)
            except (IsADirectoryError, PermissionError):
                # empty-directory removal rides the same verb: the checkpoint
                # GC graph unlinks a step directory after emptying it, and a
                # non-empty directory still fails (OSError) as it should
                os.rmdir(path)
        finally:
            self.stats.op_end()

    def truncate(self, fd: int, size: int) -> None:
        self.stats.op_begin()
        try:
            os.ftruncate(fd, size)
        finally:
            self.stats.op_end()

    def supports_staging(self) -> bool:
        return True


@dataclass(frozen=True)
class DeviceProfile:
    """Latency/parallelism profile (paper Fig. 1 / §6 experimental setup).

    The default profile models the storage tier a TPU-pod *host* actually
    talks to — a remote/parallel blob store (ms-scale per-op latency, high
    aggregate parallelism).  Python's ``time.sleep`` granularity (~100 us)
    makes microsecond-scale NVMe emulation unmeasurable in-process, so the
    paper's 60 us-class NVMe profile is provided as :data:`NVME_PROFILE`
    for reference but benchmarks default to :data:`REMOTE_PROFILE`.  The
    *shape* of the effect (throughput scales with queue depth until the
    device's internal parallelism saturates) is identical — only the time
    constant changes.
    """

    channels: int = 16  # independent internal units (channels/dies/servers)
    base_latency: float = 2e-3  # per-op command+seek time (seconds)
    per_byte: float = 1.25e-9  # streaming cost per byte per channel (~800 MB/s)
    crossing_cost: float = 5e-6  # one user/kernel boundary crossing
    metadata_latency: float = 1.5e-3  # fstat/getdents/open service time
    #: page-cache *hit* service time: the syscall still happens and the
    #: kernel still memcpys out of the cache, so a hit charges a small fixed
    #: cost plus a per-byte memcpy term (~10 GB/s) — a 1 MB cached read is
    #: NOT free the way a 1 KB one nearly is.  Hits occupy no device channel.
    cache_hit_latency: float = 5e-6
    cache_hit_per_byte: float = 1e-10

    def raw_bandwidth_bytes(self) -> float:
        """Aggregate streaming ceiling (bytes/s) with every channel busy on
        infinitely large requests — the denominator for 'fraction of raw
        device bandwidth' in ``bench_bandwidth``."""
        if self.per_byte <= 0:
            return float("inf")
        return self.channels / self.per_byte


#: default: remote blob / parallel-FS tier of a training cluster
REMOTE_PROFILE = DeviceProfile()

#: the paper's Toshiba NVMe (~60 MB/s @ QD1/4 KB => ~66 us/op; ~1.2 GB/s peak).
#: Useful for unit tests of the cost model, too fine-grained to benchmark
#: under Python sleep granularity.
NVME_PROFILE = DeviceProfile(
    channels=16,
    base_latency=60e-6,
    per_byte=1.2e-9,
    crossing_cost=2.5e-6,
    metadata_latency=40e-6,
)


def _precise_sleep(dur: float) -> None:
    """``time.sleep`` has a ~1 ms floor inside CI containers, which would
    inflate microsecond-scale costs (boundary crossings, ~5 us) two hundred
    fold and drown the effect being modelled.  Spin for those; sleep for
    anything >= 100 us — spinning holds the GIL, so longer busy-waits would
    serialize the worker pools this device model exists to exercise."""
    if dur <= 0:
        return
    if dur >= 1e-4:
        time.sleep(dur)
        return
    end = time.perf_counter() + dur
    while time.perf_counter() < end:
        pass


class _PageCacheModel:
    """A tiny LRU model of the kernel page cache (paper §6.3 varies its
    capacity via cgroups).  Cache hits serve data without charging device
    latency — the syscall still happens, it is just fast."""

    def __init__(self, capacity_bytes: int, page: int = 4096):
        from collections import OrderedDict

        self.page = page
        self.capacity_pages = max(1, capacity_bytes // page)
        self._lru: "OrderedDict[tuple, bool]" = OrderedDict()
        self._lock = threading.Lock()
        self.hits = 0
        self.misses = 0

    def _pages(self, path: str, offset: int, size: int):
        first = offset // self.page
        last = (offset + max(size, 1) - 1) // self.page
        return [(path, i) for i in range(first, last + 1)]

    def access(self, path: str, offset: int, size: int, insert: bool = True) -> bool:
        """True iff fully cached; inserts pages (LRU evict) either way."""
        keys = self._pages(path, offset, size)
        with self._lock:
            hit = all(k in self._lru for k in keys)
            if insert:
                for k in keys:
                    if k in self._lru:
                        self._lru.move_to_end(k)
                    else:
                        self._lru[k] = True
                        if len(self._lru) > self.capacity_pages:
                            self._lru.popitem(last=False)
            if hit:
                self.hits += 1
            else:
                self.misses += 1
            return hit


class SimulatedDevice(Device):
    """Wraps an inner device with a K-channel latency model.

    Each operation holds one channel slot while it 'executes', so wall time
    improves with concurrency up to ``channels`` — the storage-I/O-parallelism
    effect the paper exploits.  The data itself is served by the inner device
    (correctness is real; only timing is synthetic).  ``cache_bytes`` > 0
    enables the page-cache model: cached preads skip the device charge but
    still pay the hit cost (``cache_hit_latency + size * cache_hit_per_byte``
    — the kernel's memcpy out of the cache scales with request size).

    ``direct=True`` is the simulated *direct lane*: preads bypass the
    page-cache model entirely (every read pays real device latency, exactly
    like O_DIRECT skipping the cache) and :attr:`alignment` reports a
    512-byte logical block so aligned leases and the extent coalescer
    engage.  The bandwidth-vs-request-size curve
    (``size / (base_latency + size * per_byte)``) is then fully exposed:
    1 KiB requests crawl at ~17 MB/s on the NVMe profile while 1 MiB
    super-reads stream at ~800 MB/s per channel.
    """

    def __init__(
        self,
        inner: Optional[Device] = None,
        profile: DeviceProfile = DeviceProfile(),
        cache_bytes: int = 0,
        direct: bool = False,
    ):
        self.inner = inner if inner is not None else OSDevice()
        self.profile = profile
        self.stats = DeviceStats()
        self._channels = threading.Semaphore(profile.channels)
        self.direct = direct
        self.alignment = 512 if direct else 0
        self.cache = (_PageCacheModel(cache_bytes)
                      if cache_bytes > 0 and not direct else None)
        self._fd_paths: Dict[int, str] = {}
        self._fd_lock = threading.Lock()

    def _service(self, nbytes: int, metadata: bool = False) -> None:
        p = self.profile
        dur = p.metadata_latency if metadata else p.base_latency + nbytes * p.per_byte
        with self._channels:
            _precise_sleep(dur)

    def _hit(self, nbytes: int) -> None:
        """Page-cache hit service: no device channel occupied, but the
        kernel's copy-out is charged per byte — the curve pinned by
        tests/test_device_model.py."""
        p = self.profile
        _precise_sleep(p.cache_hit_latency + nbytes * p.cache_hit_per_byte)

    def charge_crossing(self) -> None:
        self.stats.crossing()
        _precise_sleep(self.profile.crossing_cost)

    def _path_of(self, fd: int) -> str:
        with self._fd_lock:
            return self._fd_paths.get(fd, f"<fd:{fd}>")

    def open(self, path: str, flags: str = "r") -> int:
        self.stats.op_begin()
        try:
            self._service(0, metadata=True)
            fd = self.inner.open(path, flags)
            with self._fd_lock:
                self._fd_paths[fd] = path
            return fd
        finally:
            self.stats.op_end()

    def close(self, fd: int) -> None:
        self.stats.op_begin()
        try:
            with self._fd_lock:
                self._fd_paths.pop(fd, None)
            return self.inner.close(fd)
        finally:
            self.stats.op_end()

    def pread(self, fd: int, size: int, offset: int) -> bytes:
        self.stats.op_begin()
        try:
            cached = self.cache is not None and self.cache.access(
                self._path_of(fd), offset, size
            )
            if cached:
                self._hit(size)
            else:
                self._service(size)
            return self.inner.pread(fd, size, offset)
        finally:
            self.stats.op_end(read_bytes=size)

    def pread_into(self, fd: int, buf, offset: int) -> int:
        self.stats.op_begin()
        try:
            cached = self.cache is not None and self.cache.access(
                self._path_of(fd), offset, len(buf)
            )
            if cached:
                self._hit(len(buf))
            else:
                self._service(len(buf))
            return self.inner.pread_into(fd, buf, offset)
        finally:
            self.stats.op_end(read_bytes=len(buf))

    def pwrite(self, fd: int, data: bytes, offset: int) -> int:
        self.stats.op_begin()
        try:
            if self.cache is not None:
                self.cache.access(self._path_of(fd), offset, len(data))
            self._service(len(data))
            return self.inner.pwrite(fd, data, offset)
        finally:
            self.stats.op_end(write_bytes=len(data))

    def fstatat(self, path: str) -> os.stat_result:
        self.stats.op_begin()
        try:
            self._service(0, metadata=True)
            return self.inner.fstatat(path)
        finally:
            self.stats.op_end()

    def getdents(self, path: str) -> List[str]:
        self.stats.op_begin()
        try:
            self._service(0, metadata=True)
            return self.inner.getdents(path)
        finally:
            self.stats.op_end()

    def fsync(self, fd: int) -> None:
        self.stats.op_begin()
        try:
            self._service(0, metadata=True)
            return self.inner.fsync(fd)
        finally:
            self.stats.op_end()

    def rename(self, src: str, dst: str) -> None:
        self.stats.op_begin()
        try:
            self._service(0, metadata=True)
            self.inner.rename(src, dst)
            with self._fd_lock:
                for fd, p in self._fd_paths.items():
                    if p == src:
                        self._fd_paths[fd] = dst
        finally:
            self.stats.op_end()

    def unlink(self, path: str) -> None:
        self.stats.op_begin()
        try:
            self._service(0, metadata=True)
            self.inner.unlink(path)
        finally:
            self.stats.op_end()

    def truncate(self, fd: int, size: int) -> None:
        self.stats.op_begin()
        try:
            self._service(0, metadata=True)
            self.inner.truncate(fd, size)
        finally:
            self.stats.op_end()

    def supports_staging(self) -> bool:
        return self.inner.supports_staging()


_SHARD_PREFIX = re.compile(r"^shard(\d+):(.*)$")


class ShardedDevice(Device):
    """N independent sub-devices behind one Device interface.

    Namespace: ``shard{k}:{path}`` pins a path to sub-device ``k``; a bare
    path is routed by a stable hash of the path string, so unprefixed files
    (manifests, commit markers) read back from the same sub-device they were
    written to.  ``getdents`` on a bare path returns the union across all
    sub-devices — a striped directory reads like one directory.

    File descriptors returned by :meth:`open` are *virtual*: sub-devices may
    reuse fd numbers between themselves, so the sharded device allocates its
    own fd space and keeps the (shard, real fd) mapping.  That mapping is also
    how :class:`repro.core.backends.MultiQueueBackend` routes an fd-addressed
    ``IORequest`` to the queue pair owning its target device.

    Stats on this object are the *aggregate* view (e.g. ``max_inflight``
    across all sub-devices — the number to watch when checking that a batch
    really fanned out); per-device counters live on ``devices[i].stats``.
    """

    def __init__(self, devices: Sequence[Device]):
        if not devices:
            raise ValueError("ShardedDevice needs at least one sub-device")
        self.devices: List[Device] = list(devices)
        self.stats = DeviceStats()
        self._vfds: Dict[int, Tuple[int, int]] = {}  # vfd -> (shard, real fd)
        self._next_vfd = 1000
        self._lock = threading.Lock()

    @classmethod
    def simulated(
        cls,
        n: int,
        profile: DeviceProfile = REMOTE_PROFILE,
        cache_bytes: int = 0,
        inner_factory=None,
        direct: bool = False,
    ) -> "ShardedDevice":
        """N :class:`SimulatedDevice` shards, each with its own latency model
        and (by default) its own in-memory backing store."""
        factory = inner_factory if inner_factory is not None else MemDevice
        return cls([
            SimulatedDevice(factory(), profile, cache_bytes=cache_bytes,
                            direct=direct)
            for _ in range(n)
        ])

    # -- namespace ---------------------------------------------------------
    @property
    def num_devices(self) -> int:
        return len(self.devices)

    @property
    def alignment(self) -> int:
        """Strictest sub-device alignment: a lease aligned for the pickiest
        shard is a valid direct-I/O target on every shard."""
        return max(getattr(d, "alignment", 0) for d in self.devices)

    def place(self, path: str, hint: int = 0) -> str:
        return f"shard{hint % len(self.devices)}:{path}"

    def resolve(self, path: str) -> Tuple[int, str]:
        """(shard index, sub-device path) for any path in the namespace."""
        m = _SHARD_PREFIX.match(path)
        if m:
            idx = int(m.group(1))
            if idx >= len(self.devices):
                raise FileNotFoundError(f"no shard {idx}: {path!r}")
            return idx, m.group(2)
        return zlib.crc32(path.encode()) % len(self.devices), path

    def shard_of_fd(self, fd: int) -> int:
        with self._lock:
            if fd not in self._vfds:
                raise OSError(f"bad virtual fd {fd}")
            return self._vfds[fd][0]

    def route(self, sc, args) -> int:
        """Shard index an IORequest targets — the MultiQueueBackend's
        queue-selection function.  Path-addressed syscalls resolve the
        namespace; fd-addressed ones look up the virtual fd."""
        from .syscalls import Sys  # local import: avoid a module cycle

        if sc in (Sys.OPEN, Sys.FSTATAT, Sys.GETDENTS, Sys.UNLINK, Sys.RENAME):
            return self.resolve(args[0])[0]
        return self.shard_of_fd(args[0])

    def _lookup(self, fd: int) -> Tuple[Device, int]:
        with self._lock:
            if fd not in self._vfds:
                raise OSError(f"bad virtual fd {fd}")
            shard, rfd = self._vfds[fd]
        return self.devices[shard], rfd

    # -- Device interface --------------------------------------------------
    def open(self, path: str, flags: str = "r") -> int:
        shard, sub = self.resolve(path)
        self.stats.op_begin()
        try:
            rfd = self.devices[shard].open(sub, flags)
        finally:
            self.stats.op_end()
        with self._lock:
            vfd = self._next_vfd
            self._next_vfd += 1
            self._vfds[vfd] = (shard, rfd)
        return vfd

    def close(self, fd: int) -> None:
        dev, rfd = self._lookup(fd)
        self.stats.op_begin()
        try:
            dev.close(rfd)
        finally:
            self.stats.op_end()
        with self._lock:
            self._vfds.pop(fd, None)

    def pread(self, fd: int, size: int, offset: int) -> bytes:
        dev, rfd = self._lookup(fd)
        self.stats.op_begin()
        try:
            return dev.pread(rfd, size, offset)
        finally:
            self.stats.op_end(read_bytes=size)

    def pread_into(self, fd: int, buf, offset: int) -> int:
        dev, rfd = self._lookup(fd)
        self.stats.op_begin()
        try:
            return dev.pread_into(rfd, buf, offset)
        finally:
            self.stats.op_end(read_bytes=len(buf))

    def pwrite(self, fd: int, data: bytes, offset: int) -> int:
        dev, rfd = self._lookup(fd)
        self.stats.op_begin()
        try:
            return dev.pwrite(rfd, data, offset)
        finally:
            self.stats.op_end(write_bytes=len(data))

    def fstatat(self, path: str) -> os.stat_result:
        shard, sub = self.resolve(path)
        self.stats.op_begin()
        try:
            return self.devices[shard].fstatat(sub)
        finally:
            self.stats.op_end()

    def getdents(self, path: str) -> List[str]:
        m = _SHARD_PREFIX.match(path)
        self.stats.op_begin()
        try:
            if m:
                shard, sub = self.resolve(path)
                return self.devices[shard].getdents(sub)
            # bare path: union across all sub-devices (striped directory)
            names: set = set()
            errors = 0
            for dev in self.devices:
                try:
                    names.update(dev.getdents(path))
                except FileNotFoundError:
                    errors += 1
            if errors == len(self.devices):
                raise FileNotFoundError(path)
            return sorted(names)
        finally:
            self.stats.op_end()

    def fsync(self, fd: int) -> None:
        dev, rfd = self._lookup(fd)
        self.stats.op_begin()
        try:
            dev.fsync(rfd)
        finally:
            self.stats.op_end()

    def rename(self, src: str, dst: str) -> None:
        """Same-shard renames are the atomic fast path (the staging layer
        derives staged names so src and dst co-locate); cross-shard renames
        degrade to copy + unlink, which is not atomic — callers that need
        publish atomicity must keep staged and final names on one shard."""
        s_shard, s_sub = self.resolve(src)
        d_shard, d_sub = self.resolve(dst)
        self.stats.op_begin()
        try:
            if s_shard == d_shard:
                self.devices[s_shard].rename(s_sub, d_sub)
                return
            size = self.devices[s_shard].fstatat(s_sub).st_size
            sfd = self.devices[s_shard].open(s_sub, "r")
            dfd = self.devices[d_shard].open(d_sub, "w")
            try:
                data = self.devices[s_shard].pread(sfd, size, 0)
                self.devices[d_shard].pwrite(dfd, data, 0)
            finally:
                self.devices[s_shard].close(sfd)
                self.devices[d_shard].close(dfd)
            self.devices[s_shard].unlink(s_sub)
        finally:
            self.stats.op_end()

    def unlink(self, path: str) -> None:
        """Pinned (``shard{k}:``) paths unlink exactly there.  A bare path
        first tries its hash route, then falls back to every sub-device —
        the union view, mirroring ``getdents``: pinned creations (shard
        files, staged extents) live where ``place()`` put them, not where
        the hash of their bare name points, and callers sweeping a
        directory by its ``getdents`` listing address them bare."""
        pinned = _SHARD_PREFIX.match(path) is not None
        shard, sub = self.resolve(path)
        self.stats.op_begin()
        try:
            try:
                self.devices[shard].unlink(sub)
                return
            except FileNotFoundError:
                if pinned:
                    raise
            found = False
            for i, d in enumerate(self.devices):
                if i == shard:
                    continue
                try:
                    d.unlink(sub)
                    found = True
                except FileNotFoundError:
                    pass
            if not found:
                raise FileNotFoundError(path)
        finally:
            self.stats.op_end()

    def truncate(self, fd: int, size: int) -> None:
        dev, rfd = self._lookup(fd)
        self.stats.op_begin()
        try:
            dev.truncate(rfd, size)
        finally:
            self.stats.op_end()

    def supports_staging(self) -> bool:
        return all(d.supports_staging() for d in self.devices)

    def charge_crossing(self) -> None:
        # A single-queue caller crosses into "the kernel" once; attribute the
        # cost to sub-device 0 (representative) and count it at the aggregate.
        self.stats.crossing()
        self.devices[0].charge_crossing()

    def sub_snapshots(self) -> List[dict]:
        return [d.stats.snapshot() for d in self.devices]


class MemDevice(Device):
    """In-memory device for fast unit tests (no host FS, no latency)."""

    def __init__(self) -> None:
        self.stats = DeviceStats()
        self._files: Dict[str, bytearray] = {}
        self._fds: Dict[int, str] = {}
        self._next_fd = 100
        self._lock = threading.Lock()

    def open(self, path: str, flags: str = "r") -> int:
        self.stats.op_begin()
        try:
            with self._lock:
                if flags in ("w",):
                    self._files[path] = bytearray()
                elif path not in self._files:
                    if flags == "r":
                        raise FileNotFoundError(path)
                    self._files[path] = bytearray()
                fd = self._next_fd
                self._next_fd += 1
                self._fds[fd] = path
                return fd
        finally:
            self.stats.op_end()

    def close(self, fd: int) -> None:
        self.stats.op_begin()
        try:
            with self._lock:
                self._fds.pop(fd, None)
        finally:
            self.stats.op_end()

    def pread(self, fd: int, size: int, offset: int) -> bytes:
        self.stats.op_begin()
        try:
            with self._lock:
                buf = self._files[self._fds[fd]]
                return bytes(buf[offset : offset + size])
        finally:
            self.stats.op_end(read_bytes=size)

    def pread_into(self, fd: int, buf, offset: int) -> int:
        self.stats.op_begin()
        try:
            with self._lock:
                backing = self._files[self._fds[fd]]
                end = min(len(backing), offset + len(buf))
                n = max(0, end - offset)
                if n:
                    # one copy backing -> registered buffer, no intermediate
                    # bytearray slice + bytes() pair like pread() pays
                    mv = memoryview(backing)
                    try:
                        buf[:n] = mv[offset:end]
                    finally:
                        mv.release()
                return n
        finally:
            self.stats.op_end(read_bytes=len(buf))

    def pwrite(self, fd: int, data: bytes, offset: int) -> int:
        self.stats.op_begin()
        try:
            with self._lock:
                buf = self._files[self._fds[fd]]
                if len(buf) < offset + len(data):
                    buf.extend(b"\x00" * (offset + len(data) - len(buf)))
                buf[offset : offset + len(data)] = data
                return len(data)
        finally:
            self.stats.op_end(write_bytes=len(data))

    def fstatat(self, path: str):
        self.stats.op_begin()
        try:
            with self._lock:
                if path not in self._files:
                    raise FileNotFoundError(path)
                size = len(self._files[path])

            class _Stat:
                st_size = size
                st_mode = 0o100644

            return _Stat()
        finally:
            self.stats.op_end()

    def getdents(self, path: str) -> List[str]:
        self.stats.op_begin()
        try:
            prefix = path.rstrip("/") + "/"
            with self._lock:
                names = {p[len(prefix) :].split("/")[0] for p in self._files if p.startswith(prefix)}
            return sorted(names)
        finally:
            self.stats.op_end()

    def fsync(self, fd: int) -> None:
        self.stats.op_begin()
        self.stats.op_end()

    def rename(self, src: str, dst: str) -> None:
        self.stats.op_begin()
        try:
            with self._lock:
                if src not in self._files:
                    raise FileNotFoundError(src)
                self._files[dst] = self._files.pop(src)
                # open fds follow the file to its new name (inode semantics)
                for fd, p in self._fds.items():
                    if p == src:
                        self._fds[fd] = dst
        finally:
            self.stats.op_end()

    def unlink(self, path: str) -> None:
        self.stats.op_begin()
        try:
            with self._lock:
                if path not in self._files:
                    raise FileNotFoundError(path)
                del self._files[path]
        finally:
            self.stats.op_end()

    def truncate(self, fd: int, size: int) -> None:
        self.stats.op_begin()
        try:
            with self._lock:
                buf = self._files[self._fds[fd]]
                del buf[size:]
        finally:
            self.stats.op_end()

    def supports_staging(self) -> bool:
        return True
