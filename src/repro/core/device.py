"""Storage device layer.

Foreactor's syscall nodes ultimately hit a storage device. On the paper's
testbed that is a Toshiba NVMe SSD behind ext4; in this framework the same
role is played by a ``Device`` object so that

* ``OSDevice`` issues the real host syscalls (os.pread/os.pwrite/...), and
* ``SimulatedDevice`` wraps any device with the paper's Fig.-1 cost model:
  every operation occupies one of ``channels`` internal units for
  ``base_latency + bytes * per_byte`` seconds.  This makes the storage-I/O-
  parallelism effect (throughput scaling with queue depth until channels
  saturate) deterministic and measurable inside a CI container whose page
  cache would otherwise hide it.

A *boundary crossing* models the user/kernel transition cost: io_uring-style
backends pay one crossing per submitted batch, thread-pool/sync backends pay
one per request (paper §2.3, Table 1).
"""

from __future__ import annotations

import os
import threading
import time
from dataclasses import dataclass, field
from typing import Dict, List, Optional


@dataclass
class DeviceStats:
    """Operation counters, used by benchmarks and tests."""

    ops: int = 0
    read_bytes: int = 0
    write_bytes: int = 0
    crossings: int = 0
    inflight: int = 0
    max_inflight: int = 0
    _lock: threading.Lock = field(default_factory=threading.Lock, repr=False)

    def op_begin(self) -> None:
        with self._lock:
            self.ops += 1
            self.inflight += 1
            self.max_inflight = max(self.max_inflight, self.inflight)

    def op_end(self, read_bytes: int = 0, write_bytes: int = 0) -> None:
        with self._lock:
            self.inflight -= 1
            self.read_bytes += read_bytes
            self.write_bytes += write_bytes

    def crossing(self) -> None:
        with self._lock:
            self.crossings += 1

    def snapshot(self) -> dict:
        with self._lock:
            return {
                "ops": self.ops,
                "read_bytes": self.read_bytes,
                "write_bytes": self.write_bytes,
                "crossings": self.crossings,
                "max_inflight": self.max_inflight,
            }


class Device:
    """Abstract storage device: the sink for all syscall nodes."""

    stats: DeviceStats

    def open(self, path: str, flags: str = "r") -> int:
        raise NotImplementedError

    def close(self, fd: int) -> None:
        raise NotImplementedError

    def pread(self, fd: int, size: int, offset: int) -> bytes:
        raise NotImplementedError

    def pwrite(self, fd: int, data: bytes, offset: int) -> int:
        raise NotImplementedError

    def fstatat(self, path: str) -> os.stat_result:
        raise NotImplementedError

    def getdents(self, path: str) -> List[str]:
        raise NotImplementedError

    def fsync(self, fd: int) -> None:
        raise NotImplementedError

    # cost hook for the user/kernel boundary; real devices pay it implicitly.
    def charge_crossing(self) -> None:
        self.stats.crossing()


_FLAGS = {
    "r": os.O_RDONLY,
    "w": os.O_WRONLY | os.O_CREAT | os.O_TRUNC,
    "rw": os.O_RDWR | os.O_CREAT,
    "a": os.O_WRONLY | os.O_CREAT | os.O_APPEND,
}


class OSDevice(Device):
    """Direct host filesystem device (real syscalls)."""

    def __init__(self) -> None:
        self.stats = DeviceStats()

    def open(self, path: str, flags: str = "r") -> int:
        self.stats.op_begin()
        try:
            if flags != "r":
                parent = os.path.dirname(path)
                if parent and not os.path.isdir(parent):
                    os.makedirs(parent, exist_ok=True)
            return os.open(path, _FLAGS[flags], 0o644)
        finally:
            self.stats.op_end()

    def close(self, fd: int) -> None:
        self.stats.op_begin()
        try:
            os.close(fd)
        finally:
            self.stats.op_end()

    def pread(self, fd: int, size: int, offset: int) -> bytes:
        self.stats.op_begin()
        try:
            data = os.pread(fd, size, offset)
            return data
        finally:
            self.stats.op_end(read_bytes=size)

    def pwrite(self, fd: int, data: bytes, offset: int) -> int:
        self.stats.op_begin()
        try:
            return os.pwrite(fd, data, offset)
        finally:
            self.stats.op_end(write_bytes=len(data))

    def fstatat(self, path: str) -> os.stat_result:
        self.stats.op_begin()
        try:
            return os.stat(path)
        finally:
            self.stats.op_end()

    def getdents(self, path: str) -> List[str]:
        self.stats.op_begin()
        try:
            return sorted(os.listdir(path))
        finally:
            self.stats.op_end()

    def fsync(self, fd: int) -> None:
        self.stats.op_begin()
        try:
            os.fsync(fd)
        finally:
            self.stats.op_end()


@dataclass(frozen=True)
class DeviceProfile:
    """Latency/parallelism profile (paper Fig. 1 / §6 experimental setup).

    The default profile models the storage tier a TPU-pod *host* actually
    talks to — a remote/parallel blob store (ms-scale per-op latency, high
    aggregate parallelism).  Python's ``time.sleep`` granularity (~100 us)
    makes microsecond-scale NVMe emulation unmeasurable in-process, so the
    paper's 60 us-class NVMe profile is provided as :data:`NVME_PROFILE`
    for reference but benchmarks default to :data:`REMOTE_PROFILE`.  The
    *shape* of the effect (throughput scales with queue depth until the
    device's internal parallelism saturates) is identical — only the time
    constant changes.
    """

    channels: int = 16  # independent internal units (channels/dies/servers)
    base_latency: float = 2e-3  # per-op command+seek time (seconds)
    per_byte: float = 1.25e-9  # streaming cost per byte per channel (~800 MB/s)
    crossing_cost: float = 5e-6  # one user/kernel boundary crossing
    metadata_latency: float = 1.5e-3  # fstat/getdents/open service time


#: default: remote blob / parallel-FS tier of a training cluster
REMOTE_PROFILE = DeviceProfile()

#: the paper's Toshiba NVMe (~60 MB/s @ QD1/4 KB => ~66 us/op; ~1.2 GB/s peak).
#: Useful for unit tests of the cost model, too fine-grained to benchmark
#: under Python sleep granularity.
NVME_PROFILE = DeviceProfile(
    channels=16,
    base_latency=60e-6,
    per_byte=1.2e-9,
    crossing_cost=2.5e-6,
    metadata_latency=40e-6,
)


class _PageCacheModel:
    """A tiny LRU model of the kernel page cache (paper §6.3 varies its
    capacity via cgroups).  Cache hits serve data without charging device
    latency — the syscall still happens, it is just fast."""

    def __init__(self, capacity_bytes: int, page: int = 4096):
        from collections import OrderedDict

        self.page = page
        self.capacity_pages = max(1, capacity_bytes // page)
        self._lru: "OrderedDict[tuple, bool]" = OrderedDict()
        self._lock = threading.Lock()
        self.hits = 0
        self.misses = 0

    def _pages(self, path: str, offset: int, size: int):
        first = offset // self.page
        last = (offset + max(size, 1) - 1) // self.page
        return [(path, i) for i in range(first, last + 1)]

    def access(self, path: str, offset: int, size: int, insert: bool = True) -> bool:
        """True iff fully cached; inserts pages (LRU evict) either way."""
        keys = self._pages(path, offset, size)
        with self._lock:
            hit = all(k in self._lru for k in keys)
            if insert:
                for k in keys:
                    if k in self._lru:
                        self._lru.move_to_end(k)
                    else:
                        self._lru[k] = True
                        if len(self._lru) > self.capacity_pages:
                            self._lru.popitem(last=False)
            if hit:
                self.hits += 1
            else:
                self.misses += 1
            return hit


class SimulatedDevice(Device):
    """Wraps an inner device with a K-channel latency model.

    Each operation holds one channel slot while it 'executes', so wall time
    improves with concurrency up to ``channels`` — the storage-I/O-parallelism
    effect the paper exploits.  The data itself is served by the inner device
    (correctness is real; only timing is synthetic).  ``cache_bytes`` > 0
    enables the page-cache model: cached preads skip the latency charge.
    """

    def __init__(
        self,
        inner: Optional[Device] = None,
        profile: DeviceProfile = DeviceProfile(),
        cache_bytes: int = 0,
    ):
        self.inner = inner if inner is not None else OSDevice()
        self.profile = profile
        self.stats = DeviceStats()
        self._channels = threading.Semaphore(profile.channels)
        self.cache = _PageCacheModel(cache_bytes) if cache_bytes > 0 else None
        self._fd_paths: Dict[int, str] = {}
        self._fd_lock = threading.Lock()

    def _service(self, nbytes: int, metadata: bool = False) -> None:
        p = self.profile
        dur = p.metadata_latency if metadata else p.base_latency + nbytes * p.per_byte
        with self._channels:
            time.sleep(dur)

    def charge_crossing(self) -> None:
        self.stats.crossing()
        time.sleep(self.profile.crossing_cost)

    def _path_of(self, fd: int) -> str:
        with self._fd_lock:
            return self._fd_paths.get(fd, f"<fd:{fd}>")

    def open(self, path: str, flags: str = "r") -> int:
        self.stats.op_begin()
        try:
            self._service(0, metadata=True)
            fd = self.inner.open(path, flags)
            with self._fd_lock:
                self._fd_paths[fd] = path
            return fd
        finally:
            self.stats.op_end()

    def close(self, fd: int) -> None:
        self.stats.op_begin()
        try:
            with self._fd_lock:
                self._fd_paths.pop(fd, None)
            return self.inner.close(fd)
        finally:
            self.stats.op_end()

    def pread(self, fd: int, size: int, offset: int) -> bytes:
        self.stats.op_begin()
        try:
            cached = self.cache is not None and self.cache.access(
                self._path_of(fd), offset, size
            )
            if not cached:
                self._service(size)
            return self.inner.pread(fd, size, offset)
        finally:
            self.stats.op_end(read_bytes=size)

    def pwrite(self, fd: int, data: bytes, offset: int) -> int:
        self.stats.op_begin()
        try:
            if self.cache is not None:
                self.cache.access(self._path_of(fd), offset, len(data))
            self._service(len(data))
            return self.inner.pwrite(fd, data, offset)
        finally:
            self.stats.op_end(write_bytes=len(data))

    def fstatat(self, path: str) -> os.stat_result:
        self.stats.op_begin()
        try:
            self._service(0, metadata=True)
            return self.inner.fstatat(path)
        finally:
            self.stats.op_end()

    def getdents(self, path: str) -> List[str]:
        self.stats.op_begin()
        try:
            self._service(0, metadata=True)
            return self.inner.getdents(path)
        finally:
            self.stats.op_end()

    def fsync(self, fd: int) -> None:
        self.stats.op_begin()
        try:
            self._service(0, metadata=True)
            return self.inner.fsync(fd)
        finally:
            self.stats.op_end()


class MemDevice(Device):
    """In-memory device for fast unit tests (no host FS, no latency)."""

    def __init__(self) -> None:
        self.stats = DeviceStats()
        self._files: Dict[str, bytearray] = {}
        self._fds: Dict[int, str] = {}
        self._next_fd = 100
        self._lock = threading.Lock()

    def open(self, path: str, flags: str = "r") -> int:
        self.stats.op_begin()
        try:
            with self._lock:
                if flags in ("w",):
                    self._files[path] = bytearray()
                elif path not in self._files:
                    if flags == "r":
                        raise FileNotFoundError(path)
                    self._files[path] = bytearray()
                fd = self._next_fd
                self._next_fd += 1
                self._fds[fd] = path
                return fd
        finally:
            self.stats.op_end()

    def close(self, fd: int) -> None:
        self.stats.op_begin()
        try:
            with self._lock:
                self._fds.pop(fd, None)
        finally:
            self.stats.op_end()

    def pread(self, fd: int, size: int, offset: int) -> bytes:
        self.stats.op_begin()
        try:
            with self._lock:
                buf = self._files[self._fds[fd]]
                return bytes(buf[offset : offset + size])
        finally:
            self.stats.op_end(read_bytes=size)

    def pwrite(self, fd: int, data: bytes, offset: int) -> int:
        self.stats.op_begin()
        try:
            with self._lock:
                buf = self._files[self._fds[fd]]
                if len(buf) < offset + len(data):
                    buf.extend(b"\x00" * (offset + len(data) - len(buf)))
                buf[offset : offset + len(data)] = data
                return len(data)
        finally:
            self.stats.op_end(write_bytes=len(data))

    def fstatat(self, path: str):
        self.stats.op_begin()
        try:
            with self._lock:
                if path not in self._files:
                    raise FileNotFoundError(path)
                size = len(self._files[path])

            class _Stat:
                st_size = size
                st_mode = 0o100644

            return _Stat()
        finally:
            self.stats.op_end()

    def getdents(self, path: str) -> List[str]:
        self.stats.op_begin()
        try:
            prefix = path.rstrip("/") + "/"
            with self._lock:
                names = {p[len(prefix) :].split("/")[0] for p in self._files if p.startswith(prefix)}
            return sorted(names)
        finally:
            self.stats.op_end()

    def fsync(self, fd: int) -> None:
        self.stats.op_begin()
        self.stats.op_end()
