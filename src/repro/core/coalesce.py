"""Plan-level extent coalescing: fuse adjacent PREADs into super-reads.

The foreaction graph gives the engine *exact* future syscall arguments
(paper §2's premise), which is precisely what is needed to safely make
requests bigger, not just earlier: a run of PREAD records on the same fd
whose ``(offset, size)`` pairs are statically known and exactly adjacent
(``offset_{i+1} == offset_i + size_i`` — the loop-provenance shapes the
miner emits for checkpoint restore and sequential data pipelines) is fused
into ONE MB-scale *super-read* backed by a single aligned buffer lease.
On devices whose cost is ``base_latency + bytes * per_byte`` (every real
disk, and :class:`repro.core.device.SimulatedDevice`), N tiny reads pay N
base latencies while one fused read pays one — the difference between
~17 MB/s and ~800 MB/s per channel at 1 KiB vs 1 MiB request size on the
NVMe profile.

Mechanics (the carrier/satellite model):

* The fuse pass (:meth:`ExtentCoalescer.fuse`) runs inside the I/O plane's
  dispatch, on the link-chain partition of a submitted batch.  Only runs of
  >= 2 *single-request* chains fuse (link chains carry ordering the fusion
  would destroy); the first member becomes the **carrier** — it stays in
  the dispatched chain list with a ``runner`` that executes the super-read
  — and the rest become **satellites**, removed from dispatch but kept in
  every ledger, so cancellation and the session's accounting invariant
  (``pre_issued == served_async + cancelled + wasted_completions``) see
  them exactly as before.
* On a full read the carrier's runner *scatters*: each satellite is claimed
  and finished with a zero-copy :class:`repro.core.buffers.LeaseView` into
  the shared slab (its sub-range of the super-read); the carrier itself
  returns the parent lease trimmed to its own extent.
* A short read (EOF inside the fused range) or an exception **decomposes**:
  every member is re-executed as its own per-extent pread, so EOF
  boundaries and per-extent errors (EIO mid-run) surface byte-identically
  to the unfused/sync execution — each satellite terminates exactly once,
  with its own result or its own error.
* A carrier cancelled before execution (early exit, pressure eviction)
  leaves satellites PREPARED; ``cancel_remaining`` reaches them through the
  ledgers, and a *demanded* satellite is decomposed on the spot by
  :meth:`FusedRead.on_demand` (the backend ``wait`` hook).

Cross-references: docs/ARCHITECTURE.md ("Direct I/O & extent coalescing");
*super-read*, *scatter view*, *alignment class* and *direct lane* are
defined in docs/GLOSSARY.md.
"""

from __future__ import annotations

import threading
from typing import Any, Dict, List, Optional

from .buffers import BufferPool
from .syscalls import IORequest, Sys

#: fused reads cap at the pool's top size class (4 MiB): bigger would run
#: unleased and allocate per request, forfeiting the registered-buffer win
MAX_FUSED_BYTES = 1 << 22

#: a run shorter than this is left alone (nothing to fuse)
MIN_RUN = 2


class CoalesceStats:
    """Counters for the fuse pass and the fused-read lifecycle."""

    def __init__(self) -> None:
        self._lock = threading.Lock()
        self.super_reads = 0  # FusedRead objects created
        self.extents_fused = 0  # member requests covered (incl. carriers)
        self.bytes_fused = 0  # sum of fused byte ranges
        self.scatters = 0  # full reads scattered to views
        self.decompositions = 0  # short-read / error fallbacks
        self.demand_decompositions = 0  # satellite demanded after carrier died
        self.unleased_fallbacks = 0  # pool declined; plain-buffer super-read

    def bump(self, field: str, n: int = 1) -> None:
        with self._lock:
            setattr(self, field, getattr(self, field) + n)

    def snapshot(self) -> Dict[str, int]:
        with self._lock:
            return {
                "super_reads": self.super_reads,
                "extents_fused": self.extents_fused,
                "bytes_fused": self.bytes_fused,
                "scatters": self.scatters,
                "decompositions": self.decompositions,
                "demand_decompositions": self.demand_decompositions,
                "unleased_fallbacks": self.unleased_fallbacks,
            }


def _pool_alignment(device) -> int:
    """Map a device's logical block size onto the pool's alignment classes."""
    a = getattr(device, "alignment", 0) or 0
    if a <= 0:
        return 0
    return 512 if a <= 512 else 4096


class FusedRead:
    """One super-read covering ``members`` (adjacent PREADs on one fd).

    ``members[0]`` is the carrier: it keeps its place in the dispatched
    chains and carries :meth:`run` as its staged runner; the rest are
    satellites, finished by the carrier's execution (scatter or decompose)
    or — if the carrier is cancelled first — by ``cancel_remaining`` /
    :meth:`on_demand`.
    """

    __slots__ = ("members", "fd", "offset", "total", "pool", "stats",
                 "_rel")

    def __init__(self, members: List[IORequest], pool: Optional[BufferPool],
                 stats: CoalesceStats):
        self.members = members
        self.fd = members[0].args[0]
        self.offset = members[0].args[2]
        self.total = sum(r.args[1] for r in members)
        self.pool = pool
        self.stats = stats
        # per-member (start offset relative to the fused range, size)
        rel, off = [], 0
        for r in members:
            rel.append((off, r.args[1]))
            off += r.args[1]
        self._rel = rel
        for r in members:
            r.fused = self
        carrier = members[0]
        carrier.runner = self.run

    # -- execution (the carrier's staged runner) ---------------------------
    def run(self, device) -> Any:
        """Execute the super-read; returns the carrier's own result (the
        worker finishes the carrier with it, like any staged runner)."""
        lease = None
        if self.pool is not None:
            lease = self.pool.lease(self.total,
                                    tenant=self.members[0].tenant,
                                    alignment=_pool_alignment(device))
        try:
            if lease is not None:
                n = device.pread_into(self.fd, lease.mv[: self.total],
                                      self.offset)
            else:
                self.stats.bump("unleased_fallbacks")
                data = device.pread(self.fd, self.total, self.offset)
                n = len(data)
        except BaseException:
            if lease is not None:
                lease.release()
            return self._decompose(device)
        if n < self.total:
            # EOF inside the fused range: per-extent re-reads reproduce the
            # exact short-read boundary each member would have seen unfused
            if lease is not None:
                lease.release()
            return self._decompose(device)
        self.stats.bump("scatters")
        if lease is not None:
            return self._scatter_lease(lease)
        return self._scatter_bytes(data)

    def _scatter_lease(self, lease) -> Any:
        carrier = self.members[0]
        for i in range(1, len(self.members)):
            sat = self.members[i]
            if not sat.claim():  # cancel won the race; it is terminal
                continue
            start, size = self._rel[i]
            view = lease.view(start, size)
            sat.lease = view
            sat.finish(view)
        # the carrier keeps the parent lease, trimmed to its own extent;
        # take_result materializes bytes[0:size0] and drops the parent ref
        # (the slab recycles once every scatter view releases too)
        lease.filled(self._rel[0][1])
        carrier.lease = lease
        return lease

    def _scatter_bytes(self, data: bytes) -> bytes:
        for i in range(1, len(self.members)):
            sat = self.members[i]
            if not sat.claim():
                continue
            start, size = self._rel[i]
            sat.finish(data[start: start + size])
        size0 = self._rel[0][1]
        return data[:size0]

    def _decompose(self, device) -> Any:
        """Per-extent fallback: every member runs as its own pread, so
        short reads and errors land on exactly the extent that owns them.
        The carrier's own outcome is returned/raised (the worker finishes
        it); each satellite is finished here, exactly once."""
        self.stats.bump("decompositions")
        carrier_result: Any = None
        carrier_error: Optional[BaseException] = None
        for i, req in enumerate(self.members):
            fd, size, off = req.args
            if i == 0:
                try:
                    carrier_result = device.pread(fd, size, off)
                except BaseException as e:  # noqa: BLE001 — re-raised below
                    carrier_error = e
                continue
            if not req.claim():
                continue
            try:
                req.finish(device.pread(fd, size, off))
            except BaseException as e:  # noqa: BLE001 — satellite's own error
                req.finish(error=e)
        if carrier_error is not None:
            raise carrier_error
        return carrier_result

    # -- demand hook (backend.wait) ----------------------------------------
    def on_demand(self, device, req: IORequest) -> None:
        """Called by the backend when the frontier demands ``req``.  For a
        satellite this waits out the carrier (it always reaches a terminal
        state once dispatched: a worker runs it or cancellation takes it)
        and, if the carrier died without scattering, serves the satellite's
        own extent inline — the demand-decomposition path."""
        if req.is_done() or req is self.members[0]:
            return
        self.members[0].wait_done()
        if req.is_done():
            return
        if not req.claim():
            return
        self.stats.bump("demand_decompositions")
        fd, size, off = req.args
        device.charge_crossing()
        try:
            req.finish(device.pread(fd, size, off))
        except BaseException as e:  # noqa: BLE001 — the extent's own error
            req.finish(error=e)


class ExtentCoalescer:
    """The fuse pass the I/O plane runs over each dispatched batch."""

    def __init__(self, pool: Optional[BufferPool],
                 max_bytes: int = MAX_FUSED_BYTES):
        self.pool = pool
        self.max_bytes = max_bytes
        self.stats = CoalesceStats()

    @staticmethod
    def _fusable(chain: List[IORequest]) -> bool:
        """Only bare single-request PREADs with fully static int args fuse;
        link chains, staged runners, already-leased or already-terminal
        entries pass through untouched."""
        if len(chain) != 1:
            return False
        r = chain[0]
        return (r.sc is Sys.PREAD and not r.link and r.runner is None
                and r.lease is None and r.fused is None and not r.is_done()
                and len(r.args) == 3
                and isinstance(r.args[0], int)
                and isinstance(r.args[1], int) and r.args[1] > 0
                and isinstance(r.args[2], int))

    def fuse(self, chains: List[List[IORequest]]) -> List[List[IORequest]]:
        """Rewrite a chain list, replacing each adjacent same-fd run with
        its carrier; satellites leave the dispatch set (their terminal
        state now comes from the carrier or from cancellation)."""
        out: List[List[IORequest]] = []
        run: List[IORequest] = []
        run_bytes = 0

        def flush() -> None:
            nonlocal run, run_bytes
            if len(run) >= MIN_RUN:
                fused = FusedRead(run, self.pool, self.stats)
                self.stats.bump("super_reads")
                self.stats.bump("extents_fused", len(run))
                self.stats.bump("bytes_fused", fused.total)
                out.append([run[0]])
            else:
                out.extend([r] for r in run)
            run, run_bytes = [], 0

        for chain in chains:
            if not self._fusable(chain):
                flush()
                out.append(chain)
                continue
            r = chain[0]
            fd, size, off = r.args
            if run:
                prev = run[-1]
                adjacent = (fd == prev.args[0]
                            and off == prev.args[2] + prev.args[1])
                if not adjacent or run_bytes + size > self.max_bytes:
                    flush()
            run.append(r)
            run_bytes += size
        flush()
        return out
