"""Granite-MoE 3B-a800m — 40 routed experts top-8, GQA kv=8
[hf:ibm-granite/granite-3.0-3b-a800m-base family; assignment dims]."""

from repro.models.config import ModelConfig, MoEConfig


def full() -> ModelConfig:
    return ModelConfig(
        name="granite-moe-3b-a800m",
        vocab_size=49155, d_model=1536, n_layers=32,
        n_heads=24, n_kv_heads=8, head_dim=64, d_ff=512,
        moe=MoEConfig(num_experts=40, top_k=8, d_expert=512,
                      capacity_factor=1.05, group_tokens=256),
        mlp_act="silu", rope_theta=10000.0,
        remat_policy="dots",
    )


def smoke() -> ModelConfig:
    return ModelConfig(
        name="granite-moe-smoke",
        vocab_size=512, d_model=128, n_layers=2,
        n_heads=4, n_kv_heads=2, head_dim=32, d_ff=128,
        moe=MoEConfig(num_experts=8, top_k=2, d_expert=64, capacity_factor=2.0, dropless=True),
        mlp_act="silu",
        param_dtype="float32", compute_dtype="float32",
        loss_chunk=64, remat=False,
    )
