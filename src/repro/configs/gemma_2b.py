"""Gemma 2B — GeGLU, head_dim 256, MQA (kv=1) [arXiv:2403.08295]."""

from repro.models.config import ModelConfig


def full() -> ModelConfig:
    return ModelConfig(
        name="gemma-2b",
        vocab_size=256000, d_model=2048, n_layers=18,
        n_heads=8, n_kv_heads=1, head_dim=256, d_ff=16384,
        mlp_act="gelu", rope_theta=10000.0,
        norm_unit_offset=True, scale_embed=True, tie_embeddings=True,
    )


def smoke() -> ModelConfig:
    return ModelConfig(
        name="gemma-2b-smoke",
        vocab_size=512, d_model=128, n_layers=2,
        n_heads=4, n_kv_heads=1, head_dim=32, d_ff=256,
        mlp_act="gelu", norm_unit_offset=True, scale_embed=True,
        tie_embeddings=True,
        param_dtype="float32", compute_dtype="float32",
        loss_chunk=64, remat=False,
    )
