"""Qwen2-VL 7B — M-RoPE, qkv bias, vision frontend stubbed to precomputed
patch embeddings [arXiv:2409.12191]."""

from repro.models.config import ModelConfig


def full() -> ModelConfig:
    return ModelConfig(
        name="qwen2-vl-7b",
        vocab_size=152064, d_model=3584, n_layers=28,
        n_heads=28, n_kv_heads=4, d_ff=18944,
        mlp_act="silu", rope_theta=1000000.0,
        rope_type="mrope", mrope_sections=(16, 24, 24),
        qkv_bias=True, visual_stub=True,
    )


def smoke() -> ModelConfig:
    return ModelConfig(
        name="qwen2-vl-smoke",
        vocab_size=512, d_model=128, n_layers=2,
        n_heads=4, n_kv_heads=2, d_ff=256,
        mlp_act="silu", rope_type="mrope", mrope_sections=(4, 6, 6),
        qkv_bias=True, visual_stub=True,
        param_dtype="float32", compute_dtype="float32",
        loss_chunk=64, remat=False,
    )
