"""Whisper-tiny — enc-dec audio transformer; conv frontend stubbed to
precomputed frame embeddings [arXiv:2212.04356]."""

from repro.models.config import EncDecConfig, ModelConfig


def full() -> ModelConfig:
    return ModelConfig(
        name="whisper-tiny",
        vocab_size=51865, d_model=384, n_layers=4,
        n_heads=6, n_kv_heads=6, d_ff=1536,
        mlp_act="gelu_mlp", norm="layernorm", qkv_bias=True,
        rope_type="none",
        enc_dec=EncDecConfig(n_enc_layers=4, n_audio_ctx=1500),
    )


def smoke() -> ModelConfig:
    return ModelConfig(
        name="whisper-smoke",
        vocab_size=512, d_model=64, n_layers=2,
        n_heads=4, n_kv_heads=4, d_ff=128,
        mlp_act="gelu_mlp", norm="layernorm", qkv_bias=True,
        rope_type="none",
        enc_dec=EncDecConfig(n_enc_layers=2, n_audio_ctx=64),
        param_dtype="float32", compute_dtype="float32",
        loss_chunk=32, remat=False,
    )
