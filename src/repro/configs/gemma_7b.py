"""Gemma 7B — GeGLU, head_dim 256, 16 MHA heads [arXiv:2403.08295]."""

from repro.models.config import ModelConfig


def full() -> ModelConfig:
    return ModelConfig(
        name="gemma-7b",
        vocab_size=256000, d_model=3072, n_layers=28,
        n_heads=16, n_kv_heads=16, head_dim=256, d_ff=24576,
        mlp_act="gelu", rope_theta=10000.0,
        norm_unit_offset=True, scale_embed=True, tie_embeddings=True,
    )


def smoke() -> ModelConfig:
    return ModelConfig(
        name="gemma-7b-smoke",
        vocab_size=512, d_model=96, n_layers=2,
        n_heads=4, n_kv_heads=4, head_dim=32, d_ff=192,
        mlp_act="gelu", norm_unit_offset=True, scale_embed=True,
        tie_embeddings=True,
        param_dtype="float32", compute_dtype="float32",
        loss_chunk=64, remat=False,
    )
