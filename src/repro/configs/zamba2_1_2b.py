"""Zamba2 1.2B — Mamba2 backbone with a shared attention block applied
every 6th layer [arXiv:2411.15242].  (The per-invocation LoRA deltas on
the shared block are omitted; see DESIGN.md.)"""

from repro.models.config import MambaConfig, ModelConfig


def _pattern(n_layers: int, every: int = 6):
    return tuple(
        "shared_attn" if (i + 1) % every == 0 else "mamba2"
        for i in range(n_layers)
    )


def full() -> ModelConfig:
    return ModelConfig(
        name="zamba2-1.2b",
        vocab_size=32000, d_model=2048, n_layers=38,
        n_heads=32, n_kv_heads=32, head_dim=64, d_ff=8192,
        block_pattern=_pattern(38),
        mamba=MambaConfig(d_state=64, d_conv=4, expand=2, headdim=64, ngroups=1),
        mlp_act="silu", rope_theta=10000.0,
    )


def smoke() -> ModelConfig:
    return ModelConfig(
        name="zamba2-smoke",
        vocab_size=512, d_model=128, n_layers=4,
        n_heads=4, n_kv_heads=4, head_dim=32, d_ff=256,
        block_pattern=("mamba2", "shared_attn", "mamba2", "shared_attn"),
        mamba=MambaConfig(d_state=16, d_conv=4, expand=2, headdim=32, ngroups=1),
        mlp_act="silu",
        param_dtype="float32", compute_dtype="float32",
        loss_chunk=64, remat=False,
    )
