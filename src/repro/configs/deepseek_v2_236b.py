"""DeepSeek-V2 236B — MLA (kv_lora 512) + fine-grained MoE:
160 routed experts top-6 + 2 shared, first layer dense [arXiv:2405.04434]."""

from repro.models.config import MLAConfig, ModelConfig, MoEConfig


def full() -> ModelConfig:
    return ModelConfig(
        name="deepseek-v2-236b",
        vocab_size=102400, d_model=5120, n_layers=60,
        n_heads=128, n_kv_heads=128, d_ff=12288,
        block_pattern=("mla",) * 60,
        mla=MLAConfig(q_lora=1536, kv_lora=512, qk_nope=128, qk_rope=64,
                      v_head=128),
        moe=MoEConfig(num_experts=160, top_k=6, d_expert=1536, num_shared=2,
                      first_dense_layers=1, dense_d_ff=12288,
                      capacity_factor=1.0),
        mlp_act="silu", rope_theta=10000.0,
        sharding_profile="tp",
    )


def smoke() -> ModelConfig:
    return ModelConfig(
        name="deepseek-v2-smoke",
        vocab_size=512, d_model=128, n_layers=2,
        n_heads=4, n_kv_heads=4, d_ff=256,
        block_pattern=("mla",) * 2,
        mla=MLAConfig(q_lora=64, kv_lora=32, qk_nope=16, qk_rope=16, v_head=16),
        moe=MoEConfig(num_experts=8, top_k=2, d_expert=64, num_shared=1,
                      first_dense_layers=1, dense_d_ff=256,
                      capacity_factor=2.0, dropless=True),
        mlp_act="silu",
        param_dtype="float32", compute_dtype="float32",
        loss_chunk=64, remat=False,
    )
