"""Command-R 35B — parallel attention+MLP blocks, LayerNorm, no biases,
tied embeddings [hf:CohereForAI/c4ai-command-r-v01]."""

from repro.models.config import ModelConfig


def full() -> ModelConfig:
    return ModelConfig(
        name="command-r-35b",
        vocab_size=256000, d_model=8192, n_layers=40,
        n_heads=64, n_kv_heads=8, d_ff=22528,
        mlp_act="silu", rope_theta=10000.0,
        parallel_block=True, norm="layernorm", tie_embeddings=True,
    )


def smoke() -> ModelConfig:
    return ModelConfig(
        name="command-r-smoke",
        vocab_size=512, d_model=128, n_layers=2,
        n_heads=8, n_kv_heads=2, d_ff=352,
        mlp_act="silu", parallel_block=True, norm="layernorm",
        tie_embeddings=True,
        param_dtype="float32", compute_dtype="float32",
        loss_chunk=64, remat=False,
    )
