"""Assigned-architecture registry: ``--arch <id>`` resolution.

Each module defines ``full()`` (the published configuration, exercised
only via the dry-run) and ``smoke()`` (a reduced same-family config that
runs a real forward/train step on CPU).

Shapes (assignment): every arch pairs with the LM shape set below;
``decode_*``/``long_*`` lower serve_step (single new token against a
seq_len cache).  ``long_500k`` requires sub-quadratic sequence mixing and
is only runnable for the SSM/hybrid archs (see ``SKIP_CELLS``).
"""

from __future__ import annotations

import importlib
from dataclasses import dataclass
from typing import Dict, List, Optional, Tuple

from repro.models.config import ModelConfig

ARCH_IDS = [
    "qwen2_vl_7b",
    "deepseek_v2_236b",
    "granite_moe_3b_a800m",
    "tinyllama_1_1b",
    "gemma_2b",
    "command_r_35b",
    "gemma_7b",
    "whisper_tiny",
    "zamba2_1_2b",
    "rwkv6_7b",
]

# public ids as given in the assignment -> module names
ALIASES = {
    "qwen2-vl-7b": "qwen2_vl_7b",
    "deepseek-v2-236b": "deepseek_v2_236b",
    "granite-moe-3b-a800m": "granite_moe_3b_a800m",
    "tinyllama-1.1b": "tinyllama_1_1b",
    "gemma-2b": "gemma_2b",
    "command-r-35b": "command_r_35b",
    "gemma-7b": "gemma_7b",
    "whisper-tiny": "whisper_tiny",
    "zamba2-1.2b": "zamba2_1_2b",
    "rwkv6-7b": "rwkv6_7b",
}


@dataclass(frozen=True)
class ShapeSpec:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # "train" | "prefill" | "decode"


SHAPES: Dict[str, ShapeSpec] = {
    "train_4k": ShapeSpec("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeSpec("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeSpec("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeSpec("long_500k", 524288, 1, "decode"),
}

#: archs whose sequence mixing is sub-quadratic end-to-end (long_500k runs)
LONG_CONTEXT_OK = {"zamba2_1_2b", "rwkv6_7b"}

#: (arch, shape) cells skipped, with the reason recorded in EXPERIMENTS.md
SKIP_CELLS: Dict[Tuple[str, str], str] = {
    (a, "long_500k"): "pure full-attention arch: O(S^2) prefill / O(S) KV "
                      "cache at 524k is out of scope per assignment"
    for a in ARCH_IDS if a not in LONG_CONTEXT_OK
}


def resolve(arch: str) -> str:
    return ALIASES.get(arch, arch)


def get_config(arch: str, smoke: bool = False) -> ModelConfig:
    mod = importlib.import_module(f"repro.configs.{resolve(arch)}")
    return mod.smoke() if smoke else mod.full()


def cells(include_skipped: bool = False) -> List[Tuple[str, str]]:
    out = []
    for a in ARCH_IDS:
        for s in SHAPES:
            if not include_skipped and (a, s) in SKIP_CELLS:
                continue
            out.append((a, s))
    return out
