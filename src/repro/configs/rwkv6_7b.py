"""RWKV6 (Finch) 7B — attention-free, data-dependent decay
[arXiv:2404.05892]."""

from repro.models.config import ModelConfig, RWKVConfig


def full() -> ModelConfig:
    return ModelConfig(
        name="rwkv6-7b",
        vocab_size=65536, d_model=4096, n_layers=32,
        n_heads=64, n_kv_heads=64, d_ff=14336,
        block_pattern=("rwkv6",) * 32,
        rwkv=RWKVConfig(head_dim=64, decay_lora=64, mix_lora=32),
        rope_type="none",
    )


def smoke() -> ModelConfig:
    return ModelConfig(
        name="rwkv6-smoke",
        vocab_size=512, d_model=128, n_layers=2,
        n_heads=4, n_kv_heads=4, d_ff=256,
        block_pattern=("rwkv6",) * 2,
        rwkv=RWKVConfig(head_dim=32, decay_lora=16, mix_lora=8),
        rope_type="none",
        param_dtype="float32", compute_dtype="float32",
        loss_chunk=64, remat=False,
    )
