"""TinyLlama 1.1B — llama2-architecture dense transformer [arXiv:2401.02385]."""

from repro.models.config import ModelConfig


def full() -> ModelConfig:
    return ModelConfig(
        name="tinyllama-1.1b",
        vocab_size=32000, d_model=2048, n_layers=22,
        n_heads=32, n_kv_heads=4, d_ff=5632,
        mlp_act="silu", rope_theta=10000.0,
    )


def smoke() -> ModelConfig:
    return ModelConfig(
        name="tinyllama-smoke",
        vocab_size=512, d_model=128, n_layers=2,
        n_heads=8, n_kv_heads=2, d_ff=352,
        mlp_act="silu", rope_theta=10000.0,
        param_dtype="float32", compute_dtype="float32",
        loss_chunk=64, remat=False,
    )
