"""On-disk B+-tree with Scan and bulk Load (paper §4.2, Fig. 7).

An *m*-ary balanced search tree of fixed-size pages in one database file.
64-bit integer keys and values; ``degree`` keys per node (max 510 at the
8 KB page size, as in the paper).  Bulk-loading writes key-sorted full
leaves left-to-right (a loop of leaf pwrites); a range scan gathers the
candidate leaf page IDs from the internal levels and then performs a loop
of leaf preads — the two I/O loops Foreactor parallelizes.

Page layout (little-endian)::

    page 0            meta:  'BPT1' u32 degree u32 page_size u64 npages
                             u64 root u32 height u64 nleaves u64 nitems
    pages 1..nleaves  leaves: u8 type=1 u16 nkeys u64 right_sibling
                              keys u64[degree] values u64[degree]
    then internals    nodes:  u8 type=2 u16 nkeys u64 0
                              keys u64[degree] children u64[degree]
                      (keys[i] = max key in subtree of children[i])

Internal pages (a <1% fraction of the file) are cached in memory at
``open()`` — the analogue of LevelDB holding index blocks resident — so
Scan's device I/O is exactly the leaf loop, and point ``search`` on a cold
tree (``search_cold``) demonstrates the strict-dependency-chain limitation
of §7.
"""

from __future__ import annotations

import struct
from typing import List, Optional, Tuple

import numpy as np

from repro.core.api import io
from repro.core.device import Device

MAGIC = b"BPT1"
PAGE_SIZE = 8192
MAX_DEGREE = 510  # (8192 - 11) // 16 = 511; paper uses 510
_META = struct.Struct("<4sIIQQIQQ")
_NODE_HDR = struct.Struct("<BHQ")
LEAF, INTERNAL = 1, 2


def leaf_page_bytes(
    keys: np.ndarray, vals: np.ndarray, degree: int, leaf_idx: int,
    nleaves: int, page_size: int = PAGE_SIZE,
) -> bytes:
    """Serialize leaf ``leaf_idx`` of a bulk-load from the full sorted
    arrays.  Pure function — shared by the loader and its foreaction-graph
    plugin (the plugin *is* the Compute annotation of the pwrite node)."""
    lo = leaf_idx * degree
    hi = min(lo + degree, len(keys))
    n = hi - lo
    right_sib = leaf_idx + 2 if leaf_idx + 1 < nleaves else 0  # page ids are 1-based
    buf = bytearray(page_size)
    _NODE_HDR.pack_into(buf, 0, LEAF, n, right_sib)
    o = _NODE_HDR.size
    buf[o : o + 8 * n] = np.ascontiguousarray(keys[lo:hi], dtype="<u8").tobytes()
    o += 8 * degree
    buf[o : o + 8 * n] = np.ascontiguousarray(vals[lo:hi], dtype="<u8").tobytes()
    return bytes(buf)


def internal_page_bytes(
    keys: List[int], children: List[int], degree: int, page_size: int = PAGE_SIZE
) -> bytes:
    n = len(keys)
    buf = bytearray(page_size)
    _NODE_HDR.pack_into(buf, 0, INTERNAL, n, 0)
    o = _NODE_HDR.size
    buf[o : o + 8 * n] = np.asarray(keys, dtype="<u8").tobytes()
    o += 8 * degree
    buf[o : o + 8 * n] = np.asarray(children, dtype="<u8").tobytes()
    return bytes(buf)


def parse_node(page: bytes, degree: int):
    typ, n, sib = _NODE_HDR.unpack_from(page, 0)
    o = _NODE_HDR.size
    keys = np.frombuffer(page, dtype="<u8", count=n, offset=o)
    vals = np.frombuffer(page, dtype="<u8", count=n, offset=o + 8 * degree)
    return typ, n, sib, keys, vals


def plan_internal_levels(nleaves: int, degree: int, leaf_max_keys: np.ndarray):
    """Compute the internal levels for a bulk load.  Returns
    (levels, root_page, npages): levels is a list (bottom-up) of lists of
    (page_id, keys, children)."""
    levels = []
    next_page = 1 + nleaves
    child_ids = list(range(1, 1 + nleaves))
    child_max = list(int(k) for k in leaf_max_keys)
    if nleaves == 1:
        return [], 1, 1 + nleaves
    while len(child_ids) > 1:
        level = []
        for i in range(0, len(child_ids), degree):
            ks = child_max[i : i + degree]
            cs = child_ids[i : i + degree]
            level.append((next_page, ks, cs))
            next_page += 1
        levels.append(level)
        child_ids = [pid for pid, _, _ in level]
        child_max = [ks[-1] for _, ks, _ in level]
    root_page = levels[-1][-1][0]
    return levels, root_page, next_page


class BPTree:
    def __init__(self, device: Device, path: str, degree: int = MAX_DEGREE,
                 page_size: int = PAGE_SIZE):
        if not (2 <= degree <= MAX_DEGREE):
            raise ValueError(f"degree must be in [2, {MAX_DEGREE}]")
        self.device = device
        self.path = path
        self.degree = degree
        self.page_size = page_size
        self.fd: Optional[int] = None
        self.npages = 0
        self.root = 0
        self.height = 0
        self.nleaves = 0
        self.nitems = 0
        self._internal_cache: dict = {}

    # -- construction -------------------------------------------------------
    def bulk_load(self, keys: np.ndarray, vals: np.ndarray) -> None:
        """Build the tree from a sorted key/value stream.

        The leaf-write loop is THE I/O loop of paper §4.2: page contents are
        deterministic functions of (records, degree, leaf_idx), so a
        foreaction graph can compute future pwrite arguments ahead of time
        and pre-issue them (all edges strong: every write is guaranteed).
        """
        keys = np.asarray(keys, dtype=np.uint64)
        vals = np.asarray(vals, dtype=np.uint64)
        if len(keys) == 0:
            raise ValueError("bulk_load of empty stream")
        if not bool(np.all(keys[:-1] < keys[1:])):
            raise ValueError("bulk_load requires strictly sorted unique keys")
        degree = self.degree
        nleaves = (len(keys) + degree - 1) // degree
        self.fd = io.open(self.device, self.path, "w")
        # --- the leaf pwrite loop (foreactor-parallelizable) ---
        for leaf in range(nleaves):
            page = leaf_page_bytes(keys, vals, degree, leaf, nleaves, self.page_size)
            io.pwrite(self.device, self.fd, page, (1 + leaf) * self.page_size)
        # --- internal levels + meta (small, serial) ---
        leaf_max = keys[np.minimum(np.arange(1, nleaves + 1) * degree, len(keys)) - 1]
        levels, root, npages = plan_internal_levels(nleaves, degree, leaf_max)
        for level in levels:
            for pid, ks, cs in level:
                io.pwrite(self.device, self.fd,
                          internal_page_bytes(ks, cs, degree, self.page_size),
                          pid * self.page_size)
        height = len(levels) + 1
        meta = _META.pack(MAGIC, degree, self.page_size, npages, root, height,
                          nleaves, len(keys))
        io.pwrite(self.device, self.fd, meta, 0)
        io.fsync(self.device, self.fd)
        self.npages, self.root, self.height = npages, root, height
        self.nleaves, self.nitems = nleaves, len(keys)
        self._load_internal_cache()

    # -- opening --------------------------------------------------------------
    def open(self) -> "BPTree":
        self.fd = io.open(self.device, self.path, "r")
        meta = io.pread(self.device, self.fd, _META.size, 0)
        magic, degree, page_size, npages, root, height, nleaves, nitems = _META.unpack(meta)
        if magic != MAGIC:
            raise ValueError("bad B+-tree magic")
        self.degree, self.page_size = degree, page_size
        self.npages, self.root, self.height = npages, root, height
        self.nleaves, self.nitems = nleaves, nitems
        self._load_internal_cache()
        return self

    def _load_internal_cache(self) -> None:
        """Pin internal pages in memory (LevelDB-index-block analogue)."""
        self._internal_cache = {}
        for pid in range(1 + self.nleaves, self.npages):
            page = io.pread(self.device, self.fd, self.page_size, pid * self.page_size)
            self._internal_cache[pid] = parse_node(page, self.degree)

    def close(self) -> None:
        if self.fd is not None:
            io.close(self.device, self.fd)
            self.fd = None

    # -- reading ---------------------------------------------------------------
    def read_leaf(self, leaf_idx: int) -> bytes:
        return io.pread(self.device, self.fd, self.page_size,
                        (1 + leaf_idx) * self.page_size)

    def leaf_range(self, lo: int, hi: int) -> Tuple[int, int]:
        """Candidate leaf indices covering [lo, hi] — derived from the cached
        internal levels ('looking up the last level internal pages and
        gathering all candidate leaf page IDs', §4.2)."""
        first = self._descend_to_leaf(lo)
        last = self._descend_to_leaf(hi)
        return first, last

    def _descend_to_leaf(self, key: int) -> int:
        pid = self.root
        while pid > self.nleaves:  # internal pages come after leaves
            typ, n, _, ks, cs = self._internal_cache[pid]
            i = int(np.searchsorted(ks, key, side="left"))
            if i >= n:
                i = n - 1
            pid = int(cs[i])
        return pid - 1  # leaf page id -> leaf index

    def scan(self, lo: int, hi: int) -> List[Tuple[int, int]]:
        """Range scan [lo, hi] — a loop of leaf preads over the candidate
        leaf range.  THE read loop Foreactor parallelizes (Fig. 7a)."""
        if self.nitems == 0:
            return []
        first, last = self.leaf_range(lo, hi)
        out: List[Tuple[int, int]] = []
        for leaf in range(first, last + 1):
            page = self.read_leaf(leaf)
            typ, n, _sib, ks, vs = parse_node(page, self.degree)
            a = int(np.searchsorted(ks[:n], lo, side="left"))
            b = int(np.searchsorted(ks[:n], hi, side="right"))
            for i in range(a, b):
                out.append((int(ks[i]), int(vs[i])))
        return out

    def search(self, key: int) -> Optional[int]:
        """Point lookup using the cached internals (1 leaf pread)."""
        if self.nitems == 0:
            return None
        leaf = self._descend_to_leaf(key)
        typ, n, _sib, ks, vs = parse_node(self.read_leaf(leaf), self.degree)
        i = int(np.searchsorted(ks[:n], key, side="left"))
        if i < n and int(ks[i]) == key:
            return int(vs[i])
        return None

    def search_cold(self, key: int) -> Optional[int]:
        """Point lookup reading every page from the device: a strict
        dependency chain of preads — the §7 limitation (not speculatable)."""
        pid = self.root
        while True:
            page = io.pread(self.device, self.fd, self.page_size, pid * self.page_size)
            typ, n, _sib, ks, vs = parse_node(page, self.degree)
            if typ == LEAF:
                i = int(np.searchsorted(ks[:n], key, side="left"))
                if i < n and int(ks[i]) == key:
                    return int(vs[i])
                return None
            i = int(np.searchsorted(ks[:n], key, side="left"))
            if i >= n:
                i = n - 1
            pid = int(vs[i])
