"""du / cp analogues (paper §4.1, §6.1) — serially-written application code.

These functions are deliberately written exactly as a naive serial utility
would be: ``du_dir`` loops ``fstatat`` over directory entries; ``cp_file``
loops read->write over fixed-size buffers.  Foreactor parallelizes them
*without modifying this file* — the foreaction graphs live in
:mod:`repro.store.plugins`.
"""

from __future__ import annotations

from repro.core.api import io
from repro.core.device import Device

CP_BUF = 128 * 1024  # the paper's cp copies in 128 KB buffers


def du_dir(device: Device, root: str) -> int:
    """Total size of all entries in ``root`` (flat, like the paper's du
    benchmark directories)."""
    total = 0
    for name in io.getdents(device, root):
        st = io.fstatat(device, f"{root}/{name}")
        total += st.st_size
    return total


def cp_file(device: Device, src: str, dst: str, buf_size: int = CP_BUF) -> int:
    """Copy ``src`` to ``dst`` in ``buf_size`` chunks (read->write loop)."""
    size = io.fstatat(device, src).st_size
    sfd = io.open(device, src, "r")
    dfd = io.open(device, dst, "w")
    off = 0
    while off < size:
        n = min(buf_size, size - off)
        data = io.pread(device, sfd, n, off)
        io.pwrite(device, dfd, data, off)
        off += n
    io.fsync(device, dfd)
    io.close(device, sfd)
    io.close(device, dfd)
    return size
