"""Foreaction-graph plugin files for the case-study applications
(paper §4, Fig. 4; §5.1 'Foreaction Graph as Plugin Code').

Each ``build_*`` function composes a graph with the libforeactor builder
API (AddSyscallNode / AddBranchingNode / SyscallSetNext / BranchAppendChild)
and each ``capture_*`` function is the wrapper that captures the *Input*
annotation variables into the per-invocation ctx.

Stub conventions (paper §5.1):
  ComputeArgs(ctx, epochs) -> None (not ready) | ((args...), link_flag)
  SaveResult(ctx, epochs, rc) -> None
  Choice(ctx, epochs)      -> None (not ready) | child index
"""

from __future__ import annotations

import math
from typing import Any, Dict

import numpy as np

from repro.core.graph import ForeactionGraph, FromNode, GraphBuilder
from repro.core.syscalls import Sys

from . import bptree as bpt

# ---------------------------------------------------------------------------
# du: getdents followed by an fstatat loop (Fig. 4a)
# ---------------------------------------------------------------------------


def build_du_graph() -> ForeactionGraph:
    b = GraphBuilder("du")

    def dents_args(ctx, ep):
        return ((ctx["root"],), False)

    def dents_save(ctx, ep, rc):
        ctx["entries"] = rc

    def stat_args(ctx, ep):
        ents = ctx.get("entries")
        if ents is None or ep[0] >= len(ents):
            return None
        return ((f"{ctx['root']}/{ents[ep[0]]}",), False)

    def head_choice(ctx, ep):
        ents = ctx.get("entries")
        if ents is None:
            return None
        return 0 if len(ents) > 0 else 1

    def loop_choice(ctx, ep):
        ents = ctx.get("entries")
        if ents is None:
            return None
        return 0 if ep[0] + 1 < len(ents) else 1

    b.AddSyscallNode("getdents", Sys.GETDENTS, dents_args, dents_save)
    b.AddBranchingNode("any_entries", head_choice)
    b.AddSyscallNode("fstat", Sys.FSTATAT, stat_args)
    b.AddBranchingNode("more_entries", loop_choice)
    b.SyscallSetNext("getdents", "any_entries")
    b.BranchAppendChild("any_entries", "fstat")
    b.BranchAppendChild("any_entries", None)
    b.SyscallSetNext("fstat", "more_entries")
    b.BranchAppendChild("more_entries", "fstat", loopback=True)
    b.BranchAppendChild("more_entries", None)
    return b.Build()


def capture_du(device, root: str) -> Dict[str, Any]:
    return {"root": root}


# ---------------------------------------------------------------------------
# cp: fstat, open src/dst, then a loop of Link'ed pread->pwrite (Fig. 4b).
# The pwrite's data argument is the internal buffer the linked pread
# populates (Harvest of the read does nothing — no extra copies).
# All loop edges are strong: every write is guaranteed, so non-pure
# pre-issuing is allowed (§3.3 'no unrecoverable side effects').
# ---------------------------------------------------------------------------


def build_cp_graph() -> ForeactionGraph:
    b = GraphBuilder("cp")

    def stat_args(ctx, ep):
        return ((ctx["src"],), False)

    def stat_save(ctx, ep, rc):
        ctx["size"] = rc.st_size

    def open_src_args(ctx, ep):
        return ((ctx["src"], "r"), False)

    def open_src_save(ctx, ep, rc):
        ctx["sfd"] = rc

    def open_dst_args(ctx, ep):
        return ((ctx["dst"], "w"), False)

    def open_dst_save(ctx, ep, rc):
        ctx["dfd"] = rc

    def _chunk(ctx, e):
        off = e * ctx["buf_size"]
        n = min(ctx["buf_size"], ctx["size"] - off)
        return off, n

    def read_args(ctx, ep):
        if "sfd" not in ctx or "size" not in ctx:
            return None
        off, n = _chunk(ctx, ep[0])
        if n <= 0:
            return None
        return ((ctx["sfd"], n, off), True)  # link=True: submit with the pwrite

    def write_args(ctx, ep):
        if "dfd" not in ctx or "size" not in ctx:
            return None
        off, n = _chunk(ctx, ep[0])
        if n <= 0:
            return None
        return ((ctx["dfd"], FromNode("pread"), off), False)

    def head_choice(ctx, ep):
        if "size" not in ctx:
            return None
        return 0 if ctx["size"] > 0 else 1

    def loop_choice(ctx, ep):
        if "size" not in ctx:
            return None
        return 0 if (ep[0] + 1) * ctx["buf_size"] < ctx["size"] else 1

    b.AddSyscallNode("fstat_src", Sys.FSTATAT, stat_args, stat_save)
    b.AddSyscallNode("open_src", Sys.OPEN, open_src_args, open_src_save)
    b.AddSyscallNode("open_dst", Sys.OPEN, open_dst_args, open_dst_save)
    b.AddBranchingNode("any_data", head_choice)
    b.AddSyscallNode("pread", Sys.PREAD, read_args)
    b.AddSyscallNode("pwrite", Sys.PWRITE, write_args)
    b.AddBranchingNode("more_data", loop_choice)
    b.SyscallSetNext("fstat_src", "open_src")
    b.SyscallSetNext("open_src", "open_dst")
    b.SyscallSetNext("open_dst", "any_data")
    b.BranchAppendChild("any_data", "pread")
    b.BranchAppendChild("any_data", None)
    b.SyscallSetNext("pread", "pwrite")
    b.SyscallSetNext("pwrite", "more_data")
    b.BranchAppendChild("more_data", "pread", loopback=True)
    b.BranchAppendChild("more_data", None)
    return b.Build()


def capture_cp(device, src: str, dst: str, buf_size: int = 128 * 1024) -> Dict[str, Any]:
    return {"src": src, "dst": dst, "buf_size": buf_size}


# ---------------------------------------------------------------------------
# B+-tree Scan: a pure pread loop over candidate leaf pages (§4.2 — same
# shape as the stat loop, replacing fstatat with pread).
# ---------------------------------------------------------------------------


def build_bptree_scan_graph() -> ForeactionGraph:
    b = GraphBuilder("bptree_scan")

    def read_args(ctx, ep):
        leaf = ctx["first_leaf"] + ep[0]
        if leaf > ctx["last_leaf"]:
            return None
        return ((ctx["fd"], ctx["page_size"], (1 + leaf) * ctx["page_size"]), False)

    def loop_choice(ctx, ep):
        return 0 if ctx["first_leaf"] + ep[0] + 1 <= ctx["last_leaf"] else 1

    b.AddSyscallNode("pread_leaf", Sys.PREAD, read_args)
    b.AddBranchingNode("more_leaves", loop_choice)
    b.SyscallSetNext("pread_leaf", "more_leaves")
    b.BranchAppendChild("more_leaves", "pread_leaf", loopback=True)
    b.BranchAppendChild("more_leaves", None)
    return b.Build()


def capture_bptree_scan(tree: "bpt.BPTree", lo: int, hi: int) -> Dict[str, Any]:
    first, last = tree.leaf_range(lo, hi)
    return {
        "fd": tree.fd,
        "page_size": tree.page_size,
        "first_leaf": first,
        "last_leaf": last,
    }


def scan_with_graph(tree: "bpt.BPTree", lo: int, hi: int):
    """The wrapped application function for Scan (identical logic to
    BPTree.scan; kept standalone so the wrapper can capture leaf_range
    before the loop begins)."""
    return tree.scan(lo, hi)


# ---------------------------------------------------------------------------
# B+-tree bulk Load: open + a loop of leaf pwrites whose page bytes are
# computed ahead of time from the input record stream (§4.2).  Writes are
# guaranteed (strong edges throughout).
# ---------------------------------------------------------------------------


def build_bptree_load_graph() -> ForeactionGraph:
    b = GraphBuilder("bptree_load")

    def open_args(ctx, ep):
        return ((ctx["path"], "w"), False)

    def open_save(ctx, ep, rc):
        ctx["fd"] = rc

    def write_args(ctx, ep):
        if "fd" not in ctx:
            return None
        leaf = ep[0]
        if leaf >= ctx["nleaves"]:
            return None
        # the Compute annotation pulled forward: build the page bytes now
        page = bpt.leaf_page_bytes(
            ctx["keys"], ctx["vals"], ctx["degree"], leaf, ctx["nleaves"],
            ctx["page_size"],
        )
        return ((ctx["fd"], page, (1 + leaf) * ctx["page_size"]), False)

    def loop_choice(ctx, ep):
        return 0 if ep[0] + 1 < ctx["nleaves"] else 1

    b.AddSyscallNode("open_db", Sys.OPEN, open_args, open_save)
    b.AddSyscallNode("pwrite_leaf", Sys.PWRITE, write_args)
    b.AddBranchingNode("more_leaves", loop_choice)
    b.SyscallSetNext("open_db", "pwrite_leaf")
    b.SyscallSetNext("pwrite_leaf", "more_leaves")
    b.BranchAppendChild("more_leaves", "pwrite_leaf", loopback=True)
    b.BranchAppendChild("more_leaves", None)
    return b.Build()


def capture_bptree_load(tree: "bpt.BPTree", keys, vals) -> Dict[str, Any]:
    keys = np.asarray(keys, dtype=np.uint64)
    vals = np.asarray(vals, dtype=np.uint64)
    return {
        "path": tree.path,
        "degree": tree.degree,
        "page_size": tree.page_size,
        "keys": keys,
        "vals": vals,
        "nleaves": (len(keys) + tree.degree - 1) // tree.degree,
    }


def load_with_graph(tree: "bpt.BPTree", keys, vals):
    return tree.bulk_load(keys, vals)


# ---------------------------------------------------------------------------
# LSM-tree Get (Fig. 4c): a chain of pread_data nodes over candidate
# tables; the Compute annotation is the in-memory index-block lookup; the
# edge out of each pread is WEAK (the function may return early on a
# match), so only pure reads may be pre-issued past it — which they are.
# ---------------------------------------------------------------------------


def build_lsm_get_graph() -> ForeactionGraph:
    b = GraphBuilder("lsm_get")

    def read_args(ctx, ep):
        cands = ctx["cands"]
        if ep[0] >= len(cands):
            return None
        _t, off, length = cands[ep[0]]
        return ((_t.fd, length, off), False)

    def head_choice(ctx, ep):
        return 0 if len(ctx["cands"]) > 0 else 1

    def loop_choice(ctx, ep):
        return 0 if ep[0] + 1 < len(ctx["cands"]) else 1

    b.AddBranchingNode("any_cands", head_choice)
    b.AddSyscallNode("pread_data", Sys.PREAD, read_args)
    b.AddBranchingNode("more_cands", loop_choice)
    b.SetStart("any_cands")
    b.BranchAppendChild("any_cands", "pread_data")
    b.BranchAppendChild("any_cands", None)
    # weak edge: Get returns early when the key is found in this block
    b.SyscallSetNext("pread_data", "more_cands", weak=True)
    b.BranchAppendChild("more_cands", "pread_data", loopback=True)
    b.BranchAppendChild("more_cands", None)
    return b.Build()


def capture_lsm_get(lsm, key: int) -> Dict[str, Any]:
    return {"cands": lsm.candidates(key), "key": key}


def build_lsm_multiget_graph() -> ForeactionGraph:
    """N-key scatter-gather Get as ONE generated plan (the futures-style
    analytics shape): the per-key candidate chains of
    ``LSMTree.multi_get`` flattened round-robin — every key's first
    candidate, then every second candidate, ... — into a single pread loop.

    Unlike ``lsm_get`` the loop edge is STRONG: the issue phase reads every
    flattened candidate unconditionally (each is some key's possible home),
    and the per-key early exit moves to the harvest barrier, where a
    resolved key simply cancels the futures it no longer needs.
    """
    b = GraphBuilder("lsm_multiget")

    def read_args(ctx, ep):
        extents = ctx["extents"]
        if ep[0] >= len(extents):
            return None
        fd, length, off = extents[ep[0]]
        return ((fd, length, off), False)

    def head_choice(ctx, ep):
        return 0 if len(ctx["extents"]) > 0 else 1

    def loop_choice(ctx, ep):
        return 0 if ep[0] + 1 < len(ctx["extents"]) else 1

    b.AddBranchingNode("any_exts", head_choice)
    b.AddSyscallNode("pread_data", Sys.PREAD, read_args)
    b.AddBranchingNode("more_exts", loop_choice)
    b.SetStart("any_exts")
    b.BranchAppendChild("any_exts", "pread_data")
    b.BranchAppendChild("any_exts", None)
    b.SyscallSetNext("pread_data", "more_exts")
    b.BranchAppendChild("more_exts", "pread_data", loopback=True)
    b.BranchAppendChild("more_exts", None)
    return b.Build()


def capture_lsm_multiget(lsm, keys) -> Dict[str, Any]:
    """Flatten the batch's candidate extents in the exact order
    ``LSMTree.multi_get`` issues them: round-robin across keys, memtable
    hits (tombstones included) contributing none."""
    with lsm._lock:
        in_mem = {k for k in keys if k in lsm.mem}
    per_key = [([] if k in in_mem else lsm.candidates(k)) for k in keys]
    extents = []
    width = max((len(c) for c in per_key), default=0)
    for j in range(width):
        for cands in per_key:
            if j < len(cands):
                t, off, length = cands[j]
                extents.append((t.fd, length, off))
    return {"extents": extents, "keys": list(keys)}


def register_all(fa, precompile: bool = False) -> None:
    """Register every case-study graph on a Foreactor instance.

    ``precompile=True`` builds each graph and compiles its
    :class:`repro.core.plan.GraphPlan` immediately (cached per graph), so a
    serving process warms the plan cache before the first request instead
    of lowering on the request path."""
    names = ("du", "cp", "bptree_scan", "bptree_load", "lsm_get",
             "lsm_multiget")
    fa.register("du", build_du_graph)
    fa.register("cp", build_cp_graph)
    fa.register("bptree_scan", build_bptree_scan_graph)
    fa.register("bptree_load", build_bptree_load_graph)
    fa.register("lsm_get", build_lsm_get_graph)
    fa.register("lsm_multiget", build_lsm_multiget_graph)
    if precompile:
        for name in names:
            fa.plan(name)


# ---------------------------------------------------------------------------
# Mined counterparts: the same du/cp applications observed and mined instead
# of hand-annotated.  The graphs above are the ground truth the miner is
# cross-checked against (tests/test_trace_mine.py asserts the mined graphs
# produce the same pre-issue schedule); docs/AUTHORING.md renders them, and
# tools/check_doc_refs.py rebuilds them to keep those renderings honest.
# ---------------------------------------------------------------------------


def mine_reference_graphs():
    """Record du/cp traces on a deterministic scratch workload and mine
    them; returns ``{"du": MinedGraph, "cp": MinedGraph}``.

    Deterministic by construction (fixed file set, fixed sizes), so two
    calls yield structurally identical graphs — the property the doc
    checker and the determinism test both lean on.
    """
    from repro.core import Foreactor, MemDevice
    from repro.core.api import io as _io  # noqa: F401  (app code routes via io)

    from .fileutils import cp_file, du_dir

    dev = MemDevice()
    for i in range(5):
        fd = dev.open(f"/dir/f{i}", "w")
        dev.pwrite(fd, bytes([i % 251]) * (32 + 8 * i), 0)
        dev.close(fd)
    for i in range(3):
        fd = dev.open(f"/dir2/g{i}", "w")
        dev.pwrite(fd, bytes([i]) * 16, 0)
        dev.close(fd)
    # cp sources: one even multiple of the buffer, one with a remainder
    for name, size in (("/src_a.bin", 4 * 4096), ("/src_b.bin", 6 * 4096 + 100)):
        fd = dev.open(name, "w")
        dev.pwrite(fd, bytes(range(256)) * (size // 256) + b"\x00" * (size % 256), 0)
        dev.close(fd)

    fa = Foreactor(device=dev, backend="sync")
    du = fa.observe("du_mined", capture_du)(du_dir)
    du(dev, "/dir")
    du(dev, "/dir2")
    cp = fa.observe("cp_mined", lambda device, src, dst, buf_size=4096:
                    capture_cp(device, src, dst, buf_size))(cp_file)
    # train on the remainder-chunk trace (it pins the clamped-residual size
    # provenance); the even-multiple trace is the held-out validation run
    cp(dev, "/src_b.bin", "/dst_b.bin", 4096)
    cp(dev, "/src_a.bin", "/dst_a.bin", 4096)
    mined_du = fa.mine("du_mined", register=False)
    mined_cp = fa.mine("cp_mined", register=False)
    fa.shutdown()
    return {"du": mined_du, "cp": mined_cp}
