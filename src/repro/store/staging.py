"""Staged, undoable write-path speculation (the §3.3 extension).

The paper restricts pre-issuing to syscalls with "no unrecoverable side
effects": a pwrite behind a weak edge may never run early, because if the
function exits before reaching it the bytes are already on disk.  This
module makes those side effects *recoverable*, which is what lets the
engine treat ``Effect.UNDOABLE`` nodes like pure ones (see
``repro.core.syscalls.effect_of`` and docs/ARCHITECTURE.md, "Undoable
write speculation"):

* **Staged creates** — a speculative ``open(path, "w")`` lands in a
  *staging extent*: a temporary name next to the final path (same
  directory, and on a :class:`repro.core.device.ShardedDevice` the same
  sub-device, so publish stays a single atomic rename).  Every write
  through the returned fd hits the staged file; the committed namespace
  never sees partial state.
* **Staged overwrites** — a speculative ``pwrite`` to a pre-existing fd
  first preads the bytes it is about to clobber into the *undo log*, then
  writes in place.  Rollback replays the log in reverse and truncates away
  any extension past the old end.
* **Staged renames** — a speculative ``rename`` to a fresh destination
  executes immediately but logs the old name; rollback renames back.  This
  is what makes the checkpoint GC graph's tombstone rename (de-committing a
  checkpoint by moving its commit marker aside) speculable and abortable.
* **Publish barrier** — a staged create is *published* (renamed onto its
  final path) when the frontier serves the ``close`` of its fd, or — for
  fds the function leaves open — when the session commits.  Until then the
  file is invisible to the committed namespace; after, it is committed even
  if the session later aborts (the close was the commit point, exactly like
  the checkpoint manager's commit marker).
* **Rollback** — ``finalize(ok=False)`` (session raised) or an
  early-exited speculation (the frontier never demanded the node) unwinds:
  staged files are unlinked, overwrite undo entries are replayed newest
  first.  Aborted speculation leaves no trace in the committed namespace
  (``tests/test_conformance.py`` proves it against every backend).

A transaction belongs to one ``SpecSession``; records are appended on the
session thread (at peek or at a frontier serve) but ``applied`` flips on
worker threads, so the record list is lock-protected.

Known limits, documented rather than hidden: overwrite rollback needs the
fd still open at teardown (the Device API addresses writes by fd); a
sparse overwrite that starts past the old end of file leaves the device's
zero padding between old-EOF and the write offset behind after rollback;
and writes *into* a staged file pre-issue only on guaranteed paths —
behind a weak edge they would commit wholesale if the create publishes,
and byte-range undo of un-demanded writes is unsound under concurrent
extends, so the engine keeps them at the frontier
(``SpecSession._make_request``).
"""

from __future__ import annotations

import itertools
import os
import threading
import warnings
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, List, Optional, Tuple

from repro.core.device import Device, ShardedDevice
from repro.core.syscalls import resolve_args


class StagingError(RuntimeError):
    """A staging transaction could not fully revert or publish its state."""

#: infix marking a staged (not yet published) file; never appears in a
#: committed namespace because publish renames it away and rollback unlinks
STAGE_TAG = ".__stg"

_txn_counter = itertools.count()


def staged_name(device: Device, path: str, token: str, seq: int) -> str:
    """The staging-extent name for ``path``: same directory, and on a
    sharded device pinned to the same sub-device as the final path, so the
    publish rename never crosses shards (cross-shard rename is a
    non-atomic copy fallback)."""
    if isinstance(device, ShardedDevice):
        shard, sub = device.resolve(path)
        return f"shard{shard}:{sub}{STAGE_TAG}.{token}.{seq}"
    return f"{path}{STAGE_TAG}.{token}.{seq}"


@dataclass
class StageRecord:
    """One undo-log entry: a staged create, a logged overwrite, or an
    undoable rename."""

    kind: str  # "create" | "overwrite" | "rename"
    final_path: Optional[str] = None  # create/rename: where the file ends up
    staged_path: Optional[str] = None  # create: staged name; rename: old name
    flags: Optional[str] = None
    fd: Optional[int] = None  # create: staged fd; overwrite: target fd
    offset: int = 0  # overwrite: where the write landed
    old_data: Optional[bytes] = None  # overwrite: clobbered bytes
    new_len: int = 0  # overwrite: length written
    applied: bool = False  # the runner actually executed
    demanded: bool = False  # the frontier reached (or served) the node
    published: bool = False  # committed: rename done / undo entry dropped
    undone: bool = False


class StagingTxn:
    """The per-session write transaction: staging extents + undo log.

    The engine calls :meth:`stage_create` / :meth:`stage_overwrite` when
    pre-issuing (or frontier-serving) an undoable syscall, marks records
    *demanded* as the frontier reaches them, publishes at close barriers
    via :meth:`on_close`, and settles everything in :meth:`finalize`.
    """

    def __init__(self, device: Device, token: Optional[str] = None):
        self.device = device
        self.token = token if token is not None else (
            f"{os.getpid():x}-{next(_txn_counter):x}")
        self._lock = threading.Lock()
        self._records: List[StageRecord] = []
        self._staged_fds: Dict[int, StageRecord] = {}
        self._seq = itertools.count()
        # observability (tests, bench_write)
        self.published_count = 0
        self.undone_count = 0
        self.rollback_errors: List[BaseException] = []

    # -- staging -----------------------------------------------------------
    def stage_create(self, path: str, flags: str = "w",
                     ) -> Tuple[Callable[[Device], int], StageRecord]:
        """Redirect a truncating-create to a staging extent.  Returns the
        execution runner (for the IORequest) and the undo-log record."""
        rec = StageRecord(kind="create", final_path=path, flags=flags,
                          staged_path=staged_name(self.device, path,
                                                  self.token, next(self._seq)))
        with self._lock:
            self._records.append(rec)

        def runner(device: Device) -> int:
            fd = device.open(rec.staged_path, rec.flags)
            with self._lock:
                rec.fd = fd
                rec.applied = True
                self._staged_fds[fd] = rec
            return fd

        return runner, rec

    def stage_overwrite(self, args: Tuple[Any, ...],
                        ) -> Tuple[Callable[[Device], int], StageRecord]:
        """Wrap a pwrite to a non-staged fd with undo-bytes capture.  The
        fd/data arguments may still be deferred (``FromRequest``); they are
        resolved inside the runner, on the executing worker."""
        rec = StageRecord(kind="overwrite")
        with self._lock:
            self._records.append(rec)

        def runner(device: Device) -> int:
            fd, data, off = resolve_args(args)
            old = device.pread(fd, len(data), off)
            with self._lock:
                rec.fd = fd
                rec.offset = off
                rec.old_data = old
                rec.new_len = len(data)
                rec.applied = True
            return device.pwrite(fd, data, off)

        return runner, rec

    def stage_rename(self, args: Tuple[Any, ...],
                     ) -> Tuple[Callable[[Device], Any], StageRecord]:
        """Wrap a rename so an aborted speculation can rename back.

        The rename executes immediately (like an overwrite, the effect is
        visible as soon as the runner lands); the record remembers the old
        name so rollback restores the namespace.  Sound only for a *fresh*
        destination — an overwriting rename would clobber bytes the
        rename-back cannot restore, the same file-granularity limit staged
        creates have.  The checkpoint GC graph's tombstone rename (a commit
        marker moved to a unique tombstone name) is the canonical user.
        """
        rec = StageRecord(kind="rename")
        with self._lock:
            self._records.append(rec)

        def runner(device: Device):
            src, dst = resolve_args(args)
            out = device.rename(src, dst)
            with self._lock:
                rec.staged_path = src
                rec.final_path = dst
                rec.applied = True
            return out

        return runner, rec

    def is_staged_fd(self, fd: Any) -> bool:
        """True iff ``fd`` refers to a file this transaction created — a
        write through it needs no undo entry (rollback unlinks the file)."""
        with self._lock:
            return fd in self._staged_fds

    # -- commit points -------------------------------------------------------
    def on_demand(self, rec: StageRecord) -> None:
        """The frontier harvested (or served) the record's node: real
        execution now depends on this side effect."""
        rec.demanded = True

    def record_for_fd(self, fd: Any) -> Optional[StageRecord]:
        """The staged-create record ``fd`` currently refers to.  Callers
        must resolve while the fd is provably still open (at pre-issue or
        just before a frontier-served close) — once a close has executed,
        the OS may recycle the number for a later staged create and a raw
        fd lookup would name the wrong record."""
        with self._lock:
            return self._staged_fds.get(fd)

    def publish_demanded(self) -> None:
        """Hard commit point mid-session: publish every record the frontier
        has demanded *now*, in program order, instead of waiting for the
        session to settle.  After this call those effects survive a later
        abort — which is exactly what a forward-only protocol needs at its
        point of no return.  The checkpoint GC graph calls it right after
        the frontier serves the tombstone rename and before any unlink: a
        crash or abort beyond that point must leave the tombstone in place
        (the half-unlinked directory is only safe because it is
        de-committed), while an abort before it rolls the rename back and
        the checkpoint stays fully live."""
        with self._lock:
            records = list(self._records)
        for rec in records:
            if rec.demanded:
                self._publish(rec)

    def publish_close(self, rec: Optional[StageRecord]) -> None:
        """Publish barrier: the frontier served the ``close`` of this
        record's file — rename it onto its final path.  Identity-checked:
        the fd mapping is dropped only if it still points at ``rec`` (a
        recycled fd number belonging to a newer staged create stays)."""
        if rec is None:
            return
        with self._lock:
            if self._staged_fds.get(rec.fd) is rec:
                del self._staged_fds[rec.fd]
        if rec.demanded:
            self._publish(rec)

    def on_close(self, fd: int) -> None:
        """fd-addressed convenience form of :meth:`publish_close`; only
        safe while ``fd`` is still open (no reuse possible)."""
        self.publish_close(self.record_for_fd(fd))

    def _publish(self, rec: StageRecord) -> None:
        if rec.published or rec.undone:
            return
        if rec.kind == "create":
            self.device.rename(rec.staged_path, rec.final_path)
        # overwrite publish = drop the undo entry; bytes are already in place
        rec.published = True
        self.published_count += 1

    def _undo(self, rec: StageRecord) -> None:
        if rec.published or rec.undone or not rec.applied:
            rec.undone = True
            return
        if rec.kind == "create":
            with self._lock:
                # identity check: the fd number may have been reused by a
                # later staged create after the application closed this one
                still_open = self._staged_fds.get(rec.fd) is rec
                if still_open:
                    del self._staged_fds[rec.fd]
            if still_open:
                try:
                    self.device.close(rec.fd)
                except Exception:
                    pass
            try:
                self.device.unlink(rec.staged_path)
            except FileNotFoundError:
                pass
        elif rec.kind == "rename":
            # rename back: the destination was fresh, so this restores the
            # namespace exactly
            self.device.rename(rec.final_path, rec.staged_path)
        else:
            self.device.pwrite(rec.fd, rec.old_data, rec.offset)
            if len(rec.old_data) < rec.new_len:
                # the write extended the file: cut the extension back off
                self.device.truncate(rec.fd, rec.offset + len(rec.old_data))
        rec.undone = True
        self.undone_count += 1

    def finalize(self, ok: bool) -> None:
        """Settle the transaction at session teardown (after the backend
        drained, so no staged runner is still executing).

        ``ok=True`` (the wrapped function returned): publish every record
        the frontier demanded, in program order — commit-marker-last
        protocols keep their ordering — and roll back speculation that ran
        past the real exit.  ``ok=False`` (it raised): roll back everything
        unpublished, newest first, so overlapping undo bytes replay in
        reverse application order.

        A failing undo never abandons the rest of the rollback: every
        record is attempted, failures are collected on
        ``self.rollback_errors``, and they surface as a raised
        :class:`StagingError` on the commit path but only as a warning on
        the abort path — the application's original exception is already
        propagating there and must not be replaced by the cleanup's.
        """
        with self._lock:
            records = list(self._records)
        if ok:
            for rec in records:
                if rec.demanded:
                    self._publish(rec)
        for rec in reversed(records):
            if not rec.published:
                try:
                    self._undo(rec)
                except Exception as e:
                    self.rollback_errors.append(e)
        if self.rollback_errors:
            msg = (f"staging rollback left {len(self.rollback_errors)} "
                   f"record(s) unreverted: {self.rollback_errors[:3]!r}")
            if ok:
                raise StagingError(msg) from self.rollback_errors[0]
            warnings.warn(msg, RuntimeWarning)

    def snapshot(self) -> Dict[str, int]:
        with self._lock:
            return {
                "records": len(self._records),
                "published": self.published_count,
                "undone": self.undone_count,
            }
