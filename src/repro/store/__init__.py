"""On-storage substrate the paper's case studies (§4) run on:

* :mod:`repro.store.recordio`  — fixed-record shard files (data pipeline)
* :mod:`repro.store.bptree`    — on-disk B+-tree with Scan / bulk Load (§4.2)
* :mod:`repro.store.lsm`       — LSM-tree key-value store with Get (§4.3)
* :mod:`repro.store.fileutils` — du / cp analogues (§4.1)
* :mod:`repro.store.plugins`   — the foreaction-graph plugin files for all of
  the above (paper Fig. 4), written against :mod:`repro.core`.

All I/O goes through :class:`repro.core.api.io` so that an active Foreactor
session can intercept and speculate; with no session the calls hit the
device directly (original serial behaviour).
"""
