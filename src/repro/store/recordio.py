"""Fixed-record shard files.

Format::

    [magic 'RIO1'][u32 record_size][u32 count][pad to 16] records...

Records live at ``HEADER + i * record_size``.  This is the on-disk format
the training data pipeline reads (one record = one tokenized sequence) and
a convenient substrate for regular-I/O-loop experiments (paper §4.1).
"""

from __future__ import annotations

import struct
from typing import Iterator, List, Optional

from repro.core.api import Foreactor, io
from repro.core.device import Device
from repro.core.patterns import build_write_file_graph

MAGIC = b"RIO1"
HEADER = 16
_HDR = struct.Struct("<4sII4x")


class RecordShardWriter:
    def __init__(self, device: Device, path: str, record_size: int):
        self.device = device
        self.path = path
        self.record_size = record_size
        self.count = 0
        self.fd = io.open(device, path, "w")
        io.pwrite(device, self.fd, _HDR.pack(MAGIC, record_size, 0), 0)

    def append(self, payload: bytes) -> int:
        if len(payload) != self.record_size:
            raise ValueError(f"record must be exactly {self.record_size} bytes")
        off = HEADER + self.count * self.record_size
        io.pwrite(self.device, self.fd, payload, off)
        self.count += 1
        return self.count - 1

    def close(self) -> None:
        io.pwrite(self.device, self.fd, _HDR.pack(MAGIC, self.record_size, self.count), 0)
        io.fsync(self.device, self.fd)
        io.close(self.device, self.fd)


class RecordShardReader:
    def __init__(self, device: Device, path: str):
        self.device = device
        self.path = path
        self.fd = io.open(device, path, "r")
        magic, self.record_size, self.count = _HDR.unpack(io.pread(device, self.fd, HEADER, 0))
        if magic != MAGIC:
            raise ValueError(f"{path}: bad shard magic {magic!r}")

    def offset_of(self, i: int) -> int:
        return HEADER + i * self.record_size

    def read_record(self, i: int) -> bytes:
        if not (0 <= i < self.count):
            raise IndexError(i)
        return io.pread(self.device, self.fd, self.record_size, self.offset_of(i))

    def __len__(self) -> int:
        return self.count

    def __iter__(self) -> Iterator[bytes]:
        for i in range(self.count):
            yield self.read_record(i)

    def close(self) -> None:
        io.close(self.device, self.fd)


def write_shard(device: Device, path: str, records: List[bytes],
                fa: Optional[Foreactor] = None) -> None:
    """Write one complete shard file.

    Without ``fa`` this is the original serial path (header, appends,
    header rewrite, fsync, close).  With a Foreactor it becomes one
    ``write_file`` foreaction chain: the create is staged (undoable), the
    final header and every record pre-issue as guaranteed writes, fsync and
    close ride behind as harvest barriers, and the file publishes onto
    ``path`` at the close — a crashed or aborted writer leaves no partial
    shard in the committed namespace.  Final bytes are identical either
    way (the speculative path just writes the true record count once
    instead of rewriting the header at close).
    """
    if not records:
        raise ValueError("empty shard")
    record_size = len(records[0])
    if fa is None:
        w = RecordShardWriter(device, path, record_size)
        for r in records:
            w.append(r)
        w.close()
        return
    for r in records:
        if len(r) != record_size:
            raise ValueError(f"record must be exactly {record_size} bytes")
    writes = [(_HDR.pack(MAGIC, record_size, len(records)), 0)]
    writes += [(r, HEADER + i * record_size) for i, r in enumerate(records)]
    fa.register("write_file", build_write_file_graph)

    @fa.wrap("write_file", lambda: {"path": path, "writes": writes})
    def _write_all():
        fd = io.open(device, path, "w")
        for data, off in writes:
            io.pwrite(device, fd, data, off)
        io.fsync(device, fd)
        io.close(device, fd)

    _write_all()
