"""LSM-tree key-value store (paper §4.3, §6.3 — the LevelDB analogue).

Records live in sorted-table (SSTable) files: a sequence of ~``block_size``
data blocks, an index block mapping first-keys to block extents, and a
footer.  New records go to an in-memory memtable; when full it is dumped as
a new level-0 table (L0 tables may overlap).  When L0 grows past a limit, a
compaction merges it (plus overlapping L1 tables) into non-overlapping L1
tables, and so on down the levels.

``get`` is the paper's measured code path: after a memtable miss it walks
the candidate tables — all covering L0 tables newest-to-oldest, then at
most one table per lower level — doing an in-memory index-block lookup
followed by one data-block ``pread`` per table, returning early on a match.
That pread chain (12~19 deep in the paper's LevelDB) is what the
foreaction graph of Fig. 4(c) parallelizes.

SSTable file layout (little-endian)::

    data blocks:  entries  (u64 key, u32 vlen, value-bytes), sorted by key
    index block:  entries  (u64 first_key, u64 offset, u32 length)
    footer:       'SST1' u64 index_off u32 index_len u32 nblocks
                  u64 min_key u64 max_key u64 nitems
"""

from __future__ import annotations

import json
import struct
import threading
from typing import Dict, Iterator, List, Optional, Tuple

from repro.core.api import io
from repro.core.device import Device

SST_MAGIC = b"SST1"
_FOOTER = struct.Struct("<4s4xQIIQQQ")
_IDXENT = struct.Struct("<QQI")
_ENT = struct.Struct("<QI")
TOMBSTONE = 0xFFFFFFFF
DEFAULT_BLOCK = 4096


def encode_entries(items: List[Tuple[int, Optional[bytes]]]) -> Tuple[bytes, List[Tuple[int, int, int]]]:
    """Serialize sorted (key, value|None) items into blocks; returns
    (data_bytes, index) with index entries (first_key, offset, length)."""
    out = bytearray()
    index: List[Tuple[int, int, int]] = []
    blk_start = 0
    blk_first: Optional[int] = None
    for k, v in items:
        if blk_first is None:
            blk_first = k
        if v is None:
            out += _ENT.pack(k, TOMBSTONE)
        else:
            out += _ENT.pack(k, len(v)) + v
        if len(out) - blk_start >= DEFAULT_BLOCK_HINT.size:
            index.append((blk_first, blk_start, len(out) - blk_start))
            blk_start = len(out)
            blk_first = None
    if blk_first is not None:
        index.append((blk_first, blk_start, len(out) - blk_start))
    return bytes(out), index


class _BlockHint:
    """Mutable module default so tests can shrink block size."""

    def __init__(self, size: int):
        self.size = size


DEFAULT_BLOCK_HINT = _BlockHint(DEFAULT_BLOCK)


def decode_block(data: bytes) -> Iterator[Tuple[int, Optional[bytes]]]:
    o = 0
    while o + _ENT.size <= len(data):
        k, vlen = _ENT.unpack_from(data, o)
        o += _ENT.size
        if vlen == TOMBSTONE:
            yield k, None
        else:
            yield k, bytes(data[o : o + vlen])
            o += vlen


def search_block(data: bytes, key: int) -> Tuple[bool, Optional[bytes]]:
    """(found, value) — value None + found=True means tombstone."""
    for k, v in decode_block(data):
        if k == key:
            return True, v
        if k > key:
            break
    return False, None


class SSTable:
    def __init__(self, device: Device, path: str):
        self.device = device
        self.path = path
        self.fd: Optional[int] = None
        self.index: List[Tuple[int, int, int]] = []
        self.min_key = 0
        self.max_key = 0
        self.nitems = 0
        self.size_bytes = 0

    @staticmethod
    def build(device: Device, path: str, items: List[Tuple[int, Optional[bytes]]]) -> "SSTable":
        data, index = encode_entries(items)
        idx_bytes = b"".join(_IDXENT.pack(*e) for e in index)
        footer = _FOOTER.pack(SST_MAGIC, len(data), len(idx_bytes), len(index),
                              items[0][0], items[-1][0], len(items))
        fd = io.open(device, path, "w")
        io.pwrite(device, fd, data + idx_bytes + footer, 0)
        io.fsync(device, fd)
        io.close(device, fd)
        t = SSTable(device, path)
        t.open()
        return t

    def open(self) -> "SSTable":
        self.fd = io.open(self.device, self.path, "r")
        st = io.fstatat(self.device, self.path)
        self.size_bytes = st.st_size
        footer = io.pread(self.device, self.fd, _FOOTER.size, st.st_size - _FOOTER.size)
        magic, idx_off, idx_len, nblocks, mn, mx, n = _FOOTER.unpack(footer)
        if magic != SST_MAGIC:
            raise ValueError(f"{self.path}: bad sstable magic")
        raw = io.pread(self.device, self.fd, idx_len, idx_off)
        self.index = [_IDXENT.unpack_from(raw, i * _IDXENT.size) for i in range(nblocks)]
        self.min_key, self.max_key, self.nitems = mn, mx, n
        return self

    def close(self) -> None:
        if self.fd is not None:
            io.close(self.device, self.fd)
            self.fd = None

    def covers(self, key: int) -> bool:
        return self.min_key <= key <= self.max_key

    def block_for(self, key: int) -> Optional[Tuple[int, int]]:
        """In-memory index-block binary search (the Compute annotation of
        the pread_data node, Fig. 4c) -> (offset, length) or None."""
        if not self.covers(key):
            return None
        lo, hi = 0, len(self.index) - 1
        pos = -1
        while lo <= hi:
            mid = (lo + hi) // 2
            if self.index[mid][0] <= key:
                pos = mid
                lo = mid + 1
            else:
                hi = mid - 1
        if pos < 0:
            return None
        _, off, length = self.index[pos]
        return off, length

    def read_block(self, off: int, length: int) -> bytes:
        return io.pread(self.device, self.fd, length, off)

    def iter_all(self) -> Iterator[Tuple[int, Optional[bytes]]]:
        for _, off, length in self.index:
            yield from decode_block(self.read_block(off, length))


class LSMTree:
    """Levels of SSTables + memtable.  ``get`` is the foreactor-target path."""

    MANIFEST = "MANIFEST.json"

    def __init__(
        self,
        device: Device,
        root: str,
        memtable_limit_bytes: int = 1 << 21,  # ~2 MB tables, like LevelDB
        l0_limit: int = 4,
        level_ratio: int = 10,
        fsync_writes: bool = True,
    ):
        self.device = device
        self.root = root.rstrip("/")
        self.memtable_limit = memtable_limit_bytes
        self.l0_limit = l0_limit
        self.level_ratio = level_ratio
        self.fsync_writes = fsync_writes
        self.mem: Dict[int, Optional[bytes]] = {}
        self.mem_bytes = 0
        self.levels: List[List[SSTable]] = [[]]  # levels[0] newest-first
        self._next_file = 0
        self._lock = threading.RLock()

    # -- write path ------------------------------------------------------------
    def put(self, key: int, value: bytes) -> None:
        with self._lock:
            self.mem[key] = value
            self.mem_bytes += 12 + len(value)
            if self.mem_bytes >= self.memtable_limit:
                self.flush()

    def delete(self, key: int) -> None:
        with self._lock:
            self.mem[key] = None
            self.mem_bytes += 12
            if self.mem_bytes >= self.memtable_limit:
                self.flush()

    def _new_path(self) -> str:
        p = f"{self.root}/sst_{self._next_file:06d}.sst"
        self._next_file += 1
        return p

    def flush(self) -> None:
        """Dump the memtable as a new L0 table (newest first)."""
        with self._lock:
            if not self.mem:
                return
            items = sorted(self.mem.items())
            t = SSTable.build(self.device, self._new_path(), items)
            self.levels[0].insert(0, t)
            self.mem = {}
            self.mem_bytes = 0
            if len(self.levels[0]) > self.l0_limit:
                self.compact(0)
            self._write_manifest()

    def compact(self, level: int) -> None:
        """Merge `level` into `level+1` (full-level merge; newest wins)."""
        with self._lock:
            while len(self.levels) <= level + 1:
                self.levels.append([])
            src = self.levels[level]
            dst = self.levels[level + 1]
            merged: Dict[int, Optional[bytes]] = {}
            # oldest first so newer overwrites: dst oldest, then src oldest->newest
            for t in list(reversed(dst)) + list(reversed(src)):
                for k, v in t.iter_all():
                    merged[k] = v
            items = sorted(merged.items())
            # drop tombstones at the bottom level
            if level + 2 >= len(self.levels) or not self.levels[level + 2]:
                items = [(k, v) for k, v in items if v is not None]
            new_tables: List[SSTable] = []
            # split into ~memtable_limit chunks (non-overlapping by construction)
            chunk: List[Tuple[int, Optional[bytes]]] = []
            size = 0
            for k, v in items:
                chunk.append((k, v))
                size += 12 + (len(v) if v else 0)
                if size >= self.memtable_limit * 2:
                    new_tables.append(SSTable.build(self.device, self._new_path(), chunk))
                    chunk, size = [], 0
            if chunk:
                new_tables.append(SSTable.build(self.device, self._new_path(), chunk))
            for t in src + dst:
                t.close()
            self.levels[level] = []
            self.levels[level + 1] = new_tables
            if len(new_tables) > self.level_ratio ** (level + 1):
                self.compact(level + 1)

    def _write_manifest(self) -> None:
        m = {
            "next_file": self._next_file,
            "levels": [[t.path for t in lvl] for lvl in self.levels],
        }
        data = json.dumps(m).encode()
        fd = io.open(self.device, f"{self.root}/{self.MANIFEST}", "w")
        io.pwrite(self.device, fd, data, 0)
        if self.fsync_writes:
            io.fsync(self.device, fd)
        io.close(self.device, fd)

    @classmethod
    def open_existing(cls, device: Device, root: str, **kw) -> "LSMTree":
        """Re-open from MANIFEST (e.g. on a different Device wrapper)."""
        self = cls(device, root, **kw)
        fd = io.open(device, f"{root.rstrip('/')}/{cls.MANIFEST}", "r")
        st = io.fstatat(device, f"{root.rstrip('/')}/{cls.MANIFEST}")
        m = json.loads(io.pread(device, fd, st.st_size, 0))
        io.close(device, fd)
        self._next_file = m["next_file"]
        self.levels = [[SSTable(device, p).open() for p in lvl] for lvl in m["levels"]]
        return self

    # -- read path (the paper's Get) ---------------------------------------------
    def candidates(self, key: int) -> List[Tuple[SSTable, int, int]]:
        """The candidate pread list of Fig. 4(c): every covering L0 table
        newest-to-oldest, then at most one table per lower level; each with
        its data-block extent from the in-memory index lookup."""
        out: List[Tuple[SSTable, int, int]] = []
        with self._lock:
            for t in self.levels[0]:
                blk = t.block_for(key)
                if blk is not None:
                    out.append((t, blk[0], blk[1]))
            for lvl in self.levels[1:]:
                for t in lvl:  # non-overlapping: at most one covers
                    blk = t.block_for(key)
                    if blk is not None:
                        out.append((t, blk[0], blk[1]))
                        break
        return out

    def get(self, key: int) -> Optional[bytes]:
        """Point lookup — memtable, then the candidate pread chain with
        early exit on match (the weak edge of Fig. 4c)."""
        with self._lock:
            if key in self.mem:
                return self.mem[key]
        for t, off, length in self.candidates(key):
            data = io.pread(self.device, t.fd, length, off)
            found, v = search_block(data, key)
            if found:
                return v  # may be None (tombstone) — still an early exit
        return None

    def multi_get(self, keys: List[int]) -> List[Optional[bytes]]:
        """Batched point lookup: N keys, one scatter-gather plan.

        Issue phase: every key's candidate blocks are read through
        ``io.pread_async`` in round-robin order (all first candidates, then
        all second candidates, ...), so under an active ``lsm_multiget``
        session the whole fan-out is in flight before any result is
        demanded.  Harvest barrier: keys resolve in candidate order with the
        usual early exit; futures a key no longer needs are cancelled.  One
        key's read error does not abandon the others — every key is
        harvested first, then the first error (if any) is re-raised.

        Without an active session the futures come back already resolved,
        making this exactly N sequential ``get``\\s — the conformance
        oracle.  The flattened candidate order here must match
        ``repro.store.plugins.capture_lsm_multiget``, which drives the
        generated graph's pread loop over the same extents.
        """
        results: List[Optional[bytes]] = [None] * len(keys)
        per_key: List[List[Tuple[SSTable, int, int]]] = []
        with self._lock:
            from_mem = {i: self.mem[k] for i, k in enumerate(keys)
                        if k in self.mem}
        for i, k in enumerate(keys):
            # memtable hits (tombstones included) take no candidates
            per_key.append([] if i in from_mem else self.candidates(k))
        futs: List[List] = [[None] * len(c) for c in per_key]
        width = max((len(c) for c in per_key), default=0)
        for j in range(width):
            for i, cands in enumerate(per_key):
                if j < len(cands):
                    t, off, length = cands[j]
                    futs[i][j] = io.pread_async(self.device, t.fd,
                                                length, off)
        first_error: Optional[BaseException] = None
        for i, k in enumerate(keys):
            if i in from_mem:
                results[i] = from_mem[i]
                continue
            found_at = len(per_key[i])
            for j in range(len(per_key[i])):
                try:
                    data = futs[i][j].result()
                except BaseException as e:
                    if first_error is None:
                        first_error = e
                    continue  # the other keys must still resolve
                found, v = search_block(data, k)
                if found:
                    results[i] = v
                    found_at = j
                    break
            for j in range(found_at + 1, len(per_key[i])):
                futs[i][j].cancel()  # still-queued tail reads
        if first_error is not None:
            raise first_error
        return results

    # -- misc -------------------------------------------------------------------
    def table_count(self) -> int:
        return sum(len(l) for l in self.levels)

    def close(self) -> None:
        for lvl in self.levels:
            for t in lvl:
                t.close()
