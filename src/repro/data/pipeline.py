"""Deterministic sharded token pipeline (see package docstring).

On a :class:`repro.core.device.ShardedDevice` the record shards are placed on
distinct sub-devices (``Device.place``), so a batch's speculated preads —
whose record permutation is known at activation time — fan out across
per-device queue pairs via the multi-queue backend instead of serializing on
one device (docs/ARCHITECTURE.md, "Sharded multi-device substrate").
"""

from __future__ import annotations

import queue
import threading
from dataclasses import dataclass
from typing import Dict, List, Optional, Tuple

import numpy as np

from repro.core.api import Foreactor, io
from repro.core.device import Device
from repro.core.patterns import register_patterns
from repro.store.recordio import HEADER, RecordShardReader, RecordShardWriter


@dataclass(frozen=True)
class DataConfig:
    seq_len: int
    batch_size: int  # per-process batch (global batch / data-parallel hosts)
    seed: int = 0
    dtype: str = "<i4"  # token storage dtype
    #: streaming order: epochs iterate records in storage order (identity
    #: permutation) instead of shuffling.  Consecutive records of a shard
    #: are byte-adjacent in its file, so a batch's pread extents form the
    #: same-fd adjacent runs the I/O plane's extent coalescer fuses into
    #: MB-scale super-reads — the bandwidth-oriented ingestion mode
    #: (evaluation sweeps, dataset conversion, cache warmup).
    sequential: bool = False

    @property
    def record_tokens(self) -> int:
        # +1 token so inputs/labels are a shift of the same record
        return self.seq_len + 1

    @property
    def record_bytes(self) -> int:
        return self.record_tokens * np.dtype(self.dtype).itemsize


def write_synthetic_dataset(
    device: Device, root: str, cfg: DataConfig, num_shards: int,
    records_per_shard: int, vocab_size: int, seed: int = 1234,
) -> List[str]:
    """Generate token shards (synthetic LM data for tests/examples)."""
    rng = np.random.default_rng(seed)
    paths = []
    for s in range(num_shards):
        # shard s lives on sub-device s % N of a ShardedDevice (identity on
        # flat devices) so independent record reads hit independent devices
        path = device.place(f"{root.rstrip('/')}/shard_{s:05d}.rio", hint=s)
        w = RecordShardWriter(device, path, cfg.record_bytes)
        toks = rng.integers(0, vocab_size, size=(records_per_shard, cfg.record_tokens),
                            dtype=np.int32)
        for r in range(records_per_shard):
            w.append(toks[r].astype(cfg.dtype).tobytes())
        w.close()
        paths.append(path)
    return paths


class ShardedTokenDataset:
    """A set of record shards with a global deterministic record order."""

    def __init__(self, device: Device, paths: List[str]):
        self.device = device
        self.readers = [RecordShardReader(device, p) for p in paths]
        counts = [len(r) for r in self.readers]
        self.cum = np.concatenate([[0], np.cumsum(counts)])
        self.total = int(self.cum[-1])
        rb = {r.record_size for r in self.readers}
        if len(rb) != 1:
            raise ValueError("all shards must share a record size")
        self.record_bytes = rb.pop()

    def locate(self, global_idx: int) -> Tuple[int, int]:
        s = int(np.searchsorted(self.cum, global_idx, side="right")) - 1
        return s, int(global_idx - self.cum[s])

    def extent(self, global_idx: int) -> Tuple[int, int, int]:
        """(fd, size, offset) of a record — the pread arguments."""
        s, li = self.locate(global_idx)
        r = self.readers[s]
        return r.fd, self.record_bytes, r.offset_of(li)

    def close(self) -> None:
        for r in self.readers:
            r.close()


class TokenBatchLoader:
    """Deterministic, resumable batch loader with explicit-speculation
    record prefetch.

    Batch ``(epoch, step)`` reads records
    ``perm(seed, epoch)[step*B : (step+1)*B]`` — so ComputeArgs of every
    future pread is known at activation time and the engine keeps
    ``depth`` reads in flight across the whole batch (and, with the
    background double-buffer thread, across batch boundaries too).
    """

    def __init__(self, dataset: ShardedTokenDataset, cfg: DataConfig,
                 fa: Optional[Foreactor] = None, prefetch: bool = True):
        self.ds = dataset
        self.cfg = cfg
        self.fa = fa if fa is not None else Foreactor(device=dataset.device, depth=32)
        # precompile: the first batch load is on the training critical path
        register_patterns(self.fa, precompile=True)
        self.prefetch = prefetch
        self.steps_per_epoch = self.ds.total // cfg.batch_size
        self._perm_cache: Dict[int, np.ndarray] = {}
        # persistent double-buffer worker: one long-lived thread keeps one
        # live backend (queue pairs are per-thread), instead of paying
        # worker-pool construction on every batch
        self._bg: Optional[threading.Thread] = None
        self._bg_req: "queue.Queue[Optional[Tuple[int, int]]]" = queue.Queue()
        self._bg_done = threading.Event()
        self._bg_pending = False
        self._bg_out: Optional[Tuple[Tuple[int, int], np.ndarray]] = None

    def perm(self, epoch: int) -> np.ndarray:
        p = self._perm_cache.get(epoch)
        if p is None:
            if self.cfg.sequential:
                p = np.arange(self.ds.total)
            else:
                rng = np.random.default_rng((self.cfg.seed, epoch))
                p = rng.permutation(self.ds.total)
            self._perm_cache = {epoch: p}  # keep only the active epoch
        return p

    def batch_indices(self, epoch: int, step: int) -> np.ndarray:
        if not (0 <= step < self.steps_per_epoch):
            raise IndexError(f"step {step} out of range")
        B = self.cfg.batch_size
        return self.perm(epoch)[step * B : (step + 1) * B]

    def _read_batch(self, epoch: int, step: int) -> np.ndarray:
        idx = self.batch_indices(epoch, step)
        extents = [self.ds.extent(int(i)) for i in idx]

        if self.prefetch:
            @self.fa.wrap("pread_extents", lambda extents: {"extents": extents})
            def _read(extents):
                return [io.pread(self.ds.device, fd, n, off) for fd, n, off in extents]
            raw = _read(extents)
        else:
            raw = [io.pread(self.ds.device, fd, n, off) for fd, n, off in extents]
        toks = np.stack([np.frombuffer(r, dtype=self.cfg.dtype) for r in raw])
        return toks.astype(np.int32)

    def load(self, epoch: int, step: int) -> Dict[str, np.ndarray]:
        """Return {'tokens': [B,S], 'labels': [B,S]} for (epoch, step).

        If the background double-buffer already holds this batch, it is
        returned immediately and the next batch starts loading.
        """
        rec = None
        if self._bg_pending:
            self._bg_done.wait()
            self._bg_pending = False
            if self._bg_out is not None and self._bg_out[0] == (epoch, step):
                rec = self._bg_out[1]
            self._bg_out = None
        if rec is None:
            rec = self._read_batch(epoch, step)
        if self.prefetch:
            ns, ne = step + 1, epoch
            if ns >= self.steps_per_epoch:
                ns, ne = 0, epoch + 1
            self._ensure_worker()
            self._bg_done.clear()
            self._bg_pending = True
            self._bg_req.put((ne, ns))
        return {"tokens": rec[:, :-1], "labels": rec[:, 1:]}

    def _ensure_worker(self) -> None:
        if self._bg is not None:
            return

        def loop():
            while True:
                item = self._bg_req.get()
                if item is None:
                    return
                ep, st = item
                try:
                    self._bg_out = ((ep, st), self._read_batch(ep, st))
                except BaseException:
                    self._bg_out = None
                finally:
                    self._bg_done.set()

        self._bg = threading.Thread(target=loop, name="token-prefetch", daemon=True)
        self._bg.start()

    def close(self) -> None:
        if self._bg_pending:
            self._bg_done.wait()
            self._bg_pending = False
            self._bg_out = None
        if self._bg is not None:
            self._bg_req.put(None)
            self._bg.join(timeout=5)
            self._bg = None
