"""Training input pipeline with explicit-speculation prefetch.

Tokenized sequences live in fixed-record shards (:mod:`repro.store.recordio`).
Batch composition is a pure function of (seed, epoch, step), so the exact
``pread`` extents of any future batch are computable ahead of time — the
textbook case for a foreaction graph (regular I/O loop, paper §4.1), and
the reason *explicit* speculation needs no prediction machinery here.

The loader is deterministic and resumable from (epoch, step) alone, which
is what makes checkpoint/restart and elastic rescaling exact.
"""

from .pipeline import DataConfig, ShardedTokenDataset, TokenBatchLoader, write_synthetic_dataset

__all__ = ["DataConfig", "ShardedTokenDataset", "TokenBatchLoader", "write_synthetic_dataset"]
