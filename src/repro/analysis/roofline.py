"""Three-term roofline model for TPU v5e (target hardware).

    compute term    = FLOPs / (chips * peak_FLOP/s)
    memory term     = HBM bytes / (chips * HBM_bw)
    collective term = collective bytes / (chips * link_bw)

All inputs here are *per-device* (the SPMD module is per-partition), so
each term reduces to per-device quantity / per-chip rate.  The dominant
term is the step-time lower bound; its fraction of the sum of terms says
how bound the cell is.
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, Optional


@dataclass(frozen=True)
class HW:
    """TPU v5e per-chip constants (assignment-specified)."""

    peak_flops: float = 197e12     # bf16 FLOP/s
    hbm_bw: float = 819e9          # bytes/s
    link_bw: float = 50e9          # bytes/s per ICI link


@dataclass
class RooflineTerms:
    compute_s: float
    memory_s: float
    collective_s: float
    dominant: str
    bound_s: float                 # max of the three = step-time lower bound
    model_flops_per_dev: Optional[float] = None
    useful_ratio: Optional[float] = None  # MODEL_FLOPS / HLO_FLOPs

    def to_dict(self) -> Dict:
        return {
            "compute_s": self.compute_s,
            "memory_s": self.memory_s,
            "collective_s": self.collective_s,
            "dominant": self.dominant,
            "bound_s": self.bound_s,
            "model_flops_per_dev": self.model_flops_per_dev,
            "useful_ratio": self.useful_ratio,
        }


def roofline(flops_per_dev: float, hbm_bytes_per_dev: float,
             coll_bytes_per_dev: float, hw: HW = HW(),
             model_flops_per_dev: Optional[float] = None) -> RooflineTerms:
    c = flops_per_dev / hw.peak_flops
    m = hbm_bytes_per_dev / hw.hbm_bw
    l = coll_bytes_per_dev / hw.link_bw
    terms = {"compute": c, "memory": m, "collective": l}
    dom = max(terms, key=terms.get)
    return RooflineTerms(
        compute_s=c, memory_s=m, collective_s=l, dominant=dom,
        bound_s=terms[dom],
        model_flops_per_dev=model_flops_per_dev,
        useful_ratio=(model_flops_per_dev / flops_per_dev
                      if model_flops_per_dev and flops_per_dev else None),
    )


def roofline_from_report(report: Dict, hw: HW = HW(),
                         model_flops_per_dev: Optional[float] = None) -> RooflineTerms:
    """Build terms from a dry-run JSON report (hlo-analyzed fields)."""
    h = report["hlo"]
    return roofline(h["dot_flops"], h["dot_bytes"], h["collective_bytes"],
                    hw, model_flops_per_dev)


def model_flops(cfg, shape, mode: str) -> float:
    """Analytic MODEL_FLOPS: 6*N_active*D_tokens (train) / 2*N_active*D
    (prefill) / 2*N_active per token (decode), plus attention terms.

    N_active counts embedding-free active params (MoE: top-k + shared
    experts only).
    """
    import numpy as np

    D = cfg.d_model
    L = cfg.n_layers
    # per-layer active params (rough standard accounting)
    n_active = 0.0
    for i, kind in enumerate(cfg.blocks):
        if kind in ("attn", "shared_attn"):
            hd, H, KV = cfg.hd, cfg.n_heads, cfg.n_kv_heads
            n_active += D * hd * (H + 2 * KV) + H * hd * D
        elif kind == "mla":
            m = cfg.mla
            n_active += (D * m.q_lora + m.q_lora * cfg.n_heads * (m.qk_nope + m.qk_rope)
                         + D * (m.kv_lora + m.qk_rope)
                         + m.kv_lora * cfg.n_heads * (m.qk_nope + m.v_head)
                         + cfg.n_heads * m.v_head * D)
        elif kind == "mamba2":
            mc = cfg.mamba
            Din = mc.d_inner(D)
            n_active += D * (2 * Din + 2 * mc.ngroups * mc.d_state
                             + mc.n_heads(D)) + Din * D
        elif kind == "rwkv6":
            n_active += 4 * D * D + D * D + D * cfg.d_ff + cfg.d_ff * D + D * D
        # ffn
        if kind in ("attn", "shared_attn", "mla") and cfg.moe is not None:
            mm = cfg.moe
            if i >= mm.first_dense_layers:
                n_active += 3 * D * mm.d_expert * (mm.top_k + mm.num_shared)
            else:
                n_active += 3 * D * (mm.dense_d_ff or cfg.d_ff)
        elif kind in ("attn", "shared_attn"):
            mult = 2 if cfg.mlp_act == "gelu_mlp" else 3
            n_active += mult * D * cfg.d_ff
    if cfg.enc_dec is not None:
        # encoder layers + decoder cross-attention
        n_active += cfg.enc_dec.n_enc_layers * (4 * D * D + 2 * D * cfg.d_ff)
        n_active += L * 4 * D * D  # cross attn
    n_active += D * cfg.padded_vocab  # lm head

    tokens = shape.global_batch * (shape.seq_len if mode != "decode" else 1)
    if mode == "train":
        flops = 6.0 * n_active * tokens
        # causal attention scores+values: 6 * (2 * S^2/2 * H * hd) per seq
        attn_layers = sum(1 for k in cfg.blocks if k in ("attn", "shared_attn", "mla"))
        hd_eff = (cfg.mla.qk_nope + cfg.mla.qk_rope + cfg.mla.v_head) / 2 if cfg.mla \
            else cfg.hd
        flops += 6.0 * attn_layers * shape.global_batch * \
            (shape.seq_len ** 2) * cfg.n_heads * hd_eff
    elif mode == "prefill":
        flops = 2.0 * n_active * tokens
        attn_layers = sum(1 for k in cfg.blocks if k in ("attn", "shared_attn", "mla"))
        hd_eff = (cfg.mla.qk_nope + cfg.mla.qk_rope + cfg.mla.v_head) / 2 if cfg.mla \
            else cfg.hd
        flops += 2.0 * attn_layers * shape.global_batch * \
            (shape.seq_len ** 2) * cfg.n_heads * hd_eff
    else:  # decode: one token, attention over the cache
        flops = 2.0 * n_active * tokens
        attn_layers = sum(1 for k in cfg.blocks if k in ("attn", "shared_attn", "mla"))
        hd_eff = (cfg.mla.qk_nope + cfg.mla.qk_rope + cfg.mla.v_head) / 2 if cfg.mla \
            else cfg.hd
        flops += 2.0 * attn_layers * shape.global_batch * 2 * \
            shape.seq_len * cfg.n_heads * hd_eff
    return flops
