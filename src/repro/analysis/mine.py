"""Foreaction-graph mining from syscall traces — the *speculate* half of
observe-then-speculate.

Hand-writing foreaction graphs is the paper's stated adoption cost.  This
module removes it for the common loop shapes: one or more recorded traces
(:class:`repro.core.trace.Trace`) are folded into a directly-follows graph
and emitted as a ready-to-register ``ForeactionGraph``:

1. **Skeleton folding** — each trace's syscall-kind string is collapsed by
   tandem-repeat detection: a block repeating ``MIN_REPS`` or more times
   becomes a loop segment (emitting an epoch counter), everything else stays
   a literal node.  All traces must align against one skeleton; traces that
   diverge structurally are refused (``UnminableTrace``).
2. **Argument provenance** — for every node and argument position, the
   concrete values across (trace, epoch) samples are explained by a small
   provenance language: invocation input (``ctx[key]``), literal constant,
   affine in the epoch counter, element/attribute of a prior node's result,
   path join of a base and a listing element, clamped residual
   (``min(chunk, total - chunk*ep)``), or the raw buffer of the immediately
   preceding read (→ ``FromNode`` + link flag, the paper's Fig. 4b chain).
   A value no detector can explain is refused.
3. **Loop-count provenance** — iteration counts are explained the same way
   (``len`` of a producer listing, ``len`` of a ctx list, ``ceil(total /
   chunk)``, constant).  Counts that *vary* under a provable upper bound
   become early-exit loops: the body's closing edge is marked *weak*, so the
   engine speculates to the bound but never pre-issues non-pure nodes past
   it (paper §3.3).
4. **Validation** — :func:`replay_trace` replays a trace serially against a
   graph, demanding that every choice is decidable, every argument
   computable and equal to the recorded one, and the end state reachable
   (End, or a weak edge permitting early exit).  :func:`mine_and_validate`
   holds out the last trace and refuses graphs that cannot replay it
   (``UnsoundGraph``) — the soundness gate for ``auto_graph`` wrapping.

``CLOSE``/``FSYNC`` nodes get a *harvest barrier*: their ``ComputeArgs``
only becomes ready once every earlier node has been harvested, so the miner
never schedules an fd teardown concurrently with speculated I/O it cannot
prove independent (the hand-written plugins simply omit those trailing
calls; the mined graphs track them but serve them at the frontier).

Cross-references: docs/AUTHORING.md ("Mining a graph from traces") walks
through this module end-to-end; docs/GLOSSARY.md defines *directly-follows
graph*, *miner*, *validator*, *argument provenance*.
"""

from __future__ import annotations

import math
from dataclasses import dataclass, field
from typing import Any, Dict, List, Optional, Sequence, Tuple

from repro.core.graph import (BranchNode, Edge, ForeactionGraph, FromNode,
                              GraphBuilder, SyscallNode)
from repro.core.plan import END, compile_plan
from repro.core.syscalls import Sys
from repro.core.trace import Trace, TraceEvent

#: a repeated block must occur at least this many times to fold into a loop
MIN_REPS = 3
#: longest loop body (in syscall nodes) the folder searches for
MAX_PERIOD = 4

#: syscalls that tear down or flush an fd — mined nodes of these kinds get a
#: harvest barrier (never pre-issued ahead of unharvested predecessors)
BARRIER_KINDS = frozenset({Sys.CLOSE, Sys.FSYNC, Sys.UNLINK})


class UnminableTrace(RuntimeError):
    """The trace set cannot be folded into one sound skeleton."""


class ReplayMismatch(RuntimeError):
    """A trace does not replay exactly against a graph."""


class UnsoundGraph(RuntimeError):
    """A mined graph failed held-out replay validation."""


#: sentinel: a provenance whose producer has not been harvested yet
NOT_READY = object()


# ---------------------------------------------------------------------------
# Argument provenance language
# ---------------------------------------------------------------------------
class Prov:
    """Provenance of one argument value: how to recompute it from the
    invocation ctx and prior results, at any epoch."""

    def eval(self, ctx: Dict[str, Any], ep: Tuple[int, ...]) -> Any:
        raise NotImplementedError

    def describe(self) -> str:
        raise NotImplementedError


def _mined(ctx: Dict[str, Any]) -> Dict[str, Any]:
    return ctx.setdefault("__mined__", {})


@dataclass(frozen=True)
class PConst(Prov):
    """A literal recorded in every training trace.  Sound only if the value
    is genuinely invocation-independent — held-out validation is the check."""

    value: Any

    def eval(self, ctx, ep):
        return self.value

    def describe(self):
        v = self.value
        if isinstance(v, bytes) and len(v) > 16:
            return f"literal <{len(v)} bytes>"
        return f"literal {v!r}"


@dataclass(frozen=True)
class PCtx(Prov):
    """An invocation input: ``ctx[key]``."""

    key: str

    def eval(self, ctx, ep):
        return ctx[self.key] if self.key in ctx else NOT_READY

    def describe(self):
        return f"ctx[{self.key!r}]"


@dataclass(frozen=True)
class PLinear(Prov):
    """Affine in one epoch counter: ``a*ep + b`` (offsets, indices)."""

    loop: int
    a: int
    b: int

    def eval(self, ctx, ep):
        return self.a * ep[self.loop] + self.b

    def describe(self):
        return f"{self.a}*ep{self.loop} + {self.b}"


@dataclass(frozen=True)
class PResult(Prov):
    """The harvested result of an epoch-independent node (an fd, a stat)."""

    node: str

    def eval(self, ctx, ep):
        m = _mined(ctx)
        return m[self.node] if self.node in m else NOT_READY

    def describe(self):
        return f"result({self.node!r})"


@dataclass(frozen=True)
class PAttr(Prov):
    """An attribute of a producer's result (``st_size`` of a stat)."""

    node: str
    attr: str

    def eval(self, ctx, ep):
        m = _mined(ctx)
        if self.node not in m:
            return NOT_READY
        return getattr(m[self.node], self.attr)

    def describe(self):
        return f"result({self.node!r}).{self.attr}"


@dataclass(frozen=True)
class PElem(Prov):
    """Element of a producer's list result, indexed by an epoch counter
    (the du shape: ``entries[ep]`` from the getdents listing)."""

    node: str
    loop: int

    def eval(self, ctx, ep):
        m = _mined(ctx)
        if self.node not in m:
            return NOT_READY
        seq = m[self.node]
        i = ep[self.loop]
        return seq[i] if i < len(seq) else NOT_READY

    def describe(self):
        return f"result({self.node!r})[ep{self.loop}]"


@dataclass(frozen=True)
class PCtxElem(Prov):
    """Element of a ctx list, indexed by an epoch counter; ``index`` picks a
    tuple component (``ctx['extents'][ep][2]``)."""

    key: str
    loop: int
    index: Optional[int] = None

    def eval(self, ctx, ep):
        if self.key not in ctx:
            return NOT_READY
        seq = ctx[self.key]
        i = ep[self.loop]
        if i >= len(seq):
            return NOT_READY
        v = seq[i]
        return v if self.index is None else v[self.index]

    def describe(self):
        sub = "" if self.index is None else f"[{self.index}]"
        return f"ctx[{self.key!r}][ep{self.loop}]{sub}"


@dataclass(frozen=True)
class PPathJoin(Prov):
    """``f"{base}/{listing[ep]}"`` — a path built from a directory and one
    of its entries (the du fstat argument)."""

    base: Prov
    node: str
    loop: int

    def eval(self, ctx, ep):
        base = self.base.eval(ctx, ep)
        if base is NOT_READY:
            return NOT_READY
        m = _mined(ctx)
        if self.node not in m:
            return NOT_READY
        seq = m[self.node]
        i = ep[self.loop]
        return f"{base}/{seq[i]}" if i < len(seq) else NOT_READY

    def describe(self):
        return f"{self.base.describe()} + '/' + result({self.node!r})[ep{self.loop}]"


@dataclass(frozen=True)
class PClampedResidual(Prov):
    """``min(chunk, total - chunk*ep)`` — the classic chunked-copy size
    whose final chunk is the remainder."""

    chunk: int
    total: Prov
    loop: int

    def eval(self, ctx, ep):
        total = self.total.eval(ctx, ep)
        if total is NOT_READY:
            return NOT_READY
        return min(self.chunk, total - self.chunk * ep[self.loop])

    def describe(self):
        return f"min({self.chunk}, {self.total.describe()} - {self.chunk}*ep{self.loop})"


@dataclass(frozen=True)
class PLink(Prov):
    """The raw buffer of the immediately preceding read at the same epoch —
    becomes a ``FromNode`` and sets the producer's link flag (Fig. 4b)."""

    node: str

    def eval(self, ctx, ep):
        return FromNode(self.node)

    def describe(self):
        return f"buffer_of({self.node!r})"


# ---------------------------------------------------------------------------
# Loop-count provenance
# ---------------------------------------------------------------------------
class CountProv:
    def value(self, ctx: Dict[str, Any]) -> Any:
        raise NotImplementedError

    def describe(self) -> str:
        raise NotImplementedError


@dataclass(frozen=True)
class CLen(CountProv):
    """``len(result(node))`` — loop over a producer's listing."""

    node: str

    def value(self, ctx):
        m = _mined(ctx)
        return len(m[self.node]) if self.node in m else NOT_READY

    def describe(self):
        return f"len(result({self.node!r}))"


@dataclass(frozen=True)
class CCtxLen(CountProv):
    """``len(ctx[key])`` — loop over an invocation-input list."""

    key: str

    def value(self, ctx):
        return len(ctx[self.key]) if self.key in ctx else NOT_READY

    def describe(self):
        return f"len(ctx[{self.key!r}])"


@dataclass(frozen=True)
class CCeil(CountProv):
    """``ceil(total / chunk)`` — chunked loop over a byte range."""

    total: Prov
    chunk: int

    def value(self, ctx):
        total = self.total.eval(ctx, ())
        if total is NOT_READY:
            return NOT_READY
        return max(0, -(-total // self.chunk))

    def describe(self):
        return f"ceil({self.total.describe()} / {self.chunk})"


@dataclass(frozen=True)
class CConst(CountProv):
    """A constant count recorded in every training trace (trace literal)."""

    n: int

    def value(self, ctx):
        return self.n

    def describe(self):
        return f"literal {self.n}"


# ---------------------------------------------------------------------------
# Skeleton: tandem-repeat folding + alignment
# ---------------------------------------------------------------------------
@dataclass
class LitSeg:
    sc: Sys


@dataclass
class LoopSeg:
    body: List[Sys]
    #: per-trace iteration counts, filled during alignment
    counts: List[int] = field(default_factory=list)


def _fold(kinds: List[Sys]) -> List[Any]:
    """Collapse tandem repeats (period <= MAX_PERIOD, >= MIN_REPS reps)
    into loop segments, left to right, smallest period first."""
    segs: List[Any] = []
    i, n = 0, len(kinds)
    while i < n:
        folded = False
        for p in range(1, MAX_PERIOD + 1):
            if i + p > n:
                break
            r = 1
            while kinds[i + r * p : i + (r + 1) * p] == kinds[i : i + p]:
                r += 1
            if r >= MIN_REPS:
                segs.append(LoopSeg(body=kinds[i : i + p]))
                i += p * r
                folded = True
                break
        if not folded:
            segs.append(LitSeg(sc=kinds[i]))
            i += 1
    return segs


def _align(kinds: List[Sys], segs: List[Any]) -> List[int]:
    """Fit a trace's kind string to a skeleton; returns per-loop counts.
    Raises UnminableTrace on structural divergence."""
    counts: List[int] = []
    i = 0
    for seg in segs:
        if isinstance(seg, LitSeg):
            if i >= len(kinds) or kinds[i] is not seg.sc:
                raise UnminableTrace(
                    f"trace diverges at event {i}: expected {seg.sc}, "
                    f"got {kinds[i] if i < len(kinds) else 'end-of-trace'}"
                )
            i += 1
        else:
            p = len(seg.body)
            c = 0
            while kinds[i : i + p] == seg.body:
                c += 1
                i += p
            counts.append(c)
    if i != len(kinds):
        raise UnminableTrace(
            f"trace has {len(kinds) - i} events beyond the skeleton "
            "(structural divergence the miner cannot fold)"
        )
    return counts


# ---------------------------------------------------------------------------
# Node metadata assembled during mining
# ---------------------------------------------------------------------------
@dataclass
class _NodeInfo:
    name: str
    sc: Sys
    seg_idx: int
    loop: Optional[int]  # loop ordinal, None for literal nodes
    body_pos: int = 0
    #: samples: (trace_idx, epoch_in_loop, event)
    samples: List[Tuple[int, int, TraceEvent]] = field(default_factory=list)
    provs: List[Prov] = field(default_factory=list)
    link: bool = False
    barrier: bool = False


@dataclass
class _LoopInfo:
    ordinal: int
    seg_idx: int
    counts: List[int]
    count_prov: Optional[CountProv] = None
    weak: bool = False
    head: bool = False


@dataclass
class MinedGraph:
    """A mined ``ForeactionGraph`` plus the evidence it was built from."""

    name: str
    graph: ForeactionGraph
    nodes: List[_NodeInfo]
    loops: List[_LoopInfo]
    num_traces: int

    def builder(self):
        """A zero-arg builder suitable for ``Foreactor.register``.  Closes
        over the graph alone — not the MinedGraph, whose evidence samples
        pin every recorded I/O buffer."""
        graph = self.graph
        return lambda: graph

    def signature(self) -> str:
        """Deterministic structural + provenance description; two minings of
        the same trace set must produce identical signatures."""
        lines = [f"mined graph {self.name!r} from {self.num_traces} trace(s)"]
        for nd in self.nodes:
            where = f"loop{nd.loop}[{nd.body_pos}]" if nd.loop is not None else "literal"
            flags = "".join(
                [" link" if nd.link else "", " barrier" if nd.barrier else ""]
            )
            args = ", ".join(p.describe() for p in nd.provs)
            lines.append(f"  {nd.name}: {nd.sc.value} ({where}){flags} <- ({args})")
        for lp in self.loops:
            kind = "early-exit (weak)" if lp.weak else "strong"
            head = " +head" if lp.head else ""
            lines.append(
                f"  loop{lp.ordinal}: count = {lp.count_prov.describe()}, "
                f"{kind}{head}"
            )
        return "\n".join(lines)

    def explain(self) -> str:
        return self.signature()

    def to_dot(self) -> str:
        return self.graph.to_dot()


# ---------------------------------------------------------------------------
# Provenance fitting
# ---------------------------------------------------------------------------
def _all_equal(values: Sequence[Any]) -> bool:
    first = values[0]
    return all(v == first for v in values[1:])


def _fit_per_trace_constant(
    per_trace: Dict[int, Any], ctxs: List[Dict[str, Any]],
    prod_res: Dict[str, Dict[int, Any]], earlier: List[str],
) -> Optional[Prov]:
    """Explain a value that is constant within each trace but may differ
    across traces: ctx input, producer result, or producer attribute."""
    for key in sorted(ctxs[0].keys()):
        if all(key in ctxs[t] and ctxs[t][key] == v for t, v in per_trace.items()):
            return PCtx(key)
    for node in earlier:
        res = prod_res.get(node, {})
        if all(t in res and res[t] == v for t, v in per_trace.items()):
            return PResult(node)
        for attr in ("st_size",):
            try:
                if all(
                    t in res and getattr(res[t], attr) == v
                    for t, v in per_trace.items()
                ):
                    return PAttr(node, attr)
            except AttributeError:
                continue
    vals = list(per_trace.values())
    if _all_equal(vals):
        return PConst(vals[0])
    return None


def _fit_arg(
    node: _NodeInfo,
    pos: int,
    ctxs: List[Dict[str, Any]],
    prod_res: Dict[str, Dict[int, Any]],
    earlier: List[str],
    body_nodes: List[_NodeInfo],
    body_results: Dict[str, Dict[Tuple[int, int], Any]],
) -> Prov:
    """Explain argument ``pos`` of ``node`` across all samples, or raise."""
    samples = [(t, k, ev.args[pos]) for (t, k, ev) in node.samples]
    values = [v for (_t, _k, v) in samples]

    # 1. constant within each trace (covers globally-constant too)
    per_trace: Dict[int, Any] = {}
    per_trace_const = True
    for t, _k, v in samples:
        if t in per_trace:
            if per_trace[t] != v:
                per_trace_const = False
                break
        else:
            per_trace[t] = v
    if per_trace_const:
        prov = _fit_per_trace_constant(per_trace, ctxs, prod_res, earlier)
        if prov is not None:
            return prov
        raise UnminableTrace(
            f"node {node.name!r} arg {pos}: per-invocation value with no "
            f"ctx/producer provenance ({sorted(map(repr, set(map(repr, per_trace.values()))))})"
        )

    # epoch-varying: only meaningful inside a loop
    if node.loop is None:
        raise UnminableTrace(
            f"node {node.name!r} arg {pos}: varying value outside a loop"
        )
    loop = node.loop

    # 2. element of a ctx list (whole element or tuple component) — input-
    # derived provenance is tried before epoch arithmetic: fds and the like
    # often form accidental arithmetic sequences that would misgeneralize
    for key in sorted(ctxs[0].keys()):
        seqs = {t: ctxs[t].get(key) for t in {t for t, _k, _v in samples}}
        if not all(isinstance(s, (list, tuple)) for s in seqs.values()):
            continue
        if all(k < len(seqs[t]) and seqs[t][k] == v for t, k, v in samples):
            return PCtxElem(key, loop)
        elem0 = seqs[next(iter(seqs))]
        width = len(elem0[0]) if elem0 and isinstance(elem0[0], (list, tuple)) else 0
        for j in range(width):
            if all(
                k < len(seqs[t])
                and isinstance(seqs[t][k], (list, tuple))
                and len(seqs[t][k]) > j
                and seqs[t][k][j] == v
                for t, k, v in samples
            ):
                return PCtxElem(key, loop, j)

    # 3. element of a producer's listing result
    for prod in earlier:
        res = prod_res.get(prod, {})
        if not res or not all(isinstance(r, (list, tuple)) for r in res.values()):
            continue
        if all(
            t in res and k < len(res[t]) and res[t][k] == v for t, k, v in samples
        ):
            return PElem(prod, loop)

    # 4. path join: f"{base}/{listing[ep]}"
    if all(isinstance(v, str) for v in values):
        for prod in earlier:
            res = prod_res.get(prod, {})
            if not res or not all(isinstance(r, (list, tuple)) for r in res.values()):
                continue
            bases: Dict[int, str] = {}
            ok = True
            for t, k, v in samples:
                if t not in res or k >= len(res[t]):
                    ok = False
                    break
                tail = f"/{res[t][k]}"
                if not v.endswith(tail):
                    ok = False
                    break
                base = v[: -len(tail)]
                if bases.setdefault(t, base) != base:
                    ok = False
                    break
            if ok:
                base_prov = _fit_per_trace_constant(bases, ctxs, prod_res, earlier)
                if base_prov is not None:
                    return PPathJoin(base_prov, prod, loop)

    # 5. affine in the epoch counter (offsets, indices)
    if all(isinstance(v, int) and not isinstance(v, bool) for v in values):
        by_trace: Dict[int, List[Tuple[int, int]]] = {}
        for t, k, v in samples:
            by_trace.setdefault(t, []).append((k, v))
        fit: Optional[Tuple[int, int]] = None
        for pts in by_trace.values():
            if len(pts) >= 2:
                (k0, v0), (k1, v1) = pts[0], pts[1]
                if k1 != k0 and (v1 - v0) % (k1 - k0) == 0:
                    a = (v1 - v0) // (k1 - k0)
                    fit = (a, v0 - a * k0)
                break
        if fit is not None and all(
            v == fit[0] * k + fit[1] for (_t, k, v) in samples
        ):
            return PLinear(loop, fit[0], fit[1])
        # 6. clamped residual: min(chunk, total - chunk*ep)
        chunk = max(values)
        if chunk > 0:
            totals: Dict[int, int] = {}
            ok = True
            for t, pts in by_trace.items():
                pts = sorted(pts)
                last_k, last_v = pts[-1]
                total = chunk * last_k + last_v
                if not all(v == min(chunk, total - chunk * k) for k, v in pts):
                    ok = False
                    break
                totals[t] = total
            if ok:
                base = _fit_per_trace_constant(totals, ctxs, prod_res, earlier)
                if base is not None:
                    return PClampedResidual(chunk, base, loop)

    # 7. buffer of the immediately preceding read in the same body (link)
    if all(isinstance(v, bytes) for v in values) and node.body_pos > 0:
        prev = body_nodes[node.body_pos - 1]
        if prev.sc is Sys.PREAD:
            res = body_results.get(prev.name, {})
            if all((t, k) in res and res[(t, k)] == v for t, k, v in samples):
                prev.link = True
                return PLink(prev.name)

    raise UnminableTrace(
        f"node {node.name!r} arg {pos}: epoch-varying value with no "
        "provenance (data-dependent argument the miner cannot prove)"
    )


def _fit_count(
    lp: _LoopInfo,
    ctxs: List[Dict[str, Any]],
    prod_res: Dict[str, Dict[int, Any]],
    earlier: List[str],
    chunk_candidates: List[int],
) -> Tuple[CountProv, bool]:
    """Explain a loop's per-trace iteration counts; returns (prov, weak)."""
    counts = lp.counts
    tids = list(range(len(counts)))

    # exact: len of a producer listing
    for node in earlier:
        res = prod_res.get(node, {})
        if res and all(
            t in res
            and isinstance(res[t], (list, tuple))
            and len(res[t]) == counts[t]
            for t in tids
        ):
            return CLen(node), False
    # exact: len of a ctx list
    for key in sorted(ctxs[0].keys()):
        if all(
            key in ctxs[t]
            and isinstance(ctxs[t][key], (list, tuple))
            and len(ctxs[t][key]) == counts[t]
            for t in tids
        ):
            return CCtxLen(key), False
    # exact: ceil(total / chunk)
    for chunk in sorted(set(c for c in chunk_candidates if c > 0)):
        for node in earlier:
            res = prod_res.get(node, {})
            try:
                if res and all(
                    t in res
                    and math.ceil(getattr(res[t], "st_size") / chunk) == counts[t]
                    for t in tids
                ):
                    return CCeil(PAttr(node, "st_size"), chunk), False
            except AttributeError:
                continue
        for key in sorted(ctxs[0].keys()):
            vals = [ctxs[t].get(key) for t in tids]
            if all(isinstance(v, int) and not isinstance(v, bool) for v in vals):
                if all(math.ceil(vals[t] / chunk) == counts[t] for t in tids):
                    return CCeil(PCtx(key), chunk), False
    # exact: constant (trace literal)
    if _all_equal(counts):
        return CConst(counts[0]), False
    # varying under a provable bound: early-exit loop (weak edges)
    for node in earlier:
        res = prod_res.get(node, {})
        if res and all(
            t in res
            and isinstance(res[t], (list, tuple))
            and counts[t] <= len(res[t])
            for t in tids
        ):
            return CLen(node), True
    for key in sorted(ctxs[0].keys()):
        if all(
            key in ctxs[t]
            and isinstance(ctxs[t][key], (list, tuple))
            and counts[t] <= len(ctxs[t][key])
            for t in tids
        ):
            return CCtxLen(key), True
    raise UnminableTrace(
        f"loop {lp.ordinal}: iteration counts {counts} diverge with no "
        "count provenance (data-dependent branch the miner cannot prove)"
    )


# ---------------------------------------------------------------------------
# Stub construction
# ---------------------------------------------------------------------------
def _barrier_requirements(
    nodes: List[_NodeInfo], loops: List[_LoopInfo], upto: int
) -> List[Tuple[str, Any]]:
    """(node name, required harvest count) pairs for every node before index
    ``upto``; loop-body nodes require their loop's dynamic count."""
    reqs: List[Tuple[str, Any]] = []
    for nd in nodes[:upto]:
        if nd.loop is None:
            reqs.append((nd.name, 1))
        else:
            reqs.append((nd.name, loops[nd.loop].count_prov))
    return reqs


def _make_compute_args(
    nd: _NodeInfo, count_prov: Optional[CountProv],
    barrier_reqs: Optional[List[Tuple[str, Any]]],
):
    provs = list(nd.provs)
    link = nd.link
    loop = nd.loop

    def compute_args(ctx, ep):
        if count_prov is not None:
            cnt = count_prov.value(ctx)
            if cnt is NOT_READY or ep[loop] >= cnt:
                return None
        if barrier_reqs is not None:
            harvested = ctx.get("__mined_n__", {})
            for name, need in barrier_reqs:
                if not isinstance(need, int):
                    need = need.value(ctx)
                    if need is NOT_READY:
                        return None
                if harvested.get(name, 0) < need:
                    return None
        out = []
        for p in provs:
            v = p.eval(ctx, ep)
            if v is NOT_READY:
                return None
            out.append(v)
        return tuple(out), link

    return compute_args


def _make_save_result(nd: _NodeInfo):
    name = nd.name
    store = nd.loop is None  # literal results feed downstream provenance

    def save_result(ctx, ep, rc):
        if store:
            _mined(ctx)[name] = rc
        n = ctx.setdefault("__mined_n__", {})
        n[name] = n.get(name, 0) + 1

    return save_result


def _make_head_choice(count_prov: CountProv):
    def choice(ctx, ep):
        cnt = count_prov.value(ctx)
        if cnt is NOT_READY:
            return None
        return 0 if cnt > 0 else 1

    return choice


def _make_more_choice(count_prov: CountProv, loop: int):
    def choice(ctx, ep):
        cnt = count_prov.value(ctx)
        if cnt is NOT_READY:
            return None
        return 0 if ep[loop] + 1 < cnt else 1

    return choice


# ---------------------------------------------------------------------------
# The miner
# ---------------------------------------------------------------------------
def mine_traces(
    traces: Sequence[Trace],
    ctxs: Optional[Sequence[Dict[str, Any]]] = None,
    name: str = "mined",
) -> MinedGraph:
    """Fold one or more traces into a directly-follows graph and emit a
    ``ForeactionGraph``.  Raises :class:`UnminableTrace` when the traces
    diverge structurally or an argument/count has no provenance."""
    if not traces:
        raise UnminableTrace("no traces to mine")
    if ctxs is None:
        ctxs = [{} for _ in traces]
    ctxs = list(ctxs)
    if len(ctxs) != len(traces):
        raise ValueError("ctxs must align 1:1 with traces")
    for t, tr in enumerate(traces):
        for ev in tr:
            if ev.error is not None:
                raise UnminableTrace(
                    f"trace {t} event {ev.seq} recorded an error ({ev.error!r}); "
                    "mine only from clean runs"
                )

    # -- skeleton: try each trace as reference, longest first ---------------
    order = sorted(range(len(traces)), key=lambda t: (-len(traces[t]), t))
    segs = None
    counts_by_trace: List[List[int]] = []
    last_err: Optional[UnminableTrace] = None
    for ref in order:
        cand = _fold(list(traces[ref].kinds()))
        try:
            counts_by_trace = [_align(list(tr.kinds()), cand) for tr in traces]
        except UnminableTrace as e:
            last_err = e
            continue
        segs = cand
        break
    if segs is None:
        raise last_err if last_err is not None else UnminableTrace("empty traces")

    # -- node metadata + sample assignment ----------------------------------
    nodes: List[_NodeInfo] = []
    loops: List[_LoopInfo] = []
    name_counts: Dict[str, int] = {}

    def _node_name(sc: Sys) -> str:
        k = name_counts.get(sc.value, 0) + 1
        name_counts[sc.value] = k
        return sc.value if k == 1 else f"{sc.value}_{k}"

    for si, seg in enumerate(segs):
        if isinstance(seg, LitSeg):
            nodes.append(_NodeInfo(_node_name(seg.sc), seg.sc, si, None))
        else:
            lp = _LoopInfo(ordinal=len(loops), seg_idx=si,
                           counts=[c[len(loops)] for c in counts_by_trace])
            for pos, sc in enumerate(seg.body):
                nodes.append(
                    _NodeInfo(_node_name(sc), sc, si, lp.ordinal, body_pos=pos)
                )
            loops.append(lp)

    node_by_seg: Dict[int, List[_NodeInfo]] = {}
    for nd in nodes:
        node_by_seg.setdefault(nd.seg_idx, []).append(nd)

    body_results: Dict[str, Dict[Tuple[int, int], Any]] = {}
    prod_res: Dict[str, Dict[int, Any]] = {}
    for t, tr in enumerate(traces):
        i = 0
        li = 0
        for si, seg in enumerate(segs):
            if isinstance(seg, LitSeg):
                nd = node_by_seg[si][0]
                nd.samples.append((t, 0, tr[i]))
                prod_res.setdefault(nd.name, {})[t] = tr[i].result
                i += 1
            else:
                cnt = counts_by_trace[t][li]
                li += 1
                for k in range(cnt):
                    for nd in node_by_seg[si]:
                        nd.samples.append((t, k, tr[i]))
                        body_results.setdefault(nd.name, {})[(t, k)] = tr[i].result
                        i += 1

    # -- provenance fitting --------------------------------------------------
    for idx, nd in enumerate(nodes):
        if not nd.samples:
            # a loop no trace entered: keep the node, its args must come from
            # count-bounded provenance — refuse, there is nothing to fit
            raise UnminableTrace(
                f"node {nd.name!r} has no samples (loop never entered)"
            )
        earlier = [p.name for p in nodes[:idx] if p.loop is None]
        body = node_by_seg[nd.seg_idx] if nd.loop is not None else [nd]
        nargs = len(nd.samples[0][2].args)
        if any(len(ev.args) != nargs for (_t, _k, ev) in nd.samples):
            raise UnminableTrace(f"node {nd.name!r}: inconsistent arity")
        nd.provs = [
            _fit_arg(nd, pos, ctxs, prod_res, earlier, body, body_results)
            for pos in range(nargs)
        ]
        nd.barrier = nd.sc in BARRIER_KINDS

    # -- loop-count provenance ----------------------------------------------
    for lp in loops:
        body = node_by_seg[lp.seg_idx]
        chunk_candidates = []
        for nd in body:
            for p in nd.provs:
                if isinstance(p, PLinear) and p.a > 0:
                    chunk_candidates.append(p.a)
                if isinstance(p, PClampedResidual):
                    chunk_candidates.append(p.chunk)
        first_body_idx = nodes.index(body[0])
        earlier = [p.name for p in nodes[:first_body_idx] if p.loop is None]
        lp.count_prov, lp.weak = _fit_count(
            lp, ctxs, prod_res, earlier, chunk_candidates
        )
        # a dynamic count can be zero at a future invocation: guard with a
        # head branch; constant counts observed >= 1 skip it (bptree shape)
        lp.head = not isinstance(lp.count_prov, CConst) or lp.count_prov.n == 0

    # -- graph assembly ------------------------------------------------------
    b = GraphBuilder(name)
    start_name: Optional[str] = None
    #: pending out-edges to wire to the next segment's entry (or End):
    #: ("syscall", src, weak) | ("branch", src)
    pending: List[Tuple[str, str, bool]] = []

    def _wire(dst: Optional[str]) -> None:
        for kind, src, weak in pending:
            if kind == "syscall":
                b.SyscallSetNext(src, dst, weak=weak)
            else:
                b.BranchAppendChild(src, dst)
        pending.clear()

    for si, seg in enumerate(segs):
        segnodes = node_by_seg[si]
        if isinstance(seg, LitSeg):
            nd = segnodes[0]
            barrier_reqs = (
                _barrier_requirements(nodes, loops, nodes.index(nd))
                if nd.barrier
                else None
            )
            b.AddSyscallNode(
                nd.name, nd.sc,
                _make_compute_args(nd, None, barrier_reqs),
                _make_save_result(nd),
            )
            if start_name is None:
                start_name = nd.name
            _wire(nd.name)
            pending.append(("syscall", nd.name, False))
        else:
            lp = next(l for l in loops if l.seg_idx == si)
            entry = None
            if lp.head:
                head = f"loop{lp.ordinal}_head"
                b.AddBranchingNode(head, _make_head_choice(lp.count_prov))
                if start_name is None:
                    start_name = head
                _wire(head)
                entry = head
            for nd in segnodes:
                barrier_reqs = (
                    _barrier_requirements(nodes, loops, nodes.index(nd))
                    if nd.barrier
                    else None
                )
                b.AddSyscallNode(
                    nd.name, nd.sc,
                    _make_compute_args(nd, lp.count_prov, barrier_reqs),
                    _make_save_result(nd),
                )
            first, last = segnodes[0], segnodes[-1]
            if start_name is None:
                start_name = first.name
            if entry is not None:
                b.BranchAppendChild(entry, first.name)
            else:
                _wire(first.name)
            for a, c in zip(segnodes, segnodes[1:]):
                b.SyscallSetNext(a.name, c.name)
            more = f"loop{lp.ordinal}_more"
            b.AddBranchingNode(more, _make_more_choice(lp.count_prov, lp.ordinal))
            b.SyscallSetNext(last.name, more, weak=lp.weak)
            b.BranchAppendChild(more, first.name, loopback=True)
            if entry is not None:
                pending.append(("branch", entry, False))
            pending.append(("branch", more, False))
    _wire(None)
    assert start_name is not None
    b.SetStart(start_name)
    graph = b.Build()
    return MinedGraph(
        name=name, graph=graph, nodes=nodes, loops=loops, num_traces=len(traces)
    )


# ---------------------------------------------------------------------------
# The validator: serial replay
# ---------------------------------------------------------------------------
def replay_trace(graph: ForeactionGraph, ctx: Dict[str, Any], trace: Trace) -> None:
    """Replay ``trace`` serially against ``graph`` with inputs ``ctx``;
    raises :class:`ReplayMismatch` unless every event matches exactly and
    the trace ends at End or across a weak edge.

    The replay walks the graph's *compiled plan* (:mod:`repro.core.plan`) —
    the same lowered representation the engine interprets — so the validator
    proves soundness of exactly the artifact that will speculate, and a
    lowering bug can never pass validation on the object graph while
    misbehaving at run time.  Compilation is cached, so replaying N traces
    lowers the graph once."""
    plan = compile_plan(graph)
    ctx = dict(ctx)
    ctx.pop("__mined__", None)
    ctx.pop("__mined_n__", None)
    epochs = plan.initial_epochs()
    nid = plan.start_dst
    weak_crossed = plan.start_weak
    results: Dict[Tuple[str, Tuple[int, ...]], Any] = {}

    for ev in trace:
        # resolve branch records at the frontier
        res = plan.resolve_branches(nid, epochs, ctx, weak_crossed)
        if res is None:
            raise ReplayMismatch(
                f"event {ev.seq}: branch undecidable at the frontier "
                "(count provenance not ready during serial replay)"
            )
        nid, epochs, weak_crossed = res
        if nid == END:
            raise ReplayMismatch(
                f"event {ev.seq}: graph reached End with {ev.sc} still pending"
            )
        name = plan.names[nid]
        if plan.sc[nid] is not ev.sc:
            raise ReplayMismatch(
                f"event {ev.seq}: graph expects {plan.sc[nid]} at {name!r}, "
                f"trace has {ev.sc}"
            )
        out = plan.compute[nid](ctx, epochs)
        if out is None:
            raise ReplayMismatch(
                f"event {ev.seq}: {name!r} args not computable at the "
                "frontier during serial replay"
            )
        args, _link = out
        if len(args) != len(ev.args):
            raise ReplayMismatch(
                f"event {ev.seq}: {name!r} arity {len(args)} != trace "
                f"arity {len(ev.args)}"
            )
        for i, (a, b2) in enumerate(zip(args, ev.args)):
            if isinstance(a, FromNode):
                a = results.get((a.name, epochs), NOT_READY)
            if a is NOT_READY or a != b2:
                raise ReplayMismatch(
                    f"event {ev.seq}: {name!r} arg {i} computes "
                    f"{a!r}, trace recorded {b2!r}"
                )
        results[(name, epochs)] = ev.result
        if plan.save[nid] is not None:
            plan.save[nid](ctx, epochs, ev.result)
        nid, epochs, weak_crossed = plan.follow_out(nid, epochs)
        # weak resets per step: only the tail matters for the end state
    # trace consumed: must be able to reach End, or have exited over weak
    res = plan.resolve_branches(nid, epochs, ctx, weak_crossed)
    if res is None:
        raise ReplayMismatch(
            "end of trace: branch undecidable, cannot prove completion"
        )
    nid, epochs, weak_crossed = res
    if nid != END and not weak_crossed:
        raise ReplayMismatch(
            f"trace ended at {plan.names[nid]!r} mid-graph with no weak edge "
            "permitting early exit"
        )


def mine_and_validate(
    traces: Sequence[Trace],
    ctxs: Optional[Sequence[Dict[str, Any]]] = None,
    name: str = "mined",
    holdout: bool = True,
) -> MinedGraph:
    """Mine on all-but-the-last trace, then replay *every* trace (including
    the held-out one) against the mined graph.  Raises
    :class:`UnsoundGraph` if any replay fails — the gate that keeps
    ``auto_graph`` wrapping honest."""
    if ctxs is None:
        ctxs = [{} for _ in traces]
    train = traces[:-1] if (holdout and len(traces) >= 2) else traces
    train_ctxs = ctxs[: len(train)]
    mined = mine_traces(train, train_ctxs, name=name)
    for t, (tr, ctx) in enumerate(zip(traces, ctxs)):
        try:
            replay_trace(mined.graph, ctx, tr)
        except ReplayMismatch as e:
            held = " (held-out)" if t >= len(train) else ""
            raise UnsoundGraph(
                f"mined graph {name!r} failed replay of trace {t}{held}: {e}"
            ) from e
    return mined


def preissue_overlap(graph: ForeactionGraph, ctx: Dict[str, Any],
                     trace: Trace) -> int:
    """Predicted pre-issue coverage of ``trace`` by ``graph``'s compiled
    plan — the number of leading events the plan reproduces with exactly
    the recorded arguments (:func:`repro.core.plan.predicted_preissue`).

    This is the re-miner's improvement metric: a full match equals
    ``len(trace)``; a graph that drifted away from the live pattern scores
    only the still-matching prefix."""
    from repro.core.plan import predicted_preissue

    return predicted_preissue(compile_plan(graph), ctx, trace.events)


def synthesize_trace(graph: ForeactionGraph, ctx: Dict[str, Any],
                     device) -> Trace:
    """Execute ``graph``'s compiled plan serially against ``device`` and
    record the resulting syscall trace — the *replay* direction of
    mine∘replay: a graph generating the very traces it was mined from.

    Walks from Start taking strong edges (branch choices must be decidable
    from ``ctx`` plus already-saved results, as in serial replay) and
    executes each computed syscall in order.  Used by the fixed-point
    property test (re-mining a mined graph's own traces must reproduce the
    same pre-issue schedule) and handy for shadow-validating a candidate
    without live traffic.  Raises :class:`ReplayMismatch` when a stub is
    undecidable mid-walk — e.g. a weak loop whose count only a live run
    determines."""
    from repro.core.syscalls import execute

    plan = compile_plan(graph)
    ctx = dict(ctx)
    ctx.pop("__mined__", None)
    ctx.pop("__mined_n__", None)
    out = Trace(graph.name)
    epochs = plan.initial_epochs()
    nid = plan.start_dst
    results: Dict[Tuple[str, Tuple[int, ...]], Any] = {}
    while True:
        res = plan.resolve_branches(nid, epochs, ctx, False)
        if res is None:
            raise ReplayMismatch(
                f"synthesis of {graph.name!r}: branch undecidable at "
                f"event {len(out)} (count provenance needs a live run)")
        nid, epochs, _weak = res
        if nid == END:
            return out
        computed = plan.compute[nid](ctx, epochs)
        if computed is None:
            raise ReplayMismatch(
                f"synthesis of {graph.name!r}: {plan.names[nid]!r} args "
                f"not computable at event {len(out)}")
        args, _link = computed
        resolved = []
        for a in args:
            if isinstance(a, FromNode):
                key = (a.name, epochs)
                if key not in results:
                    raise ReplayMismatch(
                        f"synthesis of {graph.name!r}: link producer "
                        f"{a.name!r} has no result at event {len(out)}")
                a = results[key]
            resolved.append(a)
        resolved = tuple(resolved)
        rc = execute(device, plan.sc[nid], resolved)
        out.append(TraceEvent(seq=len(out), sc=plan.sc[nid],
                              args=resolved, result=rc))
        results[(plan.names[nid], epochs)] = rc
        if plan.save[nid] is not None:
            plan.save[nid](ctx, epochs, rc)
        nid, epochs, _weak = plan.follow_out(nid, epochs)
