"""Compiled-artifact analysis: scan-aware HLO cost extraction + roofline."""

from .hlo import HloSummary, analyze_hlo
from .roofline import HW, RooflineTerms, roofline_from_report

__all__ = ["HloSummary", "analyze_hlo", "HW", "RooflineTerms", "roofline_from_report"]
