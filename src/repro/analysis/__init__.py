"""Analysis tools: HLO cost extraction, roofline, and the foreaction-graph
miner that folds recorded syscall traces into speculatable graphs."""

from .hlo import HloSummary, analyze_hlo
from .mine import (MinedGraph, ReplayMismatch, UnminableTrace, UnsoundGraph,
                   mine_and_validate, mine_traces, preissue_overlap,
                   replay_trace, synthesize_trace)
from .remine import ReMineConfig, ReMiner
from .roofline import HW, RooflineTerms, roofline_from_report

__all__ = [
    "HloSummary", "analyze_hlo", "HW", "RooflineTerms", "roofline_from_report",
    "MinedGraph", "ReplayMismatch", "UnminableTrace", "UnsoundGraph",
    "mine_and_validate", "mine_traces", "replay_trace",
    "preissue_overlap", "synthesize_trace", "ReMineConfig", "ReMiner",
]
