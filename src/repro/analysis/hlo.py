"""Scan-aware cost extraction from compiled (post-SPMD) HLO text.

``compiled.cost_analysis()`` counts each while-loop (lax.scan) body ONCE,
so for a depth-L scanned model it under-reports FLOPs by ~L x.  This
module re-derives costs from the HLO text itself:

* computations are parsed into (name -> ops) tables;
* the call graph (while bodies, fusions, calls, conditionals) propagates a
  *trip multiplier* down from ENTRY — a while body's ops count trip_count
  times (trip counts recovered from the loop-condition's s32 constant);
* FLOPs come from ``dot`` ops: 2 * prod(result_shape) * prod(contracted
  lhs dims), times the computation's multiplier;
* collective bytes (all-gather / all-reduce / reduce-scatter / all-to-all
  / collective-permute) are result-shape bytes times the multiplier.

Because the SPMD pipeline emits a *per-partition* module, every number
extracted here is **per device**.
"""

from __future__ import annotations

import re
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s4": 1, "u4": 1,
    "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4,
    "s64": 8, "u64": 8, "f64": 8, "c64": 8, "c128": 16,
}

_COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
                "collective-permute")

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_OP_RE = re.compile(r"^(?:ROOT\s+)?%?([\w.\-]+)\s*=\s*((?:\([^)]*\))|(?:\S+))\s+([\w\-]+)\(")


def _shapes_in(sig: str) -> List[Tuple[str, Tuple[int, ...]]]:
    out = []
    for dt, dims in _SHAPE_RE.findall(sig):
        if dt in _DTYPE_BYTES:
            out.append((dt, tuple(int(d) for d in dims.split(",") if d)))
    return out


def _sig_bytes(sig: str) -> int:
    total = 0
    for dt, dims in _shapes_in(sig):
        n = 1
        for d in dims:
            n *= d
        total += n * _DTYPE_BYTES[dt]
    return total


@dataclass
class _Op:
    name: str
    sig: str
    kind: str
    line: str


@dataclass
class _Computation:
    name: str
    ops: List[_Op] = field(default_factory=list)
    symbols: Dict[str, str] = field(default_factory=dict)  # op name -> sig


def _parse_computations(txt: str) -> Dict[str, _Computation]:
    comps: Dict[str, _Computation] = {}
    cur: Optional[_Computation] = None
    for raw in txt.splitlines():
        line = raw.rstrip()
        s = line.strip()
        # header params may contain nested tuple shapes — match loosely
        header = re.match(r"^(?:ENTRY\s+)?%?([\w.\-]+)\s*\(.*\)\s*->.*\{\s*$", s)
        if header and not line.startswith(" "):
            cur = _Computation(header.group(1))
            comps[cur.name] = cur
            continue
        if s == "}":
            cur = None
            continue
        if cur is None:
            continue
        m = _OP_RE.match(s)
        if m:
            op = _Op(m.group(1), m.group(2), m.group(3), s)
            cur.ops.append(op)
            cur.symbols[m.group(1)] = m.group(2)
    return comps


def _entry_name(txt: str) -> Optional[str]:
    m = re.search(r"^ENTRY\s+%?([\w.\-]+)", txt, re.M)
    return m.group(1) if m else None


def _trip_count(cond: _Computation) -> int:
    """Largest s32 constant in the loop condition — loops are `i < N`."""
    best = 1
    for op in cond.ops:
        if op.kind == "constant":
            m = re.search(r"constant\((\d+)\)", op.line)
            if m and "s32" in op.sig:
                best = max(best, int(m.group(1)))
    return best


_CALLEE_RE = re.compile(
    r"(?:condition|body|calls|to_apply|branch_computations)=\{?%?([\w.\-]+(?:,\s*%?[\w.\-]+)*)\}?")


def _multipliers(comps: Dict[str, _Computation], entry: str) -> Dict[str, int]:
    mult: Dict[str, int] = {entry: 1}
    stack = [entry]
    while stack:
        cname = stack.pop()
        comp = comps.get(cname)
        if comp is None:
            continue
        m = mult[cname]
        for op in comp.ops:
            refs = _CALLEE_RE.findall(op.line)
            if not refs:
                continue
            if op.kind == "while":
                body = re.search(r"body=%?([\w.\-]+)", op.line)
                cond = re.search(r"condition=%?([\w.\-]+)", op.line)
                trips = _trip_count(comps[cond.group(1)]) if cond and \
                    cond.group(1) in comps else 1
                for name, k in ((body and body.group(1), trips),
                                (cond and cond.group(1), trips)):
                    if name:
                        new = m * k
                        if new > mult.get(name, 0):
                            mult[name] = new
                            stack.append(name)
            else:
                for grp in refs:
                    for name in re.split(r",\s*%?", grp):
                        new = m
                        if new > mult.get(name, 0):
                            mult[name] = new
                            stack.append(name)
    return mult


def _dot_flops(comp: _Computation, op: _Op) -> int:
    """2 * prod(result) * prod(lhs contracting dims)."""
    res = _shapes_in(op.sig)
    if not res:
        return 0
    out_elems = 1
    for d in res[0][1]:
        out_elems *= d
    # operand format varies by HLO version: `dot(%name, ...)` (shape via the
    # symbol table) vs `dot(f32[256,256]{1,0} %name, ...)` (inline shape)
    m = re.search(r"dot\(((?:[^,{}\[\]]|\[[^\]]*\]|\{[^}]*\})+),", op.line)
    lhs_txt = m.group(1) if m else ""
    lhs_shapes = _shapes_in(lhs_txt)
    if not lhs_shapes:
        nm = re.search(r"%?([\w.\-]+)\s*$", lhs_txt.strip())
        lhs_sig = comp.symbols.get(nm.group(1), "") if nm else ""
        lhs_shapes = _shapes_in(lhs_sig)
    cd = re.search(r"lhs_contracting_dims=\{([\d,]*)\}", op.line)
    contract = 1
    if lhs_shapes and cd:
        lshape = lhs_shapes[0][1]
        for d in cd.group(1).split(","):
            if d:
                contract *= lshape[int(d)]
    return 2 * out_elems * contract


@dataclass
class HloSummary:
    """Per-device, trip-weighted costs."""

    dot_flops: float = 0.0
    dot_bytes: float = 0.0           # dot operand+result traffic (lower bound)
    collective_bytes: float = 0.0
    collectives: Dict[str, float] = field(default_factory=dict)
    collective_count: int = 0
    while_loops: int = 0
    max_trip: int = 1
    unweighted_dot_flops: float = 0.0

    def to_dict(self) -> Dict:
        return {
            "dot_flops": self.dot_flops,
            "dot_bytes": self.dot_bytes,
            "collective_bytes": self.collective_bytes,
            "collectives": dict(self.collectives),
            "collective_count": self.collective_count,
            "while_loops": self.while_loops,
            "max_trip": self.max_trip,
            "unweighted_dot_flops": self.unweighted_dot_flops,
        }


def analyze_hlo(txt: str) -> HloSummary:
    comps = _parse_computations(txt)
    entry = _entry_name(txt)
    if entry is None or entry not in comps:
        # fall back: treat the largest computation as entry
        entry = max(comps, key=lambda c: len(comps[c].ops)) if comps else None
        if entry is None:
            return HloSummary()
    mult = _multipliers(comps, entry)
    out = HloSummary(collectives={k: 0.0 for k in _COLLECTIVES})
    for cname, comp in comps.items():
        m = mult.get(cname, 0)
        if m == 0:
            continue  # unreachable from entry (dead or cond-only helper)
        out.max_trip = max(out.max_trip, m)
        for op in comp.ops:
            if op.kind == "while":
                out.while_loops += 1
            elif op.kind == "dot":
                f = _dot_flops(comp, op)
                out.dot_flops += m * f
                out.unweighted_dot_flops += f
                # operands + result bytes
                ops_m = re.search(r"dot\(%?([\w.\-]+),\s*%?([\w.\-]+)\)", op.line)
                b = _sig_bytes(op.sig)
                if ops_m:
                    b += _sig_bytes(comp.symbols.get(ops_m.group(1), ""))
                    b += _sig_bytes(comp.symbols.get(ops_m.group(2), ""))
                out.dot_bytes += m * b
            else:
                base = op.kind.replace("-start", "")
                if base in _COLLECTIVES and not op.kind.endswith("-done"):
                    b = _sig_bytes(op.sig)
                    out.collectives[base] += m * b
                    out.collective_bytes += m * b
                    out.collective_count += 1
    return out
