"""Online graph re-mining with validated hot-swap.

The miner (:mod:`repro.analysis.mine`) removes the paper's adoption cost
*once*: record a few traces, fold them into a graph, speculate.  But the
mined graph bakes in whatever the application did during observation — fd
numbers, file sizes, loop counts — and a long-lived server drifts: an LSM
compaction rewrites the level layout, a config reload changes a scan
width.  The pre-issuing engine stays *correct* under drift (the harvest
guard refuses stale pre-issues and serves synchronously), but the
speculation benefit silently decays to zero.

:class:`ReMiner` closes the loop online:

1. **Sample** — elect 1-in-``sample_every`` activations of each watched
   endpoint to run serially under a ``RecordingSession``; the trace lands
   in the endpoint's bounded :class:`repro.core.trace.TraceRing`.
2. **Mine + shadow-validate** — every ``remine_every`` sampled traces,
   mine a candidate and replay *all* sampled traces (including a held-out
   one) against it; any mismatch refuses the candidate.
3. **Predicted improvement** — score candidate vs incumbent with
   :func:`repro.analysis.mine.preissue_overlap` on the held-out traces;
   a candidate that does not strictly beat the incumbent's predicted
   pre-issue schedule is refused (no churn for zero gain).
4. **Hot-swap** — :meth:`repro.core.api.Foreactor.swap_graph` replaces
   the builder atomically: in-flight sessions finish on the plan they
   activated with, new sessions build version N+1.
5. **Regression guard** — the first ``guard_sessions`` sessions on the
   new version feed a per-version waste ledger; if their waste rate
   (``cancelled + wasted_completions`` per pre-issue — the *sum* is
   deterministic, the split is worker-timing-dependent) regresses past
   the pre-swap baseline, the guard rolls the swap back and vetoes that
   candidate's signature until a structurally different one appears.

Everything is counter-driven — no wall clock, no randomness — so a seeded
single-threaded run makes identical sampling, mining, swap and rollback
decisions every time (the drift-replay harness in tests/test_remine.py
asserts exactly that).
"""

from __future__ import annotations

import threading
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, List, Optional, Set

from .mine import (MinedGraph, ReplayMismatch, UnminableTrace, UnsoundGraph,
                   mine_and_validate, preissue_overlap)


@dataclass
class ReMineConfig:
    """Knobs for the sample → mine → validate → swap → guard loop.

    See docs/TUNING.md ("Sample rate vs re-mine cadence") for how these
    interact with :attr:`repro.core.api.Foreactor.trace_capacity`.
    """

    #: sample 1 in N activations per watched endpoint (serial recording)
    sample_every: int = 8
    #: refuse to mine from fewer than this many resident traces
    min_traces: int = 3
    #: attempt a re-mine every N delivered traces per endpoint
    remine_every: int = 3
    #: newest traces held out of training and scored for predicted
    #: pre-issue improvement (mine_and_validate additionally replays them)
    holdout: int = 1
    #: speculating sessions on the new version before the guard decides
    guard_sessions: int = 6
    #: rollback when new waste rate > baseline * ratio + slack
    guard_waste_ratio: float = 1.5
    guard_waste_slack: float = 0.05
    #: decision-log ring size (the log is the replay-identity artifact)
    max_decisions: int = 256


@dataclass
class _VersionLedger:
    """Waste accounting for all finished sessions of one graph version."""

    sessions: int = 0
    pre_issued: int = 0
    served_async: int = 0
    wasted: int = 0  # cancelled + wasted_completions (the deterministic sum)
    stale_harvests: int = 0

    def add(self, stats) -> None:
        self.sessions += 1
        self.pre_issued += stats.pre_issued
        self.served_async += stats.served_async
        self.wasted += stats.cancelled + stats.wasted_completions
        self.stale_harvests += stats.stale_harvests

    def waste_rate(self) -> float:
        return self.wasted / max(1, self.pre_issued)

    def to_dict(self) -> Dict[str, Any]:
        return {
            "sessions": self.sessions,
            "pre_issued": self.pre_issued,
            "served_async": self.served_async,
            "wasted": self.wasted,
            "stale_harvests": self.stale_harvests,
        }


@dataclass
class _Endpoint:
    """Per-watched-endpoint state, all counter-driven."""

    activations: int = 0
    samples: int = 0
    traces_seen: int = 0
    since_attempt: int = 0
    attempts: int = 0
    swaps: int = 0
    rollbacks: int = 0
    refusals: Dict[str, int] = field(default_factory=dict)
    #: per-graph-version waste ledgers (bounded: old versions evicted)
    ledgers: Dict[int, _VersionLedger] = field(default_factory=dict)
    #: regression-guard state, armed by a swap, cleared by keep/rollback
    guard_version: Optional[int] = None
    guard_sig: Optional[str] = None
    guard_baseline: float = 0.0
    prev_builder: Optional[Callable] = None
    #: signatures of rolled-back candidates — refused until a structurally
    #: different candidate appears (prevents swap/rollback oscillation on
    #: the same bad evidence)
    vetoed: Set[str] = field(default_factory=set)


class ReMiner:
    """Background re-miner: attach to a :class:`repro.core.api.Foreactor`,
    ``watch`` the endpoints whose graphs may drift, and serve traffic.

    "Background" here means *off the request path*, not *on a thread*: the
    attempt runs inline on whichever thread delivered the cadence-tripping
    trace (a sampled request already paying serial cost), which keeps every
    decision deterministic under the drift-replay harness.  All entry
    points (``sample``/``on_trace``/``on_session_finish``) are near-zero
    for unwatched endpoints and one counter bump for watched ones.
    """

    def __init__(self, fa, config: Optional[ReMineConfig] = None,
                 watch: Optional[List[str]] = None):
        self.fa = fa
        self.cfg = config or ReMineConfig()
        self._lock = threading.Lock()
        self._eps: Dict[str, _Endpoint] = {}
        self._decisions: List[Dict[str, Any]] = []
        self._injected = 0
        for name in (watch or []):
            self.watch(name)
        fa.attach_reminer(self)

    # -- wiring -----------------------------------------------------------
    def watch(self, name: str) -> None:
        """Start sampling and re-mining endpoint ``name``."""
        with self._lock:
            self._eps.setdefault(name, _Endpoint())

    def sample(self, name: str) -> bool:
        """Called by ``Foreactor.activate``: elect this activation for
        serial trace recording?  Counter-based (every ``sample_every``-th
        activation) — deterministic, no RNG."""
        with self._lock:
            ep = self._eps.get(name)
            if ep is None:
                return False
            ep.activations += 1
            if ep.activations % self.cfg.sample_every == 0:
                ep.samples += 1
                return True
            return False

    # -- evidence intake --------------------------------------------------
    def on_trace(self, name: str) -> None:
        """A sampled (or explicitly recorded) trace landed in the ring."""
        with self._lock:
            ep = self._eps.get(name)
            if ep is None:
                return
            ep.traces_seen += 1
            ep.since_attempt += 1
            due = ep.since_attempt >= self.cfg.remine_every
            if due:
                ep.since_attempt = 0
        if due:
            self._attempt(name)

    def on_session_finish(self, name: Optional[str], version: int,
                          stats) -> None:
        """Called by ``Foreactor.deactivate`` for every finished
        *speculating* session: feeds the per-version waste ledger and, when
        a guard is armed and has enough evidence, decides keep/rollback."""
        rollback = False
        with self._lock:
            ep = self._eps.get(name) if name else None
            if ep is None:
                return
            led = ep.ledgers.get(version)
            if led is None:
                led = ep.ledgers[version] = _VersionLedger()
                while len(ep.ledgers) > 8:
                    ep.ledgers.pop(min(ep.ledgers))
            led.add(stats)
            if ep.guard_version is not None and version == ep.guard_version \
                    and led.sessions >= self.cfg.guard_sessions:
                rate = led.waste_rate()
                limit = (ep.guard_baseline * self.cfg.guard_waste_ratio
                         + self.cfg.guard_waste_slack)
                if rate > limit:
                    rollback = True
                else:
                    self._decide(name, "keep", "guard_passed",
                                 version=version,
                                 waste_rate=round(rate, 6),
                                 limit=round(limit, 6))
                    ep.guard_version = None
                    ep.guard_sig = None
                    ep.prev_builder = None
        if rollback:
            self._rollback(name)

    # -- the re-mine attempt ----------------------------------------------
    def _attempt(self, name: str) -> None:
        cfg = self.cfg
        pairs = self.fa.traces(name)
        with self._lock:
            self._eps[name].attempts += 1
        if len(pairs) < cfg.min_traces:
            self._refuse(name, "too_few_traces", resident=len(pairs))
            return
        # mine on all resident evidence first (more traces = stronger
        # provenance fitting and more shadow replays).  Right after a drift
        # the ring holds a mix of old- and new-pattern traces, which the
        # full-set attempt correctly refuses — so fall back to the newest
        # ``min_traces`` suffix, the window that converges to pure
        # post-drift evidence fastest.  Both scopes failing is a refusal.
        scopes = [("all", pairs)]
        suffix = pairs[-cfg.min_traces:]
        if len(suffix) < len(pairs):
            scopes.append(("suffix", suffix))
        mined = None
        scope = None
        reason, err = "unminable", ""
        for scope_name, sub in scopes:
            ctxs = [c for (c, _t) in sub]
            trs = [t for (_c, t) in sub]
            try:
                mined = mine_and_validate(trs, ctxs, name=name, holdout=True)
                scope, pairs = scope_name, sub
                break
            except UnminableTrace as e:
                reason, err = "unminable", str(e)[:120]
            except (UnsoundGraph, ReplayMismatch) as e:
                # shadow validation: a trace (possibly the held-out one)
                # the candidate cannot replay byte-for-byte
                reason, err = "shadow", str(e)[:120]
        if mined is None:
            self._refuse(name, reason, error=err)
            return
        sig = mined.signature()
        with self._lock:
            vetoed = sig in self._eps[name].vetoed
        if vetoed:
            self._refuse(name, "vetoed_by_rollback")
            return
        # predicted pre-issue improvement on the held-out (newest) traces
        hold = pairs[-cfg.holdout:]
        try:
            incumbent = self.fa.graph(name)
        except KeyError:
            incumbent = None
        cand_score = sum(
            preissue_overlap(mined.graph, c, t) for (c, t) in hold)
        inc_score = -1 if incumbent is None else sum(
            preissue_overlap(incumbent, c, t) for (c, t) in hold)
        if cand_score <= inc_score:
            self._refuse(name, "no_predicted_improvement",
                         candidate=cand_score, incumbent=inc_score)
            return
        self._swap(name, mined.builder(), sig, scope=scope,
                   candidate=cand_score, incumbent=inc_score)

    def _swap(self, name: str, builder: Callable, sig: str, **detail) -> None:
        with self._lock:
            ep = self._eps[name]
            old_version = self.fa.graph_version(name)
            baseline = ep.ledgers.get(old_version)
            prev = self.fa.swap_graph(name, builder)
            ep.swaps += 1
            # arm the regression guard: the next build is version N+1
            ep.guard_version = old_version + 1
            ep.guard_sig = sig
            ep.guard_baseline = baseline.waste_rate() if baseline else 0.0
            if ep.prev_builder is None:
                ep.prev_builder = prev
            self._decide(name, "swap", "validated_improvement",
                         old_version=old_version,
                         new_version=old_version + 1,
                         baseline_waste=round(ep.guard_baseline, 6),
                         **detail)
        # old-pattern evidence must not seed the next attempt
        self.fa.drop_traces(name)

    def _rollback(self, name: str) -> None:
        with self._lock:
            ep = self._eps[name]
            if ep.guard_version is None or ep.prev_builder is None:
                return
            led = ep.ledgers.get(ep.guard_version)
            self.fa.swap_graph(name, ep.prev_builder, rollback=True)
            ep.rollbacks += 1
            if ep.guard_sig is not None:
                ep.vetoed.add(ep.guard_sig)
            self._decide(name, "rollback", "waste_regression",
                         bad_version=ep.guard_version,
                         waste_rate=round(led.waste_rate(), 6) if led else None,
                         baseline=round(ep.guard_baseline, 6))
            ep.guard_version = None
            ep.guard_sig = None
            ep.prev_builder = None
        self.fa.drop_traces(name)

    # -- canary / observability -------------------------------------------
    def inject_candidate(self, name: str, builder: Callable,
                         sig: Optional[str] = None) -> None:
        """Swap in an externally supplied candidate under the same
        regression guard the miner's own swaps get — the canary API the
        drift-replay harness uses to prove the guard rolls a bad graph
        back.  ``sig`` identifies the candidate in the veto set."""
        self.watch(name)
        with self._lock:
            self._injected += 1
            n = self._injected
        self._swap(name, builder, sig or f"injected#{n}", injected=True)

    def _refuse(self, name: str, reason: str, **detail) -> None:
        with self._lock:
            ep = self._eps[name]
            ep.refusals[reason] = ep.refusals.get(reason, 0) + 1
            self._decide(name, "refuse", reason, **detail)

    def _decide(self, name: str, action: str, reason: str, **detail) -> None:
        # caller may or may not hold the lock; appends are atomic under the
        # GIL and the log is only ever read via snapshot()
        entry = {"endpoint": name, "action": action, "reason": reason}
        if detail:
            entry.update(sorted(detail.items()))
        self._decisions.append(entry)
        del self._decisions[:-self.cfg.max_decisions]

    def decisions(self) -> List[Dict[str, Any]]:
        """The decision log: every refuse/swap/keep/rollback with its why.
        Contains no timestamps or ids — two seeded runs of the same
        workload produce byte-identical logs."""
        return list(self._decisions)

    def snapshot(self) -> Dict[str, Any]:
        """Deterministic full-state dump for reports and replay-identity
        assertions."""
        with self._lock:
            eps = {}
            for name in sorted(self._eps):
                ep = self._eps[name]
                eps[name] = {
                    "activations": ep.activations,
                    "samples": ep.samples,
                    "traces_seen": ep.traces_seen,
                    "attempts": ep.attempts,
                    "swaps": ep.swaps,
                    "rollbacks": ep.rollbacks,
                    "refusals": dict(sorted(ep.refusals.items())),
                    "guard_active": ep.guard_version is not None,
                    "vetoed": len(ep.vetoed),
                    "ledgers": {v: ep.ledgers[v].to_dict()
                                for v in sorted(ep.ledgers)},
                }
            return {"endpoints": eps, "decisions": list(self._decisions)}
