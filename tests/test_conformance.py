"""Backend-conformance suite.

Randomized graph programs — mixed pread/fstat/getdents chains with random
early exits, plus guaranteed-write and linked copy programs — executed under
every backend ({sync, user_threads, io_uring, multi_queue, shared-scheduler})
× speculation depth ({0, 1, adaptive}) must be *byte-identical* to the same
program run on ``SyncBackend``, and every session must satisfy the ledger
invariant::

    pre_issued == served_async + cancelled + wasted_completions

i.e. every pre-issued request is accounted exactly once: harvested by the
frontier, cancelled before execution, or drained to completion and wasted.

Write-bearing programs additionally prove the undoable-write extension
(repro.store.staging): random mixes of overwrites, staged creates and reads
behind weak edges commit byte-identical namespaces to sync execution at
every depth, and abort/fault paths leave the committed namespace exactly as
they found it — speculated *and* demanded writes roll back, staged files
vanish without residue.
"""

import random

import pytest
from _hypothesis_support import HAS_HYPOTHESIS, given, settings, st

from repro.core import (Foreactor, GraphBuilder, MemDevice, ShardedDevice,
                        Sys, io)
from repro.core.graph import FromNode
from repro.core.patterns import (build_copy_extents_graph,
                                 build_pwrite_extents_graph)

N_FILES = 10
FILE_SIZE = 96


def file_bytes(i: int) -> bytes:
    return bytes((i * 7 + j) % 251 for j in range(FILE_SIZE))


def make_device(kind: str):
    dev = ShardedDevice([MemDevice() for _ in range(3)]) if kind == "sharded" \
        else MemDevice()
    for i in range(N_FILES):
        fd = dev.open(f"/c/f{i}", "w")
        dev.pwrite(fd, file_bytes(i), 0)
        dev.close(fd)
    return dev


# -- random read programs -----------------------------------------------------
# A program is a list of pure steps plus an exit point (the early return that
# weak edges model): ("pread", file, size, offset) | ("fstat", file) |
# ("getdents",).

def random_program(rng: random.Random, length: int):
    steps = []
    for _ in range(length):
        r = rng.random()
        if r < 0.7:
            off = rng.randrange(0, FILE_SIZE - 8)
            steps.append(("pread", rng.randrange(N_FILES),
                          rng.randrange(1, FILE_SIZE - off), off))
        elif r < 0.9:
            steps.append(("fstat", rng.randrange(N_FILES)))
        else:
            steps.append(("getdents",))
    exit_at = rng.randint(1, length)  # stop after this many steps
    return steps, exit_at


def build_chain_graph(name: str, steps):
    """One syscall node per step, every edge weak (the caller may return
    after any step — all steps are pure, so still fully pre-issuable)."""
    b = GraphBuilder(name)
    prev = None
    for idx, step in enumerate(steps):
        node = f"s{idx}"
        if step[0] == "pread":
            def args(ctx, ep, step=step):
                return ((ctx["fds"][step[1]], step[2], step[3]), False)
            b.AddSyscallNode(node, Sys.PREAD, args)
        elif step[0] == "fstat":
            def args(ctx, ep, step=step):
                return ((f"/c/f{step[1]}",), False)
            b.AddSyscallNode(node, Sys.FSTATAT, args)
        else:
            b.AddSyscallNode(node, Sys.GETDENTS,
                             lambda ctx, ep: (("/c",), False))
        if prev is not None:
            b.SyscallSetNext(prev, node, weak=True)
        prev = node
    b.SyscallSetNext(prev, None, weak=True)
    return b.Build()


def run_program(dev, steps, exit_at, fa_kwargs, depth):
    """Execute a read program under a fresh Foreactor; returns (results,
    stats) where results is a canonical list (bytes / sizes / name lists)."""
    fa = Foreactor(device=dev, depth=depth, **fa_kwargs)
    fa.register("prog", lambda: build_chain_graph("prog", steps))
    fds = [dev.open(f"/c/f{i}", "r") for i in range(N_FILES)]

    @fa.wrap("prog", lambda: {"fds": fds})
    def prog():
        out = []
        for step in steps[:exit_at]:
            if step[0] == "pread":
                out.append(io.pread(dev, fds[step[1]], step[2], step[3]))
            elif step[0] == "fstat":
                out.append(io.fstatat(dev, f"/c/f{step[1]}").st_size)
            else:
                out.append(tuple(io.getdents(dev, "/c")))
        return out

    try:
        result = prog()
    finally:
        stats = fa.total_stats
        fa.shutdown()
    return result, stats


def assert_ledger_invariant(stats):
    assert stats.pre_issued == (stats.served_async + stats.cancelled
                                + stats.wasted_completions), vars(stats)


CONFIGS = [
    ("sync", "flat", dict(backend="sync")),
    ("user_threads", "flat", dict(backend="user_threads", workers=4)),
    ("io_uring", "flat", dict(backend="io_uring", workers=4)),
    ("multi_queue", "sharded", dict(backend="multi_queue", workers=2)),
    ("shared", "flat", dict(backend="io_uring", workers=4, shared=True)),
]
DEPTHS = [0, 1, "adaptive"]

_rng = random.Random(20260730)
PROGRAMS = [random_program(_rng, length) for length in (6, 12, 12, 18)]
# pin degenerate exits: immediate return and full run
PROGRAMS[1] = (PROGRAMS[1][0], 1)
PROGRAMS[2] = (PROGRAMS[2][0], len(PROGRAMS[2][0]))


@pytest.mark.parametrize("depth", DEPTHS)
@pytest.mark.parametrize("cfg", CONFIGS, ids=[c[0] for c in CONFIGS])
@pytest.mark.parametrize("prog_idx", range(len(PROGRAMS)))
def test_read_program_conformance(cfg, depth, prog_idx):
    _name, kind, kwargs = cfg
    steps, exit_at = PROGRAMS[prog_idx]
    reference, ref_stats = run_program(make_device(kind), steps, exit_at,
                                       dict(backend="sync"), 0)
    result, stats = run_program(make_device(kind), steps, exit_at,
                                kwargs, depth)
    assert result == reference
    assert_ledger_invariant(stats)
    assert_ledger_invariant(ref_stats)


# -- guaranteed writes --------------------------------------------------------

def run_write_program(dev, fa_kwargs, depth):
    fa = Foreactor(device=dev, depth=depth, **fa_kwargs)
    fa.register("writes", build_pwrite_extents_graph)
    fd = dev.open("/c/out.bin", "w")
    chunks = [bytes([i + 1]) * 24 for i in range(10)]
    writes = [(fd, chunks[i], i * 24) for i in range(len(chunks))]

    @fa.wrap("writes", lambda: {"writes": writes})
    def writer():
        for wfd, data, off in writes:
            io.pwrite(dev, wfd, data, off)
        io.fsync(dev, fd)

    try:
        writer()
    finally:
        stats = fa.total_stats
        fa.shutdown()
    rfd = dev.open("/c/out.bin", "r")
    content = dev.pread(rfd, 24 * len(chunks), 0)
    dev.close(rfd)
    dev.close(fd)
    return content, stats


@pytest.mark.parametrize("depth", [1, 8, "adaptive"])
@pytest.mark.parametrize("cfg", CONFIGS, ids=[c[0] for c in CONFIGS])
def test_write_program_conformance(cfg, depth):
    _name, kind, kwargs = cfg
    reference, _ = run_write_program(make_device(kind), dict(backend="sync"), 0)
    content, stats = run_write_program(make_device(kind), kwargs, depth)
    assert content == reference
    assert_ledger_invariant(stats)


# -- linked copy (FromRequest plumbing) --------------------------------------

def run_copy_program(dev, fa_kwargs, depth):
    fa = Foreactor(device=dev, depth=depth, **fa_kwargs)
    fa.register("cp", build_copy_extents_graph)
    sfd = dev.open("/c/f0", "r")
    dfd = dev.open("/c/copy.bin", "w")
    pairs = [(sfd, dfd, 16, i * 16) for i in range(FILE_SIZE // 16)]

    @fa.wrap("cp", lambda: {"pairs": pairs})
    def copy():
        for s, d, size, off in pairs:
            data = io.pread(dev, s, size, off)
            io.pwrite(dev, d, data, off)

    try:
        copy()
    finally:
        stats = fa.total_stats
        fa.shutdown()
    rfd = dev.open("/c/copy.bin", "r")
    content = dev.pread(rfd, FILE_SIZE, 0)
    dev.close(rfd)
    return content, stats


@pytest.mark.parametrize("depth", [1, 8, "adaptive"])
@pytest.mark.parametrize("cfg", CONFIGS, ids=[c[0] for c in CONFIGS])
def test_copy_program_conformance(cfg, depth):
    _name, kind, kwargs = cfg
    content, stats = run_copy_program(make_device(kind), kwargs, depth)
    assert content == file_bytes(0)
    assert_ledger_invariant(stats)


# -- write-bearing random programs (undoable-write conformance) ---------------
# Ops: ("pread", ro_file, size, off)          — read-only files, never written
#      ("pwrite", rw_file, token, slot)       — overwrite slot*8 of a rw file
#      ("open", cid) ... ("wnew", cid, token, slot) ... ("close", cid)
#      — a staged create macro: open /c/new{cid} w, chunk writes, close.
# Every edge is weak: the program may exit (or abort) after any op, so every
# write pre-issue goes through the staging transaction.

N_RO = 5  # files 0..4 are read-only in write programs
N_RW = N_FILES - N_RO  # files 5..9 take overwrites


def _tok(t: int) -> bytes:
    return bytes(((t * 13 + j) % 251) for j in range(8))


def random_write_program(rng: random.Random, length: int):
    ops = []
    slots = [(f, s) for f in range(N_RO, N_FILES)
             for s in range(FILE_SIZE // 8)]
    rng.shuffle(slots)  # each (file, slot) written at most once: no races
    cid = 0
    while len(ops) < length:
        r = rng.random()
        if r < 0.35:
            off = rng.randrange(0, FILE_SIZE - 8)
            ops.append(("pread", rng.randrange(N_RO),
                        rng.randrange(1, FILE_SIZE - off), off))
        elif r < 0.75 and slots:
            f, s = slots.pop()
            ops.append(("pwrite", f, rng.randrange(1000), s))
        else:
            n = rng.randint(1, 3)
            ops.append(("open", cid))
            for k in range(n):
                ops.append(("wnew", cid, rng.randrange(1000), k))
            ops.append(("close", cid))
            cid += 1
    exit_at = rng.randint(1, len(ops))
    return ops, exit_at


def build_write_program_graph(name: str, ops):
    b = GraphBuilder(name)
    prev = None
    for idx, op in enumerate(ops):
        node = f"s{idx}"
        if op[0] == "pread":
            def args(ctx, ep, op=op):
                return ((ctx["fds"][op[1]], op[2], op[3]), False)
            b.AddSyscallNode(node, Sys.PREAD, args)
        elif op[0] == "pwrite":
            def args(ctx, ep, op=op):
                return ((ctx["fds"][op[1]], _tok(op[2]), op[3] * 8), False)
            b.AddSyscallNode(node, Sys.PWRITE, args)
        elif op[0] == "open":
            def args(ctx, ep, op=op):
                return ((f"/c/new{op[1]}", "w"), False)

            def save(ctx, ep, rc, op=op):
                ctx.setdefault("new_fds", {})[op[1]] = rc
            b.AddSyscallNode(f"open{op[1]}", Sys.OPEN, args, save)
            node = f"open{op[1]}"
        elif op[0] == "wnew":
            def args(ctx, ep, op=op):
                fds = ctx.get("new_fds", {})
                fd = fds.get(op[1], FromNode(f"open{op[1]}"))
                return ((fd, _tok(op[2]), op[3] * 8), False)
            b.AddSyscallNode(node, Sys.PWRITE, args)
        else:  # close
            def args(ctx, ep, op=op):
                fds = ctx.get("new_fds", {})
                if op[1] not in fds:
                    return None
                return ((fds[op[1]],), False)
            b.AddSyscallNode(node, Sys.CLOSE, args)
        if prev is not None:
            b.SyscallSetNext(prev, node, weak=True)
        prev = node
    b.SyscallSetNext(prev, None, weak=True)
    return b.Build()


def namespace_snapshot(dev) -> dict:
    """Committed bytes of every file under /c, via plain device ops."""
    out = {}
    for name in dev.getdents("/c"):
        path = f"/c/{name}"
        size = dev.fstatat(path).st_size
        fd = dev.open(path, "r")
        out[name] = dev.pread(fd, size, 0)
        dev.close(fd)
    return out


def run_write_bearing_program(dev, ops, exit_at, fa_kwargs, depth,
                              abort: bool = False):
    fa = Foreactor(device=dev, depth=depth, **fa_kwargs)
    fa.register("wprog", lambda: build_write_program_graph("wprog", ops))
    fds = [dev.open(f"/c/f{i}", "r" if i < N_RO else "rw")
           for i in range(N_FILES)]
    results = []

    @fa.wrap("wprog", lambda: {"fds": fds})
    def prog():
        new_fds = {}
        for op in ops[:exit_at]:
            if op[0] == "pread":
                results.append(io.pread(dev, fds[op[1]], op[2], op[3]))
            elif op[0] == "pwrite":
                io.pwrite(dev, fds[op[1]], _tok(op[2]), op[3] * 8)
            elif op[0] == "open":
                new_fds[op[1]] = io.open(dev, f"/c/new{op[1]}", "w")
            elif op[0] == "wnew":
                io.pwrite(dev, new_fds[op[1]], _tok(op[2]), op[3] * 8)
            else:
                io.close(dev, new_fds.pop(op[1]))
        if abort:
            raise RuntimeError("injected abort")

    try:
        prog()
    except RuntimeError:
        assert abort
    finally:
        stats = fa.total_stats
        fa.shutdown()
    for fd in fds:
        dev.close(fd)
    return results, namespace_snapshot(dev), stats


_wrng = random.Random(20260731)
WRITE_PROGRAMS = [random_write_program(_wrng, n) for n in (6, 10, 14, 18)]
WRITE_PROGRAMS[1] = (WRITE_PROGRAMS[1][0], len(WRITE_PROGRAMS[1][0]))  # full run


@pytest.mark.parametrize("depth", [1, 8, "adaptive"])
@pytest.mark.parametrize("cfg", CONFIGS, ids=[c[0] for c in CONFIGS])
@pytest.mark.parametrize("prog_idx", range(len(WRITE_PROGRAMS)))
def test_write_bearing_program_conformance(cfg, depth, prog_idx):
    """Committed namespace + read results byte-identical to sync at every
    backend × depth, including weak-edge staged writes."""
    _name, kind, kwargs = cfg
    ops, exit_at = WRITE_PROGRAMS[prog_idx]
    ref_res, ref_ns, ref_stats = run_write_bearing_program(
        make_device(kind), ops, exit_at, dict(backend="sync"), 0)
    res, ns, stats = run_write_bearing_program(
        make_device(kind), ops, exit_at, kwargs, depth)
    assert res == ref_res
    assert ns == ref_ns
    assert_ledger_invariant(stats)
    assert_ledger_invariant(ref_stats)


def _abortable_prefix(ops) -> int:
    """Longest prefix containing no close (no publish barrier crossed):
    aborting inside it must leave the namespace untouched."""
    for i, op in enumerate(ops):
        if op[0] == "close":
            return max(1, i)
    return len(ops)


@pytest.mark.parametrize("depth", [0, 1, 8, "adaptive"])
@pytest.mark.parametrize("cfg", CONFIGS, ids=[c[0] for c in CONFIGS])
def test_abort_never_mutates_committed_namespace(cfg, depth):
    """Fault path: a session that raises before any publish barrier rolls
    back every write — demanded or speculative — on every backend."""
    _name, kind, kwargs = cfg
    ops, _ = WRITE_PROGRAMS[3]
    exit_at = _abortable_prefix(ops)
    dev = make_device(kind)
    before = namespace_snapshot(dev)
    _res, after, stats = run_write_bearing_program(
        dev, ops, exit_at, kwargs, depth, abort=True)
    assert after == before
    assert_ledger_invariant(stats)


# -- delta-chain restore conformance -------------------------------------------

import numpy as np

from repro.checkpoint import CheckpointManager


def _ckpt_tree(rng):
    return {"w": rng.standard_normal(192).astype(np.float32),
            "b": rng.standard_normal(48).astype(np.float32)}


@pytest.mark.parametrize("chain", [1, 3, 6])
@pytest.mark.parametrize("cfg", CONFIGS, ids=[c[0] for c in CONFIGS])
def test_delta_chain_restore_conformance(cfg, chain):
    """A chained delta restore (base + ``chain`` overlays) is byte-identical
    to restoring a plain full save of the same final tree, on every
    backend."""
    _name, kind, kwargs = cfg
    dev = make_device(kind)
    fa = Foreactor(device=dev, depth=8, **kwargs)
    mgr = CheckpointManager(dev, "/ckpt", fa=fa, num_shards=3,
                            chunk_bytes=256, keep=chain + 2,
                            max_delta_chain=chain + 2)
    rng = np.random.default_rng(chain)
    tree = _ckpt_tree(rng)
    mgr.save(0, tree)
    for s in range(1, chain + 1):
        idx = rng.integers(0, tree["w"].size, size=3)
        tree["w"][idx] = rng.standard_normal(3).astype(np.float32)
        mgr.save(s, tree, delta=True)
        assert mgr.read_manifest(s)["kind"] == "delta"

    ref_dev = make_device(kind)
    ref_fa = Foreactor(device=ref_dev, backend="sync", depth=0)
    ref = CheckpointManager(ref_dev, "/ckpt", fa=ref_fa, num_shards=3,
                            chunk_bytes=256)
    ref.save(chain, tree)
    try:
        got, _ = mgr.restore(chain, check_crc=True)
        want, _ = ref.restore(chain, check_crc=True)
        assert set(got) == set(want)
        for k in got:
            assert got[k].tobytes() == want[k].tobytes(), k
    finally:
        fa.shutdown()
        ref_fa.shutdown()


# -- property-based sweep (hypothesis) ---------------------------------------

if HAS_HYPOTHESIS:
    _program_strategy = st.integers(min_value=0, max_value=2 ** 31)
else:  # the stub accepts anything; the test body will be skipped
    _program_strategy = st.integers()


@settings(max_examples=25, deadline=None)
@given(seed=_program_strategy)
def test_random_programs_match_sync(seed):
    """Hypothesis sweep: arbitrary read programs under the deepest-stack
    configs (shared scheduler + multi-queue, adaptive depth) match sync."""
    rng = random.Random(seed)
    steps, exit_at = random_program(rng, rng.randint(2, 16))
    reference, _ = run_program(make_device("flat"), steps, exit_at,
                               dict(backend="sync"), 0)
    for kind, kwargs in (
        ("flat", dict(backend="io_uring", workers=4, shared=True)),
        ("sharded", dict(backend="multi_queue", workers=2)),
    ):
        ref = reference if kind == "flat" else \
            run_program(make_device(kind), steps, exit_at,
                        dict(backend="sync"), 0)[0]
        result, stats = run_program(make_device(kind), steps, exit_at,
                                    kwargs, "adaptive")
        assert result == ref
        assert_ledger_invariant(stats)


# -- direct + coalesced lanes -------------------------------------------------
# The same read programs, but the device advertises a 512-byte direct-lane
# alignment and the plane's extent coalescer is on: adjacent same-fd pread
# runs fuse into super-reads backed by aligned leases, scattered back as
# zero-copy views.  Results must stay byte-identical to sync (which never
# coalesces — the oracle), including EOF-short fused reads, and the ledger
# invariant must account every satellite exactly once.

def make_direct_device(kind: str):
    dev = make_device(kind)
    for d in (dev.devices if kind == "sharded" else [dev]):
        d.alignment = 512  # direct lane: leases must come aligned
    return dev


def adjacent_program(files, reads_per_file, size):
    """Per-file adjacent pread runs — the coalescer's target shape."""
    return [("pread", f, size, i * size)
            for f in range(files) for i in range(reads_per_file)]


#: (steps, exit_at): full adjacent runs, a mid-run early exit (cancelled
#: satellites), and a run past EOF (fused short read must decompose)
COALESCE_PROGRAMS = [
    (adjacent_program(3, 8, 12), 24),   # 3 files x 96 bytes, exact EOF
    (adjacent_program(3, 8, 12), 9),    # exit mid-run on file 2
    (adjacent_program(1, 8, 16), 8),    # reads run past EOF at 96
]


@pytest.mark.parametrize("depth", DEPTHS + [32])
@pytest.mark.parametrize("cfg", CONFIGS, ids=[c[0] for c in CONFIGS])
@pytest.mark.parametrize("prog_idx", range(len(COALESCE_PROGRAMS)))
def test_coalesced_read_conformance(cfg, depth, prog_idx):
    _name, kind, kwargs = cfg
    steps, exit_at = COALESCE_PROGRAMS[prog_idx]
    reference, ref_stats = run_program(make_device(kind), steps, exit_at,
                                       dict(backend="sync"), 0)
    result, stats = run_program(make_direct_device(kind), steps, exit_at,
                                dict(coalesce=True, **kwargs), depth)
    assert result == reference
    assert_ledger_invariant(stats)
    assert_ledger_invariant(ref_stats)


@pytest.mark.parametrize("depth", [1, 8, "adaptive"])
@pytest.mark.parametrize("cfg", CONFIGS, ids=[c[0] for c in CONFIGS])
def test_coalesced_write_program_conformance(cfg, depth):
    """With the coalescer on, guaranteed writes serialize their payloads
    into leased aligned buffers (the WRITE_FIXED analogue) — the committed
    bytes must still be identical to sync."""
    _name, kind, kwargs = cfg
    reference, _ = run_write_program(make_device(kind),
                                     dict(backend="sync"), 0)
    content, stats = run_write_program(make_direct_device(kind),
                                       dict(coalesce=True, **kwargs), depth)
    assert content == reference
    assert_ledger_invariant(stats)
