"""Fault-injection tests for the speculation engine and backends.

Faults are keyed by *request identity* (offset / path), never by call
order — speculation reorders execution, so order-keyed injection would be
nondeterministic.  Covered:

* a worker raising EIO on a link-chain head cancels the chain's dependents
  exactly once and never executes the dependent write;
* a compute-args stub raising mid-peek leaves prepared-but-unsubmitted
  writes in the submission queue, where teardown cancels them before they
  ever touch the device;
* short reads propagate byte-identically to synchronous execution;
* on a shared backend, one tenant's fault never leaks into another
  tenant's session.
"""

import errno
import threading

import pytest

from repro.core import Foreactor, GraphBuilder, MemDevice, Sys, io
from repro.core.device import Device
from repro.core.patterns import (build_copy_extents_graph,
                                 build_pread_extents_graph)
from repro.core.syscalls import ReqState


class FaultyDevice(Device):
    """Delegating device that injects deterministic faults:

    * ``eio_offsets`` — any pread at one of these offsets raises EIO;
    * ``short_offsets`` — any pread at one of these offsets returns half
      the requested bytes.
    """

    def __init__(self, inner: Device):
        self.inner = inner
        self.stats = inner.stats
        self.eio_offsets = set()
        self.short_offsets = set()

    def open(self, path, flags="r"):
        return self.inner.open(path, flags)

    def close(self, fd):
        return self.inner.close(fd)

    def pread(self, fd, size, offset):
        if offset in self.eio_offsets:
            raise OSError(errno.EIO, f"injected EIO at offset {offset}")
        data = self.inner.pread(fd, size, offset)
        if offset in self.short_offsets:
            return data[: max(1, size // 2)]
        return data

    def pwrite(self, fd, data, offset):
        return self.inner.pwrite(fd, data, offset)

    def fstatat(self, path):
        return self.inner.fstatat(path)

    def getdents(self, path):
        return self.inner.getdents(path)

    def fsync(self, fd):
        return self.inner.fsync(fd)


def make_faulty(n_blocks: int = 8, block: int = 32):
    inner = MemDevice()
    fd = inner.open("/src.bin", "w")
    # layout: block i is bytes [i*block, (i+1)*block), filled with i+1
    payload = b"".join(bytes([i + 1]) * block for i in range(n_blocks))
    inner.pwrite(fd, payload, 0)
    inner.close(fd)
    return FaultyDevice(inner), payload


BLOCK = 32
FAIL_AT = 3  # chain index whose pread raises


@pytest.mark.parametrize("backend", ["io_uring", "user_threads"])
def test_eio_mid_link_chain_cancels_dependent_exactly_once(backend):
    """Fig. 4b copy chains: pread #3 raises EIO on the worker; its linked
    pwrite must be cancelled exactly once and never executed, while the
    error surfaces at the frontier and the ledger invariant still holds."""
    dev, payload = make_faulty()
    dev.eio_offsets = {FAIL_AT * BLOCK}
    fa = Foreactor(device=dev, backend=backend, depth=16)
    fa.register("cp", build_copy_extents_graph)
    sfd = dev.open("/src.bin", "r")
    dfd = dev.open("/dst.bin", "w")
    pairs = [(sfd, dfd, BLOCK, i * BLOCK) for i in range(8)]

    sess = fa.activate("cp", {"pairs": pairs})
    with pytest.raises(OSError) as exc:
        try:
            for s, d, size, off in pairs:
                data = io.pread(dev, s, size, off)
                io.pwrite(dev, d, data, off)
        finally:
            stats = fa.deactivate(sess)
    assert exc.value.errno == errno.EIO

    # the dependent pwrite of the failed chain was cancelled, exactly once
    st = sess._state[(sess.plan.id_of["pwrite"], (FAIL_AT,))]
    assert st.req is not None and st.req.state is ReqState.CANCELLED
    assert not st.harvested
    # and it never touched the device: block FAIL_AT of dst is unwritten
    rfd = dev.open("/dst.bin", "r")
    dst = dev.pread(rfd, BLOCK * 8, 0)
    assert dst[FAIL_AT * BLOCK : (FAIL_AT + 1) * BLOCK].strip(b"\x00") == b""
    # chains before the failure did copy
    assert dst[:BLOCK] == payload[:BLOCK]
    assert stats.pre_issued == (stats.served_async + stats.cancelled
                                + stats.wasted_completions), vars(stats)
    assert stats.cancelled >= 1
    # idempotent finish: re-running it must not double-count the cancel
    before = (stats.cancelled, stats.wasted_completions)
    sess.finish()
    assert (sess.stats.cancelled, sess.stats.wasted_completions) == before
    fa.shutdown()


def test_stub_error_never_executes_prepared_unsubmitted_write():
    """A ComputeArgs stub raising mid-peek aborts the batch before
    submit_all: entries already prepared stay in the submission queue and
    teardown cancels them — no write may reach the device (§3.3: a non-pure
    request is only guaranteed while the function keeps running)."""
    dev = MemDevice()
    fd = dev.open("/out.bin", "w")
    chunks = [bytes([i + 1]) * 16 for i in range(8)]

    def build():
        b = GraphBuilder("wl")

        def args(ctx, ep):
            if ep[0] == 2:
                raise RuntimeError("stub blew up computing epoch 2")
            if ep[0] >= len(chunks):
                return None
            return ((fd, chunks[ep[0]], ep[0] * 16), False)

        b.AddSyscallNode("pwrite", Sys.PWRITE, args)
        b.AddBranchingNode("more",
                           lambda ctx, ep: 0 if ep[0] + 1 < len(chunks) else 1)
        b.SyscallSetNext("pwrite", "more")
        b.BranchAppendChild("more", "pwrite", loopback=True)
        b.BranchAppendChild("more", None)
        return b.Build()

    fa = Foreactor(device=dev, backend="io_uring", depth=8)
    fa.register("wl", build)

    @fa.wrap("wl", lambda: {})
    def writer():
        for i, c in enumerate(chunks):
            io.pwrite(dev, fd, c, i * 16)

    with pytest.raises(RuntimeError, match="epoch 2"):
        writer()
    fa.shutdown()
    # the stub raised during the very first intercept's peek, before the
    # frontier was served: nothing — demanded or speculative — may have
    # executed, even though epoch 1 was already prepared.
    assert dev.stats.write_bytes == 0
    assert dev.fstatat("/out.bin").st_size == 0
    s = fa.total_stats
    assert s.cancelled == s.pre_issued > 0
    assert s.pre_issued == s.served_async + s.cancelled + s.wasted_completions


@pytest.mark.parametrize("shared", [False, True])
def test_short_read_conforms_to_sync(shared):
    """A device returning short reads must yield byte-identical results
    under speculation and under synchronous execution."""
    def run(fa_kwargs, depth):
        dev, _payload = make_faulty()
        dev.short_offsets = {2 * BLOCK, 5 * BLOCK}
        fa = Foreactor(device=dev, depth=depth, **fa_kwargs)
        fa.register("scan", lambda: build_pread_extents_graph("scan", weak=True))
        fd = dev.open("/src.bin", "r")
        extents = [(fd, BLOCK, i * BLOCK) for i in range(8)]

        @fa.wrap("scan", lambda: {"extents": extents})
        def scan():
            return [io.pread(dev, f, n, off) for f, n, off in extents]

        out = scan()
        fa.shutdown()
        return out

    reference = run(dict(backend="sync"), 0)
    assert len(reference[2]) == BLOCK // 2  # the injection really fired
    speculated = run(dict(backend="io_uring", workers=4, shared=shared), 8)
    assert speculated == reference


def test_fault_never_leaks_across_tenants_on_shared_backend():
    """Tenant A's EIO must surface only in A's sessions; tenant B sharing
    the same backend keeps getting correct bytes, and the shared pool is
    empty once both finish."""
    dev, payload = make_faulty()
    dev.eio_offsets = {6 * BLOCK}  # only tenant A reads this offset
    fa = Foreactor(device=dev, backend="io_uring", depth=8, workers=4,
                   shared=True)
    fa.register("scan", lambda: build_pread_extents_graph("scan", weak=True))
    fd_a = dev.open("/src.bin", "r")
    fd_b = dev.open("/src.bin", "r")
    ext_a = [(fd_a, BLOCK, i * BLOCK) for i in range(4, 8)]  # hits offset 6
    ext_b = [(fd_b, BLOCK, i * BLOCK) for i in range(0, 4)]  # clean

    results = {"a_errors": 0, "b": []}

    def client_a():
        with fa.tenant("A", priority="low"):
            @fa.wrap("scan", lambda: {"extents": ext_a})
            def scan():
                return [io.pread(dev, f, n, off) for f, n, off in ext_a]
            for _ in range(6):
                try:
                    scan()
                except OSError as e:
                    assert e.errno == errno.EIO
                    results["a_errors"] += 1

    def client_b():
        with fa.tenant("B", priority="high"):
            @fa.wrap("scan", lambda: {"extents": ext_b})
            def scan():
                return [io.pread(dev, f, n, off) for f, n, off in ext_b]
            for _ in range(6):
                results["b"].append(scan())

    ta = threading.Thread(target=client_a)
    tb = threading.Thread(target=client_b)
    ta.start(); tb.start()
    ta.join(timeout=30); tb.join(timeout=30)
    assert not ta.is_alive() and not tb.is_alive(), "deadlock"

    assert results["a_errors"] == 6  # every A call hit its own fault
    expect_b = [payload[i * BLOCK : (i + 1) * BLOCK] for i in range(4)]
    assert results["b"] == [expect_b] * 6  # B never saw A's failure
    s = fa.total_stats
    assert s.pre_issued == s.served_async + s.cancelled + s.wasted_completions
    assert fa.shared_backend().inflight() == 0
    fa.shutdown()
