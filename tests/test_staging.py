"""Undoable write-path speculation: staging extents, undo log, publish
barriers (repro.store.staging), and the write-graph consumers built on them
(checkpoint save graph, speculative record-shard writer, save_async
join-or-raise semantics)."""

import threading
import time

import numpy as np
import pytest

from repro.checkpoint import CheckpointManager
from repro.checkpoint.manager import CheckpointError
from repro.core import (Effect, Foreactor, GraphBuilder, MemDevice, OSDevice,
                        ShardedDevice, SimulatedDevice, Sys, effect_of, io)
from repro.store.recordio import RecordShardReader, write_shard
from repro.store.staging import STAGE_TAG, StagingTxn, staged_name


# -- effect classification ----------------------------------------------------

def test_effect_classes():
    assert effect_of(Sys.PREAD, (3, 8, 0)) is Effect.PURE
    assert effect_of(Sys.OPEN, ("/x", "r")) is Effect.PURE
    assert effect_of(Sys.OPEN, ("/x", "w")) is Effect.UNDOABLE
    assert effect_of(Sys.OPEN, ("/x", "rw")) is Effect.BARRIER
    assert effect_of(Sys.OPEN, ("/x", "a")) is Effect.BARRIER
    assert effect_of(Sys.PWRITE, (3, b"z", 0)) is Effect.UNDOABLE
    assert effect_of(Sys.FSYNC, (3,)) is Effect.BARRIER
    assert effect_of(Sys.CLOSE, (3,)) is Effect.BARRIER


# -- device namespace operations ----------------------------------------------

def _roundtrip(dev, prefix=""):
    fd = dev.open(f"{prefix}/a/x", "w")
    dev.pwrite(fd, b"hello world", 0)
    # rename while the fd is open: writes keep landing in the new name
    dev.rename(f"{prefix}/a/x", f"{prefix}/a/y")
    dev.pwrite(fd, b"HELLO", 0)
    dev.truncate(fd, 8)
    dev.close(fd)
    rfd = dev.open(f"{prefix}/a/y", "r")
    got = dev.pread(rfd, 64, 0)
    dev.close(rfd)
    assert got == b"HELLO wo"
    dev.unlink(f"{prefix}/a/y")
    with pytest.raises(FileNotFoundError):
        dev.fstatat(f"{prefix}/a/y")


def test_memdevice_staging_ops():
    assert MemDevice().supports_staging()
    _roundtrip(MemDevice())


def test_osdevice_staging_ops(tmp_path):
    assert OSDevice().supports_staging()
    _roundtrip(OSDevice(), prefix=str(tmp_path))


def test_simulated_device_staging_ops():
    dev = SimulatedDevice(MemDevice())
    assert dev.supports_staging()
    _roundtrip(dev)


def test_sharded_device_staging_ops():
    dev = ShardedDevice([MemDevice() for _ in range(3)])
    assert dev.supports_staging()
    # same-shard rename (explicit prefix): atomic fast path
    fd = dev.open("shard1:/s/x", "w")
    dev.pwrite(fd, b"abc", 0)
    dev.close(fd)
    dev.rename("shard1:/s/x", "shard1:/s/y")
    rfd = dev.open("shard1:/s/y", "r")
    assert dev.pread(rfd, 3, 0) == b"abc"
    dev.close(rfd)
    # cross-shard rename: copy fallback, source removed
    dev.rename("shard1:/s/y", "shard2:/s/z")
    rfd = dev.open("shard2:/s/z", "r")
    assert dev.pread(rfd, 3, 0) == b"abc"
    dev.close(rfd)
    with pytest.raises(FileNotFoundError):
        dev.fstatat("shard1:/s/y")


def test_staged_name_colocates_on_shard():
    dev = ShardedDevice([MemDevice() for _ in range(4)])
    for path in ("/ck/shard_0001.bin", "shard2:/ck/shard_0002.bin", "/m.json"):
        sn = staged_name(dev, path, "t0", 0)
        assert dev.resolve(sn)[0] == dev.resolve(path)[0]
        assert STAGE_TAG in sn


# -- StagingTxn unit behaviour -------------------------------------------------

def test_txn_create_publish_and_rollback():
    dev = MemDevice()
    txn = StagingTxn(dev)
    runner, rec = txn.stage_create("/out/a.bin", "w")
    fd = runner(dev)
    dev.pwrite(fd, b"payload", 0)
    # invisible at the final path until published
    with pytest.raises(FileNotFoundError):
        dev.fstatat("/out/a.bin")
    txn.on_demand(rec)
    dev.close(fd)
    txn.on_close(fd)  # publish barrier
    assert dev.fstatat("/out/a.bin").st_size == 7
    # a second create that is never demanded rolls back at finalize
    runner2, rec2 = txn.stage_create("/out/b.bin", "w")
    fd2 = runner2(dev)
    dev.pwrite(fd2, b"junk", 0)
    txn.finalize(ok=True)
    with pytest.raises(FileNotFoundError):
        dev.fstatat("/out/b.bin")
    assert not dev.getdents("/out") or dev.getdents("/out") == ["a.bin"]


def test_txn_overwrite_rollback_restores_bytes_and_length():
    dev = MemDevice()
    fd = dev.open("/f.bin", "w")
    dev.pwrite(fd, b"0123456789", 0)
    txn = StagingTxn(dev)
    runner, rec = txn.stage_overwrite((fd, b"XXXXXXXX", 6))  # extends to 14
    runner(dev)
    assert dev.pread(fd, 14, 0) == b"012345XXXXXXXX"
    txn.finalize(ok=False)
    # old bytes replayed, extension truncated away
    assert dev.fstatat("/f.bin").st_size == 10
    assert dev.pread(fd, 10, 0) == b"0123456789"


def test_txn_abort_unwinds_all_creates():
    dev = MemDevice()
    txn = StagingTxn(dev)
    fds = []
    for i in range(3):
        runner, rec = txn.stage_create(f"/d/f{i}", "w")
        fd = runner(dev)
        dev.pwrite(fd, b"x" * 8, 0)
        txn.on_demand(rec)
        fds.append(fd)
    txn.finalize(ok=False)  # even demanded creates roll back on abort
    assert dev.getdents("/d") == []
    assert dev._files == {}
    assert txn.snapshot()["undone"] == 3


def test_publish_close_is_identity_checked():
    """OS fd-number reuse: publishing one record's close barrier must never
    pop or publish a newer staged create that recycled the same fd."""
    dev = MemDevice()
    txn = StagingTxn(dev)
    r1, rec1 = txn.stage_create("/d/a", "w")
    fd1 = r1(dev)
    txn.on_demand(rec1)
    dev.close(fd1)
    # simulate the OS recycling fd1 for a second staged create
    r2, rec2 = txn.stage_create("/d/b", "w")
    fd2 = r2(dev)
    with txn._lock:
        del txn._staged_fds[fd2]
        rec2.fd = fd1
        txn._staged_fds[fd1] = rec2
    txn.publish_close(rec1)  # rec1 resolved by identity at pre-issue time
    assert rec1.published
    assert not rec2.published
    assert txn.record_for_fd(fd1) is rec2  # the newer mapping survives


def test_rollback_continues_past_a_failing_undo():
    """One failing undo must not abandon the rest of the rollback, and on
    the abort path the failure surfaces as a warning (never replacing the
    application's original exception)."""
    import warnings as _warnings

    dev = MemDevice()
    txn = StagingTxn(dev)
    fd = dev.open("/f", "w")
    dev.pwrite(fd, b"0123456789", 0)
    ro, rec_o = txn.stage_overwrite((fd, b"XXXX", 0))
    ro(dev)
    rc, rec_c = txn.stage_create("/d/c", "w")
    fdc = rc(dev)
    dev.pwrite(fdc, b"z", 0)
    dev.close(fd)  # the overwrite's undo target fd is now invalid
    with _warnings.catch_warnings(record=True) as caught:
        _warnings.simplefilter("always")
        txn.finalize(ok=False)
    assert rec_c.undone  # the later create still rolled back
    with pytest.raises(FileNotFoundError):
        dev.fstatat("/d/c")
    assert txn.rollback_errors  # the failure was recorded ...
    assert any(issubclass(w.category, RuntimeWarning) for w in caught)


# -- engine integration --------------------------------------------------------

def _write_chain_graph(name, n, weak=True):
    """n pwrite nodes to ctx fd, every edge weak (exit possible anywhere)."""
    b = GraphBuilder(name)
    prev = None
    for i in range(n):
        def args(ctx, ep, i=i):
            return ((ctx["fd"], ctx["chunks"][i], i * 8), False)
        b.AddSyscallNode(f"w{i}", Sys.PWRITE, args)
        if prev is not None:
            b.SyscallSetNext(prev, f"w{i}", weak=weak)
        prev = f"w{i}"
    b.SyscallSetNext(prev, None, weak=weak)
    return b.Build()


def test_early_exit_rolls_back_speculated_writes():
    """Weak-edge writes pre-issue (staged) and the un-demanded suffix is
    rolled back: committed bytes match what a serial run produced."""
    dev = MemDevice()
    fd = dev.open("/t.bin", "w")
    dev.pwrite(fd, b"." * 64, 0)
    chunks = [bytes([65 + i]) * 8 for i in range(8)]
    fa = Foreactor(device=dev, backend="io_uring", depth=8)
    fa.register("wchain", lambda: _write_chain_graph("wchain", 8))

    @fa.wrap("wchain", lambda: {"fd": fd, "chunks": chunks})
    def partial():
        for i in range(3):  # exits early: writes 3..7 are speculation only
            io.pwrite(dev, fd, chunks[i], i * 8)

    partial()
    fa.shutdown()
    got = dev.pread(fd, 64, 0)
    assert got == b"".join(chunks[:3]) + b"." * 40
    assert fa.total_stats.pre_issued > 0


def test_abort_rolls_back_demanded_writes_too():
    """A raising session is a failed transaction: even writes the function
    already issued are unwound — the committed namespace is untouched."""
    dev = MemDevice()
    fd = dev.open("/t.bin", "w")
    dev.pwrite(fd, b"." * 64, 0)
    chunks = [bytes([65 + i]) * 8 for i in range(8)]
    fa = Foreactor(device=dev, backend="io_uring", depth=4)
    fa.register("wchain", lambda: _write_chain_graph("wchain", 8))

    @fa.wrap("wchain", lambda: {"fd": fd, "chunks": chunks})
    def crashing():
        for i in range(4):
            io.pwrite(dev, fd, chunks[i], i * 8)
        raise RuntimeError("boom")

    with pytest.raises(RuntimeError):
        crashing()
    fa.shutdown()
    assert dev.pread(fd, 64, 0) == b"." * 64


def test_staged_create_publishes_at_close():
    """An in-graph creating open lands in a staging extent; the file enters
    the committed namespace exactly at the close barrier."""
    dev = MemDevice()
    b = GraphBuilder("create_write")

    def open_args(ctx, ep):
        return (("/pub/out.bin", "w"), False)

    def open_save(ctx, ep, rc):
        ctx["fd"] = rc
        # mid-session: the final path must not exist yet (still staged)
        try:
            dev.fstatat("/pub/out.bin")
            ctx["visible_early"] = True
        except FileNotFoundError:
            ctx["visible_early"] = False

    def w_args(ctx, ep):
        if "fd" not in ctx:
            return None
        return ((ctx["fd"], b"DATA", 0), False)

    def w_save(ctx, ep, rc):
        ctx["w_done"] = True

    def c_args(ctx, ep):
        if not ctx.get("w_done"):
            return None
        return ((ctx["fd"],), False)

    b.AddSyscallNode("open", Sys.OPEN, open_args, open_save)
    b.AddSyscallNode("w", Sys.PWRITE, w_args, w_save)
    b.AddSyscallNode("close", Sys.CLOSE, c_args)
    b.SyscallSetNext("open", "w")
    b.SyscallSetNext("w", "close")
    b.SyscallSetNext("close", None)
    g = b.Build()

    fa = Foreactor(device=dev, backend="io_uring", depth=4)
    fa.register("create_write", lambda: g)
    ctx = {}

    @fa.wrap("create_write", lambda: ctx)
    def run():
        fd = io.open(dev, "/pub/out.bin", "w")
        io.pwrite(dev, fd, b"DATA", 0)
        io.close(dev, fd)

    run()
    fa.shutdown()
    assert ctx["visible_early"] is False
    rfd = dev.open("/pub/out.bin", "r")
    assert dev.pread(rfd, 4, 0) == b"DATA"
    dev.close(rfd)
    # no staging residue anywhere in the directory
    assert all(STAGE_TAG not in n for n in dev.getdents("/pub"))


def test_staging_disabled_preserves_paper_rule():
    """Foreactor(staging=False): undoable nodes behind weak edges are not
    pre-issued (original §3.3 behaviour)."""
    dev = MemDevice()
    fd = dev.open("/t.bin", "w")
    dev.pwrite(fd, b"." * 64, 0)
    chunks = [bytes([65 + i]) * 8 for i in range(8)]
    fa = Foreactor(device=dev, backend="io_uring", depth=8, staging=False)
    fa.register("wchain", lambda: _write_chain_graph("wchain", 8))

    @fa.wrap("wchain", lambda: {"fd": fd, "chunks": chunks})
    def partial():
        for i in range(3):
            io.pwrite(dev, fd, chunks[i], i * 8)

    partial()
    fa.shutdown()
    assert fa.total_stats.pre_issued == 0
    assert dev.pread(dev.open("/t.bin", "r"), 64, 0) == \
        b"".join(chunks[:3]) + b"." * 40


# -- checkpoint save graph ----------------------------------------------------

def _tree():
    return {"w": np.arange(4096, dtype=np.float32),
            "b": np.arange(256, dtype=np.float32)}


@pytest.mark.parametrize("kind", ["flat", "sharded"])
def test_ckpt_save_graph_roundtrip(kind):
    dev = ShardedDevice([MemDevice() for _ in range(3)]) if kind == "sharded" \
        else MemDevice()
    fa = Foreactor(device=dev, depth=64)
    mgr = CheckpointManager(dev, "/ck", fa=fa, num_shards=4,
                            chunk_bytes=1024, keep=3)
    tree = _tree()
    mgr.save(7, tree, extra={"epoch": 1})
    assert fa.total_stats.pre_issued > 0  # the save speculated
    assert mgr.committed_steps() == [7]
    flat, extra = mgr.restore(7)
    assert extra == {"epoch": 1}
    assert np.array_equal(flat["['w']"], tree["w"])
    assert np.array_equal(flat["['b']"], tree["b"])
    fa.shutdown()


def test_ckpt_save_bytes_identical_to_serial():
    """The speculated write graph commits byte-identical shard files and
    marker to the sync (serial) execution of the same save; the manifest is
    compared structurally (its wall_time field is clock-dependent)."""
    import json

    def run(backend, depth):
        dev = MemDevice()
        fa = Foreactor(device=dev, backend=backend, depth=depth)
        mgr = CheckpointManager(dev, "/ck", fa=fa, num_shards=4,
                                chunk_bytes=512, keep=3)
        mgr.save(3, _tree())
        fa.shutdown()
        return {p: bytes(buf) for p, buf in dev._files.items()}

    serial = run("sync", 0)
    spec = run("io_uring", 64)
    assert serial.keys() == spec.keys()
    for p in serial:
        if p.endswith("manifest.json"):
            a, b = json.loads(serial[p]), json.loads(spec[p])
            a.pop("wall_time"), b.pop("wall_time")
            assert a == b, p
        else:
            assert serial[p] == spec[p], p


def test_ckpt_save_abort_leaves_no_trace():
    """A save that dies mid-graph must not leave a committed step NOR any
    partial file in the step directory."""
    dev = MemDevice()
    fa = Foreactor(device=dev, depth=64)
    mgr = CheckpointManager(dev, "/ck", fa=fa, num_shards=4,
                            chunk_bytes=512, keep=3)
    tree = _tree()
    mgr.save(1, tree)  # a good step to fall back to

    boom = {"n": 0}
    orig_fsync = type(dev).fsync

    def failing_fsync(self, fd):
        boom["n"] += 1
        if boom["n"] > 2:
            raise OSError("EIO: injected")
        return orig_fsync(self, fd)

    type(dev).fsync = failing_fsync
    try:
        with pytest.raises((OSError, RuntimeError)):
            mgr.save(2, tree)
    finally:
        type(dev).fsync = orig_fsync
    assert mgr.committed_steps() == [1]
    # nothing of step 2 in the committed namespace: no marker, no manifest,
    # no staged residue
    leftover = [p for p in dev._files if "step_0000000002" in p]
    assert leftover == [], leftover
    # and step 1 still restores
    assert mgr.restore_latest() is not None
    fa.shutdown()


# -- save_async join-or-raise (regression) ------------------------------------

def test_save_async_joins_inflight_thread():
    """A second save_async while the first is in flight must join it, not
    overwrite/orphan its thread."""
    dev = MemDevice()
    fa = Foreactor(device=dev, depth=32)
    mgr = CheckpointManager(dev, "/ck", fa=fa, num_shards=2,
                            chunk_bytes=512, keep=5)
    gate = threading.Event()
    orig_save = mgr.save
    order = []

    def slow_save(step, tree, extra=None, delta=False):
        order.append(("start", step))
        if step == 10:
            gate.wait(timeout=5)
        orig_save(step, tree, extra, delta=delta)
        order.append(("end", step))

    mgr.save = slow_save
    tree = _tree()
    mgr.save_async(10, tree)
    t = threading.Thread(target=lambda: (time.sleep(0.05), gate.set()))
    t.start()
    mgr.save_async(20, tree)  # must block until save 10 finished
    t.join()
    mgr.wait_pending()
    fa.shutdown()
    assert order.index(("end", 10)) < order.index(("start", 20))
    assert sorted(mgr.committed_steps()) == [10, 20]


def test_save_async_surfaces_prior_error():
    """If the in-flight save failed, the *next* save_async raises its error
    instead of silently dropping it."""
    dev = MemDevice()
    fa = Foreactor(device=dev, depth=32)
    mgr = CheckpointManager(dev, "/ck", fa=fa, num_shards=2,
                            chunk_bytes=512, keep=5)

    def bad_save(step, tree, extra=None, delta=False):
        raise OSError("ENOSPC: injected")

    good_save = mgr.save
    mgr.save = bad_save
    mgr.save_async(10, _tree())
    mgr.save = good_save
    with pytest.raises(CheckpointError, match="ENOSPC"):
        mgr.save_async(20, _tree())
    # the manager is usable again afterwards
    mgr.save_async(30, _tree())
    mgr.wait_pending()
    fa.shutdown()
    assert mgr.committed_steps() == [30]


# -- speculative record-shard writer -------------------------------------------

def test_write_shard_speculative_matches_serial():
    records = [bytes([i]) * 32 for i in range(20)]
    dev_a, dev_b = MemDevice(), MemDevice()
    write_shard(dev_a, "/data/s.rio", records)  # serial
    fa = Foreactor(device=dev_b, backend="io_uring", depth=32)
    write_shard(dev_b, "/data/s.rio", records, fa=fa)  # one write_file graph
    assert fa.total_stats.pre_issued > 0
    fa.shutdown()
    assert bytes(dev_a._files["/data/s.rio"]) == bytes(dev_b._files["/data/s.rio"])
    r = RecordShardReader(dev_b, "/data/s.rio")
    assert list(r) == records
    r.close()


def test_write_shard_speculative_abort_leaves_no_file():
    dev = MemDevice()
    fa = Foreactor(device=dev, backend="io_uring", depth=32)
    records = [bytes([i]) * 32 for i in range(20)]
    orig = type(dev).fsync
    type(dev).fsync = lambda self, fd: (_ for _ in ()).throw(OSError("EIO"))
    try:
        with pytest.raises(OSError):
            write_shard(dev, "/data/s.rio", records, fa=fa)
    finally:
        type(dev).fsync = orig
    fa.shutdown()
    assert dev._files == {}, list(dev._files)
