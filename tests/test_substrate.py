"""Checkpoint + data-pipeline tests: roundtrip, corruption fallback,
async overlap, replication, deterministic resumability."""

import numpy as np
import pytest

from repro.checkpoint import CheckpointError, CheckpointManager
from repro.core import MemDevice
from repro.data import (DataConfig, ShardedTokenDataset, TokenBatchLoader,
                        write_synthetic_dataset)


def tree():
    return {
        "a": {"w": np.arange(4000, dtype=np.float32).reshape(40, 100),
              "b": np.ones(100, dtype=np.float32)},
        "emb": np.random.default_rng(0).normal(size=(500, 32)).astype(np.float32),
        "step": np.asarray(7, np.int32),
    }


def test_checkpoint_roundtrip_and_validation():
    dev = MemDevice()
    mgr = CheckpointManager(dev, "/ck", num_shards=4, chunk_bytes=1 << 12, keep=2)
    t = tree()
    mgr.save(10, t, extra={"epoch": 1})
    assert mgr.latest_step() == 10
    assert mgr.validate(10)
    restored, extra = mgr.restore_tree(10, t)
    assert extra == {"epoch": 1}
    for (k1, a), (k2, b) in zip(
            sorted({"a.w": t["a"]["w"], "a.b": t["a"]["b"], "emb": t["emb"]}.items()),
            sorted({"a.w": restored["a"]["w"], "a.b": restored["a"]["b"],
                    "emb": restored["emb"]}.items())):
        np.testing.assert_array_equal(a, b)


def test_checkpoint_gc_and_fallback_on_corruption():
    dev = MemDevice()
    mgr = CheckpointManager(dev, "/ck", num_shards=2, chunk_bytes=1 << 12, keep=2)
    t = tree()
    for s in (1, 2, 3):
        mgr.save(s, t)
    assert mgr.committed_steps() == [2, 3]  # keep=2 tombstoned step 1
    # corrupt the newest shard -> restore_latest falls back to step 2
    fd = dev.open("/ck/step_0000000003/shard_0000.bin", "w")
    dev.pwrite(fd, b"garbage", 0)
    dev.close(fd)
    out = mgr.restore_latest(like=t)
    assert out is not None and out[0] == 2


def test_checkpoint_crc_detects_bitrot():
    dev = MemDevice()
    mgr = CheckpointManager(dev, "/ck", num_shards=2, chunk_bytes=1 << 12)
    t = tree()
    mgr.save(5, t)
    # flip one byte without changing the size (validate() passes, crc fails)
    path = "/ck/step_0000000005/shard_0000.bin"
    fd = dev.open(path, "rw")
    b = dev.pread(fd, 1, 100)
    dev.pwrite(fd, bytes([b[0] ^ 0xFF]), 100)
    dev.close(fd)
    assert mgr.validate(5)  # sizes still match
    with pytest.raises(CheckpointError, match="crc"):
        mgr.restore_tree(5, t)


def test_checkpoint_async_and_replicate():
    dev = MemDevice()
    mgr = CheckpointManager(dev, "/ck", num_shards=2, chunk_bytes=1 << 12)
    t = tree()
    mgr.save_async(20, t)
    mgr.wait_pending()
    assert mgr.latest_step() == 20
    dst = CheckpointManager(dev, "/ck_dr", num_shards=2, chunk_bytes=1 << 12)
    mgr.replicate(20, dst)
    r, _ = dst.restore_tree(20, t)
    np.testing.assert_array_equal(r["emb"], t["emb"])


def make_loader(dev, cfg, prefetch=False):
    paths = [f"/data/shard_{i:05d}.rio" for i in range(3)]
    ds = ShardedTokenDataset(dev, paths)
    return TokenBatchLoader(ds, cfg, prefetch=prefetch)


def test_pipeline_deterministic_and_resumable():
    dev = MemDevice()
    cfg = DataConfig(seq_len=32, batch_size=8, seed=11)
    write_synthetic_dataset(dev, "/data", cfg, 3, 40, vocab_size=100)
    l1 = make_loader(dev, cfg)
    l2 = make_loader(dev, cfg)
    b_a = l1.load(0, 0)
    _ = l1.load(0, 1)
    b_c = l1.load(0, 2)
    # a fresh loader resumed at step 2 reproduces the batch exactly
    b_c2 = l2.load(0, 2)
    np.testing.assert_array_equal(b_c["tokens"], b_c2["tokens"])
    # labels are next-token shifts of tokens
    np.testing.assert_array_equal(b_a["tokens"][:, 1:], b_a["labels"][:, :-1])
    # different epochs shuffle differently
    b_e1 = l2.load(1, 0)
    assert not np.array_equal(b_a["tokens"], b_e1["tokens"])
    l1.close(); l2.close()


def test_pipeline_covers_every_record_once_per_epoch():
    dev = MemDevice()
    cfg = DataConfig(seq_len=16, batch_size=5, seed=3)
    write_synthetic_dataset(dev, "/data", cfg, 3, 10, vocab_size=50)
    loader = make_loader(dev, cfg)
    seen = []
    for s in range(loader.steps_per_epoch):
        seen.extend(loader.batch_indices(0, s).tolist())
    assert len(seen) == len(set(seen))  # no duplicates within an epoch
    assert len(seen) == loader.steps_per_epoch * cfg.batch_size
    loader.close()
