"""Deterministic drift-replay harness for online re-mining and hot-swap.

The scenario the re-miner exists for: a served endpoint whose true syscall
pattern is baked into its mined graph (fd numbers, extent geometry, loop
counts — app state *not* in the activation ctx), and that pattern changes
mid-serve.  The engine's harvest-time argument guard keeps every response
byte-identical to serial execution, but the speculation benefit decays to
zero until :class:`repro.analysis.remine.ReMiner` samples the new pattern,
shadow-validates a re-mined candidate, and hot-swaps it.

Everything here is deterministic, single-threaded, and free of wall-clock
sleeps (the PR-6 ``ManualPlane`` style): an :class:`EagerPlane` executes
every admitted request inline at submit, and every re-miner decision is
counter-driven — so a seeded run replays with identical swap decisions,
which one test asserts outright by comparing two full-run snapshots.

Checked across every swap/rollback boundary:

* byte-identity with the sync oracle on every single request;
* the session-stats ledger
  ``pre_issued == served_async + cancelled + wasted_completions``;
* in-flight sessions finish on the plan they activated with;
* an injected bad candidate is swapped, caught by the waste-regression
  guard, rolled back, and vetoed;
* the validator refuses unsound candidates mined from drifted/ambiguous
  evidence (loop-count change, spurious branch, reordered write barrier)
  and keeps the old graph.
"""

import random
import threading

import pytest
from _hypothesis_support import given, settings, st

from repro.analysis.mine import (UnsoundGraph, mine_and_validate,
                                 preissue_overlap, synthesize_trace)
from repro.analysis.remine import ReMineConfig, ReMiner
from repro.core import (Foreactor, GraphBuilder, MemDevice, ShardedDevice,
                        Sys, TraceRing, io)
from repro.core.backends import IOPlane
from repro.core.syscalls import perform


# -- the deterministic I/O plane ----------------------------------------------

class EagerPlane(IOPlane):
    """A zero-thread plane that executes every admitted request inline at
    submit: pre-issues complete immediately and deterministically, and a
    speculated request with stale (post-drift) arguments fails or reads the
    wrong bytes *safely* — errors land in the request like a lane worker's
    would, never in the submitting thread."""

    def __init__(self, device):
        super().__init__(device, lanes=())
        self.executed = 0

    def _run(self, req) -> None:
        if req.claim():
            try:
                req.finish(perform(self.device, req))
            except BaseException as e:  # mirror lanes.py worker behaviour
                req.finish(error=e)
            self.executed += 1

    def submit(self, batch):
        if not batch:
            return 0
        with self._lock:
            self._submitted.extend(batch)
            if len(self._submitted) > self._LEDGER_COMPACT:
                self._submitted = [r for r in self._submitted
                                   if not r.is_done()]
        for r in batch:
            self._run(r)
        return len(batch)

    # IOPlane aliases submit_batch at class definition time
    submit_batch = submit


# -- the drifting endpoint ----------------------------------------------------
# Geometry lives in *app state*, not ctx: the mined graph can only bake it
# in as constants (PConst fd/size, PLinear offset, CConst count) — exactly
# the class of graph that goes stale when the app reconfigures.

FILE = "/data/blob"
FILE_BYTES = bytes((i * 31 + 7) % 251 for i in range(1 << 14))


class DriftApp:
    def __init__(self, dev, fd):
        self.dev = dev
        self.fd = fd
        self.count, self.size, self.stride = 4, 256, 512

    def drift(self, count=6, size=128, stride=256):
        self.count, self.size, self.stride = count, size, stride

    def run(self):
        return b"".join(
            io.pread(self.dev, self.fd, self.size, i * self.stride)
            for i in range(self.count))

    def oracle(self):
        return b"".join(
            self.dev.pread(self.fd, self.size, i * self.stride)
            for i in range(self.count))


def make_env(cfg=None, capacity=64):
    dev = MemDevice()
    fd = dev.open(FILE, "w")
    dev.pwrite(fd, FILE_BYTES, 0)
    app = DriftApp(dev, fd)
    fa = Foreactor(device=dev, backend="io_uring", depth=8,
                   trace_capacity=capacity)
    plane = EagerPlane(dev)
    fa._backend_pool.backend = plane  # deterministic plane, no workers
    fa._backends.append(plane)
    rm = ReMiner(fa, cfg or ReMineConfig(sample_every=4, min_traces=3,
                                         remine_every=3, guard_sessions=3),
                 watch=["scan"])
    return dev, app, fa, rm


def bootstrap(fa, app, n=3):
    """Offline observe→mine: n recorded traces trip the re-mine cadence and
    install the first mined graph (no incumbent → unconditional swap)."""
    for _ in range(n):
        fa.record("scan", {}, app.run)


def serve(fa, app, n):
    """n closed-loop requests; every one is checked byte-identical to the
    sync oracle and ledger-consistent before the next is admitted."""
    out = []
    for _ in range(n):
        sess = fa.activate("scan", {})
        try:
            got = app.run()
        finally:
            st = fa.deactivate(sess)
        assert got == app.oracle(), "response diverged from sync oracle"
        assert st.pre_issued == (st.served_async + st.cancelled
                                 + st.wasted_completions), vars(st)
        out.append(st)
    return out


def ep_snapshot(rm, name="scan"):
    return rm.snapshot()["endpoints"][name]


# -- the headline: drift → refusal → validated hot-swap → recovery ------------

def test_drift_replay_hot_swap_recovers_speculation():
    dev, app, fa, rm = make_env()
    bootstrap(fa, app)
    assert ep_snapshot(rm)["swaps"] == 1  # initial mined graph installed

    pre = serve(fa, app, 8)
    assert sum(s.served_async for s in pre) > 0, "no speculation pre-drift"
    assert sum(s.stale_harvests for s in pre) == 0
    v_before = fa.graph_version("scan")

    app.drift()  # the true pattern changes mid-serve
    post = serve(fa, app, 16)

    # correctness held throughout (serve() asserted per-op), and the guard
    # visibly refused stale pre-issues while the old graph was live
    assert sum(s.stale_harvests for s in post) > 0
    # mixed old/new evidence was refused before the suffix window converged
    acts = [(d["action"], d.get("scope")) for d in rm.decisions()]
    assert ("refuse", None) in acts
    assert ("swap", "suffix") in acts, f"no suffix-scope re-swap in {acts}"

    # speculation benefit is back: fresh sessions pre-issue and waste
    # nothing (the first recovery activation builds version N+1)
    rec = serve(fa, app, 6)
    assert fa.graph_version("scan") > v_before
    spec = [s for s in rec if s.pre_issued > 0]  # skip sampled (serial) ones
    assert spec and all(
        s.cancelled + s.wasted_completions == 0 and s.served_async > 0
        for s in spec)

    stats = fa.plan_cache_stats()["per_graph"]["scan"]
    assert stats["swaps"] == 2 and stats["rollbacks"] == 0
    fa.shutdown()


def _drift_scenario(seed: int):
    """One full seeded drift-replay run; returns (responses, snapshot)."""
    rng = random.Random(seed)
    dev, app, fa, rm = make_env()
    bootstrap(fa, app)
    responses = []
    pre_ops = rng.randint(6, 10)
    post_ops = 16 + rng.randint(0, 4)
    for phase_ops in (pre_ops, post_ops):
        for _ in range(phase_ops):
            sess = fa.activate("scan", {})
            try:
                responses.append(app.run())
            finally:
                fa.deactivate(sess)
        app.drift()
    snap = rm.snapshot()
    fa.shutdown()
    return responses, snap


def test_swap_decisions_replay_identical_across_runs():
    """The re-miner is counter-driven end to end: two runs of the same
    seeded workload make byte-identical swap decisions — the decision log
    carries no timestamps, ids, or RNG."""
    r1, s1 = _drift_scenario(seed=7)
    r2, s2 = _drift_scenario(seed=7)
    assert r1 == r2
    assert s1 == s2
    assert s1["endpoints"]["scan"]["swaps"] >= 2  # bootstrap + recovery


def test_in_flight_session_finishes_on_its_own_plan_across_swap():
    """swap_graph is atomic at the registry: a session activated on version
    N keeps its compiled plan (and its graph_version stamp) even when the
    swap lands mid-session; the next activation builds N+1."""
    dev, app, fa, rm = _quiet_env()
    bootstrap(fa, app)
    serve(fa, app, 1)  # build v1
    v1 = fa.graph_version("scan")

    sess = fa.activate("scan", {})
    try:
        # first half of the pattern, then the swap lands mid-flight
        first = [io.pread(dev, app.fd, app.size, i * app.stride)
                 for i in range(2)]
        fa.swap_graph("scan", fa._graph_builders["scan"])
        rest = [io.pread(dev, app.fd, app.size, i * app.stride)
                for i in range(2, app.count)]
    finally:
        st = fa.deactivate(sess)
    assert b"".join(first + rest) == app.oracle()
    assert st.pre_issued == (st.served_async + st.cancelled
                             + st.wasted_completions)
    assert sess.graph_version == v1  # stamped at activation, not at finish
    serve(fa, app, 1)  # next activation rebuilds
    assert fa.graph_version("scan") == v1 + 1
    fa.shutdown()


def test_injected_bad_candidate_is_rolled_back_and_vetoed():
    """The regression guard end to end: a candidate that validates nowhere
    near the live pattern gets swapped in via the canary API, wastes its
    pre-issues for guard_sessions sessions, and is rolled back — the old
    builder restored, the candidate's signature vetoed, every response
    byte-identical the whole time."""
    dev, app, fa, rm = _quiet_env()
    bootstrap(fa, app)
    good = serve(fa, app, 4)
    assert sum(s.cancelled + s.wasted_completions for s in good) == 0

    def bad_builder():
        # plausible but wrong: reads from offsets the app never touches
        b = GraphBuilder("scan")
        prev = None
        for i in range(4):
            node = f"p{i}"
            b.AddSyscallNode(node, Sys.PREAD,
                             lambda ctx, ep, i=i: ((app.fd, 64, 8192 + i), False))
            if prev is not None:
                b.SyscallSetNext(prev, node, weak=False)
            prev = node
        b.SyscallSetNext(prev, None, weak=True)
        b.SetStart("p0")
        return b.Build()

    rm.inject_candidate("scan", bad_builder, sig="bad-canary")
    stats = fa.plan_cache_stats()["per_graph"]["scan"]
    assert stats["swaps"] == 2 and stats["rollbacks"] == 0

    # guard window: responses stay correct (harvest guard refuses the junk),
    # waste is visible, and after guard_sessions the rollback fires
    during = serve(fa, app, 3)
    assert all(s.cancelled + s.wasted_completions > 0
               for s in during if s.pre_issued > 0)
    ep = ep_snapshot(rm)
    assert ep["rollbacks"] == 1 and ep["vetoed"] == 1
    assert not ep["guard_active"]
    stats = fa.plan_cache_stats()["per_graph"]["scan"]
    assert stats["rollbacks"] == 1
    acts = [d["action"] for d in rm.decisions()]
    assert "rollback" in acts

    # restored graph serves with zero waste again
    after = serve(fa, app, 4)
    spec = [s for s in after if s.pre_issued > 0]
    assert spec and all(s.cancelled + s.wasted_completions == 0 for s in spec)
    fa.shutdown()


# -- satellite: adversarial drifted/ambiguous evidence ------------------------

def _quiet_env():
    """Env with sampling effectively off — evidence is fed via record()."""
    return make_env(ReMineConfig(sample_every=10 ** 9, min_traces=3,
                                 remine_every=3, guard_sessions=3))


def test_validator_refuses_loop_count_change_and_keeps_old_graph():
    """Two count=4 traces train a CConst(4) loop; a count=6 held-out trace
    must fail shadow replay — the unsound candidate never swaps in."""
    dev, app, fa, rm = _quiet_env()
    bootstrap(fa, app)  # v-next swap on count=4 pattern
    swaps_before = ep_snapshot(rm)["swaps"]
    v = fa.graph_version("scan")
    fa.record("scan", {}, app.run)
    fa.record("scan", {}, app.run)
    app.count = 6  # loop-count drift lands in the newest (held-out) trace
    fa.record("scan", {}, app.run)  # cadence → attempt → must refuse
    ep = ep_snapshot(rm)
    assert ep["swaps"] == swaps_before
    assert ep["refusals"].get("shadow", 0) >= 1
    assert fa.graph_version("scan") == v  # old graph kept
    fa.shutdown()


def test_miner_refuses_spurious_branch_in_minority_trace():
    """A syscall that appears mid-pattern in one trace only (a 'new weak
    branch' the evidence cannot justify) breaks structural alignment; the
    attempt refuses rather than guess."""
    dev, app, fa, rm = _quiet_env()
    bootstrap(fa, app)
    swaps_before = ep_snapshot(rm)["swaps"]

    def with_spurious_stat():
        a = io.pread(app.dev, app.fd, app.size, 0)
        io.fstatat(app.dev, FILE)  # the branch the other traces lack
        return a + io.pread(app.dev, app.fd, app.size, app.stride)

    def plain():
        return (io.pread(app.dev, app.fd, app.size, 0)
                + io.pread(app.dev, app.fd, app.size, app.stride))

    fa.drop_traces("scan")
    # the divergent trace lands in the *training* set: structural alignment
    # itself fails, before any replay runs
    fa.record("scan", {}, with_spurious_stat)
    fa.record("scan", {}, plain)
    fa.record("scan", {}, plain)
    ep = ep_snapshot(rm)
    assert ep["swaps"] == swaps_before
    assert ep["refusals"].get("unminable", 0) >= 1
    fa.shutdown()


def test_miner_refuses_reordered_write_barrier():
    """pwrite→fsync in most traces, fsync→pwrite in one: a reordered
    harvest barrier is a structural divergence, not a minable pattern."""
    dev, app, fa, rm = _quiet_env()
    bootstrap(fa, app)
    swaps_before = ep_snapshot(rm)["swaps"]
    wfd = dev.open("/data/wal", "w")

    def write_then_sync():
        io.pwrite(app.dev, wfd, b"x" * 64, 0)
        io.fsync(app.dev, wfd)

    def sync_then_write():
        io.fsync(app.dev, wfd)
        io.pwrite(app.dev, wfd, b"x" * 64, 0)

    fa.drop_traces("scan")
    fa.record("scan", {}, sync_then_write)  # reordered, in the training set
    fa.record("scan", {}, write_then_sync)
    fa.record("scan", {}, write_then_sync)
    ep = ep_snapshot(rm)
    assert ep["swaps"] == swaps_before
    assert ep["refusals"].get("unminable", 0) >= 1
    fa.shutdown()


# -- satellite: mine ∘ replay ∘ mine is a fixed point -------------------------

@settings(max_examples=15, deadline=None)
@given(count=st.integers(min_value=3, max_value=7),
       size=st.integers(min_value=1, max_value=64),
       stride=st.integers(min_value=64, max_value=256))
def test_mine_synthesize_mine_fixed_point(count, size, stride):
    """Re-mining the traces a mined graph generates about itself must
    reproduce the same graph: identical structural signature, hence the
    identical pre-issue schedule."""
    dev = MemDevice()
    fd = dev.open(FILE, "w")
    dev.pwrite(fd, FILE_BYTES, 0)
    app = DriftApp(dev, fd)
    app.count, app.size, app.stride = count, size, stride
    fa = Foreactor(device=dev, backend="sync")
    for _ in range(3):
        fa.record("scan", {}, app.run)
    pairs = fa.traces("scan")
    g1 = mine_and_validate([t for _, t in pairs], [c for c, _ in pairs],
                           name="scan")
    synth = [synthesize_trace(g1.graph, {}, dev) for _ in range(3)]
    g2 = mine_and_validate(synth, [{} for _ in synth], name="scan")
    assert g2.signature() == g1.signature()
    # and the predicted pre-issue schedule covers the synthetic trace fully
    assert preissue_overlap(g2.graph, {}, synth[0]) == len(synth[0])
    fa.shutdown()


# -- satellite: invalidate_graph racing in-flight sessions, all backends ------

N_FILES = 6
FSIZE = 96

CONFIGS = [
    ("sync", "flat", dict(backend="sync")),
    ("user_threads", "flat", dict(backend="user_threads", workers=4)),
    ("io_uring", "flat", dict(backend="io_uring", workers=4)),
    ("multi_queue", "sharded", dict(backend="multi_queue", workers=2)),
    ("shared", "flat", dict(backend="io_uring", workers=4, shared=True)),
]


def _race_device(kind):
    dev = ShardedDevice([MemDevice() for _ in range(3)]) if kind == "sharded" \
        else MemDevice()
    for i in range(N_FILES):
        fd = dev.open(f"/c/f{i}", "w")
        dev.pwrite(fd, bytes((i * 7 + j) % 251 for j in range(FSIZE)), 0)
        dev.close(fd)
    return dev


def _chain_builder(fds):
    def build():
        b = GraphBuilder("race")
        prev = None
        for i in range(N_FILES):
            node = f"s{i}"
            b.AddSyscallNode(node, Sys.PREAD,
                             lambda ctx, ep, i=i: ((fds[i], 32, 0), False))
            if prev is not None:
                b.SyscallSetNext(prev, node, weak=True)
            prev = node
        b.SyscallSetNext(prev, None, weak=True)
        b.SetStart("s0")
        return b.Build()
    return build


@pytest.mark.parametrize("cfg", CONFIGS, ids=[c[0] for c in CONFIGS])
def test_invalidate_races_in_flight_session_on_every_backend(cfg):
    """Session A (worker thread) is mid-graph when the main thread
    invalidates + swaps and compiles version N+1 for session B.  Both must
    stay byte-identical to the oracle with intact ledgers; A keeps the
    version it activated on.  Event-gated, no sleeps."""
    name, kind, kwargs = cfg
    dev = _race_device(kind)
    fds = [dev.open(f"/c/f{i}", "r") for i in range(N_FILES)]
    oracle = [dev.pread(fd, 32, 0) for fd in fds]
    fa = Foreactor(device=dev, depth=4, **kwargs)
    fa.register("race", _chain_builder(fds))

    a_started = threading.Event()
    swap_done = threading.Event()
    a_out, a_stat, a_ver = [], [], []

    def session_a():
        sess = fa.activate("race", {})
        try:
            a_out.append(io.pread(dev, fds[0], 32, 0))
            a_started.set()
            swap_done.wait()  # the swap lands while A is mid-graph
            for i in range(1, N_FILES):
                a_out.append(io.pread(dev, fds[i], 32, 0))
        finally:
            a_stat.append(fa.deactivate(sess))
            a_ver.append(sess.graph_version)

    t = threading.Thread(target=session_a)
    t.start()
    a_started.wait()
    v1 = fa.graph_version("race")
    fa.invalidate_graph("race")
    fa.swap_graph("race", _chain_builder(fds))
    # session B compiles version N+1 while A is still in flight
    sess_b = fa.activate("race", {})
    try:
        b_out = [io.pread(dev, fds[i], 32, 0) for i in range(N_FILES)]
    finally:
        b_stat = fa.deactivate(sess_b)
    swap_done.set()
    t.join()

    assert a_out == oracle and b_out == oracle
    for st_ in (a_stat[0], b_stat):
        assert st_.pre_issued == (st_.served_async + st_.cancelled
                                  + st_.wasted_completions), vars(st_)
    assert a_ver[0] == v1
    assert sess_b.graph_version == v1 + 1
    fa.shutdown()


# -- satellite: the trace ring bounds memory under sustained sampling ---------

def test_trace_ring_rejects_degenerate_capacity():
    with pytest.raises(ValueError):
        TraceRing(0)


def test_trace_ring_bounds_memory_and_counts_drops():
    ring = TraceRing(4)
    for i in range(50):
        ring.append({"i": i}, object())
    assert len(ring) == 4
    assert ring.stats() == {"capacity": 4, "resident": 4,
                            "recorded": 50, "dropped": 46}
    # newest survive: the live pattern, which is what re-mining wants
    assert [c["i"] for c, _ in ring.snapshot()] == [46, 47, 48, 49]


def test_sustained_sampling_is_bounded_and_reported():
    """The regression satellite: before the ring, Foreactor._traces grew
    one pinned buffer set per sampled request forever.  Now residency is
    capped at trace_capacity and the drop count is visible in stats."""
    dev = MemDevice()
    fd = dev.open(FILE, "w")
    dev.pwrite(fd, FILE_BYTES, 0)
    app = DriftApp(dev, fd)
    fa = Foreactor(device=dev, backend="sync", trace_capacity=8)
    for _ in range(40):
        fa.record("scan", {}, app.run)
    assert len(fa.traces("scan")) == 8
    st_ = fa.trace_stats()["scan"]
    assert st_ == {"capacity": 8, "resident": 8,
                   "recorded": 40, "dropped": 32}
    fa.shutdown()


def test_sampled_activations_record_and_stay_correct():
    """sample_every=N: the elected activations run serially under a
    RecordingSession (still byte-correct, still ledger-clean with zero
    pre-issues) and their traces land in the ring; unwatched endpoints
    are never sampled."""
    dev, app, fa, rm = make_env(ReMineConfig(sample_every=3, min_traces=99,
                                             remine_every=99))
    bootstrap(fa, app)
    fa.mine("scan")  # cadence is off in this env: register explicitly
    stats = serve(fa, app, 9)
    sampled = [s for s in stats if s.pre_issued == 0 and s.served_sync > 0]
    assert len(fa.traces("scan")) == 3  # every 3rd activation
    assert len(sampled) >= 3
    # a graph the re-miner does not watch is never sampled
    assert rm.sample("other_endpoint") is False
    fa.shutdown()
