import os
import sys

# Tests must see the real single-CPU device (the 512-device override is
# exclusively for the dry-run, per the assignment).
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
