"""Tests for the scan-aware HLO cost analyzer and roofline model."""

import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.analysis.hlo import analyze_hlo
from repro.analysis.roofline import HW, model_flops, roofline
from repro.configs import SHAPES, get_config


def _compiled_text(fn, *sds):
    return jax.jit(fn).lower(*sds).compile().as_text()


def test_trip_weighted_flops_match_unrolled():
    """A 10-trip scanned matmul must count ~10x the single-body flops."""
    w = jax.ShapeDtypeStruct((256, 256), jnp.float32)
    x = jax.ShapeDtypeStruct((256, 256), jnp.float32)

    def scanned(x, w):
        def body(x, _):
            return jnp.tanh(x @ w), None
        y, _ = jax.lax.scan(body, x, jnp.arange(10))
        return y.sum()

    def unrolled(x, w):
        for _ in range(10):
            x = jnp.tanh(x @ w)
        return x.sum()

    s_scan = analyze_hlo(_compiled_text(scanned, x, w))
    s_unrl = analyze_hlo(_compiled_text(unrolled, x, w))
    one = 2 * 256 ** 3
    assert s_unrl.dot_flops == pytest.approx(10 * one, rel=0.01)
    assert s_scan.dot_flops == pytest.approx(s_unrl.dot_flops, rel=0.05)
    assert s_scan.max_trip >= 10
    assert s_scan.while_loops >= 1


def test_grad_of_scan_counts_both_passes():
    w = jax.ShapeDtypeStruct((128, 128), jnp.float32)
    x = jax.ShapeDtypeStruct((128, 128), jnp.float32)

    def loss(x, w):
        def body(x, _):
            return jnp.tanh(x @ w), None
        y, _ = jax.lax.scan(body, x, jnp.arange(6))
        return (y ** 2).sum()

    s = analyze_hlo(_compiled_text(jax.grad(loss, argnums=(0, 1)), x, w))
    one = 2 * 128 ** 3
    # fwd (6) + bwd dx (6) + bwd dw (6) = 18 matmuls minimum
    assert s.dot_flops >= 17 * one


def test_no_collectives_on_single_device():
    x = jax.ShapeDtypeStruct((64, 64), jnp.float32)
    s = analyze_hlo(_compiled_text(lambda x: (x @ x).sum(), x))
    assert s.collective_bytes == 0
    assert s.collective_count == 0


def test_roofline_dominance():
    hw = HW()
    t = roofline(197e12, 0.0, 0.0, hw)  # exactly 1s of compute
    assert t.dominant == "compute" and t.bound_s == pytest.approx(1.0)
    t = roofline(1.0, 819e9 * 2, 50e9, hw)
    assert t.dominant == "memory" and t.bound_s == pytest.approx(2.0)
    t = roofline(1.0, 1.0, 50e9 * 3, hw)
    assert t.dominant == "collective" and t.bound_s == pytest.approx(3.0)


def test_model_flops_scales_with_tokens():
    cfg = get_config("tinyllama_1_1b")
    f_train = model_flops(cfg, SHAPES["train_4k"], "train")
    f_prefill = model_flops(cfg, SHAPES["prefill_32k"], "prefill")
    f_decode = model_flops(cfg, SHAPES["decode_32k"], "decode")
    # train ~ 3x prefill per token (bwd), decode per token ~ prefill/token
    assert f_train > f_prefill > f_decode > 0
    # 6ND sanity: ~1.1B params, 1.05M tokens -> ~7e15 + attention
    assert 6e15 < f_train < 2e16
