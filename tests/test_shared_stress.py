"""Concurrency stress tests for the shared-backend scheduler (tier-1,
bounded runtime; also tagged ``stress`` so CI can run them under a hard
timeout — a hang here must fail fast, not stall the suite).

Covers the three failure modes a shared substrate introduces:

* the ``_AsyncBackend`` submitted-request ledger raced when ``inflight``/
  ``drain`` rebuilt it concurrently with ``submit_all`` (regression test
  for the lock added alongside the scheduler);
* deadlock / leaked in-flight requests with many tenants × many
  activations on one backend;
* fairness: total speculative occupancy never exceeds the backend's
  capacity, so no tenant's demand request can wait behind more than
  ``capacity`` speculative requests.
"""

import random
import threading

import pytest

from repro.core import Foreactor, MemDevice, QueuePairBackend, io
from repro.core.patterns import build_pread_extents_graph
from repro.core.syscalls import IORequest, Sys

pytestmark = pytest.mark.stress


def make_dev(nfiles=32, size=64):
    dev = MemDevice()
    for i in range(nfiles):
        fd = dev.open(f"/s/f{i}", "w")
        dev.pwrite(fd, bytes([i % 251]) * size, 0)
        dev.close(fd)
    return dev


def test_async_backend_ledger_is_thread_safe():
    """Hammer one QueuePairBackend from submitter threads while other
    threads rebuild the ledger via inflight()/drain().  Without the ledger
    lock, concurrent list rebuilds lose submitted entries (they then never
    drain or cancel) and len() races throw."""
    dev = make_dev()
    backend = QueuePairBackend(dev, workers=4)
    fds = [dev.open(f"/s/f{i}", "r") for i in range(8)]
    errors = []
    all_reqs = []
    reqs_lock = threading.Lock()
    stop = threading.Event()

    def submitter(tid):
        try:
            rng = random.Random(tid)
            for _ in range(150):
                batch = [IORequest(sc=Sys.PREAD,
                                   args=(fds[rng.randrange(8)], 16, 0))
                         for _ in range(4)]
                for r in batch:
                    backend.prepare(r)
                backend.submit_all()
                with reqs_lock:
                    all_reqs.extend(batch)
        except BaseException as e:  # pragma: no cover - the failure mode
            errors.append(e)

    def poller():
        try:
            while not stop.is_set():
                backend.inflight()
        except BaseException as e:  # pragma: no cover
            errors.append(e)

    subs = [threading.Thread(target=submitter, args=(i,)) for i in range(4)]
    polls = [threading.Thread(target=poller) for _ in range(2)]
    for t in subs + polls:
        t.start()
    for t in subs:
        t.join(timeout=60)
    stop.set()
    for t in polls:
        t.join(timeout=10)
    assert not errors, errors
    backend.drain()
    assert backend.inflight() == 0
    # the real ledger property: nothing was lost — every submitted request
    # reached completion (a dropped ledger entry would stay PREPARED forever)
    for r in all_reqs:
        assert r.wait_done(timeout=5), "request lost by the ledger race"
    backend.shutdown()


def test_shared_backend_many_tenants_no_deadlock():
    """N tenant threads × M activations on ONE shared queue pair: all
    sessions finish, the pool is empty afterwards, and speculative
    occupancy never exceeded capacity (weighted-fair admission)."""
    dev = make_dev()
    fa = Foreactor(device=dev, backend="io_uring", depth=8, workers=6,
                   shared=True)
    fa.register("scan", lambda: build_pread_extents_graph("scan", weak=True))
    N_THREADS, M_ACTIVATIONS = 8, 20
    errors = []

    def client(tid):
        try:
            rng = random.Random(tid)
            fds = [dev.open(f"/s/f{i}", "r") for i in range(16)]
            prio = ("high", "normal", "low")[tid % 3]
            with fa.tenant(f"tenant-{tid}", priority=prio,
                           weight=1.0 + (tid % 2)):
                @fa.wrap("scan", lambda: {"extents": extents})
                def scan():
                    out = 0
                    for j, (fd, n, off) in enumerate(extents):
                        out += len(io.pread(dev, fd, n, off))
                        if j == stop_at:
                            break  # early exit: leftover speculation
                    return out
                for _ in range(M_ACTIVATIONS):
                    extents = [(fd, 64, 0) for fd in fds]
                    stop_at = rng.randrange(len(extents))
                    assert scan() == 64 * (stop_at + 1)
        except BaseException as e:
            errors.append(e)

    threads = [threading.Thread(target=client, args=(i,))
               for i in range(N_THREADS)]
    for t in threads:
        t.start()
    for t in threads:
        t.join(timeout=120)
    assert all(not t.is_alive() for t in threads), "deadlock in shared backend"
    assert not errors, errors

    inner = fa.shared_backend()
    inner.drain()
    assert inner.inflight() == 0, "requests leaked in the shared pool"
    snap = fa.scheduler.snapshot()
    # fairness: a demand op can never queue behind more speculation than the
    # backend can hold — admission bounds total speculative occupancy
    assert snap["max_spec_inflight"] <= snap["capacity"], snap
    assert snap["spec_inflight"] == 0, snap
    s = fa.total_stats
    assert s.pre_issued == s.served_async + s.cancelled + s.wasted_completions
    fa.shutdown()


def test_demand_is_never_starved_by_cold_tenant_speculation():
    """A cold tenant floods the shared backend with deep speculation; a hot
    tenant's demand-only traffic (depth 0 — every op is demand) must still
    complete every call.  Structural guarantee checked via the scheduler:
    speculation never held more than ``capacity`` slots, and the hot
    tenant's sync ops are untouched by it."""
    dev = make_dev()
    fa = Foreactor(device=dev, backend="io_uring", depth=32, workers=4,
                   shared=True)
    fa.register("scan", lambda: build_pread_extents_graph("scan", weak=True))
    done = threading.Event()
    errors = []

    def cold():  # speculates far past its share, low priority
        try:
            fds = [dev.open(f"/s/f{i}", "r") for i in range(32)]
            extents = [(fd, 64, 0) for fd in fds]
            with fa.tenant("cold", priority="low"):
                @fa.wrap("scan", lambda: {"extents": extents})
                def scan():
                    return [io.pread(dev, fd, n, off)
                            for fd, n, off in extents]
                while not done.is_set():
                    scan()
        except BaseException as e:
            errors.append(e)

    def hot():
        try:
            fds = [dev.open(f"/s/f{i}", "r") for i in range(4)]
            extents = [(fd, 64, 0) for fd in fds]
            with fa.tenant("hot", priority="high"):
                @fa.wrap("scan", lambda: {"extents": extents})
                def scan():
                    return [io.pread(dev, fd, n, off)
                            for fd, n, off in extents]
                for _ in range(50):
                    out = scan()
                    assert all(len(b) == 64 for b in out)
        except BaseException as e:
            errors.append(e)
        finally:
            done.set()

    tc = threading.Thread(target=cold)
    th = threading.Thread(target=hot)
    tc.start(); th.start()
    th.join(timeout=60)
    done.set()
    tc.join(timeout=60)
    assert not th.is_alive() and not tc.is_alive(), "starvation/deadlock"
    assert not errors, errors
    snap = fa.scheduler.snapshot()
    assert snap["max_spec_inflight"] <= snap["capacity"], snap
    fa.shutdown()
