"""Sharded multi-device substrate tests: namespace routing, per-device queue
pairs, fan-out accounting, and the checkpoint/data-pipeline integrations."""

import numpy as np
import pytest

from repro.core import (DeviceProfile, Foreactor, GraphBuilder, MemDevice,
                        MultiQueueBackend, ShardedDevice, SimulatedDevice,
                        Sys, io, make_backend)
from repro.core.syscalls import IORequest
from repro.checkpoint import CheckpointManager
from repro.data import (DataConfig, ShardedTokenDataset, TokenBatchLoader,
                        write_synthetic_dataset)


def mem_sharded(n=4):
    return ShardedDevice([MemDevice() for _ in range(n)])


# -- namespace / routing -----------------------------------------------------
def test_prefixed_paths_pin_to_subdevice():
    dev = mem_sharded(4)
    fd = dev.open("shard2:/a/b", "w")
    dev.pwrite(fd, b"hello", 0)
    dev.close(fd)
    # the file exists on sub-device 2 under the bare path, nowhere else
    assert dev.devices[2].fstatat("/a/b").st_size == 5
    for i in (0, 1, 3):
        with pytest.raises(FileNotFoundError):
            dev.devices[i].fstatat("/a/b")
    assert dev.fstatat("shard2:/a/b").st_size == 5


def test_bare_paths_hash_route_consistently():
    dev = mem_sharded(4)
    fd = dev.open("/cfg/manifest.json", "w")
    dev.pwrite(fd, b"{}", 0)
    dev.close(fd)
    # read back through the same namespace: must find the same sub-device
    assert dev.fstatat("/cfg/manifest.json").st_size == 2


def test_place_spreads_round_robin():
    dev = mem_sharded(3)
    assert dev.place("/f", hint=0) == "shard0:/f"
    assert dev.place("/f", hint=4) == "shard1:/f"
    assert MemDevice().place("/f", hint=4) == "/f"  # flat devices: identity


def test_virtual_fds_do_not_collide():
    dev = mem_sharded(2)
    # both MemDevices hand out the same real fd numbers; virtual fds differ
    fd_a = dev.open("shard0:/x", "w")
    fd_b = dev.open("shard1:/y", "w")
    assert fd_a != fd_b
    dev.pwrite(fd_a, b"aa", 0)
    dev.pwrite(fd_b, b"bbbb", 0)
    assert dev.fstatat("shard0:/x").st_size == 2
    assert dev.fstatat("shard1:/y").st_size == 4


def test_getdents_merges_across_shards():
    dev = mem_sharded(3)
    for i in range(6):
        fd = dev.open(dev.place(f"/d/f{i}", hint=i), "w")
        dev.pwrite(fd, b"x", 0)
        dev.close(fd)
    assert dev.getdents("/d") == [f"f{i}" for i in range(6)]
    # MemDevice lists unknown dirs as empty (it never raises), so the union
    # is empty rather than an error
    assert dev.getdents("/nope") == []


def test_route_maps_requests_to_owning_queue():
    dev = mem_sharded(4)
    assert dev.route(Sys.FSTATAT, ("shard3:/p",)) == 3
    fd = dev.open("shard1:/q", "w")
    assert dev.route(Sys.PWRITE, (fd, b"z", 0)) == 1


# -- multi-queue backend -----------------------------------------------------
def test_make_backend_auto_and_type_guard():
    sharded = mem_sharded(2)
    assert isinstance(make_backend("auto", sharded), MultiQueueBackend)
    assert make_backend("auto", MemDevice()).name == "io_uring"
    with pytest.raises(TypeError):
        make_backend("multi_queue", MemDevice())


def test_multi_queue_crossings_charged_per_touched_device():
    dev = mem_sharded(4)
    be = make_backend("multi_queue", dev)
    fds = [dev.open(dev.place(f"/f{i}", hint=i), "w") for i in range(4)]
    for i, fd in enumerate(fds):
        be.prepare(IORequest(sc=Sys.PWRITE, args=(fd, b"d", 0)))
    assert be.submit_all() == 4
    be.drain()
    # one io_uring_enter per touched queue pair: each device crossed once
    assert [d.stats.crossings for d in dev.devices] == [1, 1, 1, 1]
    be.shutdown()


def test_multi_queue_external_synchrony_stat_loop():
    """Speculated execution over N devices is indistinguishable from serial."""
    dev = mem_sharded(4)
    paths = [dev.place(f"/d/f{i}", hint=i) for i in range(24)]
    for i, p in enumerate(paths):
        fd = dev.open(p, "w")
        dev.pwrite(fd, bytes([i % 251]) * (i + 1), 0)
        dev.close(fd)
    fa = Foreactor(device=dev, depth=8)  # auto -> multi_queue
    from repro.core.patterns import register_patterns
    register_patterns(fa)

    @fa.wrap("stat_list", lambda paths: {"paths": paths})
    def du(paths):
        return sum(io.fstatat(dev, p).st_size for p in paths)

    serial = sum(dev.fstatat(p).st_size for p in paths)
    assert du(paths) == serial
    assert fa.total_stats.served_async > 0
    fa.shutdown()


def test_multi_queue_batch_fans_out_beyond_one_device():
    """Aggregate in-flight concurrency must exceed a single device's channel
    count — the whole point of per-device queue pairs."""
    profile = DeviceProfile(channels=2, base_latency=5e-3,
                            metadata_latency=5e-3, crossing_cost=0.0)
    dev = ShardedDevice.simulated(4, profile=profile)
    paths = [dev.place(f"/d/f{i}", hint=i) for i in range(16)]
    for p in paths:
        shard, sub = dev.resolve(p)
        inner = dev.devices[shard].inner
        fd = inner.open(sub, "w")
        inner.pwrite(fd, b"z", 0)
        inner.close(fd)
    fa = Foreactor(device=dev, backend="multi_queue", depth=16, workers=2)
    from repro.core.patterns import register_patterns
    register_patterns(fa)

    @fa.wrap("stat_list", lambda paths: {"paths": paths})
    def du(paths):
        return sum(io.fstatat(dev, p).st_size for p in paths)

    assert du(paths) == 16
    assert dev.stats.max_inflight > profile.channels
    fa.shutdown()


def test_link_chain_stays_on_one_queue():
    """A linked pread->pwrite chain must execute in order even when the read
    and write target different sub-devices."""
    dev = mem_sharded(2)
    fd_in = dev.open("shard0:/in", "w")
    dev.pwrite(fd_in, bytes(range(32)), 0)
    fd_out = dev.open("shard1:/out", "w")

    from repro.core.graph import FromNode

    def g():
        b = GraphBuilder("xlink")
        b.AddSyscallNode("pread", Sys.PREAD,
                         lambda ctx, ep: ((fd_in, 32, 0), True))
        b.AddSyscallNode("pwrite", Sys.PWRITE,
                         lambda ctx, ep: ((fd_out, FromNode("pread"), 0), False))
        b.SyscallSetNext("pread", "pwrite")
        b.SyscallSetNext("pwrite", None)
        return b.Build()

    fa = Foreactor(device=dev, backend="multi_queue", depth=4)
    fa.register("xlink", g)

    @fa.wrap("xlink", lambda: {})
    def copy1():
        d = io.pread(dev, fd_in, 32, 0)
        io.pwrite(dev, fd_out, d, 0)

    copy1()
    assert dev.pread(fd_out, 32, 0) == bytes(range(32))
    fa.shutdown()


# -- consumers ---------------------------------------------------------------
def test_checkpoint_roundtrip_on_sharded_device():
    dev = mem_sharded(4)
    tree = {"w": np.arange(4096, dtype=np.float32).reshape(64, 64),
            "b": np.ones(33, dtype=np.float32)}
    mgr = CheckpointManager(dev, "/ck", num_shards=8, chunk_bytes=1 << 10)
    mgr.save(5, tree, extra={"epoch": 2})
    assert mgr.committed_steps() == [5]
    assert mgr.validate(5)
    restored, extra = mgr.restore_tree(5, tree)
    assert extra == {"epoch": 2}
    np.testing.assert_array_equal(restored["w"], tree["w"])
    # shard files really live on distinct sub-devices
    touched = [d.stats.snapshot()["write_bytes"] > 0 for d in dev.devices]
    assert all(touched)


def test_pipeline_on_sharded_device_matches_flat():
    cfg = DataConfig(seq_len=16, batch_size=4, seed=3)
    sharded = mem_sharded(4)
    flat = MemDevice()
    kw = dict(num_shards=8, records_per_shard=8, vocab_size=50, seed=7)
    sp = write_synthetic_dataset(sharded, "/data", cfg, **kw)
    fp = write_synthetic_dataset(flat, "/data", cfg, **kw)
    assert any(p.startswith("shard") for p in sp)  # placement happened
    ls = TokenBatchLoader(ShardedTokenDataset(sharded, sp), cfg)
    lf = TokenBatchLoader(ShardedTokenDataset(flat, fp), cfg, prefetch=False)
    for step in range(3):
        bs, bf = ls.load(0, step), lf.load(0, step)
        np.testing.assert_array_equal(bs["tokens"], bf["tokens"])
        np.testing.assert_array_equal(bs["labels"], bf["labels"])
    ls.close()
    lf.close()
