"""Session teardown coverage (paper §6.4 early exit, §5.3 graph mismatch):
cancellation of in-flight speculation, drain on deactivate, wasted-completion
accounting, and strict-mode GraphMismatch — on both the single queue pair and
the sharded multi-queue backend."""

import pytest

from repro.core import (DeviceProfile, Foreactor, GraphBuilder, GraphMismatch,
                        MemDevice, ShardedDevice, Sys, io)

SLOW = DeviceProfile(channels=2, base_latency=4e-3, metadata_latency=4e-3,
                     crossing_cost=0.0)


def make_device(backend, n=4, simulated=False):
    """A device compatible with the backend under test."""
    if backend == "multi_queue":
        if simulated:
            return ShardedDevice.simulated(n, profile=SLOW)
        return ShardedDevice([MemDevice() for _ in range(n)])
    if simulated:
        from repro.core import SimulatedDevice
        return SimulatedDevice(MemDevice(), SLOW)
    return MemDevice()


def seed_files(dev, count, backend, size=16):
    paths = [dev.place(f"/d/f{i}", hint=i) for i in range(count)]
    for i, p in enumerate(paths):
        fd = dev.open(p, "w")
        dev.pwrite(fd, bytes([i % 251]) * size, 0)
        dev.close(fd)
    return paths


def read_chain_weak_graph():
    """Pure reads behind weak edges: the function may exit at any step."""
    b = GraphBuilder("read_chain")
    b.AddSyscallNode(
        "pread", Sys.PREAD,
        lambda ctx, ep: (tuple(ctx["extents"][ep[0]]), False)
        if ep[0] < len(ctx["extents"]) else None)
    b.AddBranchingNode(
        "more", lambda ctx, ep: 0 if ep[0] + 1 < len(ctx["extents"]) else 1)
    b.SyscallSetNext("pread", "more", weak=True)
    b.BranchAppendChild("more", "pread", loopback=True)
    b.BranchAppendChild("more", None)
    return b.Build()


def stat_loop_graph():
    b = GraphBuilder("stat_loop")
    b.AddSyscallNode(
        "fstat", Sys.FSTATAT,
        lambda ctx, ep: ((ctx["paths"][ep[0]],), False)
        if ep[0] < len(ctx["paths"]) else None)
    b.AddBranchingNode(
        "more", lambda ctx, ep: 0 if ep[0] + 1 < len(ctx["paths"]) else 1)
    b.SyscallSetNext("fstat", "more")
    b.BranchAppendChild("more", "fstat", loopback=True)
    b.BranchAppendChild("more", None)
    return b.Build()


BACKENDS = ["io_uring", "user_threads", "multi_queue"]


@pytest.mark.parametrize("backend", BACKENDS)
def test_early_exit_cancels_inflight_speculation(backend):
    """On a slow device with a deep peek, an early exit must find some
    requests still queued (cancelled) and account the completed-but-unread
    ones as wasted; deactivate drains so nothing runs after."""
    dev = make_device(backend, simulated=True)
    # files live on the inner store; open through the public namespace
    paths = []
    for i in range(24):
        p = dev.place(f"/d/f{i}", hint=i)
        fd = dev.open(p, "w")
        dev.pwrite(fd, bytes([i % 251]) * 8, 0)
        dev.close(fd)
        paths.append(p)
    fa = Foreactor(device=dev, backend=backend, depth=24, workers=2)
    fa.register("read_chain", read_chain_weak_graph)
    extents = []
    for p in paths:
        fd = dev.open(p, "r")
        extents.append((fd, 8, 0))

    @fa.wrap("read_chain", lambda: {"extents": extents})
    def search():
        for i, (fd, n, off) in enumerate(extents):
            data = io.pread(dev, fd, n, off)
            if i == 1:  # found early
                return data
        return None

    out = search()
    assert out == bytes([1]) * 8
    s = fa.total_stats
    assert s.pre_issued > 2  # speculation ran past the exit point
    assert s.cancelled > 0  # slow device: some requests never started
    assert s.cancelled + s.wasted_completions <= s.pre_issued
    # drain happened on deactivate: no request is still in flight
    assert dev.stats.snapshot()["max_inflight"] >= 1
    with dev.stats._lock:
        assert dev.stats.inflight == 0
    fa.shutdown()


@pytest.mark.parametrize("backend", BACKENDS)
def test_wasted_completions_accounted_on_fast_device(backend):
    """On a fast device everything completes before the early exit, so the
    discarded work shows up as wasted_completions, not cancellations."""
    dev = make_device(backend, simulated=False)
    paths = seed_files(dev, 16, backend)
    fa = Foreactor(device=dev, backend=backend, depth=16)
    fa.register("read_chain", read_chain_weak_graph)
    extents = []
    for p in paths:
        fd = dev.open(p, "r")
        extents.append((fd, 16, 0))

    @fa.wrap("read_chain", lambda: {"extents": extents})
    def search():
        for i, (fd, n, off) in enumerate(extents):
            data = io.pread(dev, fd, n, off)
            if i == 2:
                return data
        return None

    assert search() == bytes([2]) * 16
    s = fa.total_stats
    assert s.pre_issued > 3
    assert s.cancelled + s.wasted_completions > 0
    fa.shutdown()


@pytest.mark.parametrize("backend", BACKENDS)
def test_strict_mode_raises_graph_mismatch(backend):
    dev = make_device(backend)
    paths = seed_files(dev, 4, backend)
    fa = Foreactor(device=dev, backend=backend, depth=4, strict=True)
    fa.register("stat_loop", stat_loop_graph)

    @fa.wrap("stat_loop", lambda paths: {"paths": paths})
    def bad(paths):
        fd = dev.open(paths[0], "r")  # graph expects fstatat, app opens
        return io.pread(dev, fd, 4, 0)

    with pytest.raises(GraphMismatch):
        bad(paths)
    fa.shutdown()


@pytest.mark.parametrize("backend", BACKENDS)
def test_lenient_mode_passes_mismatch_through(backend):
    dev = make_device(backend)
    paths = seed_files(dev, 4, backend)
    fa = Foreactor(device=dev, backend=backend, depth=4, strict=False)
    fa.register("stat_loop", stat_loop_graph)

    @fa.wrap("stat_loop", lambda paths: {"paths": paths})
    def mixed(paths):
        total = sum(io.fstatat(dev, p).st_size for p in paths)
        return total, io.getdents(dev, "/d")  # not in the graph: untracked

    total, names = mixed(paths)
    assert total == 4 * 16
    assert len(names) == 4
    assert fa.total_stats.untracked >= 1
    fa.shutdown()


@pytest.mark.parametrize("backend", BACKENDS)
def test_finish_after_stub_raises_mid_batch(backend):
    """Regression: a ComputeArgs stub raising *mid-peek* (after some
    requests were prepared but before the batch was submitted) must leave no
    request behind — finish() cancels/drains everything exactly once, the
    prepared-but-unsubmitted entries never execute, and the per-thread
    backend serves the next activation cleanly."""
    dev = make_device(backend)
    paths = seed_files(dev, 12, backend)

    class Boom(RuntimeError):
        pass

    def exploding_graph():
        b = GraphBuilder("exploding")

        def args(ctx, ep):
            if ep[0] >= len(ctx["paths"]):
                return None
            if ep[0] == 5:
                raise Boom("stub failure mid-peek")
            return ((ctx["paths"][ep[0]],), False)

        b.AddSyscallNode("fstat", Sys.FSTATAT, args)
        b.AddBranchingNode(
            "more", lambda ctx, ep: 0 if ep[0] + 1 < len(ctx["paths"]) else 1)
        b.SyscallSetNext("fstat", "more")
        b.BranchAppendChild("more", "fstat", loopback=True)
        b.BranchAppendChild("more", None)
        return b.Build()

    fa = Foreactor(device=dev, backend=backend, depth=12)
    fa.register("exploding", exploding_graph)

    @fa.wrap("exploding", lambda paths: {"paths": paths})
    def du(paths):
        return sum(io.fstatat(dev, p).st_size for p in paths)

    with pytest.raises(Boom):
        du(paths)  # the first intercept's peek walks into the raising stub
    s = fa.total_stats
    # everything pre-issued was either harvested, cancelled, or drained to
    # completion and accounted wasted — nothing is unaccounted or in flight
    assert s.pre_issued == s.served_async + s.cancelled + s.wasted_completions
    with dev.stats._lock:
        assert dev.stats.inflight == 0
    # the same thread's backend must be reusable for a healthy activation
    fa.register("stat_loop", stat_loop_graph)

    @fa.wrap("stat_loop", lambda paths: {"paths": paths})
    def du_ok(paths):
        return sum(io.fstatat(dev, p).st_size for p in paths)

    assert du_ok(paths) == 12 * 16
    fa.shutdown()


def test_finish_runs_accounting_even_if_teardown_raises():
    """Regression: finish() used to mark itself done before doing any work,
    so an error during teardown skipped the remaining steps and a retry
    returned without ever draining or accounting.  Now cancellation, drain
    and wasted-completion accounting are chained in finally blocks: an error
    in one step still runs the later ones, every request ends in a terminal
    state, and a second finish() is a no-op returning the same stats."""
    from repro.core.api import _session_stack
    from repro.core.syscalls import ReqState

    dev = make_device("io_uring")
    paths = seed_files(dev, 8, "io_uring")
    fa = Foreactor(device=dev, backend="io_uring", depth=8)
    fa.register("read_chain", read_chain_weak_graph)
    extents = []
    for p in paths:
        fd = dev.open(p, "r")
        extents.append((fd, 16, 0))
    sess = fa.activate("read_chain", {"extents": extents})
    try:
        io.pread(dev, extents[0][0], 16, 0)  # pre-issues the rest
    finally:
        _session_stack().pop()
    assert sess.stats.pre_issued > 0

    backend = sess.backend
    real_drain = backend.drain

    class DrainBoom(RuntimeError):
        pass

    def bad_drain():
        real_drain()  # the backend does quiesce...
        raise DrainBoom()  # ...but the teardown path errors afterwards

    backend.drain = bad_drain
    with pytest.raises(DrainBoom):
        sess.finish()
    backend.drain = real_drain
    # cancellation and accounting both ran despite the drain error:
    stats = sess.stats
    assert stats.pre_issued == stats.served_async + stats.cancelled \
        + stats.wasted_completions
    # every speculated request reached a terminal state (nothing leaks into
    # the next activation on this backend)
    for st in sess._state.values():
        if st.req is not None:
            assert st.req.state in (ReqState.COMPLETED, ReqState.CANCELLED)
    # idempotent: a second finish() does not double-cancel or double-count
    before = (stats.cancelled, stats.wasted_completions)
    assert sess.finish() is stats
    assert (stats.cancelled, stats.wasted_completions) == before
    fa.shutdown()


@pytest.mark.parametrize("backend", BACKENDS)
def test_session_finish_is_idempotent_and_backend_reusable(backend):
    """After a teardown the per-thread backend must serve the next
    activation (the paper keeps queue pairs live across invocations)."""
    dev = make_device(backend)
    paths = seed_files(dev, 8, backend)
    fa = Foreactor(device=dev, backend=backend, depth=8)
    fa.register("stat_loop", stat_loop_graph)

    @fa.wrap("stat_loop", lambda paths: {"paths": paths})
    def du(paths):
        return sum(io.fstatat(dev, p).st_size for p in paths)

    expect = sum(dev.fstatat(p).st_size for p in paths)
    assert du(paths) == expect
    assert du(paths) == expect  # same backend, fresh session
    sess = fa.activate("stat_loop", {"paths": paths})
    fa.deactivate(sess)
    assert sess.finish() is sess.stats  # idempotent finish
    fa.shutdown()


def test_orphaned_batch_resubmits_when_function_survives_stub_error():
    """A stub raising mid-walk quarantines the already-built batch; if the
    wrapped function catches the error and keeps running, the next
    intercept must re-offer those requests — otherwise the frontier
    demanding one waits forever on a request no worker ever received."""
    from repro.core import Foreactor, GraphBuilder, MemDevice, Sys, io

    dev = MemDevice()
    fd = dev.open("/o/f", "w")
    dev.pwrite(fd, bytes(range(64)), 0)
    dev.close(fd)

    boom = {"armed": True}

    def args_ok(i):
        return lambda ctx, ep: ((ctx["fd"], 8, i * 8), False)

    def args_boom(ctx, ep):
        if boom["armed"]:
            boom["armed"] = False
            raise RuntimeError("stub error on first peek")
        return ((ctx["fd"], 8, 16), False)

    b = GraphBuilder("orphans")
    b.AddSyscallNode("s0", Sys.PREAD, args_ok(0))
    b.AddSyscallNode("s1", Sys.PREAD, args_ok(1))
    b.AddSyscallNode("s2", Sys.PREAD, args_boom)
    b.SyscallSetNext("s0", "s1")
    b.SyscallSetNext("s1", "s2")
    b.SyscallSetNext("s2", None)
    fa = Foreactor(device=dev, backend="io_uring", depth=4, workers=2)
    fa.register("orphans", lambda: b.Build())
    rfd = dev.open("/o/f", "r")

    @fa.wrap("orphans", lambda: {"fd": rfd})
    def prog():
        out = []
        for i in range(3):
            try:
                out.append(io.pread(dev, rfd, 8, i * 8))
            except RuntimeError:
                # the stub error surfaces through the first intercept; the
                # function keeps going — s1 was stranded in the quarantine
                out.append(io.pread(dev, rfd, 8, i * 8))
        return out

    result = prog()
    stats = fa.total_stats
    fa.shutdown()
    assert result == [bytes(range(i * 8, i * 8 + 8)) for i in range(3)]
    assert stats.pre_issued == (stats.served_async + stats.cancelled
                                + stats.wasted_completions)
