"""Plan-compilation suite: lowering correctness, cache identity, and
schedule conformance of the plan interpreter against the paper's walk.

Three layers of guarantee:

1. **Cache identity** — compiling the same graph twice returns the *same*
   ``GraphPlan`` object (per (graph, depth-mode)); independent builds of the
   same authoring code lower to structurally identical plans.
2. **Lowering equivalence** — a symbolic walk over the plan's flat arrays
   visits exactly the nodes, epochs and weak flags a walk over the authoring
   object graph visits, for every reusable pattern and case-study plugin
   graph.
3. **Schedule conformance** — the integer-cursor interpreter pre-issues the
   same requests in the same order as the original object walker
   (Algorithm 1): for deterministic pure programs the walker's schedule is
   computable in closed form, and the engine must reproduce it at every
   depth, early exit or not.  (Byte-level result conformance across all
   backends × depths — including write-bearing programs — lives in
   tests/test_conformance.py, which runs the interpreter against the sync
   oracle.)
"""

import random

import pytest
from _hypothesis_support import HAS_HYPOTHESIS, given, settings, st

from repro.core import (Foreactor, GraphBuilder, MemDevice, QueuePairBackend,
                        Sys, compile_plan, io)
from repro.core.graph import BranchNode, SyscallNode
from repro.core.patterns import PATTERNS
from repro.core.plan import END, KIND_BRANCH, KIND_SYSCALL
from repro.store import plugins


def _all_reference_graphs():
    graphs = [(name, builder()) for name, builder in PATTERNS.items()]
    graphs += [
        ("du", plugins.build_du_graph()),
        ("cp", plugins.build_cp_graph()),
        ("bptree_scan", plugins.build_bptree_scan_graph()),
        ("bptree_load", plugins.build_bptree_load_graph()),
        ("lsm_get", plugins.build_lsm_get_graph()),
    ]
    return graphs


# -- cache identity -----------------------------------------------------------

def test_compile_twice_returns_identical_plan():
    for name, g in _all_reference_graphs():
        p1 = compile_plan(g)
        p2 = compile_plan(g)
        assert p1 is p2, name  # cache hit per callsite
        assert p1.structure() == p2.structure()


def test_cache_keyed_by_depth_mode():
    g = PATTERNS["pread_extents"]()
    fixed = compile_plan(g, "fixed")
    adaptive = compile_plan(g, "adaptive")
    assert fixed is not adaptive
    assert fixed.structure() == adaptive.structure()
    assert compile_plan(g, "fixed") is fixed
    assert compile_plan(g, "adaptive") is adaptive


def test_independent_builds_lower_to_identical_structure():
    """Two builds of the same authoring code differ as objects but must
    lower to byte-identical plan structures (stub identities excluded)."""
    for name, builder in PATTERNS.items():
        a, b = builder(), builder()
        assert a is not b
        assert compile_plan(a).structure() == compile_plan(b).structure(), name


def test_foreactor_plan_is_cached_per_graph():
    fa = Foreactor(device=MemDevice(), backend="sync")
    fa.register("extents", PATTERNS["pread_extents"])
    p1 = fa.plan("extents")
    assert p1 is fa.plan("extents")
    assert p1 is compile_plan(fa.graph("extents"),
                              "adaptive" if fa.depth == "adaptive" else "fixed")


# -- lowering equivalence -----------------------------------------------------

def _object_walk(graph, ctx, max_steps=200):
    """Reference walk over the authoring object graph (the original
    engine's cursor rules): yields (name, sc, epochs, weak-into-node)."""
    out = []
    node, epochs, weak = graph.start.dst, graph.initial_epochs(), graph.start.weak
    steps = 0
    while steps < max_steps:
        while isinstance(node, BranchNode):
            idx = node.choose(ctx, epochs)
            if idx is None:
                return out, "stall"
            e = node.children[idx]
            if e.loop_id is not None:
                lst = list(epochs)
                lst[e.loop_id] += 1
                epochs = tuple(lst)
            weak = weak or e.weak
            node = e.dst
        if node is None:
            return out, "end"
        assert isinstance(node, SyscallNode)
        out.append((node.name, node.sc, epochs, weak))
        e = node.out
        if e.loop_id is not None:
            lst = list(epochs)
            lst[e.loop_id] += 1
            epochs = tuple(lst)
        weak = e.weak
        node = e.dst
        steps += 1
    return out, "limit"


def _plan_walk(plan, ctx, max_steps=200):
    """The same walk over the compiled plan's flat arrays."""
    out = []
    nid, epochs, weak = plan.start_dst, plan.initial_epochs(), plan.start_weak
    steps = 0
    while steps < max_steps:
        res = plan.resolve_branches(nid, epochs, ctx, weak)
        if res is None:
            return out, "stall"
        nid, epochs, weak = res
        if nid == END:
            return out, "end"
        assert plan.kind[nid] == KIND_SYSCALL
        out.append((plan.names[nid], plan.sc[nid], epochs, weak))
        nid, epochs, weak = plan.follow_out(nid, epochs)
        steps += 1
    return out, "limit"


# only the Choice stubs run during a symbolic walk, so each ctx carries the
# branch-decision inputs (plus whatever they read transitively)
WALK_CTXS = {
    "stat_list": {"paths": ["/a", "/b", "/c"]},
    "open_list": {"paths": ["/a", "/b"]},
    "pread_extents": {"extents": [(3, 8, 0), (3, 8, 8), (3, 8, 16)]},
    "pwrite_extents": {"writes": [(3, b"x" * 4, 0), (3, b"y" * 4, 4)]},
    "write_file": {"path": "/f", "writes": [(b"x" * 4, 0)]},
    "copy_extents": {"pairs": [(3, 4, 8, 0), (3, 4, 8, 8)]},
    "unlink_list": {"victims": ["/a", "/b", "/c"]},
    "du": {"root": "/d", "entries": ["x", "y"]},
    "cp": {"src": "/s", "dst": "/d", "buf_size": 4096, "size": 8192,
           "sfd": 3, "dfd": 4},
    "bptree_scan": {"fd": 3, "page_size": 64, "first_leaf": 0,
                    "last_leaf": 1},
    "bptree_load": {"nleaves": 2},
    "lsm_get": {"cands": [1, 2], "key": 1},
}


@pytest.mark.parametrize("name,graph",
                         _all_reference_graphs(),
                         ids=[n for n, _ in _all_reference_graphs()])
def test_plan_walk_matches_object_walk(name, graph):
    ctx = dict(WALK_CTXS[name])
    ref, ref_endstate = _object_walk(graph, dict(ctx))
    got, got_endstate = _plan_walk(compile_plan(graph), dict(ctx))
    assert got == ref
    assert got_endstate == ref_endstate


def test_topological_ids_are_dense_and_complete():
    for name, g in _all_reference_graphs():
        p = compile_plan(g)
        assert sorted(p.id_of.values()) == list(range(p.num_nodes)), name
        assert set(p.id_of) == set(g.syscall_nodes) | set(g.branch_nodes)
        for nid in range(p.num_nodes):
            if p.kind[nid] == KIND_BRANCH:
                assert p.choose[nid] is not None
            else:
                assert p.compute[nid] is not None


# -- schedule conformance -----------------------------------------------------
# For an all-pure chain of N nodes with weak edges and an early exit after
# `exit_at` serves, Algorithm 1's pre-issue schedule is closed-form: the
# first intercept issues nodes 1..depth (node 0 is the frontier), and each
# later intercept slides the window by one — overall, nodes 1..min(exit_at-1
# + depth, N-1) in node order, each exactly once.  The original object
# walker produced exactly this; the plan interpreter must too.

def _expected_chain_schedule(n_nodes, exit_at, depth):
    upper = min(exit_at - 1 + depth, n_nodes - 1)
    return [f"s{i}" for i in range(1, upper + 1)]


class _ScheduleSpy:
    def __init__(self, inner):
        self.inner = inner
        self.order = []

    def submit(self, batch):
        self.order.extend(r.tag for r in batch)
        return self.inner.submit(batch)

    def __getattr__(self, name):
        return getattr(self.inner, name)


def _run_chain(n_nodes, exit_at, depth):
    dev = MemDevice()
    fd = dev.open("/f", "w")
    dev.pwrite(fd, bytes(64), 0)
    dev.close(fd)
    b = GraphBuilder("chain")
    prev = None
    for i in range(n_nodes):
        b.AddSyscallNode(f"s{i}", Sys.PREAD,
                         lambda ctx, ep, i=i: ((ctx["fd"], 8, i), False))
        if prev is not None:
            b.SyscallSetNext(prev, f"s{i}", weak=True)
        prev = f"s{i}"
    b.SyscallSetNext(prev, None, weak=True)
    graph = b.Build()

    fa = Foreactor(device=dev, backend="io_uring", depth=depth, workers=4)
    fa.register("chain", lambda: graph)
    rfd = dev.open("/f", "r")

    spy_holder = {}

    @fa.wrap("chain", lambda: {"fd": rfd})
    def prog():
        from repro.core.api import current_session
        sess = current_session()
        if not isinstance(sess.backend, _ScheduleSpy):
            sess.backend = _ScheduleSpy(sess.backend)
        spy_holder["spy"] = sess.backend
        for i in range(exit_at):
            io.pread(dev, rfd, 8, i)

    prog()
    plan = fa.plan("chain")
    names = [plan.names[nid] for (nid, _ep) in spy_holder["spy"].order]
    fa.shutdown()
    return names


@pytest.mark.parametrize("depth", [1, 2, 4, 16])
@pytest.mark.parametrize("n_nodes,exit_at", [(12, 12), (12, 1), (12, 5),
                                             (3, 2)])
def test_interpreter_schedule_matches_walker_closed_form(n_nodes, exit_at,
                                                         depth):
    got = _run_chain(n_nodes, exit_at, depth)
    assert got == _expected_chain_schedule(n_nodes, exit_at, depth)


# -- property sweep (hypothesis) ---------------------------------------------

if HAS_HYPOTHESIS:
    _seed_strategy = st.integers(min_value=0, max_value=2 ** 31)
else:
    _seed_strategy = st.integers()


@settings(max_examples=30, deadline=None)
@given(seed=_seed_strategy)
def test_random_chain_graphs_compile_deterministically(seed):
    """Random chain/branch graphs: two independent builds lower to the same
    structure, and the cache returns one object per build."""
    rng = random.Random(seed)
    length = rng.randint(1, 12)
    weaks = [rng.random() < 0.5 for _ in range(length)]

    def build():
        b = GraphBuilder(f"r{seed}")
        prev = None
        for i in range(length):
            b.AddSyscallNode(f"s{i}", Sys.PREAD,
                             lambda ctx, ep, i=i: ((0, 1, i), False))
            if prev is not None:
                b.SyscallSetNext(prev, f"s{i}", weak=weaks[i])
            prev = f"s{i}"
        b.SyscallSetNext(prev, None, weak=weaks[0])
        return b.Build()

    g1, g2 = build(), build()
    p1, p2 = compile_plan(g1), compile_plan(g2)
    assert p1 is compile_plan(g1)
    assert p2 is compile_plan(g2)
    assert p1 is not p2
    assert p1.structure() == p2.structure()


def test_loop_back_only_reachable_node_compiles():
    """The validator accepts a do-while shape where the body is reachable
    only through the loop-back edge; lowering must give it an id too."""
    b = GraphBuilder("dowhile")
    b.AddSyscallNode("a", Sys.PREAD, lambda ctx, ep: ((0, 1, 0), False))
    b.AddBranchingNode("br", lambda ctx, ep: 0 if ep[0] < 2 else 1)
    b.AddSyscallNode("x", Sys.PREAD, lambda ctx, ep: ((0, 1, 1), False))
    b.SyscallSetNext("a", "br")
    b.BranchAppendChild("br", "x", loopback=True)
    b.BranchAppendChild("br", None)
    b.SyscallSetNext("x", "br")
    g = b.Build()  # validator passes: x is reachable via the loop edge
    p = compile_plan(g)
    assert set(p.id_of) == {"a", "br", "x"}
    walk, end = _plan_walk(p, {})
    assert [w[0] for w in walk] == ["a", "x", "x"] and end == "end"
