"""Foreaction-graph structure tests (paper §3.2) + hypothesis properties."""

import pytest
from _hypothesis_support import given, settings, st

from repro.core.graph import GraphBuilder, ForeactionGraph
from repro.core.syscalls import Sys, is_pure


def _linear_loop(n_pre=1):
    b = GraphBuilder("g")
    b.AddSyscallNode("read", Sys.PREAD, lambda ctx, ep: ((1, 4, 0), False))
    b.AddBranchingNode("more", lambda ctx, ep: 0 if ep[0] < 3 else 1)
    b.SyscallSetNext("read", "more")
    b.BranchAppendChild("more", "read", loopback=True)
    b.BranchAppendChild("more", None)
    return b.Build()


def test_builder_basic():
    g = _linear_loop()
    assert g.num_loops == 1
    assert g.start.dst.name == "read"
    g.validate()


def test_forward_reference_wiring():
    b = GraphBuilder("fwd")
    b.AddSyscallNode("a", Sys.PREAD, lambda c, e: None)
    b.SyscallSetNext("a", "br")  # br not defined yet
    b.AddBranchingNode("br", lambda c, e: 1)
    b.BranchAppendChild("br", "a", loopback=True)
    b.BranchAppendChild("br", None)
    g = b.Build()
    assert g.syscall_nodes["a"].out.dst.name == "br"


def test_duplicate_name_rejected():
    b = GraphBuilder("dup")
    b.AddSyscallNode("x", Sys.PREAD, lambda c, e: None)
    with pytest.raises(ValueError):
        b.AddSyscallNode("x", Sys.PWRITE, lambda c, e: None)


def test_missing_edge_rejected():
    b = GraphBuilder("dangling")
    b.AddSyscallNode("x", Sys.PREAD, lambda c, e: None)
    with pytest.raises(ValueError):
        b.Build()  # no outgoing edge on x


def test_unreachable_rejected():
    b = GraphBuilder("unreachable")
    b.AddSyscallNode("x", Sys.PREAD, lambda c, e: None)
    b.SyscallSetNext("x", None)
    b.AddSyscallNode("orphan", Sys.PREAD, lambda c, e: None)
    b.SyscallSetNext("orphan", None)
    with pytest.raises(ValueError, match="unreachable"):
        b.Build()


def test_purity_classification():
    assert is_pure(Sys.PREAD, (1, 2, 3))
    assert is_pure(Sys.FSTATAT, ("/x",))
    assert is_pure(Sys.GETDENTS, ("/d",))
    assert is_pure(Sys.OPEN, ("/f", "r"))
    assert not is_pure(Sys.OPEN, ("/f", "w"))
    assert not is_pure(Sys.PWRITE, (1, b"x", 0))
    assert not is_pure(Sys.FSYNC, (1,))


def test_to_dot_renders():
    dot = _linear_loop().to_dot()
    assert "digraph" in dot and "read" in dot and "style=dashed" not in dot


@settings(max_examples=50, deadline=None)
@given(n=st.integers(1, 8), weak_at=st.integers(0, 7))
def test_chain_graphs_validate(n, weak_at):
    """Any linear chain of syscall nodes with one optional weak edge is a
    valid foreaction graph."""
    b = GraphBuilder("chain")
    for i in range(n):
        b.AddSyscallNode(f"s{i}", Sys.PREAD, lambda c, e: None)
    for i in range(n - 1):
        b.SyscallSetNext(f"s{i}", f"s{i+1}", weak=(i == weak_at))
    b.SyscallSetNext(f"s{n-1}", None)
    g = b.Build()
    g.validate()
    assert len(g.syscall_nodes) == n
    assert g.num_loops == 0
