"""Per-arch smoke tests (reduced configs, real CPU step) + decode
consistency + MoE oracle equivalence + layer-group invariants."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from dataclasses import replace

from repro.configs import ARCH_IDS, get_config
from repro.models import build_model
from repro.models import mlp as mlpm
from repro.models.lm import layer_groups

RNG = jax.random.PRNGKey(0)
B, S = 2, 32


def make_batch(cfg, B, S, key=jax.random.PRNGKey(1)):
    batch = {
        "tokens": jax.random.randint(key, (B, S), 0, cfg.vocab_size),
        "labels": jax.random.randint(key, (B, S), 0, cfg.vocab_size),
    }
    if cfg.visual_stub:
        batch["visual_embeds"] = jax.random.normal(key, (B, 8, cfg.d_model), jnp.float32)
    if cfg.enc_dec is not None:
        batch["frames"] = jax.random.normal(
            key, (B, cfg.enc_dec.n_audio_ctx, cfg.d_model), jnp.float32)
    return batch


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_arch_smoke_train_step(arch):
    """One forward+backward on the reduced config: finite loss + grads,
    correct logits shape."""
    cfg = get_config(arch, smoke=True)
    model = build_model(cfg)
    params = model.init(RNG)
    batch = make_batch(cfg, B, S)
    loss = jax.jit(model.loss)(params, batch)
    assert np.isfinite(float(loss))
    grads = jax.grad(lambda p: model.loss(p, batch))(params)
    gn = sum(jnp.sum(g.astype(jnp.float32) ** 2) for g in jax.tree.leaves(grads))
    assert np.isfinite(float(gn)) and float(gn) > 0
    logits, cache = jax.jit(lambda p, b: model.prefill(p, b, S + 4))(params, batch)
    assert logits.shape == (B, cfg.padded_vocab)
    assert np.isfinite(np.asarray(logits[:, : cfg.vocab_size])).all()


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_arch_decode_matches_forward(arch):
    cfg = get_config(arch, smoke=True)
    model = build_model(cfg)
    params = model.init(RNG)
    batch = make_batch(cfg, B, S)
    toks = batch["tokens"]
    P = S - 4
    pb = dict(batch)
    pb["tokens"] = toks[:, :P]
    logits, cache = model.prefill(params, pb, S)
    if model.is_enc_dec:
        for t in range(P, S):
            logits, cache = model.decode_step(
                params, cache, toks[:, t], jnp.full((B,), t, jnp.int32))
        full_logits, _ = model.prefill(params, batch, S)
        np.testing.assert_allclose(
            np.asarray(logits)[:, : cfg.vocab_size],
            np.asarray(full_logits)[:, : cfg.vocab_size], atol=2e-3, rtol=2e-3)
        return
    full = model.logits(params, batch)
    np.testing.assert_allclose(np.asarray(logits), np.asarray(full[:, P - 1]),
                               atol=2e-3, rtol=2e-3)
    for t in range(P, S):
        logits, cache = model.decode_step(
            params, cache, toks[:, t], jnp.full((B,), t, jnp.int32))
        np.testing.assert_allclose(np.asarray(logits), np.asarray(full[:, t]),
                                   atol=2e-3, rtol=2e-3)


def test_moe_capacity_and_dropless_match_oracle():
    cfg = get_config("granite_moe_3b_a800m", smoke=True)
    cfg = replace(cfg, moe=replace(cfg.moe, dropless=False, capacity_factor=8.0,
                                   group_tokens=32))
    k = jax.random.PRNGKey(3)
    p = mlpm.moe_init(cfg, k)
    x = jax.random.normal(k, (2, 64, cfg.d_model), jnp.float32)
    y_cap, aux = mlpm.moe_apply(cfg, p, x)
    y_oracle = mlpm.moe_apply_dense_oracle(cfg, p, x)
    np.testing.assert_allclose(np.asarray(y_cap), np.asarray(y_oracle),
                               atol=2e-4, rtol=2e-4)
    cfg2 = replace(cfg, moe=replace(cfg.moe, dropless=True))
    y_dl, _ = mlpm.moe_apply(cfg2, p, x)
    np.testing.assert_allclose(np.asarray(y_dl), np.asarray(y_oracle),
                               atol=2e-4, rtol=2e-4)
    assert float(aux) >= 0


def test_moe_capacity_drops_bounded():
    """With cf=1 some tokens may drop, but output stays finite and close in
    norm to the oracle (regularization-level deviation, not corruption)."""
    cfg = get_config("granite_moe_3b_a800m", smoke=True)
    cfg = replace(cfg, moe=replace(cfg.moe, dropless=False, capacity_factor=1.0,
                                   group_tokens=64))
    k = jax.random.PRNGKey(4)
    p = mlpm.moe_init(cfg, k)
    x = jax.random.normal(k, (2, 64, cfg.d_model), jnp.float32)
    y, _ = mlpm.moe_apply(cfg, p, x)
    y_oracle = mlpm.moe_apply_dense_oracle(cfg, p, x)
    assert np.isfinite(np.asarray(y)).all()
    rel = float(jnp.linalg.norm(y - y_oracle) / jnp.linalg.norm(y_oracle))
    assert rel < 0.9


def test_layer_groups_partition_blocks():
    for arch in ARCH_IDS:
        cfg = get_config(arch, smoke=True)
        if cfg.enc_dec is not None:
            continue
        gs = layer_groups(cfg)
        assert sum(g.count for g in gs) == cfg.n_layers
        # groups tile the pattern contiguously
        i = 0
        for g in gs:
            assert g.start == i
            for j in range(g.count):
                assert cfg.blocks[i + j] == g.kind
            i += g.count


def test_mrope_equals_rope_for_text_positions():
    """With all three position streams equal, M-RoPE must reduce to RoPE."""
    from repro.models.common import apply_mrope, apply_rope

    k = jax.random.PRNGKey(0)
    x = jax.random.normal(k, (2, 16, 4, 32), jnp.float32)
    pos = jnp.broadcast_to(jnp.arange(16)[None], (2, 16))
    pos3 = jnp.broadcast_to(pos[None], (3, 2, 16))
    a = apply_rope(x, pos, 10000.0)
    b = apply_mrope(x, pos3, 10000.0, (4, 6, 6))
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-5, rtol=1e-5)


def test_chunked_loss_matches_full_softmax():
    from repro.models.common import chunked_softmax_xent, lm_head_logits

    cfg = get_config("tinyllama_1_1b", smoke=True)
    k = jax.random.PRNGKey(0)
    emb = {"tok": jax.random.normal(k, (cfg.padded_vocab, cfg.d_model)) * 0.02}
    h = jax.random.normal(k, (2, 64, cfg.d_model), jnp.float32)
    labels = jax.random.randint(k, (2, 64), 0, cfg.vocab_size)
    l1 = chunked_softmax_xent(cfg, emb, None, h, labels)
    logits = lm_head_logits(cfg, emb, None, h)
    lse = jax.nn.logsumexp(logits, -1)
    lab = jnp.take_along_axis(logits, labels[..., None], -1)[..., 0]
    l2 = (lse - lab).mean()
    np.testing.assert_allclose(float(l1), float(l2), rtol=1e-5)
