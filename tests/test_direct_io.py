"""Direct-I/O lane tests: the O_DIRECT probe, alignment reporting, bounce
reads, and alignment-classed buffer leases.

The O_DIRECT end-to-end test is *opportunistic*: many CI filesystems
(tmpfs, overlayfs) refuse the flag at open time, which the device is
specified to survive by falling back to buffered I/O per fd.  When the
probe falls back, the test verifies the fallback accounting and skips the
direct-only assertions — nothing in CI hard-requires O_DIRECT.
"""

import mmap
import os

import pytest

from repro.core import MemDevice, OSDevice, ShardedDevice, SimulatedDevice
from repro.core.buffers import ALIGNMENT_CLASSES, BufferPool


PAYLOAD = bytes((i * 31 + 7) % 251 for i in range(2 * 4096 + 100))


def _write(dev, path):
    fd = dev.open(path, "w")
    dev.pwrite(fd, PAYLOAD, 0)
    dev.fsync(fd)
    dev.close(fd)


# -- alignment reporting ------------------------------------------------------

def test_alignment_reporting():
    assert OSDevice().alignment == 0
    assert OSDevice(direct=True).alignment == 4096
    assert SimulatedDevice(MemDevice()).alignment == 0
    assert SimulatedDevice(MemDevice(), direct=True).alignment == 512
    assert MemDevice().alignment == 0  # Device default


def test_sharded_alignment_is_strictest_sub_device():
    devs = [MemDevice() for _ in range(3)]
    sharded = ShardedDevice(devs)
    assert sharded.alignment == 0
    devs[1].alignment = 512
    assert sharded.alignment == 512
    devs[2].alignment = 4096
    assert sharded.alignment == 4096
    assert ShardedDevice.simulated(2, direct=True).alignment == 512


def test_simulated_direct_disables_page_cache():
    dev = SimulatedDevice(MemDevice(), cache_bytes=1 << 20, direct=True)
    assert dev.cache is None  # O_DIRECT bypasses the page cache
    assert SimulatedDevice(MemDevice(), cache_bytes=1 << 20).cache is not None


# -- aligned buffer classes ---------------------------------------------------

def test_aligned_lease_classes_and_freelist_separation():
    assert ALIGNMENT_CLASSES == (0, 512, 4096)
    pool = BufferPool()
    plain = pool.lease(1000)
    aligned = pool.lease(1000, alignment=4096)
    assert not plain.aligned and aligned.aligned
    # mmap slabs are page-aligned: valid O_DIRECT targets for both classes
    addr = (ctypes_address(aligned.mv))
    assert addr % 4096 == 0
    plain.release()
    aligned.release()
    # recycling never crosses classes: an aligned request must not get the
    # plain bytearray back
    again = pool.lease(1000, alignment=512)
    assert again.aligned
    again.release()
    with pytest.raises(ValueError):
        pool.lease(64, alignment=256)  # not an alignment class


def ctypes_address(mv) -> int:
    import ctypes
    c = (ctypes.c_char * len(mv)).from_buffer(mv)
    try:
        return ctypes.addressof(c)
    finally:
        del c


# -- OSDevice direct lane -----------------------------------------------------

def test_osdevice_direct_probe_and_bounce_reads(tmp_path):
    """Opportunistic O_DIRECT: asserts the direct lane end to end when the
    mount accepts the flag, and the per-fd buffered fallback when not."""
    path = str(tmp_path / "data.bin")
    dev = OSDevice(direct=True)
    _write(dev, path)  # write path is always buffered

    fd = dev.open(path, "r")
    try:
        # correctness must hold either way: aligned, unaligned, EOF-short
        assert dev.pread(fd, 4096, 0) == PAYLOAD[:4096]
        assert dev.pread(fd, 50, 100) == PAYLOAD[100:150]
        assert dev.pread(fd, 4096, 2 * 4096) == PAYLOAD[2 * 4096:]
        assert dev.pread(fd, 16, len(PAYLOAD) + 4096) == b""

        # pread_into with an aligned mmap slab (the lease fast path)
        buf = mmap.mmap(-1, 4096)
        try:
            n = dev.pread_into(fd, memoryview(buf), 4096)
            assert n == 4096 and buf[:n] == PAYLOAD[4096: 2 * 4096]
        finally:
            buf.close()
        # pread_into with an unaligned length: bounce path
        small = bytearray(100)
        n = dev.pread_into(fd, small, 8)
        assert n == 100 and bytes(small) == PAYLOAD[8:108]

        if dev.direct_opens == 0:
            assert dev.direct_fallbacks >= 1  # probe refused, counted
            pytest.skip("mount refuses O_DIRECT; buffered fallback verified")
        assert dev._is_direct(fd)
    finally:
        dev.close(fd)
    assert not dev._is_direct(fd)  # close retires the direct fd


def test_osdevice_buffered_mode_never_probes(tmp_path):
    path = str(tmp_path / "plain.bin")
    dev = OSDevice()
    _write(dev, path)
    fd = dev.open(path, "r")
    try:
        assert dev.direct_opens == 0 and dev.direct_fallbacks == 0
        assert not dev._is_direct(fd)
        assert dev.pread(fd, 64, 32) == PAYLOAD[32:96]
    finally:
        dev.close(fd)
