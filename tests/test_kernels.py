"""Pallas kernel sweeps: every kernel vs its pure-jnp oracle, in
interpret mode (the kernel body executes in Python on CPU), across
shapes and dtypes; plus custom-vjp gradient checks on the ops wrappers."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref
from repro.kernels.decode_attention import flash_decode
from repro.kernels.flash_attention import flash_attention_fwd
from repro.kernels.mamba2_scan import mamba2_scan
from repro.kernels.rwkv6_scan import rwkv6_scan

RNG = np.random.default_rng(0)


def rnd(shape, dtype=jnp.float32, scale=1.0):
    return jnp.asarray(RNG.normal(size=shape) * scale, dtype)


# -- flash attention ------------------------------------------------------------
@pytest.mark.parametrize("B,H,KV,S,T,D,causal", [
    (1, 4, 4, 128, 128, 64, True),
    (2, 8, 2, 128, 256, 64, True),     # GQA + cross lengths
    (1, 2, 1, 256, 256, 128, False),   # MQA, non-causal
    (1, 4, 2, 128, 128, 256, True),    # gemma-size head_dim
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_attention_sweep(B, H, KV, S, T, D, causal, dtype):
    q, k, v = rnd((B, H, S, D), dtype), rnd((B, KV, T, D), dtype), rnd((B, KV, T, D), dtype)
    o_ref = ref.attention_naive(q, k, v, causal)
    o_ker = flash_attention_fwd(q, k, v, causal, block_q=64, block_k=64,
                                interpret=True)
    tol = 2e-5 if dtype == jnp.float32 else 2e-2
    np.testing.assert_allclose(np.asarray(o_ref, np.float32),
                               np.asarray(o_ker, np.float32), atol=tol, rtol=tol)


def test_blockwise_ref_matches_naive_ragged_lengths():
    q, k, v = rnd((2, 4, 300, 64)), rnd((2, 2, 300, 64)), rnd((2, 2, 300, 64))
    o1 = ref.attention_naive(q, k, v, True)
    o2 = ref.attention_blockwise(q, k, v, True, block_q=128, block_k=128)
    np.testing.assert_allclose(o1, o2, atol=2e-5, rtol=2e-5)


# -- decode attention -------------------------------------------------------------
@pytest.mark.parametrize("B,H,KV,T,D", [
    (2, 8, 2, 512, 64), (1, 4, 1, 1024, 128), (3, 6, 6, 512, 64)])
def test_flash_decode_sweep(B, H, KV, T, D):
    q = rnd((B, H, D))
    k, v = rnd((B, KV, T, D)), rnd((B, KV, T, D))
    length = jnp.asarray(RNG.integers(1, T + 1, B), jnp.int32)
    o_ref = ref.decode_attention_naive(q, k, v, length)
    o_ker = flash_decode(q, k, v, length, block_k=128, interpret=True)
    np.testing.assert_allclose(o_ref, o_ker, atol=2e-5, rtol=2e-5)


# -- mamba2 -------------------------------------------------------------------------
@pytest.mark.parametrize("B,S,H,P,G,N,chunk,hb", [
    (2, 128, 8, 16, 2, 8, 32, 4),
    (1, 256, 4, 32, 1, 16, 64, 4),   # single group (zamba2 style)
    (2, 64, 8, 64, 8, 32, 32, 8),    # per-head groups
])
def test_mamba2_kernel_sweep(B, S, H, P, G, N, chunk, hb):
    x = rnd((B, S, H, P))
    dt = jnp.asarray(RNG.uniform(0.01, 0.2, (B, S, H)), jnp.float32)
    A = jnp.asarray(-RNG.uniform(0.5, 2, H), jnp.float32)
    Bm, Cm = rnd((B, S, G, N)), rnd((B, S, G, N))
    h0 = rnd((B, H, P, N))
    y1, h1 = ref.mamba2_scan_naive(x, dt, A, Bm, Cm, h0)
    y2, h2 = mamba2_scan(x, dt, A, Bm, Cm, h0, chunk=chunk, head_block=hb,
                         interpret=True)
    np.testing.assert_allclose(y1, y2, atol=1e-4, rtol=1e-4)
    np.testing.assert_allclose(h1, h2, atol=1e-4, rtol=1e-4)


# -- rwkv6 ---------------------------------------------------------------------------
@pytest.mark.parametrize("B,S,H,K,V,chunk,sub", [
    (2, 128, 4, 16, 16, 32, 16),
    (1, 256, 2, 64, 64, 64, 32),
    (2, 64, 8, 32, 32, 64, 32),
])
def test_rwkv6_kernel_sweep(B, S, H, K, V, chunk, sub):
    r, k, v = rnd((B, S, H, K)), rnd((B, S, H, K)), rnd((B, S, H, V))
    w = jnp.asarray(-RNG.uniform(0.01, 3.0, (B, S, H, K)), jnp.float32)
    u = rnd((H, K))
    s0 = rnd((B, H, K, V))
    yc, sc = ref.rwkv6_scan_chunked(r, k, v, w, u, s0, chunk=chunk)
    y2, s2 = rwkv6_scan(r, k, v, w, u, s0, chunk=chunk, sub=sub, interpret=True)
    np.testing.assert_allclose(yc, y2, atol=5e-5, rtol=5e-5)
    np.testing.assert_allclose(sc, s2, atol=5e-5, rtol=5e-5)
    # and the chunked math equals the token recurrence
    yn, sn = ref.rwkv6_scan_naive(r, k, v, w, u, s0)
    np.testing.assert_allclose(yn, y2, atol=2e-3, rtol=2e-3)


# -- decode steps equal scan prefixes -------------------------------------------------
def test_mamba2_decode_equals_scan():
    B, S, H, P, G, N = 2, 16, 4, 8, 2, 8
    x = rnd((B, S, H, P))
    dt = jnp.asarray(RNG.uniform(0.01, 0.2, (B, S, H)), jnp.float32)
    A = jnp.asarray(-RNG.uniform(0.5, 2, H), jnp.float32)
    Bm, Cm = rnd((B, S, G, N)), rnd((B, S, G, N))
    y, _ = ref.mamba2_scan_naive(x, dt, A, Bm, Cm)
    h = jnp.zeros((B, H, P, N), jnp.float32)
    for t in range(S):
        yt, h = ops.mamba2_decode(x[:, t], dt[:, t], A, Bm[:, t], Cm[:, t], h)
        np.testing.assert_allclose(yt, y[:, t], atol=3e-5, rtol=3e-5)


def test_rwkv6_decode_equals_scan():
    B, S, H, K = 2, 16, 4, 8
    r, k, v = rnd((B, S, H, K)), rnd((B, S, H, K)), rnd((B, S, H, K))
    w = jnp.asarray(-RNG.uniform(0.05, 1.0, (B, S, H, K)), jnp.float32)
    u = rnd((H, K))
    y, _ = ref.rwkv6_scan_naive(r, k, v, w, u)
    s = jnp.zeros((B, H, K, K), jnp.float32)
    for t in range(S):
        yt, s = ops.rwkv6_decode(r[:, t], k[:, t], v[:, t], w[:, t], u, s)
        np.testing.assert_allclose(yt, y[:, t], atol=3e-5, rtol=3e-5)


# -- custom vjp: pallas fwd + ref bwd == ref fwd+bwd ------------------------------------
def test_attention_custom_vjp_grads():
    q, k, v = rnd((1, 2, 64, 32)), rnd((1, 2, 64, 32)), rnd((1, 2, 64, 32))

    def f_ker(q, k, v):
        return ops.attention(q, k, v, causal=True, impl="interpret").sum()

    def f_ref(q, k, v):
        return ops.attention(q, k, v, causal=True, impl="ref").sum()

    g1 = jax.grad(f_ker, argnums=(0, 1, 2))(q, k, v)
    g2 = jax.grad(f_ref, argnums=(0, 1, 2))(q, k, v)
    for a, b in zip(g1, g2):
        np.testing.assert_allclose(a, b, atol=2e-4, rtol=2e-4)


def test_mamba2_custom_vjp_grads():
    B, S, H, P, G, N = 1, 64, 2, 8, 1, 8
    x = rnd((B, S, H, P))
    dt = jnp.asarray(RNG.uniform(0.01, 0.2, (B, S, H)), jnp.float32)
    A = jnp.asarray(-RNG.uniform(0.5, 2, H), jnp.float32)
    Bm, Cm = rnd((B, S, G, N)), rnd((B, S, G, N))

    def f(impl):
        def g(x, Bm, Cm):
            y, _ = ops.mamba2(x, dt, A, Bm, Cm, impl=impl, chunk=32)
            return (y ** 2).sum()
        return g

    g1 = jax.grad(f("interpret"), argnums=(0, 1, 2))(x, Bm, Cm)
    g2 = jax.grad(f("ref"), argnums=(0, 1, 2))(x, Bm, Cm)
    for a, b in zip(g1, g2):
        np.testing.assert_allclose(a, b, atol=2e-3, rtol=2e-3)
