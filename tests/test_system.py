"""End-to-end behaviour of the paper's system: explicit speculation
improves I/O-loop wall time on a parallel device while preserving results
(the paper's core claims, scaled to CI)."""

import time

import numpy as np
import pytest

from repro.core import (DeviceProfile, Foreactor, MemDevice, SimulatedDevice, io)
from repro.store import plugins
from repro.store.fileutils import du_dir
from repro.store.lsm import LSMTree

FAST = DeviceProfile(channels=16, base_latency=8e-4, metadata_latency=6e-4,
                     crossing_cost=3e-6)


def best_of(fn, repeats=3):
    """Best-of-N wall time: a single-shot measurement on a loaded CI
    container conflates OS scheduler noise (and cold worker-pool setup)
    with the effect under test; the min filters it, exactly like
    ``benchmarks.common.timeit_min``."""
    best = float("inf")
    out = None
    for _ in range(repeats):
        t0 = time.perf_counter()
        out = fn()
        best = min(best, time.perf_counter() - t0)
    return out, best


def test_speculation_speeds_up_stat_loop():
    """Fig. 6(a) direction: du with pre-issuing beats serial du, and the
    result is identical."""
    inner = MemDevice()
    for i in range(80):
        fd = inner.open(f"/d/f{i}", "w")
        inner.pwrite(fd, b"z" * (i + 1), 0)
        inner.close(fd)
    dev = SimulatedDevice(inner, FAST)
    fa = Foreactor(device=dev, backend="io_uring", depth=16)
    plugins.register_all(fa)
    wrapped = fa.wrap("du", plugins.capture_du)(du_dir)

    expect, t_sync = best_of(lambda: du_dir(dev, "/d"))
    got, t_fa = best_of(lambda: wrapped(dev, "/d"))
    assert got == expect
    assert t_fa < t_sync * 0.55, (t_fa, t_sync)  # paper reports up to 50%
    fa.shutdown()


def test_speculation_speeds_up_lsm_get():
    """Fig. 8 direction: Get over a multi-table chain is faster with
    speculation, identical results, early exit preserved."""
    rng = np.random.default_rng(0)
    inner = MemDevice()
    lsm = LSMTree(inner, "/db", memtable_limit_bytes=1 << 13, l0_limit=100,
                  fsync_writes=False)
    ref = {}
    for k in rng.permutation(1500):
        v = f"{k:08d}".encode() * 4
        lsm.put(int(k), v)
        ref[int(k)] = v
    lsm.flush()
    assert lsm.table_count() >= 4

    dev = SimulatedDevice(inner, FAST)
    lsm_sim = LSMTree.open_existing(dev, "/db")
    fa = Foreactor(device=dev, backend="io_uring", depth=16)
    plugins.register_all(fa)
    get = fa.wrap("lsm_get", plugins.capture_lsm_get)(lambda l, k: l.get(k))
    keys = [int(k) for k in rng.choice(1500, 40)]

    def run_sync():
        for k in keys:
            assert lsm_sim.get(k) == ref[k]

    def run_fa():
        for k in keys:
            assert get(lsm_sim, k) == ref[k]

    _, t_sync = best_of(run_sync, repeats=2)
    _, t_fa = best_of(run_fa, repeats=2)
    assert t_fa < t_sync, (t_fa, t_sync)
    fa.shutdown()


def test_backend_swap_preserves_semantics():
    """Table 1: the same graphs run unmodified on both backends."""
    inner = MemDevice()
    for i in range(30):
        fd = inner.open(f"/d/f{i}", "w")
        inner.pwrite(fd, b"y" * (i + 1), 0)
        inner.close(fd)
    results = {}
    for backend in ("io_uring", "user_threads", "sync"):
        dev = SimulatedDevice(inner, FAST)
        fa = Foreactor(device=dev, backend=backend, depth=8)
        plugins.register_all(fa)
        wrapped = fa.wrap("du", plugins.capture_du)(du_dir)
        results[backend] = wrapped(dev, "/d")
        fa.shutdown()
    assert len(set(results.values())) == 1
