"""The pooled completion primitive (repro.core.completion).

IORequest completion used to ride a per-request ``threading.Event`` plus a
per-request claim lock; both now live on a fixed, process-wide stripe table
(the completion-queue analogue).  These tests pin down the three contracts
the swap must preserve under any interleaving:

* **no lost wakeups** — a waiter blocked on ``wait_done`` is always woken
  by the finish/cancel that terminates its request, even when many
  requests share one stripe;
* **no double delivery** — the completion callback fires exactly once per
  request across racing finish/cancel (including the shared backend's
  evict-then-re-finish path), and ``take_result`` materializes once;
* **claim/cancel exclusivity** — exactly one of N racing claimers/
  cancellers wins the PREPARED request.

The hypothesis property test explores random interleavings; the
deterministic stress variant runs a seeded schedule of the same shape so
the property is exercised even where hypothesis is not installed
(tests/_hypothesis_support.py degrades @given to skips there).
"""

import random
import threading

from _hypothesis_support import given, settings, st

from repro.core import completion_pool
from repro.core.completion import CompletionPool
from repro.core.syscalls import IORequest, ReqState, Sys


def _req() -> IORequest:
    return IORequest(sc=Sys.PREAD, args=(0, 16, 0))


# -- unit: the basic lifecycle on the shared stripes --------------------------

def test_finish_wakes_waiter_and_delivers_result():
    r = _req()
    assert not r.is_done()
    got = []
    t = threading.Thread(target=lambda: got.append(r.wait_result()))
    t.start()
    r.finish(b"payload")
    t.join(timeout=5)
    assert not t.is_alive()
    assert got == [b"payload"]
    assert r.is_done() and r.wait_done(timeout=0) is True


def test_wait_done_timeout_returns_false_without_completion():
    r = _req()
    assert r.wait_done(timeout=0.01) is False
    r.finish(b"x")
    assert r.wait_done(timeout=0.01) is True


def test_claim_cancel_exclusive():
    r = _req()
    assert r.claim() is True
    assert r.cancel() is False  # already submitted: too late to cancel
    assert r.claim() is False
    r2 = _req()
    assert r2.cancel() is True
    assert r2.claim() is False  # cancelled: a worker must never run it
    assert r2.is_done()


def test_completion_cb_fires_once_on_finish():
    r = _req()
    fired = []
    r.completion_cb = fired.append
    r.finish(b"x")
    r.finish(b"y")  # re-finish (evict-then-serve-inline shape)
    assert fired == [r]


def test_completion_cb_fires_once_across_cancel_then_finish():
    """The shared backend's eviction race: cancel() releases the slot via
    the callback, the demand path then re-finishes the request inline —
    the callback must NOT fire again."""
    r = _req()
    fired = []
    r.completion_cb = fired.append
    assert r.cancel() is True
    r.finish(b"served-inline")
    assert fired == [r]
    assert r.result == b"served-inline"


def test_many_requests_share_stripes_without_crosstalk():
    """More requests than stripes: waiters on colliding stripes are all
    woken by their own request's completion, none by another's."""
    pool = completion_pool()
    n = pool.snapshot()["stripes"] * 3
    reqs = [_req() for _ in range(n)]
    results = [None] * n
    threads = [
        threading.Thread(target=lambda i=i: results.__setitem__(
            i, reqs[i].wait_result()))
        for i in range(n)
    ]
    for t in threads:
        t.start()
    for i, r in enumerate(reqs):
        r.finish(i)
    for t in threads:
        t.join(timeout=10)
    assert all(not t.is_alive() for t in threads), "lost wakeup"
    assert results == list(range(n))
    assert pool.snapshot()["waiters"] == 0


def test_pool_requires_power_of_two_stripes():
    try:
        CompletionPool(48)
    except ValueError:
        pass
    else:  # pragma: no cover
        raise AssertionError("non-power-of-two stripe count accepted")


# -- the interleaving property ------------------------------------------------

def _race_once(seed: int, n_reqs: int = 8, n_waiters: int = 3) -> None:
    """One seeded interleaving: N requests, each with a callback counter and
    ``n_waiters`` blocked waiters, attacked by racing canceller/finisher
    threads.  Afterwards: every waiter woke, every callback fired exactly
    once, every request is terminal, and the winner (claim vs cancel) is
    coherent with the final state."""
    rng = random.Random(seed)
    reqs = [_req() for _ in range(n_reqs)]
    fired = {id(r): 0 for r in reqs}
    flock = threading.Lock()

    def make_cb(r):
        def cb(req):
            assert req is r
            with flock:
                fired[id(r)] += 1
        return cb

    for r in reqs:
        r.completion_cb = make_cb(r)

    woke = []
    wlock = threading.Lock()

    def waiter(r):
        assert r.wait_done(timeout=10) is True
        with wlock:
            woke.append(r)

    waiters = [threading.Thread(target=waiter, args=(r,))
               for r in reqs for _ in range(n_waiters)]
    for t in waiters:
        t.start()

    # racing terminators: some claim-then-finish (worker path), some cancel
    # (eviction path), some finish directly (inline demand path)
    def attack(tid):
        order = list(reqs)
        rng2 = random.Random(seed * 31 + tid)
        rng2.shuffle(order)
        for r in order:
            roll = rng2.random()
            if roll < 0.4:
                if r.claim():
                    r.finish(b"worker")
            elif roll < 0.7:
                r.cancel()
            else:
                r.finish(b"inline")

    attackers = [threading.Thread(target=attack, args=(i,))
                 for i in range(rng.randint(2, 4))]
    for t in attackers:
        t.start()
    for t in attackers:
        t.join(timeout=10)
    # every request saw at least one terminator (finish unconditionally in
    # the attacker mix), so all waiters must wake
    for t in waiters:
        t.join(timeout=10)
    assert all(not t.is_alive() for t in waiters), "lost wakeup"
    assert len(woke) == n_reqs * n_waiters
    for r in reqs:
        assert r.is_done()
        assert fired[id(r)] == 1, "completion delivered != once"


@settings(deadline=None, max_examples=25)
@given(st.integers(min_value=0, max_value=10 ** 6))
def test_random_interleavings_never_lose_wakeups_or_double_deliver(seed):
    _race_once(seed)


def test_seeded_interleavings_deterministic_sweep():
    """The same property as the hypothesis test on a fixed seed set, so the
    interleaving space is exercised even without hypothesis installed."""
    for seed in range(12):
        _race_once(seed)
