"""Device cost-model regressions: the bandwidth-vs-request-size curve and
the request-size-aware page-cache hit cost.

The extent coalescer's entire win rests on the cost shape
``base_latency + size * per_byte``: amortizing one command setup over an
MB-scale super-read.  These tests pin that curve analytically (no
sleeping) and pin the page-cache hit model — a cache hit charges
``cache_hit_latency + size * cache_hit_per_byte`` (the kernel memcpy out
of the cache scales with request size; a 1 MB cached read is not free the
way a 1 KB one nearly is) and occupies no device channel.
"""

import pytest

import repro.core.device as device_mod
from repro.core import DeviceProfile, MemDevice, NVME_PROFILE, SimulatedDevice


def model_bandwidth(profile: DeviceProfile, size: int) -> float:
    """Per-channel streaming bandwidth (bytes/s) at one request size."""
    return size / (profile.base_latency + size * profile.per_byte)


def test_bandwidth_curve_is_monotone_in_request_size():
    sizes = [1 << k for k in range(9, 23)]  # 512 B .. 4 MiB
    bws = [model_bandwidth(NVME_PROFILE, s) for s in sizes]
    assert bws == sorted(bws)


def test_nvme_profile_curve_endpoints_pinned():
    # the coalescing win quoted across the docs: ~17 MB/s at 1 KiB
    # requests vs ~800 MB/s at 1 MiB super-reads, per channel
    assert model_bandwidth(NVME_PROFILE, 1 << 10) == pytest.approx(
        16.7e6, rel=0.05)
    assert model_bandwidth(NVME_PROFILE, 1 << 20) == pytest.approx(
        795e6, rel=0.05)


def test_raw_bandwidth_ceiling():
    p = DeviceProfile(channels=4, per_byte=4e-9)
    assert p.raw_bandwidth_bytes() == pytest.approx(1e9)
    assert DeviceProfile(per_byte=0.0).raw_bandwidth_bytes() == float("inf")
    # the per-channel curve approaches (never exceeds) the raw ceiling
    assert model_bandwidth(p, 1 << 30) < 1e9 / p.channels * 1.001


class _SleepRecorder:
    def __init__(self):
        self.durations = []

    def __call__(self, dur):
        self.durations.append(dur)


@pytest.fixture()
def recorded_sleep(monkeypatch):
    rec = _SleepRecorder()
    monkeypatch.setattr(device_mod, "_precise_sleep", rec)
    return rec


def _dev(cache_bytes=1 << 20, **profile_kw):
    profile = DeviceProfile(**profile_kw)
    inner = MemDevice()
    fd = inner.open("/f", "w")
    inner.pwrite(fd, bytes(range(256)) * 8192, 0)  # 2 MiB
    inner.close(fd)
    dev = SimulatedDevice(inner, profile, cache_bytes=cache_bytes)
    return dev, dev.open("/f", "r")


def test_cache_hit_cost_accounts_for_request_size(recorded_sleep):
    """Regression for the flat-hit-cost bug: a hit used to charge only the
    fixed latency, making MB-scale cached reads implausibly free.  The hit
    charge is pinned to ``cache_hit_latency + size * cache_hit_per_byte``."""
    p = dict(base_latency=1e-3, per_byte=1e-9,
             cache_hit_latency=5e-6, cache_hit_per_byte=1e-10,
             metadata_latency=0.0)
    dev, fd = _dev(**p)
    recorded_sleep.durations.clear()

    for size in (1 << 10, 1 << 20):
        dev.pread(fd, size, 0)  # miss: full device charge
        assert recorded_sleep.durations[-1] == pytest.approx(
            p["base_latency"] + size * p["per_byte"])
        dev.pread(fd, size, 0)  # hit: kernel copy-out, size-dependent
        assert recorded_sleep.durations[-1] == pytest.approx(
            p["cache_hit_latency"] + size * p["cache_hit_per_byte"])

    hit_1k = p["cache_hit_latency"] + (1 << 10) * p["cache_hit_per_byte"]
    hit_1m = p["cache_hit_latency"] + (1 << 20) * p["cache_hit_per_byte"]
    assert hit_1m > hit_1k  # the curve, not a flat constant


def test_cache_hit_occupies_no_channel(recorded_sleep):
    """Hits must not consume device-channel slots: a single-channel device
    serves cached reads without queueing behind the device."""
    dev, fd = _dev(channels=1, base_latency=1e-3, per_byte=0.0,
                   cache_hit_latency=5e-6, cache_hit_per_byte=1e-10,
                   metadata_latency=0.0)
    dev.pread(fd, 4096, 0)  # warm the cache
    # exhaust the only channel; a hit must still be served
    assert dev._channels.acquire(blocking=False)
    try:
        recorded_sleep.durations.clear()
        dev.pread(fd, 4096, 0)
        assert len(recorded_sleep.durations) == 1  # did not block on _service
        assert recorded_sleep.durations[0] == pytest.approx(
            5e-6 + 4096 * 1e-10)
    finally:
        dev._channels.release()


def test_direct_mode_always_charges_device(recorded_sleep):
    dev, fd = _dev(base_latency=1e-3, per_byte=1e-9, metadata_latency=0.0)
    dev_direct = SimulatedDevice(dev.inner, dev.profile,
                                 cache_bytes=1 << 20, direct=True)
    dfd = dev_direct.open("/f", "r")
    recorded_sleep.durations.clear()
    for _ in range(2):
        dev_direct.pread(dfd, 4096, 0)
    # no cache on the direct lane: both reads pay full device service
    assert recorded_sleep.durations == [
        pytest.approx(1e-3 + 4096 * 1e-9)] * 2
