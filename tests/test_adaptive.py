"""Adaptive speculation depth: DepthController policy units and
engine-integrated convergence on deep-loop vs early-exit workloads."""

import pytest

from repro.core import (DepthController, DeviceProfile, Foreactor, MemDevice,
                        SessionStats, SimulatedDevice, io)
from repro.core.patterns import build_pread_extents_graph, build_stat_list_graph

FAST_SIM = DeviceProfile(channels=8, base_latency=1.5e-3,
                         metadata_latency=1.0e-3, per_byte=0.0,
                         crossing_cost=0.0)


def _stats(**kw) -> SessionStats:
    s = SessionStats()
    for k, v in kw.items():
        setattr(s, k, v)
    return s


# -- controller policy units --------------------------------------------------
def test_controller_grows_on_blocked_sessions():
    c = DepthController(initial=2, max_depth=32)
    blocked = _stats(intercepted=20, pre_issued=19, served_async=19,
                     wait_seconds=0.5)
    c.on_finish(blocked, wall_seconds=1.0)
    assert c.depth == 4
    c.on_finish(blocked, wall_seconds=1.0)
    c.on_finish(blocked, wall_seconds=1.0)
    assert c.depth == 16


def test_controller_shrinks_toward_consumption_on_waste():
    c = DepthController(initial=32, max_depth=64)
    wasteful = _stats(intercepted=3, pre_issued=30, served_async=2,
                      cancelled=20, wasted_completions=8)
    c.on_finish(wasteful, wall_seconds=1.0)
    assert c.depth == 3  # served_async + 1
    # a wasteful verdict gates the next growth attempt
    blocked = _stats(intercepted=3, pre_issued=2, served_async=2,
                     wait_seconds=0.5)
    c.on_finish(blocked, wall_seconds=1.0)
    assert c.depth == 3  # no regrow right after waste
    c.on_finish(blocked, wall_seconds=1.0)
    assert c.depth == 6  # waste verdict cleared, growth resumes


def test_controller_respects_bounds():
    c = DepthController(initial=1, min_depth=1, max_depth=8)
    blocked = _stats(intercepted=50, pre_issued=49, served_async=49,
                     wait_seconds=1.0)
    for _ in range(10):
        c.on_finish(blocked, wall_seconds=1.0)
    assert c.depth == 8
    wasteful = _stats(intercepted=1, pre_issued=8, served_async=0,
                      cancelled=8)
    c.on_finish(wasteful, wall_seconds=1.0)
    assert c.depth == 1


def test_controller_window_grows_within_a_session():
    c = DepthController(initial=2, max_depth=64, window=4)
    # 1st serve starts the window clock; 4 more blocked serves close it
    for _ in range(5):
        c.on_serve(wait_seconds=0.1, async_hit=True)
    assert c.depth == 4


def test_controller_occupancy_gates_growth():
    class Saturated:
        capacity = 4

        def inflight(self):
            return 4

    c = DepthController(initial=4, max_depth=64)
    blocked = _stats(intercepted=20, pre_issued=19, served_async=19,
                     wait_seconds=0.5)
    c.on_finish(blocked, wall_seconds=1.0, backend=Saturated())
    assert c.depth == 4  # queue full at depth >= capacity: growth refused


def test_depth_argument_validation():
    with pytest.raises(ValueError):
        Foreactor(device=MemDevice(), depth="turbo")


# -- engine integration -------------------------------------------------------
def stat_loop_graph():
    return build_stat_list_graph("stat_loop")


def read_chain_weak_graph():
    return build_pread_extents_graph("read_chain", weak=True)


def _seed(dev, n, size=16):
    paths = []
    for i in range(n):
        p = f"/d/f{i}"
        fd = dev.open(p, "w")
        dev.pwrite(fd, bytes([i % 251]) * size, 0)
        dev.close(fd)
        paths.append(p)
    return paths


def test_adaptive_depth_external_synchrony_and_growth():
    inner = MemDevice()
    paths = _seed(inner, 24)
    dev = SimulatedDevice(inner, FAST_SIM)
    fa = Foreactor(device=dev, backend="io_uring", depth="adaptive",
                   workers=8)
    fa.register("stat_loop", stat_loop_graph)

    @fa.wrap("stat_loop", lambda paths: {"paths": paths})
    def du(paths):
        return sum(io.fstatat(dev, p).st_size for p in paths)

    expect = 24 * 16
    for _ in range(4):
        assert du(paths) == expect  # correctness at every depth it visits
    c = fa.controller("stat_loop")
    assert c.depth > 2  # a fully-consumed blocked loop grew the depth
    assert c.grows >= 1
    fa.shutdown()


def test_adaptive_depth_shrinks_on_early_exit_workload():
    inner = MemDevice()
    paths = _seed(inner, 32)
    dev = SimulatedDevice(inner, FAST_SIM)
    fa = Foreactor(device=dev, backend="io_uring", depth="adaptive",
                   depth_range=(1, 64), workers=8)
    fa.register("read_chain", read_chain_weak_graph)
    extents = []
    for p in paths:
        fd = dev.open(p, "r")
        extents.append((fd, 16, 0))

    @fa.wrap("read_chain", lambda: {"extents": extents})
    def search():
        for i, (fd, n, off) in enumerate(extents):
            data = io.pread(dev, fd, n, off)
            if i == 2:
                return data
        return None

    for _ in range(6):
        assert search() == bytes([2]) * 16
    c = fa.controller("read_chain")
    # consumption is 3 reads per call: depth must settle near that, far
    # below the 64 ceiling a fixed-depth config would waste
    assert c.depth <= 8
    fa.shutdown()


def test_explicit_depth_overrides_adaptive():
    dev = MemDevice()
    paths = _seed(dev, 8)
    fa = Foreactor(device=dev, backend="io_uring", depth="adaptive")
    fa.register("stat_loop", stat_loop_graph)
    sess = fa.activate("stat_loop", {"paths": paths}, depth=3)
    assert sess.controller is None
    assert sess.depth == 3
    fa.deactivate(sess)
    sess2 = fa.activate("stat_loop", {"paths": paths})
    assert sess2.controller is fa.controller("stat_loop")
    fa.deactivate(sess2)
    fa.shutdown()
