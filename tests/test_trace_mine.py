"""Observe-then-speculate coverage: trace recording, graph mining,
replay validation, auto_graph wrapping, and the mined-vs-hand-written
cross-check on the paper's du/cp case studies."""

import pytest
from _hypothesis_support import given, settings, st

from repro.analysis.mine import (ReplayMismatch, UnminableTrace, UnsoundGraph,
                                 mine_and_validate, mine_traces, replay_trace)
from repro.core import (Foreactor, MemDevice, QueuePairBackend, SpecSession,
                        Sys, io)
from repro.core.api import _session_stack
from repro.store import plugins
from repro.store.fileutils import cp_file, du_dir


def make_dev(nfiles=6, size=32, root="/d"):
    dev = MemDevice()
    for i in range(nfiles):
        fd = dev.open(f"{root}/f{i}", "w")
        dev.pwrite(fd, bytes([i % 251]) * size, 0)
        dev.close(fd)
    return dev


# -- trace recording ---------------------------------------------------------
def test_trace_recorder_records_serial_execution():
    dev = make_dev(3)
    fa = Foreactor(device=dev, backend="io_uring")
    du = fa.observe("du_t", lambda device, root: {"root": root})(du_dir)
    total = du(dev, "/d")
    assert total == 3 * 32
    pairs = fa.traces("du_t")
    assert len(pairs) == 1
    ctx, trace = pairs[0]
    assert ctx == {"root": "/d"}
    assert trace.kinds() == [Sys.GETDENTS] + [Sys.FSTATAT] * 3
    assert trace[0].result == ["f0", "f1", "f2"]
    assert trace[1].args == ("/d/f0",)
    # recording is pure observation: no speculation happened
    assert fa.total_stats.pre_issued == 0
    fa.shutdown()


def test_trace_jsonable_renders_without_blowup():
    dev = make_dev(3)
    fa = Foreactor(device=dev)
    du = fa.observe("du_t", lambda device, root: {"root": root})(du_dir)
    du(dev, "/d")
    rows = fa.traces("du_t")[0][1].to_jsonable()
    assert rows[0]["sc"] == "getdents"
    assert all("seq" in r for r in rows)
    fa.shutdown()


# -- mining: structure and provenance ----------------------------------------
def test_mined_du_graph_structure_and_generalization():
    dev = make_dev(6)
    fa = Foreactor(device=dev, backend="io_uring", depth=8)
    du = fa.observe("du_m", lambda device, root: {"root": root})(du_dir)
    du(dev, "/d")
    mined = fa.mine("du_m")
    g = mined.graph
    assert set(g.syscall_nodes) == {"getdents", "fstatat"}
    assert g.num_loops == 1
    # generalizes to a different directory through ctx/listing provenance
    for i in range(4):
        fd = dev.open(f"/e/x{i}", "w")
        dev.pwrite(fd, b"y" * 8, 0)
        dev.close(fd)
    du_spec = fa.wrap("du_m", lambda device, root: {"root": root})(du_dir)
    assert du_spec(dev, "/e") == 4 * 8
    assert fa.total_stats.served_async > 0
    fa.shutdown()


def test_mining_is_deterministic():
    ref1 = plugins.mine_reference_graphs()
    ref2 = plugins.mine_reference_graphs()
    for key in ("du", "cp"):
        assert ref1[key].signature() == ref2[key].signature()
        assert ref1[key].graph.to_dot() == ref2[key].graph.to_dot()


def test_validator_refuses_overfit_graph():
    """Training only on an even-multiple copy overfits the chunk size; the
    held-out remainder trace must be refused, not silently mis-speculated."""
    dev = MemDevice()
    for name, size in (("/a", 4 * 1024), ("/b", 3 * 1024 + 100)):
        fd = dev.open(name, "w")
        dev.pwrite(fd, b"z" * size, 0)
        dev.close(fd)
    fa = Foreactor(device=dev)
    cap = lambda device, src, dst, buf_size=1024: {
        "src": src, "dst": dst, "buf_size": buf_size}
    cp = fa.observe("cp_m", cap)(cp_file)
    cp(dev, "/a", "/o1", 1024)   # training: all chunks == buf_size
    cp(dev, "/b", "/o2", 1024)   # held out: remainder chunk
    with pytest.raises(UnsoundGraph):
        fa.mine("cp_m")
    fa.shutdown()


def test_miner_refuses_structural_divergence():
    dev = make_dev(4)

    def weird(device, mode):
        if mode:
            io.getdents(device, "/d")
            for i in range(4):
                io.fstatat(device, f"/d/f{i}")
        else:
            io.fstatat(device, "/d/f0")
            io.getdents(device, "/d")
        return None

    fa = Foreactor(device=dev)
    obs = fa.observe("w", lambda device, mode: {})(weird)
    obs(dev, True)
    obs(dev, False)
    with pytest.raises((UnminableTrace, UnsoundGraph)):
        fa.mine("w")
    fa.shutdown()


def test_miner_refuses_unexplained_argument():
    """A data-dependent argument (hash of loop index) has no provenance."""
    dev = MemDevice()
    paths = []
    import zlib
    for i in range(5):
        p = f"/h/{zlib.crc32(bytes([i])) % 1000}"
        fd = dev.open(p, "w")
        dev.pwrite(fd, b"q" * 4, 0)
        dev.close(fd)
        paths.append(p)

    def statloop(device):
        for p in paths:
            io.fstatat(device, p)

    fa = Foreactor(device=dev)
    obs = fa.observe("h", lambda device: {})(statloop)
    obs(dev)
    with pytest.raises(UnminableTrace):
        fa.mine("h")
    fa.shutdown()


def test_mined_early_exit_loop_is_weak():
    dev = make_dev(10)
    fds = [dev.open(f"/d/f{i}", "r") for i in range(10)]
    extents = [[fd, 32, 0] for fd in fds]

    def search(device, extents, stop):
        for i, (fd, n, off) in enumerate(extents):
            data = io.pread(device, fd, n, off)
            if i == stop:
                return data
        return None

    fa = Foreactor(device=dev, backend="io_uring", depth=10)
    cap = lambda device, extents, stop: {"extents": extents}
    obs = fa.observe("s", cap)(search)
    obs(dev, extents, 6)
    obs(dev, extents, 3)
    obs(dev, extents, 8)
    mined = fa.mine("s")
    (node,) = mined.graph.syscall_nodes.values()
    assert node.out.weak  # early exit permitted at every iteration
    spec = fa.wrap("s", cap)(search)
    assert spec(dev, extents, 2) == bytes([2]) * 32
    s = fa.total_stats
    assert s.pre_issued > 3  # speculated past the exit
    assert s.cancelled + s.wasted_completions > 0  # and discarded the rest
    fa.shutdown()


def test_mined_barrier_keeps_close_at_the_frontier():
    """CLOSE/FSYNC are mined with a harvest barrier: never pre-issued while
    earlier speculated I/O is unharvested (an early close would fail it)."""
    ref = plugins.mine_reference_graphs()
    g = ref["cp"].graph
    dev = MemDevice()
    fd = dev.open("/src.bin", "w")
    dev.pwrite(fd, bytes(range(256)) * 64, 0)  # 16 KiB = 4 chunks
    dev.close(fd)
    backend = _SpyBackend(QueuePairBackend(dev, workers=8))
    ctx = {"src": "/src.bin", "dst": "/dst.bin", "buf_size": 4096}
    sess = SpecSession(g, ctx, backend, dev, depth=16)
    _session_stack().append(sess)
    try:
        cp_file(dev, "/src.bin", "/dst.bin", 4096)
    finally:
        _session_stack().pop()
        sess.finish()
    prepared_kinds = [sc for (sc, _a) in backend.prepared]
    assert Sys.CLOSE not in prepared_kinds
    assert Sys.FSYNC not in prepared_kinds
    assert prepared_kinds.count(Sys.PWRITE) == 4
    f1, f2 = dev.open("/src.bin", "r"), dev.open("/dst.bin", "r")
    assert dev.pread(f1, 16384, 0) == dev.pread(f2, 16384, 0)
    backend.shutdown()


# -- mined vs hand-written: same pre-issue schedule ---------------------------
class _SpyBackend:
    """Delegating backend that logs the pre-issue schedule (submit order —
    the engine hands the whole peeked batch over in one ``submit`` call)."""

    def __init__(self, inner):
        self.inner = inner
        self.prepared = []

    def prepare(self, req):
        self.prepared.append((req.sc, _normalize(req.args)))
        self.inner.prepare(req)

    def submit(self, batch):
        for req in batch:
            self.prepared.append((req.sc, _normalize(req.args)))
        return self.inner.submit(batch)

    def __getattr__(self, name):
        return getattr(self.inner, name)


def _normalize(args):
    from repro.core.syscalls import FromRequest

    out = []
    for a in args:
        if isinstance(a, FromRequest):
            out.append("<linked>")
        elif isinstance(a, bytes):
            out.append(("bytes", len(a)))
        else:
            out.append(a)
    return tuple(out)


def _schedule(graph, ctx, dev, fn, *args, depth=16):
    backend = _SpyBackend(QueuePairBackend(dev, workers=8))
    sess = SpecSession(graph, ctx, backend, dev, depth=depth)
    _session_stack().append(sess)
    try:
        result = fn(*args)
    finally:
        _session_stack().pop()
        sess.finish()
    backend.shutdown()
    return result, backend.prepared


def test_mined_du_matches_handwritten_preissue_schedule():
    ref = plugins.mine_reference_graphs()
    hand = plugins.build_du_graph()
    dev = make_dev(8, size=24, root="/w")
    r1, sched_hand = _schedule(
        hand, plugins.capture_du(dev, "/w"), dev, du_dir, dev, "/w")
    r2, sched_mined = _schedule(
        ref["du"].graph, plugins.capture_du(dev, "/w"), dev, du_dir, dev, "/w")
    assert r1 == r2 == 8 * 24
    assert sched_hand == sched_mined
    assert len(sched_hand) > 0


def test_mined_cp_matches_handwritten_preissue_schedule():
    ref = plugins.mine_reference_graphs()
    hand = plugins.build_cp_graph()

    def fresh():
        dev = MemDevice()
        fd = dev.open("/s.bin", "w")
        dev.pwrite(fd, bytes(range(256)) * 80, 0)  # 20 KiB = 5 x 4 KiB
        dev.close(fd)
        return dev

    dev1, dev2 = fresh(), fresh()
    r1, sched_hand = _schedule(
        hand, plugins.capture_cp(dev1, "/s.bin", "/d.bin", 4096),
        dev1, cp_file, dev1, "/s.bin", "/d.bin", 4096)
    r2, sched_mined = _schedule(
        ref["cp"].graph, {"src": "/s.bin", "dst": "/d.bin", "buf_size": 4096},
        dev2, cp_file, dev2, "/s.bin", "/d.bin", 4096)
    assert r1 == r2 == 20480
    # identical schedules on the hand graph's node set; the mined graph may
    # not add anything beyond it (fsync/close stay behind the barrier)
    assert sched_hand == sched_mined
    assert [sc for (sc, _a) in sched_hand].count(Sys.PREAD) == 5
    # both destinations carry identical bytes
    f1, f2 = dev1.open("/d.bin", "r"), dev2.open("/d.bin", "r")
    assert dev1.pread(f1, 20480, 0) == dev2.pread(f2, 20480, 0)


# -- auto_graph wrapping ------------------------------------------------------
def test_auto_graph_observes_then_speculates():
    dev = make_dev(8)
    fa = Foreactor(device=dev, backend="io_uring", depth=8)
    du = fa.wrap("du_auto", lambda device, root: {"root": root},
                 auto_graph=True, observe_calls=2)(du_dir)
    assert du(dev, "/d") == 8 * 32          # observation 1 (serial)
    assert du.__foreactor_auto__["state"] == "observing"
    assert du(dev, "/d") == 8 * 32          # observation 2 -> mine
    assert du.__foreactor_auto__["state"] == "speculating"
    assert fa.total_stats.pre_issued == 0   # nothing speculated yet
    assert du(dev, "/d") == 8 * 32          # now speculated
    assert fa.total_stats.served_async > 0
    fa.shutdown()


def test_auto_graph_disables_on_unminable_function():
    dev = make_dev(6)
    flips = {"n": 0}

    def flaky(device):
        # structurally different every call: unminable by design
        flips["n"] += 1
        if flips["n"] % 2:
            io.getdents(device, "/d")
        else:
            io.fstatat(device, "/d/f0")
            io.getdents(device, "/d")
        return flips["n"]

    fa = Foreactor(device=dev, backend="io_uring")
    f = fa.wrap("flaky", lambda device: {}, auto_graph=True,
                observe_calls=2)(flaky)
    f(dev)
    f(dev)
    assert f.__foreactor_auto__["state"] == "disabled"
    assert f.__foreactor_auto__["reason"]
    assert f(dev) == 3  # still correct, permanently serial
    assert fa.total_stats.pre_issued == 0
    fa.shutdown()


# -- property-based: mined graphs replay their inputs -------------------------
# The @given variants explore random trace sets when hypothesis is
# installed; the _grid test below runs a fixed sample of the same property
# unconditionally, so the invariant is exercised even where hypothesis is
# absent (tests/_hypothesis_support.py degrades @given to skips there).
def test_grid_mined_graphs_replay_and_are_deterministic():
    for kind in (0, 1, 2):
        for lengths in ([4], [3, 7], [5, 3, 9], [12]):
            dev = MemDevice()
            ctxs, traces = _synthetic_traces(kind, len(lengths), lengths, dev)
            m1 = mine_traces(traces, ctxs, name="grid")
            m2 = mine_traces(traces, ctxs, name="grid")
            assert m1.signature() == m2.signature()
            assert m1.graph.to_dot() == m2.graph.to_dot()
            for ctx, tr in zip(ctxs, traces):
                replay_trace(m1.graph, ctx, tr)


def _synthetic_traces(kind, n_traces, lengths, dev):
    """Build (ctxs, traces) for a randomly chosen program shape."""
    from repro.core import TraceRecorder

    ctxs, traces = [], []
    for t in range(n_traces):
        n = lengths[t]
        paths = []
        for i in range(n):
            p = f"/p{t}/f{i}"
            fd = dev.open(p, "w")
            dev.pwrite(fd, bytes([i % 251]) * 8, 0)
            dev.close(fd)
            paths.append(p)
        rec = TraceRecorder(dev)
        _session_stack().append(rec)
        try:
            if kind == 0:  # stat loop over a ctx list
                ctx = {"paths": paths}
                for p in paths:
                    io.fstatat(dev, p)
            elif kind == 1:  # du shape: listing then stat loop
                ctx = {"root": f"/p{t}"}
                for name in io.getdents(dev, f"/p{t}"):
                    io.fstatat(dev, f"/p{t}/{name}")
            else:  # pread loop over ctx extents
                fds = [dev.open(p, "r") for p in paths]
                ctx = {"extents": [[fd, 8, 0] for fd in fds]}
                for fd in fds:
                    io.pread(dev, fd, 8, 0)
        finally:
            _session_stack().pop()
        ctxs.append(ctx)
        traces.append(rec.finish())
    return ctxs, traces


@settings(max_examples=25, deadline=None)
@given(
    kind=st.integers(0, 2),
    lengths=st.lists(st.integers(3, 12), min_size=1, max_size=4),
)
def test_property_mined_graph_replays_every_input_trace(kind, lengths):
    dev = MemDevice()
    ctxs, traces = _synthetic_traces(kind, len(lengths), lengths, dev)
    mined = mine_traces(traces, ctxs, name="prop")
    for ctx, tr in zip(ctxs, traces):
        replay_trace(mined.graph, ctx, tr)  # must not raise


@settings(max_examples=15, deadline=None)
@given(
    kind=st.integers(0, 2),
    lengths=st.lists(st.integers(3, 10), min_size=1, max_size=3),
)
def test_property_mining_twice_is_identical(kind, lengths):
    dev = MemDevice()
    ctxs, traces = _synthetic_traces(kind, len(lengths), lengths, dev)
    m1 = mine_traces(traces, ctxs, name="det")
    m2 = mine_traces(traces, ctxs, name="det")
    assert m1.signature() == m2.signature()
    assert m1.graph.to_dot() == m2.graph.to_dot()


@settings(max_examples=15, deadline=None)
@given(
    lengths=st.lists(st.integers(3, 10), min_size=2, max_size=4),
    exits=st.data(),
)
def test_property_early_exit_traces_replay(lengths, exits):
    """Traces that exit at random positions mine into a weak loop that
    replays every one of them (including full consumption)."""
    dev = MemDevice()
    ctxs, traces = [], []
    from repro.core import TraceRecorder

    for t, n in enumerate(lengths):
        fds = []
        for i in range(n):
            p = f"/q{t}/f{i}"
            fd = dev.open(p, "w")
            dev.pwrite(fd, bytes([i % 251]) * 8, 0)
            dev.close(fd)
            fds.append(dev.open(p, "r"))
        stop = exits.draw(st.integers(1, n))
        rec = TraceRecorder(dev)
        _session_stack().append(rec)
        try:
            for i, fd in enumerate(fds):
                io.pread(dev, fd, 8, 0)
                if i + 1 == stop:
                    break
        finally:
            _session_stack().pop()
        ctxs.append({"extents": [[fd, 8, 0] for fd in fds]})
        traces.append(rec.finish())
    try:
        mined = mine_traces(traces, ctxs, name="exit")
    except UnminableTrace:
        # refusal is only legitimate when no trace repeated enough to fold a
        # loop (the documented "record representative inputs" requirement)
        assert max(len(tr) for tr in traces) < 3
        assert len(traces) >= 2
        return
    for ctx, tr in zip(ctxs, traces):
        replay_trace(mined.graph, ctx, tr)
