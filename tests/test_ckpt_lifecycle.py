"""Checkpoint lifecycle at fleet scale: retention + speculated GC + delta
chains, proven by a crash matrix.

The harness runs every lifecycle scenario once with a counting device
wrapper to enumerate its *mutating* device ops (creating open, pwrite,
fsync, rename, unlink — one per foreaction-graph node class that touches
the namespace), then replays the scenario killing the process immediately
before each op in turn.  A kill freezes the device: the op raises, and
every later mutation (including staging-rollback attempts — a dead process
cannot clean up) raises too, which is exactly the state a real crash
leaves.  After each kill a *fresh* manager over the surviving bytes (the
restart) must:

* ``restore_latest()`` a byte-identical known-good checkpoint — never a
  half-written, half-deleted, or mixed-generation one (the atomic-commit
  invariant and the GC protocol's forward-only guarantee);
* finish the crashed collection on its next ``gc()`` pass and then save +
  restore normally, leaving no tombstones or staging residue behind.

Scenario coverage: empty root, retention-limit GC of a full save, a live
full+delta chain, GC of a whole delta chain (base must outlive every
kept delta), sweep of a crash-orphaned tombstone, and re-saving an
already-committed step (the non-atomic-overwrite regression).  A smaller
sampled matrix repeats two scenarios under the speculating io_uring
backend, where op order is nondeterministic but the invariants must hold
at any interleaving.

Property tests (hypothesis, optional via ``_hypothesis_support``) pin the
retention policy's pure core: keep-set spec, monotonicity under appended
saves, and delta-chain closure.
"""

from __future__ import annotations

import threading
import warnings

import numpy as np
import pytest
from _hypothesis_support import HAS_HYPOTHESIS, given, settings, st

from repro.checkpoint import CheckpointManager, CheckpointPolicy, SaveInfo, chain_of
from repro.checkpoint.manager import COMMIT_MARKER, GC_TAG
from repro.core import Foreactor, MemDevice
from repro.store.staging import STAGE_TAG, StagingTxn

ROOT = "/ck"
SHARDS = 2
CHUNK = 128  # w: 384 B -> 3 extents, b: 96 B -> 1 extent


class _Killed(Exception):
    """The injected process death (not an OSError: recovery code that
    tolerates I/O errors must still die on it)."""


class CrashDevice:
    """Device wrapper with deterministic kill-point injection.

    Counts mutating ops (the namespace-changing node classes).  When armed,
    the ``kill_at``-th mutating op after arming raises *before* executing,
    and the device freezes: every later mutation raises too, so rollback
    paths cannot "helpfully" clean up state a dead process would have left
    behind.  Reads keep working only because the harness, not the victim,
    does the post-mortem.
    """

    def __init__(self, inner):
        self.inner = inner
        self.kill_at = None  # absolute count of the op to die before
        self.count = 0
        self.frozen = False
        self.trace = []  # mutating op kinds, in execution order
        self._lock = threading.Lock()

    def _mut(self, kind: str) -> None:
        with self._lock:
            if self.frozen:
                raise _Killed(f"dead process: {kind}")
            self.count += 1
            self.trace.append(kind)
            if self.kill_at is not None and self.count >= self.kill_at:
                self.frozen = True
                raise _Killed(f"killed before op #{self.count} ({kind})")

    def open(self, path, flags="r"):
        if flags != "r":
            self._mut("open_w")
        return self.inner.open(path, flags)

    def pwrite(self, fd, data, off):
        self._mut("pwrite")
        return self.inner.pwrite(fd, data, off)

    def fsync(self, fd):
        self._mut("fsync")
        return self.inner.fsync(fd)

    def rename(self, src, dst):
        self._mut("rename")
        return self.inner.rename(src, dst)

    def unlink(self, path):
        self._mut("unlink")
        return self.inner.unlink(path)

    def truncate(self, fd, length):
        self._mut("pwrite")  # same class: an in-place byte mutation
        return self.inner.truncate(fd, length)

    def __getattr__(self, name):  # reads, close, place, stats, ...
        return getattr(self.inner, name)


def make_tree(seed: int):
    rng = np.random.default_rng(seed)
    return {"w": rng.standard_normal(96).astype(np.float32),
            "b": rng.standard_normal(24).astype(np.float32)}


def flat_snap(tree):
    """Copy a tree into the flat {\"['k']\": array} form restore returns."""
    return {f"['{k}']": np.array(v, copy=True) for k, v in tree.items()}


def _expect(expected, step, tree):
    """Record that ``step``, if it ever commits, must restore to exactly
    these bytes (a step may have several acceptable generations when the
    scenario re-saves it)."""
    expected.setdefault(step, []).append(flat_snap(tree))


# -- scenarios -----------------------------------------------------------------
# Each scenario drives one manager; ops before arm() are the (never-killed)
# setup, ops after it form the kill matrix.

SCENARIOS = {}


def scenario(name, may_be_empty=False, keep=3):
    def deco(fn):
        SCENARIOS[name] = (fn, may_be_empty, keep)
        return fn
    return deco


@scenario("empty_full", may_be_empty=True)
def s_empty_full(mgr, expected, arm):
    """First save into an empty root: any kill leaves either nothing
    committed or the complete step."""
    t0 = make_tree(0)
    _expect(expected, 0, t0)
    arm()
    mgr.save(0, t0)


@scenario("retention_gc", keep=2)
def s_retention_gc(mgr, expected, arm):
    """A save at the retention limit: commit of step 2 triggers GC of
    step 0 (tombstone rename + unlinks), killed at every point."""
    for s in range(2):
        t = make_tree(s)
        _expect(expected, s, t)
        mgr.save(s, t)
    t2 = make_tree(2)
    _expect(expected, 2, t2)
    arm()
    mgr.save(2, t2)


@scenario("delta_chain_save", keep=10)
def s_delta_chain_save(mgr, expected, arm):
    """Appending a delta to a live full+delta chain: a killed delta save
    must never damage the chain it was extending."""
    t = make_tree(0)
    _expect(expected, 0, t)
    mgr.save(0, t)
    for s in (1, 2):
        t["w"][s] = s + 0.5
        _expect(expected, s, t)
        mgr.save(s, t, delta=True)
    t["w"][7] = 9.25
    _expect(expected, 3, t)
    arm()
    mgr.save(3, t, delta=True)


@scenario("gc_delta_chain", keep=10)
def s_gc_delta_chain(mgr, expected, arm):
    """Collecting an entire delta chain (policy tightened to keep_last=1):
    victims go newest-first, so at no kill point does a committed delta
    survive the base it needs."""
    t = make_tree(0)
    _expect(expected, 0, t)
    mgr.save(0, t)
    for s in (1, 2):
        t["w"][s] = -1.0 * s
        _expect(expected, s, t)
        mgr.save(s, t, delta=True)
    t3 = make_tree(3)
    _expect(expected, 3, t3)
    mgr.policy = CheckpointPolicy(keep_last=1)
    arm()
    mgr.save(3, t3)


@scenario("sweep_resume", keep=5)
def s_sweep_resume(mgr, expected, arm):
    """A previous GC died right after its point of no return (tombstone in
    place, files intact).  The sweep must finish the collection — and be
    killable at every step itself."""
    t0, t1 = make_tree(0), make_tree(1)
    mgr.save(0, t0)
    _expect(expected, 1, t1)
    mgr.save(1, t1)
    # forge the crash state: de-commit step 0 exactly as the GC graph does
    mgr.device.rename(f"{mgr.step_dir(0)}/{COMMIT_MARKER}",
                      mgr._tombstone_path(0))
    arm()
    mgr.gc()


@scenario("resave_committed", keep=5)
def s_resave_committed(mgr, expected, arm):
    """Re-saving an already-committed step (an emergency save landing on a
    periodic save's step).  Restore must see the old generation or the new
    one — never a stale ``ok`` marker vouching for mixed bytes."""
    t_a, t_b = make_tree(10), make_tree(11)
    _expect(expected, 1, t_a)
    mgr.save(1, t_a)
    _expect(expected, 2, t_b)
    mgr.save(2, t_b)
    t_c = make_tree(12)
    _expect(expected, 2, t_c)
    arm()
    mgr.save(2, t_c)


def run_scenario(name, kill_at=None, backend="sync", depth=0, workers=0):
    fn, _may_be_empty, keep = SCENARIOS[name]
    inner = MemDevice()
    crash = CrashDevice(inner)
    kw = {"workers": workers} if workers else {}
    fa = Foreactor(device=crash, backend=backend, depth=depth, **kw)
    mgr = CheckpointManager(crash, ROOT, fa=fa, num_shards=SHARDS,
                            chunk_bytes=CHUNK, keep=keep)
    expected = {}
    killed = False
    base = [0]

    def arm():
        base[0] = crash.count
        if kill_at is not None:
            crash.kill_at = crash.count + kill_at

    with warnings.catch_warnings():
        # a frozen device makes staging rollback fail by design; the abort
        # path reports that as a RuntimeWarning, which is the point here
        warnings.simplefilter("ignore", RuntimeWarning)
        try:
            fn(mgr, expected, arm)
        except _Killed:
            killed = True
        finally:
            fa.shutdown()
    return inner, expected, killed, crash.count - base[0], crash.trace[base[0]:]


def assert_recovered(inner, expected, may_be_empty, ctx):
    """The restart: a fresh manager over the surviving bytes must restore a
    known-good checkpoint, finish any crashed GC, and work normally."""
    fa = Foreactor(device=inner, backend="sync", depth=0)
    mgr = CheckpointManager(inner, ROOT, fa=fa, num_shards=SHARDS,
                            chunk_bytes=CHUNK, keep=3)
    try:
        for step in mgr.committed_steps():
            assert step in expected, \
                f"{ctx}: committed step {step} was never a good snapshot"
        out = mgr.restore_latest()
        if out is None:
            assert may_be_empty and mgr.committed_steps() == [], \
                f"{ctx}: lost every checkpoint"
        else:
            step, flat, _extra = out
            ok = any(set(flat) == set(s)
                     and all(np.array_equal(flat[k], s[k]) for k in s)
                     for s in expected.get(step, []))
            assert ok, f"{ctx}: step {step} restored torn/unknown bytes"
        # recovery: the next pass finishes any crashed collection...
        mgr.gc()
        # ...and the store saves + restores normally on top of it
        t = make_tree(999)
        mgr.save(999, t)
        step, flat, _extra = mgr.restore_latest()
        assert step == 999, ctx
        want = flat_snap(t)
        assert set(flat) == set(want) and \
            all(np.array_equal(flat[k], want[k]) for k in want), ctx
        # a completed pass leaves no tombstones and no staging residue
        leftovers = [p for p in inner._files
                     if GC_TAG in p or STAGE_TAG in p]
        assert leftovers == [], f"{ctx}: {leftovers}"
    finally:
        fa.shutdown()


# -- the matrix ----------------------------------------------------------------

@pytest.mark.parametrize("name", sorted(SCENARIOS))
def test_crash_matrix(name):
    _inner, _exp, killed, n_ops, _trace = run_scenario(name)
    assert not killed and n_ops > 0
    _fn, may_be_empty, _keep = SCENARIOS[name]
    for k in range(1, n_ops + 1):
        inner, expected, killed, _n, _t = run_scenario(name, kill_at=k)
        assert killed, f"{name}: kill point {k}/{n_ops} never fired"
        assert_recovered(inner, expected, may_be_empty,
                         ctx=f"{name} kill {k}/{n_ops}")


def test_matrix_covers_every_mutation_class():
    """Meta-check: the scenarios' armed phases actually exercise every
    namespace-mutating node class, so 'killed before every op' really means
    'killed after every node class'."""
    kinds = set()
    for name in SCENARIOS:
        _i, _e, _k, n_ops, trace = run_scenario(name)
        assert n_ops == len(trace)
        kinds.update(trace)
    assert kinds >= {"open_w", "pwrite", "fsync", "rename", "unlink"}, kinds


@pytest.mark.parametrize("name", ["retention_gc", "delta_chain_save"])
def test_crash_matrix_speculated_smoke(name):
    """Sampled kills under the speculating backend: op order is
    nondeterministic there, but any interleaving must satisfy the same
    restart invariants."""
    _i, _e, _k, n_ops, _t = run_scenario(name)
    _fn, may_be_empty, _keep = SCENARIOS[name]
    for k in sorted({1, 2, max(1, n_ops // 2), max(1, n_ops - 1), n_ops}):
        inner, expected, _killed, _n, _t2 = run_scenario(
            name, kill_at=k, backend="io_uring", depth=32, workers=4)
        assert_recovered(inner, expected, may_be_empty,
                         ctx=f"spec:{name} kill {k}")


# -- regressions the matrix reproduced -----------------------------------------

def test_partial_dir_never_shadows_latest():
    """A killed save's partial directory at a higher step number (no commit
    marker) must not shadow the real latest checkpoint."""
    inner = MemDevice()
    fa = Foreactor(device=inner, backend="sync", depth=0)
    mgr = CheckpointManager(inner, ROOT, fa=fa, num_shards=SHARDS,
                            chunk_bytes=CHUNK, keep=3)
    t5 = make_tree(5)
    mgr.save(5, t5)
    d = mgr.step_dir(9)  # forged debris: shard + manifest, no marker
    for name, data in (("shard_0000.bin", b"junk"), ("manifest.json", b"{}")):
        fd = inner.open(f"{d}/{name}", "w")
        inner.pwrite(fd, data, 0)
        inner.close(fd)
    assert mgr.latest_step() == 5
    step, flat, _ = mgr.restore_latest()
    assert step == 5
    want = flat_snap(t5)
    assert all(np.array_equal(flat[k], want[k]) for k in want)
    fa.shutdown()


def test_gc_never_collects_base_of_kept_delta():
    """Directly: tighten retention over a full+delta chain; the kept delta
    pins its base (the chain is one retention unit)."""
    inner = MemDevice()
    fa = Foreactor(device=inner, backend="sync", depth=0)
    mgr = CheckpointManager(inner, ROOT, fa=fa, num_shards=SHARDS,
                            chunk_bytes=CHUNK, keep=10)
    t = make_tree(0)
    mgr.save(0, t)
    for s in (1, 2, 3):
        t["w"][s] = s * 2.0
        mgr.save(s, t, delta=True)
    mgr.policy = CheckpointPolicy(keep_last=1)
    mgr.gc()
    # keep_last=1 keeps delta 3 — and therefore, via chain closure, every
    # base under it; nothing in the chain may be collected
    assert mgr.committed_steps() == [0, 1, 2, 3]
    step, flat, _ = mgr.restore_latest()
    assert step == 3
    want = flat_snap(t)
    assert all(np.array_equal(flat[k], want[k]) for k in want)
    fa.shutdown()


# -- staged rename + point of no return ----------------------------------------

def test_stage_rename_rollback_restores_name():
    dev = MemDevice()
    fd = dev.open("/a/x", "w")
    dev.pwrite(fd, b"hi", 0)
    dev.close(fd)
    txn = StagingTxn(dev)
    runner, rec = txn.stage_rename(("/a/x", "/a/y"))
    runner(dev)
    assert "/a/y" in dev._files and "/a/x" not in dev._files
    txn.finalize(ok=False)  # abort: rename back
    assert "/a/x" in dev._files and "/a/y" not in dev._files
    assert rec.undone


def test_publish_demanded_pins_rename_through_abort():
    """publish_demanded is the GC protocol's point of no return: a demanded
    rename published through it survives a later abort."""
    dev = MemDevice()
    fd = dev.open("/a/x", "w")
    dev.pwrite(fd, b"hi", 0)
    dev.close(fd)
    txn = StagingTxn(dev)
    runner, rec = txn.stage_rename(("/a/x", "/a/y"))
    runner(dev)
    txn.on_demand(rec)
    txn.publish_demanded()
    txn.finalize(ok=False)  # the abort must NOT rename back
    assert "/a/y" in dev._files and "/a/x" not in dev._files


# -- retention policy: pure-core property tests --------------------------------

if HAS_HYPOTHESIS:
    @st.composite
    def _histories(draw, allow_delta=True):
        """Realistic save histories: strictly increasing steps,
        nondecreasing wall time, each delta based on the previous save
        (exactly what the manager produces)."""
        n = draw(st.integers(min_value=0, max_value=12))
        hist, step, t = [], 0, 0.0
        for _ in range(n):
            step += draw(st.integers(min_value=1, max_value=5))
            t += draw(st.floats(min_value=0.0, max_value=10.0,
                                allow_nan=False))
            if allow_delta and hist and draw(st.booleans()):
                kind, base = "delta", hist[-1].step
            else:
                kind, base = "full", None
            hist.append(SaveInfo(step=step, wall_time=t, kind=kind,
                                 base=base))
        return hist

    _policies = st.builds(CheckpointPolicy,
                          keep_last=st.integers(min_value=1, max_value=4),
                          keep_spaced=st.integers(min_value=0, max_value=3),
                          spacing_s=st.sampled_from([1.0, 5.0, 30.0]))
    _policies_any = st.builds(CheckpointPolicy,
                              keep_last=st.integers(min_value=0, max_value=4),
                              keep_spaced=st.integers(min_value=0,
                                                      max_value=3),
                              spacing_s=st.sampled_from([1.0, 5.0, 30.0]))
else:  # stubs; @given degrades each test to a visible skip
    def _histories(allow_delta=True):
        return None

    _policies = _policies_any = None


@settings(max_examples=100, deadline=None)
@given(h=_histories(), p=_policies)
def test_keep_steps_satisfies_spec(h, p):
    """keep-set ⊆ history; newest keep_last always kept; newest keep_spaced
    anchors always kept; a kept delta always keeps its base (closure)."""
    keep = p.keep_steps(h)
    steps = sorted({s.step for s in h})
    by_step = {s.step: s for s in h}
    assert keep <= set(steps)
    assert set(steps[-p.keep_last:] if p.keep_last else []) <= keep
    if p.keep_spaced and h:
        assert set(p.anchors(h)[-p.keep_spaced:]) <= keep
    for s in keep:
        b = by_step[s].base
        if b is not None and b in by_step:
            assert b in keep, (s, b)


@settings(max_examples=100, deadline=None)
@given(h=_histories(), p=_policies)
def test_keep_steps_monotone_under_append(h, p):
    """Appending a save never *adds* older steps to the keep-set:
    keep(h + [x]) ⊆ keep(h) ∪ {x.step}.  Holds for manager-shaped
    histories (keep_last >= 1, deltas based on the previous save), which
    is what makes GC forward-only: a collected step stays collected."""
    for i in range(1, len(h) + 1):
        prev = p.keep_steps(h[:i - 1])
        cur = p.keep_steps(h[:i])
        assert cur <= prev | {h[i - 1].step}, (i, sorted(prev), sorted(cur))


@settings(max_examples=100, deadline=None)
@given(h=_histories(allow_delta=False), p=_policies_any)
def test_keep_steps_monotone_full_only_any_policy(h, p):
    """For full-save-only histories monotonicity needs no keep_last floor
    (no chain closure can reach back past the window)."""
    for i in range(1, len(h) + 1):
        prev = p.keep_steps(h[:i - 1])
        cur = p.keep_steps(h[:i])
        assert cur <= prev | {h[i - 1].step}


# deterministic policy examples (run even without hypothesis)

def test_keep_steps_examples():
    h = [SaveInfo(step=s, wall_time=float(s)) for s in range(5)]
    assert CheckpointPolicy(keep_last=2).keep_steps(h) == {3, 4}
    # spacing 2s over wall times 0..4 anchors 0, 2, 4; newest 2 = {2, 4}
    p = CheckpointPolicy(keep_last=1, keep_spaced=2, spacing_s=2.0)
    assert p.keep_steps(h) == {2, 4}
    assert CheckpointPolicy(keep_last=0, keep_spaced=0).keep_steps(h) == set()
    assert CheckpointPolicy().keep_steps([]) == frozenset()


def test_keep_steps_chain_closure_example():
    h = [SaveInfo(0, 0.0), SaveInfo(5, 1.0, "delta", 0),
         SaveInfo(9, 2.0, "delta", 5)]
    assert CheckpointPolicy(keep_last=1).keep_steps(h) == {9, 5, 0}
    assert chain_of(9, {s.step: s for s in h}) == [9, 5, 0]


# -- wall-clock monotonicity ----------------------------------------------------

@settings(max_examples=100, deadline=None)
@given(clocks=(st.lists(st.floats(min_value=0.0, max_value=1e4,
                                  allow_nan=False),
                        min_size=0, max_size=12)
               if HAS_HYPOTHESIS else st.none()),
       p=_policies)
def test_clamped_clock_keeps_policy_monotone(clocks, p):
    """The manager's wall-time clamp (running max over committed saves)
    turns ANY raw clock sequence — including one that steps backwards —
    into a manager-shaped history, and on that history the policy keep-set
    stays monotone under append (GC stays forward-only)."""
    hist, floor = [], 0.0
    for i, raw in enumerate(clocks):
        floor = max(raw, floor)  # the save()-side clamp
        hist.append(SaveInfo(step=i, wall_time=floor))
    assert all(a.wall_time <= b.wall_time for a, b in zip(hist, hist[1:]))
    for i in range(1, len(hist) + 1):
        prev = p.keep_steps(hist[:i - 1])
        cur = p.keep_steps(hist[:i])
        assert cur <= prev | {hist[i - 1].step}


def test_manager_clamps_backwards_clock(monkeypatch):
    """Manager-level: a system clock that steps backwards between saves
    must not produce a non-monotone committed history, and a *fresh*
    manager (restart) must recover the floor from on-disk manifests."""
    import repro.checkpoint.manager as mgr_mod
    ticks = iter([1000.0, 900.0, 950.0])
    monkeypatch.setattr(mgr_mod.time, "time", lambda: next(ticks))
    inner = MemDevice()
    fa = Foreactor(device=inner, backend="sync", depth=0)
    mgr = CheckpointManager(inner, ROOT, fa=fa, num_shards=SHARDS,
                            chunk_bytes=CHUNK, keep=10)
    for s in (0, 1):
        mgr.save(s, make_tree(s))
    # step 1 saved while the clock read 900 — clamped to step 0's 1000
    walls = [mgr.read_manifest(s)["wall_time"] for s in (0, 1)]
    assert walls == [1000.0, 1000.0]
    # restart: a fresh manager rebuilds the floor from committed manifests
    mgr2 = CheckpointManager(inner, ROOT, fa=fa, num_shards=SHARDS,
                             chunk_bytes=CHUNK, keep=10)
    mgr2.save(2, make_tree(2))  # clock reads 950 — still behind the floor
    assert mgr2.read_manifest(2)["wall_time"] == 1000.0
    hist = mgr2.history()
    assert [s.step for s in hist] == [0, 1, 2]
    assert all(a.wall_time <= b.wall_time for a, b in zip(hist, hist[1:]))
    fa.shutdown()
