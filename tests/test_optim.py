"""Optimizer + gradient-compression codec tests."""

import jax
import jax.numpy as jnp
import numpy as np

from repro.optim.adamw import AdamWConfig, adamw_init, adamw_update, cosine_schedule
from repro.optim.compress import dequantize_int8, int8_codec_roundtrip, quantize_int8


def test_adamw_reduces_quadratic_loss():
    cfg = AdamWConfig(lr=0.1, warmup_steps=1, total_steps=200, weight_decay=0.0)
    params = {"w": jnp.asarray([3.0, -2.0, 5.0])}
    st = adamw_init(cfg, params)
    for _ in range(200):
        grads = {"w": 2 * params["w"]}  # d/dw of w^2
        params, st, _ = adamw_update(cfg, params, grads, st)
    assert float(jnp.abs(params["w"]).max()) < 0.5


def test_adamw_master_is_distinct_buffer():
    cfg = AdamWConfig()
    params = {"w": jnp.ones(8, jnp.float32)}
    st = adamw_init(cfg, params)
    # donation safety: master must not alias the fp32 params
    assert st["master"]["w"].unsafe_buffer_pointer() != params["w"].unsafe_buffer_pointer()


def test_schedule_warmup_and_decay():
    cfg = AdamWConfig(lr=1.0, warmup_steps=10, total_steps=100, min_lr_ratio=0.1)
    lrs = [float(cosine_schedule(cfg, jnp.asarray(s))) for s in (0, 5, 10, 100)]
    assert lrs[0] == 0.0 and abs(lrs[1] - 0.5) < 1e-6
    assert abs(lrs[2] - 1.0) < 1e-6 and abs(lrs[3] - 0.1) < 1e-6


def test_int8_quantize_bounds():
    x = jnp.asarray(np.random.default_rng(0).normal(size=(64, 64)) * 5, jnp.float32)
    q, s = quantize_int8(x)
    err = np.abs(np.asarray(dequantize_int8(q, s) - x))
    assert err.max() <= float(s) * 0.5 + 1e-6  # half-ulp of the scale


def test_int8_error_feedback_preserves_sum():
    """x_hat + err == x + err_in: no gradient mass is lost across steps."""
    rng = np.random.default_rng(1)
    x = jnp.asarray(rng.normal(size=(128,)), jnp.float32)
    err = jnp.asarray(rng.normal(size=(128,)) * 0.01, jnp.float32)
    xhat, new_err = int8_codec_roundtrip(x, err)
    np.testing.assert_allclose(np.asarray(xhat + new_err),
                               np.asarray(x + err), rtol=1e-6, atol=1e-6)


def test_int8_error_feedback_converges_on_repeated_grads():
    """Accumulated quantized steps track the true sum (EF property)."""
    g = jnp.asarray([0.003, -1.0, 0.5, 2e-4], jnp.float32)
    err = None
    acc = jnp.zeros_like(g)
    for _ in range(100):
        xhat, err = int8_codec_roundtrip(g, err)
        acc = acc + xhat
    np.testing.assert_allclose(np.asarray(acc), np.asarray(100 * g),
                               rtol=0.02, atol=0.02)
